"""Unit tests for the futurization layer (paper §3.1 semantics)."""
import threading
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    Future,
    FutureState,
    Promise,
    async_,
    dataflow,
    get_runtime,
    make_ready_future,
    wait_all,
    when_all,
    when_any,
)


def test_ready_future():
    f = make_ready_future(42)
    assert f.done() and f.is_ready()
    assert f.get() == 42
    assert f.state is FutureState.READY


def test_failed_future_raises_on_get():
    f = Future.failed(ValueError("boom"))
    assert f.state is FutureState.FAILED
    with pytest.raises(ValueError, match="boom"):
        f.get()
    assert isinstance(f.exception(), ValueError)


def test_async_runs_on_pool():
    ident = async_(lambda: threading.current_thread().name).get()
    assert "repro-host" in ident


def test_then_chains_and_propagates_values():
    f = async_(lambda: 3).then(lambda v: v + 1).then(lambda v: v * 2)
    assert f.get() == 8


def test_then_propagates_failure_without_calling_fn():
    called = []
    f = Future.failed(RuntimeError("x")).then(lambda v: called.append(v))
    with pytest.raises(RuntimeError):
        f.get()
    assert called == []


def test_promise():
    p = Promise()
    f = p.get_future()
    assert not f.done()
    p.set_value("v")
    assert f.get() == "v"


def test_when_all_collects_in_order():
    fs = [async_(lambda i=i: (time.sleep(0.01 * (3 - i)), i)[1]) for i in range(3)]
    assert when_all(fs).get() == [0, 1, 2]


def test_when_all_empty():
    assert when_all([]).get() == []


def test_when_all_fails_fast():
    fs = [make_ready_future(1), Future.failed(KeyError("k"))]
    with pytest.raises(KeyError):
        when_all(fs).get()


def test_when_any_returns_first():
    slow = async_(lambda: (time.sleep(0.2), "slow")[1])
    fast = make_ready_future("fast")
    idx, val = when_any([slow, fast]).get()
    assert (idx, val) == (1, "fast")


def test_wait_all_blocks_until_done():
    done = []
    fs = [async_(lambda i=i: done.append(i)) for i in range(4)]
    wait_all(fs)
    assert sorted(done) == [0, 1, 2, 3]


def test_dataflow_mixes_futures_and_values():
    a = async_(lambda: 10)
    out = dataflow(lambda x, y, z=0: x + y + z, a, 5, z=async_(lambda: 1))
    assert out.get() == 16


def test_dataflow_chain_builds_graph():
    a = async_(lambda: jnp.arange(4.0))
    b = dataflow(jnp.sum, a)
    c = dataflow(lambda x, y: x + y, b, 4.0)
    assert float(c.get()) == 10.0


def test_from_array_resolves_to_ready_value():
    x = jnp.ones((8, 8)) @ jnp.ones((8, 8))
    f = Future.from_array(x)
    out = f.get()
    np.testing.assert_allclose(np.asarray(out), 8.0)


def test_from_array_then_continuation():
    x = jnp.full((4,), 2.0)
    got = Future.from_array(x).then(lambda a: float(jnp.sum(a))).get()
    assert got == 8.0


def test_future_exception_inside_dataflow():
    def bad(_):
        raise ZeroDivisionError

    f = dataflow(bad, make_ready_future(1))
    with pytest.raises(ZeroDivisionError):
        f.get()


def test_work_queue_preserves_fifo_order():
    q = get_runtime().queue("test-fifo")
    seen = []
    futs = [q.submit(lambda i=i: seen.append(i)) for i in range(32)]
    wait_all(futs)
    assert seen == list(range(32))


def test_work_queue_survives_task_exception():
    q = get_runtime().queue("test-exc")
    bad = q.submit(lambda: 1 / 0)
    good = q.submit(lambda: "ok")
    with pytest.raises(ZeroDivisionError):
        bad.get()
    assert good.get() == "ok"


# ---------------------------------------------------------------------------
# cancellation (serving-engine backpressure contract)
# ---------------------------------------------------------------------------


def test_cancel_pending_future_and_promise_discards_late_result():
    import concurrent.futures as cf

    p = Promise(name="cancel-me")
    f = p.get_future()
    assert f.cancel() and f.cancelled()
    assert f.cancel()  # idempotent (stdlib semantics: still cancelled)
    with pytest.raises(cf.CancelledError):
        f.get()
    assert isinstance(f.exception(), cf.CancelledError)
    assert f.state is FutureState.FAILED
    p.set_value(42)  # late result is discarded, never raised
    p.set_exception(RuntimeError("late error too"))


def test_cancel_completed_future_returns_false():
    assert not make_ready_future(1).cancel()
    p = Promise()
    p.set_value(2)
    assert not p.get_future().cancel()


def test_then_attached_before_cancel_fails_with_cancelled_error():
    import concurrent.futures as cf

    p = Promise(name="parent")
    f = p.get_future()
    g = f.then(lambda v: v + 1)  # pending path: callback registered
    assert f.cancel()
    with pytest.raises(cf.CancelledError):
        g.get(timeout=10)  # must resolve, not hang forever


def test_cancel_racing_inflight_resolver_discards_result():
    import concurrent.futures as cf

    started = threading.Event()

    def slow_resolver():
        started.set()
        time.sleep(0.2)
        return 42

    f = Future(resolver=slow_resolver, name="slow")
    outcome = []

    def consume():
        try:
            outcome.append(("value", f.get()))
        except cf.CancelledError:
            outcome.append(("cancelled", None))
        except BaseException as e:  # noqa: BLE001
            outcome.append(("error", e))

    t = threading.Thread(target=consume)
    t.start()
    started.wait(10)  # the consumer claimed the resolver and is producing
    assert f.cancel()
    t.join(10)
    # the produced value is discarded; the consumer sees CancelledError,
    # never InvalidStateError
    assert outcome == [("cancelled", None)]


def test_when_all_propagates_cancellation():
    import concurrent.futures as cf

    p1, p2 = Promise(), Promise()
    joined = when_all([p1.get_future(), p2.get_future()])
    p1.get_future().cancel()
    p2.set_value(1)
    assert isinstance(joined.exception(timeout=10), cf.CancelledError)
