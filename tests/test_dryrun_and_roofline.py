"""Dry-run record integrity + roofline-term tests.

The dry-run itself (32 cells x 2 meshes, 512 fake devices) runs out of
band (``python -m repro.launch.dryrun --all --both-meshes``); these tests
validate its outputs and the roofline math. They SKIP (not fail) when the
records have not been generated yet.
"""
import json
from pathlib import Path

import pytest

from repro.analysis.roofline import load_records, model_flops, roofline_terms
from repro.configs import cells, get_config, get_shape

RESULTS = Path(__file__).resolve().parents[1] / "results" / "dryrun"


def _records(tag):
    recs = {}
    for p in RESULTS.glob(f"*__{tag}.json"):
        r = json.loads(p.read_text())
        recs[(r["arch"], r["shape"])] = r
    return recs


@pytest.mark.parametrize("tag,n_dev", [("singlepod", 256), ("multipod", 512)])
def test_all_cells_compiled_without_error(tag, n_dev):
    recs = _records(tag)
    if not recs:
        pytest.skip(f"no {tag} dry-run records; run repro.launch.dryrun first")
    expected = set(cells())
    missing = expected - set(recs)
    assert not missing, f"missing cells: {sorted(missing)}"
    errors = {k for k, r in recs.items() if "error" in r}
    assert not errors, f"cells with errors: {sorted(errors)}"
    for r in recs.values():
        assert r["devices"] == n_dev


def test_cell_list_has_documented_skips():
    cs = cells()
    assert len(cs) == 32  # 10 archs x 4 shapes - 8 full-attention long_500k skips
    assert ("mamba2-130m", "long_500k") in cs
    assert ("hymba-1.5b", "long_500k") in cs
    assert ("deepseek-67b", "long_500k") not in cs


def test_model_flops_train_matches_6nd_leading_order():
    cfg = get_config("deepseek-67b")
    shape = get_shape("train_4k")
    mf = model_flops(cfg, shape)
    six_nd = 6.0 * cfg.param_count() * shape.global_batch * shape.seq_len
    assert mf >= six_nd
    assert mf < 1.5 * six_nd  # attention term is a correction, not dominant


def test_moe_uses_active_params():
    cfg = get_config("phi3.5-moe-42b-a6.6b")
    shape = get_shape("train_4k")
    mf = model_flops(cfg, shape)
    six_active = 6.0 * cfg.active_param_count() * shape.global_batch * shape.seq_len
    six_total = 6.0 * cfg.param_count() * shape.global_batch * shape.seq_len
    assert mf < 0.6 * six_total  # nowhere near dense cost
    assert mf >= six_active


def test_roofline_terms_shape():
    recs = _records("singlepod")
    if not recs:
        pytest.skip("no records")
    r = next(iter(recs.values()))
    t = roofline_terms(r)
    assert t["bound"] in ("compute", "memory", "collective")
    assert t["step_seconds"] == max(t["compute_s"], t["memory_s"], t["collective_s"])
    assert 0 <= t["mfu"] <= 1.5


def test_collectives_present_in_multipod():
    """The pod axis must actually be exercised: multi-pod records should
    show collective traffic for training cells."""
    recs = _records("multipod")
    if not recs:
        pytest.skip("no multipod records")
    r = recs.get(("deepseek-67b", "train_4k"))
    if r is None or "error" in r:
        pytest.skip("deepseek multipod record missing")
    assert sum(r["hlo"]["collective_counts"].values()) > 0
