"""Continuous-batching ``RequestEngine`` (DESIGN.md §12): admission /
backpressure / cancellation, micro-batch assembly with bucketed padding,
batch-aware scheduler placement, captured-graph replay on an engine
stream, per-request slice resolution (bit-equal to unbatched execution),
loopback + 2-process-cluster fan-out, and the forced-8-device spread."""
import os
import subprocess
import sys
import textwrap
import time

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import Scheduler, get_all_devices, wait_all
from repro.core.executor import QueueLoad
from repro.serving import EngineClosed, QueueFull, RequestEngine

# Linear elementwise step: jit-fused, per-op eager and remote-eager all
# produce the SAME bits, so one reference covers every execution route.
def _linear_step(x):
    return x * 2.0 + 1.0


def _linear_ref(p):
    return np.asarray(p, np.float32) * 2.0 + 1.0


@pytest.fixture(scope="module")
def device():
    return get_all_devices(1, 0).get()[0]


@pytest.fixture()
def engine(device):
    eng = RequestEngine(
        _linear_step,
        max_batch=4,
        max_delay_s=0.005,
        scheduler=Scheduler([device], policy="least_loaded"),
        name="t-linear",
    )
    yield eng
    eng.close()


# ---------------------------------------------------------------------------
# admission surface
# ---------------------------------------------------------------------------


def test_submit_rejects_rowless_and_ragged_payloads(engine):
    with pytest.raises(ValueError, match="leading row axis"):
        engine.submit(np.float32(3.0))
    with pytest.raises(ValueError, match="disagree"):
        engine.submit({"a": np.ones((1, 4), np.float32), "b": np.ones((2, 4), np.float32)})
    with pytest.raises(KeyError, match="no kind"):
        engine.submit(np.ones((1, 2), np.float32), kind="nope")
    # oversize requests are refused at admission — queued, they could
    # never join any group and would wedge the queue behind them forever
    with pytest.raises(ValueError, match="max_batch"):
        engine.submit(np.ones((5, 4), np.float32))  # engine max_batch=4


def test_requests_batch_and_resolve_bit_equal_slices(engine):
    rng = np.random.default_rng(0)
    payloads = [rng.normal(size=(1, 16)).astype(np.float32) for _ in range(10)]
    futs = [engine.submit(p) for p in payloads]
    for p, f in zip(payloads, futs):
        got = f.get(timeout=60)
        want = _linear_ref(p)
        assert isinstance(got, np.ndarray) and got.shape == p.shape
        assert got.dtype == want.dtype and np.array_equal(got, want)
    m = engine.metrics()
    assert m["requests_completed"] >= 10
    assert m["batches"] < 10  # continuous batching actually batched
    assert m["mean_batch_rows"] > 1.0


def test_multi_row_requests_slice_correctly(engine):
    rng = np.random.default_rng(1)
    p2 = rng.normal(size=(2, 16)).astype(np.float32)
    p3 = rng.normal(size=(3, 16)).astype(np.float32)
    f2, f3 = engine.submit(p2), engine.submit(p3)
    assert np.array_equal(f2.get(timeout=60), _linear_ref(p2))
    assert np.array_equal(f3.get(timeout=60), _linear_ref(p3))


def test_broadcast_leaves_gate_batch_compatibility(device):
    eng = RequestEngine(
        lambda b: {"y": b["x"] * b["scale"]},
        max_batch=8,
        max_delay_s=0.02,
        scheduler=Scheduler([device], policy="least_loaded"),
        name="t-bcast",
    )
    try:
        futs = [
            eng.submit({"x": np.full((1, 4), float(i), np.float32),
                        "scale": np.float32(2.0 if i % 2 == 0 else 3.0)})
            for i in range(6)
        ]
        for i, f in enumerate(futs):
            scale = 2.0 if i % 2 == 0 else 3.0
            np.testing.assert_array_equal(f.get(timeout=60)["y"], np.full((1, 4), scale * i, np.float32))
        # two distinct broadcast values can never share a micro-batch
        assert eng.metrics()["batches"] >= 2
    finally:
        eng.close()


def test_backpressure_queue_full_and_cancellation(device):
    eng = RequestEngine(
        _linear_step,
        max_batch=2,
        max_delay_s=10.0,  # deadline never fires during the test
        max_queue=3,
        scheduler=Scheduler([device], policy="least_loaded"),
        name="t-bp",
    )
    try:
        eng.submit(np.ones((2, 4), np.float32)).get(timeout=60)  # warm the route
        time.sleep(0.05)
        futs = [eng.submit(np.ones((1, 4), np.float32)) for _ in range(3)]
        with pytest.raises(QueueFull, match="backpressure"):
            eng.submit(np.ones((1, 4), np.float32))
        assert futs[2].cancel()  # pending: cancellable
        assert futs[2].cancelled()
    finally:
        eng.close()  # drains the two live requests
    assert np.array_equal(futs[0].get(), _linear_ref(np.ones((1, 4), np.float32)))
    assert np.array_equal(futs[1].get(), _linear_ref(np.ones((1, 4), np.float32)))
    assert eng.metrics()["requests_cancelled"] == 1


def test_close_cancel_pending_fails_fast(device):
    eng = RequestEngine(
        _linear_step,
        max_batch=8,
        max_delay_s=10.0,
        scheduler=Scheduler([device], policy="least_loaded"),
        name="t-close",
    )
    f = eng.submit(np.ones((1, 4), np.float32))
    eng.close(cancel_pending=True)
    with pytest.raises(EngineClosed):
        f.get(timeout=10)
    with pytest.raises(EngineClosed):
        eng.submit(np.ones((1, 4), np.float32))


def test_failing_step_fails_every_member_future(device):
    def boom(x):
        raise RuntimeError("step exploded")

    eng = RequestEngine(
        boom, max_batch=4, max_delay_s=0.005,
        scheduler=Scheduler([device], policy="least_loaded"), name="t-boom",
    )
    try:
        futs = [eng.submit(np.ones((1, 2), np.float32)) for _ in range(3)]
        for f in futs:
            with pytest.raises(RuntimeError, match="step exploded"):
                f.get(timeout=60)
        assert eng.metrics()["requests_failed"] == 3
    finally:
        eng.close()


def test_metrics_latency_and_throughput(engine):
    futs = [engine.submit(np.ones((1, 8), np.float32)) for _ in range(6)]
    wait_all(futs)
    engine.drain()
    m = engine.metrics()
    assert m["requests_completed"] >= 6
    assert 0.0 < m["latency_p50_s"] <= m["latency_p99_s"]
    assert m["requests_per_s"] > 0.0
    assert m["queue_high_water"] >= 1


# ---------------------------------------------------------------------------
# padding buckets: the executable cache must hit a handful of shapes
# ---------------------------------------------------------------------------


def test_bucketed_padding_reuses_compiled_routes(device):
    eng = RequestEngine(
        _linear_step, max_batch=8, max_delay_s=0.004,
        scheduler=Scheduler([device], policy="least_loaded"), name="t-bucket",
    )
    try:
        rng = np.random.default_rng(3)
        payloads = [rng.normal(size=(1, 8)).astype(np.float32) for _ in range(30)]
        futs = [eng.submit(p) for p in payloads]
        for p, f in zip(payloads, futs):
            assert np.array_equal(f.get(timeout=60), _linear_ref(p))
        # every compiled graph route is bucket-shaped — occupancy varied
        # over 30 requests, compiled shapes must not
        buckets = {k[2] for k in eng._graphs}
        assert buckets.issubset({1, 2, 4, 8})
        m = eng.metrics()
        assert m["padded_rows"] >= 0 and m["rows"] == 30
    finally:
        eng.close()


def test_broadcast_values_share_one_compiled_route(device):
    """A decode ``pos`` that changes every step must REUSE the compiled
    graph route (fed at replay), not compile one executable per value."""
    eng = RequestEngine(
        lambda b: {"y": b["x"] + b["pos"].astype(np.float32)},
        max_batch=2,
        max_delay_s=0.002,
        scheduler=Scheduler([device], policy="least_loaded"),
        name="t-routekey",
    )
    try:
        for pos in range(6):  # six distinct broadcast values, same shapes
            got = eng.submit(
                {"x": np.zeros((1, 4), np.float32), "pos": np.int32(pos)}
            ).get(timeout=60)
            np.testing.assert_array_equal(got["y"], np.full((1, 4), float(pos), np.float32))
        routes = [k for k, v in eng._graphs.items() if v is not None]
        assert routes, "graph route was never built"
        assert len({k[1] for k in routes}) == 1  # ONE route key across all pos
        assert len(routes) <= 2  # at most one per bucket actually used
    finally:
        eng.close()


# ---------------------------------------------------------------------------
# graph replay route: engine stream + replay-with-feeds
# ---------------------------------------------------------------------------


def test_engine_uses_graph_replay_on_engine_stream(engine, device):
    futs = [engine.submit(np.ones((1, 16), np.float32)) for _ in range(4)]
    wait_all(futs)
    assert engine._graphs, "no captured graph route was built"
    entry = next(iter(engine._graphs.values()))
    assert entry is not None and not entry.exe._fanout
    # the engine owns a dedicated stream on the device it placed on
    s = engine._streams[device.key]
    assert s.device is device and s is not device.default_stream


def test_graph_disabled_falls_back_to_direct(device):
    eng = RequestEngine(
        _linear_step, max_batch=4, max_delay_s=0.005, graph=False,
        scheduler=Scheduler([device], policy="least_loaded"), name="t-direct",
    )
    try:
        p = np.random.default_rng(4).normal(size=(1, 8)).astype(np.float32)
        assert np.array_equal(eng.submit(p).get(timeout=60), _linear_ref(p))
        assert not eng._graphs
    finally:
        eng.close()


def test_replay_stream_override_matches_default_lane(device):
    """GraphExec.replay(stream=...) — the engine's feed path — is bit-equal
    to a default-lane replay, and fan-out plans refuse the override."""
    from repro.core import capture

    prog = device.create_program({"k": _linear_step}, "rp").get()
    buf = device.create_buffer((4,), np.float32).get()
    with capture("stream-replay") as g:
        w = buf.enqueue_write(0, np.zeros(4, np.float32))
        node = prog.run([buf], "k")
    exe = g.instantiate()
    x = np.arange(4, dtype=np.float32)
    base = exe.replay(feeds={w: x}).get()[node]
    s = device.create_stream("replay-override")
    alt = s.replay(exe, feeds={w: x})
    # the replay future is a stream completion: events recorded after it
    # cover the replayed graph's device completion (Program.run contract)
    with s._lock:
        assert alt in s._completions
    ev = s.record()
    ev.wait()
    assert alt.done()
    np.testing.assert_array_equal(np.asarray(alt.get()[node]), np.asarray(base))

    # a fan-out exec resolved its lanes at instantiate: stream= refused
    b2 = device.create_buffer((4,), np.float32).get()
    o1 = device.create_buffer((4,), np.float32).get()
    o2 = device.create_buffer((4,), np.float32).get()
    with capture("fan") as g2:
        w2 = b2.enqueue_write(0, x)
        prog.run([b2], "k", out=[o1])  # independent chains -> fan-out
        prog.run([b2], "k", out=[o2])
    exe2 = g2.instantiate()
    if exe2._fanout:
        with pytest.raises(ValueError, match="fan-out"):
            exe2.replay(feeds={w2: x}, stream=s)


# ---------------------------------------------------------------------------
# batch-aware scheduler hook
# ---------------------------------------------------------------------------


class _FakeQueue:
    def __init__(self, depth=0):
        self.depth = depth

    def load(self):
        return QueueLoad(self.depth, 0, 0.0, 0.0, self.depth, 0)


class _FakeDevice:
    def __init__(self, key, depth=0):
        self.key = key
        self.ops_queue = _FakeQueue(depth)


class _FakeBuf:
    def __init__(self, device, nbytes):
        self.device, self.nbytes = device, nbytes


def test_select_batch_scores_the_union_of_member_args():
    d0, d1 = _FakeDevice("cpu:0"), _FakeDevice("cpu:1")
    sched = Scheduler([d0, d1], policy="affinity")
    # three requests: 2 small on d0, 1 large on d1 — the UNION wins for d0
    batch = [
        [_FakeBuf(d0, 600)],
        [_FakeBuf(d0, 600)],
        [_FakeBuf(d1, 1000)],
    ]
    assert sched.select_batch(batch).key == "cpu:0"
    assert sched.stats() == {"cpu:0": 1}  # one decision for the whole batch
    # flipped weights: the batch follows the bytes
    batch2 = [[_FakeBuf(d1, 5000)], [_FakeBuf(d0, 600)]]
    assert sched.select_batch(batch2).key == "cpu:1"


# ---------------------------------------------------------------------------
# route_batches failure-path coverage (ISSUE satellite)
# ---------------------------------------------------------------------------


def test_route_batches_closure_on_cross_process_locality_raises():
    from repro.serving.serve_step import route_batches

    class _Port:
        in_process = False

    class _Remote:
        is_remote_proxy = True
        key = "L9/cpu:0"
        _port = _Port()
        ops_queue = _FakeQueue()

    sched = Scheduler([_Remote()], policy="static")
    with pytest.raises(ValueError, match="kernel name"):
        route_batches(lambda b: b, [np.ones(4, np.float32)], scheduler=sched)


def test_route_batches_percolate_false_skips_device_put(device):
    from repro.serving.serve_step import route_batches

    sched = Scheduler([device], policy="static")
    marker = np.ones(4, np.float32)
    # percolate=False hands the batch through UNTOUCHED (identity), while
    # the default device_put stages a fresh jax.Array
    [kept] = route_batches(lambda b: b is marker, [marker], scheduler=sched, percolate=False)
    assert kept.get() is True
    [placed] = route_batches(lambda b: b, [marker], scheduler=sched)
    out = placed.get()
    assert out is not marker and isinstance(out, jax.Array)


def test_route_batches_kernel_name_local_matches_loopback():
    from repro.core import LoopbackParcelport
    from repro.serving.serve_step import route_batches

    x = np.random.default_rng(6).normal(size=(64,)).astype(np.float32)
    dev = get_all_devices().get()[0]
    [local] = route_batches("partition_map_ref", [x], scheduler=Scheduler([dev], policy="static"))
    local_val = np.asarray(local.get())
    port = LoopbackParcelport(n_localities=1)
    try:
        [remote] = route_batches(
            "partition_map_ref", [x], scheduler=Scheduler(port.devices(), policy="static")
        )
        remote_val = np.asarray(remote.get())
    finally:
        port.shutdown()
    assert remote_val.dtype == local_val.dtype
    np.testing.assert_array_equal(remote_val, local_val)


# ---------------------------------------------------------------------------
# serve-engine decode: micro-batched decode == per-request decode
# ---------------------------------------------------------------------------


def test_make_serve_engine_batched_decode_matches_per_request(device):
    from repro.configs import get_config, smoke
    from repro.models import get_model
    from repro.serving import cache_to_rows, make_serve_engine
    from repro.serving.serve_step import make_serve_step

    cfg = smoke(get_config("olmo-1b"))
    m = get_model(cfg)
    params = m.init(cfg, jax.random.key(0))
    step = jax.jit(make_serve_step(cfg))

    eng = make_serve_engine(
        cfg, params, max_batch=4, max_delay_s=0.02,
        scheduler=Scheduler([device], policy="least_loaded"),
    )
    try:
        rng = np.random.default_rng(0)
        reqs = []
        for _ in range(3):
            cache = m.init_cache(cfg, 1, 8, dtype=jnp.float32)
            tok = jnp.asarray(rng.integers(0, cfg.vocab_size, size=(1, 1)), jnp.int32)
            reqs.append({"cache": cache_to_rows(cache), "tokens": tok, "pos": np.int32(0)})
        futs = [eng.submit(r, kind="decode") for r in reqs]
        for r, f in zip(reqs, futs):
            got = f.get(timeout=300)
            from repro.serving import rows_to_cache

            nxt, logits, cache = step(
                params, rows_to_cache(r["cache"]), r["tokens"], r["pos"]
            )
            assert got["next"].shape == (1, 1)
            np.testing.assert_array_equal(got["next"], np.asarray(nxt))
            np.testing.assert_allclose(got["logits"], np.asarray(logits), rtol=2e-5, atol=2e-5)
            ref_leaves = jax.tree_util.tree_leaves(cache_to_rows(cache))
            got_leaves = jax.tree_util.tree_leaves(got["cache"])
            for a, b in zip(got_leaves, ref_leaves):
                np.testing.assert_allclose(a, np.asarray(b), rtol=2e-5, atol=2e-5)
    finally:
        eng.close()


# ---------------------------------------------------------------------------
# loopback fan-out: in-process localities through the parcel codec
# ---------------------------------------------------------------------------


def test_place_batch_sticks_by_route_and_rehomes_on_yield():
    """``_place_batch`` sends the route's home as the ``prefer`` hint on
    every batch after the first; there is no periodic withhold (a forced
    re-ask under a self-repelling load policy always migrates — a lane
    warmup per probe, not a fair comparison).  When the scheduler's own
    structural yield overrides the hint, the home follows the device the
    policy actually picked."""

    class _Dev:
        def __init__(self, key):
            self.key = key

    class _HintSched:
        """Honors the hint until told to yield (modeling the structural
        occupancy hysteresis breaking); otherwise self-repels to the
        next device (least_loaded bounced by its own recent charge)."""

        def __init__(self):
            self.prefers = []
            self.i = 0
            self.yield_now = False

        def select_batch(self, leaves, prefer=None):
            self.prefers.append(prefer)
            if prefer is not None and not self.yield_now:
                return _Dev(prefer)
            self.i += 1
            return _Dev(f"cpu:{self.i % 4}")

    class _Req:
        key = ("apply", None, ())
        leaves = [np.ones(4, np.float32)]

    eng = RequestEngine("partition_map_ref", name="t-sticky")
    try:
        sched = _HintSched()
        keys = [eng._place_batch(sched, [_Req()]).key for _ in range(12)]
        # cold start (no hint), then the home is hinted every batch
        assert sched.prefers[0] is None
        assert sched.prefers[1:12] == ["cpu:1"] * 11
        assert keys == ["cpu:1"] * 12            # never migrates unprompted
        # structural yield: the scheduler overrides the hint once...
        sched.yield_now = True
        assert eng._place_batch(sched, [_Req()]).key == "cpu:2"
        sched.yield_now = False
        # ...and the home follows the yield.
        assert eng._place_batch(sched, [_Req()]).key == "cpu:2"
        assert sched.prefers[-1] == "cpu:2"
    finally:
        eng.close()


def test_engine_spreads_micro_batches_over_loopback_localities():
    from repro.core import LoopbackParcelport

    port = LoopbackParcelport(n_localities=2)
    try:
        sched = Scheduler(port.devices(), policy="round_robin")
        eng = RequestEngine(
            "partition_map_ref", max_batch=2, max_delay_s=0.005,
            scheduler=sched, name="t-loop",
        )
        try:
            futs = [eng.submit(np.full((1, 8), float(i), np.float32)) for i in range(8)]
            for f in futs:
                np.testing.assert_allclose(f.get(timeout=60), np.ones((1, 8)), rtol=1e-6)
            assert len(sched.stats()) == 2  # both simulated localities took batches
            assert eng.metrics()["batches"] >= 2
        finally:
            eng.close()
    finally:
        port.shutdown()


def test_apply_batched_action_slices_rows_per_request():
    from repro.core import LoopbackParcelport, register_kernel

    port = LoopbackParcelport(n_localities=1)
    try:
        lid = port.localities()[0].process_index
        batch = np.arange(12, dtype=np.float32).reshape(4, 3)  # 3 real + 1 pad row
        chunks = port.call(
            lid, "apply_batched",
            {"kernel": "partition_map_ref", "batch": batch, "rows": [1, 2]},
        ).get()
        assert len(chunks) == 2
        assert chunks[0].shape == (1, 3) and chunks[1].shape == (2, 3)
        np.testing.assert_allclose(np.concatenate(chunks), np.ones((3, 3)), rtol=1e-6)

        # a 0-d output leaf is shared per request, not row-sliced (the
        # same rule as the engine's local slice path)
        register_kernel(
            "t_engine_scalar_out",
            lambda b: {"rows": b * 2.0, "norm": jnp.float32(b.sum())},
        )
        chunks = port.call(
            lid, "apply_batched",
            {"kernel": "t_engine_scalar_out", "batch": batch, "rows": [2, 2]},
        ).get()
        assert chunks[0]["rows"].shape == (2, 3)
        assert chunks[0]["norm"].shape == () and chunks[1]["norm"] == chunks[0]["norm"]
    finally:
        port.shutdown()


# ---------------------------------------------------------------------------
# 2-process cluster: batched apply parcels end-to-end (acceptance)
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_engine_serves_over_2_process_cluster_bit_equal():
    from repro.core import LocalClusterParcelport
    from repro.kernels.partition_map.ref import partition_map_ref

    port = LocalClusterParcelport(n_workers=2, heartbeat_timeout=60.0)
    try:
        sched = Scheduler(port.devices(), policy="round_robin")
        eng = RequestEngine(
            "partition_map_ref", max_batch=4, max_delay_s=0.01,
            scheduler=sched, name="t-cluster",
        )
        try:
            rng = np.random.default_rng(5)
            payloads = [rng.normal(size=(1, 16)).astype(np.float32) for _ in range(8)]
            futs = [eng.submit(p) for p in payloads]
            for p, f in zip(payloads, futs):
                got = f.get(timeout=300)
                # the worker executes the registry kernel eagerly over the
                # padded batch; rows are independent, so each request's
                # slice is bit-equal to unbatched eager execution
                want = np.asarray(partition_map_ref(p))
                assert got.dtype == want.dtype and np.array_equal(got, want)
            assert len(sched.stats()) == 2  # both worker processes served
            m = eng.metrics()
            assert m["requests_completed"] == 8 and m["batches"] < 8
        finally:
            eng.close()
    finally:
        port.shutdown()


# ---------------------------------------------------------------------------
# forced-8-device integration (re-exec pattern, see test_scheduler.py)
# ---------------------------------------------------------------------------

_CHILD = textwrap.dedent(
    """
    import os, time
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                               "--xla_cpu_multi_thread_eigen=false "
                               + os.environ.get("XLA_FLAGS", ""))
    import numpy as np
    import jax
    from repro.core import Scheduler, get_all_devices, wait_all
    from repro.serving import RequestEngine

    devices = get_all_devices(1, 0).get()
    assert len(devices) == 8, devices

    def step(x):
        return x * 2.0 + 1.0

    sched = Scheduler(devices, policy="least_loaded")
    eng = RequestEngine(step, max_batch=4, max_delay_s=0.002,
                        scheduler=sched, name="fleet")
    rng = np.random.default_rng(0)
    payloads = [rng.normal(size=(1, 256)).astype(np.float32) for _ in range(64)]
    futs = [eng.submit(p) for p in payloads]
    wait_all(futs)
    for p, f in zip(payloads, futs):
        got = f.get()
        want = np.asarray(p) * 2.0 + 1.0
        assert got.dtype == want.dtype and np.array_equal(got, want)
    m = eng.metrics()
    spread = sched.stats()
    print("SPREAD", len(spread), "BATCHES", m["batches"])
    assert m["requests_completed"] == 64
    assert m["batches"] < 64                       # batching happened
    # ONE request stream = ONE route: sticky placement pins it to the
    # device whose caches it warmed (DESIGN.md S17) instead of spraying
    # the fleet; the fleet engages only on structural backlog.
    assert len(spread) == 1, spread
    eng.close()
    print("OK")
    """
)


@pytest.mark.slow
def test_engine_integration_8_host_devices():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src:" + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-c", _CHILD],
        capture_output=True,
        text=True,
        env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=900,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "OK" in proc.stdout, proc.stdout
