"""Async checkpoint / restore / fail-stop resume tests (paper Fig. 5
pattern + DESIGN.md §6), including save atomicity under a mid-write
process kill (publish-by-rename: ``latest_step()`` is never torn)."""
import subprocess
import sys
import textwrap
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpoint import CheckpointManager


def _state(seed=0):
    k = jax.random.key(seed)
    return {
        "params": {"w": jax.random.normal(k, (8, 4)), "b": jnp.zeros(4)},
        "opt": {"m": jnp.ones((8, 4)), "step": jnp.int32(7)},
    }


def test_save_restore_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    st = _state()
    mgr.save_async(10, st, extra={"cursor": 42}).get()
    like = jax.tree.map(jnp.zeros_like, st)
    restored, extra = mgr.restore(like)
    assert extra["cursor"] == 42
    for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_save_is_asynchronous(tmp_path):
    """save_async returns before the write lands; the future resolves it."""
    mgr = CheckpointManager(str(tmp_path))
    big = {"x": jnp.ones((512, 512))}
    t0 = time.perf_counter()
    fut = mgr.save_async(1, big)
    t_submit = time.perf_counter() - t0
    info = fut.get()
    assert info["step"] == 1
    # submission must be much faster than the full write
    assert t_submit < max(info["seconds"], 0.05) + 0.05


def test_retention_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    st = _state()
    for s in (1, 2, 3, 4):
        mgr.save_async(s, st).get()
    assert mgr.steps() == [3, 4]


def test_latest_and_missing(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    assert mgr.latest_step() is None
    with pytest.raises(FileNotFoundError):
        mgr.restore({"a": jnp.zeros(1)})


def test_kill_mid_save_never_tears_latest_step(tmp_path):
    """A writer killed in the middle of ``save_async`` must leave only the
    previously-published checkpoint visible: the half-written step stays a
    ``.tmp`` staging dir (never listed, restore never reads it) and the
    next manager sweeps it."""
    child = textwrap.dedent(
        """
        import os
        import numpy as np
        from repro.checkpoint.checkpoint import CheckpointManager

        d = os.environ["CKPT_DIR"]
        mgr = CheckpointManager(d)
        state = {"w": np.arange(64, dtype=np.float32)}
        mgr.save_async(1, state).get()          # durable baseline

        real_savez = np.savez
        def torn_savez(path, **arrays):          # half the bytes, then die
            real_savez(path, **arrays)
            with open(path, "r+b") as f:
                f.truncate(os.path.getsize(path) // 2)
            os._exit(42)                         # no atexit, no cleanup
        np.savez = torn_savez
        mgr.save_async(2, state).get()
        """
    )
    env = {**__import__("os").environ, "CKPT_DIR": str(tmp_path), "PYTHONPATH": "src"}
    proc = subprocess.run([sys.executable, "-c", child], env=env, cwd="/root/repo",
                          capture_output=True, text=True, timeout=240)
    assert proc.returncode == 42, proc.stderr

    leftovers = sorted(p.name for p in tmp_path.iterdir())
    assert "step_00000002.tmp" in leftovers  # the kill really was mid-write

    mgr = CheckpointManager(str(tmp_path))  # crash-restart: sweeps the orphan
    assert mgr.steps() == [1]
    assert mgr.latest_step() == 1  # never the torn step
    restored, _ = mgr.restore({"w": np.zeros(64, np.float32)})
    np.testing.assert_array_equal(restored["w"], np.arange(64, dtype=np.float32))
    assert not list(tmp_path.glob("*.tmp"))  # orphan swept on construction


def test_resave_of_restored_step_replaces_published_dir(tmp_path):
    """Re-saving a step that already exists (resume at k, checkpoint at k
    again) atomically replaces the published dir instead of failing."""
    mgr = CheckpointManager(str(tmp_path))
    mgr.save_async(3, {"x": jnp.zeros(4)}).get()
    mgr.save_async(3, {"x": jnp.ones(4)}).get()
    assert mgr.steps() == [3]
    restored, _ = mgr.restore({"x": jnp.zeros(4)})
    np.testing.assert_array_equal(np.asarray(restored["x"]), np.ones(4))


def test_failstop_resume_is_deterministic(tmp_path):
    """Kill-and-resume must reproduce the uninterrupted run exactly:
    train 8 straight vs train 4 + restore + 4 -> identical final loss."""
    from repro.launch.train import train

    full = train(
        "olmo-1b", use_smoke=True, steps=8, batch=2, seq=16, lr=1e-3,
        ckpt_dir=str(tmp_path / "a"), ckpt_every=100, log_every=0, seed=5,
    )
    part1 = train(
        "olmo-1b", use_smoke=True, steps=4, batch=2, seq=16, lr=1e-3,
        ckpt_dir=str(tmp_path / "b"), ckpt_every=4, log_every=0, seed=5,
        schedule_total=8,  # LR horizon must match the uninterrupted run
    )
    # "crash": start a fresh process state and resume from the checkpoint
    resumed = train(
        "olmo-1b", use_smoke=True, steps=8, batch=2, seq=16, lr=1e-3,
        ckpt_dir=str(tmp_path / "b"), resume=True, ckpt_every=100, log_every=0, seed=5,
    )
    np.testing.assert_allclose(resumed["final_loss"], full["final_loss"], rtol=1e-5)
