"""Async checkpoint / restore / fail-stop resume tests (paper Fig. 5
pattern + DESIGN.md §6)."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpoint import CheckpointManager


def _state(seed=0):
    k = jax.random.key(seed)
    return {
        "params": {"w": jax.random.normal(k, (8, 4)), "b": jnp.zeros(4)},
        "opt": {"m": jnp.ones((8, 4)), "step": jnp.int32(7)},
    }


def test_save_restore_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    st = _state()
    mgr.save_async(10, st, extra={"cursor": 42}).get()
    like = jax.tree.map(jnp.zeros_like, st)
    restored, extra = mgr.restore(like)
    assert extra["cursor"] == 42
    for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_save_is_asynchronous(tmp_path):
    """save_async returns before the write lands; the future resolves it."""
    mgr = CheckpointManager(str(tmp_path))
    big = {"x": jnp.ones((512, 512))}
    t0 = time.perf_counter()
    fut = mgr.save_async(1, big)
    t_submit = time.perf_counter() - t0
    info = fut.get()
    assert info["step"] == 1
    # submission must be much faster than the full write
    assert t_submit < max(info["seconds"], 0.05) + 0.05


def test_retention_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    st = _state()
    for s in (1, 2, 3, 4):
        mgr.save_async(s, st).get()
    assert mgr.steps() == [3, 4]


def test_latest_and_missing(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    assert mgr.latest_step() is None
    with pytest.raises(FileNotFoundError):
        mgr.restore({"a": jnp.zeros(1)})


def test_failstop_resume_is_deterministic(tmp_path):
    """Kill-and-resume must reproduce the uninterrupted run exactly:
    train 8 straight vs train 4 + restore + 4 -> identical final loss."""
    from repro.launch.train import train

    full = train(
        "olmo-1b", use_smoke=True, steps=8, batch=2, seq=16, lr=1e-3,
        ckpt_dir=str(tmp_path / "a"), ckpt_every=100, log_every=0, seed=5,
    )
    part1 = train(
        "olmo-1b", use_smoke=True, steps=4, batch=2, seq=16, lr=1e-3,
        ckpt_dir=str(tmp_path / "b"), ckpt_every=4, log_every=0, seed=5,
        schedule_total=8,  # LR horizon must match the uninterrupted run
    )
    # "crash": start a fresh process state and resume from the checkpoint
    resumed = train(
        "olmo-1b", use_smoke=True, steps=8, batch=2, seq=16, lr=1e-3,
        ckpt_dir=str(tmp_path / "b"), resume=True, ckpt_every=100, log_every=0, seed=5,
    )
    np.testing.assert_allclose(resumed["final_loss"], full["final_loss"], rtol=1e-5)
