"""Logical-axis sharding resolver unit tests (divisibility, axis reuse)."""
import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import cells, get_config, get_shape
from repro.distribution.recipes import plan_for
from repro.distribution.sharding import make_rules, spec_for


class FakeMesh:
    axis_names = ("data", "model")

    class devices:  # noqa: D106 - just needs .shape
        shape = (16, 16)


MESH = FakeMesh()
RULES = {"batch": ("data",), "heads": "model", "mlp": "model", "seq": None}


def test_spec_basic():
    assert spec_for(("batch", "seq", "heads"), RULES) == P(("data",), None, "model")


def test_spec_trailing_none_trimmed():
    assert spec_for(("batch", "seq"), RULES) == P(("data",))


def test_divisibility_drops_rule():
    # heads=36 does not divide model=16 -> replicated
    s = spec_for(("batch", "heads"), RULES, shape=(32, 36), mesh=MESH)
    assert s == P(("data",))
    s2 = spec_for(("batch", "heads"), RULES, shape=(32, 32), mesh=MESH)
    assert s2 == P(("data",), "model")


def test_axis_used_once():
    rules = {"a": "model", "b": "model"}
    s = spec_for(("a", "b"), rules, shape=(16, 16), mesh=MESH)
    assert s == P("model")  # second claim on "model" dropped


def test_batch_not_shardable_when_too_small():
    s = spec_for(("batch",), RULES, shape=(1,), mesh=MESH)
    assert s == P()


@pytest.mark.parametrize("arch,shape", cells())
def test_plans_materialize_for_all_cells(arch, shape):
    cfg = get_config(arch)
    plan = plan_for(cfg, get_shape(shape))
    assert plan.rules["batch"] is None or plan.rules["batch"] == ("data",)
    if cfg.moe is not None:
        if cfg.moe.strategy == "ep":
            assert plan.rules["p_experts"] == "model"
        else:
            assert plan.rules["p_expert_mlp"] == "model"
    if shape.startswith("long"):
        assert plan.rules["batch"] is None  # batch=1 cannot shard
