"""Elastic restart: a checkpoint saved under one mesh restores onto a
DIFFERENT mesh shape with correct values and shardings (DESIGN.md §6).

Runs in a subprocess with 4 host devices: save params sharded on a
(2, 2) (data, model) mesh -> restore onto (4, 1) and (1, 4) meshes.
"""
import os
import subprocess
import sys
import textwrap

import pytest

_CHILD = textwrap.dedent(
    """
    import os, tempfile
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4 " + os.environ.get("XLA_FLAGS", "")
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.checkpoint.checkpoint import CheckpointManager
    from repro.configs import get_config, smoke
    from repro.distribution.recipes import plan_for
    from repro.configs.base import ShapeConfig
    from repro.distribution.sharding import tree_sharding
    from repro.launch.mesh import make_host_mesh
    from repro.models import get_model

    cfg = smoke(get_config("stablelm-1.6b"))
    m = get_model(cfg)
    rules = plan_for(cfg, ShapeConfig("t", 32, 4, "train")).rules
    pspecs = m.param_specs(cfg)

    # save under mesh A (2 data x 2 model)
    mesh_a = make_host_mesh(data=2, model=2)
    params = m.init(cfg, jax.random.key(3))
    sh_a = tree_sharding(mesh_a, pspecs, rules, params)
    params_a = jax.device_put(params, sh_a)
    d = tempfile.mkdtemp(prefix="elastic_")
    mgr = CheckpointManager(d)
    mgr.save_async(1, params_a, extra={"step": 1}).get()

    ok = True
    for shape in ((4, 1), (1, 4)):
        mesh_b = make_host_mesh(data=shape[0], model=shape[1])
        sh_b = tree_sharding(mesh_b, pspecs, rules, params)
        like = jax.tree.map(jnp.zeros_like, params)
        restored, extra = mgr.restore(like, shardings=sh_b)
        for orig, new in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
            if not np.array_equal(np.asarray(orig), np.asarray(new)):
                ok = False
        # the restored arrays really live under mesh B's sharding
        leaf = jax.tree.leaves(restored)[0]
        assert leaf.sharding.mesh.shape == dict(zip(("data", "model"), shape)), leaf.sharding
    print("ELASTIC_OK" if ok else "ELASTIC_MISMATCH")
    """
)


@pytest.mark.slow
def test_restore_onto_different_mesh():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src:" + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-c", _CHILD],
        capture_output=True,
        text=True,
        env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=600,
    )
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "ELASTIC_OK" in proc.stdout, proc.stdout + proc.stderr[-500:]
