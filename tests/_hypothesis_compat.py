"""Deterministic fallback for ``hypothesis`` when it isn't installed.

The property tests in this suite use a small subset of the hypothesis API
(``@settings``, ``@given``, ``st.integers/sampled_from/floats``).  When the
real library is available it is used (see requirements-dev.txt); when it is
missing — e.g. the minimal CPU-JAX container — this shim runs each property
test over a fixed, seeded sample of the strategy space instead of skipping
it, so tier-1 collection and coverage survive without the dependency.
"""
from __future__ import annotations

import random
from types import SimpleNamespace

# Property sweeps are slower than example tests (Pallas interpret mode);
# keep the fallback sample count small and deterministic.
_FALLBACK_EXAMPLES = 3
_SEED = 0xC0FFEE


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example_for(self, rng: random.Random):
        return self._draw(rng)


def _integers(min_value, max_value):
    return _Strategy(lambda rng: rng.randint(min_value, max_value))


def _sampled_from(elements):
    elems = list(elements)
    return _Strategy(lambda rng: elems[rng.randrange(len(elems))])


def _floats(min_value, max_value):
    return _Strategy(lambda rng: rng.uniform(min_value, max_value))


strategies = SimpleNamespace(integers=_integers, sampled_from=_sampled_from, floats=_floats)


def settings(max_examples=None, deadline=None, **_ignored):
    def deco(fn):
        if max_examples is not None:
            # applied above @given: fn is the given-wrapper
            fn._fallback_max_examples = min(max_examples, _FALLBACK_EXAMPLES)
        return fn

    return deco


def given(**strats):
    def deco(fn):
        # NOTE: no functools.wraps — pytest must see the (*args, **kwargs)
        # signature, not the wrapped one, or it would demand fixtures named
        # after the strategy keys.
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_fallback_max_examples", _FALLBACK_EXAMPLES)
            rng = random.Random(_SEED)
            for _ in range(n):
                drawn = {k: s.example_for(rng) for k, s in strats.items()}
                fn(*args, **drawn, **kwargs)

        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper._fallback_max_examples = _FALLBACK_EXAMPLES
        return wrapper

    return deco
