"""Data-pipeline prefetch + fault-monitor unit tests."""
import time

import numpy as np

from repro.data.pipeline import Pipeline, SyntheticTokens
from repro.fault.monitor import Heartbeat, StepMonitor


def test_synthetic_tokens_deterministic_by_index():
    src = SyntheticTokens(1000, 16, 4, seed=9)
    a = src.batch(3)
    b = src.batch(3)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = src.batch(4)
    assert not np.array_equal(a["tokens"], c["tokens"])
    np.testing.assert_array_equal(a["labels"][:, :-1], a["tokens"][:, 1:])


def test_pipeline_order_and_resume():
    src = SyntheticTokens(100, 8, 2, seed=1)
    pipe = Pipeline(src, depth=2)
    i0, b0 = pipe.get()
    i1, b1 = pipe.get()
    assert (i0, i1) == (0, 1)
    cursor = pipe.state()["cursor"]
    assert cursor == 2
    # resume from cursor reproduces the stream
    pipe2 = Pipeline(src, start=cursor, depth=2)
    i2, b2 = pipe2.get()
    assert i2 == 2
    np.testing.assert_array_equal(np.asarray(b2["tokens"]), src.batch(2)["tokens"])


def test_pipeline_prefetch_overlaps_slow_producer():
    class Slow(SyntheticTokens):
        def batch(self, i):
            time.sleep(0.05)
            return super().batch(i)

    pipe = Pipeline(Slow(100, 8, 2), depth=3)
    time.sleep(0.25)  # let prefetch fill
    t0 = time.perf_counter()
    pipe.get()
    pipe.get()
    assert time.perf_counter() - t0 < 0.09  # served from prefetch, not 2x50ms


def test_heartbeat_detects_death():
    died = []
    hb = Heartbeat(timeout_s=0.05, on_dead=lambda: died.append(1))
    hb.tick()
    assert hb.check()
    time.sleep(0.08)
    assert not hb.check()
    assert died == [1]


def test_heartbeat_flap_fires_on_dead_per_death():
    # dead -> tick (recovery) -> dead again: the latch must CLEAR on
    # recovery so the second death fires on_dead again (it used to stick
    # forever after the first miss)
    died = []
    hb = Heartbeat(timeout_s=0.05, on_dead=lambda: died.append(1))
    hb.tick()
    time.sleep(0.08)
    assert not hb.check()
    assert not hb.check()  # still dead: edge-triggered, no re-fire
    assert died == [1]
    hb.tick()  # worker resumes
    assert hb.check()  # recovery reads alive AND re-arms the latch
    time.sleep(0.08)
    assert not hb.check()
    assert died == [1, 1]  # second death fired again


def test_step_monitor_flags_stragglers():
    mon = StepMonitor(alpha=0.5, threshold=2.0, warmup=2)
    for i in range(5):
        assert mon.record(i, 0.1) is None
    ev = mon.record(5, 0.5)
    assert ev is not None and ev.ratio > 2
    # straggler does not poison the EWMA
    assert mon.ewma < 0.2
    assert mon.record(6, 0.1) is None
