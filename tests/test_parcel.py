"""Parcel transport & remote actions (DESIGN.md §10): codec round-trips
(property-based), loopback parcelport semantics, percolation-aware
placement over the localities × devices grid, heartbeat fail-fast, and a
real 2-process cluster integration run (mandelbrot on a remote locality
vs ref.py, bit-identical run_on_any, multi-locality graph replay)."""
import time

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # minimal container: seeded fallback sweeps
    from _hypothesis_compat import given, settings, strategies as st

from repro.core import (
    LocalClusterParcelport,
    LoopbackParcelport,
    Parcel,
    Program,
    RemoteProgram,
    Scheduler,
    get_all_devices,
    get_all_localities,
    locality_of,
    register_kernel,
    registry,
    wait_all,
)
from repro.core.futures import Future
from repro.core.parcel import (
    RemoteError,
    decode_parcel,
    dumps,
    encode_parcel,
    loads,
    resolve_kernel,
)
from repro.core.scheduler import PercolationPolicy, locality_of_key

# ---------------------------------------------------------------------------
# codec: unit + property-based round trips
# ---------------------------------------------------------------------------

# every dtype the kernels/ packages touch (float32/int32) plus common wire
# companions; arrays of each must round-trip bit-exactly
_KERNEL_DTYPES = ["<f4", "<i4", "<f8", "<i8", "<f2", "|u1", "|b1"]


def test_codec_scalars_and_containers_roundtrip():
    vals = [
        None, True, False, 0, -1, 2**70, -(2**70), 3.5, float("inf"),
        complex(1.0, -2.0), "héllo", b"\x00\xff", (), [], {},
        [1, "a", (2.0, None)], {"k": [True, {"n": b"x"}], 7: "seven"},
    ]
    for v in vals:
        assert loads(dumps(v)) == v, v
    # NaN needs its own comparison
    out = loads(dumps(float("nan")))
    assert isinstance(out, float) and np.isnan(out)


def test_codec_numpy_scalars_keep_dtype():
    for v in (np.float32(1.5), np.int32(-7), np.float16(0.25), np.uint8(255)):
        out = loads(dumps(v))
        assert out.dtype == v.dtype and out == v


def test_codec_rejects_object_dtype_and_unknown_types():
    with pytest.raises(ValueError, match="not parcel-encodable"):
        dumps(np.array([object()]))
    with pytest.raises(ValueError, match="not parcel-encodable"):
        dumps(lambda: None)  # no code on the wire, ever


def test_codec_zero_dim_arrays_keep_rank():
    # np.ascontiguousarray silently promotes 0-d to (1,); the codec must
    # not (apply_batched replies carry 0-d output leaves)
    for v in (np.zeros((), np.float32), np.array(7, np.int64)):
        out = loads(dumps(v))
        assert out.shape == () and out.dtype == v.dtype and out == v


def test_codec_noncontiguous_arrays_roundtrip():
    a = np.arange(24, dtype=np.float32).reshape(4, 6)[:, ::2]  # strided view
    out = loads(dumps(a))
    np.testing.assert_array_equal(out, a)
    f = np.asfortranarray(np.arange(12, dtype=np.int32).reshape(3, 4))
    np.testing.assert_array_equal(loads(dumps(f)), f)


@settings(max_examples=25, deadline=None)
@given(
    descr=st.sampled_from(_KERNEL_DTYPES),
    n=st.integers(min_value=0, max_value=257),
    seed=st.integers(min_value=0, max_value=2**16),
)
def test_codec_array_roundtrip_is_bit_exact(descr, n, seed):
    rng = np.random.default_rng(seed)
    dt = np.dtype(descr)
    if dt.kind == "b":
        arr = rng.integers(0, 2, size=n).astype(dt)
    elif dt.kind in "iu":
        arr = rng.integers(0, 100, size=n).astype(dt)
    else:
        arr = rng.normal(size=n).astype(dt)
    out = loads(dumps(arr))
    assert out.dtype == arr.dtype and out.shape == arr.shape
    assert out.tobytes() == arr.tobytes()  # bit-exact, not just allclose


@settings(max_examples=15, deadline=None)
@given(
    name=st.sampled_from(
        ["mandelbrot", "mandelbrot_ref", "partition_map", "stencil", "ssd", "flash_attention"]
    )
)
def test_codec_kernel_name_refs_roundtrip_and_resolve(name):
    blob = dumps({"kernel": name, "args": [("gid", 7), ("val", 1.5)]})
    out = loads(blob)
    assert out["kernel"] == name and out["args"][0] == ("gid", 7)
    assert callable(resolve_kernel(out["kernel"]))


@settings(max_examples=15, deadline=None)
@given(
    exc_i=st.integers(min_value=0, max_value=4),
    msg=st.sampled_from(["boom", "", "unicode-ø", "two words"]),
)
def test_codec_exceptions_roundtrip_by_type(exc_i, msg):
    cls = [KeyError, ValueError, RuntimeError, IndexError, ZeroDivisionError][exc_i]
    out = loads(dumps(cls(msg)))
    assert type(out) is cls and out.args == (msg,)


def test_codec_unknown_exception_degrades_to_remote_error():
    class Private(Exception):  # not importable on a "remote" locality
        pass

    out = loads(dumps(Private("secret")))
    assert isinstance(out, (Private, RemoteError))  # same-process resolves; else carrier


def test_parcel_frame_roundtrip():
    p = Parcel("launch", {"kernel": "k", "args": [("val", np.ones(3, np.float32))]},
               pid=42, locality=3)
    q = decode_parcel(encode_parcel(p))
    assert (q.action, q.pid, q.locality, q.ok) == ("launch", 42, 3, True)
    np.testing.assert_array_equal(q.payload["args"][0][1], np.ones(3, np.float32))
    bad = decode_parcel(encode_parcel(Parcel("reply", {"error": KeyError("gone")}, 1, 2, ok=False)))
    assert not bad.ok and type(bad.payload["error"]) is KeyError


def test_codec_rejects_corrupt_frames():
    with pytest.raises(ValueError, match="corrupt parcel"):
        loads(b"\x7fgarbage")
    with pytest.raises(ValueError, match="trailing"):
        loads(dumps(1) + b"x")


# ---------------------------------------------------------------------------
# percolation policy: the localities × devices grid (duck-typed fakes)
# ---------------------------------------------------------------------------


class _FakeQueue:
    def __init__(self, depth=0):
        self.depth = depth

    def load(self):
        from repro.core import QueueLoad

        return QueueLoad(self.depth, 0, 0.0, 0.0, self.depth, 0)


class _FakeDevice:
    def __init__(self, key, depth=0, alive=True):
        self.key = key
        self.ops_queue = _FakeQueue(depth)
        self._alive = alive

    def alive(self):
        return self._alive


class _FakeBuf:
    def __init__(self, device, nbytes):
        self.device, self.nbytes = device, nbytes


def test_locality_of_key():
    assert locality_of_key("cpu:0") == 0
    assert locality_of_key("L3/cpu:0") == 3
    assert locality_of_key("L12/tpu:5") == 12
    assert locality_of_key(None) == 0


def test_percolation_policy_prefers_the_data_home():
    local = _FakeDevice("cpu:0")
    r1, r2 = _FakeDevice("L1/cpu:0"), _FakeDevice("L2/cpu:0")
    args = [_FakeBuf(r1, 1 << 20)]
    assert PercolationPolicy().select([local, r1, r2], args=args).key == "L1/cpu:0"


def test_percolation_policy_charges_cross_locality_moves_more():
    # 1MB on L1 vs 200KB local: moving the local bytes to L1 costs
    # 200KB * 8 (cross) = 1.6MB > moving the remote 1MB home (1MB * 8 from
    # L1 -> local is worse too) — staying local costs only the remote 8MB?
    # Score directly: candidate L1 pays 200KB*8; candidate local pays 1MB*8.
    local = _FakeDevice("cpu:0")
    r1 = _FakeDevice("L1/cpu:0")
    args = [_FakeBuf(r1, 1 << 20), _FakeBuf(local, 200 << 10)]
    assert PercolationPolicy().select([local, r1], args=args).key == "L1/cpu:0"


def test_percolation_policy_falls_back_to_load_without_resident_bytes():
    d0, d1 = _FakeDevice("cpu:0", depth=5), _FakeDevice("L1/cpu:0", depth=0)
    assert PercolationPolicy().select([d0, d1], args=[np.ones(4)]).key == "L1/cpu:0"


def test_scheduler_excludes_dead_localities_and_raises_when_fleet_is_gone():
    ok, dead = _FakeDevice("L1/cpu:0", alive=True), _FakeDevice("L2/cpu:0", alive=False)
    s = Scheduler([dead, ok], policy="round_robin")
    assert all(s.select().key == "L1/cpu:0" for _ in range(3))
    s_all_dead = Scheduler([dead], policy="round_robin")
    with pytest.raises(RuntimeError, match="no live devices"):
        s_all_dead.select()


# ---------------------------------------------------------------------------
# loopback parcelport: full parcel path, zero process machinery
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def loopback():
    port = LoopbackParcelport(n_localities=2)
    yield port
    port.shutdown()


def test_loopback_discovers_remote_localities(loopback):
    locs = loopback.localities()
    assert len(locs) == 2 and all(not l.is_local for l in locs)
    assert all(len(l) >= 1 for l in locs)
    # cluster-wide discovery appends them to the local groups
    all_locs = get_all_localities(cluster=loopback).get()
    assert len(all_locs) >= 3
    assert any(l.is_local for l in all_locs)


def test_loopback_buffer_roundtrip_and_free(loopback):
    rdev = loopback.localities()[0].devices[0]
    data = np.arange(16, dtype=np.float32)
    buf = rdev.create_buffer_from(data).get()
    np.testing.assert_array_equal(buf.enqueue_read_sync(), data)
    buf.enqueue_write(0, data * 3).get()
    np.testing.assert_array_equal(buf.enqueue_read_sync(), data * 3)
    buf.free().get()
    with pytest.raises(KeyError, match="not a live parcel-created buffer"):
        buf.enqueue_read_sync()


def test_loopback_launch_by_registered_name_with_remote_out(loopback):
    register_kernel("tp_scale3", lambda x: x * 3.0)
    rdev = loopback.localities()[1].devices[0]
    prog = rdev.create_program("tp_scale3", name="t").get()
    assert isinstance(prog, RemoteProgram)
    src = rdev.create_buffer_from(np.arange(8, dtype=np.float32)).get()
    out = rdev.create_buffer(8, np.float32).get()
    prog.run([src], "tp_scale3", out=[out]).get()  # gid-ref args, results stay remote
    np.testing.assert_allclose(out.enqueue_read_sync(), np.arange(8.0) * 3.0)
    wait_all([src.free(), out.free()])


def test_loopback_unknown_kernel_fails_descriptively(loopback):
    rdev = loopback.localities()[0].devices[0]
    with pytest.raises(KeyError, match="not resolvable"):
        rdev.create_program(["no_such_kernel_anywhere"]).get()


def test_remote_launch_error_travels_as_exception(loopback):
    register_kernel("tp_raiser", lambda x: (_ for _ in ()).throw(ValueError("kernel blew up")))
    rdev = loopback.localities()[0].devices[0]
    prog = rdev.create_program("tp_raiser").get()
    fut = prog.run([np.ones(2, np.float32)], "tp_raiser")
    with pytest.raises(ValueError, match="kernel blew up"):
        fut.get()


def test_local_program_percolates_remote_buffer_arguments(loopback):
    # RemoteBuffer arg to a LOCAL program: explicit transfer (read parcel)
    # then a local launch — the percolation direction remote -> local.
    register_kernel("tp_add1", lambda x: x + 1.0)
    rdev = loopback.localities()[0].devices[0]
    rbuf = rdev.create_buffer_from(np.full(4, 2.0, np.float32)).get()
    dev = get_all_devices().get()[0]
    prog = Program(dev, {"tp_add1": lambda x: x + 1.0}, "local")
    res = prog.run([rbuf], "tp_add1").get()
    np.testing.assert_allclose(np.asarray(res), np.full(4, 3.0))
    rbuf.free().get()


def test_run_on_any_cluster_routes_to_remote_locality(loopback):
    register_kernel("tp_square", lambda x: x * x)
    dev = get_all_devices().get()[0]
    prog = Program(dev, {"tp_square": lambda x: x * x}, "sq")
    sched = Scheduler(loopback.devices(), policy="least_loaded")
    x = np.arange(6, dtype=np.float32)
    fut = prog.run_on_any([x], "tp_square", scheduler=sched)
    np.testing.assert_allclose(np.asarray(fut.get()[0]), x * x)
    assert all(k.startswith("L") for k in sched.stats())  # placed remotely


def test_route_batches_fans_across_loopback_localities(loopback):
    from repro.serving.serve_step import route_batches

    sched = Scheduler(loopback.devices(), policy="round_robin")
    batches = [np.full(4, i, np.float32) for i in range(4)]
    futs = route_batches("partition_map_ref", batches, scheduler=sched)
    for f in futs:
        np.testing.assert_allclose(np.asarray(f.get()), np.ones(4), rtol=1e-6)
    assert len(sched.stats()) == 2  # both simulated localities took work


def test_remote_buffer_bytes_feed_the_agas_reverse_index():
    # A cluster-style proxy records its remote placement locally; loopback
    # shares this process's registry, so exercise register_proxy directly.
    from repro.core import Placement
    from repro.core.agas import registry as reg

    class _Obj:
        pass

    obj = _Obj()
    fake_gid = (77 << 40) | 123  # minted by "locality 77"
    assert locality_of(fake_gid) == 77
    assert reg.register_proxy(obj, fake_gid, Placement("L77/cpu:0", 77), kind="buffer", nbytes=4096)
    try:
        assert reg.resolve(fake_gid) is obj
        assert reg.resident_bytes("L77/cpu:0") >= 4096
        assert not reg.register_proxy(obj, fake_gid, Placement("L77/cpu:0", 77))  # no double
    finally:
        reg.unregister(fake_gid)
    with pytest.raises(KeyError, match="owned by locality L77"):
        reg.resolve(fake_gid)


def test_collected_remote_buffer_retires_its_proxy_record(loopback):
    # A proxy under a foreign-minted GID (cluster-style registration) must
    # retire its registry record — and its resident-bytes — on GC, not
    # only on explicit free() (same leak contract as local Buffers).
    import gc

    from repro.core.device import RemoteBuffer

    rdev = loopback.localities()[0].devices[0]
    foreign_gid = (88 << 40) | 5  # not a loopback-shared GID: proxy registers
    base = registry.resident_bytes(rdev.key)
    buf = RemoteBuffer(rdev, foreign_gid, (256,), np.float32)
    assert buf._proxied and registry.resident_bytes(rdev.key) == base + 1024
    del buf
    gc.collect()
    assert registry.resident_bytes(rdev.key) == base
    with pytest.raises(KeyError):
        registry.resolve(foreign_gid)


def test_loopback_steal_fetch_batches_buffer_reads(loopback):
    # the cross-locality steal path (DESIGN.md §14): one parcel returns
    # every requested buffer, bit-exactly, in request order
    rdev = loopback.localities()[0].devices[0]
    a = np.arange(16, dtype=np.float32)
    b = np.linspace(-1.0, 1.0, 32, dtype=np.float32)
    ba = rdev.create_buffer_from(a).get()
    bb = rdev.create_buffer_from(b).get()
    arrays = loopback.call(rdev.locality_id, "steal_fetch",
                           {"gids": [ba.gid, bb.gid]}).get()
    assert len(arrays) == 2
    assert np.asarray(arrays[0]).tobytes() == a.tobytes()
    assert np.asarray(arrays[1]).tobytes() == b.tobytes()
    wait_all([ba.free(), bb.free()])


def test_steal_prefetch_resolves_remote_args_in_one_parcel(loopback):
    # what a thief pump does before running a cross-locality stolen
    # launch: remote buffer args become host arrays, the rest pass through
    rdev = loopback.localities()[1].devices[0]
    a = np.full(8, 2.0, np.float32)
    b = np.full(8, 5.0, np.float32)
    ba = rdev.create_buffer_from(a).get()
    bb = rdev.create_buffer_from(b).get()
    dev = get_all_devices().get()[0]
    sched = Scheduler([dev])
    passthrough = np.ones(3, np.float32)
    fetched = sched._prefetch_stolen_args(dev, [ba, passthrough, bb])
    assert np.asarray(fetched[0]).tobytes() == a.tobytes()
    assert fetched[1] is passthrough
    assert np.asarray(fetched[2]).tobytes() == b.tobytes()
    wait_all([ba.free(), bb.free()])


# ---------------------------------------------------------------------------
# cluster integration: 2 real worker processes (ISSUE acceptance criteria)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def cluster():
    port = LocalClusterParcelport(n_workers=2, heartbeat_timeout=60.0)
    yield port
    port.shutdown()


def test_cluster_mandelbrot_on_remote_locality_matches_ref(cluster):
    from repro.kernels.mandelbrot.ref import mandelbrot_ref

    rdev = cluster.localities()[0].devices[0]
    assert not rdev.is_local and rdev.key.startswith("L")
    prog = rdev.create_program(["mandelbrot"], name="mandel").get()
    res = prog.run([np.array([24, 32], np.int32)], "mandelbrot").get()
    np.testing.assert_array_equal(np.asarray(res[0]), np.asarray(mandelbrot_ref(24, 32)))


def test_cluster_run_on_any_is_bit_identical_to_local(cluster):
    from repro.kernels.partition_map.ref import partition_map_ref

    dev = get_all_devices().get()[0]
    prog = Program(dev, {"partition_map_ref": partition_map_ref}, "pm")
    x = np.random.default_rng(7).normal(size=(1024,)).astype(np.float32)
    local = np.asarray(prog.run([x], "partition_map_ref").get())

    remote_devs = cluster.devices()
    assert len({d.locality_id for d in remote_devs}) >= 2  # >= 2 worker processes
    for rdev in remote_devs:  # every worker produces the bit-identical answer
        sched = Scheduler([rdev], policy="static")
        fut = prog.run_on_any([x], "partition_map_ref", scheduler=sched)
        remote = np.asarray(fut.get()[0])
        assert remote.dtype == local.dtype and np.array_equal(remote, local)
        assert sched.stats() == {rdev.key: 1}


def test_cluster_multi_locality_graph_replays_through_one_future(cluster):
    from repro.core import capture
    from repro.kernels.partition_map.ref import partition_map_ref

    da = cluster.localities()[0].devices[0]
    db = cluster.localities()[1].devices[0]
    assert da.locality_id != db.locality_id
    pa = da.create_program(["partition_map_ref"], name="ga").get()
    pb = db.create_program(["partition_map_ref"], name="gb").get()

    dev = get_all_devices().get()[0]
    b_in = dev.create_buffer(128, np.float32).get()
    mid = dev.create_buffer(128, np.float32).get()
    out = dev.create_buffer(128, np.float32).get()
    x = np.random.default_rng(3).normal(size=(128,)).astype(np.float32)
    with capture("xlocality") as g:
        w = b_in.enqueue_write(0, x)
        pa.run([b_in], "partition_map_ref", out=[mid])  # segment on locality A
        pb.run([mid], "partition_map_ref", out=[out])   # segment on locality B
        r = out.enqueue_read()
    exe = g.instantiate()
    assert exe._fanout and len(exe._segments) == 2, repr(exe)
    assert {s.device.locality_id for s in exe._segments} == {da.locality_id, db.locality_id}

    fut = exe.replay()  # ONE future for the whole cross-process graph
    assert isinstance(fut, Future)
    res = fut.get()
    expect = np.asarray(partition_map_ref(partition_map_ref(x)))
    np.testing.assert_allclose(res[r], expect, rtol=1e-6)
    # re-fed replay (cudaGraphExecKernelNodeSetParams analogue) still works
    y = np.random.default_rng(4).normal(size=(128,)).astype(np.float32)
    res2 = exe.replay(feeds={w: y}).get()
    np.testing.assert_allclose(res2[r], np.asarray(partition_map_ref(partition_map_ref(y))), rtol=1e-6)


def test_cluster_route_batches_ships_apply_parcels(cluster):
    from repro.serving.serve_step import route_batches

    sched = Scheduler(cluster.devices(), policy="round_robin")
    batches = [np.full(8, float(i), np.float32) for i in range(4)]
    futs = route_batches("partition_map_ref", batches, scheduler=sched)
    for f in futs:
        np.testing.assert_allclose(np.asarray(f.get()), np.ones(8), rtol=1e-6)
    assert len(sched.stats()) == 2  # both worker processes took batches
    # a closure cannot cross the process boundary: descriptive refusal
    with pytest.raises(ValueError, match="kernel name"):
        route_batches(lambda b: b, [np.ones(2, np.float32)],
                      scheduler=Scheduler(cluster.devices(), policy="static"))


def test_cluster_remote_build_compiles_ahead(cluster):
    import jax

    rdev = cluster.localities()[0].devices[0]
    prog = rdev.create_program(["partition_map_ref"], name="bld").get()
    # Listing-2 overlap: ship the compile ahead of the data as its own parcel
    prog.build("partition_map_ref", jax.ShapeDtypeStruct((32,), np.float32)).get()
    res = prog.run([np.ones(32, np.float32)], "partition_map_ref").get()
    np.testing.assert_allclose(np.asarray(res[0]), np.ones(32), rtol=1e-6)


def test_cluster_remote_resident_pipeline_keeps_bytes_remote(cluster):
    """Write once, launch against the GID, read once: the kernel argument
    and result never transit the parent between the two parcels."""
    rdev = cluster.localities()[1].devices[0]
    x = np.linspace(0.0, 1.0, 64, dtype=np.float32)
    rbuf = rdev.create_buffer_from(x).get()
    rout = rdev.create_buffer(64, np.float32).get()
    assert registry.placement(rbuf.gid).device_key == rdev.key  # proxy record
    assert locality_of(rbuf.gid) == rdev.locality_id  # minted by the worker
    prog = rdev.create_program(["partition_map_ref"], name="resident").get()
    prog.run([rbuf], "partition_map_ref", out=[rout]).get()
    np.testing.assert_allclose(rout.enqueue_read_sync(), np.ones(64), rtol=1e-6)
    wait_all([rbuf.free(), rout.free()])


def test_cluster_steal_fetch_crosses_a_real_process_boundary(cluster):
    rdev = cluster.localities()[0].devices[0]
    a = np.random.default_rng(7).normal(size=(128,)).astype(np.float32)
    b = np.random.default_rng(8).normal(size=(64,)).astype(np.float32)
    ba = rdev.create_buffer_from(a).get()
    bb = rdev.create_buffer_from(b).get()
    arrays = cluster.call(rdev.locality_id, "steal_fetch",
                          {"gids": [ba.gid, bb.gid]}).get()
    assert np.asarray(arrays[0]).tobytes() == a.tobytes()
    assert np.asarray(arrays[1]).tobytes() == b.tobytes()
    wait_all([ba.free(), bb.free()])


def test_cluster_heartbeat_flap_recovers_and_reenters_placement():
    # satellite fix: a locality latched dead for a MISSED HEARTBEAT (the
    # process is alive) must flow work again once it answers the monitor's
    # recovery probe — before, port-level ``dead`` stayed latched forever.
    port = LocalClusterParcelport(n_workers=1, heartbeat_timeout=2.0)
    try:
        rdev = port.localities()[0].devices[0]
        lid = rdev.locality_id
        assert port.call(lid, "ping", {}).get() == "pong"
        port._mark_dead(lid, "missed its heartbeat deadline (test-induced flap)")
        assert not port.alive(lid)
        with pytest.raises(RuntimeError, match="failed"):
            port.call(lid, "ping", {}).get()  # fail-fast while latched
        with pytest.raises(RuntimeError, match="no live devices"):
            Scheduler([rdev]).select()  # excluded from placement
        deadline = time.monotonic() + 20
        while not port.alive(lid) and time.monotonic() < deadline:
            time.sleep(0.1)
        assert port.alive(lid), "flapped worker was never re-admitted"
        assert port.call(lid, "ping", {}).get() == "pong"
        assert Scheduler([rdev]).select() is rdev  # back in the fleet
    finally:
        port.shutdown()


# ---------------------------------------------------------------------------
# shared-memory lane + pipelined channel (DESIGN.md §13)
# ---------------------------------------------------------------------------

# 512 KB of float32: at the default REPRO_PARCEL_SHM_MIN threshold, so the
# payload rides the shared-memory lane in BOTH directions on an shm port.
_SHM_N = 1 << 17


def _psm_segments():
    import glob

    return set(glob.glob("/dev/shm/psm_*"))


def test_cluster_shm_lane_roundtrip_is_bit_exact():
    from repro.core.parcel import shm_available

    if not shm_available():
        pytest.skip("no usable /dev/shm in this environment")
    port = LocalClusterParcelport(n_workers=1, heartbeat_timeout=60.0, shm=True)
    try:
        assert port._shm_ok
        rdev = port.localities()[0].devices[0]
        x = np.random.default_rng(5).normal(size=(_SHM_N,)).astype(np.float32)
        rbuf = rdev.create_buffer_from(x).get()  # parent -> worker via shm
        back = rbuf.enqueue_read_sync()          # worker -> parent via shm
        assert back.dtype == x.dtype and back.tobytes() == x.tobytes()
        # a launch whose argument and reply both cross the lane stays
        # bit-identical to the same launch on a local device
        from repro.kernels.partition_map.ref import partition_map_ref

        dev = get_all_devices().get()[0]
        local = np.asarray(Program(dev, {"partition_map_ref": partition_map_ref}, "shm-l")
                           .run([x], "partition_map_ref").get())
        prog = rdev.create_program(["partition_map_ref"], name="shm").get()
        res = np.asarray(prog.run([x], "partition_map_ref").get()[0])
        assert res.tobytes() == local.tobytes()
        rbuf.free().get()
    finally:
        port.shutdown()


def test_cluster_shm_off_falls_back_to_inline_wire():
    # shm=False must force every payload inline on the pipe — same results,
    # no lane involvement, regardless of size.
    port = LocalClusterParcelport(n_workers=1, heartbeat_timeout=60.0, shm=False)
    try:
        assert not port._shm_ok
        rdev = port.localities()[0].devices[0]
        x = np.random.default_rng(6).normal(size=(_SHM_N,)).astype(np.float32)
        rbuf = rdev.create_buffer_from(x).get()
        assert rbuf.enqueue_read_sync().tobytes() == x.tobytes()
        rbuf.free().get()
    finally:
        port.shutdown()


def test_cluster_shm_segments_do_not_leak_after_shutdown():
    from repro.core.parcel import shm_available

    if not shm_available():
        pytest.skip("no usable /dev/shm in this environment")
    before = _psm_segments()
    port = LocalClusterParcelport(n_workers=1, heartbeat_timeout=60.0, shm=True)
    try:
        rdev = port.localities()[0].devices[0]
        x = np.random.default_rng(7).normal(size=(_SHM_N,)).astype(np.float32)
        for _ in range(3):  # several lane crossings, both directions
            rbuf = rdev.create_buffer_from(x).get()
            assert rbuf.enqueue_read_sync().tobytes() == x.tobytes()
            rbuf.free().get()
        rdev.synchronize()
    finally:
        port.shutdown()
    leaked = _psm_segments() - before
    assert not leaked, f"shm segments leaked past shutdown: {sorted(leaked)}"


def test_cluster_pipelined_channel_orders_and_fences():
    port = LocalClusterParcelport(n_workers=1, heartbeat_timeout=60.0)
    try:
        assert port.pipelined  # the default channel stages + flushes
        rdev = port.localities()[0].devices[0]
        rbuf = rdev.create_buffer_from(np.zeros(16, np.float32)).get()
        futs = [rbuf.enqueue_write(0, np.full(16, float(i), np.float32)) for i in range(8)]
        # synchronize() rides the "barrier" action through the worker's
        # action pool, so its reply proves every staged parcel executed —
        # a drained lane alone only proves dispatch.
        rdev.synchronize()
        # channel FIFO: staging order == execution order -> last write wins
        np.testing.assert_array_equal(rbuf.enqueue_read_sync(), np.full(16, 7.0))
        wait_all(futs)

        prog = rdev.create_program(["partition_map_ref"], name="pipe").get()
        x = np.random.default_rng(8).normal(size=(1024,)).astype(np.float32)
        burst = [prog.run([x], "partition_map_ref") for _ in range(6)]  # in flight together
        outs = [np.asarray(f.get()[0]) for f in burst]
        assert all(o.tobytes() == outs[0].tobytes() for o in outs)
        rbuf.free().get()
    finally:
        port.shutdown()


def test_cluster_pipeline_off_uses_blocking_channel():
    port = LocalClusterParcelport(n_workers=1, heartbeat_timeout=60.0, pipeline=False)
    try:
        assert not port.pipelined
        rdev = port.localities()[0].devices[0]
        prog = rdev.create_program(["partition_map_ref"], name="nopipe").get()
        x = np.random.default_rng(9).normal(size=(256,)).astype(np.float32)
        res = np.asarray(prog.run([x], "partition_map_ref").get()[0])
        np.testing.assert_allclose(res, np.ones(256), rtol=1e-5)
        rdev.synchronize()  # no-op fence on a blocking channel
    finally:
        port.shutdown()


# ---------------------------------------------------------------------------
# fault satellite: heartbeat exclusion + fail-fast; reset satellite last
# (reset_runtime tears down every live port, including module fixtures)
# ---------------------------------------------------------------------------


def test_zz_dead_worker_fails_fast_and_is_excluded_from_placement():
    port = LocalClusterParcelport(n_workers=1, heartbeat_timeout=2.0)
    try:
        rdev = port.localities()[0].devices[0]
        lid = rdev.locality_id
        assert rdev.alive() and port.call(lid, "ping", {}).get() == "pong"
        port._workers[lid].proc.kill()  # fail-stop: the worker vanishes
        deadline = time.monotonic() + 15
        while port.alive(lid) and time.monotonic() < deadline:
            time.sleep(0.1)
        assert not port.alive(lid), "heartbeat monitor never declared the worker dead"
        # new parcels fail fast, with the action and locality in the error
        with pytest.raises(RuntimeError, match="L.*failed"):
            rdev._call("ping").get()
        with pytest.raises(RuntimeError, match="failed"):
            port.call(lid, "enqueue_read", {"gid": 1}).get()
        # and the scheduler refuses to place there
        with pytest.raises(RuntimeError, match="no live devices"):
            Scheduler([rdev]).select()
    finally:
        port.shutdown()


def test_zzz_reset_runtime_shuts_down_live_parcelport_workers():
    from repro.core import reset_runtime

    port = LocalClusterParcelport(n_workers=1, heartbeat_timeout=60.0)
    procs = [w.proc for w in port._workers.values()]
    assert all(p.is_alive() for p in procs)
    loop = LoopbackParcelport(n_localities=1)
    reset_runtime()  # must drain + stop workers, not leak them past the test
    deadline = time.monotonic() + 10
    while any(p.is_alive() for p in procs) and time.monotonic() < deadline:
        time.sleep(0.1)
    assert not any(p.is_alive() for p in procs), "reset_runtime leaked worker processes"
    assert port._shut and loop._shut
    # the runtime rebuilds cleanly afterwards (same contract as the
    # scheduler reset test)
    fresh = get_all_devices().get()[0]
    buf = fresh.create_buffer_from(np.arange(4.0, dtype=np.float32)).get()
    np.testing.assert_allclose(buf.enqueue_read_sync(), np.arange(4.0))
