"""Task-graph capture & fused replay (DESIGN.md §8) + per-op fast paths."""
import numpy as np
import pytest

from repro.core import (
    Dim3,
    Future,
    TaskGraph,
    get_all_devices,
    get_runtime,
    make_ready_future,
    wait_all,
    when_all,
    when_any,
)


@pytest.fixture(scope="module")
def device():
    devices = get_all_devices(1, 0).get()
    assert len(devices) >= 1
    return devices[0]


@pytest.fixture()
def prog(device):
    return device.create_program(
        {"double": lambda x: x * 2.0, "inc": lambda x: x + 1.0, "axpy": lambda x, y: x + y},
        name="graph-test",
    ).get()


def _bufs(device, n, k):
    return [device.create_buffer(n, np.float32).get() for _ in range(k)]


# ---------------------------------------------------------------------------
# capture -> instantiate -> replay equivalence vs eager Program.run
# ---------------------------------------------------------------------------


def test_builder_replay_matches_eager(device, prog):
    n = 256
    host = np.linspace(-1.0, 1.0, n).astype(np.float32)

    # eager chain
    ebuf = device.create_buffer_from(host).get()
    etmp, eout = _bufs(device, n, 2)
    prog.run([ebuf], "double", out=[etmp]).get()
    prog.run([etmp], "inc", out=[eout]).get()
    want = eout.enqueue_read_sync()

    # graph chain over the same kernels
    gbuf, gtmp, gout = _bufs(device, n, 3)
    g = TaskGraph("chain")
    g.write(gbuf, host)
    g.run(prog, [gbuf], "double", out=[gtmp])
    g.run(prog, [gtmp], "inc", out=[gout])
    r = g.read(gout)
    exe = g.instantiate()
    res = exe.replay().get()

    np.testing.assert_allclose(res[r], want)
    np.testing.assert_allclose(gout.enqueue_read_sync(), want)


def test_capture_context_matches_eager(device, prog):
    n = 128
    host = np.arange(n, dtype=np.float32)
    buf = device.create_buffer_from(host).get()
    out = _bufs(device, n, 1)[0]

    with device.capture("cap") as g:
        node = prog.run([buf], "double", out=[out])
        r = out.enqueue_read()
    # capture returns graph nodes, not futures
    assert not isinstance(node, Future) and not isinstance(r, Future)

    exe = g.instantiate()
    res = exe.replay().get()
    np.testing.assert_allclose(res[r], host * 2.0)

    # replay is repeatable: extern inputs are never donated
    res2 = exe.replay().get()
    np.testing.assert_allclose(res2[r], host * 2.0)


def test_graph_fuses_same_device_chain(device, prog):
    n = 64
    bufs = _bufs(device, n, 4)
    g = TaskGraph("fuse4")
    g.write(bufs[0], np.ones(n, np.float32))
    g.run(prog, [bufs[0]], "inc", out=[bufs[1]])
    g.run(prog, [bufs[1]], "inc", out=[bufs[2]])
    g.run(prog, [bufs[2]], "inc", out=[bufs[3]])
    g.read(bufs[3])
    exe = g.instantiate()
    assert len(exe._segments) == 1  # 3 launches -> 1 fused executable
    res = exe.replay().get()
    np.testing.assert_allclose(res.reads[0], np.full(n, 4.0))


def test_replay_with_feeds_overrides_write(device, prog):
    n = 32
    buf, out = _bufs(device, n, 2)
    g = TaskGraph("feeds")
    w = g.write(buf, np.zeros(n, np.float32))
    g.run(prog, [buf], "inc", out=[out])
    r = g.read(out)
    exe = g.instantiate()

    np.testing.assert_allclose(exe.replay().get()[r], 1.0)
    new = np.full(n, 5.0, np.float32)
    np.testing.assert_allclose(exe.replay(feeds={w: new}).get()[r], 6.0)
    # feed by buffer key works too
    np.testing.assert_allclose(exe.replay(feeds={buf: new * 2}).get()[r], 11.0)


def test_graph_respects_grid_block_binding(device):
    seen = {}

    def k(x, grid=None, block=None):
        seen["grid"], seen["block"] = grid, block
        return x * 1.0

    prog = device.create_program({"k": k}, name="gb").get()
    buf = device.create_buffer_from(np.zeros(4, np.float32)).get()
    out = device.create_buffer(4, np.float32).get()
    g = TaskGraph("geo")
    g.run(prog, [buf], "k", grid=Dim3(2, 1, 1), block=(64, 1, 1), out=[out])
    g.instantiate().replay().get()
    assert seen["grid"] == (2, 1, 1)
    assert seen["block"] == (64, 1, 1)


def test_outless_launch_is_fetchable(device, prog):
    host = np.arange(8, dtype=np.float32)
    buf = device.create_buffer_from(host).get()
    g = TaskGraph("outless")
    node = g.run(prog, [buf], "double")
    res = g.instantiate().replay().get()
    np.testing.assert_allclose(np.asarray(res[node]), host * 2.0)


# ---------------------------------------------------------------------------
# buffer-donation safety
# ---------------------------------------------------------------------------


def test_donated_intermediate_not_readable_after_replay(device, prog):
    n = 64
    src, tmp, out = _bufs(device, n, 3)
    src.enqueue_write(0, np.ones(n, np.float32)).get()

    g = TaskGraph("donate")
    g.run(prog, [src], "double", out=[tmp])   # tmp: graph-internal
    g.run(prog, [tmp], "inc", out=[out])      # consumed by a later launch
    g.read(out)
    exe = g.instantiate()
    exe.replay().get()

    # tmp's storage went into the fused executable — reads must fail ...
    with pytest.raises(RuntimeError, match="donated"):
        tmp.array()
    with pytest.raises(RuntimeError, match="donated"):
        tmp.enqueue_read().get()

    # ... until it is written again.
    tmp.enqueue_write(0, np.zeros(n, np.float32)).get()
    np.testing.assert_allclose(tmp.enqueue_read_sync(), 0.0)

    # terminal + extern buffers stay live.
    np.testing.assert_allclose(out.enqueue_read_sync(), 3.0)
    np.testing.assert_allclose(src.enqueue_read_sync(), 1.0)


def test_jax_array_payload_survives_donating_replays(device, prog):
    import jax.numpy as jnp

    n = 16
    buf, out = _bufs(device, n, 2)
    payload = jnp.full((n,), 2.0, jnp.float32)  # adopted by reference
    g = TaskGraph("payload")
    g.write(buf, payload)
    g.run(prog, [buf], "inc", out=[out])
    r = g.read(out)
    exe = g.instantiate()
    for _ in range(3):  # donation must not consume the recorded payload
        np.testing.assert_allclose(exe.replay().get()[r], 3.0)
    np.testing.assert_allclose(np.asarray(payload), 2.0)


def test_read_sync_rejected_under_capture(device):
    buf = device.create_buffer_from(np.zeros(4, np.float32)).get()
    with device.capture("sync-read") as g:
        with pytest.raises(RuntimeError, match="capture"):
            buf.enqueue_read_sync()
    assert g._nodes == []  # the failed sync read recorded nothing


def test_frozen_graph_rejects_new_nodes(device, prog):
    buf = device.create_buffer_from(np.zeros(4, np.float32)).get()
    g = TaskGraph("frozen")
    g.run(prog, [buf], "double")
    g.instantiate()
    with pytest.raises(RuntimeError, match="frozen"):
        g.run(prog, [buf], "double")


def test_partial_write_rejected_under_capture(device):
    buf = device.create_buffer(8, np.float32).get()
    g = TaskGraph("partial")
    with pytest.raises(NotImplementedError):
        g.write(buf, np.zeros(3, np.float32), offset=2, count=3)


# ---------------------------------------------------------------------------
# pre-bound replay fast path (DESIGN.md §13)
# ---------------------------------------------------------------------------


def test_prebound_fast_plan_replay_bit_equal_to_eager(device, prog):
    n = 512
    host = np.random.default_rng(11).normal(size=(n,)).astype(np.float32)

    def eager(x):
        ebuf = device.create_buffer_from(x).get()
        et1, et2, eout = _bufs(device, n, 3)
        prog.run([ebuf], "double", out=[et1]).get()
        prog.run([et1], "inc", out=[et2]).get()
        prog.run([et2], "double", out=[eout]).get()
        return eout.enqueue_read_sync()

    want = eager(host)

    gbuf, gt1, gt2, gout = _bufs(device, n, 4)
    g = TaskGraph("prebound")
    w = g.write(gbuf, host)
    g.run(prog, [gbuf], "double", out=[gt1])
    g.run(prog, [gt1], "inc", out=[gt2])
    g.run(prog, [gt2], "double", out=[gout])
    r = g.read(gout)
    exe = g.instantiate()
    # one local segment, no fan-out -> the flat pre-bound plan exists and
    # every replay dispatches through it as a single lane hop
    assert exe._fast is not None
    got = np.asarray(exe.replay().get()[r])
    assert got.tobytes() == want.tobytes()  # bit-equal, not just allclose

    # feed-override replays stay on the fast path and stay bit-equal
    host2 = np.random.default_rng(12).normal(size=(n,)).astype(np.float32)
    want2 = eager(host2)
    got2 = np.asarray(exe.replay(feeds={w: host2}).get()[r])
    assert got2.tobytes() == want2.tobytes()
    # and the original payload replays unchanged afterwards
    got3 = np.asarray(exe.replay().get()[r])
    assert got3.tobytes() == want.tobytes()


# ---------------------------------------------------------------------------
# submission coalescing (DESIGN.md §13)
# ---------------------------------------------------------------------------


def test_coalesced_chain_matches_eager_bit_equal(device, prog):
    from repro.core import coalesce

    n = 256
    host = np.random.default_rng(9).normal(size=(n,)).astype(np.float32)
    buf = device.create_buffer_from(host).get()
    t1, t2, out = _bufs(device, n, 3)
    prog.run([buf], "double", out=[t1]).get()
    prog.run([t1], "inc", out=[t2]).get()
    prog.run([t2], "double", out=[out]).get()
    want = out.enqueue_read_sync()

    c1, c2, cout = _bufs(device, n, 3)
    with coalesce():
        prog.run([buf], "double", out=[c1])
        prog.run([c1], "inc", out=[c2])
        f = prog.run([c2], "double", out=[cout])
    f.get()
    assert cout.enqueue_read_sync().tobytes() == want.tobytes()


def test_coalesce_preserves_per_queue_fifo_across_queues():
    from repro.core import coalesce

    rt = get_runtime()
    qa, qb = rt.queue("coalesce-fifo-a"), rt.queue("coalesce-fifo-b")
    seen_a, seen_b = [], []
    with coalesce():
        futs = []
        for i in range(32):
            futs.append(qa.submit(lambda i=i: seen_a.append(i)))
            futs.append(qb.submit(lambda i=i: seen_b.append(i)))
    wait_all(futs)
    assert seen_a == list(range(32))
    assert seen_b == list(range(32))


def test_coalesce_random_mix_matches_unscoped():
    """Property (seeded sweep): any random mix of submit/submit_many over
    two queues, run inside one coalesce() window, yields the same
    per-queue execution order and the same future results as unscoped
    submission of the identical plan."""
    from repro.core import coalesce

    rt = get_runtime()
    for seed in range(8):
        rng = np.random.default_rng(seed)
        # plan: (queue_ix, [values]) — len 1 = submit, else submit_many
        plan = []
        v = 0
        for _ in range(rng.integers(1, 24)):
            k = int(rng.integers(1, 4))
            plan.append((int(rng.integers(0, 2)), list(range(v, v + k))))
            v += k

        def execute(tag, scoped):
            qs = (rt.queue(f"coal-prop-{seed}-{tag}-0"), rt.queue(f"coal-prop-{seed}-{tag}-1"))
            seen = ([], [])
            futs = []

            def run_plan():
                for qi, vals in plan:
                    rec = seen[qi]
                    if len(vals) == 1:
                        futs.append(qs[qi].submit(lambda v=vals[0], rec=rec: (rec.append(v), v)[1]))
                    else:
                        futs.extend(qs[qi].submit_many(
                            [(lambda v=v, rec=rec: (rec.append(v), v)[1]) for v in vals]))

            if scoped:
                with coalesce():
                    run_plan()
            else:
                run_plan()
            wait_all(futs)
            return seen, [f.get() for f in futs]

        want = execute("eager", scoped=False)
        got = execute("scoped", scoped=True)
        assert got == want, f"seed {seed}: coalesced run diverged"


def test_coalesce_blocking_get_inside_scope_flushes_first():
    from repro.core import coalesce

    q = get_runtime().queue("coalesce-block")
    with coalesce():
        f = q.submit(lambda: 41)
        assert f.get() == 41  # .get() flushes the staged window: no deadlock


def test_coalesce_staged_submissions_stay_visible_to_load():
    """Load honesty: items staged in a coalesce window must already count
    in load().depth — coalescing cannot blind the least_loaded signal."""
    import threading

    from repro.core import coalesce

    q = get_runtime().queue("coalesce-load")
    gate = threading.Event()
    blocker = q.submit(gate.wait)  # hold the worker so nothing completes
    try:
        with coalesce():
            futs = [q.submit(lambda: None) for _ in range(5)]
            # staged thread-locally, not yet enqueued — depth sees them anyway
            assert q.load().depth >= 6
    finally:
        gate.set()
    wait_all(futs + [blocker])


# ---------------------------------------------------------------------------
# per-op fast paths
# ---------------------------------------------------------------------------


def test_when_all_over_ready_futures_allocates_no_pool_work():
    rt = get_runtime()
    submits = []
    orig = rt.pool.submit

    def counting_submit(*a, **kw):
        submits.append(a)
        return orig(*a, **kw)

    rt.pool.submit = counting_submit
    try:
        fs = [make_ready_future(i) for i in range(64)]
        out = when_all(fs)
        assert out.done()
        assert out.get() == list(range(64))
    finally:
        rt.pool.submit = orig
    assert submits == []  # zero pool submissions, zero thread hops


def test_ready_future_then_runs_inline_and_stays_no_alloc():
    f = make_ready_future(3)
    assert f._cf is None  # value mode: no inner concurrent future
    g = f.then(lambda v: v + 1)
    assert g.done() and g._cf is None
    assert g.get() == 4


def test_when_any_over_ready_future_is_inline():
    idx, val = when_any([make_ready_future("a"), make_ready_future("b")]).get()
    assert (idx, val) == (0, "a")


def test_failed_fast_paths_propagate():
    boom = Future.failed(ValueError("boom"))
    with pytest.raises(ValueError):
        when_all([make_ready_future(1), boom]).get()
    with pytest.raises(ValueError):
        boom.then(lambda v: v).get()


def test_submit_many_preserves_fifo_order():
    q = get_runtime().queue("test-submit-many")
    seen = []
    futs = q.submit_many([(lambda i=i: seen.append(i)) for i in range(64)])
    wait_all(futs)
    assert seen == list(range(64))
    # interleaving with plain submits keeps overall FIFO per enqueue
    seen.clear()
    f1 = q.submit_many([lambda: seen.append("a"), lambda: seen.append("b")])
    f2 = q.submit(lambda: seen.append("c"))
    wait_all(f1 + [f2])
    assert seen == ["a", "b", "c"]


def test_submit_many_carries_args_and_errors():
    q = get_runtime().queue("test-submit-many-args")
    add = lambda a, b: a + b  # noqa: E731
    boom = lambda: 1 / 0  # noqa: E731
    f_add, f_boom, f_kw = q.submit_many(
        [(add, (2, 3)), boom, (add, (1,), {"b": 10})]
    )
    assert f_add.get() == 5
    with pytest.raises(ZeroDivisionError):
        f_boom.get()
    assert f_kw.get() == 11
