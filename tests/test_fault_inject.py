"""Unit coverage for the chaos layer itself (``repro.fault.inject``):
every injector, plus the guarantees injection must NOT break — parcel
drop/delay preserve channel ordering, a stalled lane is visible to
``least_loaded`` rather than fatal, heartbeat flaps fire ``on_dead`` once
per death.  The elastic-training chaos suite (test_elastic_train.py)
builds on the hooks proven here."""
import time

import numpy as np
import pytest

from repro.core.device import get_all_devices
from repro.core.parcel import LoopbackParcelport
from repro.core.scheduler import Scheduler
from repro.fault.inject import FaultInjector, InjectedFault, ParcelDropped
from repro.fault.monitor import Heartbeat


@pytest.fixture
def port():
    p = LoopbackParcelport(n_localities=2)
    yield p
    p.shutdown()


def _lid(port, i=0):
    return port.localities()[i].process_index


# ---------------------------------------------------------------------------
# parcel drop
# ---------------------------------------------------------------------------


def test_drop_fails_future_with_parcel_dropped(port):
    inj = FaultInjector(seed=0)
    inj.drop_parcels(port, actions=["ping"], count=1)
    lid = _lid(port)
    with pytest.raises(ParcelDropped):
        port.call(lid, "ping", {}).get()
    # count exhausted: the channel works again
    assert port.call(lid, "ping", {}).get() == "pong"
    assert [f.kind for f in inj.log] == ["drop"]


def test_drop_preserves_ordering_of_surviving_parcels(port):
    """A dropped write never reaches the wire, so the surviving writes
    land in submission order — the buffer ends at the LAST surviving
    write's value, never an earlier one (no reordering artifact)."""
    lid = _lid(port)
    dev = port.localities()[0].devices[0]
    buf = dev.create_buffer_from(np.zeros(4, np.float32)).get()
    inj = FaultInjector(seed=0)
    inj.drop_parcels(port, actions=["enqueue_write"], count=1)
    futs = [buf.enqueue_write(0, np.full(4, float(i), np.float32)) for i in range(1, 6)]
    outcomes = []
    for f in futs:
        try:
            f.get()
            outcomes.append("ok")
        except ParcelDropped:
            outcomes.append("dropped")
    assert outcomes.count("dropped") == 1  # exactly the injected one
    last_ok = max(i for i, o in enumerate(outcomes) if o == "ok") + 1
    np.testing.assert_array_equal(
        buf.enqueue_read().get(), np.full(4, float(last_ok), np.float32)
    )
    buf.free()


def test_drop_filters_by_locality_and_action(port):
    l0, l1 = _lid(port, 0), _lid(port, 1)
    inj = FaultInjector(seed=0)
    inj.drop_parcels(port, actions=["ping"], localities=[l0])
    with pytest.raises(ParcelDropped):
        port.call(l0, "ping", {}).get()
    assert port.call(l1, "ping", {}).get() == "pong"  # other locality untouched
    assert port.call(l0, "barrier", {}).get() is None  # other action untouched
    inj.clear_parcel_faults(port)
    assert port.call(l0, "ping", {}).get() == "pong"


def test_probabilistic_drops_replay_identically():
    """Same seed, same call sequence -> the same parcels drop: a chaos
    scenario is named by its seed."""

    def scenario(seed):
        p = LoopbackParcelport(n_localities=1)
        try:
            inj = FaultInjector(seed=seed)
            inj.drop_parcels(p, actions=["ping"], p=0.5)
            lid = _lid(p)
            out = []
            for _ in range(16):
                try:
                    p.call(lid, "ping", {}).get()
                    out.append(1)
                except ParcelDropped:
                    out.append(0)
            return out
        finally:
            p.shutdown()

    a, b, c = scenario(3), scenario(3), scenario(4)
    assert a == b
    assert 0 < sum(a) < 16  # p=0.5 actually drops some and passes some
    assert a != c  # a different seed is a different scenario


# ---------------------------------------------------------------------------
# parcel delay
# ---------------------------------------------------------------------------


def test_delay_slows_but_never_reorders(port):
    """Delay sleeps on the sender BEFORE the send, so later parcels on the
    channel queue behind it: FIFO holds, the reply just arrives late."""
    lid = _lid(port)
    dev = port.localities()[0].devices[0]
    buf = dev.create_buffer_from(np.zeros(2, np.float32)).get()
    inj = FaultInjector(seed=0)
    inj.delay_parcels(port, seconds=0.15, actions=["enqueue_write"], count=1)
    t0 = time.monotonic()
    f1 = buf.enqueue_write(0, np.full(2, 1.0, np.float32))  # delayed
    f2 = buf.enqueue_write(0, np.full(2, 2.0, np.float32))  # queues behind it
    f1.get()
    f2.get()
    assert time.monotonic() - t0 >= 0.15
    np.testing.assert_array_equal(buf.enqueue_read().get(), np.full(2, 2.0, np.float32))
    assert [f.kind for f in inj.log] == ["delay"]
    buf.free()


# ---------------------------------------------------------------------------
# worker kill (loopback transport)
# ---------------------------------------------------------------------------


def test_kill_worker_fails_fast_and_revive_readmits(port):
    lid = _lid(port)
    inj = FaultInjector(seed=0)
    assert port.alive(lid)
    inj.kill_worker(port, lid)
    assert not port.alive(lid)
    with pytest.raises(RuntimeError, match="failed fast"):
        port.call(lid, "ping", {}).get()
    assert not port.localities()[0].devices[0].alive()  # scheduler-visible
    port.revive(lid)
    assert port.alive(lid)
    assert port.call(lid, "ping", {}).get() == "pong"


# ---------------------------------------------------------------------------
# lane stall
# ---------------------------------------------------------------------------


def test_stall_lane_visible_to_least_loaded():
    """A stalled lane is a SLOW device, not a dead one: its queue depth
    rises, ``least_loaded`` routes around it, and queued work completes
    once the stall drains."""
    dev = get_all_devices().get()[0]
    inj = FaultInjector(seed=0)
    stall = inj.stall_lane(dev, 0.25)
    probe = dev.ops_queue.submit(lambda: 42)  # queues behind the stall
    load = dev.ops_queue.load()
    assert load.depth >= 1 or load.inflight >= 1

    class _IdleQueue:
        def load(self):
            return type(load)(depth=0, inflight=0, busy_for=0.0, busy_time=0.0,
                              submitted=0, completed=0)

    class _IdleDev:
        key = "cpu:idle"
        ops_queue = _IdleQueue()

    from repro.core.scheduler import make_policy

    picked = make_policy("least_loaded").select([dev, _IdleDev()])
    assert picked.key == "cpu:idle"
    assert probe.get() == 42  # stalled, not lost
    stall.get()
    assert [f.kind for f in inj.log] == ["stall"]


# ---------------------------------------------------------------------------
# scheduler cordon
# ---------------------------------------------------------------------------


def test_cordon_excludes_device_until_uncordon():
    class _FakeQueue:
        def load(self):
            from repro.core.executor import QueueLoad

            return QueueLoad(depth=0, inflight=0, busy_for=0.0, busy_time=0.0,
                             submitted=0, completed=0)

    class _FakeDev:
        def __init__(self, key):
            self.key = key
            self.ops_queue = _FakeQueue()

    devs = [_FakeDev("cpu:0"), _FakeDev("cpu:1")]
    sched = Scheduler(devs, policy="round_robin", steal=False)
    inj = FaultInjector(seed=0)
    inj.cordon_device(sched, "cpu:1")
    assert {sched.select().key for _ in range(4)} == {"cpu:0"}
    # cordoning the whole fleet waives the cordon instead of deadlocking
    inj.cordon_device(sched, "cpu:0")
    assert sched.select().key in {"cpu:0", "cpu:1"}
    inj.uncordon_device(sched, "cpu:0")
    inj.uncordon_device(sched, "cpu:1")
    assert {sched.select().key for _ in range(4)} == {"cpu:0", "cpu:1"}


# ---------------------------------------------------------------------------
# heartbeat corruption
# ---------------------------------------------------------------------------


def test_corrupt_heartbeat_fires_on_dead_per_death():
    deaths = []
    hb = Heartbeat(timeout_s=60.0, on_dead=lambda: deaths.append(1))
    inj = FaultInjector(seed=0)
    hb.tick()
    assert hb.check()
    inj.corrupt_heartbeat(hb)
    assert not hb.check()  # death #1
    assert not hb.check()  # latched: no double fire
    assert len(deaths) == 1
    hb.tick()  # recovery clears the latch
    assert hb.check()
    inj.corrupt_heartbeat(hb)  # flap: death #2
    assert not hb.check()
    assert len(deaths) == 2
    assert all(f.kind == "hb_expire" for f in inj.log)


# ---------------------------------------------------------------------------
# scenario planning
# ---------------------------------------------------------------------------


def test_plan_kill_is_deterministic_and_in_range():
    victims = ["w0", "w1", "w2"]
    a = FaultInjector(seed=11).plan_kill(10, victims)
    b = FaultInjector(seed=11).plan_kill(10, victims)
    assert a == b
    for seed in range(20):
        k, v = FaultInjector(seed=seed).plan_kill(10, victims)
        assert 1 <= k < 10
        assert v in victims
    with pytest.raises(ValueError):
        FaultInjector(seed=0).plan_kill(10, [])


def test_injector_log_records_fired_faults_in_order(port):
    inj = FaultInjector(seed=0)
    lid = _lid(port)
    inj.drop_parcels(port, actions=["ping"], count=1)
    with pytest.raises(ParcelDropped):
        port.call(lid, "ping", {}).get()
    inj.delay_parcels(port, seconds=0.01, actions=["ping"], count=1)
    port.call(lid, "ping", {}).get()
    assert [(f.kind, f.action) for f in inj.log] == [("drop", "ping"), ("delay", "ping")]
    assert all(isinstance(f, InjectedFault) for f in inj.log)
