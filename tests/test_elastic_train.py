"""Property-based chaos suite for elastic data-parallel training
(DESIGN.md §16).

The central property, drilled over random (seed, kill-step, victim)
triples: a worker killed MID-STEP discards that step's partial results,
the trainer reshards over the survivors, and the loss curve from the
reshard point is **bit-identical** to a clean (N-1)-worker run seeded
from the same state — dask-style re-execution from AGAS-resident driver
state, no checkpoint involved.  Around it: re-join/scale-out resume full
N-way sharding, dropped gradient parcels retry before a link is declared
dead, the parcel route leaks neither workers nor shm segments, and the
checkpoint path remains the (bit-exact) last resort.
"""
import glob

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # hypothesis not installed: deterministic fallback shim
    from _hypothesis_compat import given, settings, strategies as st

from repro.core import agas
from repro.core.parcel import LoopbackParcelport
from repro.fault.inject import FaultInjector
from repro.training.elastic import ElasticTrainer, LocalWorker

# One shard family for the whole file: module-level caches in
# repro.training.elastic mean compilation is paid once, every further
# trainer (each property example builds two) replays pre-bound plans.
ARCH, BATCH, SEQ, TOTAL = "olmo-1b", 6, 8, 5


def _trainer(workers=3, seed=0, **kw):
    kw.setdefault("total_steps", TOTAL)  # one LR horizon -> one jitted update
    return ElasticTrainer(
        ARCH, use_smoke=True, batch=BATCH, seq=SEQ, seed=seed, workers=workers, **kw
    )


def _count_dispatches(trainer):
    """Wrap every worker's run_shard to count shards dispatched per step
    boundary — the observable for 'resumes N-way sharding'."""
    counts = {}
    for w in trainer.workers:
        orig = w.run_shard

        def wrapped(task, _w=w, _orig=orig):
            counts[_w.wid] = counts.get(_w.wid, 0) + 1
            return _orig(task)

        w.run_shard = wrapped
    return counts


# ---------------------------------------------------------------------------
# THE property: mid-step kill -> bit-identical to a clean N-1 run
# ---------------------------------------------------------------------------


@settings(max_examples=3, deadline=None)
@given(seed=st.integers(0, 3), kill_step=st.integers(1, 3), victim=st.integers(0, 2))
def test_midstep_kill_bit_identical_to_clean_survivor_run(seed, kill_step, victim):
    t = _trainer(workers=3, seed=seed)
    try:
        t.run(kill_step)  # 3-way up to the kill step
        snap = t.snapshot()  # state AT the kill step (reference seed)
        t.workers[victim].kill_at_step(t.cursor)  # dies inside its shard
        tail = t.run(TOTAL - kill_step)["losses"]
        deaths = [e for e in t.events if e[0] == "death"]
        assert [(e[1], e[2]) for e in deaths] == [(kill_step, victim)]
        assert len(t.active_workers()) == 2
    finally:
        t.close()

    ref = _trainer(
        workers=2, seed=seed, state=(snap["params"], snap["opt_state"]),
        start_step=snap["step"],
    )
    try:
        ref_tail = ref.run(TOTAL - kill_step)["losses"]
        assert not ref.events  # the reference run saw no faults
    finally:
        ref.close()
    # bit-identical, not approximately equal: same floats, every step
    assert tail == ref_tail
    assert np.float64(tail[0]) == np.float64(ref_tail[0])


# ---------------------------------------------------------------------------
# elasticity up: re-join and scale-out resume full sharding
# ---------------------------------------------------------------------------


def test_revived_worker_rejoins_n_way_sharding_at_step_boundary():
    t = _trainer(workers=3)
    counts = _count_dispatches(t)
    try:
        t.workers[1].kill()  # boundary death: excluded, no mid-step event
        t.step()
        assert counts == {0: 1, 2: 1}  # 2-way over survivors
        t.workers[1].revive()
        t.step()  # next boundary re-reads the active set
        assert counts == {0: 2, 1: 1, 2: 2}  # back to 3-way
        assert len(t.active_workers()) == 3
        assert not [e for e in t.events if e[0] == "death"]  # no step was lost
    finally:
        t.close()


def test_add_worker_scales_out_next_step():
    t = _trainer(workers=2)
    try:
        t.step()
        w = t.add_worker(LocalWorker(7))
        counts = _count_dispatches(t)
        t.step()
        assert counts == {0: 1, 1: 1, 7: 1}  # admitted at the boundary
        assert ("join", 1, 7) in t.events
        assert w in t.active_workers()
    finally:
        t.close()


# ---------------------------------------------------------------------------
# parcel route: recovery without leaking workers or shm segments
# ---------------------------------------------------------------------------


def test_parcel_route_kill_recovers_and_leaks_nothing():
    before = set(glob.glob("/dev/shm/psm_*"))
    port = LoopbackParcelport(n_localities=3)
    try:
        t = _trainer(workers=3, seed=1, port=port)
        try:
            t.run(1)
            snap = t.snapshot()
            t.workers[2].kill_at_step(t.cursor)  # parcel fails fast mid-step
            tail = t.run(2)["losses"]
            assert [e[0] for e in t.events].count("death") == 1
            assert len(t.active_workers()) == 2
            t.workers[2].revive()  # recovered locality re-admitted
            t.run(1)
            assert len(t.active_workers()) == 3
        finally:
            t.close()
        # remote (loopback) gradients match the local route bit-for-bit
        ref = _trainer(workers=2, seed=1, state=(snap["params"], snap["opt_state"]),
                       start_step=snap["step"])
        try:
            assert tail == ref.run(2)["losses"]
        finally:
            ref.close()
    finally:
        port.shutdown()
    leaked = set(glob.glob("/dev/shm/psm_*")) - before
    assert not leaked, f"shm segments leaked past shutdown: {sorted(leaked)}"


def test_dropped_parcels_retry_then_reshard_after_link_death():
    port = LoopbackParcelport(n_localities=2)
    try:
        inj = FaultInjector(seed=0)
        t = _trainer(workers=2, port=port, max_retries=2)
        try:
            lid0 = t.workers[0].lid
            # one transient drop: re-sent to the SAME worker, not a death
            inj.drop_parcels(port, actions=["invoke"], localities=[lid0], count=1)
            t.step()
            assert [e[0] for e in t.events] == ["retry"]
            assert len(t.active_workers()) == 2
            # persistent drops: retries exhaust, link declared dead, reshard
            inj.drop_parcels(port, actions=["invoke"], localities=[lid0], p=1.0)
            t.step()
            kinds = [e[0] for e in t.events]
            assert kinds.count("retry") == 1 + t.max_retries
            assert kinds.count("death") == 1
            assert [w.wid for w in t.active_workers()] == [1]
        finally:
            inj.clear_parcel_faults(port)
            t.close()
    finally:
        port.shutdown()


# ---------------------------------------------------------------------------
# driver wiring (--workers/--chaos) and AGAS-resident state
# ---------------------------------------------------------------------------


def test_train_driver_chaos_run_completes_with_recovery():
    from repro.launch.train import train

    out = train(ARCH, use_smoke=True, steps=4, batch=BATCH, seq=SEQ,
                workers=3, chaos=2, log_every=0)
    assert len(out["losses"]) == 4  # the kill cost zero steps
    assert all(np.isfinite(l) for l in out["losses"])
    assert len(out["recoveries"]) == 1  # seeded kill fired and was absorbed


def test_master_state_is_agas_resident_until_close():
    t = _trainer(workers=2)
    gid = t.agas_gid
    assert gid in agas.registry.gids_on(agas.HOST_KEY, kind="elastic-state")
    assert agas.registry.resolve(gid) is t
    t.close()
    assert gid not in agas.registry.gids_on(agas.HOST_KEY, kind="elastic-state")


def test_every_worker_dead_raises_with_resume_hint():
    t = _trainer(workers=2)
    try:
        for w in t.workers:
            w.kill()
        with pytest.raises(RuntimeError, match="resume=True"):
            t.step()
    finally:
        t.close()


# ---------------------------------------------------------------------------
# checkpoint restore: the last resort, still bit-exact
# ---------------------------------------------------------------------------


def test_checkpoint_resume_matches_uninterrupted_run(tmp_path):
    a = _trainer(workers=2, ckpt_dir=str(tmp_path), ckpt_every=1)
    try:
        a.run(2)
    finally:
        a.close()  # driver "dies" here; durable state is the checkpoint

    b = _trainer(workers=2, ckpt_dir=str(tmp_path), resume=True)
    try:
        assert b.cursor == 2
        resumed = b.run(3)["losses"]
    finally:
        b.close()

    c = _trainer(workers=2)  # never interrupted
    try:
        full = c.run(TOTAL)["losses"]
    finally:
        c.close()
    assert resumed == full[2:]  # npz round-trip loses no bits
