"""Streams & events (DESIGN.md §11): same-stream FIFO, cross-stream
event happens-before (property-based), real lane concurrency (high-water
mark), stream-aware graph replay bit-equal to eager, remote stream
ordering over the loopback parcelport, and the Device.synchronize
all-streams fix."""
import threading
import time

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # minimal container: seeded fallback sweeps
    from _hypothesis_compat import given, settings, strategies as st

from repro.core import (
    Event,
    LoopbackParcelport,
    Stream,
    TaskGraph,
    capture,
    get_all_devices,
)


@pytest.fixture(scope="module")
def device():
    devices = get_all_devices(1, 0).get()
    assert len(devices) >= 1
    return devices[0]


@pytest.fixture()
def prog(device):
    return device.create_program(
        {"double": lambda x: x * 2.0, "inc": lambda x: x + 1.0, "axpy": lambda x, y: x + y},
        name="stream-test",
    ).get()


# ---------------------------------------------------------------------------
# same-stream FIFO ordering
# ---------------------------------------------------------------------------


def test_default_stream_is_ops_queue(device):
    assert device.default_stream.lane is device.ops_queue
    assert device.default_stream in device.streams()


def test_same_stream_fifo_host_callbacks(device):
    s = device.create_stream()
    seen = []
    futs = [s.submit(lambda i=i: seen.append(i)) for i in range(64)]
    futs[-1].get()
    assert seen == list(range(64))


@settings(max_examples=10, deadline=None)
@given(n_ops=st.integers(min_value=1, max_value=12), seed=st.integers(min_value=0, max_value=2**16))
def test_same_stream_fifo_random_op_mix(n_ops, seed):
    """Property: any random interleave of writes/launches/reads on ONE
    stream observes strict submission order — each read sees the value
    produced by everything submitted before it, nothing after.
    (Fixtures are fetched inline: the hypothesis fallback shim passes
    only drawn arguments.)"""
    device = get_all_devices().get()[0]
    prog = device.create_program({"inc": lambda x: x + 1.0}, name="fifo-prop").get()
    rng = np.random.default_rng(seed)
    s = device.create_stream()
    n = 32
    buf = device.create_buffer(n, np.float32).get()
    out = device.create_buffer(n, np.float32).get()
    s.enqueue_write(buf, 0, np.zeros(n, np.float32))

    expect = np.zeros(n, np.float32)
    checks = []  # (future, expected np array)
    for _ in range(n_ops):
        op = rng.integers(0, 3)
        if op == 0:  # overwrite with fresh payload
            payload = rng.normal(size=(n,)).astype(np.float32)
            s.enqueue_write(buf, 0, payload)
            expect = payload
        elif op == 1:  # launch reading buf, writing out, then copy back
            s.launch(prog, [buf], "inc", out=[out])
            s.enqueue_write(buf, 0, _ReadThrough(out))
            expect = expect + 1.0
        else:  # read must see exactly the current expected value
            checks.append((s.enqueue_read(buf), expect.copy()))
    checks.append((s.enqueue_read(buf), expect.copy()))
    for fut, want in checks:
        np.testing.assert_allclose(fut.get(), want, rtol=1e-6)


class _ReadThrough:
    """Write payload that materializes the CURRENT value of another
    buffer when the write task runs — valid only because same-stream
    FIFO guarantees the producing launch already completed."""

    def __init__(self, buf):
        self.buf = buf

    def __array__(self, dtype=None, copy=None):
        import jax

        return np.asarray(jax.block_until_ready(self.buf.array()))


# ---------------------------------------------------------------------------
# cross-stream event happens-before
# ---------------------------------------------------------------------------


def test_event_record_wait_query(device):
    s1, s2 = device.create_stream(), device.create_stream()
    gate = threading.Event()
    s1.submit(gate.wait)  # s1 is stuck until we say go
    e = s1.record()
    assert isinstance(e, Event)
    assert not e.query()

    seen = []
    s2.wait_event(e)
    after = s2.submit(lambda: seen.append("after-event"))
    time.sleep(0.05)
    assert seen == []  # s2 must not have run past the gate
    gate.set()
    after.get()
    assert seen == ["after-event"]
    assert e.query()
    e.wait()  # idempotent host wait


def test_wait_event_same_stream_is_noop(device):
    s = device.create_stream()
    e = s.record()
    assert s.wait_event(e) is e.future  # FIFO already orders later work
    s.synchronize()


def test_record_covers_async_launch_completion(device, prog):
    """An event recorded after a launch fires at kernel COMPLETION, not
    dispatch: the waiting stream must observe the launch's output."""
    n = 1 << 16
    s1, s2 = device.create_stream(), device.create_stream()
    a = device.create_buffer(n, np.float32).get()
    out = device.create_buffer(n, np.float32).get()
    host = np.linspace(0.0, 1.0, n).astype(np.float32)
    s1.enqueue_write(a, 0, host)
    s1.launch(prog, [a], "double", out=[out])
    done = s1.record()
    s2.wait_event(done)
    got = s2.enqueue_read(out).get()
    np.testing.assert_allclose(got, host * 2.0, rtol=1e-6)


@settings(max_examples=8, deadline=None)
@given(
    n_tokens=st.integers(min_value=1, max_value=8),
    delay_ms=st.integers(min_value=0, max_value=20),
)
def test_event_happens_before_property(n_tokens, delay_ms):
    """Property: everything submitted to s1 before record() is visible
    to everything submitted to s2 after wait_event(), for any producer
    delay — the event edge carries happens-before."""
    device = get_all_devices().get()[0]
    s1, s2 = device.create_stream(), device.create_stream()
    produced, consumed = [], []

    def produce(i):
        if delay_ms:
            time.sleep(delay_ms / 1000.0)
        produced.append(i)

    for i in range(n_tokens):
        s1.submit(produce, i)
    s2.wait_event(s1.record())
    done = s2.submit(lambda: consumed.extend(produced))
    done.get()
    assert consumed == list(range(n_tokens))


# ---------------------------------------------------------------------------
# overlap really occurs (concurrent-lane high-water mark)
# ---------------------------------------------------------------------------


def test_streams_overlap_high_water_mark(device):
    s1, s2 = device.create_stream(), device.create_stream()
    device._dispatcher.reset_high_water()
    barrier = threading.Barrier(2, timeout=10)
    # Each lane parks in the barrier until the OTHER lane arrives: the
    # test passes only if two lanes genuinely run at the same time.
    f1 = s1.submit(barrier.wait)
    f2 = s2.submit(barrier.wait)
    f1.get(timeout=10)
    f2.get(timeout=10)
    assert device._dispatcher.high_water() >= 2


def test_single_stream_never_overlaps_itself(device):
    s = device.create_stream()
    active, peak = [0], [0]
    lock = threading.Lock()

    def task():
        with lock:
            active[0] += 1
            peak[0] = max(peak[0], active[0])
        time.sleep(0.005)
        with lock:
            active[0] -= 1

    futs = [s.submit(task) for _ in range(16)]
    futs[-1].get()
    assert peak[0] == 1  # same-stream tasks are strictly serial


# ---------------------------------------------------------------------------
# stream-aware graph replay
# ---------------------------------------------------------------------------


def test_graph_two_chains_two_streams_bit_equal_eager(device, prog):
    n = 256
    ha = np.linspace(-1.0, 1.0, n).astype(np.float32)
    hb = np.linspace(1.0, 3.0, n).astype(np.float32)

    # eager reference
    ea = device.create_buffer_from(ha).get()
    eb = device.create_buffer_from(hb).get()
    eoa = device.create_buffer(n, np.float32).get()
    eob = device.create_buffer(n, np.float32).get()
    prog.run([ea], "double", out=[eoa]).get()
    prog.run([eb], "inc", out=[eob]).get()
    want_a, want_b = eoa.enqueue_read_sync(), eob.enqueue_read_sync()

    # captured: two independent SSA chains -> two segments on two lanes
    a = device.create_buffer(n, np.float32).get()
    b = device.create_buffer(n, np.float32).get()
    oa = device.create_buffer(n, np.float32).get()
    ob = device.create_buffer(n, np.float32).get()
    with capture("chains") as g:
        g.write(a, ha)
        g.write(b, hb)
        prog.run([a], "double", out=[oa])
        prog.run([b], "inc", out=[ob])
        ra, rb = oa.enqueue_read(), ob.enqueue_read()
    exe = g.instantiate()
    assert exe._fanout and len(exe._segments) == 2, repr(exe)
    assert len({id(s.queue) for s in exe._segments}) == 2, repr(exe)  # distinct lanes

    for _ in range(3):  # replays are repeatable AND bit-equal to eager
        res = exe.replay().get()
        np.testing.assert_array_equal(res[ra], want_a)
        np.testing.assert_array_equal(res[rb], want_b)


def test_graph_chain_join_has_event_edge(device, prog):
    n = 64
    a, b = (device.create_buffer(n, np.float32).get() for _ in range(2))
    ma, mb, out = (device.create_buffer(n, np.float32).get() for _ in range(3))
    with capture("join") as g:
        g.write(a, np.ones(n, np.float32))
        g.write(b, np.full(n, 2.0, np.float32))
        prog.run([a], "inc", out=[ma])      # chain 0
        prog.run([b], "double", out=[mb])   # chain 1 (independent head)
        prog.run([ma, mb], "axpy", out=[out])  # join -> event edge from chain 1
        r = g.read(out)
    exe = g.instantiate()
    assert exe._fanout and len(exe._segments) == 3, repr(exe)
    assert exe._event_edges, "chain join must synchronize through an event edge"
    res = exe.replay().get()
    np.testing.assert_allclose(res[r], np.full(n, 6.0))  # (1+1) + 2*2


def test_eager_read_after_fanout_replay_sees_commit(device, prog):
    """Commit-visibility fence: an eager read submitted right after a
    multi-chain replay() returns must observe the replayed values, not
    pre-replay state (the single-hop path's FIFO guarantee, preserved)."""
    n = 128
    a, b = (device.create_buffer(n, np.float32).get() for _ in range(2))
    oa, ob = (device.create_buffer(n, np.float32).get() for _ in range(2))
    with capture("fence") as g:
        g.write(a, np.ones(n, np.float32))
        g.write(b, np.full(n, 3.0, np.float32))
        prog.run([a], "inc", out=[oa])      # chain 0 (default lane)
        prog.run([b], "double", out=[ob])   # chain 1 (replay lane)
    exe = g.instantiate()
    assert exe._fanout, repr(exe)
    for _ in range(5):
        exe.replay(sync="dispatch")  # don't wait: race the eager read
        got = ob.enqueue_read_sync()  # eager, default lane, right after
        np.testing.assert_allclose(got, np.full(n, 6.0))


def test_stream_names_never_share_a_lane(device):
    """A user-chosen name colliding with an auto 's{idx}' (or 'default')
    must not alias another stream's lane — lanes are per-stream."""
    streams = [device.create_stream("s2"), device.create_stream(),
               device.create_stream("default"), device.create_stream("replay.1")]
    lanes = {id(s.lane) for s in streams} | {id(device.ops_queue)}
    assert len(lanes) == len(streams) + 1


def test_graph_dependent_chain_stays_one_segment(device, prog):
    """A dependent chain must NOT be split across streams — same-chain
    launches fuse into one segment exactly as before (§8)."""
    n = 64
    bufs = [device.create_buffer(n, np.float32).get() for _ in range(3)]
    with capture("seq") as g:
        g.write(bufs[0], np.zeros(n, np.float32))
        prog.run([bufs[0]], "inc", out=[bufs[1]])
        prog.run([bufs[1]], "inc", out=[bufs[2]])
        r = g.read(bufs[2])
    exe = g.instantiate()
    assert len(exe._segments) == 1 and not exe._fanout, repr(exe)
    np.testing.assert_allclose(exe.replay().get()[r], np.full(n, 2.0))


# ---------------------------------------------------------------------------
# remote streams over the loopback parcelport
# ---------------------------------------------------------------------------


def test_remote_stream_ordering_loopback():
    port = LoopbackParcelport(n_localities=1)
    try:
        rdev = port.localities()[0].devices[0]
        s = rdev.create_stream()
        assert s in rdev.streams() and s is not rdev.default_stream

        n = 128
        buf = rdev.create_buffer(n, np.float32).get()
        # write -> overwrite -> read, all on one channel: FIFO end-to-end
        s.enqueue_write(buf, 0, np.zeros(n, np.float32))
        s.enqueue_write(buf, 0, np.arange(n, dtype=np.float32))
        got = s.enqueue_read(buf).get()
        np.testing.assert_array_equal(got, np.arange(n, dtype=np.float32))

        # launch ordered on the channel behind the write it consumes
        rprog = rdev.create_program(["partition_map_ref"], "stream-loop").get()
        rout = rdev.create_buffer(n, np.float32).get()
        host = np.linspace(0.0, 1.0, n).astype(np.float32)
        s.enqueue_write(buf, 0, host)
        rprog.run([buf], "partition_map_ref", out=[rout], stream=s)
        got = s.enqueue_read(rout).get()
        assert got.shape == (n,)

        # event recorded on a remote stream; another channel waits on it
        s2 = rdev.create_stream()
        s2.wait_event(s.record())
        s2.enqueue_write(buf, 0, np.zeros(n, np.float32))
        assert float(s2.enqueue_read(buf).get().sum()) == 0.0

        rdev.synchronize()  # drains EVERY channel
        assert all(st_.query() for st_ in rdev.streams())
    finally:
        port.shutdown()


@settings(max_examples=5, deadline=None)
@given(n_writes=st.integers(min_value=1, max_value=8), seed=st.integers(min_value=0, max_value=999))
def test_remote_stream_last_write_wins_property(n_writes, seed):
    """Property: N racing writes on ONE remote channel resolve to the
    LAST one — parcel-channel FIFO holds for any count."""
    port = LoopbackParcelport(n_localities=1)
    try:
        rdev = port.localities()[0].devices[0]
        s = rdev.create_stream()
        buf = rdev.create_buffer(16, np.float32).get()
        rng = np.random.default_rng(seed)
        last = None
        for _ in range(n_writes):
            last = rng.normal(size=(16,)).astype(np.float32)
            s.enqueue_write(buf, 0, last)
        np.testing.assert_array_equal(s.enqueue_read(buf).get(), last)
    finally:
        port.shutdown()


# ---------------------------------------------------------------------------
# Device.synchronize drains ALL streams; misc surface
# ---------------------------------------------------------------------------


def test_device_synchronize_drains_all_streams(device):
    s = device.create_stream()
    done = []
    s.submit(lambda: (time.sleep(0.15), done.append(1)))
    # Pre-fix, synchronize() drained only the default lane and returned
    # while the non-default stream still had work in flight.
    device.synchronize()
    assert done == [1]
    assert s.query()


def test_stream_of_wrong_device_is_refused(device, prog):
    class _OtherDevice:
        key = "not-a-real-device:9"

    bad = Stream(_OtherDevice(), device.ops_queue, name="bad")
    buf = device.create_buffer(8, np.float32).get()
    with pytest.raises(ValueError, match="belongs to device"):
        buf.enqueue_write(0, np.zeros(8, np.float32), stream=bad)
    with pytest.raises(ValueError, match="belongs to device"):
        prog.run([buf], "inc", stream=bad)


def test_program_launch_alias_with_stream(device, prog):
    s = device.create_stream()
    buf = device.create_buffer_from(np.full(16, 2.0, np.float32)).get()
    out = device.create_buffer(16, np.float32).get()
    res = prog.launch([buf], "double", out=[out], stream=s).get()
    np.testing.assert_allclose(res[0].array(), np.full(16, 4.0))


def test_device_load_counts_every_lane(device):
    """The scheduler's load signal sums per-lane depth (§11): work parked
    on two different streams shows up as depth >= 2."""
    s1, s2 = device.create_stream(), device.create_stream()
    gate = threading.Event()
    f1 = s1.submit(gate.wait)
    f2 = s2.submit(gate.wait)
    time.sleep(0.02)
    try:
        assert device.load().depth >= 2
    finally:
        gate.set()
        f1.get()
        f2.get()


# ---------------------------------------------------------------------------
# submission coalescing across streams (DESIGN.md §13)
# ---------------------------------------------------------------------------


def test_coalesce_window_over_two_streams_keeps_per_stream_fifo(device):
    from repro.core import coalesce

    s1, s2 = device.create_stream(), device.create_stream()
    seen1, seen2 = [], []
    with coalesce():
        futs = [s1.submit(lambda i=i: seen1.append(i)) for i in range(16)]
        futs += [s2.submit(lambda i=i: seen2.append(i)) for i in range(16)]
    for f in futs:
        f.get()
    assert seen1 == list(range(16))
    assert seen2 == list(range(16))


def test_coalesced_stream_launch_chain_bit_equal(device, prog):
    from repro.core import coalesce

    n = 64
    host = np.random.default_rng(21).normal(size=(n,)).astype(np.float32)
    s = device.create_stream()
    buf = device.create_buffer_from(host).get()
    out = device.create_buffer(n, np.float32).get()
    s.launch(prog, [buf], "double", out=[out])
    want = np.asarray(s.enqueue_read(out).get())

    cout = device.create_buffer(n, np.float32).get()
    with coalesce():
        s.launch(prog, [buf], "double", out=[cout])
        r = s.enqueue_read(cout)
    assert np.asarray(r.get()).tobytes() == want.tobytes()


def test_coalesce_staged_stream_work_counts_in_device_load(device):
    from repro.core import coalesce

    s = device.create_stream()
    gate = threading.Event()
    blocker = s.submit(gate.wait)
    try:
        with coalesce():
            futs = [s.submit(lambda: None) for _ in range(4)]
            assert device.load().depth >= 5  # staged items already visible
    finally:
        gate.set()
    for f in futs + [blocker]:
        f.get()
