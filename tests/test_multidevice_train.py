"""Integration: REAL sharded execution on multiple (host) devices.

The dry-run proves lowering; this proves execution: a smoke model trains
data-parallel on a 2x2 (data, model) mesh of 4 host devices in a
subprocess (jax fixes the device count at first init), and the loss curve
must match the single-device run — distribution must not change the math.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

_CHILD = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4 " + os.environ.get("XLA_FLAGS", "")
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.configs import get_config, smoke
    from repro.configs.base import ShapeConfig
    from repro.distribution.recipes import plan_for
    from repro.distribution.sharding import axis_rules, spec_for, tree_sharding
    from repro.launch.mesh import make_host_mesh
    from repro.models import batch_logical_specs, get_model, make_batch
    from repro.training.optimizer import OptConfig, init_opt_state
    from repro.training.train_step import make_train_step
    from dataclasses import replace

    cfg = smoke(get_config("olmo-1b"))
    shape = ShapeConfig("t", seq_len=32, global_batch=4, kind="train")
    plan = replace(plan_for(cfg, shape), num_microbatches=1, remat="none",
                   q_block=None, compute_dtype="float32")
    m = get_model(cfg)
    opt_cfg = OptConfig(lr=1e-2, warmup_steps=0, total_steps=10)
    batch = make_batch(cfg, shape, seed=7)

    def run(mesh, rules):
        params = m.init(cfg, jax.random.key(0))
        opt = init_opt_state(params)
        step = make_train_step(cfg, shape, opt_cfg, plan)
        losses = []
        if mesh is None:
            jstep = jax.jit(step)
            for _ in range(4):
                params2, opt, metrics = jstep(params, opt, batch)
                params = params2
                losses.append(float(metrics["loss"]))
            return losses
        with axis_rules(rules, mesh):
            pspecs = m.param_specs(cfg)
            psh = tree_sharding(mesh, pspecs, rules, params)
            osh = {"m": tree_sharding(mesh, pspecs, rules, opt["m"]),
                   "v": tree_sharding(mesh, pspecs, rules, opt["v"]),
                   "step": jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())}
            blog = batch_logical_specs(cfg, shape)
            bsh = {k: jax.sharding.NamedSharding(
                       mesh, spec_for(blog[k], rules, shape=v.shape, mesh=mesh))
                   for k, v in batch.items()}
            params = jax.device_put(params, psh)
            opt = jax.device_put(opt, osh)
            b = {k: jax.device_put(np.asarray(v), bsh[k]) for k, v in batch.items()}
            jstep = jax.jit(step, in_shardings=(psh, osh, bsh), out_shardings=(psh, osh, None))
            for _ in range(4):
                params, opt, metrics = jstep(params, opt, b)
                losses.append(float(metrics["loss"]))
            return losses

    single = run(None, None)
    mesh = make_host_mesh(data=2, model=2)
    rules = dict(plan.rules)
    sharded = run(mesh, rules)
    print("SINGLE", ",".join(f"{x:.6f}" for x in single))
    print("SHARDED", ",".join(f"{x:.6f}" for x in sharded))
    """
)


@pytest.mark.slow
def test_sharded_training_matches_single_device():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src:" + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-c", _CHILD],
        capture_output=True,
        text=True,
        env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=900,
    )
    assert proc.returncode == 0, proc.stderr[-2000:]
    lines = {l.split(" ", 1)[0]: l.split(" ", 1)[1] for l in proc.stdout.splitlines() if " " in l}
    single = np.array([float(x) for x in lines["SINGLE"].split(",")])
    sharded = np.array([float(x) for x in lines["SHARDED"].split(",")])
    assert single[-1] < single[0]  # it actually trains
    np.testing.assert_allclose(single, sharded, rtol=2e-4)
