"""Per-architecture smoke tests: reduced config, one forward + one train
step + one decode step on CPU; asserts shapes and absence of NaNs.

The FULL configs are exercised only by the dry-run (ShapeDtypeStruct).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALL_ARCHS, get_config, smoke
from repro.configs.base import ShapeConfig
from repro.models import get_model, make_batch

SMOKE_SHAPE = ShapeConfig("smoke", seq_len=32, global_batch=2, kind="train")


def _smoke_cfg(arch):
    return smoke(get_config(arch))


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = _smoke_cfg(arch)
    m = get_model(cfg)
    params = m.init(cfg, jax.random.key(0))
    batch = make_batch(cfg, SMOKE_SHAPE)
    logits, aux = m.forward(cfg, params, batch, q_block=16)
    B, S = batch["tokens"].shape
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits))), f"{arch}: non-finite logits"
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_train_step_reduces_loss_shape(arch):
    """One SGD step must produce a finite scalar loss and finite grads."""
    cfg = _smoke_cfg(arch)
    m = get_model(cfg)
    params = m.init(cfg, jax.random.key(1))
    batch = make_batch(cfg, SMOKE_SHAPE, seed=1)

    loss, grads = jax.value_and_grad(lambda p: m.loss_fn(cfg, p, batch, q_block=16))(params)
    assert np.isfinite(float(loss)), f"{arch}: loss={loss}"
    flat, _ = jax.tree.flatten(grads)
    assert all(bool(jnp.all(jnp.isfinite(g))) for g in flat), f"{arch}: non-finite grads"
    # losses should be near log(vocab) for random init
    assert 0.1 * np.log(cfg.vocab_size) < float(loss) < 10 * np.log(cfg.vocab_size)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_decode_step(arch):
    cfg = _smoke_cfg(arch)
    m = get_model(cfg)
    params = m.init(cfg, jax.random.key(2))
    B, S = 2, 16
    cache = m.init_cache(cfg, B, S, dtype=jnp.float32)
    if cfg.family == "encdec":
        frames = jnp.zeros((B, cfg.encdec.encoder_seq, cfg.d_model), jnp.float32)
        from repro.models.encdec import _cross_kv, encode

        enc = encode(cfg, params, frames)
        for i, (k, v) in enumerate(_cross_kv(cfg, params, enc)):
            cache["cross_k"] = cache["cross_k"].at[i].set(k.astype(cache["cross_k"].dtype))
            cache["cross_v"] = cache["cross_v"].at[i].set(v.astype(cache["cross_v"].dtype))
    tok = jnp.zeros((B, 1), jnp.int32)
    logits, cache2 = m.decode_step(cfg, params, cache, tok, jnp.int32(0))
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits))), f"{arch}: non-finite decode logits"
    # cache structure preserved
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_param_specs_match_params(arch):
    """Logical-axis spec tree must mirror the param tree leaf-for-leaf."""
    cfg = _smoke_cfg(arch)
    m = get_model(cfg)
    params = m.init(cfg, jax.random.key(3))
    specs = m.param_specs(cfg)

    def is_names(x):
        return isinstance(x, tuple) and all(isinstance(n, (str, type(None))) for n in x)

    pleaves = jax.tree.leaves(params)
    sleaves = jax.tree.leaves(specs, is_leaf=is_names)
    assert len(pleaves) == len(sleaves), f"{arch}: {len(pleaves)} params vs {len(sleaves)} specs"
    for p, s in zip(pleaves, sleaves):
        assert p.ndim == len(s), f"{arch}: param rank {p.shape} vs spec {s}"


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_param_count_sane(arch):
    """Analytic full-size param count is within 25% of the reduced-model
    scaling sanity bound (catches config typos like swapped dims)."""
    cfg = get_config(arch)
    n = cfg.param_count()
    expected = {
        "qwen2-vl-72b": 72e9,
        "olmo-1b": 1.2e9,
        "starcoder2-7b": 7e9,
        "deepseek-67b": 67e9,
        "stablelm-1.6b": 1.6e9,
        "phi3.5-moe-42b-a6.6b": 42e9,
        "qwen2-moe-a2.7b": 14.3e9,
        "mamba2-130m": 0.13e9,
        "hymba-1.5b": 1.5e9,
        "whisper-tiny": 0.039e9,
    }[arch]
    assert 0.5 * expected < n < 1.7 * expected, f"{arch}: {n/1e9:.2f}B vs {expected/1e9:.2f}B"
