"""Per-kernel validation: Pallas (interpret=True on CPU) vs pure-jnp
oracle, swept over shapes/dtypes — plus hypothesis property sweeps
(deterministic fallback sweeps when hypothesis isn't installed)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # minimal container: seeded fallback sweeps
    from _hypothesis_compat import given, settings, strategies as st

from repro.kernels.flash_attention.kernel import flash_attention_bhsd
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.mandelbrot.kernel import mandelbrot
from repro.kernels.mandelbrot.ref import mandelbrot_ref
from repro.kernels.partition_map.kernel import partition_map
from repro.kernels.partition_map.ref import partition_map_ref
from repro.kernels.ssd_scan.kernel import ssd_scan
from repro.kernels.ssd_scan.ref import ssd_ref
from repro.kernels.stencil.kernel import stencil
from repro.kernels.stencil.ref import stencil_ref

# ---------------------------------------------------------------------------
# registry surface
# ---------------------------------------------------------------------------


def test_all_kernels_aggregates_every_package():
    from repro.kernels import all_kernels

    ks = all_kernels()
    # one representative op per package, all callable
    for name in ("stencil", "partition_map", "mandelbrot", "flash_attention", "ssd"):
        assert name in ks and callable(ks[name]), name
    # aggregation is deterministic (fixed package order)
    assert list(ks) == list(all_kernels())


# ---------------------------------------------------------------------------
# stencil
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n,block", [(64, 16), (256, 64), (1024, 128), (4096, 1024)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_stencil_matches_ref(n, block, dtype):
    x = jax.random.normal(jax.random.key(n), (n,), dtype)
    got = stencil(x, block=block, interpret=True)
    want = stencil_ref(x)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=2e-2, atol=2e-2
    )


@settings(max_examples=10, deadline=None)
@given(
    nb=st.integers(2, 8),
    block=st.sampled_from([8, 32, 128]),
    seed=st.integers(0, 2**16),
)
def test_stencil_property(nb, block, seed):
    x = jax.random.normal(jax.random.key(seed), (nb * block,), jnp.float32)
    got = stencil(x, block=block, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(stencil_ref(x)), rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# partition map
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n,block", [(128, 32), (8192, 2048)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_partition_map_matches_ref(n, block, dtype):
    x = (jax.random.normal(jax.random.key(7), (n,)) * 10).astype(dtype)
    got = partition_map(x, block=block, interpret=True)
    want = partition_map_ref(x)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=2e-2, atol=2e-2
    )


def test_partition_map_is_one():
    x = jax.random.normal(jax.random.key(0), (1024,), jnp.float32) * 100
    np.testing.assert_allclose(np.asarray(partition_map(x, block=256)), 1.0, rtol=1e-5)


# ---------------------------------------------------------------------------
# mandelbrot
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("h,w,blk", [(64, 64, (32, 32)), (128, 256, (64, 128))])
def test_mandelbrot_matches_ref(h, w, blk):
    got = mandelbrot(height=h, width=w, max_iter=32, block=blk, interpret=True)
    want = mandelbrot_ref(h, w, 32)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_mandelbrot_interior_hits_max_iter():
    it = mandelbrot(height=64, width=64, max_iter=24, block=(32, 32), interpret=True)
    # the origin neighbourhood is inside the set -> max_iter
    mid = np.asarray(it)[32, 21]  # c approx (-1, 0): inside
    assert mid == 24


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("B,Sq,Skv,H,K,D,bq,bk", [
    (1, 128, 128, 4, 4, 64, 64, 64),     # MHA
    (2, 256, 256, 8, 2, 32, 128, 64),    # GQA R=4
    (1, 128, 256, 4, 1, 64, 64, 128),    # MQA, cross Skv>Sq
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_matches_ref(B, Sq, Skv, H, K, D, bq, bk, causal):
    if causal and Sq != Skv:
        pytest.skip("causal requires square here")
    ks = jax.random.split(jax.random.key(3), 3)
    q = jax.random.normal(ks[0], (B, Sq, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, Skv, K, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, Skv, K, D), jnp.float32)
    got = flash_attention_bhsd(
        q.swapaxes(1, 2), k.swapaxes(1, 2), v.swapaxes(1, 2),
        causal=causal, bq=bq, bk=bk, interpret=True,
    ).swapaxes(1, 2)
    want = flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


@settings(max_examples=8, deadline=None)
@given(
    B=st.integers(1, 2),
    nq=st.integers(1, 4),
    K=st.sampled_from([1, 2, 4]),
    R=st.sampled_from([1, 2, 4]),
    D=st.sampled_from([16, 64]),
    seed=st.integers(0, 2**16),
)
def test_flash_attention_property(B, nq, K, R, D, seed):
    bq = bk = 32
    Sq = Skv = nq * bq
    H = K * R
    ks = jax.random.split(jax.random.key(seed), 3)
    q = jax.random.normal(ks[0], (B, Sq, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, Skv, K, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, Skv, K, D), jnp.float32)
    got = flash_attention(q, k, v, causal=True, bq=bq, bk=bk, impl="pallas")
    want = flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=3e-4, atol=3e-4)


def test_flash_attention_bf16():
    ks = jax.random.split(jax.random.key(5), 3)
    q = jax.random.normal(ks[0], (1, 128, 4, 32), jnp.bfloat16)
    k = jax.random.normal(ks[1], (1, 128, 2, 32), jnp.bfloat16)
    v = jax.random.normal(ks[2], (1, 128, 2, 32), jnp.bfloat16)
    got = flash_attention(q, k, v, causal=True, bq=64, bk=64, impl="pallas")
    want = flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=5e-2, atol=5e-2
    )


# ---------------------------------------------------------------------------
# SSD scan
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("G,S,P,N,chunk", [
    (2, 64, 16, 8, 16),
    (4, 128, 32, 16, 64),
    (1, 256, 64, 128, 64),   # mamba2-130m-like head
])
def test_ssd_scan_matches_sequential_ref(G, S, P, N, chunk):
    ks = jax.random.split(jax.random.key(11), 5)
    x = jax.random.normal(ks[0], (G, S, P), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (G, S))) * 0.1
    A = -jnp.exp(jax.random.normal(ks[2], (G,)) * 0.3)
    B = jax.random.normal(ks[3], (G, S, N), jnp.float32) * 0.5
    C = jax.random.normal(ks[4], (G, S, N), jnp.float32) * 0.5
    got = ssd_scan(x, dt, A, B, C, chunk=chunk, interpret=True)
    want = ssd_ref(x, dt, A, B, C)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-3, atol=2e-3)


@settings(max_examples=8, deadline=None)
@given(
    G=st.integers(1, 3),
    nc=st.integers(1, 4),
    chunk=st.sampled_from([8, 16, 32]),
    P=st.sampled_from([8, 16]),
    N=st.sampled_from([4, 16]),
    seed=st.integers(0, 2**16),
)
def test_ssd_scan_property(G, nc, chunk, P, N, seed):
    S = nc * chunk
    ks = jax.random.split(jax.random.key(seed), 5)
    x = jax.random.normal(ks[0], (G, S, P), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (G, S))) * 0.2
    A = -jnp.exp(jax.random.normal(ks[2], (G,)) * 0.2)
    B = jax.random.normal(ks[3], (G, S, N)) * 0.3
    C = jax.random.normal(ks[4], (G, S, N)) * 0.3
    got = ssd_scan(x, dt, A, B, C, chunk=chunk, interpret=True)
    want = ssd_ref(x, dt, A, B, C)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=5e-3, atol=5e-3)


def test_ssd_model_layer_uses_same_math():
    """The model's ssd_chunked and the kernel agree (same chunk boundaries)."""
    from repro.models.ssm import ssd_chunked

    Bz, S, H, P, N = 2, 64, 3, 16, 8
    ks = jax.random.split(jax.random.key(13), 5)
    x = jax.random.normal(ks[0], (Bz, S, H, P), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (Bz, S, H))) * 0.1
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    B = jax.random.normal(ks[3], (Bz, S, 1, N)) * 0.5
    C = jax.random.normal(ks[4], (Bz, S, 1, N)) * 0.5
    y_model, _ = ssd_chunked(x, dt, A, B, C, chunk=16)

    from repro.kernels.ssd_scan.ops import ssd

    Bh = jnp.repeat(B, H, axis=2)
    Ch = jnp.repeat(C, H, axis=2)
    y_kernel = ssd(x, dt, A, Bh, Ch, impl="pallas", chunk=16)
    np.testing.assert_allclose(
        np.asarray(y_model), np.asarray(y_kernel, np.float32), rtol=2e-3, atol=2e-3
    )
