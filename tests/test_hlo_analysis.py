"""Unit tests for the loop-aware HLO analyzer (roofline substrate)."""
import textwrap

import pytest

from repro.analysis.hlo_analysis import (
    _nbytes,
    analyze,
    execution_multipliers,
    parse_hlo,
)

SIMPLE = textwrap.dedent(
    """\
    HloModule jit_f, is_scheduled=true

    %wrapped_tanh_computation (param_0.1: f32[256,256]) -> f32[256,256] {
      %param_0.1 = f32[256,256]{1,0} parameter(0)
      ROOT %tanh.1 = f32[256,256]{1,0} tanh(%param_0.1)
    }

    %region_0.2 (arg_tuple.1: (s32[], f32[256,256], f32[256,256])) -> (s32[], f32[256,256], f32[256,256]) {
      %arg_tuple.1 = (s32[], f32[256,256]{1,0}, f32[256,256]{1,0}) parameter(0)
      %get-tuple-element.6 = s32[] get-tuple-element(%arg_tuple.1), index=0
      %get-tuple-element.7 = f32[256,256]{1,0} get-tuple-element(%arg_tuple.1), index=1
      %get-tuple-element.14 = f32[256,256]{1,0} get-tuple-element(%arg_tuple.1), index=2
      %dot_general.0 = f32[256,256]{1,0} dot(%get-tuple-element.7, %get-tuple-element.14), lhs_contracting_dims={1}, rhs_contracting_dims={0}
      %wrapped_tanh = f32[256,256]{1,0} fusion(%dot_general.0), kind=kLoop, calls=%wrapped_tanh_computation
      ROOT %tuple.2 = (s32[], f32[256,256]{1,0}, f32[256,256]{1,0}) tuple(%get-tuple-element.6, %wrapped_tanh, %get-tuple-element.14)
    }

    %region_1.3 (arg_tuple.3: (s32[], f32[256,256], f32[256,256])) -> pred[] {
      %arg_tuple.3 = (s32[], f32[256,256]{1,0}, f32[256,256]{1,0}) parameter(0)
      %get-tuple-element.9 = s32[] get-tuple-element(%arg_tuple.3), index=0
      %constant.4 = s32[] constant(16)
      ROOT %compare.1 = pred[] compare(%get-tuple-element.9, %constant.4), direction=LT
    }

    ENTRY %main.4 (x.1: f32[256,256], w.1: f32[256,256]) -> f32[256,256] {
      %x.1 = f32[256,256]{1,0} parameter(0)
      %w.1 = f32[256,256]{1,0} parameter(1)
      %constant.2 = s32[] constant(0)
      %tuple = (s32[], f32[256,256]{1,0}, f32[256,256]{1,0}) tuple(%constant.2, %x.1, %w.1)
      %while.5 = (s32[], f32[256,256]{1,0}, f32[256,256]{1,0}) while(%tuple), condition=%region_1.3, body=%region_0.2, backend_config={"known_trip_count":{"n":"16"}}
      ROOT %get-tuple-element.20 = f32[256,256]{1,0} get-tuple-element(%while.5), index=1
    }
    """
)


def test_nbytes():
    assert _nbytes("f32[256,256]{1,0}") == 256 * 256 * 4
    assert _nbytes("bf16[8]") == 16
    assert _nbytes("(f32[2,2], s32[])") == 20
    assert _nbytes("pred[]") == 1


def test_parse_and_multipliers():
    comps, entry = parse_hlo(SIMPLE)
    assert entry == "main.4"
    assert set(comps) == {"wrapped_tanh_computation", "region_0.2", "region_1.3", "main.4"}
    mult = execution_multipliers(comps, entry)
    assert mult["region_0.2"] == 16  # while body x trip count
    assert mult["region_1.3"] == 17  # condition runs trips+1
    assert mult["wrapped_tanh_computation"] == 16  # fusion inside the body


def test_dot_flops_scaled_by_trip_count():
    out = analyze(SIMPLE)
    # one 256x256x256 matmul per iteration x 16 iterations
    assert out["flops"] == pytest.approx(16 * 2 * 256**3)


def test_collective_accounting():
    hlo = textwrap.dedent(
        """\
        HloModule jit_g, is_scheduled=true

        ENTRY %main (x: f32[1024]) -> f32[1024] {
          %x = f32[1024]{0} parameter(0)
          %all-reduce.1 = f32[1024]{0} all-reduce(%x), replica_groups={{0,1,2,3}}, to_apply=%add
          %all-gather.1 = f32[4096]{0} all-gather(%all-reduce.1), replica_groups=[1,4]<=[4], dimensions={0}
          ROOT %slice = f32[1024]{0} slice(%all-gather.1), slice={[0:1024]}
        }
        """
    )
    out = analyze(hlo)
    ar = 2 * 1024 * 4 * 3 / 4  # 2 x bytes x (n-1)/n
    ag = 4096 * 4 * 3 / 4
    assert out["collective_wire_bytes"] == pytest.approx(ar + ag)
    assert out["collective_counts"] == {"all-reduce": 1, "all-gather": 1}


def test_inplace_dus_fusion_charged_at_update_size():
    hlo = textwrap.dedent(
        """\
        HloModule jit_h, is_scheduled=true

        %fused_computation (param_0: f32[64,1024], param_1: f32[1,1024], param_2: s32[]) -> f32[64,1024] {
          %param_0 = f32[64,1024]{1,0} parameter(0)
          %param_1 = f32[1,1024]{1,0} parameter(1)
          %param_2 = s32[] parameter(2)
          %c0 = s32[] constant(0)
          ROOT %dynamic-update-slice.1 = f32[64,1024]{1,0} dynamic-update-slice(%param_0, %param_1, %param_2, %c0)
        }

        ENTRY %main (buf: f32[64,1024], upd: f32[1,1024], i: s32[]) -> f32[64,1024] {
          %buf = f32[64,1024]{1,0} parameter(0)
          %upd = f32[1,1024]{1,0} parameter(1)
          %i = s32[] parameter(2)
          ROOT %dus_fusion = f32[64,1024]{1,0} fusion(%buf, %upd, %i), kind=kLoop, calls=%fused_computation
        }
        """
    )
    out = analyze(hlo)
    # charged at 2 x update bytes, not 2 x 64x1024 buffer bytes
    assert out["hbm_bytes"] == pytest.approx(2 * 1024 * 4)


def test_real_compiled_module_roundtrip():
    """End-to-end: compile a scan, analyzer flops == iterations x matmul."""
    import jax
    import jax.numpy as jnp

    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None

        y, _ = jax.lax.scan(body, x, None, length=8)
        return y

    spec = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    txt = jax.jit(f).lower(spec, spec).compile().as_text()
    out = analyze(txt)
    assert out["flops"] == pytest.approx(8 * 2 * 128**3, rel=0.01)
