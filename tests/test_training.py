"""Training loop / optimizer / microbatching integration tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, smoke
from repro.configs.base import ShapeConfig
from repro.models import get_model, make_batch
from repro.training.optimizer import OptConfig, adamw_update, init_opt_state, schedule
from repro.training.train_step import make_train_step

SHAPE = ShapeConfig("t", seq_len=32, global_batch=4, kind="train")


def test_adamw_minimizes_quadratic():
    params = {"w": jnp.array([5.0, -3.0])}
    state = init_opt_state(params)
    cfg = OptConfig(lr=0.1, weight_decay=0.0, warmup_steps=0, total_steps=200, clip_norm=None)
    for _ in range(200):
        grads = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        params, state, _ = adamw_update(cfg, params, grads, state)
    np.testing.assert_allclose(np.asarray(params["w"]), 0.0, atol=0.05)


def test_schedule_warmup_and_cosine():
    cfg = OptConfig(lr=1.0, warmup_steps=10, total_steps=110, min_lr_frac=0.1)
    assert float(schedule(cfg, jnp.int32(0))) == 0.0
    assert abs(float(schedule(cfg, jnp.int32(10))) - 1.0) < 1e-6
    assert float(schedule(cfg, jnp.int32(5))) == pytest.approx(0.5, rel=1e-5)
    assert float(schedule(cfg, jnp.int32(110))) == pytest.approx(0.1, rel=1e-4)


def test_grad_clip_bounds_update():
    params = {"w": jnp.zeros(3)}
    state = init_opt_state(params)
    cfg = OptConfig(lr=1e-3, clip_norm=1.0, warmup_steps=0, total_steps=10)
    grads = {"w": jnp.full(3, 1e6)}
    _, _, metrics = adamw_update(cfg, params, grads, state)
    assert float(metrics["gnorm"]) > 1e5  # raw norm reported


def _plan(cfg, micro):
    from repro.distribution.recipes import plan_for
    from dataclasses import replace

    # f32 compute: these tests check *numerical equivalence* properties,
    # independent of the bf16 mixed-precision policy
    p = plan_for(cfg, SHAPE)
    return replace(p, num_microbatches=micro, remat="none", q_block=None, compute_dtype="float32")


def test_train_step_loss_decreases_over_steps():
    cfg = smoke(get_config("olmo-1b"))
    m = get_model(cfg)
    opt_cfg = OptConfig(lr=1e-2, warmup_steps=0, total_steps=50)
    step = jax.jit(make_train_step(cfg, SHAPE, opt_cfg, _plan(cfg, 1)))
    params = m.init(cfg, jax.random.key(0))
    opt_state = init_opt_state(params)
    batch = make_batch(cfg, SHAPE)  # overfit one batch
    losses = []
    for _ in range(8):
        params, opt_state, metrics = step(params, opt_state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0], losses


def test_microbatching_matches_full_batch_grads():
    """n_micro=2 must produce the same update as n_micro=1 (mean of micro
    losses == full-batch loss for equal-sized microbatches)."""
    cfg = smoke(get_config("olmo-1b"))
    m = get_model(cfg)
    opt_cfg = OptConfig(lr=1e-3, warmup_steps=0, total_steps=10)
    batch = make_batch(cfg, SHAPE, seed=3)
    params = m.init(cfg, jax.random.key(1))

    outs = {}
    for n in (1, 2):
        step = jax.jit(make_train_step(cfg, SHAPE, opt_cfg, _plan(cfg, n)))
        p2, _, metrics = step(params, init_opt_state(params), batch)
        outs[n] = (jax.tree.map(np.asarray, p2), float(metrics["loss"]))
    np.testing.assert_allclose(outs[1][1], outs[2][1], rtol=1e-5)
    for a, b in zip(jax.tree.leaves(outs[1][0]), jax.tree.leaves(outs[2][0])):
        np.testing.assert_allclose(a, b, rtol=2e-4, atol=2e-6)


def test_end_to_end_train_driver(tmp_path):
    from repro.launch.train import train

    out = train(
        "stablelm-1.6b",
        use_smoke=True,
        steps=6,
        batch=4,
        seq=32,
        lr=5e-3,
        ckpt_dir=str(tmp_path / "ck"),
        ckpt_every=3,
        log_every=0,
    )
    assert len(out["losses"]) == 6
    assert np.isfinite(out["final_loss"])
    # two async checkpoints must exist (steps 3 and 6)
    from repro.checkpoint.checkpoint import CheckpointManager

    mgr = CheckpointManager(str(tmp_path / "ck"))
    assert mgr.steps() == [3, 6]
