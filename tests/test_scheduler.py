"""Locality-aware scheduler (DESIGN.md §9): placement policies, load
accounting, AGAS reverse index / resident bytes, buffer lifetime, the
stale-runtime reset fix, and a forced-8-host-device integration run."""
import gc
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import (
    QueueLoad,
    Scheduler,
    get_all_devices,
    get_all_localities,
    get_runtime,
    get_scheduler,
    make_policy,
    registry,
    reset_runtime,
    set_scheduler,
    wait_all,
)
from repro.core.scheduler import (
    AffinityPolicy,
    LeastLoadedPolicy,
    RoundRobinPolicy,
    StaticPolicy,
)

# ---------------------------------------------------------------------------
# policy unit tests (duck-typed fakes: policies only read key/ops_queue.load)
# ---------------------------------------------------------------------------


class _FakeQueue:
    def __init__(self, depth=0, busy_time=0.0):
        self.depth, self.busy_time = depth, busy_time

    def load(self) -> QueueLoad:
        return QueueLoad(
            depth=self.depth,
            inflight=1 if self.depth else 0,
            busy_for=0.0,
            busy_time=self.busy_time,
            submitted=self.depth,
            completed=0,
        )


class _FakeDevice:
    def __init__(self, key, depth=0, busy_time=0.0):
        self.key = key
        self.ops_queue = _FakeQueue(depth, busy_time)

    def __repr__(self):
        return f"_FakeDevice({self.key})"


class _FakeBuf:
    """Affinity arg: anything exposing device + nbytes counts."""

    def __init__(self, device, nbytes):
        self.device, self.nbytes = device, nbytes


def _fleet(n=4):
    return [_FakeDevice(f"cpu:{i}") for i in range(n)]


def test_static_policy_pins_one_device():
    devs = _fleet()
    p = StaticPolicy()
    assert [p.select(devs).key for _ in range(5)] == ["cpu:0"] * 5
    assert StaticPolicy(index=2).select(devs).key == "cpu:2"


def test_round_robin_cycles_through_fleet():
    devs = _fleet(3)
    p = RoundRobinPolicy()
    picked = [p.select(devs).key for _ in range(7)]
    assert picked == ["cpu:0", "cpu:1", "cpu:2", "cpu:0", "cpu:1", "cpu:2", "cpu:0"]


def test_least_loaded_prefers_idle_queue():
    devs = _fleet(4)
    devs[0].ops_queue.depth = 3
    devs[1].ops_queue.depth = 1
    devs[3].ops_queue.depth = 2
    assert LeastLoadedPolicy().select(devs).key == "cpu:2"  # the idle one


def test_least_loaded_ties_rotate_not_pile_up():
    devs = _fleet(3)
    p = LeastLoadedPolicy()
    # all idle: a blind signal must degrade to round-robin spread
    assert [p.select(devs).key for _ in range(4)] == ["cpu:0", "cpu:1", "cpu:2", "cpu:0"]
    devs[1].ops_queue.depth = 2
    picked = {p.select(devs).key for _ in range(4)}
    assert picked == {"cpu:0", "cpu:2"}  # the loaded queue is skipped


def test_affinity_avoids_percolation():
    devs = _fleet(4)
    devs[2].ops_queue.depth = 5  # resident data outweighs load ...
    args = [_FakeBuf(devs[2], nbytes=1 << 20), _FakeBuf(devs[0], nbytes=16)]
    assert AffinityPolicy().select(devs, args=args).key == "cpu:2"
    # ... and with no resident args it degrades to least_loaded
    devs[2].ops_queue.depth = 5
    assert AffinityPolicy().select(devs, args=[np.ones(4)]).key == "cpu:0"


def test_arg_home_resolves_committed_jax_arrays():
    import jax
    import jax.numpy as jnp

    from repro.core.scheduler import _arg_home

    dev = get_all_devices(1, 0).get()[0]
    arr = jax.device_put(jnp.ones(16, jnp.float32), dev.jax_device)
    key, nb = _arg_home(arr)
    assert key == dev.key and nb == arr.nbytes  # not shadowed by .device/.nbytes


def test_make_policy_rejects_unknown():
    with pytest.raises(ValueError, match="unknown placement policy"):
        make_policy("fifo")
    p = RoundRobinPolicy()
    assert make_policy(p) is p  # instances pass through


def test_scheduler_records_placement_stats():
    devs = _fleet(2)
    s = Scheduler(devs, policy="round_robin")
    for _ in range(4):
        s.select()
    assert s.stats() == {"cpu:0": 2, "cpu:1": 2}


# ---------------------------------------------------------------------------
# WorkQueue load accounting
# ---------------------------------------------------------------------------


def test_workqueue_load_counts_backlog():
    import threading

    q = get_runtime().queue("test-load-accounting")
    assert q.load().depth == 0
    gate = threading.Event()
    started = threading.Event()

    def _block():
        started.set()
        gate.wait(10)

    f = q.submit(_block)
    rest = [q.submit(lambda: None) for _ in range(3)]
    started.wait(10)
    load = q.load()
    assert load.depth == 4 and load.inflight == 1 and load.busy_for >= 0.0
    gate.set()
    wait_all([f] + rest)
    load = q.load()
    assert load.depth == 0 and load.inflight == 0
    assert load.completed == load.submitted and load.busy_time > 0.0


# ---------------------------------------------------------------------------
# AGAS reverse index, resident bytes, buffer lifetime (leak fix)
# ---------------------------------------------------------------------------


@pytest.fixture()
def device():
    return get_all_devices(1, 0).get()[0]


def test_reverse_index_and_resident_bytes(device):
    base = registry.resident_bytes(device.key)
    buf = device.create_buffer(256, np.float32).get()
    assert buf.gid in registry.gids_on(device.key, kind="buffer")
    assert registry.resident_bytes(device.key) == base + 1024
    assert device.resident_bytes() == base + 1024
    buf.free().get()
    assert registry.resident_bytes(device.key) == base
    assert buf.gid not in registry.gids_on(device.key)


def test_buffer_free_is_terminal_and_idempotent(device):
    buf = device.create_buffer(8, np.float32).get()
    buf.free().get()
    buf.free().get()  # idempotent: second free is a ready no-op
    with pytest.raises(RuntimeError, match="freed"):
        buf.array()
    with pytest.raises(KeyError):
        registry.resolve(buf.gid)


def test_free_is_ordered_after_pending_launches(device):
    prog = device.create_program({"double": lambda x: x * 2.0}, name="free-order").get()
    buf = device.create_buffer_from(np.arange(8, dtype=np.float32)).get()
    fut = prog.run([buf], "double")
    buf.free()  # queued behind the launch: the launch still reads live storage
    np.testing.assert_allclose(np.asarray(fut.get()), np.arange(8.0) * 2.0)
    with pytest.raises(RuntimeError, match="freed"):
        buf.enqueue_read().get()


def test_collected_buffer_unregisters_via_finalizer(device):
    base_bytes = registry.resident_bytes(device.key)
    buf = device.create_buffer(512, np.float32).get()
    gid = buf.gid
    assert registry.resident_bytes(device.key) == base_bytes + 2048
    del buf
    gc.collect()  # may also reap other dead objects' records — assert on gid
    with pytest.raises(KeyError):
        registry.resolve(gid)
    assert gid not in registry.gids_on(device.key)
    assert registry.resident_bytes(device.key) == base_bytes


def test_copy_to_registers_bytes_on_target(device):
    buf = device.create_buffer_from(np.arange(16, dtype=np.float32)).get()
    moved = buf.copy_to(device).get()
    assert moved.gid in registry.gids_on(device.key, kind="buffer")
    wait_all([moved.free(), buf.free()])


# ---------------------------------------------------------------------------
# localities, default scheduler, run_on_any / route_batches smoke
# ---------------------------------------------------------------------------


def test_localities_group_by_process(device):
    locs = get_all_localities(1, 0).get()
    assert len(locs) >= 1
    local = [l for l in locs if l.is_local]
    assert local and device in list(local[0])


def test_run_on_any_single_device(device):
    prog = device.create_program({"double": lambda x: x * 2.0}, name="any").get()
    sched = Scheduler([device], policy="least_loaded")
    out = device.create_buffer(4, np.float32).get()
    fut = prog.run_on_any([np.arange(4, dtype=np.float32)], "double", out=[out], scheduler=sched)
    fut.get()
    np.testing.assert_allclose(out.enqueue_read_sync(), [0.0, 2.0, 4.0, 6.0])
    assert sched.stats() == {device.key: 1}


def test_route_batches_places_every_batch(device):
    from repro.serving.serve_step import route_batches

    sched = Scheduler([device], policy="round_robin")
    batches = [{"x": np.full(4, i, np.float32)} for i in range(3)]
    futs = route_batches(lambda b: b["x"] * 2.0, batches, scheduler=sched)
    vals = [np.asarray(f.get()) for f in futs]
    for i, v in enumerate(vals):
        np.testing.assert_allclose(v, np.full(4, 2.0 * i))
    assert sched.stats() == {device.key: 3}


def test_default_scheduler_is_process_wide():
    set_scheduler(None)
    s1, s2 = get_scheduler(), get_scheduler()
    assert s1 is s2
    mine = Scheduler(policy="round_robin")
    set_scheduler(mine)
    try:
        assert get_scheduler() is mine
    finally:
        set_scheduler(None)


# ---------------------------------------------------------------------------
# stale-runtime regression (satellite fix): reset must drop cached devices
# ---------------------------------------------------------------------------


def test_reset_runtime_recycles_device_cache():
    dev = get_all_devices(1, 0).get()[0]
    dev.create_buffer(4, np.float32).get()  # exercise the old queues
    old_gid = dev.gid
    reset_runtime()
    # the old Device's AGAS record is retired with its queues
    with pytest.raises(KeyError):
        registry.resolve(old_gid)
    # rediscovery binds fresh queues — this used to raise "WorkQueue ...
    # is shut down" because the cache kept devices of the dead runtime
    fresh = get_all_devices(1, 0).get()[0]
    buf = fresh.create_buffer_from(np.arange(4.0, dtype=np.float32)).get()
    np.testing.assert_allclose(buf.enqueue_read_sync(), np.arange(4.0))
    # the default scheduler was rebuilt over the fresh fleet too
    assert get_scheduler().select().ops_queue is fresh.ops_queue


# ---------------------------------------------------------------------------
# integration: 8 forced host devices (re-exec pattern, see
# test_multidevice_train.py) — spread, least_loaded vs static wall-clock,
# affinity placement, multi-device graph fan-out replay
# ---------------------------------------------------------------------------

_CHILD = textwrap.dedent(
    """
    import os, time
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                               "--xla_cpu_multi_thread_eigen=false "
                               + os.environ.get("XLA_FLAGS", ""))
    import numpy as np
    import jax
    from repro.core import Scheduler, TaskGraph, capture, get_all_devices, registry, wait_all
    from repro.kernels.partition_map.ref import partition_map_ref

    devices = get_all_devices(1, 0).get()
    assert len(devices) == 8, devices

    # fig6 partition workload, compute-dense variant (iterated map)
    def k(x):
        def body(i, v):
            return partition_map_ref(v) * 0.5 + v * 0.5
        return jax.lax.fori_loop(0, 32, body, x)

    prog = devices[0].create_program({"k": k}, "partition").get()
    parts = [np.random.default_rng(i).normal(size=(1 << 17,)).astype(np.float32)
             for i in range(8)]

    def pipeline(sched):
        futs = [prog.run_on_any([p], "k", scheduler=sched) for p in parts]
        wait_all(futs)
        return [f.get() for f in futs]

    # placement spread: least_loaded fills the whole 8-device fleet
    sched_ll = Scheduler(devices, policy="least_loaded")
    pipeline(sched_ll)
    spread = sched_ll.stats()
    print("SPREAD", len(spread))
    assert len(spread) == 8, spread

    # wall-clock: least_loaded must beat static single-device placement.
    # Timed on a 2-device fleet (a 2-core CI box cannot feed 8 concurrent
    # queues), interleaved min-of-reps, retried on load spikes — shared
    # runners must not turn a structural 2x advantage into a flaky red.
    fleet2 = devices[:2]
    def time_policy(policy):
        sched = Scheduler(fleet2, policy=policy)
        t0 = time.perf_counter()
        pipeline(sched)
        return time.perf_counter() - t0
    time_policy("static"); time_policy("least_loaded")  # warm both routes
    best = float("inf")
    for attempt in range(4):
        t_s = t_l = float("inf")
        for _ in range(3):  # interleave so load spikes hit both policies
            t_s = min(t_s, time_policy("static"))
            t_l = min(t_l, time_policy("least_loaded"))
        best = min(best, t_l / t_s)
        print("TIMES", f"{t_s:.4f}", f"{t_l:.4f}", f"best_ratio={best:.3f}")
        if best < 0.9:
            break
    assert best < 1.0, best  # least_loaded beat static in at least one round

    # affinity keeps work where the bytes are (no percolation)
    target = devices[5]
    big = target.create_buffer_from(np.ones(1 << 16, np.float32)).get()
    aff = Scheduler(devices, policy="affinity")
    out = target.create_buffer(1 << 16, np.float32).get()
    prog.run_on_any([big], "k", out=[out], scheduler=aff).get()
    assert aff.stats() == {target.key: 1}, aff.stats()
    assert registry.placement(out.gid).device_key == target.key
    print("AFFINITY ok")

    # captured multi-device graph (recorded through run_on_any) replays
    # through ONE future: per-device fused segments + explicit transfer
    d0, d1 = devices[0], devices[1]
    p2 = d0.create_program({"inc": lambda x: x + 1.0, "scale": lambda x: x * 3.0}, "g").get()
    b_in = d0.create_buffer(16, np.float32).get()
    t_mid = d0.create_buffer(16, np.float32).get()
    t_out = d1.create_buffer(16, np.float32).get()
    rr = Scheduler([d0, d1], policy="round_robin")
    with capture("xdev") as g:
        w = b_in.enqueue_write(0, np.ones(16, np.float32))
        p2.run_on_any([b_in], "inc", out=[t_mid], scheduler=rr)     # -> cpu:0
        p2.run_on_any([t_mid], "scale", out=[t_out], scheduler=rr)  # -> cpu:1
        r = t_out.enqueue_read()
    exe = g.instantiate()
    assert exe._fanout and len(exe._segments) == 2, repr(exe)
    assert len(exe._transfers) >= 1, repr(exe)
    fut = exe.replay()          # ONE future for the whole graph
    res = fut.get()
    np.testing.assert_allclose(res[r], np.full(16, 6.0))
    res2 = exe.replay(feeds={w: np.full(16, 2.0, np.float32)}).get()
    np.testing.assert_allclose(res2[r], np.full(16, 9.0))
    assert registry.placement(t_out.gid).device_key == d1.key
    print("GRAPH", repr(exe))

    # fan-out donation safety: a sym consumed by two segments that may run
    # CONCURRENTLY (both depend only on the producer) must never be donated
    a0 = d0.create_buffer(8, np.float32).get()
    m1 = d0.create_buffer(8, np.float32).get()
    o1 = d1.create_buffer(8, np.float32).get()
    o2 = d0.create_buffer(8, np.float32).get()
    ga = TaskGraph("donate-race")
    ga.write(a0, np.ones(8, np.float32))
    ga.run(p2.for_device(d0), [a0], "inc", out=[m1])    # seg 0 (dev0) -> m1
    ga.run(p2.for_device(d1), [m1], "scale", out=[o1])  # seg 1 (dev1) reads m1
    ga.run(p2.for_device(d0), [m1], "inc", out=[o2])    # seg 2 (dev0) reads m1 too
    r1, r2 = ga.read(o1), ga.read(o2)
    m1_sym = ga._cur[id(m1)]
    exe_a = ga.instantiate()
    assert exe_a._fanout and len(exe_a._segments) == 3, repr(exe_a)
    assert m1_sym not in exe_a._donated_syms  # concurrent readers: no donation
    res_a = exe_a.replay().get()
    np.testing.assert_allclose(res_a[r1], np.full(8, 6.0))  # (1+1)*3
    np.testing.assert_allclose(res_a[r2], np.full(8, 3.0))  # (1+1)+1
    print("OK")
    """
)


@pytest.mark.slow
def test_scheduler_integration_8_host_devices():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src:" + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-c", _CHILD],
        capture_output=True,
        text=True,
        env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=900,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    out = proc.stdout
    assert "OK" in out and "AFFINITY ok" in out, out
    # the wall-clock comparison (least_loaded beats static) is asserted in
    # the child; surface its measurement here for the test log
    assert any(l.startswith("TIMES") for l in out.splitlines()), out
