"""Locality-aware scheduler (DESIGN.md §9): placement policies, load
accounting, AGAS reverse index / resident bytes, buffer lifetime, the
stale-runtime reset fix, and a forced-8-host-device integration run."""
import gc
import os
import subprocess
import sys
import textwrap
import time

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # hypothesis not installed: deterministic fallback shim
    from _hypothesis_compat import given, settings, strategies as st

from repro.core import (
    HOST_KEY,
    QueueLoad,
    Scheduler,
    get_all_devices,
    get_all_localities,
    get_runtime,
    get_scheduler,
    make_policy,
    registry,
    reset_runtime,
    set_scheduler,
    wait_all,
)
from repro.core.scheduler import (
    AffinityPolicy,
    LeastLoadedPolicy,
    PercolationPolicy,
    RoundRobinPolicy,
    StaticPolicy,
)

# ---------------------------------------------------------------------------
# policy unit tests (duck-typed fakes: policies only read key/ops_queue.load)
# ---------------------------------------------------------------------------


class _FakeQueue:
    def __init__(self, depth=0, busy_time=0.0):
        self.depth, self.busy_time = depth, busy_time

    def load(self) -> QueueLoad:
        return QueueLoad(
            depth=self.depth,
            inflight=1 if self.depth else 0,
            busy_for=0.0,
            busy_time=self.busy_time,
            submitted=self.depth,
            completed=0,
        )


class _FakeDevice:
    def __init__(self, key, depth=0, busy_time=0.0):
        self.key = key
        self.ops_queue = _FakeQueue(depth, busy_time)

    def __repr__(self):
        return f"_FakeDevice({self.key})"


class _FakeBuf:
    """Affinity arg: anything exposing device + nbytes counts."""

    def __init__(self, device, nbytes):
        self.device, self.nbytes = device, nbytes


def _fleet(n=4):
    return [_FakeDevice(f"cpu:{i}") for i in range(n)]


def test_static_policy_pins_one_device():
    devs = _fleet()
    p = StaticPolicy()
    assert [p.select(devs).key for _ in range(5)] == ["cpu:0"] * 5
    assert StaticPolicy(index=2).select(devs).key == "cpu:2"


def test_round_robin_cycles_through_fleet():
    devs = _fleet(3)
    p = RoundRobinPolicy()
    picked = [p.select(devs).key for _ in range(7)]
    assert picked == ["cpu:0", "cpu:1", "cpu:2", "cpu:0", "cpu:1", "cpu:2", "cpu:0"]


def test_least_loaded_prefers_idle_queue():
    devs = _fleet(4)
    devs[0].ops_queue.depth = 3
    devs[1].ops_queue.depth = 1
    devs[3].ops_queue.depth = 2
    assert LeastLoadedPolicy().select(devs).key == "cpu:2"  # the idle one


def test_least_loaded_ties_rotate_not_pile_up():
    devs = _fleet(3)
    p = LeastLoadedPolicy()
    # all idle: a blind signal must degrade to round-robin spread
    assert [p.select(devs).key for _ in range(4)] == ["cpu:0", "cpu:1", "cpu:2", "cpu:0"]
    devs[1].ops_queue.depth = 2
    picked = {p.select(devs).key for _ in range(4)}
    assert picked == {"cpu:0", "cpu:2"}  # the loaded queue is skipped


def test_affinity_avoids_percolation():
    devs = _fleet(4)
    devs[2].ops_queue.depth = 5  # resident data outweighs load ...
    args = [_FakeBuf(devs[2], nbytes=1 << 20), _FakeBuf(devs[0], nbytes=16)]
    assert AffinityPolicy().select(devs, args=args).key == "cpu:2"
    # ... and with no resident args it degrades to least_loaded
    devs[2].ops_queue.depth = 5
    assert AffinityPolicy().select(devs, args=[np.ones(4)]).key == "cpu:0"


def test_arg_home_resolves_committed_jax_arrays():
    import jax
    import jax.numpy as jnp

    from repro.core.scheduler import _arg_home

    dev = get_all_devices(1, 0).get()[0]
    arr = jax.device_put(jnp.ones(16, jnp.float32), dev.jax_device)
    key, nb = _arg_home(arr)
    assert key == dev.key and nb == arr.nbytes  # not shadowed by .device/.nbytes


def test_make_policy_rejects_unknown():
    with pytest.raises(ValueError, match="unknown placement policy"):
        make_policy("fifo")
    p = RoundRobinPolicy()
    assert make_policy(p) is p  # instances pass through


def test_scheduler_records_placement_stats():
    devs = _fleet(2)
    s = Scheduler(devs, policy="round_robin")
    for _ in range(4):
        s.select()
    assert s.stats() == {"cpu:0": 2, "cpu:1": 2}


# ---------------------------------------------------------------------------
# WorkQueue load accounting
# ---------------------------------------------------------------------------


def test_workqueue_load_counts_backlog():
    import threading

    q = get_runtime().queue("test-load-accounting")
    assert q.load().depth == 0
    gate = threading.Event()
    started = threading.Event()

    def _block():
        started.set()
        gate.wait(10)

    f = q.submit(_block)
    rest = [q.submit(lambda: None) for _ in range(3)]
    started.wait(10)
    load = q.load()
    assert load.depth == 4 and load.inflight == 1 and load.busy_for >= 0.0
    gate.set()
    wait_all([f] + rest)
    load = q.load()
    assert load.depth == 0 and load.inflight == 0
    assert load.completed == load.submitted and load.busy_time > 0.0


# ---------------------------------------------------------------------------
# AGAS reverse index, resident bytes, buffer lifetime (leak fix)
# ---------------------------------------------------------------------------


@pytest.fixture()
def device():
    return get_all_devices(1, 0).get()[0]


def test_reverse_index_and_resident_bytes(device):
    base = registry.resident_bytes(device.key)
    buf = device.create_buffer(256, np.float32).get()
    assert buf.gid in registry.gids_on(device.key, kind="buffer")
    assert registry.resident_bytes(device.key) == base + 1024
    assert device.resident_bytes() == base + 1024
    buf.free().get()
    assert registry.resident_bytes(device.key) == base
    assert buf.gid not in registry.gids_on(device.key)


def test_buffer_free_is_terminal_and_idempotent(device):
    buf = device.create_buffer(8, np.float32).get()
    buf.free().get()
    buf.free().get()  # idempotent: second free is a ready no-op
    with pytest.raises(RuntimeError, match="freed"):
        buf.array()
    with pytest.raises(KeyError):
        registry.resolve(buf.gid)


def test_free_is_ordered_after_pending_launches(device):
    prog = device.create_program({"double": lambda x: x * 2.0}, name="free-order").get()
    buf = device.create_buffer_from(np.arange(8, dtype=np.float32)).get()
    fut = prog.run([buf], "double")
    buf.free()  # queued behind the launch: the launch still reads live storage
    np.testing.assert_allclose(np.asarray(fut.get()), np.arange(8.0) * 2.0)
    with pytest.raises(RuntimeError, match="freed"):
        buf.enqueue_read().get()


def test_collected_buffer_unregisters_via_finalizer(device):
    base_bytes = registry.resident_bytes(device.key)
    buf = device.create_buffer(512, np.float32).get()
    gid = buf.gid
    assert registry.resident_bytes(device.key) == base_bytes + 2048
    del buf
    gc.collect()  # may also reap other dead objects' records — assert on gid
    with pytest.raises(KeyError):
        registry.resolve(gid)
    assert gid not in registry.gids_on(device.key)
    assert registry.resident_bytes(device.key) == base_bytes


def test_copy_to_registers_bytes_on_target(device):
    buf = device.create_buffer_from(np.arange(16, dtype=np.float32)).get()
    moved = buf.copy_to(device).get()
    assert moved.gid in registry.gids_on(device.key, kind="buffer")
    wait_all([moved.free(), buf.free()])


# ---------------------------------------------------------------------------
# localities, default scheduler, run_on_any / route_batches smoke
# ---------------------------------------------------------------------------


def test_localities_group_by_process(device):
    locs = get_all_localities(1, 0).get()
    assert len(locs) >= 1
    local = [l for l in locs if l.is_local]
    assert local and device in list(local[0])


def test_run_on_any_single_device(device):
    prog = device.create_program({"double": lambda x: x * 2.0}, name="any").get()
    sched = Scheduler([device], policy="least_loaded")
    out = device.create_buffer(4, np.float32).get()
    fut = prog.run_on_any([np.arange(4, dtype=np.float32)], "double", out=[out], scheduler=sched)
    fut.get()
    np.testing.assert_allclose(out.enqueue_read_sync(), [0.0, 2.0, 4.0, 6.0])
    assert sched.stats() == {device.key: 1}


def test_route_batches_places_every_batch(device):
    from repro.serving.serve_step import route_batches

    sched = Scheduler([device], policy="round_robin")
    batches = [{"x": np.full(4, i, np.float32)} for i in range(3)]
    futs = route_batches(lambda b: b["x"] * 2.0, batches, scheduler=sched)
    vals = [np.asarray(f.get()) for f in futs]
    for i, v in enumerate(vals):
        np.testing.assert_allclose(v, np.full(4, 2.0 * i))
    assert sched.stats() == {device.key: 3}


def test_default_scheduler_is_process_wide():
    set_scheduler(None)
    s1, s2 = get_scheduler(), get_scheduler()
    assert s1 is s2
    mine = Scheduler(policy="round_robin")
    set_scheduler(mine)
    try:
        assert get_scheduler() is mine
    finally:
        set_scheduler(None)


# ---------------------------------------------------------------------------
# stale-runtime regression (satellite fix): reset must drop cached devices
# ---------------------------------------------------------------------------


def test_reset_runtime_recycles_device_cache():
    dev = get_all_devices(1, 0).get()[0]
    dev.create_buffer(4, np.float32).get()  # exercise the old queues
    old_gid = dev.gid
    reset_runtime()
    # the old Device's AGAS record is retired with its queues
    with pytest.raises(KeyError):
        registry.resolve(old_gid)
    # rediscovery binds fresh queues — this used to raise "WorkQueue ...
    # is shut down" because the cache kept devices of the dead runtime
    fresh = get_all_devices(1, 0).get()[0]
    buf = fresh.create_buffer_from(np.arange(4.0, dtype=np.float32)).get()
    np.testing.assert_allclose(buf.enqueue_read_sync(), np.arange(4.0))
    # the default scheduler was rebuilt over the fresh fleet too
    assert get_scheduler().select().ops_queue is fresh.ops_queue


# ---------------------------------------------------------------------------
# load-signal decay (DESIGN.md §14): busy_ewma rises with work, forgets it
# ---------------------------------------------------------------------------


def test_busy_ewma_rises_with_work_then_decays(monkeypatch):
    from repro.core import executor

    monkeypatch.setattr(executor, "_LOAD_HALFLIFE", 0.05)
    q = get_runtime().queue("test-busy-ewma")
    q.submit(lambda: time.sleep(0.12)).get()
    hot = q.load().busy_ewma
    assert hot > 0.25, hot  # just burned >1 tau of wall time
    time.sleep(0.4)  # 8 half-lives: the signal forgets
    cold = q.load().busy_ewma
    assert cold < 0.1 and cold < hot, (hot, cold)


def test_least_loaded_sees_recent_busy_time_not_just_depth(monkeypatch):
    # Both queues report depth 0 — the lifetime-blind case that used to
    # make placement a coin flip.  The decayed busy term must separate a
    # device that just worked from one that sat idle.
    from repro.core import executor

    monkeypatch.setattr(executor, "_LOAD_HALFLIFE", 0.5)  # slow decay in-test

    class _Shell:
        def __init__(self, key, q):
            self.key, self.ops_queue = key, q

    busy = _Shell("cpu:0", get_runtime().queue("test-occ-busy"))
    idle = _Shell("cpu:1", get_runtime().queue("test-occ-idle"))
    busy.ops_queue.submit(lambda: time.sleep(0.6)).get()  # most of a tau: signal
    p = LeastLoadedPolicy()
    assert all(p.select([busy, idle]).key == "cpu:1" for _ in range(3))


# ---------------------------------------------------------------------------
# tie rotation (satellite fix): equal scores must spread, not pin to dev 0
# ---------------------------------------------------------------------------


def test_affinity_ties_rotate_across_equal_hosts():
    devs = _fleet(3)
    args = [_FakeBuf(devs[1], nbytes=1024), _FakeBuf(devs[2], nbytes=1024)]
    p = AffinityPolicy()
    picked = [p.select(devs, args=args).key for _ in range(4)]
    assert set(picked) == {"cpu:1", "cpu:2"}, picked  # tied hosts take turns
    assert picked[0] != picked[1]


def test_percolation_ties_rotate_across_equal_costs():
    devs = _fleet(2)
    foreign = _FakeBuf(_FakeDevice("cpu:9"), nbytes=512)  # same bytes to move anywhere
    p = PercolationPolicy()
    picked = [p.select(devs, args=[foreign]).key for _ in range(4)]
    assert picked == ["cpu:0", "cpu:1", "cpu:0", "cpu:1"]


def test_select_batch_cold_start_spreads_over_fleet():
    s = Scheduler(_fleet(4), policy="least_loaded", steal=False)
    keys = [s.select_batch([[np.ones(4, np.float32)]]).key for _ in range(4)]
    assert len(set(keys)) == 4, keys  # blind batches round-robin, no pile-up


def test_occupancy_recent_free_probe_ignores_own_charge():
    devs = _fleet(2)
    s = Scheduler(devs, policy="least_loaded", steal=False)
    base = s.occupancy(devs[0])
    s.charge(devs[0], 4)
    assert s.occupancy(devs[0]) > base           # charge visible to placement
    assert s.occupancy(devs[0], recent=False) == base  # ...but not to the probe


def test_select_batch_prefer_holds_against_self_repulsion():
    """A decode stream's own recent-placement charge must NOT bounce the
    next micro-batch off its home (the fig9 batched_8dev regression):
    with the ``prefer`` hint the home holds, and ``stats()`` honestly
    records the held home, not the policy's repelled pick."""
    s = Scheduler(_fleet(4), policy="least_loaded", steal=False)
    home = s.select_batch([[np.ones(4, np.float32)]])
    s.charge(home, 7)
    for _ in range(5):
        dev = s.select_batch([[np.ones(4, np.float32)]], prefer=home.key)
        assert dev.key == home.key
        s.charge(dev, 7)
    assert s.stats()[home.key] == 6


def test_select_batch_prefer_yields_to_structural_load():
    devs = _fleet(2)
    devs[0].ops_queue.depth = 20  # real backlog, beyond the 16.0 slack
    s = Scheduler(devs, policy="least_loaded", steal=False)
    dev = s.select_batch([[np.ones(4, np.float32)]], prefer="cpu:0")
    assert dev.key == "cpu:1"


def test_select_batch_prefer_holds_through_burst_depth():
    # A burst keeps a few in-flight micro-batches queued on the home
    # lane; that is not a reason to hop (each is ~100us of work, and the
    # move costs an executable-cache warmup).  Depth within the slack
    # holds.
    devs = _fleet(2)
    devs[0].ops_queue.depth = 8  # a full in-flight burst window
    s = Scheduler(devs, policy="least_loaded", steal=False)
    dev = s.select_batch([[np.ones(4, np.float32)]], prefer="cpu:0")
    assert dev.key == "cpu:0"


def test_select_batch_prefer_ignored_by_non_load_policies():
    s = Scheduler(_fleet(3), policy="round_robin", steal=False)
    keys = [s.select_batch([[np.ones(4, np.float32)]], prefer="cpu:0").key
            for _ in range(3)]
    assert keys == ["cpu:0", "cpu:1", "cpu:2"]  # hint never overrides rotation


# ---------------------------------------------------------------------------
# memory-aware placement (DESIGN.md §14): veto, LRU spill, honest accounting
# ---------------------------------------------------------------------------


class _MemDevice(_FakeDevice):
    def __init__(self, key, resident=0, limit=0):
        super().__init__(key)
        self._resident = resident
        self.memory_limit = limit

    def resident_bytes(self):
        return self._resident


def test_memory_veto_skips_near_full_device():
    full = _MemDevice("cpu:0", resident=900, limit=1000)
    empty = _MemDevice("cpu:1", resident=0, limit=1000)
    s = Scheduler([full, empty], policy="least_loaded", steal=False)
    arg = _FakeBuf(empty, nbytes=500)  # foreign to cpu:0: 900 + 500 > limit
    assert all(s.select(args=[arg]).key == "cpu:1" for _ in range(3))
    # without the over-limit incoming bytes, both devices stay candidates
    s2 = Scheduler([full, empty], policy="least_loaded", steal=False)
    assert {s2.select().key for _ in range(4)} == {"cpu:0", "cpu:1"}


def test_memory_veto_everything_full_still_places():
    devs = [_MemDevice(f"cpu:{i}", resident=2000, limit=1000) for i in range(2)]
    s = Scheduler(devs, policy="least_loaded", steal=False)
    arg = _FakeBuf(_FakeDevice("cpu:9"), nbytes=64)
    assert s.select(args=[arg]).key in {"cpu:0", "cpu:1"}  # degraded, not dead


def test_spill_refetch_keeps_resident_bytes_honest(device):
    base_dev = registry.resident_bytes(device.key)
    base_host = registry.resident_bytes(HOST_KEY)
    data = np.arange(256, dtype=np.float32)
    buf = device.create_buffer_from(data).get()
    assert registry.resident_bytes(device.key) == base_dev + 1024

    assert buf.spill().get() is True
    assert registry.placement(buf.gid).device_key == HOST_KEY
    assert registry.resident_bytes(device.key) == base_dev
    assert registry.resident_bytes(HOST_KEY) == base_host + 1024
    assert registry.spilled_bytes() >= 1024
    assert buf.spill().get() is False  # idempotent: nothing left to evict

    # transparent refetch: bit-equal data, record moves back to the device
    np.testing.assert_array_equal(buf.enqueue_read_sync(), data)
    assert registry.placement(buf.gid).device_key == device.key
    assert registry.resident_bytes(device.key) == base_dev + 1024
    assert registry.resident_bytes(HOST_KEY) == base_host

    # a full overwrite makes the host copy dead: discarded, not refetched
    buf.spill().get()
    buf.enqueue_write(0, data * 3.0).get()
    assert registry.placement(buf.gid).device_key == device.key
    assert registry.resident_bytes(HOST_KEY) == base_host
    np.testing.assert_array_equal(buf.enqueue_read_sync(), data * 3.0)
    buf.free().get()
    assert registry.resident_bytes(device.key) == base_dev


@settings(max_examples=5, deadline=None)
@given(n=st.integers(1, 4096), seed=st.integers(0, 2**31 - 1))
def test_spill_roundtrip_is_bit_exact(n, seed):
    device = get_all_devices(1, 0).get()[0]
    data = np.random.default_rng(seed).normal(size=(n,)).astype(np.float32)
    buf = device.create_buffer_from(data).get()
    try:
        assert buf.spill().get() is True
        out = np.asarray(buf.enqueue_read_sync())
        assert out.tobytes() == data.tobytes()
        assert registry.placement(buf.gid).device_key == device.key
    finally:
        buf.free().get()


def test_rehome_while_spilled_keeps_host_record(device):
    buf = device.create_buffer_from(np.ones(64, np.float32)).get()
    buf.spill().get()
    buf._rehome(device)  # re-homing a spilled handle must not lie about bytes
    assert registry.placement(buf.gid).device_key == HOST_KEY
    np.testing.assert_array_equal(buf.enqueue_read_sync(), np.ones(64))
    assert registry.placement(buf.gid).device_key == device.key
    buf.free().get()


def test_spill_lru_evicts_oldest_first(device):
    lru = device.create_buffer_from(np.zeros(256, np.float32)).get()
    mru = device.create_buffer_from(np.zeros(256, np.float32)).get()
    lru._last_use = 0.0  # force a deterministic LRU order
    mru.enqueue_read_sync()
    s = Scheduler([device], policy="least_loaded", steal=False)
    futs = s.spill_lru(device, 1, keep=())
    wait_all(futs)
    assert registry.placement(lru.gid).device_key == HOST_KEY
    assert registry.placement(mru.gid).device_key == device.key
    wait_all([lru.free(), mru.free()])


def test_memory_pressure_triggers_lru_spill_on_placement(device):
    victim = device.create_buffer_from(np.zeros(256, np.float32)).get()
    victim._last_use = 0.0
    s = Scheduler([device], policy="least_loaded", steal=False, spill_bytes=1)
    s.select(args=[_FakeBuf(_FakeDevice("cpu:9"), nbytes=4096)])
    deadline = time.monotonic() + 10.0
    while (time.monotonic() < deadline
           and registry.placement(victim.gid).device_key != HOST_KEY):
        time.sleep(0.01)
    assert registry.placement(victim.gid).device_key == HOST_KEY
    victim.free().get()


def test_spill_lru_never_evicts_kept_gids(device):
    keeper = device.create_buffer_from(np.zeros(256, np.float32)).get()
    keeper._last_use = 0.0  # oldest, but protected
    s = Scheduler([device], policy="least_loaded", steal=False)
    wait_all(s.spill_lru(device, 1, keep={keeper.gid}))
    assert registry.placement(keeper.gid).device_key == device.key
    keeper.free().get()


# ---------------------------------------------------------------------------
# steal pool (DESIGN.md §14): tail-stealing invariants on real WorkQueues
# ---------------------------------------------------------------------------


class _QueueDevice:
    """Steal-pool fake: a real WorkQueue behind a device-shaped shell, so
    the pump/steal protocol runs against real FIFO lanes while the launch
    itself stays synthetic."""

    def __init__(self, key):
        self.key = key
        self.ops_queue = get_runtime().queue(f"steal-{key}")


class _RecordingProgram:
    """``for_device``/``run`` shaped like Program: run executes on the
    bound device's queue (unit concurrency per lane, like a real launch)
    and logs ``(task_id, device_key)`` — task id is the LAST argument."""

    def __init__(self, log, delays=None):
        self.log = log
        self.delays = dict(delays or {})

    def for_device(self, dev):
        return _BoundRecording(self, dev)


class _BoundRecording:
    def __init__(self, root, dev):
        self._root, self._dev = root, dev

    def run(self, args, name, grid=None, block=None, out=None, sync="ready"):
        root, dev = self._root, self._dev

        def _work():
            d = root.delays.get(dev.key, 0.0)
            if d:
                time.sleep(d)
            root.log.append((args[-1], dev.key))
            return args[-1] * 2

        return dev.ops_queue.submit(_work)


@settings(max_examples=5, deadline=None)
@given(n=st.integers(6, 18), delay_ms=st.integers(5, 25))
def test_steal_tail_preserves_victim_head_fifo(n, delay_ms):
    # All tasks placed on a slow victim; idle siblings steal from the
    # TAIL.  Invariants: every task runs exactly once, every result is
    # right, and whatever the victim itself ran is in submission order.
    log = []
    devs = [_QueueDevice(f"sp{i}") for i in range(3)]
    prog = _RecordingProgram(log, delays={"sp0": delay_ms / 1000.0})
    sched = Scheduler(devs, policy="static", steal=True)
    futs = [sched.submit(prog, [i], "k") for i in range(n)]
    assert [f.get() for f in futs] == [2 * i for i in range(n)]
    assert len(log) == n and {tid for tid, _ in log} == set(range(n))
    ran_on_victim = [tid for tid, key in log if key == "sp0"]
    assert ran_on_victim == sorted(ran_on_victim), log
    assert sched.steal_stats()["steals"] >= 1
    assert sched.steal_stats()["pending"] == {}


def test_steal_byte_gate_blocks_expensive_migrations():
    # Tasks over REPRO_STEAL_MAX_BYTES stay home even when siblings idle.
    log = []
    devs = [_QueueDevice(f"bg{i}") for i in range(3)]
    heavy = _FakeBuf(devs[0], nbytes=1 << 20)
    prog = _RecordingProgram(log, delays={"bg0": 0.01})
    sched = Scheduler(devs, policy="static", steal=True, steal_max_bytes=1024)
    futs = [sched.submit(prog, [heavy, i], "k") for i in range(6)]
    assert [f.get() for f in futs] == [2 * i for i in range(6)]
    assert {key for _, key in log} == {"bg0"}, log  # nothing migrated
    assert sched.steal_stats()["steals"] == 0


def test_steal_disabled_uses_direct_launch_path(device, monkeypatch):
    s = Scheduler([device, device], steal=False)
    assert s.steals is False
    monkeypatch.setenv("REPRO_STEAL", "off")
    assert Scheduler([device, device]).steals is False  # env knob
    monkeypatch.setenv("REPRO_STEAL", "auto")
    assert Scheduler([device]).steals is False  # 1 device: nothing to balance
    assert Scheduler([device, device]).steals is True


def test_run_on_any_routes_through_steal_pool(device):
    prog = device.create_program({"double": lambda x: x * 2.0}, name="steal-route").get()
    other = _QueueDevice("sr1")
    sched = Scheduler([device, other], policy="static", steal=True)
    # static pins to the real device; the pool path must return the same
    # value the direct path would
    fut = prog.run_on_any([np.arange(4, dtype=np.float32)], "double", scheduler=sched)
    np.testing.assert_allclose(np.asarray(fut.get()), [0.0, 2.0, 4.0, 6.0])
    assert sched.stats()[device.key] == 1


# ---------------------------------------------------------------------------
# integration: 8 forced host devices (re-exec pattern, see
# test_multidevice_train.py) — spread, least_loaded vs static wall-clock,
# affinity placement, multi-device graph fan-out replay
# ---------------------------------------------------------------------------

_CHILD = textwrap.dedent(
    """
    import os, time
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                               "--xla_cpu_multi_thread_eigen=false "
                               + os.environ.get("XLA_FLAGS", ""))
    import numpy as np
    import jax
    from repro.core import Scheduler, TaskGraph, capture, get_all_devices, registry, wait_all
    from repro.kernels.partition_map.ref import partition_map_ref

    devices = get_all_devices(1, 0).get()
    assert len(devices) == 8, devices

    # fig6 partition workload, compute-dense variant (iterated map)
    def k(x):
        def body(i, v):
            return partition_map_ref(v) * 0.5 + v * 0.5
        return jax.lax.fori_loop(0, 32, body, x)

    prog = devices[0].create_program({"k": k}, "partition").get()
    parts = [np.random.default_rng(i).normal(size=(1 << 17,)).astype(np.float32)
             for i in range(8)]

    def pipeline(sched):
        futs = [prog.run_on_any([p], "k", scheduler=sched) for p in parts]
        wait_all(futs)
        return [f.get() for f in futs]

    # placement spread: least_loaded fills the whole 8-device fleet
    sched_ll = Scheduler(devices, policy="least_loaded")
    pipeline(sched_ll)
    spread = sched_ll.stats()
    print("SPREAD", len(spread))
    assert len(spread) == 8, spread

    # wall-clock: least_loaded must beat static single-device placement.
    # Timed on a 2-device fleet (a 2-core CI box cannot feed 8 concurrent
    # queues), interleaved min-of-reps, retried on load spikes — shared
    # runners must not turn a structural 2x advantage into a flaky red.
    # Stealing disabled: this measures the PLACEMENT signal alone — with
    # the steal pool on, idle dev1 would drain static's backlog and erase
    # the structural difference under test.
    fleet2 = devices[:2]
    def time_policy(policy):
        sched = Scheduler(fleet2, policy=policy, steal=False)
        t0 = time.perf_counter()
        pipeline(sched)
        return time.perf_counter() - t0
    time_policy("static"); time_policy("least_loaded")  # warm both routes
    best = float("inf")
    for attempt in range(4):
        t_s = t_l = float("inf")
        for _ in range(3):  # interleave so load spikes hit both policies
            t_s = min(t_s, time_policy("static"))
            t_l = min(t_l, time_policy("least_loaded"))
        best = min(best, t_l / t_s)
        print("TIMES", f"{t_s:.4f}", f"{t_l:.4f}", f"best_ratio={best:.3f}")
        if best < 0.9:
            break
    assert best < 1.0, best  # least_loaded beat static in at least one round

    # affinity keeps work where the bytes are (no percolation)
    target = devices[5]
    big = target.create_buffer_from(np.ones(1 << 16, np.float32)).get()
    aff = Scheduler(devices, policy="affinity")
    out = target.create_buffer(1 << 16, np.float32).get()
    prog.run_on_any([big], "k", out=[out], scheduler=aff).get()
    assert aff.stats() == {target.key: 1}, aff.stats()
    assert registry.placement(out.gid).device_key == target.key
    print("AFFINITY ok")

    # captured multi-device graph (recorded through run_on_any) replays
    # through ONE future: per-device fused segments + explicit transfer
    d0, d1 = devices[0], devices[1]
    p2 = d0.create_program({"inc": lambda x: x + 1.0, "scale": lambda x: x * 3.0}, "g").get()
    b_in = d0.create_buffer(16, np.float32).get()
    t_mid = d0.create_buffer(16, np.float32).get()
    t_out = d1.create_buffer(16, np.float32).get()
    rr = Scheduler([d0, d1], policy="round_robin")
    with capture("xdev") as g:
        w = b_in.enqueue_write(0, np.ones(16, np.float32))
        p2.run_on_any([b_in], "inc", out=[t_mid], scheduler=rr)     # -> cpu:0
        p2.run_on_any([t_mid], "scale", out=[t_out], scheduler=rr)  # -> cpu:1
        r = t_out.enqueue_read()
    exe = g.instantiate()
    assert exe._fanout and len(exe._segments) == 2, repr(exe)
    assert len(exe._transfers) >= 1, repr(exe)
    fut = exe.replay()          # ONE future for the whole graph
    res = fut.get()
    np.testing.assert_allclose(res[r], np.full(16, 6.0))
    res2 = exe.replay(feeds={w: np.full(16, 2.0, np.float32)}).get()
    np.testing.assert_allclose(res2[r], np.full(16, 9.0))
    assert registry.placement(t_out.gid).device_key == d1.key
    print("GRAPH", repr(exe))

    # fan-out donation safety: a sym consumed by two segments that may run
    # CONCURRENTLY (both depend only on the producer) must never be donated
    a0 = d0.create_buffer(8, np.float32).get()
    m1 = d0.create_buffer(8, np.float32).get()
    o1 = d1.create_buffer(8, np.float32).get()
    o2 = d0.create_buffer(8, np.float32).get()
    ga = TaskGraph("donate-race")
    ga.write(a0, np.ones(8, np.float32))
    ga.run(p2.for_device(d0), [a0], "inc", out=[m1])    # seg 0 (dev0) -> m1
    ga.run(p2.for_device(d1), [m1], "scale", out=[o1])  # seg 1 (dev1) reads m1
    ga.run(p2.for_device(d0), [m1], "inc", out=[o2])    # seg 2 (dev0) reads m1 too
    r1, r2 = ga.read(o1), ga.read(o2)
    m1_sym = ga._cur[id(m1)]
    exe_a = ga.instantiate()
    assert exe_a._fanout and len(exe_a._segments) == 3, repr(exe_a)
    assert m1_sym not in exe_a._donated_syms  # concurrent readers: no donation
    res_a = exe_a.replay().get()
    np.testing.assert_allclose(res_a[r1], np.full(8, 6.0))  # (1+1)*3
    np.testing.assert_allclose(res_a[r2], np.full(8, 3.0))  # (1+1)+1
    print("OK")
    """
)


@pytest.mark.slow
def test_scheduler_integration_8_host_devices():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src:" + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-c", _CHILD],
        capture_output=True,
        text=True,
        env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=900,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    out = proc.stdout
    assert "OK" in out and "AFFINITY ok" in out, out
    # the wall-clock comparison (least_loaded beats static) is asserted in
    # the child; surface its measurement here for the test log
    assert any(l.startswith("TIMES") for l in out.splitlines()), out


# ---------------------------------------------------------------------------
# integration: one throttled lane out of 8 — stealing must recover the lost
# wall-clock (ISSUE acceptance: >= 1.5x vs stealing off, results bit-equal)
# ---------------------------------------------------------------------------

_STEAL_CHILD = textwrap.dedent(
    """
    import os, time
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                               "--xla_cpu_multi_thread_eigen=false "
                               + os.environ.get("XLA_FLAGS", ""))
    import numpy as np
    from repro.core import Scheduler, get_all_devices

    devices = get_all_devices(1, 0).get()
    assert len(devices) == 8, devices

    class Throttled:
        # per-task brake on one device's default lane: each submitted item
        # sleeps first, so the lane is structurally slow task-by-task (a
        # single long block would hold the stolen head hostage instead)
        def __init__(self, q, delay):
            self._q, self._delay = q, delay
        def submit(self, fn, *a, **k):
            d = self._delay
            def slow(*aa, **kk):
                time.sleep(d)
                return fn(*aa, **kk)
            return self._q.submit(slow, *a, **k)
        def __getattr__(self, name):
            return getattr(self._q, name)

    prog = devices[0].create_program({"k": lambda x: x * 2.0 + 1.0}, "steal").get()
    parts = [np.random.default_rng(i).normal(size=(4096,)).astype(np.float32)
             for i in range(32)]

    def run(steal):
        sched = Scheduler(devices, policy="round_robin", steal=steal)
        t0 = time.perf_counter()
        futs = [prog.run_on_any([p], "k", scheduler=sched) for p in parts]
        res = [np.asarray(f.get()) for f in futs]
        return time.perf_counter() - t0, res, sched

    run(True); run(False)  # warm every sibling's compile cache first
    devices[0].ops_queue = Throttled(devices[0].ops_queue, 0.30)

    # round_robin gives the throttled lane 4 of 32 tasks: ~1.2s serialized
    # with stealing off, ~one brake tick once idle siblings drain the rest.
    best, sched_on = 0.0, None
    for attempt in range(4):
        t_off, res_off, _ = run(False)
        t_on, res_on, sched_on = run(True)
        for a, b in zip(res_off, res_on):
            assert a.tobytes() == b.tobytes()  # bit-equal, stolen or not
        best = max(best, t_off / max(t_on, 1e-9))
        print("THROTTLE", f"off={t_off:.3f}", f"on={t_on:.3f}",
              f"best_ratio={best:.2f}", "steals=", sched_on.steal_stats()["steals"])
        if best >= 1.5:
            break
    assert best >= 1.5, best
    assert sched_on.steal_stats()["steals"] > 0, sched_on.steal_stats()
    print("OK")
    """
)


@pytest.mark.slow
def test_steal_recovers_throttled_lane_8_host_devices():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src:" + env.get("PYTHONPATH", "")
    env.pop("REPRO_STEAL", None)  # the child toggles stealing explicitly
    proc = subprocess.run(
        [sys.executable, "-c", _STEAL_CHILD],
        capture_output=True,
        text=True,
        env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=900,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "OK" in proc.stdout, proc.stdout
    assert any(l.startswith("THROTTLE") for l in proc.stdout.splitlines()), proc.stdout
