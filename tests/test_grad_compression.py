"""int8 stochastic-rounding gradient compression: unbiasedness + bounds."""
import jax
import jax.numpy as jnp
import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # minimal container: seeded fallback sweeps
    from _hypothesis_compat import given, settings, strategies as st

from repro.training.grad_compression import (
    compress,
    compress_tree,
    decompress,
    decompress_tree,
)


def test_roundtrip_error_bounded_by_scale():
    g = jax.random.normal(jax.random.key(0), (1024,)) * 3.0
    q, s = compress(g, jax.random.key(1))
    err = np.abs(np.asarray(decompress(q, s) - g))
    assert err.max() <= float(s) + 1e-6  # one quantization step


def test_stochastic_rounding_is_unbiased():
    g = jnp.full((2000,), 0.3337)  # deliberately between grid points
    outs = []
    for i in range(50):
        q, s = compress(g, jax.random.key(i))
        outs.append(np.asarray(decompress(q, s)))
    mean = np.mean(outs)
    assert abs(mean - 0.3337) < 2e-4, mean


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**16), scale=st.floats(1e-3, 1e3))
def test_compression_property(seed, scale):
    g = jax.random.normal(jax.random.key(seed), (256,)) * scale
    q, s = compress(g, jax.random.key(seed + 1))
    back = np.asarray(decompress(q, s))
    assert np.all(np.abs(back - np.asarray(g)) <= float(s) * 1.0001)
    assert np.asarray(q).dtype == np.int8


def test_tree_roundtrip():
    grads = {"a": jnp.ones((8, 8)), "b": {"c": jnp.linspace(-1, 1, 64)}}
    qt, st_ = compress_tree(grads, jax.random.key(7))
    back = decompress_tree(qt, st_)
    for o, r in zip(jax.tree.leaves(grads), jax.tree.leaves(back)):
        np.testing.assert_allclose(np.asarray(o), np.asarray(r), atol=0.02)


def test_zero_gradient_safe():
    g = jnp.zeros((16,))
    q, s = compress(g, jax.random.key(0))
    np.testing.assert_array_equal(np.asarray(decompress(q, s)), 0.0)
