"""Paged KV cache + prefill/decode disaggregation (DESIGN.md §15): the
paged-attention kernel against the flash oracle across ragged and
page-straddling lengths, page-pool alloc/free/defrag invariants (no page
leaked, no page double-owned), honest AGAS accounting through
``Registry.update_nbytes``, LRU sequence spill, coalesced migration, the
``Scheduler.charge`` direct-route fix, per-kind ``LanePolicy`` lanes, and
the ``PagedServeEngine`` end to end."""
import os
import subprocess
import sys
import textwrap
import threading
import time

import numpy as np
import pytest

import jax
import jax.numpy as jnp

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # minimal container: deterministic fallback sweep
    from tests._hypothesis_compat import given, settings, strategies as st

from repro.core import Scheduler, get_all_devices
from repro.core import agas
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.paged_attention.kernel import paged_attention_bhd
from repro.kernels.paged_attention.ops import paged_attention, paged_attention_layers
from repro.kernels.paged_attention.ref import paged_attention_ref
from repro.serving import LanePolicy, RequestEngine
from repro.serving.paged import OutOfPages, PagedKVCache, PagedServeEngine, PageSpec


@pytest.fixture(scope="module")
def device():
    return get_all_devices(1, 0).get()[0]


# ---------------------------------------------------------------------------
# kernel: paged attention vs the gather oracle vs the flash reference
# ---------------------------------------------------------------------------


def _random_paged(rng, B, H, K, D, P, M, lengths):
    """Pool + tables covering ``lengths``; unreferenced pages (and page 0)
    hold huge-but-finite garbage so a masking bug shows up as a numeric
    blowup, not a rounding error."""
    N = 1 + sum(-(-l // P) for l in lengths) + 2
    k_pages = np.full((N, P, K, D), 1e6, np.float32)
    v_pages = np.full((N, P, K, D), -1e6, np.float32)
    tbl = np.zeros((B, M), np.int32)
    nxt = 1
    for b, l in enumerate(lengths):
        for j in range(-(-l // P)):
            tbl[b, j] = nxt
            valid = min(P, l - j * P)
            k_pages[nxt, :valid] = rng.normal(size=(valid, K, D))
            v_pages[nxt, :valid] = rng.normal(size=(valid, K, D))
            nxt += 1
    q = rng.normal(size=(B, H, D)).astype(np.float32)
    return q, k_pages, v_pages, tbl, np.asarray(lengths, np.int32)


def _contiguous(k_pages, v_pages, tbl, P, b, l):
    toks = [(tbl[b, t // P], t % P) for t in range(l)]
    k = np.stack([k_pages[p, o] for p, o in toks])[None]
    v = np.stack([v_pages[p, o] for p, o in toks])[None]
    return k, v


def test_paged_ref_matches_flash_on_ragged_lengths():
    rng = np.random.default_rng(0)
    B, H, K, D, P, M = 4, 4, 2, 8, 4, 6
    # partial page, exact boundary, straddling, full table
    lengths = [3, 4, 7, 24]
    q, kp, vp, tbl, lens = _random_paged(rng, B, H, K, D, P, M, lengths)
    ref = np.asarray(paged_attention_ref(q, kp, vp, tbl, lens))
    assert np.isfinite(ref).all()
    for b, l in enumerate(lengths):
        kc, vc = _contiguous(kp, vp, tbl, P, b, l)
        want = np.asarray(flash_attention_ref(q[b : b + 1, None], kc, vc, causal=False))
        np.testing.assert_allclose(ref[b], want[0, 0], rtol=1e-5, atol=1e-5)


def test_paged_kernel_matches_ref_in_interpret_mode():
    rng = np.random.default_rng(1)
    B, H, K, D, P, M = 3, 4, 2, 8, 4, 5
    lengths = [1, 6, 20]  # sub-page, page-straddling, full table
    q, kp, vp, tbl, lens = _random_paged(rng, B, H, K, D, P, M, lengths)
    ref = np.asarray(paged_attention_ref(q, kp, vp, tbl, lens))
    got = np.asarray(paged_attention_bhd(q, kp, vp, tbl, lens, interpret=True))
    np.testing.assert_allclose(got, ref, rtol=1e-5, atol=1e-5)


def test_paged_op_dispatches_and_matches():
    rng = np.random.default_rng(2)
    q, kp, vp, tbl, lens = _random_paged(rng, 2, 2, 1, 4, 4, 3, [5, 9])
    auto = np.asarray(paged_attention(q, kp, vp, tbl, lens))
    forced = np.asarray(paged_attention(q, kp, vp, tbl, lens, impl="kernel"))
    np.testing.assert_allclose(auto, forced, rtol=1e-5, atol=1e-5)


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), page=st.sampled_from([2, 4, 8]))
def test_paged_kernel_property_ragged(seed, page):
    rng = np.random.default_rng(seed)
    B = int(rng.integers(1, 4))
    K = int(rng.integers(1, 3))
    H = K * int(rng.integers(1, 3))
    D = 4
    M = int(rng.integers(1, 4))
    lengths = [int(rng.integers(1, M * page + 1)) for _ in range(B)]
    q, kp, vp, tbl, lens = _random_paged(rng, B, H, K, D, page, M, lengths)
    ref = np.asarray(paged_attention_ref(q, kp, vp, tbl, lens))
    got = np.asarray(paged_attention_bhd(q, kp, vp, tbl, lens, interpret=True))
    np.testing.assert_allclose(got, ref, rtol=1e-4, atol=1e-4)


def _random_layered(rng, Lc, B, H, K, D, P, M, lengths):
    """Folded multi-layer slab: one table, per-layer page contents
    (``_random_paged`` allocates pages deterministically from ``lengths``,
    so each layer's table comes out identical — the zoo invariant)."""
    qs, ks, vs = [], [], []
    tbl = lens = None
    for _ in range(Lc):
        q, kp, vp, tbl, lens = _random_paged(rng, B, H, K, D, P, M, lengths)
        qs.append(q), ks.append(kp), vs.append(vp)
    return np.stack(qs), np.stack(ks), np.stack(vs), tbl, lens


def test_paged_layers_matches_per_layer_ref():
    rng = np.random.default_rng(3)
    Lc, B, H, K, D, P, M = 3, 3, 4, 2, 8, 4, 5
    q, kp, vp, tbl, lens = _random_layered(rng, Lc, B, H, K, D, P, M, [3, 8, 17])
    got = np.asarray(paged_attention_layers(q, kp, vp, tbl, lens))
    assert got.shape == (Lc, B, H, D)
    for l in range(Lc):  # the fold is exactly L per-layer calls, bitwise
        want = np.asarray(paged_attention_ref(q[l], kp[l], vp[l], tbl, lens))
        np.testing.assert_array_equal(got[l], want)


def test_paged_layers_rejects_mismatched_layer_dims():
    rng = np.random.default_rng(4)
    q, kp, vp, tbl, lens = _random_layered(rng, 2, 2, 2, 1, 4, 4, 3, [5, 9])
    with pytest.raises(ValueError, match="layer dims"):
        paged_attention_layers(q[:1], kp, vp, tbl, lens)


@pytest.mark.parametrize("arch", ["olmo-1b", "qwen2-moe-a2.7b", "hymba-1.5b", "whisper-tiny"])
def test_paged_kernel_zoo_geometries(arch):
    """The Pallas kernel handles every zoo ``paged_spec`` geometry —
    multi-layer folds and GQA head ratios — matching the gather ref."""
    from repro.configs import get_config, smoke
    from repro.models.model import paged_surface

    cfg = smoke(get_config(arch))
    spec = paged_surface(cfg)[0](cfg)
    H, K, D = cfg.num_heads, spec.kv_heads, spec.head_dim
    assert H % K == 0, f"{arch}: GQA ratio must be integral"
    rng = np.random.default_rng(6)
    P, M = 4, 4
    q, kp, vp, tbl, lens = _random_layered(
        rng, spec.layers, 2, H, K, D, P, M, [3, 10])
    ref = np.asarray(paged_attention_layers(q, kp, vp, tbl, lens, impl="ref"))
    got = np.asarray(paged_attention_layers(q, kp, vp, tbl, lens, impl="kernel"))
    np.testing.assert_allclose(got, ref, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# page pool / sequence lifecycle invariants
# ---------------------------------------------------------------------------


def _spec(P=2):
    return PageSpec(layers=1, page_size=P, kv_heads=1, head_dim=2)


def _fill(spec, seq_id, tokens):
    """Deterministic page-in payload: token t of sequence s holds
    ``s * 1000 + t`` — readable back for content checks."""
    base = np.arange(tokens, dtype=np.float32) + seq_id * 1000.0
    k = np.broadcast_to(
        base[None, :, None, None],
        (spec.layers, tokens, spec.kv_heads, spec.head_dim),
    ).copy()
    return k, -k


def _check_invariants(kv):
    """No page leaked, no page double-owned, page 0 never owned."""
    for key, pool in kv.pools.items():
        owned = []
        for s in kv._seqs.values():
            if s.pool is pool:
                owned.extend(s.pages)
        assert 0 not in owned, f"{key}: reserved page 0 owned"
        assert len(owned) == len(set(owned)), f"{key}: page double-owned"
        free = set(pool._free)
        assert not (free & set(owned)), f"{key}: page both free and owned"
        assert len(free) + len(owned) == pool.num_pages - 1, f"{key}: page leaked"


def _seq_tokens(kv, seq):
    """Token values currently paged in for ``seq`` (first ``length``)."""
    k, _v = seq.pool.read_pages(seq.pages)
    flat = np.moveaxis(k, 0, 1).reshape(kv.spec.layers, -1, 1, kv.spec.head_dim)
    return flat[0, : seq.length, 0, 0]


@settings(max_examples=4, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_alloc_free_defrag_invariants(seed):
    # No fixture params under @given: the hypothesis-compat wrapper hides
    # the signature from pytest's fixture resolution.
    device = get_all_devices(1, 0).get()[0]
    rng = np.random.default_rng(seed)
    spec = _spec(P=2)
    kv = PagedKVCache(spec, devices=[device], pool_pages=24)
    live = {}
    next_id = 0
    for _ in range(40):
        op = rng.choice(["new", "free", "defrag", "spill", "resident"])
        if op == "new":
            tokens = int(rng.integers(1, 7))
            if kv.pools[device.key].num_free < spec.pages_for(tokens):
                continue
            seq = kv.new_seq(device)
            k, v = _fill(spec, next_id, tokens)
            kv.append(seq, k, v)
            live[next_id] = (seq, tokens)
            next_id += 1
        elif op == "free" and live:
            sid = int(rng.choice(list(live)))
            seq, _ = live.pop(sid)
            kv.free_seq(seq)
        elif op == "defrag":
            kv.defrag(device)
        elif op == "spill" and live:
            sid = int(rng.choice(list(live)))
            live[sid][0].spill().get()
        elif op == "resident" and live:
            sid = int(rng.choice(list(live)))
            try:
                live[sid][0].ensure_resident()
            except OutOfPages:
                pass
        _check_invariants(kv)
    # Contents survived every alloc/free/defrag/spill interleaving.
    for sid, (seq, tokens) in live.items():
        seq.ensure_resident()
        got = _seq_tokens(kv, seq)
        np.testing.assert_array_equal(got, np.arange(tokens) + sid * 1000.0)
    for seq, _ in live.values():
        kv.free_seq(seq)
    assert kv.pools[device.key].used_pages == 0


def test_page_size_env_default(monkeypatch):
    monkeypatch.setenv("REPRO_PAGE_SIZE", "8")
    assert PageSpec(1, 0, 2, 4).page_size == 8
    monkeypatch.delenv("REPRO_PAGE_SIZE")
    assert PageSpec(1, 0, 2, 4).page_size == 16
    assert PageSpec(1, 4, 2, 4).page_size == 4  # explicit wins


def test_pool_overflow_and_double_free(device):
    spec = _spec()
    kv = PagedKVCache(spec, devices=[device], pool_pages=4)  # 3 allocatable
    pool = kv.pools[device.key]
    pages = pool.alloc(3)
    with pytest.raises(OutOfPages):
        pool.alloc(1)
    pool.free(pages)
    with pytest.raises(ValueError, match="double free"):
        pool.free([pages[0]])
    with pytest.raises(ValueError, match="not an allocatable"):
        pool.free([0])


def test_defrag_compacts_and_preserves_contents(device):
    spec = _spec(P=2)
    kv = PagedKVCache(spec, devices=[device], pool_pages=16)
    seqs = []
    for sid in range(4):
        seq = kv.new_seq(device)
        kv.append(seq, *_fill(spec, sid, 4))
        seqs.append(seq)
    kv.free_seq(seqs[0])
    kv.free_seq(seqs[2])  # holes at the front and middle
    moved = kv.defrag(device)
    assert moved > 0
    live = sorted(p for s in (seqs[1], seqs[3]) for p in s.pages)
    assert live == list(range(1, len(live) + 1))  # compacted to the low slots
    for sid, seq in ((1, seqs[1]), (3, seqs[3])):
        np.testing.assert_array_equal(_seq_tokens(kv, seq), np.arange(4) + sid * 1000.0)
    assert kv.defrag(device) == 0  # idempotent once compact
    # Free explicitly: SeqPages <-> kv._seqs is a reference cycle, so a
    # leaked live sequence's AGAS registration survives until the cyclic
    # GC runs — nondeterministically mid-way through a LATER test's
    # resident-bytes accounting.
    kv.free_seq(seqs[1])
    kv.free_seq(seqs[3])


# ---------------------------------------------------------------------------
# honest accounting + scheduler integration
# ---------------------------------------------------------------------------


def test_update_nbytes_moves_resident_accounting(device):
    gid = agas.registry.register(
        object(), agas.Placement(device.key), kind="buffer", nbytes=100)
    base = agas.registry.resident_bytes(device.key)
    agas.registry.update_nbytes(gid, 350)
    assert agas.registry.resident_bytes(device.key) == base + 250
    agas.registry.update_nbytes(gid, 0)
    assert agas.registry.resident_bytes(device.key) == base - 100
    agas.registry.unregister(gid)
    with pytest.raises(KeyError):
        agas.registry.update_nbytes(gid, 1)


def test_seq_pages_account_spill_and_refetch(device):
    spec = _spec(P=2)
    kv = PagedKVCache(spec, devices=[device], pool_pages=16)
    before = agas.registry.resident_bytes(device.key)
    seq = kv.new_seq(device)
    kv.append(seq, *_fill(spec, 7, 5))  # 3 pages
    assert seq.nbytes == 3 * spec.page_bytes
    assert agas.registry.resident_bytes(device.key) == before + seq.nbytes
    free_before = kv.pools[device.key].num_free

    assert seq.spill().get() is True
    # Pages returned to the pool, bytes moved to the host pool.
    assert kv.pools[device.key].num_free == free_before + 3
    assert agas.registry.resident_bytes(device.key) == before
    assert agas.registry.placement(seq.gid).device_key == agas.HOST_KEY

    seq.ensure_resident()
    assert agas.registry.placement(seq.gid).device_key == device.key
    np.testing.assert_array_equal(_seq_tokens(kv, seq), np.arange(5) + 7000.0)
    kv.free_seq(seq)
    assert agas.registry.resident_bytes(device.key) == before


def test_spill_lru_evicts_cold_sequence_first(device):
    spec = _spec(P=2)
    kv = PagedKVCache(spec, devices=[device], pool_pages=16)
    cold = kv.new_seq(device)
    hot = kv.new_seq(device)
    kv.append(cold, *_fill(spec, 0, 4))
    kv.append(hot, *_fill(spec, 1, 4))
    cold._last_use = 0.0  # oldest spillable resident on this device
    sched = Scheduler([device], policy="least_loaded")
    for f in sched.spill_lru(device, need_bytes=1):
        f.get()
    assert cold.spilled and not hot.spilled
    kv.free_seq(cold)
    kv.free_seq(hot)


def test_scheduler_charge_biases_least_loaded(device):
    sched = Scheduler([device], policy="least_loaded")
    assert not sched._recent_extras()
    sched.charge(device, 16)
    extras = sched._recent_extras()
    assert extras.get(device.key, 0.0) > 10.0  # decays from 16
    sched.charge(device, 0)  # no-op
    assert sched._recent_extras()[device.key] <= extras[device.key] + 1e-6


# ---------------------------------------------------------------------------
# engine lanes (RequestEngine LanePolicy) + dtype round-trip
# ---------------------------------------------------------------------------


def test_lane_token_budget_caps_prefill_batches(device):
    seen = []

    def prefill(batch):  # rows (b, 16): tokens_per_row = 16
        seen.append(batch.shape[0])
        return batch * 1.0

    eng = RequestEngine(
        {"prefill": prefill},
        max_batch=8,
        max_delay_s=0.05,
        scheduler=Scheduler([device]),
        graph=False,
        lanes={"prefill": LanePolicy(token_budget=32)},  # 32 // 16 = 2 rows
        name="t-lanes",
    )
    try:
        futs = [eng.submit(np.ones((1, 16), np.float32), kind="prefill") for _ in range(6)]
        for f in futs:
            f.get(timeout=60)
    finally:
        eng.close()
    assert max(seen) <= 2  # token budget bound, not max_batch=8
    with pytest.raises(KeyError, match="unknown kind"):
        RequestEngine({"x": prefill}, lanes={"nope": LanePolicy()})


def test_lane_deadline_overrides_engine_default(device):
    eng = RequestEngine(
        {"decode": lambda b: b + 1.0},
        max_batch=8,
        max_delay_s=0.25,  # engine-wide: slow
        scheduler=Scheduler([device]),
        graph=False,
        lanes={"decode": LanePolicy(max_delay_s=0.002)},  # lane: tight
        name="t-deadline",
    )
    try:
        t0 = time.monotonic()
        eng.submit(np.ones((1, 4), np.float32), kind="decode").get(timeout=60)
        assert time.monotonic() - t0 < 0.2  # dispatched at the lane deadline
    finally:
        eng.close()


@settings(max_examples=4, deadline=None)
@given(dt=st.sampled_from(["bfloat16", "float16", "float32"]), rows=st.integers(1, 3))
def test_engine_round_trips_sub_fp32_dtypes(dt, rows):
    device = get_all_devices(1, 0).get()[0]
    dtype = jnp.dtype(dt)

    def step(batch):
        return {"cache": batch["cache"] * 2, "next": batch["tokens"]}

    eng = RequestEngine(
        {"decode": step}, max_batch=4, scheduler=Scheduler([device]),
        graph=False, name="t-dtype",
    )
    try:
        cache = jnp.full((rows, 3, 2), 1.5, dtype)
        out = eng.submit(
            {"cache": cache, "tokens": np.ones((rows, 1), np.int32), "pos": np.int32(0)},
            kind="decode",
        ).get(timeout=60)
    finally:
        eng.close()
    assert out["cache"].dtype == np.dtype(dtype)
    assert out["cache"].shape == (rows, 3, 2)
    np.testing.assert_array_equal(
        np.asarray(out["cache"], np.float32), np.full((rows, 3, 2), 3.0, np.float32))


def test_padding_waste_reported(device):
    eng = RequestEngine(
        lambda b: b * 1.0, max_batch=8, max_delay_s=0.001,
        scheduler=Scheduler([device]), graph=False, name="t-waste",
    )
    try:
        for _ in range(3):  # 3 rows pad to the 4-bucket
            eng.submit(np.ones((3, 2), np.float32)).get(timeout=60)
        m = eng.metrics()
    finally:
        eng.close()
    assert m["padded_rows"] >= 1
    assert m["padding_waste"] == pytest.approx(m["padded_rows"] / m["rows"])


# ---------------------------------------------------------------------------
# PagedServeEngine end to end (single device; fleet spread in subprocess)
# ---------------------------------------------------------------------------


def _toy_paged_model(V=64, K=1, D=4, P=4):
    """Deterministic LM: next token = (last + 1) % V, but the KV pools and
    the paged-attention gather are genuinely exercised (a masking or
    table bug turns the output non-finite, failing the assert)."""
    emb = jnp.asarray(np.random.default_rng(0).normal(size=(V, K, D)).astype(np.float32))

    def prefill_fn(tokens):
        tokens = jnp.asarray(tokens)
        e = emb[tokens]  # (B, T, K, D)
        return e[:, None], e[:, None], (tokens[:, -1] + 1) % V

    @jax.jit
    def decode_fn(kp, vp, tokens, positions, tables, lengths):
        e = emb[tokens]
        b = tokens.shape[0]
        page = tables[jnp.arange(b), positions // P]
        slot = positions % P
        kp = kp.at[0, page, slot].set(e)
        vp = vp.at[0, page, slot].set(e)
        o = paged_attention_ref(e.reshape(b, K, D), kp[0], vp[0], tables, lengths + 1)
        guard = jnp.where(jnp.isfinite(o.sum(axis=(1, 2))), 0, 1 << 20).astype(jnp.int32)
        return kp, vp, (tokens + 1) % V + guard

    return prefill_fn, decode_fn


def test_paged_engine_serves_mixed_lengths_with_zero_padding(device):
    V, P = 64, 4
    prefill_fn, decode_fn = _toy_paged_model(V=V, P=P)
    kv = PagedKVCache(PageSpec(1, P, 1, 4), devices=[device], pool_pages=64)
    eng = PagedServeEngine(
        kv, prefill_fn, decode_fn, max_seq_len=32,
        scheduler=Scheduler([device]), name="t-paged",
    )
    rng = np.random.default_rng(3)
    try:
        futs = []
        for _ in range(9):
            plen = int(rng.integers(1, 10))  # mixed lengths share decode steps
            prompt = rng.integers(0, V - 16, size=plen).astype(np.int32)
            futs.append((prompt, eng.submit(prompt, max_new_tokens=5)))
        for prompt, f in futs:
            out = f.get(timeout=120)
            want = [(int(prompt[-1]) + 1 + j) % V for j in range(5)]
            assert list(out) == want
        m = eng.metrics()
    finally:
        eng.close()
    assert m["requests_completed"] == 9
    # Sequence dimension is never padded; rows pad only when a shrinking
    # tail reuses a warm (already-compiled) batch shape, capped at 2x.
    assert m["padding_waste"] <= 0.5
    assert m["decode_steps"] < 9 * 4 + 5  # mixed lengths actually shared steps
    assert kv.pools[device.key].used_pages == 0  # all pages back


def test_paged_engine_admission_guards(device):
    prefill_fn, decode_fn = _toy_paged_model()
    kv = PagedKVCache(PageSpec(1, 4, 1, 4), devices=[device], pool_pages=16)
    eng = PagedServeEngine(kv, prefill_fn, decode_fn, max_seq_len=16,
                           scheduler=Scheduler([device]), name="t-guard")
    try:
        with pytest.raises(ValueError, match="empty prompt"):
            eng.submit(np.zeros((0,), np.int32), 4)
        with pytest.raises(ValueError, match="max_seq_len"):
            eng.submit(np.ones((12,), np.int32), 8)
    finally:
        eng.close()


# ---------------------------------------------------------------------------
# concurrency: decode/spill/defrag races, partial prefill failure, policy zeros
# ---------------------------------------------------------------------------


def test_spill_serializes_against_held_seq_lock(device):
    """A decode step holds the sequence's lock through the kernel call;
    a racing spill must wait for it, never free the pages mid-step."""
    spec = _spec(P=2)
    kv = PagedKVCache(spec, devices=[device], pool_pages=16)
    seq = kv.new_seq(device)
    kv.append(seq, *_fill(spec, 1, 4))
    seq._lock.acquire()  # simulate an in-flight decode step pinning the seq
    try:
        f = seq.spill()
        time.sleep(0.05)
        assert not f.done()  # blocked on the seq lock
        assert seq.pages and not seq.spilled  # pages untouched mid-step
    finally:
        seq._lock.release()
    assert f.get(timeout=30) is True  # spill proceeds once the step ends
    assert seq.spilled
    kv.free_seq(seq)


def test_paged_engine_exact_tokens_under_spill_pressure(device):
    """Hammer the decode lanes with a concurrent spiller (the regime where
    an unpinned sequence's pages could be freed and re-owned mid-step):
    every generated token must still be exact."""
    V, P = 64, 4
    prefill_fn, decode_fn = _toy_paged_model(V=V, P=P)
    kv = PagedKVCache(PageSpec(1, P, 1, 4), devices=[device], pool_pages=64)
    eng = PagedServeEngine(kv, prefill_fn, decode_fn, max_seq_len=32,
                           scheduler=Scheduler([device]), name="t-spillrace")
    stop = threading.Event()

    def spiller():
        while not stop.is_set():
            with kv._seq_lock:
                seqs = list(kv._seqs.values())
            for s in seqs:
                try:
                    s.spill().get(timeout=30)
                except Exception:  # noqa: BLE001 - freed mid-flight is fine
                    pass
            time.sleep(0.001)

    th = threading.Thread(target=spiller, daemon=True)
    th.start()
    rng = np.random.default_rng(7)
    try:
        futs = []
        for _ in range(8):
            plen = int(rng.integers(1, 9))
            prompt = rng.integers(0, V - 16, size=plen).astype(np.int32)
            futs.append((prompt, eng.submit(prompt, max_new_tokens=6)))
        for prompt, f in futs:
            out = f.get(timeout=120)
            want = [(int(prompt[-1]) + 1 + j) % V for j in range(6)]
            assert list(out) == want
    finally:
        stop.set()
        th.join(timeout=30)
        eng.close()
    assert kv.pools[device.key].used_pages == 0


def test_defrag_no_deadlock_with_concurrent_spillers(device):
    """defrag takes seq locks before the pool lock (same order as spill);
    the old pool-then-seq order was an ABBA deadlock against _spill_now."""
    spec = _spec(P=2)
    kv = PagedKVCache(spec, devices=[device], pool_pages=32)
    seqs = []
    for sid in range(6):
        seq = kv.new_seq(device)
        kv.append(seq, *_fill(spec, sid, 4))
        seqs.append(seq)
    stop = threading.Event()

    def churner(offset):
        i = offset
        while not stop.is_set():
            s = seqs[i % len(seqs)]
            try:
                s._spill_now()          # seq._lock -> pool.lock
                s.ensure_resident()     # seq._lock -> pool.lock
            except OutOfPages:
                pass
            i += 1

    threads = [threading.Thread(target=churner, args=(i,), daemon=True)
               for i in range(2)]
    for t in threads:
        t.start()

    def defragger():
        for _ in range(50):
            kv.defrag(device)

    d = threading.Thread(target=defragger, daemon=True)
    d.start()
    d.join(timeout=60)
    deadlocked = d.is_alive()
    stop.set()
    for t in threads:
        t.join(timeout=30)
    assert not deadlocked, "defrag deadlocked against concurrent spill"
    for sid, seq in enumerate(seqs):
        seq.ensure_resident()
        np.testing.assert_array_equal(_seq_tokens(kv, seq), np.arange(4) + sid * 1000.0)
    _check_invariants(kv)
    for seq in seqs:
        kv.free_seq(seq)


def test_prefill_partial_failure_fails_only_unadmitted(device):
    """A mid-group prefill failure must fail only the requests prefill
    still owns: already-admitted members finish normally, the lane thread
    survives (no double settlement), drain() returns, no page leaks."""
    V, P = 64, 4
    prefill_fn, decode_fn = _toy_paged_model(V=V, P=P)
    kv = PagedKVCache(PageSpec(1, P, 1, 4), devices=[device], pool_pages=64)
    eng = PagedServeEngine(
        kv, prefill_fn, decode_fn, max_seq_len=32,
        scheduler=Scheduler([device]),
        prefill=LanePolicy(max_batch=8, max_delay_s=0.25, token_budget=4096),
        name="t-partial")
    orig = eng._pool_with_room
    calls = {"n": 0}

    def flaky(dev, need_pages):
        calls["n"] += 1
        if calls["n"] == 3:
            raise OutOfPages("injected mid-group failure")
        return orig(dev, need_pages)

    eng._pool_with_room = flaky
    try:
        prompts = [np.arange(4, dtype=np.int32) + i for i in range(4)]
        futs = [eng.submit(p, max_new_tokens=4) for p in prompts]
        for i in (0, 1):  # admitted before the failure: complete exactly
            out = futs[i].get(timeout=120)
            want = [(int(prompts[i][-1]) + 1 + j) % V for j in range(4)]
            assert list(out) == want
        for i in (2, 3):  # owned by prefill at the failure: fail cleanly
            with pytest.raises(OutOfPages, match="injected"):
                futs[i].get(timeout=120)
        eng.drain()  # in-flight accounting survives the failure path
        eng._pool_with_room = orig
        # The prefill thread and the decode lane are still alive.
        out = eng.submit(np.arange(4, dtype=np.int32), 3).get(timeout=120)
        assert list(out) == [(3 + 1 + j) % V for j in range(3)]
        m = eng.metrics()
    finally:
        eng.close()
    assert m["requests_failed"] == 2
    assert m["requests_completed"] == 3
    assert kv.pools[device.key].used_pages == 0


def test_lane_policy_explicit_zero_not_treated_as_unset(device):
    """LanePolicy(token_budget=0) / max_delay_s=0.0 are real bounds, not
    'inherit the default' (matching RequestEngine._lane_bounds)."""
    V, P = 64, 4
    prefill_fn, decode_fn = _toy_paged_model(V=V, P=P)
    kv = PagedKVCache(PageSpec(1, P, 1, 4), devices=[device], pool_pages=64)
    eng = PagedServeEngine(
        kv, prefill_fn, decode_fn, max_seq_len=32,
        scheduler=Scheduler([device]),
        prefill=LanePolicy(max_batch=8, max_delay_s=0.05, token_budget=0),
        decode=LanePolicy(max_batch=64, max_delay_s=0.0),
        name="t-zero")
    try:
        prompts = [np.arange(4, dtype=np.int32) for _ in range(3)]
        futs = [eng.submit(p, 3) for p in prompts]
        for p, f in zip(prompts, futs):
            out = f.get(timeout=120)
            assert list(out) == [(int(p[-1]) + 1 + j) % V for j in range(3)]
        m = eng.metrics()
    finally:
        eng.close()
    # token_budget=0 floors at one row per prefill batch; `x or default`
    # would have read it as unset and batched all three rows together.
    assert m["prefill_batches"] == 3


# ---------------------------------------------------------------------------
# fleet: migration + spread (forced multi-device subprocess, as test_scheduler)
# ---------------------------------------------------------------------------

_FLEET = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import numpy as np, jax, jax.numpy as jnp
    from repro.core import Scheduler, get_all_devices, agas
    from repro.serving.paged import PagedKVCache, PagedServeEngine, PageSpec
    from tests.test_paged import _toy_paged_model, _fill, _spec, _seq_tokens

    devs = list(get_all_devices().get())
    assert len(devs) == 4

    # -- coalesced migration preserves contents and re-homes the AGAS record
    spec = _spec(P=2)
    kv = PagedKVCache(spec, devices=devs, pool_pages=16)
    seq = kv.new_seq(devs[0])
    kv.append(seq, *_fill(spec, 5, 5))
    src_free = kv.pools[devs[0].key].num_free
    kv.migrate(seq, devs[2])
    assert seq.pool.device.key == devs[2].key
    assert agas.registry.placement(seq.gid).device_key == devs[2].key
    assert kv.pools[devs[0].key].num_free == src_free + 3   # source pages freed
    np.testing.assert_array_equal(_seq_tokens(kv, seq), np.arange(5) + 5000.0)
    kv.migrate(seq, devs[2])  # no-op: already home
    kv.free_seq(seq)

    # -- engine spreads sequences over the fleet, zero padding waste
    V, P = 64, 4
    prefill_fn, decode_fn = _toy_paged_model(V=V, P=P)
    kv = PagedKVCache(PageSpec(1, P, 1, 4), devices=devs, pool_pages=64)
    sched = Scheduler(devs, policy="least_loaded")
    eng = PagedServeEngine(kv, prefill_fn, decode_fn, max_seq_len=32,
                           scheduler=sched, name="fleet")
    rng = np.random.default_rng(0)
    futs = []
    for i in range(16):
        plen = int(rng.integers(1, 9))
        prompt = rng.integers(0, V - 16, size=plen).astype(np.int32)
        futs.append((prompt, eng.submit(prompt, max_new_tokens=6)))
    for prompt, f in futs:
        out = f.get(timeout=120)
        want = [(int(prompt[-1]) + 1 + j) % V for j in range(6)]
        assert list(out) == want, (list(out), want)
    m = eng.metrics()
    eng.close()
    assert m["padding_waste"] <= 0.5  # row pad only for warm-shape reuse
    spread = [k for k, v in m["placements"].items() if v > 0]
    assert len(spread) >= 2, m["placements"]
    print("FLEET_OK", len(spread))
""")


def test_paged_fleet_migration_and_spread():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src"),
         os.path.join(os.path.dirname(__file__), ".."),
         env.get("PYTHONPATH", "")])
    r = subprocess.run([sys.executable, "-c", _FLEET], capture_output=True,
                       text=True, timeout=420, env=env)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "FLEET_OK" in r.stdout
