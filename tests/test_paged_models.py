"""Model zoo x paged engine parity (DESIGN.md §17).

Every architecture family decodes through ``PagedServeEngine.from_config``
with greedy tokens BIT-IDENTICAL to the padded ``decode_step`` oracle, over
ragged prompt lengths that straddle page boundaries.  Both paths share the
same prefill math (``paged_prefill``); the oracle's dense cache is seeded
from the prefill rows, so the assertion isolates exactly the part that
changed — the ragged paged decode step vs the padded one.

Also here: sampling determinism (same (seed, request_id, position) ->
same tokens at fleet size 1 vs 8), honest AGAS accounting for resident
recurrent state, and the cross-locality prefill -> page-ship -> decode
path over a loopback parcelport.
"""
import functools
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config, smoke
from repro.core import agas, get_all_devices
from repro.models.model import get_model, paged_surface
from repro.serving import PagedKVCache, PagedServeEngine, PageSpec, SamplingParams

PAGE = 16          # REPRO_PAGE_SIZE default; PageSpec(page_size=0) resolves to it
MAX_PAGES = 3
MAX_SEQ = MAX_PAGES * PAGE   # oracle cache width == engine table width * P
# partial page / straddles a boundary mid-decode / straddles at prefill
PROMPT_LENS = (5, 14, 17)
MAX_NEW = 6

ZOO = ["olmo-1b", "qwen2-moe-a2.7b", "mamba2-130m", "hymba-1.5b", "whisper-tiny"]


@pytest.fixture(scope="module")
def device():
    return get_all_devices(1, 0).get()[0]


def _setup(name, seed=0):
    cfg = smoke(get_config(name))
    params = get_model(cfg).init(cfg, jax.random.PRNGKey(seed))
    return cfg, params


def _prompts(cfg, rng):
    return [rng.integers(1, cfg.vocab_size, size=n).astype(np.int32)
            for n in PROMPT_LENS]


def _extras(cfg, rng):
    if cfg.family != "encdec":
        return None
    e = cfg.encdec
    return {"frames": rng.normal(0, 0.02, (e.encoder_seq, cfg.d_model)).astype(np.float32)}


# ---------------------------------------------------------------------------
# padded oracle: dense cache seeded from the SAME prefill, decode_step loop
# ---------------------------------------------------------------------------


def _seed_cache(cfg, m, cache, k, v, state, T):
    """Write one prefill row (k/v: (L', T', K, hd) numpy) into the padded
    decode cache, per family layout."""
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        ck = np.asarray(cache["k"]).copy()
        cv = np.asarray(cache["v"]).copy()
        ck[:, 0, : k.shape[1]] = k
        cv[:, 0, : v.shape[1]] = v
        return {"k": jnp.asarray(ck), "v": jnp.asarray(cv)}
    if fam == "ssm":
        # state: {'state': (L, H, N, P), 'conv': (L, W-1, C)} one row
        return {
            "state": jnp.asarray(state["state"])[:, None],
            "conv": jnp.asarray(state["conv"])[:, None],
        }
    if fam == "encdec":
        ck = np.asarray(cache["self_k"]).copy()
        cv = np.asarray(cache["self_v"]).copy()
        ck[:, 0, : k.shape[1]] = k
        cv[:, 0, : v.shape[1]] = v
        return {
            "self_k": jnp.asarray(ck),
            "self_v": jnp.asarray(cv),
            "cross_k": jnp.asarray(state["cross_k"])[:, None],
            "cross_v": jnp.asarray(state["cross_v"])[:, None],
        }
    if fam == "hybrid":
        from repro.models.hybrid import _is_global, kv_producers

        producers = kv_producers(cfg)
        swa = [l for l in producers if not _is_global(cfg, l)]
        glob = [l for l in producers if _is_global(cfg, l)]
        Tp = k.shape[1]  # meta + T: prefill registers meta tokens as pages
        out = {kk: np.asarray(vv).copy() for kk, vv in cache.items()}
        ring = out["swa_k"].shape[2] if swa else 0
        for i, l in enumerate(swa):
            li = producers.index(l)
            for t in range(Tp):  # ring layout: slot t % ring holds token t
                out["swa_k"][i, 0, t % ring] = k[li, t]
                out["swa_v"][i, 0, t % ring] = v[li, t]
        for j, l in enumerate(glob):
            li = producers.index(l)
            out["glob_k"][j, 0, :Tp] = k[li]
            out["glob_v"][j, 0, :Tp] = v[li]
        out["ssm_state"] = np.asarray(state["ssm_state"])[:, None]
        out["ssm_conv"] = np.asarray(state["ssm_conv"])[:, None]
        return {kk: jnp.asarray(vv) for kk, vv in out.items()}
    raise AssertionError(cfg.family)


def _oracle_tokens(cfg, params, prompt, extras, max_new):
    """Greedy tokens from the padded decode path: prefill once via the
    SHARED ``paged_prefill`` (both paths start from identical logits and
    cache rows), then ``decode_step`` over a dense ``MAX_SEQ``-wide cache
    — the width the paged path's masked attend reduces over."""
    m = get_model(cfg)
    tok = jnp.asarray(prompt)[None]
    ex = None
    if extras is not None:
        ex = {kk: jnp.asarray(vv)[None] for kk, vv in extras.items()}
    k, v, state, logits = jax.jit(functools.partial(m.paged_prefill, cfg, params))(tok, ex)
    out = [int(np.argmax(np.asarray(logits)[0]))]
    state = None if state is None else jax.tree_util.tree_map(
        lambda a: np.asarray(a)[0], state)
    cache = m.init_cache(cfg, 1, MAX_SEQ, dtype=jnp.float32)
    cache = _seed_cache(cfg, m, cache, np.asarray(k)[0], np.asarray(v)[0], state, len(prompt))

    dec = jax.jit(functools.partial(m.decode_step, cfg, params))
    T = len(prompt)
    for g in range(max_new - 1):
        # hybrid counts CONTENT tokens (meta offset added inside)
        logits, cache = dec(cache, jnp.asarray([[out[-1]]], jnp.int32),
                            jnp.int32(T + g))
        out.append(int(np.argmax(np.asarray(logits)[0, 0])))
    return out


# ---------------------------------------------------------------------------
# greedy parity: paged engine == padded oracle, bitwise, every family
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ZOO)
def test_zoo_greedy_parity_bitwise(arch, device):
    cfg, params = _setup(arch)
    rng = np.random.default_rng(3)
    prompts = _prompts(cfg, rng)
    extras = _extras(cfg, rng)

    want = [_oracle_tokens(cfg, params, p, extras, MAX_NEW) for p in prompts]

    eng = PagedServeEngine.from_config(
        cfg, params=params, devices=[device], max_seq_len=MAX_SEQ,
        name=f"t-zoo-{arch}")
    try:
        assert eng.max_pages == MAX_PAGES  # oracle width == table width * P
        futs = [eng.submit(p, MAX_NEW, extras=extras) for p in prompts]
        got = [list(np.asarray(f.get(timeout=600))) for f in futs]
    finally:
        eng.close()
    for p, w, g in zip(prompts, want, got):
        assert g == w, f"{arch} T={len(p)}: paged {g} != oracle {w}"


def test_zoo_two_model_fleet_interleaved(device):
    """Two engines over different families serve concurrently on one
    device pool without cross-talk (the tutorial §10 shape)."""
    cfg_a, par_a = _setup("olmo-1b")
    cfg_b, par_b = _setup("mamba2-130m")
    rng = np.random.default_rng(7)
    pa, pb = _prompts(cfg_a, rng)[0], _prompts(cfg_b, rng)[1]
    want_a = _oracle_tokens(cfg_a, par_a, pa, None, MAX_NEW)
    want_b = _oracle_tokens(cfg_b, par_b, pb, None, MAX_NEW)

    ea = PagedServeEngine.from_config(cfg_a, params=par_a, devices=[device],
                                      max_seq_len=MAX_SEQ, name="t-fleet-a")
    eb = PagedServeEngine.from_config(cfg_b, params=par_b, devices=[device],
                                      max_seq_len=MAX_SEQ, name="t-fleet-b")
    try:
        fa = ea.submit(pa, MAX_NEW)
        fb = eb.submit(pb, MAX_NEW)
        assert list(np.asarray(fa.get(timeout=600))) == want_a
        assert list(np.asarray(fb.get(timeout=600))) == want_b
    finally:
        ea.close()
        eb.close()


# ---------------------------------------------------------------------------
# sampling: per-request PRNG keyed by (request_id, position)
# ---------------------------------------------------------------------------


def test_sample_token_reproducible_and_param_sensitive():
    from repro.serving import sample_token

    logits = np.random.default_rng(0).normal(size=257)
    sp = SamplingParams(temperature=0.7, top_k=16, top_p=0.9, seed=11)
    a = sample_token(logits, sp, request_id=5, position=3)
    assert a == sample_token(logits, sp, request_id=5, position=3)
    draws = {sample_token(logits, sp, 5, pos) for pos in range(64)}
    assert len(draws) > 1  # position advances the stream
    # greedy ignores the PRNG entirely
    g = sample_token(logits, SamplingParams(), 5, 3)
    assert g == int(np.argmax(logits))
    # top_k=1 is greedy regardless of temperature
    assert sample_token(logits, SamplingParams(temperature=2.0, top_k=1, seed=1), 0, 0) == g


_SAMPLING_CHILD = textwrap.dedent(
    """
    import os, sys
    n = int(sys.argv[1])
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=%d "
                               "--xla_cpu_multi_thread_eigen=false "
                               + os.environ.get("XLA_FLAGS", "")) % n
    import numpy as np
    import jax
    from repro.configs import get_config, smoke
    from repro.models.model import get_model
    from repro.serving import PagedServeEngine, SamplingParams

    cfg = smoke(get_config("olmo-1b"))
    params = get_model(cfg).init(cfg, jax.random.PRNGKey(0))
    eng = PagedServeEngine.from_config(cfg, params=params, max_seq_len=48,
                                       name="t-fleet-sample")
    try:
        rng = np.random.default_rng(9)
        sp = SamplingParams(temperature=0.8, top_k=24, top_p=0.95, seed=13)
        prompts = [rng.integers(1, cfg.vocab_size, size=5 + i).astype(np.int32)
                   for i in range(8)]
        futs = [eng.submit(p, 6, sampling=sp, request_id=1000 + i)
                for i, p in enumerate(prompts)]
        for f in futs:
            print("TOKENS", list(np.asarray(f.get(timeout=600))))
    finally:
        eng.close()
    print("OK", len(jax.devices()))
    """
)


@pytest.mark.slow
def test_sampling_bitwise_across_fleet_sizes():
    """Same seed + request_ids -> the SAME sampled tokens whether the
    fleet is 1 device or 8: the PRNG keys on (seed, request_id,
    position), never on batch composition or placement."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src:" + env.get("PYTHONPATH", "")
    cwd = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    outs = {}
    for n in (1, 8):
        proc = subprocess.run(
            [sys.executable, "-c", _SAMPLING_CHILD, str(n)],
            capture_output=True, text=True, env=env, cwd=cwd, timeout=900)
        assert proc.returncode == 0, proc.stderr[-3000:]
        assert f"OK {n}" in proc.stdout, proc.stdout
        outs[n] = [l for l in proc.stdout.splitlines() if l.startswith("TOKENS")]
        assert len(outs[n]) == 8
    assert outs[1] == outs[8], (outs[1], outs[8])


# ---------------------------------------------------------------------------
# resident state: honest bytes through AGAS (spill/placement sees it)
# ---------------------------------------------------------------------------


def test_resident_state_counts_toward_agas_bytes(device):
    spec = PageSpec(layers=1, page_size=4, kv_heads=1, head_dim=2)
    kv = PagedKVCache(spec, devices=[device], pool_pages=8)
    # The AGAS registry is process-global, so other live registrations on
    # this device key are possible — assert deltas, not absolutes.
    start = agas.registry.resident_bytes(device.key)
    seq = kv.new_seq(device)
    k = np.ones((1, 4, 1, 2), np.float32)
    kv.append(seq, k, -k)
    key = next(iter(kv.pools))
    base = kv.stats()[key]["resident_bytes"]
    st = {"a": np.ones((16, 16), np.float32), "b": np.arange(8, dtype=np.int32)}
    seq.set_state(st)
    extra = 16 * 16 * 4 + 8 * 4
    assert seq.nbytes == spec.page_bytes + extra
    assert kv.stats()[key]["resident_bytes"] == base + extra
    # replacing the state re-declares, not accumulates
    seq.set_state({"a": np.ones((4,), np.float32)})
    assert kv.stats()[key]["resident_bytes"] == base + 16
    kv.free_seq(seq)
    assert agas.registry.resident_bytes(device.key) == start


def test_export_import_roundtrip_preserves_state(device):
    spec = PageSpec(layers=2, page_size=4, kv_heads=1, head_dim=2)
    kv = PagedKVCache(spec, devices=[device], pool_pages=16)
    seq = kv.new_seq(device)
    rng = np.random.default_rng(4)
    k = rng.normal(size=(2, 7, 1, 2)).astype(np.float32)
    kv.append(seq, k, -k)
    seq.set_state({"s": rng.normal(size=(3, 5)).astype(np.float32)})
    payload = kv.export_seq(seq)
    assert payload["length"] == 7

    twin = kv.import_seq(device, payload)
    assert twin.length == 7
    np.testing.assert_array_equal(
        np.asarray(twin.state["s"]), np.asarray(seq.state["s"]))
    k2, v2 = kv.export_seq(twin)["k"], kv.export_seq(twin)["v"]
    np.testing.assert_array_equal(k2, payload["k"])
    np.testing.assert_array_equal(v2, payload["v"])
    assert twin.nbytes == seq.nbytes  # identical accounting on the far side
    kv.free_seq(seq)
    kv.free_seq(twin)


# ---------------------------------------------------------------------------
# cross-locality: prefill here, ship pages, decode THERE, same tokens
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["olmo-1b", "mamba2-130m"])
def test_cross_locality_page_ship_decode_parity(arch, device):
    """Prefill on this locality, ship the page set + state over the
    parcelport ``invoke`` lane, resume decode on a loopback locality:
    tokens must equal the single-locality engine's (the worker re-derives
    bit-identical params from the config name + seed)."""
    from repro.core import LoopbackParcelport
    from repro.serving.paged import paged_worker_reset

    cfg, params = _setup(arch)
    rng = np.random.default_rng(6)
    prompt = _prompts(cfg, rng)[2]  # page-straddling prefill

    # single-locality reference: the full engine path
    eng = PagedServeEngine.from_config(
        cfg, params=params, devices=[device], max_seq_len=MAX_SEQ,
        name=f"t-ship-ref-{arch}")
    try:
        want = list(np.asarray(eng.submit(prompt, MAX_NEW).get(timeout=600)))
        max_pages = eng.max_pages
    finally:
        eng.close()

    # prefill side: pages + state + first token, exported as one payload
    spec_fn, prefill_fn, _ = paged_surface(cfg)
    kv = PagedKVCache(spec_fn(cfg), devices=[device], pool_pages=32)
    k, v, state, logits = jax.jit(functools.partial(prefill_fn, cfg, params))(
        jnp.asarray(prompt)[None], None)
    seq = kv.new_seq(device)
    kv.append(seq, np.asarray(k)[0], np.asarray(v)[0])
    if state is not None:
        seq.set_state(jax.tree_util.tree_map(lambda a: np.asarray(a)[0], state))
    first = int(np.argmax(np.asarray(logits)[0]))
    shipped = kv.export_seq(seq)
    kv.free_seq(seq)

    port = LoopbackParcelport(n_localities=2)
    try:
        lid = port.localities()[1].process_index
        paged_worker_reset({})
        got = port.call(lid, "invoke", {
            "fn": "repro.serving.paged:paged_worker_decode",
            "payload": {
                "name": f"t-ship-{arch}", "config": arch, "smoke": True,
                "seed": 0, "pool_pages": 32, "seq": shipped,
                "first_token": first, "max_new": MAX_NEW,
                "max_pages": max_pages, "sampling": None, "request_id": 0,
            },
        }).get(timeout=600)
        assert list(np.asarray(got)) == want, (arch, list(np.asarray(got)), want)
    finally:
        paged_worker_reset({})
        port.shutdown()
