"""Device / Buffer / Program object model tests (paper §4 workflow)."""
import os
import textwrap

import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # minimal container: seeded fallback sweeps
    from _hypothesis_compat import given, settings, strategies as st

from repro.core import Dim3, get_all_devices, registry, wait_all


@pytest.fixture(scope="module")
def device():
    devices = get_all_devices(1, 0).get()  # Listing 1
    assert len(devices) >= 1
    return devices[0]


def test_get_all_devices_future_and_capability_filter(device):
    none = get_all_devices(99, 0).get()
    assert none == []
    assert device.capability() >= (1, 0)
    assert device.is_local


def test_device_registered_in_agas(device):
    assert registry.resolve(device.gid) is device
    assert registry.placement(device.gid).device_key == device.key


def test_buffer_roundtrip(device):
    buf = device.create_buffer(16, np.float32).get()
    data = np.arange(16, dtype=np.float32)
    buf.enqueue_write(0, data).get()
    out = buf.enqueue_read_sync()
    np.testing.assert_array_equal(out, data)


def test_buffer_offset_window_write_read(device):
    buf = device.create_buffer(10, np.int32, fill=0).get()
    buf.enqueue_write(3, np.array([7, 8, 9], dtype=np.int32)).get()
    np.testing.assert_array_equal(
        buf.enqueue_read_sync(), [0, 0, 0, 7, 8, 9, 0, 0, 0, 0]
    )
    window = buf.enqueue_read_sync(offset=3, count=3)
    np.testing.assert_array_equal(window, [7, 8, 9])


def test_buffer_window_bounds_raise_value_error(device):
    buf = get_buf = device.create_buffer(8, np.int32).get()
    for offset, count in [(-1, 2), (0, 9), (7, 2), (9, 0), (0, -1), (-3, None)]:
        with pytest.raises(ValueError, match="out of range"):
            buf.enqueue_read(offset, count)
    with pytest.raises(ValueError, match="out of range"):
        buf.enqueue_write(-1, np.zeros(2, np.int32))
    with pytest.raises(ValueError, match="out of range"):
        buf.enqueue_write(6, np.zeros(4, np.int32))  # 6 + 4 > 8
    with pytest.raises(ValueError, match="out of range"):
        buf.enqueue_write(0, np.zeros(4, np.int32), count=9)
    with pytest.raises(ValueError, match="exceeds"):
        # in-range window, but the data cannot cover it: the write would
        # silently land fewer elements than validated
        buf.enqueue_write(0, np.zeros(4, np.int32), count=6)
    # in-range windows (including the exact tail) still work
    buf.enqueue_write(6, np.array([5, 6], np.int32)).get()
    np.testing.assert_array_equal(buf.enqueue_read_sync(6, 2), [5, 6])
    assert get_buf.enqueue_read_sync(8, 0).size == 0  # empty tail window


def test_buffer_window_bounds_property(device):
    """Property sweep: any (offset, count) window is either fully inside
    the buffer — and round-trips exactly — or raises ValueError; it is
    never silently clamped to the wrong elements."""
    size = 16
    buf = device.create_buffer(size, np.int32).get()
    base = np.arange(size, dtype=np.int32)
    buf.enqueue_write(0, base).get()

    @settings(max_examples=30, deadline=None)
    @given(
        offset=st.integers(min_value=-3, max_value=size + 3),
        count=st.integers(min_value=-2, max_value=size + 3),
    )
    def check(offset, count):
        in_range = 0 <= offset and 0 <= count and offset + count <= size
        if in_range:
            out = buf.enqueue_read_sync(offset, count)
            np.testing.assert_array_equal(out, base[offset : offset + count])
            buf.enqueue_write(offset, base[offset : offset + count], count=count).get()
            np.testing.assert_array_equal(buf.enqueue_read_sync(), base)
        else:
            with pytest.raises(ValueError, match="out of range"):
                buf.enqueue_read(offset, count)
            with pytest.raises(ValueError, match="out of range"):
                buf.enqueue_write(offset, np.zeros(max(count, 0), np.int32), count=count)

    check()


def test_buffer_async_writes_are_ordered(device):
    buf = device.create_buffer(4, np.int32).get()
    futs = [buf.enqueue_write(0, np.full(4, i, np.int32)) for i in range(8)]
    wait_all(futs)
    np.testing.assert_array_equal(buf.enqueue_read_sync(), np.full(4, 7))


def test_program_listing2_workflow(device):
    """The paper's Listing 2, end to end: sum of n elements."""
    n = 1000
    host = np.ones(n, dtype=np.uint32)

    futures = []
    inbuf = device.create_buffer(n, np.uint32).get()
    futures.append(inbuf.enqueue_write(0, host))
    resbuf = device.create_buffer(1, np.uint32).get()
    futures.append(resbuf.enqueue_write(0, np.zeros(1, np.uint32)))

    prog = device.create_program(
        {"sum": lambda x, r: r + jnp.sum(x, dtype=jnp.uint32)}, name="sum-prog"
    ).get()
    futures.append(prog.build("sum"))

    wait_all(futures)  # Listing 2 line 38
    prog.run([inbuf, resbuf], "sum", grid=Dim3(1), block=Dim3(32), out=[resbuf]).get()
    res = resbuf.enqueue_read_sync(0, 1)
    assert int(res[0]) == n


def test_program_from_file_percolation(device, tmp_path):
    src = textwrap.dedent(
        """
        import jax.numpy as jnp

        def scale(x, s):
            return x * s

        KERNELS = {"scale": scale}
        """
    )
    path = tmp_path / "kernel.py"
    path.write_text(src)
    prog = device.create_program_with_file(str(path)).get()
    assert prog.kernel_names() == ["scale"]

    buf = device.create_buffer_from(np.arange(4.0, dtype=np.float32)).get()
    out = prog.run([buf, np.float32(2.0)], "scale").get()
    np.testing.assert_allclose(np.asarray(out), [0.0, 2.0, 4.0, 6.0])


def test_program_build_is_cached(device):
    prog = device.create_program({"inc": lambda x: x + 1}, name="cache").get()
    spec = jnp.zeros((8,), jnp.float32)
    f1 = prog.build("inc", spec)
    f2 = prog.build("inc", spec)
    assert f1.get() is f2.get()


def test_program_missing_kernel_fails(device):
    prog = device.create_program({"a": lambda x: x}, name="p").get()
    with pytest.raises(KeyError):
        prog.build("nope").get()


def test_kernel_receives_grid_block(device):
    seen = {}

    def k(x, grid=None, block=None):
        seen["grid"], seen["block"] = grid, block
        return x

    prog = device.create_program({"k": k}, name="gb").get()
    buf = device.create_buffer_from(np.zeros(2, np.float32)).get()
    prog.run([buf], "k", grid=Dim3(4, 2, 1), block=(128, 1, 1)).get()
    assert seen["grid"] == (4, 2, 1)
    assert seen["block"] == (128, 1, 1)


def test_copy_to_same_process_device_updates_agas(device):
    buf = device.create_buffer_from(np.arange(6.0, dtype=np.float32)).get()
    moved = buf.copy_to(device).get()
    assert moved.gid != buf.gid
    np.testing.assert_allclose(moved.enqueue_read_sync(), np.arange(6.0))
    assert registry.placement(moved.gid).device_key == device.key
