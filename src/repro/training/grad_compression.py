"""Gradient compression: per-tensor int8 quantization with stochastic
rounding, for halving/quartering cross-pod gradient all-reduce bytes.

At 512+ chips the gradient reduce-scatter over DCI (the ``pod`` axis)
becomes the scaling wall; int8 with stochastic rounding keeps SGD
unbiased (E[q] = g) at 4x fewer wire bytes than f32 / 2x fewer than bf16.
Applied OUTSIDE the microbatch accumulation (which stays f32): compress
-> (all-reduce in int8 arithmetic carried as int32 partial sums) ->
decompress.  The dry-run path exposes it as a plan knob so the roofline
delta is measurable; the math is exercised by unit/property tests.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def compress(g, key):
    """g: float array -> (int8 q, f32 scale). Stochastic rounding: unbiased."""
    gf = g.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-30) / 127.0
    x = gf / scale
    lo = jnp.floor(x)
    p_up = x - lo  # probability of rounding up
    up = jax.random.bernoulli(key, p_up)
    q = jnp.clip(lo + up.astype(jnp.float32), -127, 127).astype(jnp.int8)
    return q, scale


def decompress(q, scale):
    return q.astype(jnp.float32) * scale


def compress_tree(grads, key):
    """Pytree version; returns (q_tree, scale_tree)."""
    leaves, tdef = jax.tree.flatten(grads)
    keys = jax.random.split(key, len(leaves))
    qs, scales = [], []
    for leaf, k in zip(leaves, keys):
        q, s = compress(leaf, k)
        qs.append(q)
        scales.append(s)
    return tdef.unflatten(qs), tdef.unflatten(scales)


def decompress_tree(q_tree, scale_tree):
    return jax.tree.map(decompress, q_tree, scale_tree)
