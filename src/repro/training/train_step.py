"""Train-step factory: microbatched gradient accumulation (lax.scan, fp32
accumulators) + AdamW update, driven by a ``CellPlan``.

The futurized runtime overlaps the *host* side of the loop (data feed,
checkpoint writes) with this step (paper Figs. 4/5 patterns); inside the
step, XLA's latency-hiding scheduler overlaps the collectives that GSPMD
inserts for the rule-set sharding.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import batch_logical_specs, get_model
from repro.training.optimizer import OptConfig, adamw_update, init_opt_state


def _split_micro(batch: dict, logical: dict, n: int) -> dict:
    """Reshape each batch leaf's *batch* axis (found via its logical spec)
    from (B, ...) to (n, B/n, ...) moved to the front for lax.scan."""
    out = {}
    for k, v in batch.items():
        names = logical[k]
        bi = names.index("batch")
        B = v.shape[bi]
        assert B % n == 0, (k, B, n)
        new_shape = v.shape[:bi] + (n, B // n) + v.shape[bi + 1 :]
        r = v.reshape(new_shape)
        out[k] = jnp.moveaxis(r, bi, 0)
    return out


def make_train_step(cfg, shape, opt_cfg: OptConfig, plan):
    """Returns ``train_step(params, opt_state, batch) -> (params, opt_state,
    metrics)`` ready for jit."""
    m = get_model(cfg)
    logical = batch_logical_specs(cfg, shape)
    n = plan.num_microbatches
    compute_dtype = jnp.bfloat16 if plan.compute_dtype == "bfloat16" else jnp.float32

    def cast(p):
        """Mixed precision: matmul weights compute in bf16; fp32 master
        copies stay in the optimizer; 1-D params (norms/biases) stay fp32."""
        if compute_dtype == jnp.float32:
            return p
        return jax.tree.map(
            lambda x: x.astype(compute_dtype)
            if (x.dtype == jnp.float32 and x.ndim >= 2)
            else x,
            p,
        )

    def loss_of(params, mb):
        return m.loss_fn(cfg, cast(params), mb, remat=plan.remat, q_block=plan.q_block)

    def train_step(params, opt_state, batch):
        if n == 1:
            loss, grads = jax.value_and_grad(loss_of)(params, batch)
        else:
            stacked = _split_micro(batch, logical, n)

            def body(acc, mb):
                l, g = jax.value_and_grad(loss_of)(params, mb)
                acc_l, acc_g = acc
                acc_g = jax.tree.map(lambda a, b: a + b.astype(jnp.float32), acc_g, g)
                return (acc_l + l, acc_g), None

            zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss_sum, grad_sum), _ = jax.lax.scan(body, (jnp.zeros((), jnp.float32), zero), stacked)
            loss = loss_sum / n
            grads = jax.tree.map(lambda g: g / n, grad_sum)

        new_params, new_state, om = adamw_update(opt_cfg, params, grads, opt_state)
        metrics = {"loss": loss, **om}
        return new_params, new_state, metrics

    return train_step


def make_init(cfg, opt_cfg: OptConfig, dtype=jnp.float32):
    """Returns ``init(key) -> (params, opt_state)`` (jit/eval_shape-able)."""
    m = get_model(cfg)

    def init(key):
        params = m.init(cfg, key, dtype)
        return params, init_opt_state(params)

    return init
