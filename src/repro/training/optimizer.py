"""AdamW built from scratch (no optax): fp32 moments, decoupled weight
decay, global-norm clipping, linear-warmup + cosine schedule.

Optimizer state shards exactly like the params (same logical specs), so
FSDP sharding of m/v falls out of the param rules — the ZeRO analogue.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def schedule(cfg: OptConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0
    )
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(math.pi * t))
    return cfg.lr * warm * cos


def init_opt_state(params):
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return {"m": zeros, "v": jax.tree.map(jnp.copy, zeros), "step": jnp.zeros((), jnp.int32)}


def opt_state_specs(param_specs_tree):
    """Optimizer state logical specs mirror the param specs."""
    return {
        "m": param_specs_tree,
        "v": param_specs_tree,
        "step": (),
    }


def global_norm(tree):
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))


def adamw_update(cfg: OptConfig, params, grads, state):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    if cfg.clip_norm is not None:
        scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)

    lr = schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m2 = cfg.b1 * m + (1 - cfg.b1) * gf
        v2 = cfg.b2 * v + (1 - cfg.b2) * gf * gf
        mh = m2 / b1c
        vh = v2 / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m2, v2

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state["m"])
    flat_v = tdef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_params, {"m": new_m, "v": new_v, "step": step}, {"gnorm": gnorm, "lr": lr}
