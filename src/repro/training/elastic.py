"""Fault-tolerant elastic data-parallel training (DESIGN.md §16).

The cluster story, closed end-to-end: the same runtime that serves also
trains.  An ``ElasticTrainer`` holds the master params + optimizer state
on the driver (registered in AGAS, so the state is a resolvable cluster
object, not a Python local), shards each global batch across a fleet of
workers, and all-reduces the returned gradients before one AdamW update:

* **Local workers** run their shard on this process's devices.  The shard
  step is captured once per (family, device, rows) as a ``TaskGraph`` —
  params/tokens/labels as write-fed buffers, one fused launch returning
  ``(*grad_leaves, loss)`` — and every subsequent step is a pre-bound
  fast-plan replay with feeds (PR 6's dispatch-tax fix, reused verbatim).
  ``donate=False``: the driver feeds the same param arrays to every
  worker's graph.
* **Parcel workers** ship the shard as ONE ``invoke`` parcel to a remote
  locality (arrays ride the shared-memory lane when large); the remote
  side resolves ``repro.training.elastic:shard_action`` by name, runs the
  shard under its own jit cache, and replies with the gradient leaves —
  optionally int8-compressed (``grad_compression``, stochastic rounding
  seeded per (step, shard), so a replayed step re-rounds identically).

**Determinism contract** (what the chaos tests pin down): shard splits
are a pure function of (batch, active-worker count); gradients are
combined on the driver in numpy float32, in shard order, weighted by
shard rows; the update is one jitted AdamW.  A step is therefore a pure
function of (params, opt_state, cursor, active count) — re-executing it
after a failure, with any workers, from the same state gives bit-identical
results.

**Elasticity, both directions** (fail-stop model, DESIGN.md §6):

* *Down*: a worker death mid-step (Heartbeat miss, process exit, or the
  fault injector) discards that step's partial results and re-executes
  the WHOLE step resharded over the survivors — dask-style recomputation
  from the AGAS-resident driver state, no checkpoint restore.  The loss
  curve from the reshard point is bit-identical to a clean N-1-worker run
  from the same state (the property the chaos suite asserts).  Checkpoint
  restore remains the last resort for driver loss, via ``resume=True``.
* *Up*: a recovered (``revive()``) or newly added (``add_worker()``)
  worker is picked up at the next step boundary — the active set is
  re-read every step, exactly like the scheduler re-reads liveness.

Transient faults are not deaths: a dropped gradient parcel
(``ParcelDropped``) is re-sent to the same worker up to
``REPRO_ELASTIC_RETRIES`` times before the link is declared dead.
"""
from __future__ import annotations

import os
import threading
import time
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.base import ShapeConfig, smoke as smoke_cfg
from repro.core import agas
from repro.core.executor import get_runtime
from repro.core.graph import TaskGraph
from repro.data.pipeline import SyntheticTokens
from repro.distribution.recipes import plan_for
from repro.fault.inject import ParcelDropped
from repro.fault.monitor import Heartbeat, StepMonitor
from repro.models import get_model
from repro.training.grad_compression import compress
from repro.training.optimizer import OptConfig, adamw_update, init_opt_state

__all__ = ["ElasticTrainer", "LocalWorker", "ParcelWorker", "WorkerDied", "shard_action"]


class WorkerDied(RuntimeError):
    """A worker was lost mid-step; the trainer reshards over survivors."""


# ---------------------------------------------------------------------------
# the shard step, shared by every route
# ---------------------------------------------------------------------------

# (arch, smoke, seq, global_batch) -> family dict.  Module-level so every
# worker/trainer in the process shares one jit cache, one treedef, one
# captured-graph cache — repeated trainers (property tests, benchmark
# sweeps) pay compilation once per shard shape, not once per trainer.
_FAMILIES: "dict[tuple, dict]" = {}
_GEXECS: "dict[tuple, tuple]" = {}  # (famkey, device.key, rows) -> capture entry
_PROGRAMS: "dict[tuple, Any]" = {}  # (famkey, device.key) -> Program
_UPDATES: "dict[OptConfig, Any]" = {}  # opt_cfg -> jitted update
_CACHE_LOCK = threading.Lock()


def _on_runtime_reset() -> None:
    """Drop the captured-graph and program caches when the runtime is
    torn down (``executor.reset_runtime``): their buffers hold queues
    owned by the dying runtime, and their AGAS records must be retired
    with them — a later memory-pressure spill must never try to evict a
    stale buffer onto a shut-down lane.  ``_FAMILIES``/``_UPDATES`` stay:
    plain jits, no runtime objects."""
    with _CACHE_LOCK:
        entries = list(_GEXECS.values())
        _GEXECS.clear()
        _PROGRAMS.clear()
    for _gexec, param_nodes, tok_node, lab_node, _launch in entries:
        for node in (*param_nodes, tok_node, lab_node):
            gid = getattr(node.buf, "gid", None)
            if gid is not None:
                agas.registry.unregister(gid)


def _get_family(arch: str, use_smoke: bool, seq: int, global_batch: int) -> dict:
    key = (str(arch), bool(use_smoke), int(seq), int(global_batch))
    with _CACHE_LOCK:
        fam = _FAMILIES.get(key)
    if fam is not None:
        return fam
    cfg = smoke_cfg(get_config(arch)) if use_smoke else get_config(arch)
    shape = ShapeConfig("custom", seq_len=seq, global_batch=global_batch, kind="train")
    plan = plan_for(cfg, shape)
    m = get_model(cfg)
    compute_dtype = jnp.bfloat16 if plan.compute_dtype == "bfloat16" else jnp.float32

    def cast(p):
        if compute_dtype == jnp.float32:
            return p
        return jax.tree.map(
            lambda x: x.astype(compute_dtype)
            if (x.dtype == jnp.float32 and x.ndim >= 2)
            else x,
            p,
        )

    def loss_of(params, mb):
        return m.loss_fn(cfg, cast(params), mb, remat=plan.remat, q_block=plan.q_block)

    def grad_step(params, batch):
        """One shard's contribution: (mean loss over shard rows, f32 grads).
        No microbatching — a shard is already a batch fraction."""
        loss, grads = jax.value_and_grad(loss_of)(params, batch)
        grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        return loss.astype(jnp.float32), grads

    def init(rng_key):
        return m.init(cfg, rng_key)

    shapes = jax.eval_shape(init, jax.random.key(0))
    fam = {
        "key": key,
        "cfg": cfg,
        "treedef": jax.tree_util.tree_structure(shapes),
        "n_leaves": len(jax.tree_util.tree_leaves(shapes)),
        "grad_step": grad_step,
        "jit_grad": jax.jit(grad_step),
        "init": init,
    }
    with _CACHE_LOCK:
        return _FAMILIES.setdefault(key, fam)


def _pack_grads(flat: "list[np.ndarray]", loss, task: dict) -> dict:
    """Wire format of one shard's reply; int8 stochastic rounding when the
    task asks for compression.  The rounding key is derived from the
    task's ``ckey`` (a pure function of (seed, step, shard)), so a
    re-executed step re-rounds bit-identically."""
    out: dict = {"loss": np.float32(loss)}
    if task.get("compress"):
        base = jax.random.key(int(task["ckey"]) % (2**31 - 1))
        qs, scales = [], []
        for i, g in enumerate(flat):
            q, s = compress(jnp.asarray(g), jax.random.fold_in(base, i))
            qs.append(np.asarray(q))
            scales.append(np.float32(np.asarray(s)))
        out["q"] = qs
        out["scales"] = scales
    else:
        out["grads"] = flat
    return out


def shard_action(payload: dict) -> dict:
    """The remote half of one data-parallel shard step.

    Resolved BY NAME (``repro.training.elastic:shard_action``) through the
    parcel ``invoke`` action — source never crosses the wire.  The payload
    carries flat param leaves + the shard's tokens/labels + config knobs;
    the reply carries ``{loss, grads | (q, scales)}``.  Worker-side state
    (jit cache, treedef) lives in the module caches above, warmed on first
    use and reused for every later step."""
    fam = _get_family(
        payload["arch"], payload["smoke"], int(payload["seq"]), int(payload["global_batch"])
    )
    params = jax.tree_util.tree_unflatten(
        fam["treedef"], [jnp.asarray(a) for a in payload["params"]]
    )
    batch = {
        "tokens": jnp.asarray(payload["tokens"]),
        "labels": jnp.asarray(payload["labels"]),
    }
    loss, grads = fam["jit_grad"](params, batch)
    flat = [np.asarray(g, np.float32) for g in jax.tree_util.tree_leaves(grads)]
    return _pack_grads(flat, np.asarray(loss), payload)


def _gexec_for(fam: dict, dev, task: dict):
    """Captured shard graph for (family, device, rows): params + tokens +
    labels as write-fed buffers, one fused launch.  Instantiated with
    ``donate=False`` (the driver feeds shared param arrays) and cached so
    every replay takes PR 6's pre-bound fast plan."""
    rows = int(task["tokens"].shape[0])
    key = (fam["key"], dev.key, rows)
    with _CACHE_LOCK:
        entry = _GEXECS.get(key)
    if entry is not None:
        return entry

    n = fam["n_leaves"]
    treedef = fam["treedef"]
    grad_step = fam["grad_step"]

    def shard_grad(*args):
        params = jax.tree_util.tree_unflatten(treedef, list(args[:n]))
        batch = {"tokens": args[n], "labels": args[n + 1]}
        loss, grads = grad_step(params, batch)
        return tuple(jax.tree_util.tree_leaves(grads)) + (loss,)

    pkey = (fam["key"], dev.key)
    with _CACHE_LOCK:
        prog = _PROGRAMS.get(pkey)
    if prog is None:
        prog = dev.create_program({"shard_grad": shard_grad}, f"elastic:{dev.key}").get()
        with _CACHE_LOCK:
            prog = _PROGRAMS.setdefault(pkey, prog)

    g = TaskGraph(f"elastic:{dev.key}:r{rows}")
    param_nodes = []
    for leaf in task["params"]:
        arr = np.asarray(leaf)
        buf = dev.create_buffer(arr.shape, arr.dtype).get()
        param_nodes.append(g.write(buf, arr))
    toks = np.asarray(task["tokens"])
    labs = np.asarray(task["labels"])
    tbuf = dev.create_buffer(toks.shape, toks.dtype).get()
    tok_node = g.write(tbuf, toks)
    lbuf = dev.create_buffer(labs.shape, labs.dtype).get()
    lab_node = g.write(lbuf, labs)
    launch = g.run(prog, [w.buf for w in param_nodes] + [tbuf, lbuf], "shard_grad")
    gexec = g.instantiate(donate=False)
    entry = (gexec, param_nodes, tok_node, lab_node, launch)
    with _CACHE_LOCK:
        return _GEXECS.setdefault(key, entry)


def _run_shard_local(task: dict, dev, route: str) -> dict:
    fam = _get_family(task["arch"], task["smoke"], int(task["seq"]), int(task["global_batch"]))
    if route == "graph":
        gexec, param_nodes, tok_node, lab_node, launch = _gexec_for(fam, dev, task)
        feeds = {node: leaf for node, leaf in zip(param_nodes, task["params"])}
        feeds[tok_node] = np.ascontiguousarray(task["tokens"])
        feeds[lab_node] = np.ascontiguousarray(task["labels"])
        res = gexec.replay(feeds=feeds).get()
        outs = res[launch]
        outs = list(outs) if isinstance(outs, (tuple, list)) else [outs]
        flat = [np.asarray(g, np.float32) for g in outs[:-1]]
        loss = np.asarray(outs[-1])
    else:  # direct-jit route (REPRO_ELASTIC_ROUTE=jit)
        params = jax.tree_util.tree_unflatten(
            fam["treedef"],
            [jax.device_put(np.asarray(a), dev.jax_device) for a in task["params"]],
        )
        batch = {
            "tokens": jax.device_put(np.asarray(task["tokens"]), dev.jax_device),
            "labels": jax.device_put(np.asarray(task["labels"]), dev.jax_device),
        }
        loss, grads = fam["jit_grad"](params, batch)
        flat = [np.asarray(g, np.float32) for g in jax.tree_util.tree_leaves(grads)]
        loss = np.asarray(loss)
    return _pack_grads(flat, loss, task)


# ---------------------------------------------------------------------------
# workers
# ---------------------------------------------------------------------------


class LocalWorker:
    """One data-parallel worker on this process: its own serial work queue
    (shards overlap across workers), its own ``Heartbeat``, optionally
    pinned to one device.  ``occupancy_tokens_per_s`` models device busy
    time with a GIL-releasing sleep (benchmark use, fig6/fig8 precedent)."""

    kind = "local"

    def __init__(
        self,
        wid: int,
        device=None,
        *,
        route: "str | None" = None,
        occupancy_tokens_per_s: "float | None" = None,
        heartbeat_timeout: float = 600.0,
        on_dead=None,
    ):
        self.wid = int(wid)
        self.device = device
        self.route = route or os.environ.get("REPRO_ELASTIC_ROUTE", "graph")
        self.occupancy = occupancy_tokens_per_s
        self.queue = get_runtime().queue(f"elastic-w{self.wid}")
        self.heartbeat = Heartbeat(timeout_s=heartbeat_timeout, on_dead=on_dead)
        self._dead = False
        self._kill_at: "Optional[int]" = None

    def _device(self):
        if self.device is None:
            from repro.core.device import get_all_devices

            self.device = get_all_devices().get()[0]
        return self.device

    def alive(self) -> bool:
        return not self._dead

    def mark_dead(self) -> None:
        self._dead = True

    def kill(self) -> None:
        """Immediate death: heartbeat expires and ``on_dead`` edge-fires."""
        self._dead = True
        self.heartbeat.force_expire()
        self.heartbeat.check()

    def revive(self) -> None:
        """Re-admit: picked up by the trainer at the next step boundary."""
        self._dead = False
        self.heartbeat.tick()

    def kill_at_step(self, step: int) -> None:
        """Arm a mid-step death (fault injection): the worker dies inside
        its own shard execution at training step ``step``."""
        self._kill_at = int(step)

    def run_shard(self, task: dict):
        def _run():
            if self._kill_at is not None and task["step"] >= self._kill_at:
                self._kill_at = None
                self.kill()
                raise WorkerDied(
                    f"worker {self.wid} killed by fault injection at step {task['step']}"
                )
            if self.occupancy:
                time.sleep(np.asarray(task["tokens"]).size / float(self.occupancy))
            out = _run_shard_local(task, self._device(), self.route)
            self.heartbeat.tick()
            return out

        return self.queue.submit(_run)


class ParcelWorker:
    """One data-parallel worker behind a parcelport locality.  The shard
    ships as ONE ``invoke`` parcel (arrays take the shm lane when large);
    liveness is the port's (heartbeat monitor / fail-fast gate)."""

    kind = "parcel"

    def __init__(self, wid: int, port, locality_id: int):
        self.wid = int(wid)
        self.port = port
        self.lid = int(locality_id)
        self._dead = False
        self._kill_at: "Optional[int]" = None

    def alive(self) -> bool:
        return not self._dead and self.port.alive(self.lid)

    def mark_dead(self) -> None:
        self._dead = True

    def kill(self) -> None:
        self._dead = True
        if hasattr(self.port, "kill"):  # loopback: flip the fail-fast gate
            self.port.kill(self.lid)
        else:  # cluster: SIGKILL the worker process
            w = self.port._workers.get(self.lid)
            if w is not None and w.proc.is_alive():
                w.proc.kill()

    def revive(self) -> None:
        self._dead = False
        if hasattr(self.port, "revive"):
            self.port.revive(self.lid)

    def kill_at_step(self, step: int) -> None:
        self._kill_at = int(step)

    def run_shard(self, task: dict):
        if self._kill_at is not None and task["step"] >= self._kill_at:
            self._kill_at = None
            self.kill()  # the call below fails fast: a mid-step death
        return self.port.call(
            self.lid, "invoke", {"fn": "repro.training.elastic:shard_action", "payload": task}
        )


# ---------------------------------------------------------------------------
# the trainer
# ---------------------------------------------------------------------------


def _update_for(opt_cfg: OptConfig):
    with _CACHE_LOCK:
        fn = _UPDATES.get(opt_cfg)
        if fn is None:
            def _upd(params, grads, state, _cfg=opt_cfg):
                return adamw_update(_cfg, params, grads, state)

            fn = _UPDATES[opt_cfg] = jax.jit(_upd)
    return fn


class ElasticTrainer:
    """Elastic data-parallel trainer over local and/or parcel workers.

    ``state=(params, opt_state), start_step=k`` seeds the trainer from a
    snapshot (the chaos tests' reference runs); ``total_steps`` pins the
    LR-schedule horizon so split runs match a single run bit-for-bit.
    """

    def __init__(
        self,
        arch: str = "olmo-1b",
        *,
        use_smoke: bool = True,
        batch: int = 8,
        seq: int = 64,
        lr: float = 3e-4,
        seed: int = 0,
        workers: int = 2,
        port=None,
        devices: "list | None" = None,
        grad_compression: bool = False,
        occupancy_tokens_per_s: "float | None" = None,
        total_steps: "int | None" = None,
        state: "tuple | None" = None,
        start_step: int = 0,
        ckpt_dir: "str | None" = None,
        ckpt_every: int = 0,
        resume: bool = False,
        max_retries: "int | None" = None,
        heartbeat_timeout: float = 600.0,
    ):
        from repro.checkpoint.checkpoint import CheckpointManager

        self.arch = arch
        self.use_smoke = bool(use_smoke)
        self.batch = int(batch)
        self.seq = int(seq)
        self.lr = float(lr)
        self.seed = int(seed)
        self.grad_compression = bool(grad_compression)
        self.total_steps = total_steps
        if max_retries is None:
            max_retries = int(os.environ.get("REPRO_ELASTIC_RETRIES", "2"))
        self.max_retries = int(max_retries)

        self._fam = _get_family(arch, use_smoke, seq, batch)
        self.source = SyntheticTokens(self._fam["cfg"].vocab_size, seq, batch, seed=seed)
        self.monitor = StepMonitor()
        self.events: "list[tuple]" = []  # ("death"|"retry"|"join", step, wid, ...)
        self.history: "list[float]" = []
        self._opt_cfg: "Optional[OptConfig]" = None
        self._ckpt_every = int(ckpt_every)
        self._mgr = CheckpointManager(ckpt_dir) if ckpt_dir else None
        self._ckpt_futs: list = []

        # -- state: snapshot > checkpoint > fresh init ----------------------
        self.cursor = int(start_step)
        if state is not None:
            params, opt_state = state
            params = jax.tree.map(jnp.asarray, params)
            opt_state = jax.tree.map(jnp.asarray, opt_state)
        else:
            params = self._fam["init"](jax.random.key(seed))
            opt_state = init_opt_state(params)
            if resume and self._mgr and self._mgr.latest_step() is not None:
                (params, opt_state), extra = self._mgr.restore((params, opt_state))
                self.cursor = int(extra.get("step", self._mgr.latest_step()))
        self.params, self.opt_state = params, opt_state

        # -- fleet -----------------------------------------------------------
        self.workers: list = []
        if port is not None:
            for i, loc in enumerate(port.localities()):
                self.workers.append(ParcelWorker(i, port, loc.process_index))
        else:
            for i in range(int(workers)):
                dev = devices[i % len(devices)] if devices else None
                self.workers.append(
                    LocalWorker(
                        i,
                        device=dev,
                        occupancy_tokens_per_s=occupancy_tokens_per_s,
                        heartbeat_timeout=heartbeat_timeout,
                    )
                )

        # The master state is an AGAS-resident cluster object: any locality
        # (or a post-mortem driver) can resolve it by GID — recovery reads
        # live state, not a stale checkpoint.
        self._agas_gid = agas.registry.register(
            self,
            agas.Placement(device_key=agas.HOST_KEY),
            kind="elastic-state",
            arch=str(arch),
            batch=self.batch,
            seq=self.seq,
        )

    # -- fleet management ----------------------------------------------------

    @property
    def agas_gid(self) -> int:
        return self._agas_gid

    def active_workers(self) -> list:
        return [w for w in self.workers if w.alive()]

    def add_worker(self, worker=None):
        """Scale up: admit ``worker`` (or spawn a fresh ``LocalWorker``)
        from the next step boundary on."""
        if worker is None:
            wid = max((w.wid for w in self.workers), default=-1) + 1
            worker = LocalWorker(wid)
        self.workers.append(worker)
        self.events.append(("join", self.cursor, worker.wid))
        return worker

    # -- one step -------------------------------------------------------------

    @staticmethod
    def _split(batch: dict, n: int) -> "list[dict]":
        toks = np.array_split(batch["tokens"], n)
        labs = np.array_split(batch["labels"], n)
        return [{"tokens": t, "labels": l} for t, l in zip(toks, labs)]

    def _task(self, shard: dict, shard_i: int, n_active: int, flat_params) -> dict:
        # ckey: pure function of (seed, step, shard, fleet size) — the
        # compression re-rounds identically when the step is re-executed.
        ckey = ((self.seed * 1_000_003 + self.cursor) * 131 + shard_i) * 31 + n_active
        return {
            "arch": self.arch,
            "smoke": self.use_smoke,
            "seq": self.seq,
            "global_batch": self.batch,
            "step": self.cursor,
            "params": flat_params,
            "tokens": shard["tokens"],
            "labels": shard["labels"],
            "compress": self.grad_compression,
            "ckey": ckey,
        }

    def _await_shard(self, w, fut, mk_task):
        """One shard's result, retrying dropped parcels on the same worker;
        everything else becomes a ``WorkerDied`` reshard."""
        attempts = 0
        while True:
            try:
                return fut.get()
            except ParcelDropped as e:
                attempts += 1
                if attempts > self.max_retries or not w.alive():
                    raise WorkerDied(
                        f"worker {w.wid}: {attempts} consecutive parcels dropped"
                    ) from e
                self.events.append(("retry", self.cursor, w.wid))
                fut = w.run_shard(mk_task())
            except WorkerDied:
                raise
            except Exception as e:  # noqa: BLE001 - transport/worker failure
                if w.alive():
                    raise  # a real error on a live worker is a bug, not a death
                raise WorkerDied(f"worker {w.wid} lost mid-step: {e}") from e

    def step(self) -> float:
        """One data-parallel step; survives any worker deaths inside it."""
        if self._opt_cfg is None:
            self._ensure_opt(1)
        t0 = time.time()
        batch = self.source.batch(self.cursor)
        B = int(batch["tokens"].shape[0])
        flat_params = [np.asarray(l) for l in jax.tree_util.tree_leaves(self.params)]

        while True:
            active = self.active_workers()
            if not active:
                raise RuntimeError(
                    "elastic trainer has no live workers: every worker died; "
                    "restart the driver with resume=True to recover from the "
                    "latest checkpoint"
                )
            n = min(len(active), B)
            active = active[:n]
            shards = self._split(batch, n)
            futs = [
                (w, i, w.run_shard(self._task(shards[i], i, n, flat_params)))
                for i, w in enumerate(active)
            ]
            results: list = [None] * n
            death = None
            for w, i, fut in futs:
                if death is not None:
                    try:  # settle the rest; their results are discarded
                        fut.exception()
                    except Exception:  # noqa: BLE001
                        pass
                    continue
                try:
                    results[i] = self._await_shard(
                        w, fut, lambda i=i, n=n: self._task(shards[i], i, n, flat_params)
                    )
                except WorkerDied as e:
                    w.mark_dead()
                    death = (w, e)
            if death is None:
                break
            # Reshard and re-execute the WHOLE step from the driver's
            # AGAS-resident state (pure: params/opt_state untouched so far).
            self.events.append(("death", self.cursor, death[0].wid, str(death[1])))

        rows = [int(s["tokens"].shape[0]) for s in shards]
        grads_flat, loss = self._combine(results, rows, B)
        grads = jax.tree_util.tree_unflatten(self._fam["treedef"], grads_flat)
        upd = _update_for(self._opt_cfg)
        self.params, self.opt_state, _metrics = upd(self.params, grads, self.opt_state)
        self.cursor += 1
        self.history.append(float(loss))
        self.monitor.record(self.cursor, time.time() - t0)
        if self._mgr and self._ckpt_every and self.cursor % self._ckpt_every == 0:
            self._ckpt_futs.append(
                self._mgr.save_async(
                    self.cursor, (self.params, self.opt_state), extra={"step": self.cursor}
                )
            )
        return float(loss)

    @staticmethod
    def _combine(results: list, rows: "list[int]", B: int) -> "tuple[list, np.float32]":
        """Driver-side all-reduce: rows-weighted sum in numpy float32, in
        shard order — bit-deterministic for a given (results, rows)."""
        total: "list[np.ndarray] | None" = None
        loss = np.float32(0.0)
        for r, res in zip(rows, results):
            w = np.float32(r / B)
            if "q" in res:  # int8 lane: decompress on the driver
                flat = [
                    q.astype(np.float32) * np.float32(s)
                    for q, s in zip(res["q"], res["scales"])
                ]
            else:
                flat = [np.asarray(g, np.float32) for g in res["grads"]]
            if total is None:
                total = [w * g for g in flat]
            else:
                total = [a + w * g for a, g in zip(total, flat)]
            loss = loss + w * np.float32(res["loss"])
        assert total is not None
        return total, loss

    # -- driving --------------------------------------------------------------

    def _ensure_opt(self, steps: int) -> None:
        if self._opt_cfg is None:
            horizon = int(self.total_steps or (self.cursor + steps))
            self._opt_cfg = OptConfig(
                lr=self.lr, warmup_steps=min(100, horizon // 10 + 1), total_steps=horizon
            )

    def run(self, steps: int, *, log_every: int = 0) -> dict:
        self._ensure_opt(steps)
        losses = []
        for _ in range(int(steps)):
            loss = self.step()
            losses.append(loss)
            if log_every and (self.cursor - 1) % log_every == 0:
                print(f"step {self.cursor - 1:5d} loss {loss:8.4f}", flush=True)
        self.wait()
        return {
            "losses": losses,
            "final_loss": losses[-1] if losses else float("nan"),
            "stragglers": len(self.monitor.events),
            "events": list(self.events),
            "params": self.params,
            "opt_state": self.opt_state,
        }

    def snapshot(self) -> dict:
        """Host copy of the full training state (reference-run seeding)."""
        return {
            "params": jax.tree.map(np.array, self.params),
            "opt_state": jax.tree.map(np.array, self.opt_state),
            "step": self.cursor,
        }

    def wait(self) -> None:
        """Drain in-flight checkpoint writes."""
        futs, self._ckpt_futs = self._ckpt_futs, []
        for f in futs:
            f.wait()
        if self._mgr:
            self._mgr.wait()

    def close(self) -> None:
        self.wait()
        agas.registry.unregister(self._agas_gid)
