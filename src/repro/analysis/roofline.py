"""Roofline terms per (arch x shape x mesh) from the dry-run records.

Hardware model (TPU v5e, per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.  Terms (seconds per step, per chip — the partitioned
module is per-device, so HLO quantities are already per-chip):

    compute    = HLO_FLOPs / 197e12
    memory     = HLO_bytes / 819e9
    collective = collective_wire_bytes / 50e9

MODEL_FLOPS: analytic useful work = 6*N_active*T (train) / 2*N_active*T
(inference) + the attention (or SSD) sequence-interaction term; the ratio
MODEL_FLOPS / HLO_FLOPs exposes remat/dispatch waste (full remat => ~0.75
by construction: one extra forward).

`python -m repro.analysis.roofline` prints the EXPERIMENTS.md tables.
"""
from __future__ import annotations

import json
from pathlib import Path

from repro.configs import get_config, get_shape

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # B/s / chip
LINK_BW = 50e9  # B/s / link

RESULTS_DIR = Path(__file__).resolve().parents[3] / "results" / "dryrun"


def load_records(multi_pod: bool = False) -> "list[dict]":
    tag = "multipod" if multi_pod else "singlepod"
    out = []
    for p in sorted(RESULTS_DIR.glob(f"*__{tag}.json")):
        out.append(json.loads(p.read_text()))
    return out


# ---------------------------------------------------------------------------
# analytic useful FLOPs
# ---------------------------------------------------------------------------

def model_flops(cfg, shape) -> float:
    """Global useful FLOPs for one step of this cell."""
    B, S = shape.global_batch, shape.seq_len
    N = cfg.active_param_count()
    L = cfg.num_layers
    H = cfg.num_heads
    hd = cfg.hd if H else 0  # attention-free archs have no heads

    if shape.kind == "train":
        T = B * S
        mat = 6.0 * N * T  # fwd 2NT + bwd 4NT
        # causal attention: QK^T + PV, halved by causality, x3 for backward
        attn = 3.0 * 2.0 * B * S * S * H * hd if not cfg.attn_free else 0.0
        if cfg.sliding_window and cfg.family == "hybrid":
            w = cfg.sliding_window
            n_glob = len(cfg.global_attn_layers)
            attn = 3.0 * 2.0 * B * S * H * hd * (
                (L - n_glob) / L * min(2 * w, S) + n_glob / L * S
            )
        ssd = 0.0
        if cfg.ssm is not None:
            s = cfg.ssm
            Hs, P, Nst, Lc = s.n_heads(cfg.d_model), s.head_dim, s.d_state, s.chunk
            # intra (2 L_c (P+N) per tok) + state in/out (4 N P per tok)
            ssd = 3.0 * B * S * Hs * (2.0 * min(Lc, S) * (P + Nst) + 4.0 * Nst * P) * L
        return mat + attn * (L if not cfg.attn_free and cfg.family != "hybrid" else 1.0) + ssd

    if shape.kind == "prefill":
        T = B * S
        mat = 2.0 * N * T
        attn = 2.0 * B * S * S * H * hd * L if not cfg.attn_free else 0.0
        return mat + attn

    # decode: one token against an S-token cache
    T = B
    mat = 2.0 * N * T
    attn = 4.0 * B * S * H * hd * L if not cfg.attn_free else 0.0
    if cfg.family == "hybrid" and cfg.sliding_window:
        n_glob = len(cfg.global_attn_layers)
        attn = 4.0 * B * H * hd * (n_glob * S + (L - n_glob) * min(cfg.sliding_window, S))
    ssd = 0.0
    if cfg.ssm is not None and cfg.family in ("ssm", "hybrid"):
        s = cfg.ssm
        Hs, P, Nst = s.n_heads(cfg.d_model), s.head_dim, s.d_state
        ssd = 4.0 * B * Hs * Nst * P * L
    return mat + attn + ssd


def hbm_floor_bytes(cfg, shape, devices: int) -> float:
    """Per-chip lower bound on HBM traffic: weights once + KV cache once."""
    n = cfg.active_param_count()
    wbytes = 2.0 * n  # bf16
    kv = 0.0
    if shape.kind == "decode" and not cfg.attn_free:
        kv = 2.0 * 2.0 * cfg.num_layers * shape.global_batch * shape.seq_len * cfg.num_kv_heads * cfg.hd
    return (wbytes + kv) / devices


# ---------------------------------------------------------------------------
# terms
# ---------------------------------------------------------------------------

def roofline_terms(rec: dict) -> dict:
    cfg = get_config(rec["arch"])
    shape = get_shape(rec["shape"])
    dev = rec["devices"]
    hlo = rec["hlo"]

    compute_s = hlo["flops"] / PEAK_FLOPS
    memory_s = hlo["hbm_bytes"] / HBM_BW
    collective_s = hlo["collective_wire_bytes"] / LINK_BW

    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    bound = max(terms, key=terms.get)
    step = max(terms.values())

    mf_global = model_flops(cfg, shape)
    mf_dev = mf_global / dev
    ratio = mf_dev / hlo["flops"] if hlo["flops"] else 0.0

    # MFU-style score: useful flops / (step time x peak)
    mfu = mf_dev / (step * PEAK_FLOPS) if step > 0 else 0.0
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)

    return {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "bound": bound,
        "step_seconds": step,
        "model_flops_global": mf_global,
        "model_flops_ratio": ratio,
        "mfu": mfu,
        "tokens_per_s": tokens / step if step > 0 else 0.0,
        "roofline_fraction": terms["compute"] / step if step > 0 else 0.0,
    }


def summarize(multi_pod: bool = False) -> str:
    lines = [
        "| arch | shape | bound | compute s | memory s | collective s | 6ND/HLO | MFU | tok/s |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for rec in load_records(multi_pod):
        if "error" in rec:
            lines.append(f"| {rec['arch']} | {rec['shape']} | ERROR | | | | | | |")
            continue
        t = roofline_terms(rec)
        lines.append(
            f"| {rec['arch']} | {rec['shape']} | **{t['bound']}** | {t['compute_s']:.2e} "
            f"| {t['memory_s']:.2e} | {t['collective_s']:.2e} | {t['model_flops_ratio']:.2f} "
            f"| {t['mfu'] * 100:.1f}% | {t['tokens_per_s']:.3g} |"
        )
    return "\n".join(lines)


def main():
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()
    print(summarize(args.multi_pod))


if __name__ == "__main__":
    main()
