"""Perf lab: the hypothesis -> change -> re-lower -> re-analyse loop.

Lowers one cell with plan/rule overrides, computes roofline terms, and
diffs them against the recorded baseline — the measurement half of the
EXPERIMENTS.md §Perf iterations.

    PYTHONPATH=src python -m repro.analysis.perf_lab \
        --cell qwen2-moe-a2.7b:train_4k --tag ep-over-tp \
        --set moe_strategy=ep --set remat=dots

Each run writes results/perf/<cell>__<tag>.json.
"""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS", "")
)

import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402
from pathlib import Path  # noqa: E402

from repro.analysis.roofline import roofline_terms  # noqa: E402
from repro.configs import get_config, get_shape  # noqa: E402
from repro.distribution.recipes import plan_for  # noqa: E402
from repro.launch.dryrun import RESULTS_DIR, cell_path, lower_cell  # noqa: E402

PERF_DIR = RESULTS_DIR.parent / "perf"


def apply_overrides(cfg, plan, sets: "dict[str, str]"):
    """Apply --set key=value overrides to the plan (and derived rules)."""
    plan_kw = {}
    rules = dict(plan.rules)
    for key, val in sets.items():
        if key.startswith("rules."):
            rules[key[6:]] = None if val in ("none", "None") else (
                tuple(val.split("+")) if "+" in val else val
            )
        elif key == "moe_strategy":
            from repro.distribution.recipes import _moe_overrides

            cfg = dataclasses.replace(cfg, moe=dataclasses.replace(cfg.moe, strategy=val))
            rules.update(_moe_overrides(cfg))
        elif key in ("remat", "compute_dtype", "cache_dtype"):
            plan_kw[key] = val
        elif key in ("q_block", "num_microbatches", "moe_groups"):
            plan_kw[key] = None if val in ("none", "None") else int(val)
        else:
            raise KeyError(f"unknown override {key}")
    plan = dataclasses.replace(plan, rules=rules, **plan_kw)
    return cfg, plan


def run_experiment(cell: str, tag: str, sets: "dict[str, str]", multi_pod: bool = False) -> dict:
    arch, shape_name = cell.split(":")
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    plan = plan_for(cfg, shape, multi_pod=multi_pod)
    cfg, plan = apply_overrides(cfg, plan, sets)

    # lower with the modified plan; patch get_config so helper paths that
    # re-fetch the config see the override too
    import repro.configs as C

    orig_get = C.get_config
    C.get_config = lambda name: cfg if name == arch else orig_get(name)
    try:
        rec = lower_cell(arch, shape_name, multi_pod=multi_pod, plan=plan)
    finally:
        C.get_config = orig_get

    rec["tag"] = tag
    rec["overrides"] = sets
    terms = roofline_terms(rec)
    rec["roofline"] = terms

    # baseline diff
    base_path = cell_path(arch, shape_name, multi_pod)
    if base_path.exists():
        base = json.loads(base_path.read_text())
        if "error" not in base:
            bt = roofline_terms(base)
            rec["baseline_roofline"] = bt
            rec["delta"] = {
                k: (terms[k] - bt[k]) / bt[k] if isinstance(bt[k], float) and bt[k] else None
                for k in ("compute_s", "memory_s", "collective_s", "step_seconds", "mfu")
            }

    PERF_DIR.mkdir(parents=True, exist_ok=True)
    out = PERF_DIR / f"{arch}__{shape_name}__{tag}.json"
    out.write_text(json.dumps(rec, indent=1, default=str))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True, help="arch:shape")
    ap.add_argument("--tag", required=True)
    ap.add_argument("--set", action="append", default=[], help="key=value")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()
    sets = dict(s.split("=", 1) for s in args.set)
    rec = run_experiment(args.cell, args.tag, sets, args.multi_pod)
    t = rec["roofline"]
    print(f"== {args.cell} [{args.tag}] {rec.get('overrides')}")
    print(
        f"   compute {t['compute_s']:.3e}s  memory {t['memory_s']:.3e}s  "
        f"collective {t['collective_s']:.3e}s  bound={t['bound']}  mfu={t['mfu']*100:.2f}%"
    )
    if "delta" in rec:
        d = rec["delta"]
        print(
            "   vs baseline: "
            + "  ".join(f"{k}:{v * 100:+.1f}%" for k, v in d.items() if v is not None)
        )


if __name__ == "__main__":
    main()
