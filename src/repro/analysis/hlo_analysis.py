"""Loop-aware static analysis of compiled HLO text.

``compiled.cost_analysis()`` counts every instruction ONCE — it does not
multiply ``while`` bodies by their trip count (probe: flops identical for
scan lengths 1/4/16), which would undercount a 95-layer scanned model by
~95x.  This module re-derives roofline inputs from ``compiled.as_text()``:

  * per-computation instruction tables (result types resolved by name),
  * ``while`` trip counts from ``backend_config={"known_trip_count"...}``,
  * execution multipliers propagated through the call graph
    (while bodies, fusions, calls, conditionals),
  * FLOPs from dot/convolution shapes x multipliers,
  * HBM traffic proxy: operand+result bytes of top-level (non-fused)
    scheduled ops x multipliers,
  * collective wire bytes per device with op-specific factors.
"""
from __future__ import annotations

import json
import re
from dataclasses import dataclass, field
from typing import Optional

_DTYPE_BYTES = {
    "pred": 1,
    "s2": 1, "s4": 1, "s8": 1, "u2": 1, "u4": 1, "u8": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1, "f8e4m3fnuz": 1, "f8e5m2fnuz": 1,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_list(type_str: str):
    """'(f32[2,3]{1,0}, s32[])' or 'bf16[4,5]' -> [(dtype, [dims]), ...]."""
    out = []
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        shape = [int(d) for d in dims.split(",") if d] if dims else []
        out.append((dt, shape))
    return out


def _nbytes(type_str: str) -> int:
    total = 0
    for dt, shape in _shape_list(type_str):
        n = 1
        for d in shape:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class Instruction:
    name: str
    result_type: str
    opcode: str
    operands: "list[str]"
    raw: str

    def attr(self, key: str) -> "Optional[str]":
        m = re.search(rf"{key}=%?([\w.\-]+)", self.raw)
        return m.group(1) if m else None


@dataclass
class Computation:
    name: str
    params: "dict[str, str]"  # param name -> type
    instructions: "list[Instruction]" = field(default_factory=list)

    def result_type_of(self, operand: str) -> "Optional[str]":
        if operand in self.params:
            return self.params[operand]
        for ins in self.instructions:
            if ins.name == operand:
                return ins.result_type
        return None


_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\((.*?)\)\s*->\s*(.+?)\s*\{")
_INSTR = re.compile(
    r"^\s*(ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\([^)]*\)|[\w\[\]{},\s]+?))\s*"
    r"([\w\-]+)\((.*)$"
)


def parse_hlo(text: str) -> "tuple[dict[str, Computation], str]":
    """Parse HLO text -> ({comp_name: Computation}, entry_name)."""
    comps: "dict[str, Computation]" = {}
    entry = ""
    cur: "Optional[Computation]" = None
    for line in text.splitlines():
        if cur is None:
            m = _COMP_HDR.match(line.strip())
            if m:
                is_entry, name, params_str, _ret = m.groups()
                params = {}
                for pm in re.finditer(r"%?([\w.\-]+)\s*:\s*((?:\([^)]*\)|[\w\[\]{},]+))", params_str):
                    params[pm.group(1)] = pm.group(2)
                cur = Computation(name, params)
                comps[name] = cur
                if is_entry:
                    entry = name
            continue
        stripped = line.strip()
        if stripped == "}":
            cur = None
            continue
        m = _INSTR.match(line)
        if not m:
            continue
        _root, name, rtype, opcode, rest = m.groups()
        # operand names: %foo references before the closing paren of the call
        call_part = rest.split("),")[0] if ")," in rest else rest
        operands = re.findall(r"%([\w.\-]+)", call_part)
        cur.instructions.append(Instruction(name, rtype.strip(), opcode, operands, line))
    return comps, entry


def _trip_count(ins: Instruction) -> int:
    m = re.search(r'known_trip_count[":{\s]+n["\s:]+"?(\d+)', ins.raw)
    if m:
        return int(m.group(1))
    return 1


def execution_multipliers(comps: "dict[str, Computation]", entry: str) -> "dict[str, float]":
    """comp name -> how many times it executes per step."""
    mult: "dict[str, float]" = {entry: 1.0}
    order = [entry]
    seen = {entry}
    while order:
        cname = order.pop(0)
        comp = comps.get(cname)
        if comp is None:
            continue
        base = mult[cname]

        def bump(target: str, factor: float):
            if target not in comps:
                return
            mult[target] = mult.get(target, 0.0) + base * factor
            if target not in seen:
                seen.add(target)
                order.append(target)

        for ins in comp.instructions:
            if ins.opcode == "while":
                trips = _trip_count(ins)
                body, cond = ins.attr("body"), ins.attr("condition")
                if body:
                    bump(body, trips)
                if cond:
                    bump(cond, trips + 1)
            elif ins.opcode in ("fusion", "call", "custom-call", "async-start"):
                callee = ins.attr("calls") or ins.attr("to_apply")
                if callee:
                    bump(callee, 1.0)
            elif ins.opcode == "conditional":
                for key in ("true_computation", "false_computation"):
                    t = ins.attr(key)
                    if t:
                        bump(t, 1.0)
                for t in re.findall(r"branch_computations=\{([^}]*)\}", ins.raw):
                    for b in re.findall(r"%([\w.\-]+)", t):
                        bump(b, 1.0)
            elif ins.opcode in ("reduce", "map", "sort", "scatter", "select-and-scatter", "reduce-window"):
                t = ins.attr("to_apply")
                if t:
                    bump(t, 1.0)  # elementwise applies — negligible flops anyway
    return mult


def _dot_flops(comp: Computation, ins: Instruction) -> float:
    res = _shape_list(ins.result_type)
    if not res:
        return 0.0
    out_elems = 1
    for d in res[0][1]:
        out_elems *= d
    lhs_t = comp.result_type_of(ins.operands[0]) if ins.operands else None
    if lhs_t is None:
        return 0.0
    lhs = _shape_list(lhs_t)
    if not lhs:
        return 0.0
    cdims = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.raw)
    csize = 1
    if cdims and cdims.group(1):
        for d in cdims.group(1).split(","):
            csize *= lhs[0][1][int(d)]
    return 2.0 * out_elems * csize


def _conv_flops(comp: Computation, ins: Instruction) -> float:
    res = _shape_list(ins.result_type)
    if not res:
        return 0.0
    out_elems = 1
    for d in res[0][1]:
        out_elems *= d
    rhs_t = comp.result_type_of(ins.operands[1]) if len(ins.operands) > 1 else None
    if rhs_t is None:
        return 0.0
    rhs = _shape_list(rhs_t)
    k_elems = 1
    for d in rhs[0][1]:
        k_elems *= d
    groups = re.search(r"feature_group_count=(\d+)", ins.raw)
    g = int(groups.group(1)) if groups else 1
    # per output elem: 2 * (kernel elems / output features) ~ approx
    out_feat = res[0][1][-1] if res[0][1] else 1
    return 2.0 * out_elems * max(k_elems // max(out_feat, 1), 1) * (1 if g else 1)


_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all", "collective-permute")
_SKIP_BYTES = {
    # control / bookkeeping
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "add-dependency", "while", "conditional", "call",
    # layout-only / view ops: fused into consumers on TPU
    "copy", "transpose", "reshape", "broadcast", "convert", "iota",
    # dynamic-slice = a view the consumer streams through (the consuming
    # dot/fusion charges the operand read); charging it separately would
    # triple-count KV-cache reads in decode
    "dynamic-slice",
}
# ops that genuinely stream ALL their operands from HBM (never fused away)
_STREAMING = {"dot", "convolution", "scatter", "sort", "reduce-scatter"}


def _fusion_bytes(ins: Instruction, comps: "dict[str, Computation]", rb: float) -> float:
    """Effective bytes moved by a fusion op.

    In-place pattern: a fusion whose called computation updates its own
    result buffer via dynamic-update-slice (scan ys-stacking, donated KV
    caches) aliases on TPU — charge the *update* bytes, not the buffer.
    """
    callee = ins.attr("calls")
    comp = comps.get(callee or "")
    if comp is None:
        return rb

    # convert-transparent fusions: the CPU backend interleaves bf16<->f32
    # converts (and layout ops) that do not exist on TPU (the MXU consumes
    # bf16 directly); a fusion made only of such ops is charged nothing.
    _TRANSPARENT = {"convert", "bitcast", "copy", "reshape", "transpose", "broadcast", "parameter", "constant"}
    if comp.instructions and all(i.opcode in _TRANSPARENT for i in comp.instructions):
        return 0.0

    def dims(t: str):
        s = _shape_list(t)
        return tuple(s[0][1]) if s else None

    out_dims = dims(ins.result_type)
    for inner in comp.instructions:
        # dims match, dtype-insensitive: XLA CPU interleaves converts
        # (bf16<->f32) around the DUS inside the same fusion
        if inner.opcode == "dynamic-update-slice" and dims(inner.result_type) == out_dims:
            upd = comp.result_type_of(inner.operands[1]) if len(inner.operands) > 1 else None
            if upd is not None:
                return float(_nbytes(upd))
    return rb


def _replica_group_size(ins: Instruction) -> int:
    m = re.search(r"replica_groups=\{\{([^}]*)\}", ins.raw)
    if m:
        return max(len([x for x in m.group(1).split(",") if x.strip() != ""]), 1)
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", ins.raw)  # iota format [n,m]
    if m:
        return int(m.group(2))
    return 1


def _collective_wire_bytes(ins: Instruction, comp: Computation) -> float:
    """Per-participating-device wire bytes (ring algorithm estimates).

    TPU-dtype correction: the CPU backend has no native bf16 matmul, so it
    upcasts bf16 dots to f32 and GSPMD then emits f32 collectives on dot
    inputs/outputs that are *semantically* bf16 (our einsums set
    preferred_element_type to the activation dtype).  Collectives whose
    metadata ties them to a dot_general — except the deliberately-f32
    attention-score and logits paths — are charged at bf16 width.
    """
    n = _replica_group_size(ins)
    if n <= 1:
        return 0.0
    rbytes = _nbytes(ins.result_type)
    if "f32[" in ins.result_type and "/dot_general" in ins.raw:
        if not any(tag in ins.raw for tag in ("bqkrd", "bkrqs", "dv->bsv", "de->te")):
            rbytes *= 0.5  # semantically bf16 on TPU
    frac = (n - 1) / n
    if ins.opcode.startswith("all-reduce"):
        return 2.0 * rbytes * frac
    if ins.opcode.startswith("all-gather"):
        return rbytes * frac
    if ins.opcode.startswith("reduce-scatter"):
        return rbytes * n * frac  # operand = n x result
    if ins.opcode.startswith("all-to-all"):
        return rbytes * frac
    if ins.opcode.startswith("collective-permute"):
        return float(rbytes)
    return 0.0


def analyze(text: str, detail: bool = False) -> dict:
    """Full loop-aware analysis of one compiled module's HLO text."""
    comps, entry = parse_hlo(text)
    mult = execution_multipliers(comps, entry)

    flops = 0.0
    hbm_bytes = 0.0
    coll_bytes = 0.0
    coll_by_kind: "dict[str, float]" = {}
    coll_count: "dict[str, int]" = {}
    bytes_by_op: "dict[str, float]" = {}

    for cname, comp in comps.items():
        k = mult.get(cname, 0.0)
        if k == 0.0:
            continue
        fused = cname.startswith("wrapped_") or "fused" in cname or cname.endswith("_computation")
        for ins in comp.instructions:
            if ins.opcode == "dot":
                flops += k * _dot_flops(comp, ins)
            elif ins.opcode == "convolution":
                flops += k * _conv_flops(comp, ins)
            base = ins.opcode.split("-start")[0]
            if any(base.startswith(c) for c in _COLLECTIVES):
                wb = k * _collective_wire_bytes(ins, comp)
                coll_bytes += wb
                coll_by_kind[base] = coll_by_kind.get(base, 0.0) + wb
                coll_count[base] = coll_count.get(base, 0) + 1
            if not fused and ins.opcode not in _SKIP_BYTES and not ins.opcode.endswith("-done"):
                # HBM traffic model (TPU-fusion-aware; DESIGN.md §7):
                #  * streaming ops (dot/conv/...): read all operands + write result
                #  * dynamic-update-slice: in-place on TPU (donation/aliasing)
                #    -> traffic = update bytes read + written, NOT the buffer
                #  * everything else materializing: write + one downstream read
                #    (2 x result) — elementwise chains fuse on TPU, so operand
                #    reads are not separately charged
                rb = _nbytes(ins.result_type)
                if ins.opcode in _STREAMING:
                    b = rb
                    for op in ins.operands:
                        t = comp.result_type_of(op)
                        if t:
                            b += _nbytes(t)
                    # TPU-dtype correction (see _collective_wire_bytes):
                    # CPU upcasts semantically-bf16 dots to f32
                    if (
                        ins.opcode == "dot"
                        and "f32[" in ins.result_type
                        and "/dot_general" in ins.raw
                        and not any(t_ in ins.raw for t_ in ("bqkrd", "bkrqs", "dv->bsv", "de->te"))
                    ):
                        b *= 0.5
                elif ins.opcode == "dynamic-update-slice":
                    upd = comp.result_type_of(ins.operands[1]) if len(ins.operands) > 1 else None
                    b = 2.0 * _nbytes(upd) if upd else rb
                elif ins.opcode == "fusion":
                    b = 2.0 * _fusion_bytes(ins, comps, rb)
                else:
                    b = 2.0 * rb
                hbm_bytes += k * b
                if detail:
                    bytes_by_op[ins.opcode] = bytes_by_op.get(ins.opcode, 0.0) + k * b

    out = {
        "flops": flops,
        "hbm_bytes": hbm_bytes,
        "collective_wire_bytes": coll_bytes,
        "collective_by_kind": coll_by_kind,
        "collective_counts": coll_count,
        "num_computations": len(comps),
    }
    if detail:
        out["bytes_by_op"] = dict(sorted(bytes_by_op.items(), key=lambda kv: -kv[1]))
    return out
