"""Per-(arch x shape) distribution recipes.

A ``CellPlan`` fixes everything the launcher needs: logical->mesh rules,
remat policy, attention q-block, microbatch count, cache dtype.  Rules are
*best-effort*: the shape-aware resolver in ``sharding.spec_for`` drops any
rule that does not divide the concrete dim, so a single rule set covers
heterogeneous archs (e.g. starcoder2's 36 heads fall back to FSDP-only
attention sharding — recorded in EXPERIMENTS.md).

Decode KV-cache strategy (probe-driven, DESIGN.md §5):
  * kv_heads divides the model axis -> shard cache on kv_heads;
  * otherwise shard cache on *seq* over model (flash-decoding style:
    XLA all-reduces the softmax statistics across seq shards).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

from repro.configs.base import ArchConfig, ShapeConfig
from repro.distribution.sharding import make_rules

MODEL_AXIS = 16


@dataclass(frozen=True)
class CellPlan:
    arch: str
    shape: str
    kind: str
    rules: dict
    remat: str = "none"
    q_block: Optional[int] = 512
    num_microbatches: int = 1
    cache_dtype: str = "bfloat16"
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    moe_groups: Optional[int] = None  # None = keep the config's value


def _moe_overrides(cfg: ArchConfig) -> dict:
    if cfg.moe is None:
        return {}
    if cfg.moe.strategy == "ep":
        return {
            "experts": "model",
            "expert_mlp": None,
            "p_experts": "model",
            "p_expert_mlp": None,
        }
    return {  # TP-MoE: slice every expert's d_ff; tokens never move
        "experts": None,
        "expert_mlp": "model",
        "p_experts": None,
        "p_expert_mlp": "model",
    }


def plan_for(cfg: ArchConfig, shape: ShapeConfig, *, multi_pod: bool = False) -> CellPlan:
    kind = shape.kind
    rules = make_rules(kind, multi_pod=multi_pod)
    rules.update(_moe_overrides(cfg))

    n_params = cfg.param_count()

    if kind == "train":
        # full remat everywhere: without it the q-block attention scan saves
        # the (B,H,S,S) softmax weights for backward (probe: 107 GB/dev on
        # olmo-1b) — recompute is the production policy at these sizes.
        remat = "full" if n_params > 5e8 else "dots"
        micro = 4 if n_params > 3e10 else (2 if n_params > 5e9 else 1)
        q_block = 512 if shape.seq_len > 2048 else None
    else:
        remat = "none"
        micro = 1
        q_block = 512 if (kind == "prefill" and shape.seq_len > 2048) else None

    if kind == "decode":
        if cfg.num_kv_heads and cfg.num_kv_heads % MODEL_AXIS == 0:
            rules["seq"] = None  # cache shards on kv_heads
        else:
            # flash-decoding: shard cache seq over model; kv_heads replicate
            rules["kv_heads"] = None
            rules["seq"] = "model"
        if shape.global_batch == 1:
            # long_500k: nothing to shard over data from the batch; put the
            # cache seq dim over (data, model) so the 524k KV/state fits
            rules["seq"] = ("data", "model")
            rules["batch"] = None

    # prefill activations: shard seq over data? keep batch over data (>=16)
    return CellPlan(
        arch=cfg.name,
        shape=shape.name,
        kind=kind,
        rules=rules,
        remat=remat,
        q_block=q_block,
        num_microbatches=micro,
    )
