"""Logical-axis sharding: MaxText-style rules mapping logical tensor axes
("batch", "seq", "embed", "heads", "mlp", "experts", ...) to mesh axes.

Model code annotates activations with ``constrain(x, "batch", "seq",
"embed")``; the distribution layer activates a rule set + mesh via
``axis_rules``.  Outside any rule context the annotations are no-ops, so
the same model runs unsharded on one CPU device.
"""
from __future__ import annotations

import threading
from contextlib import contextmanager
from typing import Any, Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "axis_rules",
    "constrain",
    "current_mesh",
    "current_rules",
    "spec_for",
    "sharding_for",
    "tree_sharding",
    "RULE_SETS",
]

_state = threading.local()


def _get() -> "tuple[Optional[dict], Optional[Mesh]]":
    return getattr(_state, "rules", None), getattr(_state, "mesh", None)


@contextmanager
def axis_rules(rules: "dict[str, Any]", mesh: "Mesh | None" = None):
    """Activate logical->mesh rules (and optionally a mesh) for this thread."""
    old = _get()
    _state.rules, _state.mesh = rules, mesh
    try:
        yield
    finally:
        _state.rules, _state.mesh = old


def current_rules() -> "Optional[dict]":
    return _get()[0]


def current_mesh() -> "Optional[Mesh]":
    return _get()[1]


def _axis_sizes(mesh: "Mesh | None") -> dict:
    if mesh is None:
        return {}
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def spec_for(
    names: "Sequence[Optional[str]]",
    rules: "dict | None" = None,
    shape: "Sequence[int] | None" = None,
    mesh: "Mesh | None" = None,
) -> P:
    """Translate logical axis names to a PartitionSpec under ``rules``.

    A rule value may be a mesh-axis name, a tuple of mesh axes, or None.
    Unknown logical names map to None (replicated along that dim).

    With ``shape``+``mesh``, rules that do not divide the dimension evenly
    are dropped (jit boundary shardings must divide — probe finding), as
    are rules reusing a mesh axis already consumed by an earlier dim.
    """
    if rules is None:
        rules = current_rules() or {}
    sizes = _axis_sizes(mesh)
    parts = []
    used: set = set()
    for i, n in enumerate(names):
        r = rules.get(n) if n is not None else None
        if r is not None:
            axes = (r,) if isinstance(r, str) else tuple(r)
            if any(a in used for a in axes):
                r = None
            elif shape is not None and sizes:
                total = 1
                for a in axes:
                    total *= sizes.get(a, 1)
                if shape[i] % total != 0:
                    r = None
            if r is not None:
                used.update(axes)
        parts.append(r)
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def constrain(x, *names: "Optional[str]"):
    """Annotate ``x`` with the sharding implied by logical ``names``."""
    rules, mesh = _get()
    if rules is None:
        return x
    spec = spec_for(names, rules, shape=x.shape, mesh=mesh)
    if mesh is not None:
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
    return jax.lax.with_sharding_constraint(x, spec)


def sharding_for(
    mesh: Mesh, names: "Sequence[Optional[str]]", rules: dict, shape: "Sequence[int] | None" = None
) -> NamedSharding:
    return NamedSharding(mesh, spec_for(names, rules, shape=shape, mesh=mesh))


def _is_names(x) -> bool:
    return isinstance(x, tuple) and all(isinstance(n, (str, type(None))) for n in x)


def tree_sharding(mesh: Mesh, logical_tree, rules: dict, shape_tree=None):
    """Map a pytree of logical-name tuples to NamedShardings.

    ``shape_tree``: matching pytree of ShapeDtypeStructs/arrays enabling
    divisibility-aware rule resolution (required at jit boundaries).
    """
    if shape_tree is None:
        return jax.tree.map(
            lambda names: sharding_for(mesh, names, rules), logical_tree, is_leaf=_is_names
        )
    flat_names = jax.tree.leaves(logical_tree, is_leaf=_is_names)
    flat_shapes, tdef = jax.tree.flatten(shape_tree)
    assert len(flat_names) == len(flat_shapes), (len(flat_names), len(flat_shapes))
    out = [
        sharding_for(mesh, n, rules, shape=s.shape) for n, s in zip(flat_names, flat_shapes)
    ]
    return tdef.unflatten(out)


# ---------------------------------------------------------------------------
# Rule sets. Mesh axes: ("pod", "data", "model") or ("data", "model").
# "pod" composes with "data" for batch/FSDP sharding; the cross-pod
# all-reduce is the only DCI traffic (DESIGN.md §5).
# ---------------------------------------------------------------------------

def _dp(multi_pod: bool):
    return ("pod", "data") if multi_pod else ("data",)


def make_rules(kind: str, *, multi_pod: bool = False, fsdp: bool = True) -> "dict[str, Any]":
    """Build the logical->mesh rule set for a shape kind.

    kind="train":  batch over (pod,)data; TP over model for heads/mlp/experts;
                   FSDP: the non-TP param dim shards over (pod,)data.
    kind="prefill"/"decode": batch over (pod,)data, TP over model; params
                   replicated over data (weight-stationary serving) unless
                   fsdp=True is forced.
    """
    dp = _dp(multi_pod)
    rules: "dict[str, Any]" = {
        # activations
        "batch": dp,
        "seq": None,
        "embed": None,
        "heads": "model",
        "kv_heads": "model",
        "head_dim": None,
        "mlp": "model",
        "experts": "model",
        "exp_groups": dp,  # grouped MoE dispatch: groups follow the data axis
        "vocab": "model",
        "ssm_inner": "model",
        "ssm_heads": "model",
        "ssm_state": None,
        "frames": None,
        # params (TP dim = model; FSDP dim = data)
        "p_embed": dp if (fsdp and kind == "train") else None,
        "p_vocab": "model",
        "p_heads": "model",
        "p_kv_heads": "model",
        "p_mlp": "model",
        "p_experts": "model",
        "p_expert_mlp": None,
        "p_ssm_inner": "model",
        "p_ssm_heads": "model",
        "p_none": None,
        "layers": None,
    }
    if kind != "train":
        # serving: keep params TP-sharded; no FSDP gather in the hot loop
        rules["p_embed"] = None
    return rules


RULE_SETS = {"train": make_rules, "prefill": make_rules, "decode": make_rules}
