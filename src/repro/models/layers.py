"""Shared model building blocks (pure JAX, functional).

Conventions
-----------
* Activations are ``(batch, seq, ...)``; params are plain dicts of arrays.
* Matmuls accumulate in fp32 (``preferred_element_type``), softmax in fp32.
* ``constrain`` tags logical shardings; no-ops outside a rules context.
* Attention never materializes the full (Sq, Skv) score matrix for long
  sequences: queries are processed in blocks via ``lax.scan`` (exact, not
  online-softmax — each block sees all keys).  The Pallas flash kernel
  (``repro.kernels.flash_attention``) is the TPU-target replacement; the
  chunked path is the XLA-lowerable baseline used by the dry-run.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.distribution.sharding import constrain

F32 = jnp.float32
NEG_INF = -1e30


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------

def ninit(key, shape, scale: "float | None" = None, dtype=jnp.float32):
    """Truncated-normal init, fan-in scaled by default."""
    if scale is None:
        fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
        scale = 1.0 / math.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rmsnorm(x, w, eps: float):
    xf = x.astype(F32)
    y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (y * w.astype(F32)).astype(x.dtype)


def layernorm(x, w, b, eps: float):
    xf = x.astype(F32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    if w is not None:
        y = y * w.astype(F32)
    if b is not None:
        y = y + b.astype(F32)
    return y.astype(x.dtype)


def apply_norm(cfg, x, p):
    """Dispatch on cfg.norm_type; ``p`` is the layer's norm param dict."""
    if cfg.norm_type == "rmsnorm":
        return rmsnorm(x, p["scale"], cfg.norm_eps)
    if cfg.norm_type == "layernorm":
        return layernorm(x, p["scale"], p["bias"], cfg.norm_eps)
    if cfg.norm_type == "layernorm_nobias":
        return layernorm(x, p["scale"], None, cfg.norm_eps)
    if cfg.norm_type == "nonparam_layernorm":  # olmo
        return layernorm(x, None, None, cfg.norm_eps)
    raise ValueError(cfg.norm_type)


def init_norm(cfg, key, dtype):
    if cfg.norm_type == "rmsnorm" or cfg.norm_type == "layernorm_nobias":
        return {"scale": jnp.ones((cfg.d_model,), dtype)}
    if cfg.norm_type == "layernorm":
        return {"scale": jnp.ones((cfg.d_model,), dtype), "bias": jnp.zeros((cfg.d_model,), dtype)}
    return {}  # nonparam


def norm_specs(cfg):
    if cfg.norm_type in ("rmsnorm", "layernorm_nobias"):
        return {"scale": ("p_none",)}
    if cfg.norm_type == "layernorm":
        return {"scale": ("p_none",), "bias": ("p_none",)}
    return {}


# ---------------------------------------------------------------------------
# rotary embeddings (RoPE / partial RoPE / M-RoPE)
# ---------------------------------------------------------------------------

def rope_angles(positions, rotary_dim: int, theta: float, sections=()):
    """positions: (B, S) int — or (3, B, S) for M-RoPE (t, h, w streams).

    Returns (cos, sin) of shape (B, S, rotary_dim) using the rotate-half
    convention (angles duplicated across the two halves).
    """
    half = rotary_dim // 2
    inv_freq = 1.0 / (theta ** (jnp.arange(0, half, dtype=F32) / half))
    if sections:
        # M-RoPE: head-dim frequency bands split between t/h/w position ids
        assert positions.ndim == 3, "mrope needs (3, B, S) positions"
        assert sum(sections) == half, (sections, half)
        parts = []
        start = 0
        for i, sec in enumerate(sections):
            f = positions[i].astype(F32)[..., None] * inv_freq[start : start + sec]
            parts.append(f)
            start += sec
        freqs = jnp.concatenate(parts, axis=-1)  # (B, S, half)
    else:
        freqs = positions.astype(F32)[..., None] * inv_freq  # (B, S, half)
    emb = jnp.concatenate([freqs, freqs], axis=-1)
    return jnp.cos(emb), jnp.sin(emb)


def _rotate_half(x):
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([-x2, x1], axis=-1)


def apply_rope(x, cos, sin):
    """x: (B, S, H, D_rot_or_more); rotates the first cos.shape[-1] dims."""
    rot = cos.shape[-1]
    xr, xp = x[..., :rot], x[..., rot:]
    c = cos[:, :, None, :].astype(F32)
    s = sin[:, :, None, :].astype(F32)
    xf = xr.astype(F32)
    out = (xf * c + _rotate_half(xf) * s).astype(x.dtype)
    if xp.shape[-1]:
        out = jnp.concatenate([out, xp], axis=-1)
    return out


# ---------------------------------------------------------------------------
# attention core
# ---------------------------------------------------------------------------

def _block_attend(q, k, v, qpos, kpos, *, causal, window, softcap, valid_len=None):
    """q: (B, Sq, K, R, D); k/v: (B, Skv, K, D); qpos: (Sq,); kpos: (Skv,).

    Returns (B, Sq, K, R, D). Scores/softmax in fp32.  ``valid_len`` may be
    a scalar (one cache fill level for the whole batch) or a ``(B,)`` array
    (ragged paged decode: each row attends over its own prefix).  The
    scalar path's op sequence is unchanged by the batched branch.
    """
    scale = 1.0 / math.sqrt(q.shape[-1])
    s = jnp.einsum("bqkrd,bskd->bkrqs", q, k, preferred_element_type=F32) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    mask = jnp.ones((qpos.shape[0], kpos.shape[0]), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window is not None:
        mask &= kpos[None, :] > (qpos[:, None] - window)
    if valid_len is not None and getattr(valid_len, "ndim", 0) == 1:
        mask_b = mask[None] & (kpos[None, None, :] < valid_len[:, None, None])
        s = jnp.where(mask_b[:, None, None], s, NEG_INF)
    else:
        if valid_len is not None:
            mask &= kpos[None, :] < valid_len
        s = jnp.where(mask[None, None, None], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    return jnp.einsum("bkrqs,bskd->bqkrd", w, v)


def attention(
    q,
    k,
    v,
    *,
    causal: bool = True,
    window: "Optional[int]" = None,
    q_offset=0,
    softcap: "Optional[float]" = None,
    q_block: "Optional[int]" = None,
    valid_len=None,
    kpos=None,
):
    """GQA attention. q: (B, Sq, H, D); k/v: (B, Skv, K, D); H % K == 0.

    ``q_block``: process queries in blocks of this size via lax.scan so the
    peak score tensor is (B, H, q_block, Skv) — required for 32k+ prefill.
    ``valid_len``: number of valid cache slots (decode) — scalar, or a
    ``(B,)`` array for ragged per-row prefixes (paged decode); ``kpos``:
    explicit key positions (defaults to arange(Skv)).
    """
    B, Sq, H, D = q.shape
    K = k.shape[2]
    R = H // K
    qr = q.reshape(B, Sq, K, R, D)
    if kpos is None:
        kpos = jnp.arange(k.shape[1])
    qpos_all = q_offset + jnp.arange(Sq)

    if q_block is None or Sq <= q_block:
        o = _block_attend(
            qr, k, v, qpos_all, kpos, causal=causal, window=window, softcap=softcap, valid_len=valid_len
        )
        return o.reshape(B, Sq, H, D)

    pad = (-Sq) % q_block
    if pad:  # tail-pad queries (outputs sliced off below; keys unaffected)
        qr = jnp.pad(qr, ((0, 0), (0, pad), (0, 0), (0, 0), (0, 0)))
        qpos_all = jnp.concatenate([qpos_all, qpos_all[-1] + 1 + jnp.arange(pad)])
    Sp = Sq + pad
    nb = Sp // q_block
    qs = qr.reshape(B, nb, q_block, K, R, D).swapaxes(0, 1)  # (nb, B, qb, K, R, D)
    ps = qpos_all.reshape(nb, q_block)

    def step(_, xs):
        qb, pb = xs
        o = _block_attend(qb, k, v, pb, kpos, causal=causal, window=window, softcap=softcap, valid_len=valid_len)
        return None, o

    _, os = jax.lax.scan(step, None, (qs, ps))
    return os.swapaxes(0, 1).reshape(B, Sp, H, D)[:, :Sq]


def local_block_attention(q, k, v, *, window: int, q_offset=0):
    """Sliding-window attention in O(S·window): queries in blocks of
    ``window`` attend to their own and the previous key block only.

    Exact for window-limited causal attention when Sq == Skv and
    Sq % window == 0 (pad upstream otherwise).
    """
    B, S, H, D = q.shape
    K = k.shape[2]
    R = H // K
    assert S % window == 0, (S, window)
    nb = S // window
    qr = q.reshape(B, nb, window, K, R, D).swapaxes(0, 1)
    kr = k.reshape(B, nb, window, K, D).swapaxes(0, 1)
    vr = v.reshape(B, nb, window, K, D).swapaxes(0, 1)
    kprev = jnp.concatenate([jnp.zeros_like(kr[:1]), kr[:-1]], axis=0)
    vprev = jnp.concatenate([jnp.zeros_like(vr[:1]), vr[:-1]], axis=0)

    def step(_, xs):
        i, qb, kb, vb, kp, vp = xs
        kk = jnp.concatenate([kp, kb], axis=1)  # (B, 2w, K, D)
        vv = jnp.concatenate([vp, vb], axis=1)
        qpos = i * window + jnp.arange(window)
        kpos = (i - 1) * window + jnp.arange(2 * window)
        o = _block_attend(qb, kk, vv, qpos, kpos, causal=True, window=window, softcap=None)
        return None, o

    idx = jnp.arange(nb)
    _, os = jax.lax.scan(step, None, (idx, qr, kr, vr, kprev, vprev))
    return os.swapaxes(0, 1).reshape(B, S, H, D)


# ---------------------------------------------------------------------------
# attention block (projections + rope + core) and its params
# ---------------------------------------------------------------------------

def init_attn(cfg, key, dtype):
    ks = jax.random.split(key, 4)
    d, H, K, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.hd
    p = {
        "wq": ninit(ks[0], (d, H * hd), dtype=dtype),
        "wk": ninit(ks[1], (d, K * hd), dtype=dtype),
        "wv": ninit(ks[2], (d, K * hd), dtype=dtype),
        "wo": ninit(ks[3], (H * hd, d), scale=1.0 / math.sqrt(H * hd), dtype=dtype),
    }
    if cfg.attn_qkv_bias:
        p["bq"] = jnp.zeros((H * hd,), dtype)
        p["bk"] = jnp.zeros((K * hd,), dtype)
        p["bv"] = jnp.zeros((K * hd,), dtype)
    if cfg.attn_out_bias:
        p["bo"] = jnp.zeros((cfg.d_model,), dtype)
    return p


def attn_specs(cfg):
    s = {
        "wq": ("p_embed", "p_heads"),
        "wk": ("p_embed", "p_kv_heads"),
        "wv": ("p_embed", "p_kv_heads"),
        "wo": ("p_heads", "p_embed"),
    }
    if cfg.attn_qkv_bias:
        s.update({"bq": ("p_heads",), "bk": ("p_kv_heads",), "bv": ("p_kv_heads",)})
    if cfg.attn_out_bias:
        s["bo"] = ("p_none",)
    return s


def qkv_proj(cfg, p, x):
    """x: (B, S, D) -> q (B,S,H,hd), k/v (B,S,K,hd), sharded on heads.

    preferred_element_type follows the activation dtype: the MXU still
    accumulates bf16 inputs in f32 internally, while keeping the *stored*
    value and — critically — the BACKWARD cotangents in bf16 (an
    accumulate-f32-then-cast pattern would upcast the whole backward pass
    to f32 through the astype transpose; §Perf "bf16-cotangent").
    """
    B, S, _ = x.shape
    H, K, hd = cfg.num_heads, cfg.num_kv_heads, cfg.hd
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"], preferred_element_type=x.dtype)
    k = jnp.einsum("bsd,dh->bsh", x, p["wk"], preferred_element_type=x.dtype)
    v = jnp.einsum("bsd,dh->bsh", x, p["wv"], preferred_element_type=x.dtype)
    if cfg.attn_qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = constrain(q.reshape(B, S, H, hd), "batch", "seq", "heads", "head_dim")
    k = constrain(k.reshape(B, S, K, hd), "batch", "seq", "kv_heads", "head_dim")
    v = constrain(v.reshape(B, S, K, hd), "batch", "seq", "kv_heads", "head_dim")
    return q, k, v


def out_proj(cfg, p, o):
    # row-parallel matmul: the contraction dim is TP-sharded, so the output
    # is all-reduced — accumulate in the activation dtype (bf16) so the
    # collective runs at half width (fp32 partial sums would double wire
    # bytes; EXPERIMENTS.md §Perf "bf16-psum").
    B, S = o.shape[:2]
    y = jnp.einsum(
        "bsh,hd->bsd", o.reshape(B, S, -1), p["wo"], preferred_element_type=o.dtype
    )
    if cfg.attn_out_bias:
        y = y + p["bo"]
    return constrain(y, "batch", "seq", "embed")


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def init_mlp(cfg, key, dtype, d_ff: "Optional[int]" = None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.mlp_type in ("swiglu", "geglu"):
        p = {
            "wi_gate": ninit(ks[0], (d, f), dtype=dtype),
            "wi_up": ninit(ks[1], (d, f), dtype=dtype),
            "wo": ninit(ks[2], (f, d), dtype=dtype),
        }
        if cfg.mlp_bias:
            p["bi_gate"] = jnp.zeros((f,), dtype)
            p["bi_up"] = jnp.zeros((f,), dtype)
            p["bo"] = jnp.zeros((d,), dtype)
        return p
    p = {"wi": ninit(ks[0], (d, f), dtype=dtype), "wo": ninit(ks[2], (f, d), dtype=dtype)}
    if cfg.mlp_bias:
        p["bi"] = jnp.zeros((f,), dtype)
        p["bo"] = jnp.zeros((d,), dtype)
    return p


def mlp_specs(cfg):
    if cfg.mlp_type in ("swiglu", "geglu"):
        s = {"wi_gate": ("p_embed", "p_mlp"), "wi_up": ("p_embed", "p_mlp"), "wo": ("p_mlp", "p_embed")}
        if cfg.mlp_bias:
            s.update({"bi_gate": ("p_mlp",), "bi_up": ("p_mlp",), "bo": ("p_none",)})
        return s
    s = {"wi": ("p_embed", "p_mlp"), "wo": ("p_mlp", "p_embed")}
    if cfg.mlp_bias:
        s.update({"bi": ("p_mlp",), "bo": ("p_none",)})
    return s


def mlp(cfg, p, x):
    if cfg.mlp_type in ("swiglu", "geglu"):
        g = jnp.einsum("bsd,df->bsf", x, p["wi_gate"], preferred_element_type=x.dtype)
        u = jnp.einsum("bsd,df->bsf", x, p["wi_up"], preferred_element_type=x.dtype)
        if cfg.mlp_bias:
            g, u = g + p["bi_gate"], u + p["bi_up"]
        g = constrain(g, "batch", "seq", "mlp")
        u = constrain(u, "batch", "seq", "mlp")
        act = jax.nn.silu(g) if cfg.mlp_type == "swiglu" else jax.nn.gelu(g)
        h = act * u
    else:
        h = jnp.einsum("bsd,df->bsf", x, p["wi"], preferred_element_type=x.dtype)
        if cfg.mlp_bias:
            h = h + p["bi"]
        h = constrain(h, "batch", "seq", "mlp")
        h = jax.nn.gelu(h)
    # row-parallel: bf16 partial sums -> half-width TP all-reduce
    y = jnp.einsum("bsf,fd->bsd", h, p["wo"], preferred_element_type=x.dtype)
    if cfg.mlp_bias:
        y = y + p["bo"]
    return constrain(y, "batch", "seq", "embed")


# ---------------------------------------------------------------------------
# embeddings / unembedding
# ---------------------------------------------------------------------------

def init_embed(cfg, key, dtype):
    p = {"table": ninit(key, (cfg.vocab_size, cfg.d_model), scale=0.02, dtype=dtype)}
    if not cfg.tie_embeddings:
        p["unembed"] = ninit(
            jax.random.fold_in(key, 1), (cfg.d_model, cfg.vocab_size), dtype=dtype
        )
    return p


def embed_specs(cfg):
    s = {"table": ("p_vocab", "p_embed")}
    if not cfg.tie_embeddings:
        s["unembed"] = ("p_embed", "p_vocab")
    return s


def embed(cfg, p, tokens):
    e = jnp.take(p["table"], tokens, axis=0)
    return constrain(e, "batch", "seq", "embed")


def unembed(cfg, p, x):
    w = p["table"].T if cfg.tie_embeddings else p["unembed"]
    logits = jnp.einsum("bsd,dv->bsv", x, w, preferred_element_type=F32)
    return constrain(logits, "batch", "seq", "vocab")


# ---------------------------------------------------------------------------
# KV cache helpers (contiguous per-layer cache, ring buffer for SWA)
# ---------------------------------------------------------------------------

def xent_loss(logits, labels, mask=None):
    """Mean next-token cross-entropy in fp32."""
    logits = logits.astype(F32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


def cache_update(ck, cv, k_new, v_new, pos, *, ring: "Optional[int]" = None):
    """Insert (B, s, K, D) new keys/values at ``pos``; returns updated cache.

    ``ring``: sliding-window ring-buffer length (slot = pos % ring).
    """
    slot = pos if ring is None else pos % ring
    ck = jax.lax.dynamic_update_slice(ck, k_new.astype(ck.dtype), (0, slot, 0, 0))
    cv = jax.lax.dynamic_update_slice(cv, v_new.astype(cv.dtype), (0, slot, 0, 0))
    return ck, cv


def decode_attend(cfg, q, ck, cv, pos, *, window: "Optional[int]" = None):
    """One-token attention against a cache. q: (B, 1, H, D); cache (B, S, K, D).

    For ring-buffer (window) caches every resident entry is in-window and in
    the past, so masking reduces to slot-validity.
    """
    if window is None:
        return attention(q, ck, cv, causal=True, q_offset=pos, valid_len=pos + 1)
    ring = ck.shape[1]
    valid = jnp.minimum(pos + 1, ring)
    return attention(q, ck, cv, causal=False, valid_len=valid)


# ---------------------------------------------------------------------------
# paged KV helpers (DESIGN.md §17): the model side of the paging contract
# ---------------------------------------------------------------------------

def page_scatter(kp, vp, k_new, v_new, tables, positions):
    """Scatter one decode-step token per row into a page slab.

    kp/vp: (N, P, K, D) pool slabs for ONE layer; k_new/v_new: (B, 1, K, D);
    tables: (B, M) page tables; positions: (B,) — the token's slot, i.e.
    the row's current length (token ``t`` lives at
    ``pages[table[b, t // P], t % P]``, the kernel-layer layout contract).
    """
    P = kp.shape[1]
    page = jnp.take_along_axis(tables, positions[:, None] // P, axis=1)[:, 0]
    slot = positions % P
    kp = kp.at[page, slot].set(k_new[:, 0].astype(kp.dtype))
    vp = vp.at[page, slot].set(v_new[:, 0].astype(vp.dtype))
    return kp, vp


def page_gather(pages, tables):
    """(N, P, K, D) slab + (B, M) table -> (B, M*P, K, D) contiguous cache.

    Position order: slot ``t`` of the result is token ``t`` of the row, so
    the gathered cache is drop-in for ``decode_attend``'s contiguous cache
    — bit-for-bit, stale slots past ``length`` included (they are masked
    to exact-zero weight downstream).
    """
    N, P, K, D = pages.shape
    B, M = tables.shape
    return pages[tables].reshape(B, M * P, K, D)


def paged_decode_attend(q, kp, vp, tables, lengths):
    """One-token GQA attention against paged KV, bit-equal to the padded
    ``decode_attend(..., pos)`` oracle when each row's ``pos == lengths[b]``
    and the oracle cache width equals ``tables.shape[1] * P``.

    q: (B, 1, H, D); kp/vp: (N, P, K, D); lengths: (B,) tokens already
    resident EXCLUDING the one scattered this step (so rows attend over
    ``lengths + 1`` slots — the fig9 toy's contract).
    """
    kc = page_gather(kp, tables)
    vc = page_gather(vp, tables)
    return attention(q, kc, vc, causal=False, valid_len=lengths + 1)


def ring_gather(pages, tables, positions, ring: int):
    """Reconstruct a sliding-window ring cache (B, ring, K, D) from paged
    full-history KV.

    Slot ``s`` of a ring cache written via ``cache_update(..., ring=ring)``
    holds the newest token whose absolute position ``p`` satisfies
    ``p % ring == s`` and ``p <= pos``; that is
    ``p = pos - ((pos - s) % ring)``.  Negative ``p`` (slot not yet
    written) is clamped to 0 — those slots are masked by the caller's
    ``valid_len=min(pos+1, ring)`` exactly as the oracle masks its
    zero-initialized slots, and masked lanes contribute exact 0.0 either
    way.
    """
    N, P, K, D = pages.shape
    s = jnp.arange(ring)
    p = positions[:, None] - ((positions[:, None] - s[None, :]) % ring)  # (B, ring)
    p = jnp.maximum(p, 0)
    page = jnp.take_along_axis(tables, p // P, axis=1)  # (B, ring)
    return pages[page, p % P]  # (B, ring, K, D)


def paged_ring_attend(q, kp, vp, tables, positions, *, ring: int):
    """Sliding-window one-token attention against paged KV: gather the
    ring layout the oracle's ring cache would hold at ``pos = positions``
    (new token already scattered), then run the same windowed attend —
    bit-equal to ``decode_attend(..., window=w)`` per row."""
    kc = ring_gather(kp, tables, positions, ring)
    vc = ring_gather(vp, tables, positions, ring)
    valid = jnp.minimum(positions + 1, ring)
    return attention(q, kc, vc, causal=False, valid_len=valid)
