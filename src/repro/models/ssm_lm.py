"""Mamba-2 language model (attention-free): embed -> scan(norm+SSD) -> lm head."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import ssm as S


def init(cfg, key, dtype=jnp.float32):
    kE, kL, kF = jax.random.split(key, 3)
    layer_keys = jax.random.split(kL, cfg.num_layers)

    def layer(k):
        k1, k2 = jax.random.split(k)
        return {"ln": L.init_norm(cfg, k1, dtype), "ssm": S.init_ssm(cfg, k2, dtype)}

    return {
        "embed": L.init_embed(cfg, kE, dtype),
        "layers": jax.vmap(layer)(layer_keys),
        "final_norm": L.init_norm(cfg, kF, dtype),
    }


def param_specs(cfg):
    layer = {"ln": L.norm_specs(cfg), "ssm": S.ssm_specs(cfg)}
    stacked = jax.tree.map(
        lambda names: ("layers",) + names,
        layer,
        is_leaf=lambda x: isinstance(x, tuple) and all(isinstance(n, (str, type(None))) for n in x),
    )
    return {"embed": L.embed_specs(cfg), "layers": stacked, "final_norm": L.norm_specs(cfg)}


def forward(cfg, params, batch, *, remat: str = "none", q_block=None, return_kv: bool = False, last_only: bool = False):
    x = L.embed(cfg, params["embed"], batch["tokens"])

    def body(x, lp):
        h = L.apply_norm(cfg, x, lp["ln"])
        return x + S.ssm_block(cfg, lp["ssm"], h), jnp.zeros((), jnp.float32)

    if remat == "full":
        body = jax.checkpoint(body)
    elif remat == "dots":
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)

    x, _ = jax.lax.scan(body, x, params["layers"])
    x = L.apply_norm(cfg, x, params["final_norm"])
    if last_only:
        x = x[:, -1:]
    logits = L.unembed(cfg, params["embed"], x)
    aux = jnp.zeros((), jnp.float32)
    if return_kv:
        return logits, aux, init_cache(cfg, x.shape[0], 0)
    return logits, aux


def loss_fn(cfg, params, batch, **kw):
    logits, _ = forward(cfg, params, batch, **kw)
    return L.xent_loss(logits, batch["labels"], batch.get("loss_mask"))


def init_cache(cfg, batch: int, max_seq: int, dtype=jnp.bfloat16):
    """SSM decode cache: per-layer recurrent state + conv window (no KV)."""
    one = S.init_ssm_cache(cfg, batch, dtype)
    return jax.tree.map(lambda t: jnp.broadcast_to(t, (cfg.num_layers,) + t.shape).copy(), one)


def cache_specs(cfg):
    return {
        "state": ("layers", "batch", "ssm_heads", "ssm_state", None),
        "conv": ("layers", "batch", None, "ssm_inner"),
    }


def decode_step(cfg, params, cache, tokens, pos, *, positions=None):
    x = L.embed(cfg, params["embed"], tokens)

    def body(x, xs):
        lp, st, cv = xs
        h = L.apply_norm(cfg, x, lp["ln"])
        y, new = S.ssm_decode_step(cfg, lp["ssm"], h, {"state": st, "conv": cv})
        return x + y, (new["state"], new["conv"])

    x, (states, convs) = jax.lax.scan(body, x, (params["layers"], cache["state"], cache["conv"]))
    x = L.apply_norm(cfg, x, params["final_norm"])
    logits = L.unembed(cfg, params["embed"], x)
    return logits, {"state": states, "conv": convs}


# ---------------------------------------------------------------------------
# paged serving contract (DESIGN.md §17)
# ---------------------------------------------------------------------------

def paged_spec(cfg):
    """Attention-free arch: a minimal 1x1 KV geometry keeps the engine's
    page machinery (tables, placement, defrag) uniform while the real
    memory — the recurrent state — rides as per-sequence resident state
    whose bytes the sequence's AGAS registration carries."""
    from repro.serving.paged import PageSpec

    return PageSpec(layers=1, page_size=0, kv_heads=1, head_dim=1, dtype=jnp.float32)


def paged_prefill(cfg, params, tokens, extras=None):
    """tokens: (B, T) -> (k, v, state, last_logits).

    k/v are zero dummies (nothing attends over them); ``state`` is the
    batch-leading {'state': (B, L, H, N, P), 'conv': (B, L, W-1, C)}
    recurrent cache the decode step threads.
    """
    x = L.embed(cfg, params["embed"], tokens)

    def body(x, lp):
        h = L.apply_norm(cfg, x, lp["ln"])
        y, cache = S.ssm_prefill(cfg, lp["ssm"], h)
        return x + y, (cache["state"], cache["conv"])

    x, (states, convs) = jax.lax.scan(body, x, params["layers"])
    x = L.apply_norm(cfg, x, params["final_norm"])
    logits = L.unembed(cfg, params["embed"], x[:, -1:])
    B, T = tokens.shape
    k = jnp.zeros((B, 1, T, 1, 1), jnp.float32)
    state = {"state": jnp.moveaxis(states, 0, 1), "conv": jnp.moveaxis(convs, 0, 1)}
    return k, k, state, logits[:, 0]


def paged_decode_step(cfg, params, k_pages, v_pages, state, tokens, positions, tables, lengths):
    """Pages pass through untouched; the recurrent state advances one
    token.  Position-free math, so ragged rows batch freely."""
    tokens = tokens.reshape(-1, 1)
    cache = {
        "state": jnp.moveaxis(state["state"], 0, 1),
        "conv": jnp.moveaxis(state["conv"], 0, 1),
    }
    logits, new = decode_step(cfg, params, cache, tokens, positions)
    state = {
        "state": jnp.moveaxis(new["state"], 0, 1),
        "conv": jnp.moveaxis(new["conv"], 0, 1),
    }
    return k_pages, v_pages, state, logits[:, 0]
