from repro.models.model import batch_logical_specs, get_model, input_specs, make_batch

__all__ = ["batch_logical_specs", "get_model", "input_specs", "make_batch"]
