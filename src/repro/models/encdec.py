"""Whisper-style encoder-decoder backbone [arXiv:2212.04356].

The conv/mel frontend is a STUB: the encoder consumes precomputed frame
embeddings (B, S_enc, D) supplied by ``input_specs()``; sinusoidal
positions are added here.  Decoder: learned positions, causal self-attn
with KV cache + cross-attn over encoder states (K/V precomputed at
prefill).  4+4 layers — unrolled loops (no scan needed).
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.models import layers as L


def _sinusoid(S, D):
    pos = np.arange(S)[:, None]
    dim = np.arange(D // 2)[None]
    inv = 1.0 / (10_000 ** (dim / max(D // 2 - 1, 1)))
    ang = pos * inv
    return jnp.asarray(np.concatenate([np.sin(ang), np.cos(ang)], axis=-1), jnp.float32)


def _init_block(cfg, key, dtype, cross: bool):
    ks = jax.random.split(key, 6)
    p = {
        "ln1": L.init_norm(cfg, ks[0], dtype),
        "attn": L.init_attn(cfg, ks[1], dtype),
        "ln2": L.init_norm(cfg, ks[2], dtype),
        "mlp": L.init_mlp(cfg, ks[3], dtype),
    }
    if cross:
        p["ln_x"] = L.init_norm(cfg, ks[4], dtype)
        p["xattn"] = L.init_attn(cfg, ks[5], dtype)
    return p


def _block_specs(cfg, cross: bool):
    s = {
        "ln1": L.norm_specs(cfg),
        "attn": L.attn_specs(cfg),
        "ln2": L.norm_specs(cfg),
        "mlp": L.mlp_specs(cfg),
    }
    if cross:
        s["ln_x"] = L.norm_specs(cfg)
        s["xattn"] = L.attn_specs(cfg)
    return s


def init(cfg, key, dtype=jnp.float32):
    e = cfg.encdec
    kE, kEnc, kDec, kP, kF1, kF2 = jax.random.split(key, 6)
    enc_keys = jax.random.split(kEnc, e.encoder_layers)
    dec_keys = jax.random.split(kDec, cfg.num_layers)
    return {
        "embed": L.init_embed(cfg, kE, dtype),
        "dec_pos": L.ninit(kP, (e.max_target_positions, cfg.d_model), scale=0.02, dtype=dtype),
        "enc_layers": [_init_block(cfg, k, dtype, cross=False) for k in enc_keys],
        "dec_layers": [_init_block(cfg, k, dtype, cross=True) for k in dec_keys],
        "enc_norm": L.init_norm(cfg, kF1, dtype),
        "final_norm": L.init_norm(cfg, kF2, dtype),
    }


def param_specs(cfg):
    e = cfg.encdec
    return {
        "embed": L.embed_specs(cfg),
        "dec_pos": ("p_none", "p_embed"),
        "enc_layers": [_block_specs(cfg, False) for _ in range(e.encoder_layers)],
        "dec_layers": [_block_specs(cfg, True) for _ in range(cfg.num_layers)],
        "enc_norm": L.norm_specs(cfg),
        "final_norm": L.norm_specs(cfg),
    }


def _self_block(cfg, lp, x, *, causal, q_block):
    h = L.apply_norm(cfg, x, lp["ln1"])
    q, k, v = L.qkv_proj(cfg, lp["attn"], h)
    o = L.attention(q, k, v, causal=causal, q_block=q_block)
    x = x + L.out_proj(cfg, lp["attn"], o)
    return x, (k, v)


def _cross(cfg, lp, x, ek, ev):
    h = L.apply_norm(cfg, x, lp["ln_x"])
    B, S, _ = h.shape
    H, hd = cfg.num_heads, cfg.hd
    q = jnp.einsum("bsd,dh->bsh", h, lp["xattn"]["wq"], preferred_element_type=h.dtype)
    if cfg.attn_qkv_bias:
        q = q + lp["xattn"]["bq"]
    o = L.attention(q.reshape(B, S, H, hd), ek, ev, causal=False)
    return x + L.out_proj(cfg, lp["xattn"], o)


def _mlp_block(cfg, lp, x):
    return x + L.mlp(cfg, lp["mlp"], L.apply_norm(cfg, x, lp["ln2"]))


def encode(cfg, params, frames, remat: str = "none"):
    """frames: (B, S_enc, D) stub embeddings -> encoder states."""
    x = frames + _sinusoid(frames.shape[1], cfg.d_model).astype(frames.dtype)

    def enc_layer(x, lp):
        x, _ = _self_block(cfg, lp, x, causal=False, q_block=None)
        return _mlp_block(cfg, lp, x)

    if remat in ("dots", "full"):
        enc_layer = jax.checkpoint(enc_layer)
    for lp in params["enc_layers"]:
        x = enc_layer(x, lp)
    return L.apply_norm(cfg, x, params["enc_norm"])


def _cross_kv(cfg, params, enc):
    """Precompute cross-attention K/V per decoder layer."""
    B, Se, _ = enc.shape
    K, hd = cfg.num_kv_heads, cfg.hd
    out = []
    for lp in params["dec_layers"]:
        k = jnp.einsum("bsd,dh->bsh", enc, lp["xattn"]["wk"], preferred_element_type=enc.dtype)
        v = jnp.einsum("bsd,dh->bsh", enc, lp["xattn"]["wv"], preferred_element_type=enc.dtype)
        if cfg.attn_qkv_bias:
            k, v = k + lp["xattn"]["bk"], v + lp["xattn"]["bv"]
        out.append((k.reshape(B, Se, K, hd), v.reshape(B, Se, K, hd)))
    return out


def forward(cfg, params, batch, *, q_block=512, remat: str = "none", return_kv: bool = False, last_only: bool = False):
    """batch: {'frames': (B, S_enc, D) stub, 'tokens': (B, S_dec)}."""
    enc = encode(cfg, params, batch["frames"], remat=remat)
    xkv = _cross_kv(cfg, params, enc)

    tokens = batch["tokens"]
    B, S = tokens.shape
    x = L.embed(cfg, params["embed"], tokens)
    pos_tab = params["dec_pos"]
    idx = jnp.arange(S) % pos_tab.shape[0]  # structural cells may exceed 448
    x = x + pos_tab[idx][None].astype(x.dtype)

    def dec_layer(x, lp, ek, ev):
        x, kv = _self_block(cfg, lp, x, causal=True, q_block=q_block)
        x = _cross(cfg, lp, x, ek, ev)
        x = _mlp_block(cfg, lp, x)
        return x, kv

    if remat in ("dots", "full"):
        dec_layer = jax.checkpoint(dec_layer, static_argnums=())

    kvs = []
    for lp, (ek, ev) in zip(params["dec_layers"], xkv):
        x, kv = dec_layer(x, lp, ek, ev)
        kvs.append(kv)

    x = L.apply_norm(cfg, x, params["final_norm"])
    if last_only:
        x = x[:, -1:]
    logits = L.unembed(cfg, params["embed"], x)
    aux = jnp.zeros((), jnp.float32)
    if return_kv:
        return logits, aux, {"self": kvs, "cross": xkv}
    return logits, aux


def loss_fn(cfg, params, batch, **kw):
    logits, _ = forward(cfg, params, batch, **kw)
    return L.xent_loss(logits, batch["labels"], batch.get("loss_mask"))


def init_cache(cfg, batch: int, max_seq: int, dtype=jnp.bfloat16):
    e = cfg.encdec
    Ld, K, hd = cfg.num_layers, cfg.num_kv_heads, cfg.hd
    return {
        "self_k": jnp.zeros((Ld, batch, max_seq, K, hd), dtype),
        "self_v": jnp.zeros((Ld, batch, max_seq, K, hd), dtype),
        "cross_k": jnp.zeros((Ld, batch, e.encoder_seq, K, hd), dtype),
        "cross_v": jnp.zeros((Ld, batch, e.encoder_seq, K, hd), dtype),
    }


def cache_specs(cfg):
    kv = ("layers", "batch", "seq", "kv_heads", "head_dim")
    return {"self_k": kv, "self_v": kv, "cross_k": kv, "cross_v": kv}


def decode_step(cfg, params, cache, tokens, pos, *, positions=None):
    """One decoder token against self-cache + precomputed cross K/V."""
    B = tokens.shape[0]
    x = L.embed(cfg, params["embed"], tokens)
    pos_tab = params["dec_pos"]
    x = x + pos_tab[pos % pos_tab.shape[0]][None, None].astype(x.dtype)

    cache = dict(cache)
    for i, lp in enumerate(params["dec_layers"]):
        h = L.apply_norm(cfg, x, lp["ln1"])
        q, k, v = L.qkv_proj(cfg, lp["attn"], h)
        ck, cv = L.cache_update(cache["self_k"][i], cache["self_v"][i], k, v, pos)
        cache["self_k"] = cache["self_k"].at[i].set(ck)
        cache["self_v"] = cache["self_v"].at[i].set(cv)
        o = L.decode_attend(cfg, q, ck, cv, pos)
        x = x + L.out_proj(cfg, lp["attn"], o)
        x = _cross(cfg, lp, x, cache["cross_k"][i], cache["cross_v"][i])
        x = _mlp_block(cfg, lp, x)

    x = L.apply_norm(cfg, x, params["final_norm"])
    logits = L.unembed(cfg, params["embed"], x)
    return logits, cache


# ---------------------------------------------------------------------------
# paged serving contract (DESIGN.md §17)
# ---------------------------------------------------------------------------

def paged_spec(cfg):
    """Decoder self-KV lives in pages; the fixed-size cross K/V (one
    entry per encoder frame, never grows) rides as per-sequence state."""
    from repro.serving.paged import PageSpec

    return PageSpec(
        layers=cfg.num_layers,
        page_size=0,
        kv_heads=cfg.num_kv_heads,
        head_dim=cfg.hd,
        dtype=jnp.float32,
    )


def paged_prefill(cfg, params, tokens, extras=None):
    """tokens: (B, T); extras['frames']: (B, S_enc, D) stub embeddings.

    Returns (k, v, state, last_logits): self-KV rows (B, L, T, K, hd)
    for the pages, cross K/V stacked batch-leading as resident state.
    """
    frames = extras["frames"]
    logits, _, kv = forward(
        cfg, params, {"frames": frames, "tokens": tokens},
        return_kv=True, last_only=True,
    )
    k = jnp.stack([kv_l[0] for kv_l in kv["self"]], axis=1)  # (B, L, T, K, hd)
    v = jnp.stack([kv_l[1] for kv_l in kv["self"]], axis=1)
    state = {
        "cross_k": jnp.stack([x[0] for x in kv["cross"]], axis=1),  # (B, L, Se, K, hd)
        "cross_v": jnp.stack([x[1] for x in kv["cross"]], axis=1),
    }
    return k, v, state, logits[:, -1]


def paged_decode_step(cfg, params, k_pages, v_pages, state, tokens, positions, tables, lengths):
    """One ragged decoder step: scatter self-KV into pages, attend over
    each row's own prefix, cross-attend the resident encoder K/V.
    Per-row math is op-for-op ``decode_step``'s."""
    tokens = tokens.reshape(-1, 1)
    x = L.embed(cfg, params["embed"], tokens)
    pos_tab = params["dec_pos"]
    x = x + pos_tab[positions % pos_tab.shape[0]][:, None].astype(x.dtype)

    for i, lp in enumerate(params["dec_layers"]):
        h = L.apply_norm(cfg, x, lp["ln1"])
        q, k, v = L.qkv_proj(cfg, lp["attn"], h)
        kp, vp = L.page_scatter(k_pages[i], v_pages[i], k, v, tables, positions)
        k_pages = k_pages.at[i].set(kp)
        v_pages = v_pages.at[i].set(vp)
        o = L.paged_decode_attend(q, kp, vp, tables, lengths)
        x = x + L.out_proj(cfg, lp["attn"], o)
        x = _cross(cfg, lp, x, state["cross_k"][:, i], state["cross_v"][:, i])
        x = _mlp_block(cfg, lp, x)

    x = L.apply_norm(cfg, x, params["final_norm"])
    logits = L.unembed(cfg, params["embed"], x)
    return k_pages, v_pages, state, logits[:, 0]
