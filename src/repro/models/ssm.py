"""Mamba-2 SSD (state-space duality) layer [arXiv:2405.21060].

Chunked SSD forward (paper §6): within-chunk "attention-like" diagonal
blocks + inter-chunk state recurrence via ``lax.scan``.  O(S·L) time,
O(S) memory for chunk length L.  The Pallas kernel
(``repro.kernels.ssd_scan``) is the TPU-target hot path; this module is
the XLA-lowerable reference used by the dry-run and smoke tests.

Param layout note: the reference fuses z/xBC/dt into one in_proj matrix;
we keep separate projections (identical math) so each shards cleanly
(DESIGN.md §5).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.distribution.sharding import constrain
from repro.models.layers import F32, ninit, rmsnorm


def _dims(cfg):
    s = cfg.ssm
    di = s.d_inner(cfg.d_model)
    H = s.n_heads(cfg.d_model)
    return s, di, H, s.head_dim, s.n_groups, s.d_state


def init_ssm(cfg, key, dtype):
    s, di, H, P, G, N = _dims(cfg)
    conv_dim = di + 2 * G * N
    ks = jax.random.split(key, 6)
    # dt bias init so softplus(dt_bias) spans [1e-3, 1e-1] (mamba2 default)
    u = jax.random.uniform(ks[4], (H,), minval=math.log(1e-3), maxval=math.log(1e-1))
    dt_init = jnp.log(jnp.expm1(jnp.exp(u)))  # inverse softplus
    return {
        "w_z": ninit(ks[0], (cfg.d_model, di), dtype=dtype),
        "w_xbc": ninit(ks[1], (cfg.d_model, conv_dim), dtype=dtype),
        "w_dt": ninit(ks[2], (cfg.d_model, H), dtype=dtype),
        "conv_w": ninit(ks[3], (s.d_conv, conv_dim), scale=1.0 / math.sqrt(s.d_conv), dtype=dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.log(jnp.arange(1, H + 1, dtype=jnp.float32)),  # A in [-1, -H]
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": dt_init.astype(jnp.float32),
        "norm_scale": jnp.ones((di,), dtype),
        "w_out": ninit(ks[5], (di, cfg.d_model), dtype=dtype),
    }


def ssm_specs(cfg):
    return {
        "w_z": ("p_embed", "p_ssm_inner"),
        "w_xbc": ("p_embed", "p_ssm_inner"),
        "w_dt": ("p_embed", "p_ssm_heads"),
        "conv_w": ("p_none", "p_ssm_inner"),
        "conv_b": ("p_ssm_inner",),
        "A_log": ("p_ssm_heads",),
        "D": ("p_ssm_heads",),
        "dt_bias": ("p_ssm_heads",),
        "norm_scale": ("p_ssm_inner",),
        "w_out": ("p_ssm_inner", "p_embed"),
    }


def _causal_depthwise_conv(x, w, b):
    """x: (B, S, C); w: (W, C) depthwise causal conv; returns (B, S, C)."""
    W = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    out = jax.lax.conv_general_dilated(
        xp,
        w[:, None, :],  # (W, 1, C) HIO for depthwise
        window_strides=(1,),
        padding="VALID",
        dimension_numbers=("NHC", "HIO", "NHC"),
        feature_group_count=x.shape[-1],
    )
    return out + b


def _expand_groups(t, H):
    """(B, L, G, N) -> (B, L, H, N) by repeating groups over heads."""
    G = t.shape[2]
    R = H // G
    return jnp.repeat(t, R, axis=2) if R > 1 else t


def ssd_chunked(xh, dt, A, Bg, Cg, chunk: int, initial_state=None):
    """Chunked SSD scan.

    xh: (B, S, H, P) inputs; dt: (B, S, H) (post-softplus);
    A: (H,) negative; Bg/Cg: (B, S, G, N).
    Returns (y (B, S, H, P), final_state (B, H, N, P)).
    """
    Bsz, S, H, P = xh.shape
    N = Bg.shape[-1]
    L = min(chunk, S)
    pad = (-S) % L
    if pad:  # zero-pad tail: dt=0 -> decay 1, B=C=0 -> state/output inert
        z2 = ((0, 0), (0, pad), (0, 0), (0, 0))
        xh = jnp.pad(xh, z2)
        Bg = jnp.pad(Bg, z2)
        Cg = jnp.pad(Cg, z2)
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
    Sp = S + pad
    nc = Sp // L

    Bh = _expand_groups(Bg, H).astype(F32)
    Ch = _expand_groups(Cg, H).astype(F32)
    xf = xh.astype(F32)
    dtf = dt.astype(F32)

    def resh(t):
        return t.reshape((Bsz, nc, L) + t.shape[2:]).swapaxes(0, 1)  # (nc, B, L, ...)

    xs = (resh(xf), resh(dtf), resh(Bh), resh(Ch))

    if initial_state is None:
        initial_state = jnp.zeros((Bsz, H, N, P), F32)

    mask = jnp.tril(jnp.ones((L, L), bool))

    def step(state, xs_c):
        xc, dtc, Bc, Cc = xs_c  # (B, L, H, P), (B, L, H), (B, L, H, N) x2
        a = dtc * A  # (B, L, H)
        cum = jnp.cumsum(a, axis=1)
        # intra-chunk: att[b,h,i,j] = (C_i . B_j) exp(cum_i - cum_j) dt_j, j<=i
        scores = jnp.einsum("bihn,bjhn->bhij", Cc, Bc)
        decay = jnp.exp(cum[:, :, None, :] - cum[:, None, :, :])  # (B, i, j, H)
        att = scores * decay.transpose(0, 3, 1, 2) * dtc[:, None, :, :].transpose(0, 3, 1, 2)
        att = jnp.where(mask[None, None], att, 0.0)
        y_intra = jnp.einsum("bhij,bjhp->bihp", att, xc)
        # inter-chunk: contribution of the incoming state
        y_inter = jnp.einsum("bihn,bhnp->bihp", Cc * jnp.exp(cum)[..., None], state)
        # chunk state: S_c = sum_j exp(cum_L - cum_j) dt_j B_j x_j^T
        w = jnp.exp(cum[:, -1:, :] - cum) * dtc  # (B, L, H)
        S_c = jnp.einsum("bjhn,bjhp->bhnp", Bc * w[..., None], xc)
        new_state = jnp.exp(cum[:, -1])[:, :, None, None] * state + S_c
        return new_state, y_intra + y_inter

    final_state, ys = jax.lax.scan(step, initial_state, xs)
    y = ys.swapaxes(0, 1).reshape(Bsz, Sp, H, P)[:, :S]
    return y, final_state


def ssm_block(cfg, p, x, *, return_state: bool = False):
    """Full Mamba-2 block: proj -> conv -> SSD -> gated norm -> out proj.

    x: (B, S, D) -> (B, S, D) [, final ssm state].
    """
    s, di, H, P, G, N = _dims(cfg)
    B_, S, D = x.shape

    z = jnp.einsum("bsd,de->bse", x, p["w_z"], preferred_element_type=x.dtype)
    xbc = jnp.einsum("bsd,de->bse", x, p["w_xbc"], preferred_element_type=x.dtype)
    dt_raw = jnp.einsum("bsd,dh->bsh", x, p["w_dt"], preferred_element_type=F32)
    z = constrain(z, "batch", "seq", "ssm_inner")
    xbc = constrain(xbc, "batch", "seq", "ssm_inner")

    xbc = jax.nn.silu(_causal_depthwise_conv(xbc, p["conv_w"], p["conv_b"]))
    xs = xbc[..., :di].reshape(B_, S, H, P)
    Bg = xbc[..., di : di + G * N].reshape(B_, S, G, N)
    Cg = xbc[..., di + G * N :].reshape(B_, S, G, N)

    dt = jax.nn.softplus(dt_raw + p["dt_bias"])  # (B, S, H) fp32
    A = -jnp.exp(p["A_log"])  # (H,)

    y, state = ssd_chunked(xs, dt, A, Bg, Cg, cfg.ssm.chunk)
    y = y + xs.astype(F32) * p["D"][:, None]
    y = y.reshape(B_, S, di).astype(x.dtype)

    # gated RMSNorm (mamba2): norm(y * silu(z)) * scale
    y = rmsnorm(y * jax.nn.silu(z), p["norm_scale"], cfg.norm_eps)
    # row-parallel: bf16 partial sums -> half-width TP all-reduce
    out = jnp.einsum("bse,ed->bsd", y, p["w_out"], preferred_element_type=x.dtype)
    out = constrain(out, "batch", "seq", "embed")
    if return_state:
        return out, state
    return out


def ssm_prefill(cfg, p, x):
    """``ssm_block`` plus the decode cache prefill leaves behind.

    Returns (out (B, S, D), cache) where ``cache`` is exactly the
    ``{'state', 'conv'}`` dict ``ssm_decode_step`` consumes: the chunked
    scan's final state and the last ``d_conv - 1`` RAW (pre-silu-conv)
    xBC projections (left-zero-padded when S < d_conv - 1, matching the
    zero-initialized rolling window).  The output math is op-for-op
    ``ssm_block``'s.
    """
    s, di, H, P, G, N = _dims(cfg)
    B_, S, D = x.shape

    z = jnp.einsum("bsd,de->bse", x, p["w_z"], preferred_element_type=x.dtype)
    xbc_raw = jnp.einsum("bsd,de->bse", x, p["w_xbc"], preferred_element_type=x.dtype)
    dt_raw = jnp.einsum("bsd,dh->bsh", x, p["w_dt"], preferred_element_type=F32)
    z = constrain(z, "batch", "seq", "ssm_inner")
    xbc_raw = constrain(xbc_raw, "batch", "seq", "ssm_inner")

    xbc = jax.nn.silu(_causal_depthwise_conv(xbc_raw, p["conv_w"], p["conv_b"]))
    xs = xbc[..., :di].reshape(B_, S, H, P)
    Bg = xbc[..., di : di + G * N].reshape(B_, S, G, N)
    Cg = xbc[..., di + G * N :].reshape(B_, S, G, N)

    dt = jax.nn.softplus(dt_raw + p["dt_bias"])  # (B, S, H) fp32
    A = -jnp.exp(p["A_log"])  # (H,)

    y, state = ssd_chunked(xs, dt, A, Bg, Cg, cfg.ssm.chunk)
    y = y + xs.astype(F32) * p["D"][:, None]
    y = y.reshape(B_, S, di).astype(x.dtype)

    y = rmsnorm(y * jax.nn.silu(z), p["norm_scale"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["w_out"], preferred_element_type=x.dtype)
    out = constrain(out, "batch", "seq", "embed")

    W = s.d_conv
    win = xbc_raw[:, max(S - (W - 1), 0):]
    pad = (W - 1) - win.shape[1]
    if pad > 0:
        win = jnp.pad(win, ((0, 0), (pad, 0), (0, 0)))
    return out, {"state": state, "conv": win.astype(x.dtype)}


# ---------------------------------------------------------------------------
# decode path: O(1) state update per token
# ---------------------------------------------------------------------------

def init_ssm_cache(cfg, batch: int, dtype=jnp.float32):
    s, di, H, P, G, N = _dims(cfg)
    conv_dim = di + 2 * G * N
    return {
        "state": jnp.zeros((batch, H, N, P), F32),
        "conv": jnp.zeros((batch, s.d_conv - 1, conv_dim), dtype),
    }


def ssm_decode_step(cfg, p, x, cache):
    """x: (B, 1, D); cache: {'state', 'conv'} -> (y (B, 1, D), new cache)."""
    s, di, H, P, G, N = _dims(cfg)
    B_ = x.shape[0]

    z = jnp.einsum("bsd,de->bse", x, p["w_z"], preferred_element_type=F32).astype(x.dtype)
    xbc_t = jnp.einsum("bsd,de->bse", x, p["w_xbc"], preferred_element_type=F32).astype(x.dtype)
    dt_raw = jnp.einsum("bsd,dh->bsh", x, p["w_dt"], preferred_element_type=F32)

    # rolling causal conv window
    win = jnp.concatenate([cache["conv"], xbc_t], axis=1)  # (B, W, C)
    conv_out = jnp.einsum("bwc,wc->bc", win.astype(F32), p["conv_w"].astype(F32)) + p["conv_b"].astype(F32)
    xbc = jax.nn.silu(conv_out)[:, None, :].astype(x.dtype)  # (B, 1, C)
    new_conv = win[:, 1:]

    xs = xbc[..., :di].reshape(B_, H, P).astype(F32)
    Bg = _expand_groups(xbc[..., di : di + G * N].reshape(B_, 1, G, N), H)[:, 0].astype(F32)
    Cg = _expand_groups(xbc[..., di + G * N :].reshape(B_, 1, G, N), H)[:, 0].astype(F32)

    dt = jax.nn.softplus(dt_raw[:, 0] + p["dt_bias"])  # (B, H)
    A = -jnp.exp(p["A_log"])
    a = jnp.exp(dt * A)  # (B, H)

    state = cache["state"] * a[:, :, None, None] + jnp.einsum(
        "bhn,bhp->bhnp", Bg * dt[..., None], xs
    )
    y = jnp.einsum("bhn,bhnp->bhp", Cg, state) + xs * p["D"][:, None]
    y = y.reshape(B_, 1, di).astype(x.dtype)

    y = rmsnorm(y * jax.nn.silu(z), p["norm_scale"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["w_out"], preferred_element_type=F32).astype(x.dtype)
    return out, {"state": state, "conv": new_conv}
