"""Mixture-of-Experts layer (phi3.5-moe: EP; qwen2-moe: TP-MoE + shared).

One capacity-based dispatch implementation serves both parallelism
strategies — they differ only in *sharding rules* (DESIGN.md §4):

  EP  (phi3.5, 16 experts % 16 == 0): the expert dim of the dispatch buffer
      and expert weights shards over ``model``; GSPMD turns the
      scatter/gather into token exchange across expert shards (the
      all-to-all analogue; §Perf iterates on the collective choice).
  TP  (qwen2-moe, 60 experts): expert weights shard on the d_ff dim; the
      dispatch buffer is expert-replicated and tokens never move.

Dispatch: top-k routing -> position-in-expert via one-hot cumsum ->
scatter into an (E, C, D) buffer (capacity C, GShard-style dropping) ->
batched expert GEMMs -> gather + weighted combine.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distribution.sharding import constrain
from repro.models.layers import F32, ninit

CAPACITY_FACTOR = 1.25


def init_moe(cfg, key, dtype):
    e = cfg.moe
    d, f = cfg.d_model, e.d_ff_expert
    ks = jax.random.split(key, 5)
    p = {
        "router": ninit(ks[0], (d, e.num_experts), scale=0.02, dtype=jnp.float32),
        "wi_gate": ninit(ks[1], (e.num_experts, d, f), dtype=dtype),
        "wi_up": ninit(ks[2], (e.num_experts, d, f), dtype=dtype),
        "wo": ninit(ks[3], (e.num_experts, f, d), dtype=dtype),
    }
    if e.num_shared_experts:
        fs = e.num_shared_experts * f
        kss = jax.random.split(ks[4], 4)
        p["shared"] = {
            "wi_gate": ninit(kss[0], (d, fs), dtype=dtype),
            "wi_up": ninit(kss[1], (d, fs), dtype=dtype),
            "wo": ninit(kss[2], (fs, d), dtype=dtype),
            "gate": ninit(kss[3], (d, 1), scale=0.02, dtype=dtype),
        }
    return p


def moe_specs(cfg):
    s = {
        "router": ("p_embed", "p_experts"),
        "wi_gate": ("p_experts", "p_embed", "p_expert_mlp"),
        "wi_up": ("p_experts", "p_embed", "p_expert_mlp"),
        "wo": ("p_experts", "p_expert_mlp", "p_embed"),
    }
    if cfg.moe.num_shared_experts:
        s["shared"] = {
            "wi_gate": ("p_embed", "p_mlp"),
            "wi_up": ("p_embed", "p_mlp"),
            "wo": ("p_mlp", "p_embed"),
            "gate": ("p_embed", "p_none"),
        }
    return s


def route(x2d, wr, top_k: int, renormalize: bool):
    """x2d: (T, D) -> (weights (T,k) fp32, idx (T,k) int32, aux_loss)."""
    logits = jnp.einsum("td,de->te", x2d.astype(F32), wr.astype(F32))
    probs = jax.nn.softmax(logits, axis=-1)
    weights, idx = jax.lax.top_k(probs, top_k)
    if renormalize:
        weights = weights / (jnp.sum(weights, axis=-1, keepdims=True) + 1e-9)
    # Switch-style load-balancing aux loss: E * sum_e(frac_tokens_e * mean_prob_e)
    E = wr.shape[-1]
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jax.nn.one_hot(idx[:, 0], E, dtype=F32), axis=0)
    aux = E * jnp.sum(me * ce)
    return weights, idx, aux


def moe_block(cfg, p, x, *, groups=None):
    """x: (B, S, D) -> (y, aux_loss). Capacity-based top-k MoE.

    Grouped dispatch (``cfg.moe.dispatch_groups`` = G): routing is global,
    but the scatter/gather stays within token groups whose dim shards over
    the data axis, so dispatch never moves tokens across data shards —
    only the expert GEMM communicates (EP) or nothing does (TP).  G=1
    recovers the single global dispatch buffer (baseline).

    ``groups`` overrides ``dispatch_groups``.  The paged serving paths
    pass ``groups=B`` so capacity buckets never span rows: dropping for
    one request then depends only on that request's own tokens, which is
    what makes serving-batch composition invisible in the outputs (the
    bit-reproducibility contract, DESIGN.md §17).
    """
    e = cfg.moe
    B, S, D = x.shape
    T = B * S
    G = max(1, min(e.dispatch_groups if groups is None else groups, T))
    while T % G:
        G -= 1
    Tg = T // G
    x2d = x.reshape(T, D)

    weights, idx, aux = route(x2d, p["router"], e.top_k, e.renormalize)

    # ---- dispatch plan: position of each (token, choice) inside its
    # (group, expert) capacity bucket
    ef = idx.reshape(G, Tg * e.top_k)  # expert id per slot-request
    onehot = jax.nn.one_hot(ef, e.num_experts, dtype=jnp.int32)  # (G, Tg*k, E)
    pos_all = jnp.cumsum(onehot, axis=1) - onehot
    pos = jnp.sum(pos_all * onehot, axis=-1)  # (G, Tg*k)
    cap = max(int(CAPACITY_FACTOR * e.top_k * Tg / e.num_experts), e.top_k)
    keep = pos < cap
    slot = jnp.where(keep, ef * cap + pos, 0)  # dropped -> slot 0, masked below

    # ---- scatter tokens into the (G, E*C, D) dispatch buffer (per group)
    xg = constrain(x2d.reshape(G, Tg, D), "exp_groups", None, "embed")
    xrep = jnp.repeat(xg, e.top_k, axis=1)  # (G, Tg*k, D)
    contrib = jnp.where(keep[..., None], xrep, 0).astype(x.dtype)
    buf = jnp.zeros((G, e.num_experts * cap, D), x.dtype)
    buf = jax.vmap(lambda b, s, c: b.at[s].add(c))(buf, slot, contrib)
    xe = buf.reshape(G, e.num_experts, cap, D)
    xe = constrain(xe, "exp_groups", "experts", None, "embed")

    # ---- expert GEMMs (batched over group x expert)
    g = jnp.einsum("gecd,edf->gecf", xe, p["wi_gate"], preferred_element_type=x.dtype)
    u = jnp.einsum("gecd,edf->gecf", xe, p["wi_up"], preferred_element_type=x.dtype)
    g = constrain(g, "exp_groups", "experts", None, "expert_mlp")
    u = constrain(u, "exp_groups", "experts", None, "expert_mlp")
    h = jax.nn.silu(g) * u
    # row-parallel under TP-MoE: bf16 partial sums -> half-width all-reduce
    ye = jnp.einsum("gecf,efd->gecd", h, p["wo"], preferred_element_type=x.dtype)
    ye = constrain(ye, "exp_groups", "experts", None, "embed")

    # ---- gather back + weighted combine over the k choices
    yflat = ye.reshape(G, e.num_experts * cap, D)
    y_tk = jax.vmap(lambda yg, s: yg[s])(yflat, slot)  # (G, Tg*k, D)
    y_tk = jnp.where(keep[..., None], y_tk, 0)
    w_tk = weights.reshape(G, Tg * e.top_k, 1).astype(x.dtype)
    y = jnp.sum((y_tk * w_tk).reshape(G, Tg, e.top_k, D), axis=2).reshape(T, D)

    # ---- always-on shared expert (qwen2-moe), sigmoid-gated
    if e.num_shared_experts:
        sp = p["shared"]
        sg = jnp.einsum("td,df->tf", x2d, sp["wi_gate"], preferred_element_type=x.dtype)
        su = jnp.einsum("td,df->tf", x2d, sp["wi_up"], preferred_element_type=x.dtype)
        sh = jax.nn.silu(sg) * su
        sy = jnp.einsum("tf,fd->td", sh, sp["wo"], preferred_element_type=x.dtype)
        gate = jax.nn.sigmoid(jnp.einsum("td,dg->tg", x2d.astype(F32), sp["gate"].astype(F32)))
        y = y + sy * gate.astype(x.dtype)

    return constrain(y.reshape(B, S, D), "batch", "seq", "embed"), aux
