"""Uniform model API over all architecture families.

    m = get_model(cfg)           # module with a fixed surface
    params = m.init(cfg, key)
    logits, aux = m.forward(cfg, params, batch)
    loss = m.loss_fn(cfg, params, batch)
    cache = m.init_cache(cfg, B, S)
    logits, cache = m.decode_step(cfg, params, cache, tok, pos)
    m.param_specs(cfg) / m.cache_specs(cfg)   # logical sharding names

``input_specs``/``make_batch`` build ShapeDtypeStruct stand-ins / random
host batches for every (arch x shape) cell, including the modality STUBS
(whisper frames, qwen2-vl patch embeddings + M-RoPE positions).

Every family also exposes the **paged serving contract** (DESIGN.md §17)
consumed by ``PagedServeEngine.from_config``:

    spec = m.paged_spec(cfg)                        # ONE multi-layer PageSpec
    k, v, state, logits = m.paged_prefill(cfg, params, tokens, extras)
    k_pages, v_pages, state, logits = m.paged_decode_step(
        cfg, params, k_pages, v_pages, state, tokens, positions, tables, lengths)

``paged_surface(cfg)`` returns the triple with a clear error if an arch
is missing a piece.
"""
from __future__ import annotations

from types import ModuleType

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models import encdec, hybrid, ssm_lm, transformer

__all__ = ["get_model", "paged_surface", "input_specs", "make_batch", "batch_logical_specs"]


def get_model(cfg: ArchConfig) -> ModuleType:
    if cfg.family in ("dense", "moe", "vlm"):
        return transformer
    if cfg.family == "ssm":
        return ssm_lm
    if cfg.family == "hybrid":
        return hybrid
    if cfg.family == "encdec":
        return encdec
    raise ValueError(cfg.family)


def paged_surface(cfg: ArchConfig):
    """(paged_spec, paged_prefill, paged_decode_step) for ``cfg``'s family.

    The uniform seam between the model zoo and the paged serving engine:
    every architecture folds its multi-layer KV into ONE ``PageSpec``
    (layer = leading slab dim, one table per sequence) and threads any
    recurrent / fixed-size residue (SSM state, conv windows, cross K/V)
    through the opaque ``state`` slot, which the engine spills, migrates
    and ships with the sequence's pages.
    """
    m = get_model(cfg)
    missing = [n for n in ("paged_spec", "paged_prefill", "paged_decode_step")
               if not hasattr(m, n)]
    if missing:
        raise NotImplementedError(
            f"model family '{cfg.family}' ({m.__name__}) lacks the paged "
            f"serving contract: missing {missing}"
        )
    return m.paged_spec, m.paged_prefill, m.paged_decode_step


def _batch_shapes(cfg: ArchConfig, shape: ShapeConfig, *, dtype=jnp.bfloat16):
    """dict name -> (shape, dtype) for the *batch* inputs of a cell."""
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "decode":
        d: dict = {"tokens": ((B, 1), jnp.int32)}
    else:
        d = {"tokens": ((B, S), jnp.int32)}
        if shape.kind == "train":
            d["labels"] = ((B, S), jnp.int32)
    if cfg.family == "encdec":
        d["frames"] = ((B, cfg.encdec.encoder_seq, cfg.d_model), dtype)
    if cfg.family == "vlm" and shape.kind != "decode":
        d["patch_embeds"] = ((B, cfg.num_patches, cfg.d_model), dtype)
        d["positions"] = ((3, B, S), jnp.int32)
    return d


def input_specs(cfg: ArchConfig, shape: ShapeConfig) -> "dict[str, jax.ShapeDtypeStruct]":
    """ShapeDtypeStruct stand-ins for every model input (no allocation)."""
    return {
        k: jax.ShapeDtypeStruct(s, dt) for k, (s, dt) in _batch_shapes(cfg, shape).items()
    }


def batch_logical_specs(cfg: ArchConfig, shape: ShapeConfig) -> "dict[str, tuple]":
    """Logical axis names for each batch input (for in_shardings)."""
    names = {
        "tokens": ("batch", "seq"),
        "labels": ("batch", "seq"),
        "frames": ("batch", None, "embed"),
        "patch_embeds": ("batch", None, "embed"),
        "positions": (None, "batch", "seq"),
    }
    return {k: names[k] for k in _batch_shapes(cfg, shape)}


def make_batch(cfg: ArchConfig, shape: ShapeConfig, seed: int = 0) -> dict:
    """Small random host batch (smoke tests / examples)."""
    rng = np.random.default_rng(seed)
    out = {}
    for k, (s, dt) in _batch_shapes(cfg, shape).items():
        if dt == jnp.int32:
            hi = cfg.vocab_size if "token" in k or "label" in k else min(shape.seq_len, 4)
            out[k] = jnp.asarray(rng.integers(0, hi, size=s), jnp.int32)
        else:
            out[k] = jnp.asarray(rng.normal(0, 0.02, size=s), dt)
    return out
