"""Hymba hybrid-head model [arXiv:2411.13676].

Each block runs attention heads and Mamba(SSD) heads IN PARALLEL on the
same normalized input; per-path outputs are normalized, scaled and
averaged (approximation of the paper's output-mean fusion — recorded in
DESIGN.md).  Sliding-window attention everywhere except
``cfg.global_attn_layers``; consecutive SWA layers share KV
(``kv_share_group=2``: even layers produce K/V, odd layers reuse them);
``cfg.meta_tokens`` learned registers are prepended to the sequence.

Layers are heterogeneous (global/local, producer/consumer), so the stack
is an unrolled python loop over per-layer param lists rather than a scan.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distribution.sharding import constrain
from repro.models import layers as L
from repro.models import ssm as S


def _is_global(cfg, l: int) -> bool:
    return l in cfg.global_attn_layers


def _kv_producer(cfg, l: int) -> int:
    """Index of the layer whose K/V layer ``l`` consumes."""
    if _is_global(cfg, l) or cfg.kv_share_group <= 1:
        return l
    base = l - (l % cfg.kv_share_group)
    return l if _is_global(cfg, base) else base


def kv_producers(cfg) -> "list[int]":
    return sorted({_kv_producer(cfg, l) for l in range(cfg.num_layers)})


def _init_layer(cfg, key, dtype, l: int):
    ks = jax.random.split(key, 6)
    produces = _kv_producer(cfg, l) == l
    attn = L.init_attn(cfg, ks[0], dtype)
    if not produces:  # consumer layers have no K/V projections
        attn.pop("wk"), attn.pop("wv")
        attn.pop("bk", None), attn.pop("bv", None)
    return {
        "ln1": L.init_norm(cfg, ks[1], dtype),
        "attn": attn,
        "ssm": S.init_ssm(cfg, ks[2], dtype),
        "fuse_attn": jnp.ones((cfg.d_model,), dtype),
        "fuse_ssm": jnp.ones((cfg.d_model,), dtype),
        "ln2": L.init_norm(cfg, ks[3], dtype),
        "mlp": L.init_mlp(cfg, ks[4], dtype),
    }


def init(cfg, key, dtype=jnp.float32):
    kE, kM, kL, kF = jax.random.split(key, 4)
    layer_keys = jax.random.split(kL, cfg.num_layers)
    return {
        "embed": L.init_embed(cfg, kE, dtype),
        "meta": L.ninit(kM, (cfg.meta_tokens, cfg.d_model), scale=0.02, dtype=dtype)
        if cfg.meta_tokens
        else jnp.zeros((0, cfg.d_model), dtype),
        "layers": [_init_layer(cfg, k, dtype, l) for l, k in enumerate(layer_keys)],
        "final_norm": L.init_norm(cfg, kF, dtype),
    }


def param_specs(cfg):
    def layer(l):
        attn = L.attn_specs(cfg)
        if _kv_producer(cfg, l) != l:
            attn.pop("wk"), attn.pop("wv")
            attn.pop("bk", None), attn.pop("bv", None)
        return {
            "ln1": L.norm_specs(cfg),
            "attn": attn,
            "ssm": S.ssm_specs(cfg),
            "fuse_attn": ("p_none",),
            "fuse_ssm": ("p_none",),
            "ln2": L.norm_specs(cfg),
            "mlp": L.mlp_specs(cfg),
        }

    return {
        "embed": L.embed_specs(cfg),
        "meta": ("p_none", "p_embed"),
        "layers": [layer(l) for l in range(cfg.num_layers)],
        "final_norm": L.norm_specs(cfg),
    }


def _pad_to(x, mult: int):
    S_ = x.shape[1]
    pad = (-S_) % mult
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad)) + ((0, 0),) * (x.ndim - 2))
    return x, pad


def forward(cfg, params, batch, *, q_block=512, remat: str = "none", return_kv: bool = False, last_only: bool = False):
    x = L.embed(cfg, params["embed"], batch["tokens"])
    B = x.shape[0]
    if cfg.meta_tokens:
        meta = jnp.broadcast_to(params["meta"][None], (B, cfg.meta_tokens, cfg.d_model))
        x = jnp.concatenate([meta.astype(x.dtype), x], axis=1)
    S_ = x.shape[1]
    pos = jnp.broadcast_to(jnp.arange(S_)[None], (B, S_))
    rot = int(cfg.hd * cfg.partial_rotary)
    cos, sin = L.rope_angles(pos, rot, cfg.rope_theta)

    shared_kv = None
    kvs = {}
    for l, lp in enumerate(params["layers"]):
        def block(x, lp=lp, l=l, shared=shared_kv):
            h = L.apply_norm(cfg, x, lp["ln1"])
            # --- ssm path
            y_ssm = S.ssm_block(cfg, lp["ssm"], h)
            # --- attention path (possibly reusing shared K/V)
            hd, H, K = cfg.hd, cfg.num_heads, cfg.num_kv_heads
            q = jnp.einsum("bsd,dh->bsh", h, lp["attn"]["wq"], preferred_element_type=h.dtype)
            if cfg.attn_qkv_bias:
                q = q + lp["attn"]["bq"]
            q = L.apply_rope(q.reshape(B, S_, H, hd), cos, sin)
            if "wk" in lp["attn"]:
                k = jnp.einsum("bsd,dh->bsh", h, lp["attn"]["wk"], preferred_element_type=h.dtype)
                v = jnp.einsum("bsd,dh->bsh", h, lp["attn"]["wv"], preferred_element_type=h.dtype)
                if cfg.attn_qkv_bias:
                    k, v = k + lp["attn"]["bk"], v + lp["attn"]["bv"]
                k = L.apply_rope(k.reshape(B, S_, K, hd), cos, sin)
                v = v.reshape(B, S_, K, hd)
            else:
                k, v = shared
            if _is_global(cfg, l) or cfg.sliding_window is None or S_ <= cfg.sliding_window:
                o = L.attention(q, k, v, causal=True, q_block=q_block)
            else:
                w = cfg.sliding_window
                qp, _ = _pad_to(q, w)
                kp, _ = _pad_to(k, w)
                vp, pad = _pad_to(v, w)
                o = L.local_block_attention(qp, kp, vp, window=w)[:, :S_]
            y_attn = L.out_proj(cfg, lp["attn"], o)
            # --- fuse: mean of per-path normalized outputs
            fused = 0.5 * (
                L.rmsnorm(y_attn, lp["fuse_attn"], cfg.norm_eps)
                + L.rmsnorm(y_ssm, lp["fuse_ssm"], cfg.norm_eps)
            )
            x = x + fused
            x = x + L.mlp(cfg, lp["mlp"], L.apply_norm(cfg, x, lp["ln2"]))
            return constrain(x, "batch", "seq", "embed"), (k, v)

        if remat in ("full", "dots"):
            block = jax.checkpoint(block)
        x, (k_l, v_l) = block(x)
        if _kv_producer(cfg, l) == l:
            shared_kv = (k_l, v_l)
            if return_kv:
                kvs[l] = (k_l, v_l)

    x = L.apply_norm(cfg, x, params["final_norm"])
    if cfg.meta_tokens:
        x = x[:, cfg.meta_tokens :]
    if last_only:
        x = x[:, -1:]
    logits = L.unembed(cfg, params["embed"], x)
    aux = jnp.zeros((), jnp.float32)
    if return_kv:
        return logits, aux, kvs
    return logits, aux


def loss_fn(cfg, params, batch, **kw):
    logits, _ = forward(cfg, params, batch, **kw)
    return L.xent_loss(logits, batch["labels"], batch.get("loss_mask"))


# ---------------------------------------------------------------------------
# decode: ring caches for SWA producers, full caches for global layers,
# SSM state for every layer
# ---------------------------------------------------------------------------

def init_cache(cfg, batch: int, max_seq: int, dtype=jnp.bfloat16):
    K, hd = cfg.num_kv_heads, cfg.hd
    producers = kv_producers(cfg)
    swa = [l for l in producers if not _is_global(cfg, l)]
    glob = [l for l in producers if _is_global(cfg, l)]
    ring = min(cfg.sliding_window or max_seq, max_seq)
    ssm1 = S.init_ssm_cache(cfg, batch, dtype)
    cache = {
        "swa_k": jnp.zeros((len(swa), batch, ring, K, hd), dtype),
        "swa_v": jnp.zeros((len(swa), batch, ring, K, hd), dtype),
        "glob_k": jnp.zeros((len(glob), batch, max_seq, K, hd), dtype),
        "glob_v": jnp.zeros((len(glob), batch, max_seq, K, hd), dtype),
        "ssm_state": jnp.broadcast_to(ssm1["state"], (cfg.num_layers,) + ssm1["state"].shape).copy(),
        "ssm_conv": jnp.broadcast_to(ssm1["conv"], (cfg.num_layers,) + ssm1["conv"].shape).copy(),
    }
    return cache


def cache_specs(cfg):
    kv = (None, "batch", "seq", "kv_heads", "head_dim")
    return {
        "swa_k": kv,
        "swa_v": kv,
        "glob_k": kv,
        "glob_v": kv,
        "ssm_state": ("layers", "batch", "ssm_heads", "ssm_state", None),
        "ssm_conv": ("layers", "batch", None, "ssm_inner"),
    }


def decode_step(cfg, params, cache, tokens, pos, *, positions=None):
    """tokens (B,1); pos counts *content* tokens; meta offset added here."""
    x = L.embed(cfg, params["embed"], tokens)
    B = x.shape[0]
    apos = pos + cfg.meta_tokens
    p1 = jnp.full((B, 1), apos, dtype=jnp.int32)
    rot = int(cfg.hd * cfg.partial_rotary)
    cos, sin = L.rope_angles(p1, rot, cfg.rope_theta)

    producers = kv_producers(cfg)
    swa = [l for l in producers if not _is_global(cfg, l)]
    glob = [l for l in producers if _is_global(cfg, l)]
    swa_ix = {l: i for i, l in enumerate(swa)}
    glob_ix = {l: i for i, l in enumerate(glob)}

    cache = dict(cache)
    shared = None
    for l, lp in enumerate(params["layers"]):
        h = L.apply_norm(cfg, x, lp["ln1"])
        y_ssm, new_ssm = S.ssm_decode_step(
            cfg, lp["ssm"], h, {"state": cache["ssm_state"][l], "conv": cache["ssm_conv"][l]}
        )
        cache["ssm_state"] = cache["ssm_state"].at[l].set(new_ssm["state"])
        cache["ssm_conv"] = cache["ssm_conv"].at[l].set(new_ssm["conv"])

        hd, H, K = cfg.hd, cfg.num_heads, cfg.num_kv_heads
        q = jnp.einsum("bsd,dh->bsh", h, lp["attn"]["wq"], preferred_element_type=h.dtype)
        if cfg.attn_qkv_bias:
            q = q + lp["attn"]["bq"]
        q = L.apply_rope(q.reshape(B, 1, H, hd), cos, sin)

        if "wk" in lp["attn"]:
            k = jnp.einsum("bsd,dh->bsh", h, lp["attn"]["wk"], preferred_element_type=h.dtype)
            v = jnp.einsum("bsd,dh->bsh", h, lp["attn"]["wv"], preferred_element_type=h.dtype)
            if cfg.attn_qkv_bias:
                k, v = k + lp["attn"]["bk"], v + lp["attn"]["bv"]
            k = L.apply_rope(k.reshape(B, 1, K, hd), cos, sin)
            v = v.reshape(B, 1, K, hd)
            if _is_global(cfg, l):
                i = glob_ix[l]
                ck, cv = L.cache_update(cache["glob_k"][i], cache["glob_v"][i], k, v, apos)
                cache["glob_k"] = cache["glob_k"].at[i].set(ck)
                cache["glob_v"] = cache["glob_v"].at[i].set(cv)
                o = L.decode_attend(cfg, q, ck, cv, apos)
            else:
                i = swa_ix[l]
                ring = cache["swa_k"].shape[2]
                ck, cv = L.cache_update(cache["swa_k"][i], cache["swa_v"][i], k, v, apos, ring=ring)
                cache["swa_k"] = cache["swa_k"].at[i].set(ck)
                cache["swa_v"] = cache["swa_v"].at[i].set(cv)
                o = L.decode_attend(cfg, q, ck, cv, apos, window=cfg.sliding_window)
                shared = (ck, cv, True)
        else:
            ck, cv, is_ring = shared
            if is_ring:
                o = L.decode_attend(cfg, q, ck, cv, apos, window=cfg.sliding_window)
            else:
                o = L.decode_attend(cfg, q, ck, cv, apos)
        y_attn = L.out_proj(cfg, lp["attn"], o)
        fused = 0.5 * (
            L.rmsnorm(y_attn, lp["fuse_attn"], cfg.norm_eps)
            + L.rmsnorm(y_ssm, lp["fuse_ssm"], cfg.norm_eps)
        )
        x = x + fused
        x = x + L.mlp(cfg, lp["mlp"], L.apply_norm(cfg, x, lp["ln2"]))

    x = L.apply_norm(cfg, x, params["final_norm"])
    logits = L.unembed(cfg, params["embed"], x)
    return logits, cache


# ---------------------------------------------------------------------------
# paged serving contract (DESIGN.md §17)
# ---------------------------------------------------------------------------

def paged_spec(cfg):
    """One slab layer per KV *producer* (consumers share the producer's
    pages, exactly as they share its cache in ``decode_step``).  SWA
    layers keep FULL history in pages; the ring layout the oracle's
    ``cache_update(..., ring=...)`` would hold is reconstructed at decode
    via ``layers.ring_gather`` — so pages stay position-addressed for
    every layer and one table serves the whole stack."""
    from repro.serving.paged import PageSpec

    return PageSpec(
        layers=len(kv_producers(cfg)),
        page_size=0,
        kv_heads=cfg.num_kv_heads,
        head_dim=cfg.hd,
        dtype=jnp.float32,
    )


def paged_prefill(cfg, params, tokens, extras=None):
    """tokens: (B, T) -> (k, v, state, last_logits).

    k/v: (B, Lp, T', K, hd) over producer layers with T' = meta + T —
    meta registers live in the pages too, so the sequence's page length
    and the decode-step positions are the same absolute coordinate.
    state: batch-leading per-layer recurrent {'ssm_state', 'ssm_conv'}.
    The block math is op-for-op ``forward``'s (ssm path via
    ``S.ssm_prefill``, the cache-returning twin of ``S.ssm_block``).
    """
    x = L.embed(cfg, params["embed"], tokens)
    B = x.shape[0]
    if cfg.meta_tokens:
        meta = jnp.broadcast_to(params["meta"][None], (B, cfg.meta_tokens, cfg.d_model))
        x = jnp.concatenate([meta.astype(x.dtype), x], axis=1)
    S_ = x.shape[1]
    pos = jnp.broadcast_to(jnp.arange(S_)[None], (B, S_))
    rot = int(cfg.hd * cfg.partial_rotary)
    cos, sin = L.rope_angles(pos, rot, cfg.rope_theta)

    producers = kv_producers(cfg)
    shared_kv = None
    kvs = {}
    states, convs = [], []
    for l, lp in enumerate(params["layers"]):
        shared = shared_kv
        h = L.apply_norm(cfg, x, lp["ln1"])
        y_ssm, ssm_cache = S.ssm_prefill(cfg, lp["ssm"], h)
        states.append(ssm_cache["state"])
        convs.append(ssm_cache["conv"])
        hd, H, K = cfg.hd, cfg.num_heads, cfg.num_kv_heads
        q = jnp.einsum("bsd,dh->bsh", h, lp["attn"]["wq"], preferred_element_type=h.dtype)
        if cfg.attn_qkv_bias:
            q = q + lp["attn"]["bq"]
        q = L.apply_rope(q.reshape(B, S_, H, hd), cos, sin)
        if "wk" in lp["attn"]:
            k = jnp.einsum("bsd,dh->bsh", h, lp["attn"]["wk"], preferred_element_type=h.dtype)
            v = jnp.einsum("bsd,dh->bsh", h, lp["attn"]["wv"], preferred_element_type=h.dtype)
            if cfg.attn_qkv_bias:
                k, v = k + lp["attn"]["bk"], v + lp["attn"]["bv"]
            k = L.apply_rope(k.reshape(B, S_, K, hd), cos, sin)
            v = v.reshape(B, S_, K, hd)
        else:
            k, v = shared
        if _is_global(cfg, l) or cfg.sliding_window is None or S_ <= cfg.sliding_window:
            o = L.attention(q, k, v, causal=True, q_block=512)
        else:
            w = cfg.sliding_window
            qp, _ = _pad_to(q, w)
            kp, _ = _pad_to(k, w)
            vp, _ = _pad_to(v, w)
            o = L.local_block_attention(qp, kp, vp, window=w)[:, :S_]
        y_attn = L.out_proj(cfg, lp["attn"], o)
        fused = 0.5 * (
            L.rmsnorm(y_attn, lp["fuse_attn"], cfg.norm_eps)
            + L.rmsnorm(y_ssm, lp["fuse_ssm"], cfg.norm_eps)
        )
        x = x + fused
        x = x + L.mlp(cfg, lp["mlp"], L.apply_norm(cfg, x, lp["ln2"]))
        x = constrain(x, "batch", "seq", "embed")
        if _kv_producer(cfg, l) == l:
            shared_kv = (k, v)
            kvs[l] = (k, v)

    xf = L.apply_norm(cfg, x, params["final_norm"])
    if cfg.meta_tokens:
        xf = xf[:, cfg.meta_tokens :]
    logits = L.unembed(cfg, params["embed"], xf[:, -1:])

    k_rows = jnp.stack([kvs[l][0] for l in producers], axis=1)  # (B, Lp, S', K, hd)
    v_rows = jnp.stack([kvs[l][1] for l in producers], axis=1)
    state = {
        "ssm_state": jnp.stack(states, axis=1),  # (B, L, H, N, P) f32
        "ssm_conv": jnp.stack(convs, axis=1),    # (B, L, W-1, C)
    }
    return k_rows, v_rows, state, logits[:, 0]


def paged_decode_step(cfg, params, k_pages, v_pages, state, tokens, positions, tables, lengths):
    """k_pages/v_pages: (Lp, N, P, K, hd); positions == lengths: (B,)
    ABSOLUTE page coordinates (meta included — prefill registered the
    meta registers as page tokens).  Per-row math is ``decode_step``'s:
    global producers scatter + full-prefix attend, SWA producers scatter
    + ring-reconstructed windowed attend, consumers reuse the producer's
    gathered cache, SSM state advances every layer.
    """
    tokens = tokens.reshape(-1, 1)
    x = L.embed(cfg, params["embed"], tokens)
    B = x.shape[0]
    p1 = positions[:, None].astype(jnp.int32)
    rot = int(cfg.hd * cfg.partial_rotary)
    cos, sin = L.rope_angles(p1, rot, cfg.rope_theta)

    producers = kv_producers(cfg)
    prod_ix = {l: i for i, l in enumerate(producers)}
    P_ = k_pages.shape[2]
    width = tables.shape[1] * P_
    ring = min(cfg.sliding_window, width) if cfg.sliding_window else width

    new_states, new_convs = [], []
    shared = None
    for l, lp in enumerate(params["layers"]):
        h = L.apply_norm(cfg, x, lp["ln1"])
        y_ssm, new_ssm = S.ssm_decode_step(
            cfg, lp["ssm"], h,
            {"state": state["ssm_state"][:, l], "conv": state["ssm_conv"][:, l]},
        )
        new_states.append(new_ssm["state"])
        new_convs.append(new_ssm["conv"])

        hd, H, K = cfg.hd, cfg.num_heads, cfg.num_kv_heads
        q = jnp.einsum("bsd,dh->bsh", h, lp["attn"]["wq"], preferred_element_type=h.dtype)
        if cfg.attn_qkv_bias:
            q = q + lp["attn"]["bq"]
        q = L.apply_rope(q.reshape(B, 1, H, hd), cos, sin)

        if "wk" in lp["attn"]:
            k = jnp.einsum("bsd,dh->bsh", h, lp["attn"]["wk"], preferred_element_type=h.dtype)
            v = jnp.einsum("bsd,dh->bsh", h, lp["attn"]["wv"], preferred_element_type=h.dtype)
            if cfg.attn_qkv_bias:
                k, v = k + lp["attn"]["bk"], v + lp["attn"]["bv"]
            k = L.apply_rope(k.reshape(B, 1, K, hd), cos, sin)
            v = v.reshape(B, 1, K, hd)
            i = prod_ix[l]
            kp, vp = L.page_scatter(k_pages[i], v_pages[i], k, v, tables, positions)
            k_pages = k_pages.at[i].set(kp)
            v_pages = v_pages.at[i].set(vp)
            if _is_global(cfg, l):
                ck = L.page_gather(kp, tables)
                cv = L.page_gather(vp, tables)
                o = L.attention(q, ck, cv, causal=False, valid_len=lengths + 1)
            else:
                ck = L.ring_gather(kp, tables, positions, ring)
                cv = L.ring_gather(vp, tables, positions, ring)
                valid = jnp.minimum(positions + 1, ring)
                o = L.attention(q, ck, cv, causal=False, valid_len=valid)
                shared = (ck, cv, True, valid)
        else:
            ck, cv, is_ring, valid = shared
            if is_ring:
                o = L.attention(q, ck, cv, causal=False, valid_len=valid)
            else:
                o = L.attention(q, ck, cv, causal=False, valid_len=lengths + 1)
        y_attn = L.out_proj(cfg, lp["attn"], o)
        fused = 0.5 * (
            L.rmsnorm(y_attn, lp["fuse_attn"], cfg.norm_eps)
            + L.rmsnorm(y_ssm, lp["fuse_ssm"], cfg.norm_eps)
        )
        x = x + fused
        x = x + L.mlp(cfg, lp["mlp"], L.apply_norm(cfg, x, lp["ln2"]))

    x = L.apply_norm(cfg, x, params["final_norm"])
    logits = L.unembed(cfg, params["embed"], x)
    state = {
        "ssm_state": jnp.stack(new_states, axis=1),
        "ssm_conv": jnp.stack(new_convs, axis=1),
    }
    return k_pages, v_pages, state, logits[:, 0]
