"""Decoder-only transformer LM (dense / MoE / VLM backbones).

Layers are *stacked* (leading L axis) and applied with ``lax.scan`` so the
HLO stays one-layer-sized regardless of depth (deepseek-67b: 95 layers),
with a configurable remat policy on the scanned body.

VLM (qwen2-vl): the vision frontend is a STUB — precomputed patch
embeddings (B, P, D) are written over positions [1, P+1) of the token
embedding, and M-RoPE consumes the stub's (3, B, S) t/h/w position ids.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.distribution.sharding import constrain
from repro.models import layers as L
from repro.models import moe as M

AUX_LOSS_COEF = 0.01


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_layer(cfg, key, dtype):
    ks = jax.random.split(key, 4)
    p = {
        "ln1": L.init_norm(cfg, ks[0], dtype),
        "attn": L.init_attn(cfg, ks[1], dtype),
        "ln2": L.init_norm(cfg, ks[2], dtype),
    }
    if cfg.moe is not None:
        p["moe"] = M.init_moe(cfg, ks[3], dtype)
    else:
        p["mlp"] = L.init_mlp(cfg, ks[3], dtype)
    return p


def _layer_specs(cfg):
    s = {
        "ln1": L.norm_specs(cfg),
        "attn": L.attn_specs(cfg),
        "ln2": L.norm_specs(cfg),
    }
    if cfg.moe is not None:
        s["moe"] = M.moe_specs(cfg)
    else:
        s["mlp"] = L.mlp_specs(cfg)
    return s


def init(cfg, key, dtype=jnp.float32):
    kE, kL, kF = jax.random.split(key, 3)
    layer_keys = jax.random.split(kL, cfg.num_layers)
    return {
        "embed": L.init_embed(cfg, kE, dtype),
        "layers": jax.vmap(lambda k: _init_layer(cfg, k, dtype))(layer_keys),
        "final_norm": L.init_norm(cfg, kF, dtype),
    }


def param_specs(cfg):
    """Logical-axis names for every param; layer params gain a 'layers' dim."""
    layer = _layer_specs(cfg)
    stacked = jax.tree.map(
        lambda names: ("layers",) + names,
        layer,
        is_leaf=lambda x: isinstance(x, tuple) and all(isinstance(n, (str, type(None))) for n in x),
    )
    return {
        "embed": L.embed_specs(cfg),
        "layers": stacked,
        "final_norm": L.norm_specs(cfg),
    }


# ---------------------------------------------------------------------------
# embedding (+ VLM patch merge)
# ---------------------------------------------------------------------------

def _embed_inputs(cfg, params, batch):
    x = L.embed(cfg, params["embed"], batch["tokens"])
    if cfg.vision_stub and "patch_embeds" in batch:
        pe = batch["patch_embeds"].astype(x.dtype)  # (B, P, D) from the stub
        P = pe.shape[1]
        x = jax.lax.dynamic_update_slice(x, pe, (0, 1, 0))  # positions [1, P+1)
        del P
    return x


def _positions(cfg, batch, S):
    if cfg.rope_type == "mrope":
        pos = batch.get("positions")
        if pos is None:  # text-only fallback: all three streams equal
            B = batch["tokens"].shape[0]
            pos = jnp.broadcast_to(jnp.arange(S)[None, None], (3, B, S))
        return pos
    B = batch["tokens"].shape[0]
    return jnp.broadcast_to(jnp.arange(S)[None], (B, S))


def _rope(cfg, positions):
    if cfg.rope_type in ("rope", "mrope"):
        rot = int(cfg.hd * cfg.partial_rotary)
        return L.rope_angles(positions, rot, cfg.rope_theta, cfg.mrope_sections)
    return None, None


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------

def _attn_mlp_layer(cfg, lp, x, cos, sin, *, q_block, return_kv, moe_groups=None):
    h = L.apply_norm(cfg, x, lp["ln1"])
    q, k, v = L.qkv_proj(cfg, lp["attn"], h)
    if cos is not None:
        q = L.apply_rope(q, cos, sin)
        k = L.apply_rope(k, cos, sin)
    if cfg.sliding_window is not None and x.shape[1] > cfg.sliding_window:
        o = L.local_block_attention(q, k, v, window=cfg.sliding_window)
    else:
        o = L.attention(q, k, v, causal=True, q_block=q_block, softcap=cfg.attn_logit_softcap)
    x = x + L.out_proj(cfg, lp["attn"], o)

    h = L.apply_norm(cfg, x, lp["ln2"])
    aux = jnp.zeros((), jnp.float32)
    if cfg.moe is not None:
        y, aux = M.moe_block(cfg, lp["moe"], h, groups=moe_groups)
    else:
        y = L.mlp(cfg, lp["mlp"], h)
    x = constrain(x + y, "batch", "seq", "embed")
    return x, aux, (k, v)


def forward(
    cfg,
    params,
    batch,
    *,
    q_block: "Optional[int]" = 512,
    remat: str = "none",
    return_kv: bool = False,
    last_only: bool = False,
    moe_groups: "Optional[int]" = None,
):
    """Teacher-forcing forward. batch["tokens"]: (B, S) int32.

    Returns (logits, aux_loss) or (logits, aux_loss, kv_cache) with
    ``return_kv`` (prefill: kv_cache is {'k','v'}: (L, B, S, K, hd)).
    """
    x = _embed_inputs(cfg, params, batch)
    S = x.shape[1]
    cos, sin = _rope(cfg, _positions(cfg, batch, S))

    def body(x, lp):
        x, aux, kv = _attn_mlp_layer(cfg, lp, x, cos, sin, q_block=q_block,
                                     return_kv=return_kv, moe_groups=moe_groups)
        ys = (aux, kv) if return_kv else (aux, (jnp.zeros((), x.dtype),) * 2)
        return x, ys

    if remat == "full":
        body = jax.checkpoint(body)
    elif remat == "dots":
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)

    x, (auxs, kvs) = jax.lax.scan(body, x, params["layers"])
    x = L.apply_norm(cfg, x, params["final_norm"])
    if last_only:  # prefill: only the final position feeds sampling
        x = x[:, -1:]
    logits = L.unembed(cfg, params["embed"], x)
    aux = jnp.sum(auxs)
    if return_kv:
        return logits, aux, {"k": kvs[0], "v": kvs[1]}
    return logits, aux


def loss_fn(cfg, params, batch, **kw):
    """Mean next-token cross-entropy (fp32) + MoE aux loss."""
    logits, aux = forward(cfg, params, batch, **kw)
    xent = L.xent_loss(logits, batch["labels"], batch.get("loss_mask"))
    return xent + AUX_LOSS_COEF * aux


# ---------------------------------------------------------------------------
# decode (one token, stacked KV cache, scan over layers)
# ---------------------------------------------------------------------------

def init_cache(cfg, batch: int, max_seq: int, dtype=jnp.bfloat16):
    shape = (cfg.num_layers, batch, max_seq, cfg.num_kv_heads, cfg.hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def cache_specs(cfg):
    names = ("layers", "batch", "seq", "kv_heads", "head_dim")
    return {"k": names, "v": names}


def decode_step(cfg, params, cache, tokens, pos, *, positions=None):
    """tokens: (B, 1) int32; pos: scalar int32 (current write position).

    Returns (logits (B, 1, V), new_cache).
    """
    x = L.embed(cfg, params["embed"], tokens)
    B = x.shape[0]
    if cfg.rope_type == "mrope":
        p3 = jnp.full((3, B, 1), pos, dtype=jnp.int32)  # text decode: t=h=w=pos
        cos, sin = _rope(cfg, p3)
    elif cfg.rope_type == "rope":
        p1 = jnp.full((B, 1), pos, dtype=jnp.int32)
        cos, sin = _rope(cfg, p1)
    else:
        cos, sin = None, None

    def body(x, xs):
        lp, ck, cv = xs
        h = L.apply_norm(cfg, x, lp["ln1"])
        q, k, v = L.qkv_proj(cfg, lp["attn"], h)
        if cos is not None:
            q = L.apply_rope(q, cos, sin)
            k = L.apply_rope(k, cos, sin)
        ck, cv = L.cache_update(ck, cv, k, v, pos)
        o = L.decode_attend(cfg, q, ck, cv, pos)
        x = x + L.out_proj(cfg, lp["attn"], o)
        h = L.apply_norm(cfg, x, lp["ln2"])
        if cfg.moe is not None:
            y, _ = M.moe_block(cfg, lp["moe"], h)
        else:
            y = L.mlp(cfg, lp["mlp"], h)
        return x + y, (ck, cv)

    x, (ks, vs) = jax.lax.scan(body, x, (params["layers"], cache["k"], cache["v"]))
    x = L.apply_norm(cfg, x, params["final_norm"])
    logits = L.unembed(cfg, params["embed"], x)
    return logits, {"k": ks, "v": vs}


# ---------------------------------------------------------------------------
# paged serving contract (DESIGN.md §17)
# ---------------------------------------------------------------------------

def paged_spec(cfg):
    """Multi-layer KV folded into ONE page geometry: layer is the leading
    slab dim, so a sequence's pages for every layer share one table."""
    from repro.serving.paged import PageSpec

    return PageSpec(
        layers=cfg.num_layers,
        page_size=0,  # 0 -> REPRO_PAGE_SIZE default
        kv_heads=cfg.num_kv_heads,
        head_dim=cfg.hd,
        dtype=jnp.float32,
    )


def paged_prefill(cfg, params, tokens, extras=None):
    """tokens: (B, T) int32 -> (k, v, state, last_logits).

    k/v: (B, L, T, K, hd) per-request KV rows ready for
    ``PagedKVCache.append``; state: None (attention-only arch);
    last_logits: (B, V) fp32 for the sampling stage.  KV bits equal
    ``forward(..., return_kv=True)`` — the padded oracle's prefill.
    """
    batch = {"tokens": tokens}
    if extras:
        batch.update(extras)
    # moe_groups=B: capacity buckets stay per-row, so each request's
    # prefill logits are independent of which rows batched with it.
    logits, _, kv = forward(cfg, params, batch, return_kv=True, last_only=True,
                            moe_groups=tokens.shape[0])
    k = jnp.moveaxis(kv["k"], 0, 1)  # (L, B, T, K, hd) -> (B, L, T, K, hd)
    v = jnp.moveaxis(kv["v"], 0, 1)
    return k, v, None, logits[:, -1]


def paged_decode_step(cfg, params, k_pages, v_pages, state, tokens, positions, tables, lengths):
    """One ragged decode step straight against the page pool.

    k_pages/v_pages: (L, N, P, K, hd) slabs; tokens: (B,) int32 last
    tokens; positions == lengths: (B,) per-row write slot / tokens already
    resident; tables: (B, M).  Returns (k_pages, v_pages, state, logits
    (B, V)).  Per-row math is op-for-op ``decode_step``'s — the new token
    is scattered at ``positions`` and each row attends over
    ``lengths + 1`` slots — so greedy tokens are bit-identical to the
    padded oracle.
    """
    tokens = tokens.reshape(-1, 1)
    x = L.embed(cfg, params["embed"], tokens)
    B = x.shape[0]
    if cfg.rope_type == "mrope":
        p3 = jnp.broadcast_to(positions[None, :, None], (3, B, 1)).astype(jnp.int32)
        cos, sin = _rope(cfg, p3)
    elif cfg.rope_type == "rope":
        cos, sin = _rope(cfg, positions[:, None].astype(jnp.int32))
    else:
        cos, sin = None, None

    def body(x, xs):
        lp, kp, vp = xs
        h = L.apply_norm(cfg, x, lp["ln1"])
        q, k, v = L.qkv_proj(cfg, lp["attn"], h)
        if cos is not None:
            q = L.apply_rope(q, cos, sin)
            k = L.apply_rope(k, cos, sin)
        kp, vp = L.page_scatter(kp, vp, k, v, tables, positions)
        o = L.paged_decode_attend(q, kp, vp, tables, lengths)
        x = x + L.out_proj(cfg, lp["attn"], o)
        h = L.apply_norm(cfg, x, lp["ln2"])
        if cfg.moe is not None:
            # per-row capacity buckets: a row's expert drops cannot depend
            # on which other sequences share the decode micro-batch
            y, _ = M.moe_block(cfg, lp["moe"], h, groups=B)
        else:
            y = L.mlp(cfg, lp["mlp"], h)
        return x + y, (kp, vp)

    x, (ks, vs) = jax.lax.scan(body, x, (params["layers"], k_pages, v_pages))
    x = L.apply_norm(cfg, x, params["final_norm"])
    logits = L.unembed(cfg, params["embed"], x)
    return ks, vs, state, logits[:, 0]
