"""Serving layer: per-step decode/prefill builders, scheduler-routed
fan-out, and the continuous-batching ``RequestEngine`` (DESIGN.md §12)."""
from repro.serving.engine import EngineClosed, QueueFull, RequestEngine
from repro.serving.serve_step import (
    cache_to_rows,
    make_prefill,
    make_serve_engine,
    make_serve_fanout,
    make_serve_step,
    rows_to_cache,
    route_batches,
)

__all__ = [
    "RequestEngine",
    "QueueFull",
    "EngineClosed",
    "cache_to_rows",
    "make_prefill",
    "make_serve_engine",
    "make_serve_fanout",
    "make_serve_step",
    "rows_to_cache",
    "route_batches",
]
