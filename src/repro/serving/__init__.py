"""Serving layer: per-step decode/prefill builders, scheduler-routed
fan-out, the continuous-batching ``RequestEngine`` (DESIGN.md §12), and
the paged-KV prefill/decode-disaggregated ``PagedServeEngine`` (§15)."""
from repro.serving.engine import EngineClosed, LanePolicy, QueueFull, RequestEngine
from repro.serving.paged import (
    OutOfPages,
    PagedKVCache,
    PagedServeEngine,
    PagePool,
    PageSpec,
    SamplingParams,
    SeqPages,
    sample_token,
)
from repro.serving.serve_step import (
    cache_to_rows,
    make_prefill,
    make_serve_engine,
    make_serve_fanout,
    make_serve_step,
    rows_to_cache,
    route_batches,
)

__all__ = [
    "RequestEngine",
    "QueueFull",
    "EngineClosed",
    "LanePolicy",
    "PageSpec",
    "PagePool",
    "PagedKVCache",
    "PagedServeEngine",
    "SamplingParams",
    "SeqPages",
    "OutOfPages",
    "sample_token",
    "cache_to_rows",
    "make_prefill",
    "make_serve_engine",
    "make_serve_fanout",
    "make_serve_step",
    "rows_to_cache",
    "route_batches",
]
