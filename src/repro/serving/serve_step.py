"""Serving steps: prefill (forward + KV cache) and greedy decode.

``serve_step`` is the unit the decode_* dry-run cells lower: one new token
against a KV cache of ``seq_len`` (donated, updated in place by XLA).

Batch fan-out (DESIGN.md §9): independent serving batches are routed
through the placement scheduler — ``route_batches`` asks the policy for a
device per batch (load for ``least_loaded``, resident bytes for
``affinity``), percolates the batch there, and runs it on that device's
ops queue.  ``make_serve_fanout`` specializes this to decode steps.

Continuous batching (DESIGN.md §12): ``route_batches`` fans out batches
the *caller* already assembled; ``make_serve_engine`` builds the
``repro.serving.engine.RequestEngine`` that assembles them — individual
decode requests are admitted, micro-batched, placed and resolved per
caller.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import get_model


def make_serve_step(cfg, plan=None):
    """Returns ``serve_step(params, cache, tokens, pos) -> (next_tokens,
    logits, cache)`` — greedy decode of one token.

    All three documented values are returned: the greedy token, the raw
    logits (callers sample / compute logprobs from them), and the updated
    KV cache."""
    m = get_model(cfg)

    def serve_step(params, cache, tokens, pos):
        logits, cache = m.decode_step(cfg, params, cache, tokens, pos)
        nxt = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        return nxt, logits, cache

    return serve_step


def make_prefill(cfg, plan=None):
    """Returns ``prefill(params, batch) -> (logits_last, kv)``."""
    m = get_model(cfg)
    q_block = getattr(plan, "q_block", 512)

    def prefill(params, batch):
        out = m.forward(cfg, params, batch, q_block=q_block, return_kv=True, last_only=True)
        logits, _aux, kv = out
        return logits, kv

    return prefill


def route_batches(fn, batches, scheduler=None, percolate: bool = True, cluster=None):
    """Fan independent batches across devices via the placement scheduler.

    For each batch (any pytree of arrays) the scheduler picks a device —
    scoring the batch's leaves, so ``affinity`` keeps cache-resident
    requests where their bytes already live — the batch is percolated
    there (``percolate=False`` trusts the caller's placement) and
    ``fn(batch)`` runs on that device's ops queue.  Returns one future
    per batch; join with ``repro.core.wait_all``.

    Cluster fan-out (DESIGN.md §10): with ``cluster`` (a ``Parcelport``)
    the fleet widens to every remote locality.  A batch placed on a
    cross-process locality ships as one ``apply`` parcel — which requires
    ``fn`` to be a registered **kernel name** (str), since a closure
    cannot cross the process boundary; in-process transports (loopback)
    and local devices accept callables as before.
    """
    import numpy as np

    from repro.core.scheduler import get_scheduler

    if scheduler is not None:
        sched = scheduler
    elif cluster is not None:
        sched = cluster.scheduler()
    else:
        sched = get_scheduler()
    kernel_name = fn if isinstance(fn, str) else None
    local_fn = fn
    if kernel_name is not None:
        from repro.core.parcel import resolve_kernel

        local_fn = resolve_kernel(kernel_name)
    futs = []
    for b in batches:
        dev = sched.select(args=jax.tree_util.tree_leaves(b))
        if getattr(dev, "is_remote_proxy", False) and not dev._port.in_process:
            if kernel_name is None:
                raise ValueError(
                    f"route_batches placed a batch on {dev.key}, a cross-process "
                    "locality, but fn is a closure: pass a registered kernel "
                    "name (str) so the work can travel as a parcel"
                )
            futs.append(dev._call(
                "apply", kernel=kernel_name, batch=jax.tree_util.tree_map(np.asarray, b)
            ))
            continue

        def _run(b=b, dev=dev):
            placed = jax.device_put(b, dev.jax_device) if percolate else b
            return local_fn(placed)

        futs.append(dev.ops_queue.submit(_run))
    return futs


def cache_to_rows(cache, batch_axis: int = 1):
    """Model-layout KV cache -> engine request layout (batch axis moved to
    the FRONT of every leaf, where ``RequestEngine`` concatenates).

    Dtype-preserving end to end, bf16/fp16 included: the engine keys and
    re-materializes leaves by ``np.dtype`` *instance* (not the char code,
    which ml_dtypes types lack), so a sub-fp32 cache round-trips through
    submit → batch → slice bit-identically.  The paged serving path
    (``repro.serving.paged``) does NOT go through these adapters at all —
    its KV never leaves the device as whole-cache rows; only page tables
    and tokens travel."""
    return jax.tree_util.tree_map(lambda a: jnp.moveaxis(a, batch_axis, 0), cache)


def rows_to_cache(cache, batch_axis: int = 1):
    """Inverse of ``cache_to_rows``."""
    return jax.tree_util.tree_map(lambda a: jnp.moveaxis(a, 0, batch_axis), cache)


def make_serve_engine(cfg, params, plan=None, cache_batch_axis: int = 1, **engine_kwargs):
    """A continuous-batching ``RequestEngine`` serving decode requests for
    one model (DESIGN.md §12).

    Each request is ``{"cache": cache_to_rows(kv), "tokens": (b, 1)
    int32, "pos": 0-d int32}`` — the per-sequence slice of
    ``serve_step``'s state (``b`` is usually 1).  Model caches batch
    along ``cache_batch_axis`` (axis 1 in this repo's layer-major
    layouts), so requests carry them through ``cache_to_rows`` — the
    engine batches over the leading axis of every leaf.  The engine
    concatenates compatible requests (``pos`` is a broadcast leaf, so
    only same-position steps share a micro-batch), pads to a bucket,
    runs ONE jitted decode step, and resolves every caller's future with
    its slice of ``{"next", "logits", "cache"}`` (cache in request
    layout — feed it straight into the next ``submit``).

    ``params`` stay host-side shared state (closed over, passed as a jit
    argument per step), so the graph path is disabled by default — a
    fused replay would bake the weights into the executable as constants.
    """
    from repro.serving.engine import RequestEngine

    step = jax.jit(make_serve_step(cfg, plan))

    def decode(batch):
        cache = rows_to_cache(batch["cache"], cache_batch_axis)
        nxt, logits, cache = step(params, cache, batch["tokens"], batch["pos"])
        return {
            "next": nxt,
            "logits": logits,
            "cache": cache_to_rows(cache, cache_batch_axis),
        }

    engine_kwargs.setdefault("graph", False)
    engine_kwargs.setdefault("name", f"serve:{getattr(cfg, 'name', 'model')}")
    return RequestEngine({"decode": decode}, **engine_kwargs)


def make_serve_fanout(cfg, plan=None):
    """Scheduler-routed decode: returns ``fanout(requests, scheduler=None)``
    where each request is a ``(params, cache, tokens, pos)`` tuple; every
    request decodes one token on the device the policy places it on.
    Returns one future per request (value: ``(next_tokens, logits,
    cache)`` — the full ``serve_step`` contract)."""
    step = jax.jit(make_serve_step(cfg, plan))

    def fanout(requests, scheduler=None):
        return route_batches(lambda req: step(*req), requests, scheduler=scheduler)

    return fanout
