"""Serving steps: prefill (forward + KV cache) and greedy decode.

``serve_step`` is the unit the decode_* dry-run cells lower: one new token
against a KV cache of ``seq_len`` (donated, updated in place by XLA).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import get_model


def make_serve_step(cfg, plan=None):
    """Returns ``serve_step(params, cache, tokens, pos) -> (next_tokens,
    logits, cache)`` — greedy decode of one token."""
    m = get_model(cfg)

    def serve_step(params, cache, tokens, pos):
        logits, cache = m.decode_step(cfg, params, cache, tokens, pos)
        nxt = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        return nxt, cache

    return serve_step


def make_prefill(cfg, plan=None):
    """Returns ``prefill(params, batch) -> (logits_last, kv)``."""
    m = get_model(cfg)
    q_block = getattr(plan, "q_block", 512)

    def prefill(params, batch):
        out = m.forward(cfg, params, batch, q_block=q_block, return_kv=True, last_only=True)
        logits, _aux, kv = out
        return logits, kv

    return prefill
