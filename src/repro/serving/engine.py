"""Continuous-batching request engine on the futurized runtime (DESIGN.md §12).

The ROADMAP's north star — heavy traffic from many concurrent users — needs
a front door: callers submit *individual* requests, but accelerators only
stay utilized when those requests execute as batches.  ``RequestEngine`` is
that multiplexing layer, built directly on the runtime's own primitives
(futures, streams, the placement scheduler, graph replay, parcels) rather
than bolted on above them:

* **Admission queue + backpressure.**  ``submit`` enqueues one request and
  returns a ``Future`` immediately.  The queue is bounded: a full queue
  raises ``QueueFull`` at the call site (callers shed or retry — the
  overload signal is explicit, never an unbounded pile-up).  Pending
  requests can be ``cancel()``-ed through their future; cancelled entries
  are dropped at batch assembly.

* **Micro-batching.**  A batcher thread groups compatible requests —
  same kind, same pytree structure, same per-row leaf shapes/dtypes, equal
  broadcast (0-d) leaves — into micro-batches, bounded by ``max_batch``
  rows and a ``max_delay_s`` deadline from the oldest member's arrival.
  Batches are padded up to *bucketed* row counts (powers of two up to
  ``max_batch``), so the ``Program``/jit executable cache hits a handful
  of shapes instead of recompiling per occupancy.

* **Placement.**  Each micro-batch is routed through the placement
  scheduler as ONE decision (``Scheduler.select_batch``): the policy
  scores the union of every member's argument leaves, so ``affinity`` /
  ``percolation`` place the batch where most of its resident bytes (KV
  cache rows) already live, and the fleet may span local devices and
  cross-process localities (a cluster parcelport's scheduler).

* **Execution.**  On a local device the step runs as a captured
  ``TaskGraph`` replayed with feeds on an engine-owned stream
  (``exe.replay(feeds=..., stream=s)``): the whole H2D-feed → fused step
  sequence rides one dedicated lane, overlapping the device's default-lane
  traffic, and replays hit the instantiate-time compiled executable.  On a
  cross-process locality the batch ships as ONE ``apply_batched`` parcel
  (kernel referenced by name; the reply carries only real rows back).
  In-process proxies and untraceable steps fall back to a direct
  queue-submitted call — same results, no fused replay.

* **Per-request results.**  The batched output's leading axis is sliced
  back per member: every caller's future resolves with exactly its rows
  (host ``np.ndarray`` leaves, like ``enqueue_read``), bit-equal to
  running that request alone through the same step.

* **Metrics.**  ``metrics()`` snapshots request counts, batch/row/padding
  totals, queue depth + high water, latency p50/p99 and requests/s.

The engine serves any row-independent step function over a pytree whose
array leaves share a leading row axis — a greedy-decode step
(``make_serve_engine``), a prefill, or a plain kernel.  Correctness
contract: the step must be *row-independent* along the leading axis
(each request's rows computed independently), which is exactly what a
batched decode/prefill step is.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import dataclass
from typing import Any, Callable, Sequence

import jax
import numpy as np

from repro.core.executor import coalesce
from repro.core.futures import Future, Promise

__all__ = ["RequestEngine", "QueueFull", "EngineClosed", "LanePolicy"]


class QueueFull(RuntimeError):
    """Backpressure: the admission queue is at capacity — shed or retry."""


class EngineClosed(RuntimeError):
    """The engine no longer accepts (or will never run) this request."""


def _now() -> float:
    return time.monotonic()


@dataclass(frozen=True)
class LanePolicy:
    """Per-kind batching policy (prefill/decode disaggregation, §15).

    A serving engine's request kinds want different batching: *prefill*
    is throughput-bound — batch as many prompt tokens as fit a budget,
    tolerate a longer assembly window — while *decode* is latency-bound
    — dispatch at a tight deadline, rows are cheap.  ``None`` fields
    inherit the engine-wide default.

    ``token_budget`` bounds a batch by ``rows × tokens_per_row`` (the
    largest leading tail axis among the request's row leaves — for a
    ``(1, T)`` prompt leaf that is ``T``), so long prompts batch fewer
    rows and short ones more, instead of one row bound serving both.
    """

    max_batch: "int | None" = None
    max_delay_s: "float | None" = None
    token_budget: "int | None" = None


def _tokens_per_row(metas) -> int:
    """The token-budget denominator: the widest leading tail axis among
    the row leaves (1 when every row leaf is a bare vector)."""
    t = 1
    for m in metas:
        if m[0] == "row" and m[1]:
            t = max(t, int(m[1][0]))
    return t


class _Request:
    __slots__ = ("kind", "payload", "leaves", "treedef", "rows", "key",
                 "promise", "arrived")

    def __init__(self, kind, payload, leaves, treedef, rows, key, promise, arrived):
        self.kind = kind
        self.payload = payload
        self.leaves = leaves
        self.treedef = treedef
        self.rows = rows
        self.key = key
        self.promise = promise
        self.arrived = arrived

    @property
    def future(self) -> Future:
        return self.promise.get_future()


def _classify(kind: str, payload) -> "tuple[list, Any, int, tuple]":
    """(leaves, treedef, rows, batch key) of one request payload.

    Array leaves with ndim >= 1 are *row* leaves: they share a leading
    row axis (usually 1) that the engine concatenates over.  0-d and
    scalar leaves are *broadcast* leaves — shared by every row — and two
    requests only share a micro-batch when their broadcast values are
    bit-equal (the decode ``pos`` scalar is the canonical example: only
    same-position steps batch together).
    """
    leaves, treedef = jax.tree_util.tree_flatten(payload)
    rows: "int | None" = None
    metas = []
    for a in leaves:
        if hasattr(a, "shape") and getattr(a, "ndim", 0) >= 1:
            lead = int(a.shape[0])
            if rows is None:
                rows = lead
            elif lead != rows:
                raise ValueError(
                    f"request row leaves disagree on the leading axis: {lead} vs {rows}"
                )
            # Dtype OBJECTS, not `.str` codes: ml_dtypes types (bfloat16)
            # have no char code — np.dtype(bfloat16).str is the void
            # '<V2', which round-trips to raw bytes and breaks
            # concatenation.  np.dtype instances hash/compare by value,
            # so they key batches exactly as the strings did.
            metas.append(("row", tuple(int(d) for d in a.shape[1:]), np.dtype(a.dtype)))
        else:
            v = np.asarray(a)
            metas.append(("bcast", v.dtype, v.tobytes()))
    if rows is None:
        raise ValueError(
            "request payload has no array leaf with a leading row axis — "
            "the engine batches over axis 0"
        )
    if rows <= 0:
        raise ValueError("request payload has zero rows")
    return leaves, treedef, rows, (kind, treedef, tuple(metas))


class _GraphEntry:
    """One compiled replay route: (device, batch key, bucket) -> GraphExec."""

    __slots__ = ("exe", "wnodes", "lnode", "out_treedef", "n_out")

    def __init__(self, exe, wnodes, lnode, out_treedef, n_out):
        self.exe = exe
        self.wnodes = wnodes  # list of (leaf index, WriteNode)
        self.lnode = lnode
        self.out_treedef = out_treedef
        self.n_out = n_out


class RequestEngine:
    """Admission queue -> micro-batches -> scheduler-placed batched steps.

    Parameters
    ----------
    fn:
        The step, per request *kind*: a callable (local execution), a
        registered **kernel name** (str — required for placement on
        cross-process localities, exactly as ``route_batches``), or a
        ``{kind: callable|str}`` dict serving several request kinds (e.g.
        ``{"decode": ..., "prefill": ...}``) from one queue.
    max_batch:
        Micro-batch row bound (also the largest padding bucket).
    max_delay_s:
        Deadline: a batch dispatches when full OR this long after its
        oldest member arrived — the latency/throughput knob.
    max_queue:
        Admission bound; ``submit`` beyond it raises ``QueueFull``.
    scheduler / cluster:
        Placement, precedence as in ``route_batches``: explicit scheduler,
        else ``cluster.scheduler()`` (the localities × devices grid), else
        the process default.
    graph:
        Replay local batches as captured ``TaskGraph``s on an engine-owned
        stream (default).  ``False`` forces the direct jit path — the
        right choice when the step closes over large parameters (a fused
        graph would bake them into the executable as constants).
    lanes:
        Per-kind ``LanePolicy`` overrides (prefill/decode disaggregation,
        DESIGN.md §15): e.g. ``{"prefill": LanePolicy(token_budget=2048,
        max_delay_s=0.01), "decode": LanePolicy(max_delay_s=0.001)}``.
        Kinds without an entry use the engine-wide bounds.
    """

    def __init__(
        self,
        fn: "Callable | str | dict",
        *,
        max_batch: int = 8,
        max_delay_s: float = 0.002,
        max_queue: int = 256,
        scheduler=None,
        cluster=None,
        graph: bool = True,
        buckets: "Sequence[int] | None" = None,
        lanes: "dict[str, LanePolicy] | None" = None,
        name: str = "engine",
    ):
        from repro.core.parcel import resolve_kernel

        if not isinstance(fn, dict):
            fn = {fn if isinstance(fn, str) else "step": fn}
        self._fns: "dict[str, Callable]" = {}
        self._kernel_names: "dict[str, str | None]" = {}
        for kind, f in fn.items():
            if isinstance(f, str):
                self._fns[kind] = resolve_kernel(f)
                self._kernel_names[kind] = f
            else:
                self._fns[kind] = f
                self._kernel_names[kind] = None
        self.name = name
        self.max_batch = int(max_batch)
        self.max_delay_s = float(max_delay_s)
        self.max_queue = int(max_queue)
        self._scheduler = scheduler
        self._cluster = cluster
        self._graph_enabled = bool(graph)
        if buckets is None:
            b, buckets = 1, []
            while b < self.max_batch:
                buckets.append(b)
                b *= 2
            buckets.append(self.max_batch)
        self._buckets = sorted(set(int(b) for b in buckets))
        if self._buckets[-1] != self.max_batch:
            raise ValueError("largest bucket must equal max_batch")
        self._lanes: "dict[str, LanePolicy]" = dict(lanes or {})
        for kind in self._lanes:
            if kind not in self._fns:
                raise KeyError(f"lane policy for unknown kind {kind!r}")

        self._cv = threading.Condition()
        self._queue: "deque[_Request]" = deque()
        self._closed = False
        self._inflight = 0

        # Execution routes, built lazily per (device, key[, bucket]).
        self._route_lock = threading.Lock()
        self._graphs: "dict[tuple, _GraphEntry | None]" = {}  # None = don't graph
        self._streams: "dict[str, Any]" = {}

        # Sticky micro-batch homes: route key -> device key.  Passed to
        # ``Scheduler.select_batch`` as the ``prefer`` hint so
        # consecutive batches of one request stream stay on the device
        # whose caches they warmed.  The scheduler's own structural-yield
        # hysteresis (recent-free occupancy, ``prefer_slack``) is the
        # escape hatch: a genuinely backed-up home makes the hint lose,
        # and the home then follows whatever the policy actually picked.
        self._sticky: "dict[tuple, str]" = {}

        # Metrics (one lock; hot counters only).
        self._m_lock = threading.Lock()
        self._started = _now()
        self._submitted = 0
        self._completed = 0
        self._failed = 0
        self._cancelled = 0
        self._batches = 0
        self._rows = 0
        self._padded_rows = 0
        self._queue_hwm = 0
        self._latencies: "deque[float]" = deque(maxlen=4096)
        self._queue_waits: "deque[float]" = deque(maxlen=4096)

        self._thread = threading.Thread(
            target=self._loop, name=f"engine:{name}", daemon=True
        )
        self._thread.start()

    # -- submission surface --------------------------------------------------

    def submit(self, payload, kind: "str | None" = None) -> Future:
        """Enqueue one request; future of its slice of the batched result
        (host ``np.ndarray`` leaves).  Raises ``QueueFull`` when the
        admission queue is at capacity and ``EngineClosed`` after
        ``close()``.  The future supports ``cancel()`` until its batch
        dispatches."""
        if kind is None:
            if len(self._fns) != 1:
                raise ValueError(f"engine serves kinds {sorted(self._fns)}; pass kind=")
            kind = next(iter(self._fns))
        elif kind not in self._fns:
            raise KeyError(f"engine {self.name!r} serves no kind {kind!r}")
        leaves, treedef, rows, key = _classify(kind, payload)
        if rows > self.max_batch:
            # An oversize request could never be taken into any group —
            # admitting it would wedge the queue behind it forever.
            raise ValueError(
                f"request has {rows} rows but max_batch is {self.max_batch}: "
                "split it, or raise max_batch"
            )
        promise: Promise = Promise(name=f"{self.name}:{kind}")
        req = _Request(kind, payload, leaves, treedef, rows, key, promise, _now())
        with self._cv:
            if self._closed:
                raise EngineClosed(f"engine {self.name!r} is closed")
            if len(self._queue) >= self.max_queue:
                raise QueueFull(
                    f"engine {self.name!r} admission queue is full "
                    f"({self.max_queue} requests) — backpressure: shed or retry"
                )
            self._queue.append(req)
            depth = len(self._queue)
            self._cv.notify_all()
        with self._m_lock:
            self._submitted += 1
            if depth > self._queue_hwm:
                self._queue_hwm = depth
        return req.future

    def __enter__(self) -> "RequestEngine":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def close(self, cancel_pending: bool = False) -> None:
        """Stop admitting; drain.  Queued requests still execute (their
        callers hold futures) unless ``cancel_pending`` fails them fast
        with ``EngineClosed``.  Blocks until in-flight batches resolve."""
        with self._cv:
            if self._closed:
                dropped = []
            else:
                self._closed = True
                dropped = list(self._queue) if cancel_pending else []
                if cancel_pending:
                    self._queue.clear()
            self._cv.notify_all()
        for req in dropped:
            req.promise.set_exception(
                EngineClosed(f"engine {self.name!r} closed before this request ran")
            )
        self._thread.join(timeout=60)
        with self._cv:
            while self._inflight:
                self._cv.wait(timeout=0.1)

    def drain(self) -> None:
        """Block until the queue is empty and no batch is in flight."""
        with self._cv:
            while self._queue or self._inflight:
                self._cv.wait(timeout=0.05)

    # -- metrics -------------------------------------------------------------

    def metrics(self) -> dict:
        """Snapshot of serving counters and latency percentiles (seconds)."""
        with self._m_lock:
            lats = sorted(self._latencies)
            waits = sorted(self._queue_waits)
            m = {
                "requests_submitted": self._submitted,
                "requests_completed": self._completed,
                "requests_failed": self._failed,
                "requests_cancelled": self._cancelled,
                "batches": self._batches,
                "rows": self._rows,
                "padded_rows": self._padded_rows,
                # Padded ÷ real rows: the cost of pow-2 bucketing — what
                # the paged engine's exact-row decode batches eliminate.
                "padding_waste": (self._padded_rows / self._rows) if self._rows else 0.0,
                "queue_high_water": self._queue_hwm,
                "mean_batch_rows": (self._rows / self._batches) if self._batches else 0.0,
            }
        with self._cv:
            m["queue_depth"] = len(self._queue)
            m["inflight_batches"] = self._inflight
        # Fleet view (DESIGN.md §14): where batches landed and how busy the
        # devices look to the shared occupancy signal — the serving-side
        # window into the scheduler's rebalancing behaviour.
        try:
            sched = self._scheduler_for()
            m["placements"] = sched.stats()
            steal_stats = getattr(sched, "steal_stats", None)
            if callable(steal_stats):
                m["steals"] = steal_stats()["steals"]
            occupancy = {}
            for d in sched.devices():
                l = d.load()
                occupancy[d.key] = round(l.depth + getattr(l, "busy_ewma", 0.0), 4)
            m["fleet_occupancy"] = occupancy
        except Exception:  # noqa: BLE001 - metrics never fail the caller
            pass
        elapsed = max(_now() - self._started, 1e-9)
        m["elapsed_s"] = elapsed
        m["requests_per_s"] = m["requests_completed"] / elapsed
        if lats:
            m["latency_p50_s"] = lats[int(0.50 * (len(lats) - 1))]
            m["latency_p99_s"] = lats[int(0.99 * (len(lats) - 1))]
        if waits:
            m["queue_wait_p50_s"] = waits[int(0.50 * (len(waits) - 1))]
            m["queue_wait_p99_s"] = waits[int(0.99 * (len(waits) - 1))]
        return m

    # -- batcher -------------------------------------------------------------

    def _bucket(self, rows: int) -> int:
        for b in self._buckets:
            if rows <= b:
                return b
        return self._buckets[-1]

    def _lane_bounds(self, key) -> "tuple[int, float]":
        """(row cap, assembly deadline) for this batch key: the kind's
        ``LanePolicy`` when one was given — token budgets divide down to a
        row cap against the key's tokens-per-row — else the engine-wide
        bounds.  The cap never exceeds ``max_batch`` (the bucket roof)."""
        kind, _treedef, metas = key
        pol = self._lanes.get(kind)
        if pol is None:
            return self.max_batch, self.max_delay_s
        cap = pol.max_batch if pol.max_batch is not None else self.max_batch
        if pol.token_budget is not None:
            cap = min(cap, max(1, pol.token_budget // _tokens_per_row(metas)))
        delay = pol.max_delay_s if pol.max_delay_s is not None else self.max_delay_s
        return min(cap, self.max_batch), delay

    def _compatible_rows(self, key, cap: int) -> int:
        rows = 0
        for r in self._queue:
            if r.key == key:
                rows += r.rows
                if rows >= cap:
                    break
        return rows

    def _take_group(self, key, cap: int) -> "list[_Request]":
        """Pop the head-compatible requests (in order, skipping cancelled
        entries) up to ``cap`` rows; incompatible requests keep their
        queue position."""
        group: "list[_Request]" = []
        rows = 0
        kept: "deque[_Request]" = deque()
        cancelled = 0
        while self._queue:
            r = self._queue.popleft()
            if r.future.cancelled():
                cancelled += 1
                continue
            if r.key == key and rows + r.rows <= cap:
                group.append(r)
                rows += r.rows
            else:
                kept.append(r)
        self._queue.extend(kept)
        if cancelled:
            with self._m_lock:
                self._cancelled += cancelled
        return group

    def _loop(self) -> None:
        while True:
            with self._cv:
                while not self._queue and not self._closed:
                    self._cv.wait()
                if not self._queue:
                    return  # closed and drained
                head = self._queue[0]
                cap, delay = self._lane_bounds(head.key)
                # A request bigger than its lane's cap still fits max_batch
                # (submit checked); run it alone rather than wedging the queue.
                cap = max(cap, head.rows)
                deadline = head.arrived + delay
                while (
                    not self._closed
                    and self._compatible_rows(head.key, cap) < cap
                    and _now() < deadline
                ):
                    self._cv.wait(timeout=max(deadline - _now(), 0.0) or 0.0005)
                group = self._take_group(head.key, cap)
                if group:
                    self._inflight += 1
            if group:
                try:
                    # One dispatch makes several submissions (stream lane
                    # task, pool join, graph pre-reads): coalesce them so
                    # each target queue sees ONE enqueue per micro-batch.
                    # The scope closes before the loop re-enters cv.wait,
                    # so nothing staged ever outlives a dispatch.
                    with coalesce():
                        self._dispatch(group)
                except BaseException as e:  # noqa: BLE001 - engine must not die
                    self._finish(group, None, e)

    # -- dispatch ------------------------------------------------------------

    def _scheduler_for(self):
        if self._scheduler is not None:
            return self._scheduler
        if self._cluster is not None:
            return self._cluster.scheduler()
        from repro.core.scheduler import get_scheduler

        return get_scheduler()

    def _place_batch(self, sched, group: "list[_Request]"):
        """Place one micro-batch, sticky by route key.

        ``least_loaded`` alone sprays consecutive micro-batches of one
        request stream across the fleet: each batch's recent-placement
        charge makes its own home score busiest, so the next batch hops
        devices (self-repulsion), churning per-device executable/graph
        caches — why fig9's batched_8dev row lost to batched_1dev.  The
        fix rides the scheduler's own path: the route's last home goes in
        as ``select_batch``'s ``prefer`` hint, which holds unless the
        home is structurally busier than the policy's pick (occupancy
        hysteresis, recent-free) or the policy is not load-based.  There
        is deliberately no periodic re-ask: withholding the hint under a
        self-repelling load policy *always* migrates the stream (the
        home carries the recency charges its own batches deposited), so
        a forced probe is a forced lane-warmup every N batches, not a
        fair comparison.  The structural yield runs on every placement
        and is the only mover; when it fires, the home follows the
        device the policy actually picked."""
        rkey = self._route_key(group[0].key)
        with self._route_lock:
            prefer = self._sticky.get(rkey)
        try:
            dev = sched.select_batch([r.leaves for r in group], prefer=prefer)
        except TypeError:  # duck-typed scheduler without the prefer hint
            dev = sched.select_batch([r.leaves for r in group])
        with self._route_lock:
            self._sticky[rkey] = dev.key
        return dev

    @staticmethod
    def _concat_rows(group: "list[_Request]", i: int, meta, pad: int):
        """One row leaf, concatenated over members and zero-padded to the
        bucket (the single copy of the padding rule — stacking and graph
        feeds both go through here)."""
        arrs = [np.asarray(r.leaves[i]) for r in group]
        if pad:
            arrs.append(np.zeros((pad,) + meta[1], dtype=np.dtype(meta[2])))
        return np.concatenate(arrs, axis=0) if len(arrs) > 1 else arrs[0]

    def _stack(self, group: "list[_Request]", bucket: int):
        """Concatenate member leaves over axis 0 and pad to the bucket;
        broadcast leaves pass through from the first member (equal across
        the group by key construction).  Returns (np pytree, total rows)."""
        kind, treedef, metas = group[0].key
        total = sum(r.rows for r in group)
        pad = bucket - total
        out_leaves = []
        for i, meta in enumerate(metas):
            if meta[0] == "row":
                out_leaves.append(self._concat_rows(group, i, meta, pad))
            else:
                out_leaves.append(np.asarray(group[0].leaves[i]))
        return jax.tree_util.tree_unflatten(treedef, out_leaves), total

    def _dispatch(self, group: "list[_Request]") -> None:
        kind = group[0].kind
        dispatched = _now()
        with self._m_lock:
            for r in group:
                self._queue_waits.append(dispatched - r.arrived)
        sched = self._scheduler_for()
        try:
            dev = self._place_batch(sched, group)
        except BaseException as e:  # noqa: BLE001 - dead fleet fails the batch
            self._finish(group, None, e)
            return
        rows = sum(r.rows for r in group)
        bucket = self._bucket(rows)
        # select_batch logged ONE placement unit, but this batch is `rows`
        # of work that the direct-jit route never shows in any lane depth:
        # charge the remainder so a 32-row decode burst weighs 32, not 1,
        # in least_loaded's recent-placement signal (the §14 submit-path
        # fix, applied to the engine's own dispatch).
        charge = getattr(sched, "charge", None)
        if callable(charge) and rows > 1:
            charge(dev, rows - 1)

        from repro.core.executor import get_runtime

        pool = get_runtime().pool
        cross_process = getattr(dev, "is_remote_proxy", False) and not dev._port.in_process
        if cross_process:
            kernel = self._kernel_names.get(kind)
            if kernel is None:
                self._finish(group, None, ValueError(
                    f"engine placed a micro-batch on {dev.key}, a cross-process "
                    "locality, but its step is a closure: construct the engine "
                    "with a registered kernel name (str) so batches can travel "
                    "as apply_batched parcels"
                ))
                return
            batch, _total = self._stack(group, bucket)
            fut = dev._call(
                "apply_batched",
                kernel=kernel,
                batch=jax.tree_util.tree_map(np.asarray, batch),
                rows=[r.rows for r in group],
            )
            pool.submit(self._join_chunks, fut, group, bucket)
            return

        entry = self._graph_route(dev, group[0].key, bucket) if self._graph_enabled else None
        if entry is not None:
            metas = group[0].key[2]
            pad = bucket - sum(r.rows for r in group)
            feeds = {}
            for i, w in entry.wnodes:
                if metas[i][0] == "row":
                    feeds[w] = self._concat_rows(group, i, metas[i], pad)
                else:
                    # Broadcast leaves are write-fed 0-d buffers, NOT baked
                    # constants: one compiled route serves every value (a
                    # decode `pos` must not compile per token).
                    feeds[w] = np.asarray(group[0].leaves[i])
            fut = entry.exe.replay(feeds=feeds, stream=self._stream_for(dev))
            pool.submit(self._join_graph, fut, entry, group, bucket)
            return

        # Direct path: loopback proxies, graph=False, or untraceable steps.
        batch, _total = self._stack(group, bucket)
        fn = self._fns[kind]

        def _run(batch=batch, dev=dev, fn=fn):
            placed = jax.device_put(batch, dev.jax_device)
            return fn(placed)

        q = dev.ops_queue
        if not getattr(dev, "is_remote_proxy", False):
            q = self._stream_for(dev).lane
        fut = q.submit(_run)
        pool.submit(self._join_direct, fut, group, bucket)

    # -- execution routes ----------------------------------------------------

    def _stream_for(self, dev):
        """The engine's dedicated stream on ``dev`` (created on first use):
        micro-batch feeds and steps ride one lane, ordered among
        themselves, concurrent with the device's other streams."""
        with self._route_lock:
            s = self._streams.get(dev.key)
            if s is None:
                s = self._streams[dev.key] = dev.create_stream(f"engine.{self.name}")
            return s

    @staticmethod
    def _route_key(key) -> tuple:
        """Batch key with broadcast VALUES erased (dtype kept): the batch
        key gates which requests share a micro-batch (bit-equal broadcast
        leaves), but compiled routes are value-independent — broadcast
        leaves are fed at replay, so a decode ``pos`` that increments
        every token reuses ONE executable instead of compiling per value."""
        kind, treedef, metas = key
        return (kind, treedef, tuple(m if m[0] == "row" else ("bcast", m[1]) for m in metas))

    def _graph_route(self, dev, key, bucket) -> "_GraphEntry | None":
        """Captured-replay route for (device, route key, bucket), built
        once.  Returns None (and remembers the refusal) when the device is
        a proxy or the step cannot be traced into a fused executable."""
        if getattr(dev, "is_remote_proxy", False):
            return None
        cache_key = (dev.key, self._route_key(key), bucket)
        with self._route_lock:
            if cache_key in self._graphs:
                return self._graphs[cache_key]
        entry = None
        try:
            entry = self._build_graph(dev, key, bucket)
        except Exception:  # noqa: BLE001 - untraceable step: direct path
            entry = None
        with self._route_lock:
            entry = self._graphs.setdefault(cache_key, entry)
        return entry

    def _build_graph(self, dev, key, bucket) -> _GraphEntry:
        from repro.core.graph import TaskGraph
        from repro.core.program import Program

        kind, treedef, metas = key
        fn = self._fns[kind]

        def flat(*leaves):
            batch = jax.tree_util.tree_unflatten(treedef, list(leaves))
            return tuple(jax.tree_util.tree_leaves(fn(batch)))

        # Shape-infer the step's output structure (and fail fast on
        # steps that cannot trace with traced broadcast leaves — e.g. a
        # value read as a static bound — falling back to the direct path,
        # which passes the concrete values).
        specs = []
        for meta in metas:
            if meta[0] == "row":
                specs.append(jax.ShapeDtypeStruct((bucket,) + meta[1], np.dtype(meta[2])))
            else:
                specs.append(jax.ShapeDtypeStruct((), np.dtype(meta[1])))
        out_shape = jax.eval_shape(fn, jax.tree_util.tree_unflatten(treedef, list(specs)))
        out_leaves, out_treedef = jax.tree_util.tree_flatten(out_shape)

        prog = Program(dev, {kind: flat}, name=f"{self.name}:{kind}")
        g = TaskGraph(f"{self.name}:{kind}:b{bucket}")
        args, wnodes = [], []
        for i, meta in enumerate(metas):
            # EVERY leaf is a write-fed buffer — row leaves bucket-shaped,
            # broadcast leaves 0-d — so one compiled route serves every
            # broadcast value (fed per replay, never baked as a constant).
            if meta[0] == "row":
                shape, dt = (bucket,) + meta[1], np.dtype(meta[2])
            else:
                shape, dt = (), np.dtype(meta[1])
            buf = dev.create_buffer(shape, dt).get()
            wnodes.append((i, g.write(buf, None)))
            args.append(buf)
        lnode = g.run(prog, args, kind)
        exe = g.instantiate()
        return _GraphEntry(exe, wnodes, lnode, out_treedef, len(out_leaves))

    # -- joins (pool tasks: block on the batch future, slice, resolve) --------

    def _join_graph(self, fut, entry: _GraphEntry, group, bucket) -> None:
        try:
            res = fut.get()
            vals = res[entry.lnode]
            leaves = [vals] if entry.n_out == 1 else list(vals)
            out = jax.tree_util.tree_unflatten(
                entry.out_treedef, [np.asarray(v) for v in leaves]
            )
        except BaseException as e:  # noqa: BLE001 - errors fan to every member
            self._finish(group, None, e, bucket)
            return
        self._finish(group, out, None, bucket)

    def _join_direct(self, fut, group, bucket) -> None:
        try:
            out = jax.tree_util.tree_map(np.asarray, fut.get())
        except BaseException as e:  # noqa: BLE001
            self._finish(group, None, e, bucket)
            return
        self._finish(group, out, None, bucket)

    def _join_chunks(self, fut, group, bucket) -> None:
        """Cross-locality reply: one pre-sliced chunk per member request."""
        try:
            chunks = fut.get()
        except BaseException as e:  # noqa: BLE001
            self._finish(group, None, e, bucket)
            return
        done = _now()
        for req, chunk in zip(group, chunks):
            req.promise.set_value(chunk)
        self._note_done(group, done, bucket, failed=False)

    def _finish(self, group, out, exc, bucket: "int | None" = None) -> None:
        done = _now()
        if exc is not None:
            for req in group:
                req.promise.set_exception(exc)
        else:
            off = 0
            for req in group:
                sl = jax.tree_util.tree_map(
                    lambda a, o=off, n=req.rows: a[o : o + n] if getattr(a, "ndim", 0) >= 1 else a,
                    out,
                )
                req.promise.set_value(sl)
                off += req.rows
        self._note_done(group, done, bucket, failed=exc is not None)

    def _note_done(self, group, done, bucket, failed: bool) -> None:
        rows = sum(r.rows for r in group)
        with self._m_lock:
            self._batches += 1
            self._rows += rows
            if bucket is not None:
                self._padded_rows += max(bucket - rows, 0)
            if failed:
                self._failed += len(group)
            else:
                self._completed += len(group)
                for r in group:
                    self._latencies.append(done - r.arrived)
        with self._cv:
            self._inflight -= 1
            self._cv.notify_all()

    def __repr__(self) -> str:
        m = self.metrics()
        return (
            f"RequestEngine({self.name}: {m['requests_completed']}/{m['requests_submitted']} "
            f"served, {m['batches']} batches, depth={m['queue_depth']})"
        )
