"""Paged KV cache + prefill/decode disaggregation (DESIGN.md §15).

The continuous-batching engine (§12) moves every request's whole KV cache
through each micro-batch: mixed-length sequences never share a batch (the
batch key includes the cache shape), rows are padded to pow-2 buckets,
and migrating a sequence repatriates megabytes.  That is why the fleet
*lost* to one device in fig9.  This module applies the GPU-virtualization
lesson (Li et al., arXiv:1511.07658): many clients share a device only
when their state is partitioned into fixed-size schedulable units.

* ``PagePool`` — one per device: two slab ``Buffer``s (k and v) of shape
  ``(layers, num_pages, page_size, kv_heads, head_dim)`` plus a free
  list.  Page 0 is *reserved* as the padding target: page-table slots
  past a sequence's tail must hold a valid index (the paged-attention
  kernel DMAs them before masking), so they all point at page 0 and no
  live sequence ever owns it.

* **Honest accounting.**  The slabs re-register under AGAS kind
  ``"pool"`` with 0 bytes — slab *capacity* is not memory pressure, and
  the LRU spiller must never evict a whole pool.  What counts is usage:
  every sequence is a ``SeqPages`` record (AGAS kind ``"buffer"``,
  ``nbytes`` = its pages × page bytes, re-declared through
  ``Registry.update_nbytes`` on every alloc/free/spill).  The §14
  memory-aware scheduler therefore sees page pressure per device, and
  its existing ``spill_lru`` evicts *cold sequences'* pages (host copy +
  pages returned to the pool), never the hot ones it placed work next to.

* ``PagedKVCache`` — the fleet-wide allocator: per-device pools,
  sequence lifecycle (``new_seq`` / ``append`` / ``free_seq``),
  ``defrag`` (compact a pool's live pages to the low slots),
  ``migrate`` (re-home a sequence's pages to another device in ONE
  coalesced move — all pages travel as one stacked array per slab, not
  one transfer per page), and ``table`` (page tables + lengths in the
  kernel's layout).

* ``PagedServeEngine`` — prefill/decode disaggregation.  Prefill is a
  throughput lane: prompts batch up to a token budget
  (``LanePolicy.token_budget``), the placement scheduler picks the
  sequence's home device (memory veto included), and the prompt's KV is
  paged in once.  Decode is a latency lane *per device*: exact-row
  batches of every active resident sequence — no row padding at all
  (``padding_waste`` ≈ 0), mixed lengths share one step because the page
  table, not the batch shape, encodes length — stepped continuously with
  a deadline-bounded wait for new arrivals.  Page-table width and pool
  shapes are static, so the jitted step stays hot across steps.  Every
  step charges the scheduler's recent-placement counter
  (``Scheduler.charge``) so ``least_loaded`` sees decode bursts that
  never touch a lane queue; every ``rebalance_every`` steps the lane
  asks ``Scheduler.select_batch`` (affinity over the ``SeqPages``
  records) whether its sequences still belong here — a different answer
  migrates one sequence, pages percolating in one coalesced move.

The **legacy** model contract is two callables (see ``make_paged_lm`` in
``benchmarks/fig9_serving.py`` or ``examples/paged_serving.py``):

``prefill_fn(tokens)``
    ``(B, T) int32 -> (k, v, next)`` with k/v ``(B, L, T, K, D)`` and
    ``next`` ``(B,) int32`` — the prompt's KV plus the first token.
``decode_fn(k_pages, v_pages, tokens, positions, tables, lengths)``
    one decode step over the *pools*: scatter each row's incoming
    token's k/v into ``pages[tables[b, pos // P], pos % P]``, attend
    through the page table (``repro.kernels.paged_attention``), return
    ``(k_pages, v_pages, next)``.  Donating the pool args keeps the
    update in place.

The model **zoo** rides the richer ``contract="zoo"`` (DESIGN.md §17),
wired by ``PagedServeEngine.from_config(cfg)`` from the uniform
``repro.models.model.paged_surface`` triple:

``prefill_fn(tokens, extras)``
    ``-> (k, v, state, last_logits)`` with k/v ``(B, L, T', K, D)`` —
    ``T'`` may exceed the prompt length (hybrid meta/register tokens
    page in too; the engine pages ``k.shape[2]`` tokens) — ``state`` an
    optional batch-leading pytree of fixed-size per-sequence residue
    (SSM recurrent state, conv windows, encoder cross K/V) and
    ``last_logits`` ``(B, V)``: the engine samples the first token
    host-side.  ``extras`` carries modality inputs (whisper frames),
    stacked from each request's ``submit(..., extras=...)``.
``decode_fn(k_pages, v_pages, state, tokens, positions, tables, lengths)``
    ``-> (k_pages, v_pages, state, logits)`` — one ragged step over the
    pools plus the batch's stacked resident state; ``logits`` ``(B, V)``
    come back to the host for sampling.

Resident state spills, migrates and ships with the sequence's pages
(``SeqPages.set_state`` folds its bytes into the AGAS record — the §14
memory-aware scheduler sees SSM state as honestly as KV pages), and
sampling is host-side and bit-reproducible: token ``position`` of
request ``request_id`` draws from
``np.random.default_rng([seed, request_id, position])`` — a pure
function of request identity, never of batch composition or fleet size
(greedy argmax when ``temperature <= 0``).

Env knobs: ``REPRO_PAGE_SIZE`` (tokens per page, default 16),
``REPRO_PAGE_POOL_BYTES`` (per-device pool bytes, default 32 MiB),
``REPRO_PREFILL_TOKEN_BUDGET`` (prefill lane batch bound, default 2048),
``REPRO_DECODE_DEADLINE_S`` (decode lane arrival wait, default 1 ms).
"""
from __future__ import annotations

import concurrent.futures as _cf
import contextlib
import functools
import os
import threading
import time
import weakref
from dataclasses import dataclass
from typing import Any, Callable, Sequence

import jax
import numpy as np

from repro.core import agas
from repro.core.executor import coalesce
from repro.core.futures import Future, Promise
from repro.serving.engine import EngineClosed, LanePolicy, QueueFull

__all__ = [
    "PageSpec",
    "PagePool",
    "PagedKVCache",
    "PagedServeEngine",
    "SamplingParams",
    "SeqPages",
    "OutOfPages",
    "sample_token",
]


def _env_int(name: str, default: int) -> int:
    try:
        return int(os.environ.get(name, default))
    except ValueError:
        return default


def _now() -> float:
    return time.monotonic()


class OutOfPages(RuntimeError):
    """The pool has fewer free pages than the allocation needs."""


@dataclass(frozen=True)
class PageSpec:
    """Geometry of one KV page: ``page_size`` tokens × ``kv_heads`` ×
    ``head_dim`` per layer, k and v both.  Pass ``page_size=0`` to take
    ``REPRO_PAGE_SIZE`` (default 16)."""

    layers: int
    page_size: int
    kv_heads: int
    head_dim: int
    dtype: Any = np.float32

    def __post_init__(self):
        if not self.page_size:
            object.__setattr__(
                self, "page_size", _env_int("REPRO_PAGE_SIZE", 16))

    @property
    def page_bytes(self) -> int:
        """Bytes one page pins across both slabs (k + v, all layers)."""
        return (2 * self.layers * self.page_size * self.kv_heads
                * self.head_dim * np.dtype(self.dtype).itemsize)

    def pages_for(self, tokens: int) -> int:
        return max(0, -(-int(tokens) // self.page_size))


@dataclass(frozen=True)
class SamplingParams:
    """Per-request sampling knobs (zoo contract).

    ``temperature <= 0`` means greedy argmax (the default, and the
    parity-oracle mode).  ``top_k``/``top_p`` filter the distribution
    after temperature scaling: keep the ``top_k`` highest-probability
    tokens (0 = unlimited), then the smallest prefix of the descending
    distribution whose cumulative probability reaches ``top_p``.
    ``seed`` keys the per-request PRNG stream."""

    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    seed: int = 0


def sample_token(logits, params: "SamplingParams | None",
                 request_id: int, position: int) -> int:
    """Sample ONE token from a ``(V,)`` logits row, bit-reproducibly.

    The PRNG is seeded ``[seed, request_id, position]`` — a pure
    function of the request's identity and the token's position, so the
    same request emits the same tokens whether it shared its decode
    batch with 0 or 63 neighbours and whether the fleet had 1 or 8
    devices.  Math is float64 on host: no accelerator, dtype or fusion
    variance can leak into the draw."""
    logits = np.asarray(logits, np.float64).reshape(-1)
    if params is None or params.temperature <= 0.0:
        return int(np.argmax(logits))
    x = logits / float(params.temperature)
    order = np.argsort(-x, kind="stable")  # stable: ties break by token id
    xs = x[order]
    keep = xs.size
    if params.top_k and params.top_k > 0:
        keep = min(keep, int(params.top_k))
    xs = xs[:keep]
    probs = np.exp(xs - xs.max())
    probs /= probs.sum()
    if params.top_p < 1.0:
        cum = np.cumsum(probs)
        # smallest prefix reaching top_p (always >= 1 token)
        cut = int(np.searchsorted(cum, params.top_p, side="left")) + 1
        probs = probs[:cut]
        probs /= probs.sum()
    rng = np.random.default_rng(
        [int(params.seed), int(request_id), int(position)])
    u = rng.random()
    idx = int(np.searchsorted(np.cumsum(probs), u, side="right"))
    idx = min(idx, probs.size - 1)
    return int(order[idx])


# Consecutive empty decode steps (nothing fits in the pool) tolerated
# before the lane declares the working set unservable and fails the
# stalled batch.  At the 2ms stall backoff this is ~1s of zero progress.
_MAX_DECODE_STALLS = 500


def _pow2_pad_idx(idx: np.ndarray) -> np.ndarray:
    """Pad a page-index vector to the next power-of-two length by
    repeating the last entry, bounding the distinct shapes the jitted
    slab gather/scatter ever compile to log2(max pages per move)."""
    n = idx.size
    want = 1
    while want < n:
        want *= 2
    if want == n:
        return idx
    return np.concatenate([idx, np.repeat(idx[-1:], want - n)])


@functools.partial(jax.jit, donate_argnums=(0,))
def _slab_scatter(slab, idx, vals):
    return slab.at[:, idx].set(vals)


@jax.jit
def _slab_gather(slab, idx):
    return slab[:, idx]


class PagePool:
    """Per-device page pool: two slab Buffers + a free list.

    All slab mutation happens under ``lock`` — the prefill lane (paging
    a prompt in), the decode lane (swapping the stepped slabs back) and
    the spiller (reading a victim's pages out) race otherwise.
    """

    def __init__(self, device, spec: PageSpec, num_pages: int):
        if num_pages < 2:
            raise ValueError("PagePool needs >= 2 pages (page 0 is reserved)")
        self.device = device
        self.spec = spec
        self.num_pages = int(num_pages)
        shape = (spec.layers, self.num_pages, spec.page_size,
                 spec.kv_heads, spec.head_dim)
        self.k_slab = device.create_buffer(shape, spec.dtype).get()
        self.v_slab = device.create_buffer(shape, spec.dtype).get()
        for b in (self.k_slab, self.v_slab):
            self._repin(b)
        self.lock = threading.RLock()
        self._free: "list[int]" = list(range(self.num_pages - 1, 0, -1))

    @staticmethod
    def _repin(buf) -> None:
        """Move a slab's AGAS record to kind ``"pool"`` at 0 bytes: the
        slab must be invisible to ``spill_lru`` (kind filter) and to the
        resident-bytes pressure signal — usage is accounted per sequence
        (``SeqPages``), capacity is not pressure."""
        agas.registry.unregister(buf.gid)
        if buf._finalizer is not None:
            buf._finalizer.detach()
        buf.gid = agas.registry.register(
            buf,
            agas.Placement(buf.device.key, buf.device.jax_device.process_index),
            kind="pool",
            nbytes=0,
        )
        buf._finalizer = weakref.finalize(buf, agas.registry.unregister, buf.gid)

    # -- allocation ----------------------------------------------------------

    def alloc(self, n: int) -> "list[int]":
        with self.lock:
            if n > len(self._free):
                raise OutOfPages(
                    f"{self.device.key}: need {n} page(s), {len(self._free)} free "
                    f"of {self.num_pages - 1}"
                )
            return [self._free.pop() for _ in range(n)]

    def free(self, pages: "Sequence[int]") -> None:
        with self.lock:
            for p in pages:
                if not 0 < p < self.num_pages:
                    raise ValueError(f"page {p} is not an allocatable page of this pool")
                if p in self._free:
                    raise ValueError(f"double free of page {p} on {self.device.key}")
                self._free.append(p)

    @property
    def num_free(self) -> int:
        with self.lock:
            return len(self._free)

    @property
    def used_pages(self) -> int:
        return (self.num_pages - 1) - self.num_free

    # -- slab views ----------------------------------------------------------

    def arrays(self) -> "tuple[jax.Array, jax.Array]":
        with self.lock:
            return self.k_slab.array(), self.v_slab.array()

    def set_arrays(self, k, v) -> None:
        """Swap the stepped slabs back in (decode returns whole pools —
        donation made the update in-place on device)."""
        with self.lock:
            self.k_slab._set_array(k)
            self.v_slab._set_array(v)

    def write_pages(self, pages: "Sequence[int]", k, v) -> None:
        """Scatter page contents into the slabs: k/v are
        ``(n, L, P, Kh, D)`` host or device arrays, one row per page.

        Runs through a jitted, slab-donating scatter with the page count
        padded to a power of two (duplicate trailing index, same value —
        a benign rewrite): eager ``.at[].set`` would copy the whole slab
        AND recompile for every distinct page count."""
        n = len(pages)
        if n == 0:
            return
        idx = _pow2_pad_idx(np.asarray(pages, np.int32))
        kk = np.moveaxis(np.asarray(k), 0, 1)
        vv = np.moveaxis(np.asarray(v), 0, 1)
        if idx.size != n:
            kk = np.concatenate([kk, np.repeat(kk[:, -1:], idx.size - n, axis=1)], axis=1)
            vv = np.concatenate([vv, np.repeat(vv[:, -1:], idx.size - n, axis=1)], axis=1)
        dev = self.device.jax_device
        with self.lock:
            ks, vs = self.k_slab.array(), self.v_slab.array()
            idxd = jax.device_put(idx, dev)
            self.k_slab._set_array(_slab_scatter(ks, idxd, jax.device_put(kk, dev)))
            self.v_slab._set_array(_slab_scatter(vs, idxd, jax.device_put(vv, dev)))

    def read_pages(self, pages: "Sequence[int]") -> "tuple[np.ndarray, np.ndarray]":
        """Gather page contents out: ``(n, L, P, Kh, D)`` host arrays.
        Jitted gather, page count padded to a power of two (extra rows
        sliced off) — same compile-churn guard as ``write_pages``."""
        n = len(pages)
        if n == 0:
            sh = (0, self.spec.layers, self.spec.page_size,
                  self.spec.kv_heads, self.spec.head_dim)
            return np.empty(sh, self.spec.dtype), np.empty(sh, self.spec.dtype)
        idx = _pow2_pad_idx(np.asarray(pages, np.int32))
        with self.lock:
            ks, vs = self.k_slab.array(), self.v_slab.array()
            idxd = jax.device_put(idx, self.device.jax_device)
            kg, vg = _slab_gather(ks, idxd), _slab_gather(vs, idxd)
        return (np.moveaxis(np.asarray(kg), 1, 0)[:n],
                np.moveaxis(np.asarray(vg), 1, 0)[:n])

    def __repr__(self) -> str:
        return (f"PagePool({self.device.key}: {self.used_pages}/"
                f"{self.num_pages - 1} pages used)")


class SeqPages:
    """One sequence's pages: the AGAS-visible unit of KV residency.

    Registered kind ``"buffer"`` with ``nbytes`` = pages × page bytes
    (re-declared on every alloc/free), exposing ``gid``/``device``/
    ``nbytes`` so the §9 affinity scoring, the §14 memory veto AND
    ``spill_lru`` all see sequences as first-class residents: the
    scheduler places decode where a sequence's pages live, and evicts the
    least-recently-*decoded* sequence under pressure.  ``spill`` copies
    the pages to host RAM and returns them to the pool (record moves to
    ``agas.HOST_KEY``); ``ensure_resident`` re-allocates and writes back.
    """

    def __init__(self, cache: "PagedKVCache", pool: PagePool, seq_id: int):
        self._cache = cache
        self.pool = pool
        self.seq_id = seq_id
        self.pages: "list[int]" = []
        self.length = 0
        # Per-sequence resident state (zoo contract): an opaque pytree of
        # host arrays — SSM recurrent state, conv windows, cross K/V —
        # that rides with the pages through spill/migrate/export.  Its
        # bytes fold into ``nbytes`` so the memory-aware scheduler and
        # the LRU spiller see recurrent residency as honestly as KV.
        self.state: Any = None
        self._state_bytes = 0
        self._spilled: "tuple[np.ndarray, np.ndarray] | None" = None
        self._lock = threading.RLock()
        self._last_use = _now()
        dev = pool.device
        self.gid = agas.registry.register(
            self, agas.Placement(dev.key, dev.jax_device.process_index),
            kind="buffer", nbytes=0,
        )
        self._finalizer = weakref.finalize(self, agas.registry.unregister, self.gid)

    @property
    def device(self):
        return self.pool.device

    @property
    def nbytes(self) -> int:
        """Device-resident bytes: pages plus the recurrent state (which
        lives with the sequence — spilled sequences pin nothing)."""
        n = len(self.pages) * self.pool.spec.page_bytes
        if self._spilled is None:
            n += self._state_bytes
        return n

    @property
    def spilled(self) -> bool:
        return self._spilled is not None

    def set_state(self, state) -> None:
        """Attach/replace the sequence's resident state (zoo contract)
        and re-declare its bytes through AGAS — SSM/hybrid recurrent
        state is real device pressure the §14 spill and memory-aware
        placement must see, not a hidden side-car."""
        with self._lock:
            self.state = state
            self._state_bytes = sum(
                int(a.nbytes) for a in jax.tree_util.tree_leaves(state)
                if hasattr(a, "nbytes"))
            self._account()

    def _account(self) -> None:
        try:
            agas.registry.update_nbytes(self.gid, self.nbytes)
        except KeyError:  # freed under a racing finalizer
            pass

    # -- spill / refetch (scheduler-driven, DESIGN.md §14) -------------------

    def spill(self) -> Future:
        """Evict to host RAM (future of True when pages were released):
        page contents copy out, the pages return to the pool's free list,
        and the AGAS record moves to ``HOST_KEY`` — device page pressure
        drops immediately, exactly like ``Buffer.spill``."""
        return self.pool.device.ops_queue.submit(self._spill_now)

    def _spill_now(self) -> bool:
        with self._lock:
            if self._spilled is not None or not self.pages:
                return False
            self._spilled = self.pool.read_pages(self.pages)
            self.pool.free(self.pages)
            self.pages = []
            agas.registry.update_placement(
                self.gid,
                agas.Placement(agas.HOST_KEY, self.pool.device.jax_device.process_index),
            )
            self._account()
            return True

    def ensure_resident(self) -> None:
        """Refetch after a spill: re-allocate (page ids may differ — the
        handle is the identity, not the page numbers) and write the host
        copy back."""
        with self._lock:
            if self._spilled is None:
                return
            k, v = self._spilled
            pages = self.pool.alloc(len(k))
            self.pool.write_pages(pages, k, v)
            self.pages = pages
            self._spilled = None
            dev = self.pool.device
            agas.registry.update_placement(
                self.gid, agas.Placement(dev.key, dev.jax_device.process_index))
            self._account()
            self._last_use = _now()

    def __repr__(self) -> str:
        state = "spilled" if self.spilled else self.pool.device.key
        return (f"SeqPages(#{self.seq_id}: {self.length} tok / "
                f"{len(self.pages)} pages @ {state})")


class PagedKVCache:
    """Fleet-wide paged KV allocator: one ``PagePool`` per device plus
    the sequence lifecycle (``new_seq``/``append``/``free_seq``), pool
    compaction (``defrag``) and coalesced cross-device ``migrate``."""

    def __init__(self, spec: PageSpec, devices: "Sequence | None" = None,
                 pool_pages: "int | None" = None,
                 pool_bytes: "int | None" = None):
        if devices is None:
            from repro.core.device import get_all_devices

            devices = list(get_all_devices().get())
        if pool_pages is None:
            if pool_bytes is None:
                pool_bytes = _env_int("REPRO_PAGE_POOL_BYTES", 32 << 20)
            pool_pages = max(2, pool_bytes // spec.page_bytes)
        self.spec = spec
        self.pools: "dict[str, PagePool]" = {
            d.key: PagePool(d, spec, pool_pages) for d in devices
        }
        self._seq_lock = threading.Lock()
        self._next_seq = 0
        self._seqs: "dict[int, SeqPages]" = {}

    def pool_of(self, device) -> PagePool:
        try:
            return self.pools[device.key]
        except KeyError:
            raise KeyError(f"no page pool on {device.key}") from None

    # -- sequence lifecycle --------------------------------------------------

    def new_seq(self, device) -> SeqPages:
        pool = self.pool_of(device)
        with self._seq_lock:
            sid = self._next_seq
            self._next_seq += 1
            seq = self._seqs[sid] = SeqPages(self, pool, sid)
        return seq

    def append(self, seq: SeqPages, k, v) -> None:
        """Page ``T`` new tokens in: k/v are ``(L, T, Kh, D)``.  Partial
        tail pages are zero-padded to the page boundary (masked by
        ``length`` at attention time)."""
        seq.ensure_resident()
        k = np.asarray(k)
        v = np.asarray(v)
        L, T, Kh, D = k.shape
        P = self.spec.page_size
        with seq._lock:
            if seq.length % P:
                raise ValueError(
                    "append must start on a page boundary (decode steps append "
                    "token-at-a-time inside decode_fn, not through append)"
                )
            n = self.spec.pages_for(T)
            pages = seq.pool.alloc(n)
            pad = n * P - T
            if pad:
                k = np.concatenate([k, np.zeros((L, pad, Kh, D), k.dtype)], axis=1)
                v = np.concatenate([v, np.zeros((L, pad, Kh, D), v.dtype)], axis=1)
            # (L, n*P, Kh, D) -> (n, L, P, Kh, D): one write per append.
            seq.pool.write_pages(
                pages,
                np.moveaxis(k.reshape(L, n, P, Kh, D), 1, 0),
                np.moveaxis(v.reshape(L, n, P, Kh, D), 1, 0),
            )
            seq.pages.extend(pages)
            seq.length += T
            seq._last_use = _now()
            seq._account()

    def ensure_slot(self, seq: SeqPages) -> None:
        """Grow the sequence by one page when the next decoded token has
        no slot (length sits on a page boundary)."""
        with seq._lock:
            if len(seq.pages) * self.spec.page_size < seq.length + 1:
                seq.pages.extend(seq.pool.alloc(1))
                seq._account()

    def note_decoded(self, seq: SeqPages) -> None:
        """One token was scattered into the sequence's tail slot by
        ``decode_fn``; the bookkeeping catches up here."""
        with seq._lock:
            seq.length += 1
            seq._last_use = _now()

    def free_seq(self, seq: SeqPages) -> None:
        with seq._lock:
            if seq.pages:
                seq.pool.free(seq.pages)
            seq.pages = []
            seq._spilled = None
            seq.state = None
            seq._state_bytes = 0
            seq.length = 0
            if seq._finalizer is not None:
                seq._finalizer.detach()
                seq._finalizer = None
            agas.registry.unregister(seq.gid)
        with self._seq_lock:
            self._seqs.pop(seq.seq_id, None)

    # -- layout for the kernel -----------------------------------------------

    def table(self, seqs: "Sequence[SeqPages]", max_pages: int):
        """(page_table (B, max_pages) int32, lengths (B,) int32) in the
        ``paged_attention`` layout: padding slots hold the reserved page
        0 so the kernel's prefetched DMAs stay in bounds."""
        B = len(seqs)
        tbl = np.zeros((B, max_pages), np.int32)
        lens = np.zeros((B,), np.int32)
        for i, s in enumerate(seqs):
            n = len(s.pages)
            if n > max_pages:
                raise ValueError(
                    f"sequence #{s.seq_id} has {n} pages, table width is {max_pages}"
                )
            tbl[i, :n] = s.pages
            lens[i] = s.length
        return tbl, lens

    # -- maintenance ---------------------------------------------------------

    def defrag(self, device) -> int:
        """Compact a pool: live pages move to the lowest slots (stable
        order), sequence tables are rewritten, the free list becomes the
        contiguous tail.  Returns the number of pages that moved.

        Lock discipline: every holder's ``seq._lock`` is acquired FIRST
        (in ``seq_id`` order) and only then the pool lock — the same
        seq-then-pool order spill/migrate/append/decode use, so the
        compaction serializes against an in-flight spill or decode step
        instead of deadlocking with it (pool-then-seq here would be the
        classic ABBA).  The free list is rebuilt from the locked holders'
        pages, so if the holder set changed while the locks were being
        collected (a raced-in ``new_seq``/``migrate`` allocated pages the
        pass cannot see), everything is released and the pass retries;
        after a few contended passes it returns 0 — defrag is
        maintenance, not a correctness gate."""
        pool = self.pool_of(device)
        moved = 0
        for _ in range(8):
            with self._seq_lock:
                holders = sorted(
                    (s for s in self._seqs.values() if s.pool is pool),
                    key=lambda s: s.seq_id)
            with contextlib.ExitStack() as stack:
                for s in holders:
                    stack.enter_context(s._lock)
                with pool.lock:
                    with self._seq_lock:
                        current = [s for s in self._seqs.values()
                                   if s.pool is pool]
                    if any(s not in holders for s in current):
                        continue  # unlocked holder raced in — retry
                    holders = [s for s in holders if s.pool is pool and s.pages]
                    live: "list[int]" = []
                    for s in holders:
                        live.extend(s.pages)
                    mapping = {old: new
                               for new, old in enumerate(sorted(live), start=1)}
                    moved = sum(1 for old, new in mapping.items() if old != new)
                    if moved:
                        order = np.arange(pool.num_pages, dtype=np.int32)
                        for old, new in mapping.items():
                            order[new] = old
                        ks, vs = pool.arrays()
                        pool.set_arrays(ks[:, order], vs[:, order])
                        for s in holders:
                            s.pages = [mapping[p] for p in s.pages]
                    pool._free = list(range(pool.num_pages - 1, len(live), -1))
                    return moved
        return 0

    def migrate(self, seq: SeqPages, device) -> None:
        """Re-home a sequence: ALL its pages leave the source slabs as one
        stacked read and land in the target pool as one stacked write —
        the §10 lesson (batch the percolation, never per-page transfers)
        applied to rebalancing.  The AGAS record moves with the pages, so
        affinity immediately scores the new home."""
        dst = self.pool_of(device)
        with seq._lock:
            if seq.pool is dst:
                return
            seq.ensure_resident()
            src = seq.pool
            with coalesce():
                k, v = src.read_pages(seq.pages)
                pages = dst.alloc(len(seq.pages))
                dst.write_pages(pages, k, v)
            src.free(seq.pages)
            seq.pool = dst
            seq.pages = pages
            agas.registry.update_placement(
                seq.gid, agas.Placement(device.key, device.jax_device.process_index))
            seq._account()
            seq._last_use = _now()

    # -- cross-locality shipping (prefill -> decode disaggregation) ----------

    def export_seq(self, seq: SeqPages) -> dict:
        """Ship-ready snapshot of one sequence: page contents leave the
        slabs as ONE coalesced gather (``read_pages``), plus length and
        the resident state.  Plain numpy throughout — over a parcelport
        ``invoke`` the big arrays ride the PR 6 shm lane, so a prefill
        locality can hand a finished prompt to a decode locality without
        serializing megabytes through the control channel."""
        with seq._lock:
            seq.ensure_resident()
            k, v = seq.pool.read_pages(seq.pages)
            state = seq.state
            if state is not None:
                state = jax.tree_util.tree_map(np.asarray, state)
            return {"k": k, "v": v, "length": int(seq.length), "state": state}

    def import_seq(self, device, payload: dict) -> SeqPages:
        """Inverse of ``export_seq``, usually on another locality's
        cache: allocate, ONE coalesced scatter, state re-attached (its
        bytes re-declared against THIS device) — decode resumes from the
        shipped table as if the prompt had prefilled here."""
        seq = self.new_seq(device)
        k = np.asarray(payload["k"])
        v = np.asarray(payload["v"])
        with seq._lock:
            pages = seq.pool.alloc(len(k))
            seq.pool.write_pages(pages, k, v)
            seq.pages = pages
            seq.length = int(payload["length"])
            if payload.get("state") is not None:
                seq.set_state(payload["state"])
            seq._account()
            seq._last_use = _now()
        return seq

    def stats(self) -> dict:
        out = {}
        for key, pool in self.pools.items():
            out[key] = {
                "used_pages": pool.used_pages,
                "free_pages": pool.num_free,
                "resident_bytes": agas.registry.resident_bytes(key),
            }
        out["spilled_bytes"] = agas.registry.spilled_bytes()
        return out


class _PagedRequest:
    __slots__ = ("tokens", "max_new", "promise", "arrived", "seq", "out",
                 "started", "first_token_s", "handed_off", "rid", "sampling",
                 "extras")

    def __init__(self, tokens, max_new, promise, arrived, rid=0,
                 sampling=None, extras=None):
        self.tokens = tokens
        self.max_new = max_new
        self.promise = promise
        self.arrived = arrived
        # Zoo-contract identity + knobs: ``rid`` keys the sampling PRNG
        # stream, ``sampling`` is a SamplingParams (None = greedy),
        # ``extras`` carries per-request modality inputs (whisper frames).
        self.rid = rid
        self.sampling = sampling
        self.extras = extras
        self.seq: "SeqPages | None" = None
        self.out: "list[int]" = []
        self.started = arrived
        self.first_token_s: "float | None" = None
        # True once prefill is done with the request — settled or admitted
        # to a decode lane.  A prefill-batch failure must fail only the
        # requests still owned by prefill: settling an already-admitted
        # request's promise again would raise InvalidStateError out of
        # whichever lane thread finishes it.
        self.handed_off = False


class PagedServeEngine:
    """Prefill/decode-disaggregated serving over a ``PagedKVCache``.

    ``submit(prompt, max_new_tokens)`` returns a future of the generated
    token ids (np.int32).  One prefill lane batches prompts by token
    budget and pages their KV onto the scheduler-chosen device; one
    decode lane per device steps every resident sequence continuously in
    exact-row batches.  See the module docstring for the model contract
    and the placement/rebalance protocol.
    """

    def __init__(self, kv: PagedKVCache, prefill_fn: Callable, decode_fn: Callable,
                 *, max_seq_len: int, scheduler=None,
                 prefill: "LanePolicy | None" = None,
                 decode: "LanePolicy | None" = None,
                 max_queue: int = 512, rebalance_every: int = 32,
                 decode_shapes: "Sequence[int] | None" = None,
                 contract: str = "legacy",
                 name: str = "paged"):
        if contract not in ("legacy", "zoo"):
            raise ValueError(f"contract must be 'legacy' or 'zoo', got {contract!r}")
        self.kv = kv
        self.prefill_fn = prefill_fn
        self.decode_fn = decode_fn
        self.contract = contract
        self._next_rid = 0
        # Optional row-count palette preseeded into every decode lane's
        # warm-shape set (see _DecodeLane): a closed palette (e.g. powers
        # of two up to max_batch) makes the set of compiled decode shapes
        # deterministic across runs — benchmarks want that — at the cost
        # of padding whenever occupancy is off-palette.  None (default)
        # learns watermarks as they occur: ~0 steady-state padding,
        # compile count bounded by distinct high-water marks instead.
        self.decode_shapes = (
            tuple(sorted({int(s) for s in decode_shapes if int(s) > 0}))
            if decode_shapes is not None else ())
        self.name = name
        self.max_seq_len = int(max_seq_len)
        self.max_pages = kv.spec.pages_for(self.max_seq_len)
        self._scheduler = scheduler
        self.max_queue = int(max_queue)
        self.rebalance_every = max(1, int(rebalance_every))
        self.prefill_policy = prefill if prefill is not None else LanePolicy(
            max_batch=8, max_delay_s=0.004,
            token_budget=_env_int("REPRO_PREFILL_TOKEN_BUDGET", 2048))
        self.decode_policy = decode if decode is not None else LanePolicy(
            max_batch=64,
            max_delay_s=float(os.environ.get("REPRO_DECODE_DEADLINE_S", 0.001)))

        self._cv = threading.Condition()
        self._queue: "list[_PagedRequest]" = []
        # Requests popped from the queue but not yet admitted/settled:
        # without this, drain() sees an idle engine while a prefill batch
        # is mid-flight (counted by neither the queue nor any lane).
        self._inflight = 0
        self._closed = False

        # Per-device decode lanes: inbox + thread, created on first use.
        self._lane_lock = threading.Lock()
        self._lanes: "dict[str, _DecodeLane]" = {}

        # Metrics.
        self._m_lock = threading.Lock()
        self._started_at = _now()
        self._submitted = 0
        self._completed = 0
        self._failed = 0
        self._prefill_batches = 0
        self._prefill_tokens = 0
        self._prefill_rows = 0
        self._prefill_padded = 0
        self._decode_steps = 0
        self._decode_rows = 0
        self._decode_padded = 0
        self._migrations = 0
        self._token_lat: "list[float]" = []
        self._seq_lat: "list[float]" = []
        self._ttft: "list[float]" = []

        self._prefill_thread = threading.Thread(
            target=self._prefill_loop, name=f"paged:{name}:prefill", daemon=True)
        self._prefill_thread.start()

    # -- construction from the model zoo -------------------------------------

    @classmethod
    def from_config(cls, cfg, *, devices=None, params=None, seed: int = 0,
                    max_seq_len: "int | None" = None,
                    pool_pages: "int | None" = None,
                    pool_bytes: "int | None" = None, **kw) -> "PagedServeEngine":
        """Wire any zoo architecture (``repro.configs``) into a paged
        engine: one ``PageSpec`` from ``paged_spec`` (multi-layer KV
        folded into one slab geometry), a jitted prefill and a jitted
        slab-donating decode step from ``paged_prefill`` /
        ``paged_decode_step``, ``contract="zoo"``.  ``params`` defaults
        to ``init(cfg, PRNGKey(seed))`` — two localities building from
        the same seed hold bit-identical weights, which is what lets a
        shipped sequence resume decoding elsewhere."""
        from repro.models.model import get_model, paged_surface

        spec_fn, prefill_fn, decode_fn = paged_surface(cfg)
        spec = spec_fn(cfg)
        if params is None:
            params = get_model(cfg).init(cfg, jax.random.PRNGKey(int(seed)))
        kv = PagedKVCache(spec, devices=devices, pool_pages=pool_pages,
                          pool_bytes=pool_bytes)
        if max_seq_len is None:
            max_seq_len = 16 * spec.page_size

        @jax.jit
        def pre(tokens, extras):
            return prefill_fn(cfg, params, tokens, extras)

        @functools.partial(jax.jit, donate_argnums=(0, 1))
        def dec(ks, vs, state, tokens, positions, tables, lengths):
            return decode_fn(cfg, params, ks, vs, state, tokens,
                             positions, tables, lengths)

        kw.setdefault("name", f"paged-{getattr(cfg, 'name', cfg.family)}")
        return cls(kv, pre, dec, max_seq_len=int(max_seq_len),
                   contract="zoo", **kw)

    # -- submission ----------------------------------------------------------

    def submit(self, prompt, max_new_tokens: int, *,
               sampling: "SamplingParams | None" = None,
               extras: "dict | None" = None,
               request_id: "int | None" = None) -> Future:
        """Queue one request.  ``sampling`` (zoo contract) selects the
        host-side sampler (None = greedy); ``extras`` carries modality
        inputs (e.g. whisper ``frames``); ``request_id`` keys the
        sampling PRNG stream — pass an explicit, fleet-stable id when
        reproducibility across deployments matters, else submission
        order numbers the stream."""
        tokens = np.asarray(prompt, np.int32).reshape(-1)
        if tokens.size == 0:
            raise ValueError("empty prompt")
        total = tokens.size + int(max_new_tokens)
        if total > self.max_seq_len:
            raise ValueError(
                f"prompt ({tokens.size}) + max_new_tokens ({max_new_tokens}) "
                f"exceeds max_seq_len ({self.max_seq_len})")
        promise: Promise = Promise(name=f"{self.name}:seq")
        with self._m_lock:
            rid = self._next_rid if request_id is None else int(request_id)
            self._next_rid += 1
        req = _PagedRequest(tokens, int(max_new_tokens), promise, _now(),
                            rid=rid, sampling=sampling, extras=extras)
        with self._cv:
            if self._closed:
                raise EngineClosed(f"engine {self.name!r} is closed")
            if len(self._queue) >= self.max_queue:
                raise QueueFull(
                    f"engine {self.name!r} admission queue is full "
                    f"({self.max_queue}) — backpressure: shed or retry")
            self._queue.append(req)
            self._cv.notify_all()
        with self._m_lock:
            self._submitted += 1
        return promise.get_future()

    def reset_metrics(self) -> None:
        """Zero the counters and latency histograms (placement state, warm
        decode shapes and resident pages are untouched).  Benchmarks call
        this after a warm-up pass so ``metrics()`` reflects only the
        measured window — warm-pass XLA compiles would otherwise dominate
        every latency percentile."""
        with self._m_lock:
            self._started_at = _now()
            self._submitted = self._completed = self._failed = 0
            self._prefill_batches = self._prefill_tokens = 0
            self._prefill_rows = self._prefill_padded = 0
            self._decode_steps = self._decode_rows = self._decode_padded = 0
            self._migrations = 0
            self._token_lat.clear()
            self._seq_lat.clear()
            self._ttft.clear()

    def __enter__(self) -> "PagedServeEngine":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def close(self) -> None:
        with self._cv:
            self._closed = True
            self._cv.notify_all()
        self._prefill_thread.join(timeout=60)
        with self._lane_lock:
            lanes = list(self._lanes.values())
        for lane in lanes:
            lane.close()

    def drain(self) -> None:
        """Block until every submitted sequence has finished: nothing
        queued, nothing mid-prefill, nothing active on a decode lane."""
        while True:
            with self._cv:
                queued = len(self._queue) + self._inflight
            with self._lane_lock:
                active = sum(lane.active_count() for lane in self._lanes.values())
            if not queued and not active:
                return
            time.sleep(0.002)

    # -- prefill lane (throughput: token-budget batching) --------------------

    def _scheduler_for(self):
        if self._scheduler is not None:
            return self._scheduler
        from repro.core.scheduler import get_scheduler

        return get_scheduler()

    def _prefill_loop(self) -> None:
        pol = self.prefill_policy
        while True:
            with self._cv:
                while not self._queue and not self._closed:
                    self._cv.wait()
                if not self._queue:
                    return
                head = self._queue[0]
                # `x if x is not None else d`, never `x or d`: an explicit
                # 0.0 deadline / 0 budget is a real policy (dispatch now),
                # matching RequestEngine._lane_bounds.
                delay = pol.max_delay_s if pol.max_delay_s is not None else 0.004
                deadline = head.arrived + delay
                T = head.tokens.size
                budget = pol.token_budget if pol.token_budget is not None else 1 << 30
                budget_rows = max(1, budget // max(T, 1))
                cap = min(pol.max_batch if pol.max_batch is not None else 8,
                          budget_rows)
                while (not self._closed and _now() < deadline
                       and sum(1 for r in self._queue if r.tokens.size == T) < cap):
                    self._cv.wait(timeout=max(deadline - _now(), 0.0005))
                group, kept = [], []
                for r in self._queue:
                    if r.tokens.size == T and len(group) < cap:
                        group.append(r)
                    else:
                        kept.append(r)
                self._queue[:] = kept
                self._inflight += len(group)
            if group:
                try:
                    self._run_prefill(group)
                except BaseException as e:  # noqa: BLE001 - lane must not die
                    # Fail only the requests prefill still owns: members
                    # already admitted to a decode lane (or settled) must
                    # not be settled twice, and a failed member's pages
                    # must go back to the pool.
                    for r in group:
                        if r.handed_off:
                            continue
                        self._finish(r, e)
                        self._prefill_done(r)

    def _run_prefill(self, group: "list[_PagedRequest]") -> None:
        batch = np.stack([r.tokens for r in group])  # (B, T) — equal-T: no padding
        state = None
        if self.contract == "zoo":
            extras = None
            if group[0].extras is not None:
                extras = {key: np.stack([np.asarray(r.extras[key]) for r in group])
                          for key in group[0].extras}
            k, v, state, logits = self.prefill_fn(batch, extras)
            logits = np.asarray(logits)
            if state is not None:
                state = jax.tree_util.tree_map(np.asarray, state)
            # First token samples host-side at position 0 of each
            # request's own PRNG stream — batch composition cannot leak.
            nxt = np.asarray(
                [sample_token(logits[i], r.sampling, r.rid, 0)
                 for i, r in enumerate(group)], np.int32)
        else:
            k, v, nxt = self.prefill_fn(batch)
            nxt = np.asarray(nxt, np.int32)
        k = np.asarray(k)
        v = np.asarray(v)
        # Page k.shape[2] tokens, not the prompt length: hybrid archs
        # prepend meta/register tokens whose KV pages in with the prompt.
        Tp = k.shape[2]
        sched = self._scheduler_for()
        done = _now()
        with self._m_lock:
            self._prefill_batches += 1
            self._prefill_tokens += batch.size
            self._prefill_rows += len(group)
        for i, req in enumerate(group):
            dev = sched.select(args=())
            pool = self._pool_with_room(dev, self.kv.spec.pages_for(Tp) + 1)
            req.seq = self.kv.new_seq(pool.device)
            # k[i]: (L, T', Kh, D) — the whole prompt pages in as one write.
            self.kv.append(req.seq, k[i], v[i])
            if state is not None:
                req.seq.set_state(
                    jax.tree_util.tree_map(lambda a, i=i: a[i], state))
            req.out.append(int(nxt[i]))
            req.started = done
            req.first_token_s = done - req.arrived
            if req.max_new <= 1:
                self._finish(req)
            else:
                self._lane_for(pool.device).admit(req)
            self._prefill_done(req)

    def _prefill_done(self, req: "_PagedRequest") -> None:
        """Prefill is done with this request (admitted or settled): mark
        it so a later batch failure cannot settle it twice, and release
        its in-flight slot for ``drain``."""
        req.handed_off = True
        with self._cv:
            self._inflight -= 1

    def _pool_with_room(self, dev, need_pages: int) -> PagePool:
        """The chosen device's pool if it has room, else spill its LRU
        sequences to make room, else the pool with the most free pages —
        admission never fails while ANY pool can hold the prompt."""
        pool = self.kv.pools.get(dev.key)
        if pool is not None and pool.num_free >= need_pages:
            return pool
        if pool is not None:
            need = (need_pages - pool.num_free) * self.kv.spec.page_bytes
            for f in self._scheduler_for().spill_lru(dev, need):
                f.get()
            if pool.num_free >= need_pages:
                return pool
        best = max(self.kv.pools.values(), key=lambda p: p.num_free)
        if best.num_free < need_pages:
            raise OutOfPages(
                f"no pool has {need_pages} free page(s); deepest is "
                f"{best.device.key} with {best.num_free}")
        return best

    def _lane_for(self, device) -> "_DecodeLane":
        with self._lane_lock:
            lane = self._lanes.get(device.key)
            if lane is None:
                lane = self._lanes[device.key] = _DecodeLane(self, device)
            return lane

    # -- completion ----------------------------------------------------------

    def _finish(self, req: "_PagedRequest", exc: "BaseException | None" = None) -> None:
        if req.seq is not None:
            self.kv.free_seq(req.seq)
            req.seq = None
        # An already-settled promise is absorbed, not raised: double
        # settlement can only mean two completion paths raced (e.g. a
        # prefill-batch failure vs. a lane that already admitted the
        # request), and a lane thread dying here would hang every other
        # active sequence's future forever.
        if exc is not None:
            try:
                req.promise.set_exception(exc)
            except _cf.InvalidStateError:
                return
            with self._m_lock:
                self._failed += 1
            return
        try:
            req.promise.set_value(np.asarray(req.out, np.int32))
        except _cf.InvalidStateError:
            return
        with self._m_lock:
            self._completed += 1
            self._seq_lat.append(_now() - req.arrived)
            if req.first_token_s is not None:
                self._ttft.append(req.first_token_s)

    # -- metrics -------------------------------------------------------------

    @staticmethod
    def _pct(xs: "list[float]", q: float) -> float:
        if not xs:
            return 0.0
        xs = sorted(xs)
        return xs[int(q * (len(xs) - 1))]

    def metrics(self) -> dict:
        with self._m_lock:
            rows = self._prefill_rows + self._decode_rows
            padded = self._prefill_padded + self._decode_padded
            m = {
                "requests_submitted": self._submitted,
                "requests_completed": self._completed,
                "requests_failed": self._failed,
                "prefill_batches": self._prefill_batches,
                "prefill_tokens": self._prefill_tokens,
                "decode_steps": self._decode_steps,
                "rows": rows,
                "padded_rows": padded,
                "padding_waste": (padded / rows) if rows else 0.0,
                "migrations": self._migrations,
                "token_latency_p50_s": self._pct(self._token_lat, 0.50),
                "token_latency_p99_s": self._pct(self._token_lat, 0.99),
                "ttft_p99_s": self._pct(self._ttft, 0.99),
                "seq_latency_p99_s": self._pct(self._seq_lat, 0.99),
            }
        elapsed = max(_now() - self._started_at, 1e-9)
        m["elapsed_s"] = elapsed
        m["seqs_per_s"] = m["requests_completed"] / elapsed
        m["kv"] = self.kv.stats()
        try:
            m["placements"] = self._scheduler_for().stats()
        except Exception:  # noqa: BLE001 - metrics never fail the caller
            pass
        with self._lane_lock:
            m["active_by_device"] = {
                k: lane.active_count() for k, lane in self._lanes.items()}
        return m

    def __repr__(self) -> str:
        return (f"PagedServeEngine({self.name}: {self._completed}/"
                f"{self._submitted} sequences)")


class _DecodeLane:
    """One device's decode lane: continuous exact-row batched stepping.

    The lane thread owns the device's resident sequences.  Each
    iteration: fold in arrivals (deadline-bounded wait only when idle),
    take up to ``max_batch`` sequences, grow tails by a page where
    needed, run ONE ``decode_fn`` step over the pools, swap the slabs
    back, and retire finished sequences.  Mixed-length sequences share
    the step at their true lengths — no sequence-dimension padding ever,
    which is the entire point of paging.

    Row counts are kept shape-stable: ``decode_fn`` is jitted by the
    caller, so every new row count is a fresh XLA compile.  The lane
    remembers which row counts it has already run (``_warm``) and pads a
    smaller batch up to the nearest warm count — duplicating the last
    row, whose scatter rewrites the same slot with the same value and
    whose output is discarded — rather than compiling a one-off shape.
    A batch that sets a new high-water mark compiles exactly (and
    becomes warm), and padding is capped at 2x the real rows, so steady
    state runs exact with ~0 padding and a shrinking tail never
    recompiles."""

    def __init__(self, engine: PagedServeEngine, device):
        self.engine = engine
        self.device = device
        self._cv = threading.Condition()
        self._warm: "set[int]" = set(engine.decode_shapes)
        self._inbox: "list[_PagedRequest]" = []
        self._active: "list[_PagedRequest]" = []
        self._closed = False
        self._steps = 0
        self._stalls = 0  # consecutive steps where nothing fit in the pool
        self._thread = threading.Thread(
            target=self._loop, name=f"paged:{engine.name}:decode:{device.key}",
            daemon=True)
        self._thread.start()

    def admit(self, req: "_PagedRequest") -> None:
        with self._cv:
            self._inbox.append(req)
            self._cv.notify_all()

    def active_count(self) -> int:
        with self._cv:
            return len(self._inbox) + len(self._active)

    def close(self) -> None:
        with self._cv:
            self._closed = True
            self._cv.notify_all()
        self._thread.join(timeout=60)

    def _loop(self) -> None:
        eng = self.engine
        pol = eng.decode_policy
        while True:
            with self._cv:
                if not self._active and not self._inbox:
                    if self._closed:
                        return
                    self._cv.wait(timeout=0.05)
                    continue
                if not self._active and self._inbox:
                    # Idle lane: give the batch one deadline window to
                    # fill (an explicit 0.0 means dispatch immediately —
                    # `is not None`, matching RequestEngine._lane_bounds).
                    delay = pol.max_delay_s if pol.max_delay_s is not None else 0.001
                    deadline = _now() + delay
                    while not self._closed and _now() < deadline:
                        self._cv.wait(timeout=max(deadline - _now(), 0.0005))
                self._active.extend(self._inbox)
                self._inbox.clear()
                # Residents first (stable, so round-robin order survives):
                # a spilled sequence can only rejoin once pages free up,
                # and putting it ahead of resident work would let one
                # unfittable sequence stall the whole lane.
                self._active.sort(key=lambda r: r.seq.spilled)
                cap = pol.max_batch if pol.max_batch is not None else 64
                batch = self._active[:cap]
            if not batch:
                continue
            try:
                self._step(batch)
            except BaseException as e:  # noqa: BLE001 - fail the batch, not the lane
                with self._cv:
                    for r in batch:
                        if r in self._active:
                            self._active.remove(r)
                for r in batch:
                    eng._finish(r, e)

    def _step(self, batch: "list[_PagedRequest]") -> None:
        eng = self.engine
        kv = eng.kv
        t0 = _now()
        # Page pressure IS the capacity limit on a small fleet: a
        # sequence whose pages cannot be made resident right now is
        # deferred — it stays active and retries as finishing sequences
        # free pages — rather than failed or force-spilling a batchmate
        # (which would thrash the same pool within one step).
        #
        # Every ready sequence's _lock is held from ensure_resident
        # through decode_fn and note_decoded, acquired in seq_id order
        # (the same order defrag uses).  The spiller's _spill_now and
        # defrag's compaction both take seq._lock first, so a batch
        # member's pages can be neither freed (and re-owned by a racing
        # prefill) nor renumbered between the page-table snapshot and
        # the scatter of the new token — without the pin, decode would
        # silently attend over another sequence's KV under pool
        # pressure, exactly the regime paging exists for.
        done: "list[_PagedRequest]" = []
        held: "list[SeqPages]" = []
        ok: "set[int]" = set()
        try:
            for r in sorted(batch, key=lambda q: q.seq.seq_id):
                s = r.seq
                s._lock.acquire()
                held.append(s)
                try:
                    s.ensure_resident()
                    kv.ensure_slot(s)
                except OutOfPages:
                    held.pop()
                    s._lock.release()
                    continue
                ok.add(s.seq_id)
            ready = [r for r in batch if r.seq.seq_id in ok]
            if not ready:
                self._stalls += 1
                if self._stalls > _MAX_DECODE_STALLS:
                    raise OutOfPages(
                        f"{self.device.key}: {len(batch)} sequence(s) stalled "
                        f"{self._stalls} consecutive steps waiting for pages — "
                        "the pool cannot hold this working set")
                time.sleep(0.002)  # wait for a sibling/finisher to free pages
                return
            self._stalls = 0
            batch = ready
            seqs = [r.seq for r in batch]
            tbl, lens = kv.table(seqs, eng.max_pages)
            tokens = np.asarray([r.out[-1] for r in batch], np.int32)
            # Shape reuse (see class docstring): pad to the nearest warm row
            # count when that costs less than doubling the batch, else
            # compile this exact count and make it warm.
            b_real = len(batch)
            cand = min((w for w in self._warm if w >= b_real), default=None)
            want = cand if cand is not None and cand - b_real <= b_real else b_real
            self._warm.add(want)
            pad = want - b_real
            if pad:
                tbl = np.concatenate([tbl, np.repeat(tbl[-1:], pad, axis=0)])
                lens = np.concatenate([lens, np.repeat(lens[-1:], pad)])
                tokens = np.concatenate([tokens, np.repeat(tokens[-1:], pad)])
            pool = kv.pool_of(self.device)
            if eng.contract == "zoo":
                # Stack each row's resident state (pad rows duplicate the
                # last row, discarded on the way back out).
                rows = [r.seq.state for r in batch]
                state = None
                if rows[0] is not None:
                    rows = rows + [rows[-1]] * pad
                    state = jax.tree_util.tree_map(
                        lambda *xs: np.stack(xs), *rows)
                with pool.lock:
                    ks, vs = pool.arrays()
                    k2, v2, st2, logits = eng.decode_fn(
                        ks, vs, state, tokens, lens, tbl, lens)
                    logits = np.asarray(logits)  # sync before the slabs swap
                    pool.set_arrays(k2, v2)
                if st2 is not None:
                    st2 = jax.tree_util.tree_map(np.asarray, st2)
                nxt = np.empty(len(batch), np.int32)
                for i, r in enumerate(batch):
                    # Position = tokens already emitted (prefill's token
                    # was position 0): identity-keyed, batch-independent.
                    nxt[i] = sample_token(logits[i], r.sampling, r.rid,
                                          len(r.out))
                    if st2 is not None:
                        r.seq.set_state(jax.tree_util.tree_map(
                            lambda a, i=i: a[i], st2))
            else:
                with pool.lock:
                    ks, vs = pool.arrays()
                    # Host operands ride the call uncommitted: the
                    # computation follows the committed slabs to this
                    # lane's device, and the C++ dispatch path moves four
                    # tiny arrays faster than four python-level
                    # device_put round-trips would.
                    k2, v2, nxt = eng.decode_fn(ks, vs, tokens, lens, tbl, lens)
                    nxt = np.asarray(nxt, np.int32)  # sync before the slabs swap
                    pool.set_arrays(k2, v2)
            for i, r in enumerate(batch):
                kv.note_decoded(r.seq)
                r.out.append(int(nxt[i]))
                if len(r.out) >= r.max_new:
                    done.append(r)
        finally:
            for s in held:
                s._lock.release()
        step_s = _now() - t0
        # Direct-route placement charge (the fix select_batch alone cannot
        # make): this step never touched a lane queue, so the recency
        # counter is the only signal least_loaded has that this device
        # just did len(batch) rows of work.
        sched = eng._scheduler_for()
        charge = getattr(sched, "charge", None)
        if callable(charge):
            charge(self.device, len(batch))
        with eng._m_lock:
            eng._decode_steps += 1
            eng._decode_rows += len(batch)
            eng._decode_padded += pad
            eng._token_lat.extend([step_s] * len(batch))
        with self._cv:
            for r in done:
                self._active.remove(r)
            # Rotate survivors to the tail so an active set larger than
            # max_batch round-robins instead of starving the overflow.
            if len(self._active) > len(batch) - len(done):
                for r in batch:
                    if r in self._active:
                        self._active.remove(r)
                        self._active.append(r)
        for r in done:
            eng._finish(r)
        self._steps += 1
        if self._steps % eng.rebalance_every == 0:
            self._maybe_rebalance([r for r in batch if r not in done])

    def _maybe_rebalance(self, batch: "list[_PagedRequest]") -> None:
        """Ask the placement layer whether this lane's sequences still
        belong here: ``select_batch`` over the ``SeqPages`` handles keeps
        them home under affinity (the bytes ARE here) — unless memory
        pressure vetoes the device, in which case the coldest sequence
        migrates (one coalesced page move) to the chosen sibling.

        Gated on page pressure: with >=20% of the pool free there is
        nothing to rebalance away from, and under a pure load policy
        (``least_loaded`` scores this lane's own just-charged work)
        asking anyway ping-pongs sequences between lanes — each move a
        page gather + scatter — for no memory relief at all."""
        if not batch:
            return
        eng = self.engine
        pool = eng.kv.pool_of(self.device)
        if pool.num_free * 5 >= pool.num_pages:
            return
        sched = eng._scheduler_for()
        try:
            dev = sched.select_batch([[r.seq] for r in batch])
        except Exception:  # noqa: BLE001 - advisory; never fail decode
            return
        if dev.key == self.device.key or dev.key not in eng.kv.pools:
            return
        victim = min(batch, key=lambda r: r.seq._last_use)
        eng.kv.migrate(victim.seq, dev)
        with self._cv:
            self._active.remove(victim)
        with eng._m_lock:
            eng._migrations += 1
        eng._lane_for(dev).admit(victim)


# ---------------------------------------------------------------------------
# cross-locality disaggregation: parcel "invoke" actions (DESIGN.md §17)
# ---------------------------------------------------------------------------
#
# Prefill on one locality, decode on another: the prefill side runs
# ``paged_prefill`` + ``PagedKVCache.append`` locally, then ships
# ``export_seq``'s payload (pages as ONE coalesced gather, plus length
# and resident state) as a parcel —
#
#     port.call(lid, "invoke", {
#         "fn": "repro.serving.paged:paged_worker_decode",
#         "payload": {...}})
#
# — where the big arrays take the shm lane.  The decode side re-derives
# the weights from the config name + PRNG seed (bit-identical params;
# nothing but pages crosses the wire), imports the sequence into its own
# pool and resumes decoding from the shipped table.  Sampling stays
# keyed by (seed, request_id, position), so the shipped continuation is
# bit-identical to a single-locality decode.

_WORKER_LOCK = threading.Lock()
_WORKERS: "dict[str, dict]" = {}


def _worker_ctx(payload: dict) -> dict:
    """Decode-side context for one shipped-page stream, built once per
    ``name`` on this locality and cached: smoke'd (or full) config,
    seed-derived params, a single-device ``PagedKVCache`` and the jitted
    slab-donating decode step."""
    name = payload["name"]
    with _WORKER_LOCK:
        ctx = _WORKERS.get(name)
        if ctx is not None:
            return ctx
        from repro.configs import get_config
        from repro.configs import smoke as _smoke
        from repro.core.device import get_all_devices
        from repro.models.model import get_model, paged_surface

        cfg = get_config(payload["config"])
        if payload.get("smoke", True):
            cfg = _smoke(cfg)
        spec_fn, _, decode_fn = paged_surface(cfg)
        params = get_model(cfg).init(
            cfg, jax.random.PRNGKey(int(payload.get("seed", 0))))
        devs = list(get_all_devices().get())
        dev = devs[int(payload.get("device_index", 0)) % len(devs)]
        kv = PagedKVCache(spec_fn(cfg), devices=[dev],
                          pool_pages=payload.get("pool_pages"))

        @functools.partial(jax.jit, donate_argnums=(0, 1))
        def dec(ks, vs, state, tokens, positions, tables, lengths):
            return decode_fn(cfg, params, ks, vs, state, tokens,
                             positions, tables, lengths)

        ctx = _WORKERS[name] = {"cfg": cfg, "kv": kv, "dev": dev, "dec": dec}
        return ctx


def paged_worker_decode(payload: dict) -> np.ndarray:
    """Parcel ``invoke`` target: resume decoding a shipped sequence.

    payload keys: ``name`` (worker cache key), ``config`` (registry
    name), ``smoke``, ``seed``, ``device_index``, ``pool_pages``,
    ``seq`` (an ``export_seq`` payload), ``first_token`` (the
    prefill-sampled token), ``max_new``, ``max_pages`` (table width —
    must match the prefill side's so the attention geometry is
    identical), ``sampling`` (SamplingParams fields or None) and
    ``request_id``.  Returns all generated tokens (np.int32), first
    token included."""
    ctx = _worker_ctx(payload)
    kv: PagedKVCache = ctx["kv"]
    dev = ctx["dev"]
    pool = kv.pool_of(dev)
    seq = kv.import_seq(dev, payload["seq"])
    sp = payload.get("sampling")
    if sp is not None and not isinstance(sp, SamplingParams):
        sp = SamplingParams(**sp)
    rid = int(payload.get("request_id", 0))
    max_pages = int(payload["max_pages"])
    out = [int(payload["first_token"])]
    try:
        for _ in range(int(payload["max_new"]) - 1):
            kv.ensure_slot(seq)
            tbl, lens = kv.table([seq], max_pages)
            tokens = np.asarray([out[-1]], np.int32)
            state = None
            if seq.state is not None:
                state = jax.tree_util.tree_map(
                    lambda a: np.asarray(a)[None], seq.state)
            with pool.lock:
                ks, vs = pool.arrays()
                k2, v2, st2, logits = ctx["dec"](
                    ks, vs, state, tokens, lens, tbl, lens)
                logits = np.asarray(logits)
                pool.set_arrays(k2, v2)
            if st2 is not None:
                seq.set_state(jax.tree_util.tree_map(
                    lambda a: np.asarray(a)[0], st2))
            kv.note_decoded(seq)
            out.append(sample_token(logits[0], sp, rid, len(out)))
    finally:
        kv.free_seq(seq)
    return np.asarray(out, np.int32)


def paged_worker_reset(payload: dict) -> bool:
    """Drop cached worker contexts (tests; ``payload`` may name one)."""
    with _WORKER_LOCK:
        name = (payload or {}).get("name")
        if name is None:
            _WORKERS.clear()
        else:
            _WORKERS.pop(name, None)
    return True
