"""StableLM-2-1.6B [hf:stabilityai/stablelm-2-1_6b; unverified].

24L, d_model=2048, 32 heads (MHA kv=32), d_ff=5632, vocab=100352.
LayerNorm, SwiGLU, partial rotary (25% of head_dim), QKV bias.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="stablelm-1.6b",
    family="dense",
    num_layers=24,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    d_ff=5632,
    vocab_size=100352,
    norm_type="layernorm",
    norm_eps=1e-5,
    mlp_type="swiglu",
    attn_qkv_bias=True,
    rope_type="rope",
    rope_theta=10_000.0,
    partial_rotary=0.25,
    source="hf:stabilityai/stablelm-2-1_6b",
)
