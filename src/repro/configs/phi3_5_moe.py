"""Phi-3.5-MoE (42B total / 6.6B active) [hf:microsoft/Phi-3.5-MoE-instruct].

32L, d_model=4096, 32 heads (GQA kv=8), 16 experts top-2, d_ff=6400/expert,
vocab=32064. RMSNorm-style (uses LayerNorm in HF config; we follow the MoE
reference layout), SwiGLU experts, RoPE. 16 experts divide the 16-way model
axis exactly -> true expert parallelism (all-to-all dispatch).
"""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="phi3.5-moe-42b-a6.6b",
    family="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=6400,  # per expert
    vocab_size=32064,
    norm_type="layernorm_nobias",
    norm_eps=1e-5,
    mlp_type="swiglu",
    rope_type="rope",
    rope_theta=10_000.0,
    moe=MoEConfig(
        num_experts=16,
        top_k=2,
        d_ff_expert=6400,
        num_shared_experts=0,
        strategy="ep",
    ),
    source="hf:microsoft/Phi-3.5-MoE-instruct",
)
