"""Qwen1.5-MoE-A2.7B [hf:Qwen/Qwen1.5-MoE-A2.7B].

24L, d_model=2048, 16 heads (MHA kv=16), 60 routed experts top-4 +
shared expert (4x expert width, sigmoid-gated), d_ff=1408/expert,
vocab=151936. RMSNorm, SwiGLU, RoPE, QKV bias (Qwen1.5 lineage).

60 experts do NOT divide the 16-way model axis -> TP-MoE strategy: every
chip holds a d_ff slice of all experts; tokens never move (DESIGN.md §4).
"""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1408,  # per expert
    vocab_size=151936,
    norm_type="rmsnorm",
    norm_eps=1e-6,
    mlp_type="swiglu",
    attn_qkv_bias=True,
    rope_type="rope",
    rope_theta=1_000_000.0,
    moe=MoEConfig(
        num_experts=60,
        top_k=4,
        d_ff_expert=1408,
        num_shared_experts=4,  # shared expert = 4x1408 = 5632 wide
        strategy="tp",
    ),
    source="hf:Qwen/Qwen1.5-MoE-A2.7B",
)
