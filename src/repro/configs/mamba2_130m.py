"""Mamba2-130M [arXiv:2405.21060; unverified] — SSD (state-space duality).

24L, d_model=768, attention-free, ssm_state=128, expand=2 (d_inner=1536),
64-dim SSM heads (24 heads), vocab=50280. RMSNorm, tied embeddings.
Sub-quadratic: runs the long_500k cell.
"""
from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="mamba2-130m",
    family="ssm",
    num_layers=24,
    d_model=768,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    norm_type="rmsnorm",
    norm_eps=1e-5,
    rope_type="none",
    tie_embeddings=True,
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, n_groups=1, chunk=256),
    source="arXiv:2405.21060",
)
