"""Architecture / shape configuration schema.

Every assigned architecture is described by an ``ArchConfig``; the four
assigned input shapes live in ``SHAPES``.  ``smoke()`` derives the reduced
config used by CPU smoke tests; full configs are exercised only by the
dry-run (ShapeDtypeStruct, no allocation).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Optional

__all__ = [
    "ArchConfig",
    "MoEConfig",
    "SSMConfig",
    "EncDecConfig",
    "ShapeConfig",
    "SHAPES",
    "smoke",
]


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    num_shared_experts: int = 0
    # "ep": experts sharded over the model axis, all-to-all dispatch.
    # "tp": every chip holds a d_ff slice of all experts, no token motion
    #        (used when num_experts does not divide the model axis).
    strategy: str = "ep"
    router_jitter: float = 0.0
    renormalize: bool = True
    # dispatch groups (GShard-style): tokens are dispatched within groups
    # whose dim shards over the data axis, so the scatter/gather never
    # crosses data shards. 1 = single global group (paper-era baseline).
    dispatch_groups: int = 1


@dataclass(frozen=True)
class SSMConfig:
    d_state: int
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk: int = 256

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclass(frozen=True)
class EncDecConfig:
    encoder_layers: int
    encoder_seq: int  # frames after the conv frontend STUB (whisper: 1500)
    max_target_positions: int = 448


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None

    # block structure
    norm_type: str = "rmsnorm"  # rmsnorm | layernorm | layernorm_nobias | nonparam_layernorm
    norm_eps: float = 1e-5
    mlp_type: str = "swiglu"  # swiglu | geglu | gelu_mlp
    attn_qkv_bias: bool = False
    attn_out_bias: bool = False
    mlp_bias: bool = False
    tie_embeddings: bool = False

    # positions
    rope_type: str = "rope"  # rope | mrope | learned | none
    rope_theta: float = 10_000.0
    partial_rotary: float = 1.0
    mrope_sections: "tuple[int, ...]" = ()  # M-RoPE (t, h, w) head_dim split

    # attention variants
    sliding_window: Optional[int] = None  # tokens; None = full
    global_attn_layers: "tuple[int, ...]" = ()  # hybrid: full-attn exceptions
    kv_share_group: int = 1  # hymba cross-layer KV sharing group size
    attn_logit_softcap: Optional[float] = None

    # substructures
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    encdec: Optional[EncDecConfig] = None

    # hybrid (hymba): parallel attention + SSM heads in one block
    hybrid_attn_ssm: bool = False
    meta_tokens: int = 0

    # vlm stub
    vision_stub: bool = False
    num_patches: int = 0  # patch embeddings supplied by input_specs

    # bookkeeping
    max_seq: int = 1 << 19
    source: str = ""

    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.num_heads

    @property
    def attn_free(self) -> bool:
        return self.family == "ssm"

    @property
    def subquadratic(self) -> bool:
        """Can this arch run the 524k-token decode cell?"""
        return self.family in ("ssm", "hybrid")

    def param_count(self) -> int:
        """Analytic parameter count (embedding included once if tied)."""
        d, v = self.d_model, self.vocab_size
        total = v * d  # embed
        if not self.tie_embeddings:
            total += v * d
        attn = 0
        if not self.attn_free:
            q = d * self.num_heads * self.hd
            kv = 2 * d * self.num_kv_heads * self.hd
            o = self.num_heads * self.hd * d
            attn = q + kv + o
        if self.mlp_type in ("swiglu", "geglu"):
            mlp = 3 * d * self.d_ff
        else:
            mlp = 2 * d * self.d_ff
        if self.moe is not None:
            e = self.moe
            per = 3 * d * e.d_ff_expert
            mlp = (e.num_experts + e.num_shared_experts) * per + d * e.num_experts
        ssm = 0
        if self.ssm is not None and self.family in ("ssm", "hybrid"):
            s = self.ssm
            di = s.d_inner(d)
            # in_proj (z,x,B,C,dt) + conv + out_proj (mamba2 layout)
            conv_dim = di + 2 * s.n_groups * s.d_state
            ssm = d * (2 * di + 2 * s.n_groups * s.d_state + s.n_heads(d)) + conv_dim * s.d_conv + di * d
            if self.family == "ssm":
                attn = 0
                mlp = 0  # mamba2 blocks have no separate MLP
        layers = self.num_layers * (attn + mlp + ssm)
        if self.encdec is not None:
            # encoder adds its own stack; decoder adds cross-attention
            enc = self.encdec.encoder_layers * (attn + mlp)
            cross = self.num_layers * attn
            layers += enc + cross
        return int(total + layers)

    def active_param_count(self) -> int:
        """Active params per token (MoE: routed top-k + shared only)."""
        if self.moe is None:
            return self.param_count()
        e = self.moe
        d = self.d_model
        per = 3 * d * e.d_ff_expert
        dense_like = self.param_count() - self.num_layers * (e.num_experts + e.num_shared_experts) * per
        return int(dense_like + self.num_layers * (e.top_k + e.num_shared_experts) * per)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: "dict[str, ShapeConfig]" = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def smoke(cfg: ArchConfig) -> ArchConfig:
    """Reduced same-family config for CPU smoke tests."""
    kw: dict = dict(
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=max(1, min(cfg.num_kv_heads, 2)) if cfg.num_kv_heads < cfg.num_heads else 4,
        d_ff=128,
        vocab_size=256,
        head_dim=16,
        max_seq=128,
        num_patches=4 if cfg.vision_stub else 0,
        meta_tokens=4 if cfg.meta_tokens else 0,
        sliding_window=16 if cfg.sliding_window else None,
        global_attn_layers=(0,) if cfg.global_attn_layers else (),
        kv_share_group=cfg.kv_share_group,
    )
    if cfg.mrope_sections:
        kw["mrope_sections"] = (4, 2, 2)
    if cfg.moe is not None:
        kw["moe"] = replace(
            cfg.moe,
            num_experts=min(8, cfg.moe.num_experts),
            top_k=min(2, cfg.moe.top_k),
            d_ff_expert=32,
        )
    if cfg.ssm is not None:
        kw["ssm"] = replace(cfg.ssm, d_state=16, head_dim=16, chunk=16)
    if cfg.encdec is not None:
        kw["encdec"] = replace(cfg.encdec, encoder_layers=2, encoder_seq=24, max_target_positions=64)
    return replace(cfg, name=cfg.name + "-smoke", **kw)
