"""Whisper-tiny [arXiv:2212.04356; unverified] — encoder-decoder audio model.

4L encoder + 4L decoder, d_model=384, 6 heads (MHA), d_ff=1536,
vocab=51865. LayerNorm(+bias), GELU MLP, learned positions (decoder),
conv frontend is a STUB: ``input_specs()`` supplies precomputed frame
embeddings (batch, 1500, 384) for the encoder.

decode_32k note (DESIGN.md §4): the real model caps decoder positions at
448; the 32k-KV decode cell exercises the runtime/sharding structurally
with positions taken from config.
"""
from repro.configs.base import ArchConfig, EncDecConfig

CONFIG = ArchConfig(
    name="whisper-tiny",
    family="encdec",
    num_layers=4,  # decoder layers
    d_model=384,
    num_heads=6,
    num_kv_heads=6,
    d_ff=1536,
    vocab_size=51865,
    norm_type="layernorm",
    norm_eps=1e-5,
    mlp_type="gelu_mlp",
    attn_qkv_bias=True,
    attn_out_bias=True,
    mlp_bias=True,
    rope_type="learned",
    encdec=EncDecConfig(encoder_layers=4, encoder_seq=1500, max_target_positions=448),
    source="arXiv:2212.04356",
)
