"""Config registry: ``get_config(arch_id)`` / ``ALL_ARCHS`` / shapes."""
from __future__ import annotations

import importlib

from repro.configs.base import SHAPES, ArchConfig, ShapeConfig, smoke

_MODULES = {
    "qwen2-vl-72b": "repro.configs.qwen2_vl_72b",
    "olmo-1b": "repro.configs.olmo_1b",
    "starcoder2-7b": "repro.configs.starcoder2_7b",
    "deepseek-67b": "repro.configs.deepseek_67b",
    "stablelm-1.6b": "repro.configs.stablelm_1_6b",
    "phi3.5-moe-42b-a6.6b": "repro.configs.phi3_5_moe",
    "qwen2-moe-a2.7b": "repro.configs.qwen2_moe_a2_7b",
    "mamba2-130m": "repro.configs.mamba2_130m",
    "hymba-1.5b": "repro.configs.hymba_1_5b",
    "whisper-tiny": "repro.configs.whisper_tiny",
}

ALL_ARCHS: "tuple[str, ...]" = tuple(_MODULES)

# short aliases accepted by --arch
_ALIASES = {
    "qwen2-vl": "qwen2-vl-72b",
    "olmo": "olmo-1b",
    "starcoder2": "starcoder2-7b",
    "deepseek": "deepseek-67b",
    "stablelm": "stablelm-1.6b",
    "phi3.5-moe": "phi3.5-moe-42b-a6.6b",
    "qwen2-moe": "qwen2-moe-a2.7b",
    "mamba2": "mamba2-130m",
    "hymba": "hymba-1.5b",
    "whisper": "whisper-tiny",
}


def get_config(name: str) -> ArchConfig:
    key = _ALIASES.get(name, name)
    mod = _MODULES.get(key)
    if mod is None:
        raise KeyError(f"unknown arch '{name}'; known: {sorted(_MODULES)}")
    return importlib.import_module(mod).CONFIG


def get_shape(name: str) -> ShapeConfig:
    if name not in SHAPES:
        raise KeyError(f"unknown shape '{name}'; known: {sorted(SHAPES)}")
    return SHAPES[name]


def cells() -> "list[tuple[str, str]]":
    """All (arch, shape) dry-run cells, with documented skips applied."""
    out = []
    for arch in ALL_ARCHS:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            if shape.name == "long_500k" and not cfg.subquadratic:
                continue  # full-attention archs skip 524k decode (DESIGN.md §4)
            out.append((arch, shape.name))
    return out


__all__ = [
    "ALL_ARCHS",
    "SHAPES",
    "ArchConfig",
    "ShapeConfig",
    "cells",
    "get_config",
    "get_shape",
    "smoke",
]
