"""StarCoder2-7B [arXiv:2402.19173; hf].

32L, d_model=4608, 36 heads (GQA kv=4), d_ff=18432, vocab=49152.
Parametric LayerNorm with bias, plain GELU MLP (c_fc/c_proj), RoPE,
attention + MLP biases.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="starcoder2-7b",
    family="dense",
    num_layers=32,
    d_model=4608,
    num_heads=36,
    num_kv_heads=4,
    d_ff=18432,
    vocab_size=49152,
    norm_type="layernorm",
    norm_eps=1e-5,
    mlp_type="gelu_mlp",
    attn_qkv_bias=True,
    attn_out_bias=True,
    mlp_bias=True,
    rope_type="rope",
    rope_theta=1_000_000.0,
    source="arXiv:2402.19173",
)
