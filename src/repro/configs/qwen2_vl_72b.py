"""Qwen2-VL-72B language backbone [arXiv:2409.12191; hf].

80L, d_model=8192, 64 heads (GQA kv=8), d_ff=29568, vocab=152064.
M-RoPE (multimodal 3D rotary, sections t/h/w), dynamic-resolution vision
frontend is a STUB: ``input_specs()`` supplies precomputed patch embeddings
merged at image-pad positions.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-72b",
    family="vlm",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=29568,
    vocab_size=152064,
    head_dim=128,
    norm_type="rmsnorm",
    norm_eps=1e-6,
    mlp_type="swiglu",
    attn_qkv_bias=True,  # Qwen2 uses QKV bias
    rope_type="mrope",
    rope_theta=1_000_000.0,
    mrope_sections=(16, 24, 24),  # t,h,w split of head_dim/2
    vision_stub=True,
    num_patches=256,
    source="arXiv:2409.12191",
)
