"""Hymba-1.5B [arXiv:2411.13676; hf] — hybrid-head architecture.

32L, d_model=1600, 25 heads (GQA kv=5, head_dim=64), d_ff=5504,
ssm_state=16, vocab=32001. Every block runs attention heads and Mamba
(SSM) heads IN PARALLEL on the same input; outputs are fused (mean of the
per-path normalized outputs). Sliding-window attention (1024) everywhere
except 3 global layers (first / middle / last); consecutive layers share
KV (cross-layer KV sharing, group=2); 128 learned meta tokens prefix the
sequence. Sub-quadratic: runs the long_500k cell.
"""
from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="hymba-1.5b",
    family="hybrid",
    num_layers=32,
    d_model=1600,
    num_heads=25,
    num_kv_heads=5,
    d_ff=5504,
    vocab_size=32001,
    head_dim=64,
    norm_type="rmsnorm",
    norm_eps=1e-6,
    mlp_type="swiglu",
    rope_type="rope",
    rope_theta=10_000.0,
    sliding_window=1024,
    global_attn_layers=(0, 15, 31),
    kv_share_group=2,
    meta_tokens=128,
    hybrid_attn_ssm=True,
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=64, n_groups=1, chunk=256),
    source="arXiv:2411.13676",
)
