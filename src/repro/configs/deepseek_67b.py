"""DeepSeek-67B [arXiv:2401.02954; hf] — llama-architecture dense LM.

95L, d_model=8192, 64 heads (GQA kv=8), d_ff=22016, vocab=102400.
RMSNorm, SwiGLU, RoPE.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-67b",
    family="dense",
    num_layers=95,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=22016,
    vocab_size=102400,
    norm_type="rmsnorm",
    norm_eps=1e-6,
    mlp_type="swiglu",
    rope_type="rope",
    rope_theta=10_000.0,
    source="arXiv:2401.02954",
)
