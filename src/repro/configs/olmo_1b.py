"""OLMo-1B [arXiv:2402.00838; hf].

16L, d_model=2048, 16 heads (MHA), d_ff=8192, vocab=50304.
Non-parametric LayerNorm (no scale/bias), SwiGLU, RoPE, tied embeddings.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="olmo-1b",
    family="dense",
    num_layers=16,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=8192,
    vocab_size=50304,
    norm_type="nonparam_layernorm",
    norm_eps=1e-5,
    mlp_type="swiglu",
    rope_type="rope",
    rope_theta=10_000.0,
    tie_embeddings=True,
    source="arXiv:2402.00838",
)
