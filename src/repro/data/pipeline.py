"""Shard-aware data pipeline with futurized double-buffered prefetch.

This is the paper's *partition benchmark* (Fig. 4) pattern as a production
feature: host batch construction and host->device transfer of batch i+1
overlap device compute of batch i, orchestrated entirely through
``repro.core`` futures on a dedicated work queue.  A straggling producer
is absorbed by the prefetch depth (DESIGN.md §6).
"""
from __future__ import annotations

import threading
from collections import deque
from typing import Any, Callable, Iterator, Optional

import jax
import numpy as np

from repro.core.executor import get_runtime
from repro.core.futures import Future


class SyntheticTokens:
    """Deterministic synthetic LM batches, indexable for exact resume.

    batch(i) is a pure function of (seed, i) — after restart, resuming at
    cursor c reproduces the identical stream (fault-tolerance substrate).
    """

    def __init__(self, vocab_size: int, seq_len: int, batch_size: int, seed: int = 0):
        self.vocab_size = vocab_size
        self.seq_len = seq_len
        self.batch_size = batch_size
        self.seed = seed

    def batch(self, index: int) -> "dict[str, np.ndarray]":
        rng = np.random.default_rng((self.seed, index))
        toks = rng.integers(
            0, self.vocab_size, size=(self.batch_size, self.seq_len + 1), dtype=np.int32
        )
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


class Pipeline:
    """Futurized prefetching loader.

    ``get()`` returns the next device-resident batch, while ``depth``
    future batches are already in flight on the ``data`` work queue
    (host gen) and transferred via ``jax.device_put`` (async).
    """

    def __init__(
        self,
        source,
        *,
        start: int = 0,
        depth: int = 2,
        shardings: "dict[str, Any] | None" = None,
        transform: "Optional[Callable]" = None,
    ):
        self.source = source
        self.cursor = start
        self.depth = depth
        self.shardings = shardings
        self.transform = transform
        self._queue = get_runtime().queue("data-pipeline")
        self._inflight: "deque[tuple[int, Future]]" = deque()
        self._lock = threading.Lock()
        for _ in range(depth):
            self._issue()

    def _issue(self) -> None:
        idx = self.cursor
        self.cursor += 1

        def produce():
            host = self.source.batch(idx)
            if self.transform is not None:
                host = self.transform(host)
            if self.shardings:
                return {
                    k: jax.device_put(v, self.shardings.get(k)) for k, v in host.items()
                }
            return {k: jax.numpy.asarray(v) for k, v in host.items()}

        self._inflight.append((idx, self._queue.submit(produce)))

    def get(self) -> "tuple[int, dict]":
        """(index, device batch) — blocks only if prefetch fell behind."""
        with self._lock:
            idx, fut = self._inflight.popleft()
            self._issue()
        return idx, fut.get()

    def get_async(self) -> "tuple[int, Future]":
        with self._lock:
            idx, fut = self._inflight.popleft()
            self._issue()
        return idx, fut

    def state(self) -> dict:
        """Checkpointable cursor (first not-yet-consumed index)."""
        with self._lock:
            first_inflight = self._inflight[0][0] if self._inflight else self.cursor
        return {"cursor": first_inflight}

    def close(self) -> None:
        """Tear down prefetch: stop issuing and settle every in-flight
        batch (producer failures are swallowed — the pipeline is going
        away).  Safe to call more than once; ``get()`` after close raises
        from the empty deque."""
        with self._lock:
            inflight, self._inflight = list(self._inflight), deque()
        for _, fut in inflight:
            try:
                fut.wait()
            except Exception:  # noqa: BLE001 - teardown is best-effort
                pass
