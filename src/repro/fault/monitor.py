"""Fault-tolerance substrate: heartbeats, straggler detection, restart.

Fail-stop model (DESIGN.md §6): every worker ticks a heartbeat; a missed
deadline marks the worker dead, the launcher exits non-zero and the
cluster scheduler relaunches from the latest checkpoint (tested by
killing a training loop mid-run and asserting bitwise-identical resume).

Straggler mitigation at the host level: per-step EWMA timing; steps
slower than ``threshold x`` EWMA raise a straggler event — the futurized
data pipeline absorbs producer stragglers via prefetch depth, and the
event lets the launcher trigger re-sharding away from a slow host.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Optional

__all__ = ["Heartbeat", "StepMonitor", "StragglerEvent"]


class Heartbeat:
    """Soft heartbeat: worker calls ``tick()``; ``check()`` (monitor side)
    returns False once the deadline is missed.

    The dead latch edge-triggers ``on_dead`` (once per death, not once per
    ``check``) and CLEARS when the worker resumes ticking: a flapping
    worker — dead, recovered, dead again — fires ``on_dead`` on every
    dead transition.  Without the reset the latch stuck forever after the
    first miss, so a recovered worker read alive from ``check()`` while a
    second death could never re-arm the callback."""

    def __init__(self, timeout_s: float = 60.0, on_dead: "Optional[Callable]" = None):
        self.timeout_s = timeout_s
        self.on_dead = on_dead
        self._last = time.monotonic()
        self._lock = threading.Lock()
        self._dead = False

    def tick(self) -> None:
        with self._lock:
            self._last = time.monotonic()

    def force_expire(self) -> None:
        """Backdate the last tick past the deadline so the next ``check()``
        reads dead — the fault injector's heartbeat-corruption hook
        (``repro.fault.inject``).  A subsequent ``tick()`` recovers the
        worker exactly as a real flap would."""
        with self._lock:
            self._last = time.monotonic() - 2.0 * self.timeout_s

    def check(self) -> bool:
        with self._lock:
            alive = (time.monotonic() - self._last) < self.timeout_s
            fire = False
            if not alive and not self._dead:
                self._dead = True
                fire = bool(self.on_dead)
            elif alive and self._dead:
                # Recovery: the worker ticked again after missing its
                # deadline — clear the latch so the next miss re-fires.
                self._dead = False
        if fire:
            self.on_dead()
        return alive


@dataclass
class StragglerEvent:
    step: int
    seconds: float
    ewma: float
    ratio: float


class StepMonitor:
    """EWMA step timing + straggler detection."""

    def __init__(self, alpha: float = 0.2, threshold: float = 2.5, warmup: int = 3):
        self.alpha = alpha
        self.threshold = threshold
        self.warmup = warmup
        self.ewma: "Optional[float]" = None
        self.count = 0
        self.events: "list[StragglerEvent]" = []

    def record(self, step: int, seconds: float) -> "Optional[StragglerEvent]":
        self.count += 1
        if self.ewma is None:
            self.ewma = seconds
            return None
        ev = None
        if self.count > self.warmup and seconds > self.threshold * self.ewma:
            ev = StragglerEvent(step, seconds, self.ewma, seconds / self.ewma)
            self.events.append(ev)
        # stragglers should not poison the baseline
        if ev is None:
            self.ewma = (1 - self.alpha) * self.ewma + self.alpha * seconds
        return ev
