"""Deterministic, seedable fault injection — the chaos layer (DESIGN.md §16).

Recovery code that is only exercised by real failures is untested code.
This module turns every failure mode the runtime claims to survive into a
*scheduled, replayable event*: kill a worker at step k, drop or delay
parcels on a transport, stall a device lane so ``least_loaded`` must route
around it, corrupt a heartbeat so the monitor declares a death.  Every
probabilistic decision draws from one seeded ``numpy`` Generator, so a
(seed, schedule) pair names exactly one failure scenario — the property
tests in ``tests/test_elastic_train.py`` and the train driver's
``--chaos`` flag replay the same scenarios bit-identically.

Hook points (all shipped by this PR):

* ``Parcelport.set_fault_filter`` — consulted on every outbound parcel;
  drops fail the sender's future with ``ParcelDropped`` *before* the wire
  (later parcels on the channel are untouched, so channel FIFO holds),
  delays sleep on the sending thread (later parcels queue behind — FIFO
  again).
* ``Scheduler.cordon`` — removes a device from placement without touching
  its in-flight work.
* ``Heartbeat.force_expire`` — backdates the last tick so the next
  ``check()`` fires ``on_dead``, exactly like a real missed deadline.
* ``LoopbackParcelport.kill`` / cluster worker ``proc.kill()`` — hard
  worker death; the elastic trainer's ``kill_at_step`` arms the same
  death mid-step, inside the victim's own shard execution.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass
from typing import Any, Optional

import numpy as np

__all__ = ["FaultInjector", "InjectedFault", "ParcelDropped"]


class ParcelDropped(RuntimeError):
    """A parcel discarded by fault injection before it reached the wire.

    Retry-safe by construction: the parcel was never sent, so nothing on
    the remote side half-ran and channel FIFO for later parcels is
    unaffected.  Callers (the elastic trainer) treat this as transient and
    re-send, unlike a worker death which forces a reshard."""


@dataclass
class InjectedFault:
    """One fault that actually fired (the injector's audit log entry)."""

    kind: str  # "drop" | "delay" | "kill" | "kill_at_step" | "stall" | "hb_expire" | "cordon" | "plan"
    target: str  # "L3", "cpu:0", "worker-2", ...
    action: Optional[str] = None  # parcel action, for drop/delay
    detail: Optional[float] = None  # seconds (delay/stall) or step (kills)


class _ParcelRule:
    """One drop/delay rule: match by action/locality, fire with seeded
    probability ``p``, at most ``count`` times."""

    __slots__ = ("kind", "actions", "localities", "p", "remaining", "seconds")

    def __init__(self, kind, actions, localities, p, count, seconds=0.0):
        self.kind = kind
        self.actions = None if actions is None else frozenset(actions)
        self.localities = None if localities is None else frozenset(localities)
        self.p = float(p)
        self.remaining = count  # None = unlimited
        self.seconds = float(seconds)

    def matches(self, locality_id: int, action: str) -> bool:
        if self.remaining is not None and self.remaining <= 0:
            return False
        if self.actions is not None and action not in self.actions:
            return False
        if self.localities is not None and locality_id not in self.localities:
            return False
        return True


class FaultInjector:
    """Seeded chaos source.  One instance = one deterministic scenario.

    All parcel-level decisions are made under one lock with one RNG in
    call order, so a single-threaded driver replays identically; the
    ``log`` records every fault that actually fired, in firing order.
    """

    def __init__(self, seed: int = 0):
        self.seed = int(seed)
        self.rng = np.random.default_rng(self.seed)
        self.log: "list[InjectedFault]" = []
        self._lock = threading.Lock()
        self._rules: "dict[int, list[_ParcelRule]]" = {}  # id(port) -> rules

    # -- parcel faults -------------------------------------------------------

    def drop_parcels(
        self,
        port,
        *,
        actions: "list[str] | None" = None,
        localities: "list[int] | None" = None,
        p: float = 1.0,
        count: "int | None" = None,
    ) -> None:
        """Fail matching outbound parcels with ``ParcelDropped`` before the
        send.  Matching ``"ping"`` starves the port's heartbeat — that is
        the transport-level heartbeat-corruption vector."""
        self._add_rule(port, _ParcelRule("drop", actions, localities, p, count))

    def delay_parcels(
        self,
        port,
        *,
        seconds: float,
        actions: "list[str] | None" = None,
        localities: "list[int] | None" = None,
        p: float = 1.0,
        count: "int | None" = None,
    ) -> None:
        """Sleep ``seconds`` on the sender before matching parcels ship.
        Later parcels on the same channel queue behind the sleep, so
        ordering guarantees are preserved — delay slows, never reorders."""
        self._add_rule(port, _ParcelRule("delay", actions, localities, p, count, seconds))

    def clear_parcel_faults(self, port) -> None:
        self._rules.pop(id(port), None)
        port.set_fault_filter(None)

    def _add_rule(self, port, rule: _ParcelRule) -> None:
        rules = self._rules.setdefault(id(port), [])
        if not rules:
            port.set_fault_filter(self._make_filter(rules))
        rules.append(rule)

    def _make_filter(self, rules: "list[_ParcelRule]"):
        def _filter(locality_id: int, action: str):
            with self._lock:
                for r in rules:
                    if not r.matches(locality_id, action):
                        continue
                    if r.p < 1.0 and self.rng.random() >= r.p:
                        continue
                    if r.remaining is not None:
                        r.remaining -= 1
                    if r.kind == "drop":
                        self.log.append(InjectedFault("drop", f"L{locality_id}", action))
                        return (
                            "drop",
                            ParcelDropped(
                                f"parcel {action!r} to locality L{locality_id} "
                                "dropped by fault injection"
                            ),
                        )
                    self.log.append(
                        InjectedFault("delay", f"L{locality_id}", action, r.seconds)
                    )
                    return ("delay", r.seconds)
            return None

        return _filter

    # -- worker death --------------------------------------------------------

    def kill_worker(self, target: Any, locality_id: "int | None" = None) -> None:
        """Hard worker death, by transport kind:

        * ``LocalClusterParcelport`` + locality id: SIGKILL the worker
          process — the port's monitor thread declares the death.
        * ``LoopbackParcelport`` + locality id: flip the port's fail-fast
          gate (``port.kill``).
        * anything with a ``kill()`` method (elastic trainer workers):
          killed directly.
        """
        workers = getattr(target, "_workers", None)
        if workers is not None and locality_id is not None:  # cluster port
            w = workers.get(locality_id)
            if w is not None and w.proc.is_alive():
                w.proc.kill()
            self.log.append(InjectedFault("kill", f"L{locality_id}"))
            return
        if locality_id is not None and hasattr(target, "kill"):  # loopback port
            target.kill(locality_id)
            self.log.append(InjectedFault("kill", f"L{locality_id}"))
            return
        if hasattr(target, "kill"):
            target.kill()
            self.log.append(InjectedFault("kill", str(getattr(target, "wid", target))))
            return
        raise TypeError(f"don't know how to kill {target!r}")

    def kill_at_step(self, worker, step: int) -> None:
        """Arm a mid-step death: the worker dies inside its own shard
        execution at training step ``step`` (the elastic trainer's
        reshard-and-re-execute path is only reachable this way)."""
        worker.kill_at_step(int(step))
        self.log.append(
            InjectedFault("kill_at_step", str(getattr(worker, "wid", worker)), detail=float(step))
        )

    # -- device / scheduler faults -------------------------------------------

    def stall_lane(self, device, seconds: float):
        """Occupy a device's ops lane with a GIL-releasing sleep: the lane
        depth rises, ``least_loaded`` routes new work elsewhere, and work
        already queued behind the stall simply waits (a slow device, not a
        dead one).  Returns the stall's future."""
        self.log.append(InjectedFault("stall", device.key, detail=float(seconds)))
        return device.ops_queue.submit(lambda: time.sleep(seconds))

    def cordon_device(self, scheduler, device_key: str) -> None:
        """Remove a device from placement via the scheduler hook."""
        scheduler.cordon(device_key)
        self.log.append(InjectedFault("cordon", device_key))

    def uncordon_device(self, scheduler, device_key: str) -> None:
        scheduler.uncordon(device_key)

    # -- heartbeat corruption ------------------------------------------------

    def corrupt_heartbeat(self, heartbeat) -> None:
        """Backdate a heartbeat past its deadline: the next ``check()``
        fires ``on_dead`` exactly as a real missed deadline would; a
        subsequent ``tick()`` recovers the worker (flap)."""
        heartbeat.force_expire()
        self.log.append(InjectedFault("hb_expire", str(id(heartbeat))))

    # -- scenario planning ---------------------------------------------------

    def plan_kill(self, steps: int, victims: "list") -> "tuple[int, Any]":
        """Deterministically draw (kill_step, victim) from the seed — the
        train driver's ``--chaos N`` flag and the property tests share
        this, so one seed names one exact failure scenario.  The kill step
        lands strictly inside the run (never step 0)."""
        victims = list(victims)
        if not victims:
            raise ValueError("plan_kill needs at least one victim")
        k = int(self.rng.integers(1, max(2, int(steps))))
        v = victims[int(self.rng.integers(0, len(victims)))]
        self.log.append(
            InjectedFault("plan", str(getattr(v, "wid", v)), detail=float(k))
        )
        return k, v
