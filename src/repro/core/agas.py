"""AGAS analogue: a process-global registry of Global IDs (paper §3, §4).

Every runtime object (device, buffer, program) is registered under a GID;
client handles hold the GID and resolve through the registry, which makes
them location-transparent: moving the backing data to another device only
updates the placement record, never the handle.  In multi-controller JAX
the "remote" case is a non-addressable device in ``jax.devices()``; the
registry does not care which it is.
"""
from __future__ import annotations

import itertools
import threading
from dataclasses import dataclass, field
from typing import Any, Optional

__all__ = ["GID", "Placement", "Registry", "registry"]

GID = int


@dataclass(frozen=True)
class Placement:
    """Where an object's backing data lives."""

    device_key: str  # e.g. "cpu:0", "tpu:13"
    process_index: int = 0
    mesh_axes: "tuple[str, ...] | None" = None  # set for mesh-sharded objects
    spec: Any = None  # PartitionSpec for mesh-sharded objects

    @property
    def is_sharded(self) -> bool:
        return self.mesh_axes is not None


@dataclass
class _Record:
    obj: Any
    placement: Placement
    kind: str = "object"
    meta: dict = field(default_factory=dict)


class Registry:
    """GID -> (object, placement). Thread-safe; one per process."""

    def __init__(self):
        self._counter = itertools.count(1)
        self._records: dict[GID, _Record] = {}
        self._lock = threading.Lock()

    def register(self, obj: Any, placement: Placement, kind: str = "object", **meta) -> GID:
        gid = next(self._counter)
        with self._lock:
            self._records[gid] = _Record(obj, placement, kind, dict(meta))
        return gid

    def resolve(self, gid: GID) -> Any:
        with self._lock:
            rec = self._records.get(gid)
        if rec is None:
            raise KeyError(f"GID {gid} is not registered")
        return rec.obj

    def placement(self, gid: GID) -> Placement:
        with self._lock:
            rec = self._records.get(gid)
        if rec is None:
            raise KeyError(f"GID {gid} is not registered")
        return rec.placement

    def update_placement(self, gid: GID, placement: Placement) -> None:
        with self._lock:
            rec = self._records.get(gid)
            if rec is None:
                raise KeyError(f"GID {gid} is not registered")
            rec.placement = placement

    def unregister(self, gid: GID) -> None:
        with self._lock:
            self._records.pop(gid, None)

    def by_kind(self, kind: str) -> "list[tuple[GID, Any]]":
        with self._lock:
            return [(g, r.obj) for g, r in self._records.items() if r.kind == kind]

    def __len__(self) -> int:
        with self._lock:
            return len(self._records)


registry = Registry()
