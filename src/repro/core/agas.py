"""AGAS analogue: a process-global registry of Global IDs (paper §3, §4).

Every runtime object (device, buffer, program) is registered under a GID;
client handles hold the GID and resolve through the registry, which makes
them location-transparent: moving the backing data to another device only
updates the placement record, never the handle.  In multi-controller JAX
the "remote" case is a non-addressable device in ``jax.devices()``; the
registry does not care which it is.

Locality-scoped GIDs (DESIGN.md §10): every process is one *locality*;
parcelport workers call ``set_locality_id`` at startup, and every GID they
mint carries their locality in its high bits (``locality_of`` recovers
it).  Cross-locality resolution happens through *proxy records*: when a
remote object's handle (e.g. ``RemoteBuffer``) arrives here, it registers
itself under the remote-minted GID via ``register_proxy`` — the same GID
then resolves on both sides of the wire, to the object on its owner and
to the proxy everywhere else.  A GID that is neither local nor proxied
raises a ``KeyError`` naming the owning locality.

Scheduler support (DESIGN.md §9): alongside the forward GID map the
registry maintains a *reverse* index ``device_key -> {GID}`` and a
per-device resident-bytes counter (fed by ``nbytes`` registration
metadata).  The ``affinity`` placement policy scores candidate devices
from these records in O(args) instead of scanning every registration —
the AGAS placement data is the percolation-avoidance signal.

Spill residency (DESIGN.md §14): a buffer evicted to host memory moves
its placement record to the pseudo-device ``HOST_KEY`` — the bytes leave
the device's resident total (placement veto sees the truth) and
``resident_bytes(HOST_KEY)`` reports the spilled pool.  The GID never
changes; refetch moves the record back.
"""
from __future__ import annotations

import itertools
import threading
import weakref
from dataclasses import dataclass, field
from typing import Any, Optional

__all__ = [
    "GID",
    "HOST_KEY",
    "Placement",
    "Registry",
    "registry",
    "set_locality_id",
    "get_locality_id",
    "locality_of",
]

GID = int

# Placement key for data spilled out of device memory into host RAM.  Not a
# schedulable device: policies never place work on it, but the reverse index
# and byte accounting treat it like any other location.
HOST_KEY = "host"

# Locality scoping: GID = (locality_id << _LOC_SHIFT) | sequence.  The
# parent process is locality 0 (seed-compatible: its GIDs are unchanged);
# parcelport workers are assigned unique nonzero ids before minting.
_LOC_SHIFT = 40
_locality_id = 0


def set_locality_id(locality_id: int) -> None:
    """Declare this process's locality (parcelport workers, at startup)."""
    global _locality_id
    _locality_id = int(locality_id)


def get_locality_id() -> int:
    return _locality_id


def locality_of(gid: GID) -> int:
    """The locality that minted ``gid``."""
    return gid >> _LOC_SHIFT


@dataclass(frozen=True)
class Placement:
    """Where an object's backing data lives."""

    device_key: str  # e.g. "cpu:0", "tpu:13"
    process_index: int = 0
    mesh_axes: "tuple[str, ...] | None" = None  # set for mesh-sharded objects
    spec: Any = None  # PartitionSpec for mesh-sharded objects

    @property
    def is_sharded(self) -> bool:
        return self.mesh_axes is not None


@dataclass
class _Record:
    obj: Any  # the object itself, or a weakref.ref to it (weak=True)
    placement: Placement
    kind: str = "object"
    meta: dict = field(default_factory=dict)
    weak: bool = False

    def target(self) -> Any:
        return self.obj() if self.weak else self.obj


class Registry:
    """GID -> (object, placement). Thread-safe; one per process.

    Registrations may carry ``nbytes=<int>`` metadata; the registry then
    keeps per-device resident-byte totals in sync across
    ``register`` / ``update_placement`` / ``unregister``.
    """

    def __init__(self):
        self._counter = itertools.count(1)
        self._records: dict[GID, _Record] = {}
        self._by_device: dict[str, set[GID]] = {}
        self._bytes: dict[str, int] = {}
        self._lock = threading.Lock()

    # -- index maintenance (call with lock held) ----------------------------

    def _index_add(self, gid: GID, rec: _Record) -> None:
        key = rec.placement.device_key
        self._by_device.setdefault(key, set()).add(gid)
        nb = rec.meta.get("nbytes", 0)
        if nb:
            self._bytes[key] = self._bytes.get(key, 0) + nb

    def _index_remove(self, gid: GID, rec: _Record) -> None:
        key = rec.placement.device_key
        gids = self._by_device.get(key)
        if gids is not None:
            gids.discard(gid)
            if not gids:
                del self._by_device[key]
        nb = rec.meta.get("nbytes", 0)
        if nb:
            left = self._bytes.get(key, 0) - nb
            if left > 0:
                self._bytes[key] = left
            else:
                self._bytes.pop(key, None)

    # -- core surface -------------------------------------------------------

    def register(self, obj: Any, placement: Placement, kind: str = "object", **meta) -> GID:
        # The registry is an address book, not an owner: objects are held
        # weakly when possible so a dropped Buffer/Program can be GC'd and
        # its finalizer can retire this record (HPX AGAS ref-counts; here
        # the CPython GC plays that role).
        try:
            store, weak = weakref.ref(obj), True
        except TypeError:
            store, weak = obj, False
        gid = (_locality_id << _LOC_SHIFT) | next(self._counter)
        with self._lock:
            rec = self._records[gid] = _Record(store, placement, kind, dict(meta), weak)
            self._index_add(gid, rec)
        return gid

    def register_proxy(self, obj: Any, gid: GID, placement: Placement, kind: str = "proxy", **meta) -> bool:
        """Insert a record under a *foreign-minted* GID (cross-locality
        resolution: the remote object's local proxy answers for its GID).
        Returns False — and registers nothing — when the GID already
        resolves here (e.g. loopback transports, where the "remote" object
        lives in this very registry)."""
        try:
            store, weak = weakref.ref(obj), True
        except TypeError:
            store, weak = obj, False
        with self._lock:
            if gid in self._records:
                return False
            rec = self._records[gid] = _Record(store, placement, kind, dict(meta), weak)
            self._index_add(gid, rec)
        return True

    def _missing(self, gid: GID) -> KeyError:
        owner = locality_of(gid)
        if owner != _locality_id:
            return KeyError(
                f"GID {gid} is owned by locality L{owner} and has no proxy here; "
                "resolve it through a parcelport"
            )
        return KeyError(f"GID {gid} is not registered")

    def resolve(self, gid: GID) -> Any:
        with self._lock:
            rec = self._records.get(gid)
        if rec is None:
            raise self._missing(gid)
        obj = rec.target()
        if obj is None:
            raise KeyError(f"GID {gid} refers to a collected object")
        return obj

    def placement(self, gid: GID) -> Placement:
        with self._lock:
            rec = self._records.get(gid)
        if rec is None:
            raise self._missing(gid)
        return rec.placement

    def update_placement(self, gid: GID, placement: Placement) -> None:
        with self._lock:
            rec = self._records.get(gid)
            if rec is None:
                raise KeyError(f"GID {gid} is not registered")
            self._index_remove(gid, rec)
            rec.placement = placement
            self._index_add(gid, rec)

    def update_nbytes(self, gid: GID, nbytes: int) -> None:
        """Re-declare a registration's resident size (page pools and other
        growable objects whose footprint changes after registration).  The
        reverse-index byte totals move with it, so the scheduler's
        memory veto and spill accounting track the *current* footprint —
        a pool slab registers its slab bytes once, then a paged KV cache
        re-charges each sequence's pages as they are allocated/freed."""
        with self._lock:
            rec = self._records.get(gid)
            if rec is None:
                raise KeyError(f"GID {gid} is not registered")
            self._index_remove(gid, rec)
            rec.meta["nbytes"] = int(nbytes)
            self._index_add(gid, rec)

    def unregister(self, gid: GID) -> None:
        with self._lock:
            rec = self._records.pop(gid, None)
            if rec is not None:
                self._index_remove(gid, rec)

    def by_kind(self, kind: str) -> "list[tuple[GID, Any]]":
        with self._lock:
            out = []
            for g, r in self._records.items():
                if r.kind != kind:
                    continue
                obj = r.target()
                if obj is not None:
                    out.append((g, obj))
            return out

    # -- scheduler queries (reverse index) ----------------------------------

    def gids_on(self, device_key: str, kind: "str | None" = None) -> "list[GID]":
        """GIDs whose placement is ``device_key`` (optionally one kind)."""
        with self._lock:
            gids = self._by_device.get(device_key)
            if not gids:
                return []
            if kind is None:
                return list(gids)
            return [g for g in gids if self._records[g].kind == kind]

    def resident_bytes(self, device_key: str) -> int:
        """Total registered bytes currently placed on ``device_key``."""
        with self._lock:
            return self._bytes.get(device_key, 0)

    def resident_bytes_by_device(self) -> "dict[str, int]":
        with self._lock:
            return dict(self._bytes)

    def spilled_bytes(self) -> int:
        """Total bytes currently evicted to host RAM (``HOST_KEY`` pool)."""
        return self.resident_bytes(HOST_KEY)

    def __len__(self) -> int:
        with self._lock:
            return len(self._records)


registry = Registry()
