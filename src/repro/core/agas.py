"""AGAS analogue: a process-global registry of Global IDs (paper §3, §4).

Every runtime object (device, buffer, program) is registered under a GID;
client handles hold the GID and resolve through the registry, which makes
them location-transparent: moving the backing data to another device only
updates the placement record, never the handle.  In multi-controller JAX
the "remote" case is a non-addressable device in ``jax.devices()``; the
registry does not care which it is.

Scheduler support (DESIGN.md §9): alongside the forward GID map the
registry maintains a *reverse* index ``device_key -> {GID}`` and a
per-device resident-bytes counter (fed by ``nbytes`` registration
metadata).  The ``affinity`` placement policy scores candidate devices
from these records in O(args) instead of scanning every registration —
the AGAS placement data is the percolation-avoidance signal.
"""
from __future__ import annotations

import itertools
import threading
import weakref
from dataclasses import dataclass, field
from typing import Any, Optional

__all__ = ["GID", "Placement", "Registry", "registry"]

GID = int


@dataclass(frozen=True)
class Placement:
    """Where an object's backing data lives."""

    device_key: str  # e.g. "cpu:0", "tpu:13"
    process_index: int = 0
    mesh_axes: "tuple[str, ...] | None" = None  # set for mesh-sharded objects
    spec: Any = None  # PartitionSpec for mesh-sharded objects

    @property
    def is_sharded(self) -> bool:
        return self.mesh_axes is not None


@dataclass
class _Record:
    obj: Any  # the object itself, or a weakref.ref to it (weak=True)
    placement: Placement
    kind: str = "object"
    meta: dict = field(default_factory=dict)
    weak: bool = False

    def target(self) -> Any:
        return self.obj() if self.weak else self.obj


class Registry:
    """GID -> (object, placement). Thread-safe; one per process.

    Registrations may carry ``nbytes=<int>`` metadata; the registry then
    keeps per-device resident-byte totals in sync across
    ``register`` / ``update_placement`` / ``unregister``.
    """

    def __init__(self):
        self._counter = itertools.count(1)
        self._records: dict[GID, _Record] = {}
        self._by_device: dict[str, set[GID]] = {}
        self._bytes: dict[str, int] = {}
        self._lock = threading.Lock()

    # -- index maintenance (call with lock held) ----------------------------

    def _index_add(self, gid: GID, rec: _Record) -> None:
        key = rec.placement.device_key
        self._by_device.setdefault(key, set()).add(gid)
        nb = rec.meta.get("nbytes", 0)
        if nb:
            self._bytes[key] = self._bytes.get(key, 0) + nb

    def _index_remove(self, gid: GID, rec: _Record) -> None:
        key = rec.placement.device_key
        gids = self._by_device.get(key)
        if gids is not None:
            gids.discard(gid)
            if not gids:
                del self._by_device[key]
        nb = rec.meta.get("nbytes", 0)
        if nb:
            left = self._bytes.get(key, 0) - nb
            if left > 0:
                self._bytes[key] = left
            else:
                self._bytes.pop(key, None)

    # -- core surface -------------------------------------------------------

    def register(self, obj: Any, placement: Placement, kind: str = "object", **meta) -> GID:
        # The registry is an address book, not an owner: objects are held
        # weakly when possible so a dropped Buffer/Program can be GC'd and
        # its finalizer can retire this record (HPX AGAS ref-counts; here
        # the CPython GC plays that role).
        try:
            store, weak = weakref.ref(obj), True
        except TypeError:
            store, weak = obj, False
        gid = next(self._counter)
        with self._lock:
            rec = self._records[gid] = _Record(store, placement, kind, dict(meta), weak)
            self._index_add(gid, rec)
        return gid

    def resolve(self, gid: GID) -> Any:
        with self._lock:
            rec = self._records.get(gid)
        if rec is None:
            raise KeyError(f"GID {gid} is not registered")
        obj = rec.target()
        if obj is None:
            raise KeyError(f"GID {gid} refers to a collected object")
        return obj

    def placement(self, gid: GID) -> Placement:
        with self._lock:
            rec = self._records.get(gid)
        if rec is None:
            raise KeyError(f"GID {gid} is not registered")
        return rec.placement

    def update_placement(self, gid: GID, placement: Placement) -> None:
        with self._lock:
            rec = self._records.get(gid)
            if rec is None:
                raise KeyError(f"GID {gid} is not registered")
            self._index_remove(gid, rec)
            rec.placement = placement
            self._index_add(gid, rec)

    def unregister(self, gid: GID) -> None:
        with self._lock:
            rec = self._records.pop(gid, None)
            if rec is not None:
                self._index_remove(gid, rec)

    def by_kind(self, kind: str) -> "list[tuple[GID, Any]]":
        with self._lock:
            out = []
            for g, r in self._records.items():
                if r.kind != kind:
                    continue
                obj = r.target()
                if obj is not None:
                    out.append((g, obj))
            return out

    # -- scheduler queries (reverse index) ----------------------------------

    def gids_on(self, device_key: str, kind: "str | None" = None) -> "list[GID]":
        """GIDs whose placement is ``device_key`` (optionally one kind)."""
        with self._lock:
            gids = self._by_device.get(device_key)
            if not gids:
                return []
            if kind is None:
                return list(gids)
            return [g for g in gids if self._records[g].kind == kind]

    def resident_bytes(self, device_key: str) -> int:
        """Total registered bytes currently placed on ``device_key``."""
        with self._lock:
            return self._bytes.get(device_key, 0)

    def resident_bytes_by_device(self) -> "dict[str, int]":
        with self._lock:
            return dict(self._bytes)

    def __len__(self) -> int:
        with self._lock:
            return len(self._records)


registry = Registry()
