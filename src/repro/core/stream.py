"""Streams & events — intra-device concurrency (DESIGN.md §11).

The paper's central performance claim is that asynchronous transfers and
kernel launches overlap with each other and with host work; a single
per-device FIFO queue cannot express that — a transfer blocks the kernel
behind it even when they touch disjoint buffers.  This module is the
CUDA-streams/events analogue (in the spirit of StarPU worker lanes and
Specx task lanes): a ``Stream`` is one ordered lane of work on one
device, an ``Event`` a recorded point in a stream that other streams and
hosts can wait on.

Concept mapping (DESIGN.md §2):

  * ``cudaStream_t``        -> ``Stream`` (one ``executor.Lane`` — or, for
    remote devices, one ordered parcel channel)
  * ``cudaEvent_t``         -> ``Event`` (``record`` / ``wait`` / ``query``,
    backed by the ``Future`` machinery)
  * ``cudaStreamWaitEvent`` -> ``Stream.wait_event``
  * ``cudaStreamSynchronize`` -> ``Stream.synchronize``
  * stream 0 / default stream -> ``Device.default_stream``

Ordering guarantees (the contract every layer above builds on):

* **Same-stream FIFO** — operations submitted to one stream execute
  strictly in submission order: a write enqueued before a launch lands
  before it, the launch before a later read.  ``Device.ops_queue`` is the
  default stream's lane, so code that never mentions streams keeps the
  exact pre-stream semantics.
* **Cross-stream: explicit only** — two streams have NO implied ordering.
  ``e = s1.record()`` then ``s2.wait_event(e)`` establishes
  happens-before: everything submitted to ``s1`` before the record is
  complete before anything submitted to ``s2`` after the wait runs.
* **Events are one-shot and monotonic** — an ``Event`` marks the point in
  the stream at which it was recorded; re-recording returns a new event.
* **Remote streams = parcel channels** — a stream on a ``RemoteDevice``
  maps onto its own ordered parcel channel: parcels of one stream arrive
  and execute in submission order; parcels of different streams may
  interleave (DESIGN.md §10).

Deadlock rule (CUDA's): ``wait_event`` on an event that will only be
recorded by LATER work on the same stream deadlocks that stream —
record-then-wait, never wait-then-record.
"""
from __future__ import annotations

import threading
from typing import Any, Callable, Optional, Sequence

from repro.core.futures import Future

__all__ = ["Event", "Stream"]


class Event:
    """A recorded point in a stream (``cudaEvent_t`` analogue).

    Becomes READY when every operation submitted to the recording stream
    *before* the ``record()`` has completed.  ``future`` exposes the
    underlying ``Future`` so hosts can compose it (``then``, ``when_all``)
    like any other asynchronous value.
    """

    __slots__ = ("stream", "name", "_future")

    def __init__(self, stream: "Stream", future: Future, name: str = ""):
        self.stream = stream
        self.name = name or f"event:{stream.name}"
        self._future = future

    @property
    def future(self) -> Future:
        return self._future

    def query(self) -> bool:
        """Non-blocking: has the recorded point been reached?
        (``cudaEventQuery``)."""
        return self._future.done()

    def wait(self, timeout: "float | None" = None) -> "Event":
        """Host-side block until the recorded point is reached
        (``cudaEventSynchronize``).  Raises if the stream work ahead of
        the record failed."""
        self._future.get(timeout)
        return self

    synchronize = wait

    def __repr__(self) -> str:
        state = "ready" if self.query() else "pending"
        return f"Event({self.name}, {state})"


class Stream:
    """One ordered lane of work on one device (``cudaStream_t`` analogue).

    Construct via ``Device.create_stream()`` (or use
    ``Device.default_stream``); the stream wraps an ``executor.Lane`` —
    or, on a ``RemoteDevice``, an ordered parcel channel — and forwards
    the device verbs with itself as the ordering scope:

        s1, s2 = dev.create_stream(), dev.create_stream()
        s1.enqueue_write(buf_a, 0, host_a)     # chain A ...
        la = s1.launch(prog, [buf_a], "k", out=[out_a])
        s2.enqueue_write(buf_b, 0, host_b)     # ... overlaps chain B
        lb = s2.launch(prog, [buf_b], "k", out=[out_b])

    Same-stream FIFO holds within each chain; the two chains run
    concurrently (see module docstring for the full contract).
    """

    __slots__ = ("device", "lane", "name", "_events", "_lock", "_completions")

    def __init__(self, device, lane, name: str = ""):
        self.device = device
        self.lane = lane
        self.name = name or getattr(lane, "name", "stream")
        self._events = 0
        self._lock = threading.Lock()
        # Completion futures of async-dispatched launches on this stream:
        # their lane task ends at DISPATCH (XLA runs the kernel in the
        # background), so a lane marker alone would record an event
        # before the kernel finishes.  record() folds these in — the
        # CUDA contract is completion, not submission.
        self._completions: "list[Future]" = []

    # -- plumbing ------------------------------------------------------------

    def _lane_for(self, device):
        """This stream's lane, validated against the submitting device —
        an op scoped to a stream of the WRONG device would silently lose
        its ordering guarantee, so it is refused outright."""
        if device is not self.device and getattr(device, "key", None) != self.device.key:
            raise ValueError(
                f"stream {self.name!r} belongs to device {self.device.key}; "
                f"it cannot order work on device {getattr(device, 'key', device)!r} — "
                "create a stream on that device instead"
            )
        return self.lane

    # -- generic host-callback submission (cudaLaunchHostFunc) ----------------

    def submit(self, fn: Callable, *args, **kwargs) -> Future:
        """Run a host callable at this point in the stream (FIFO with the
        device ops already enqueued here)."""
        return self.lane.submit(fn, *args, **kwargs)

    # -- stream-scoped device verbs -------------------------------------------

    def enqueue_write(self, buf, offset: int, data, count: "int | None" = None) -> Future:
        """``buf.enqueue_write`` ordered by this stream."""
        return buf.enqueue_write(offset, data, count, stream=self)

    def enqueue_read(self, buf, offset: int = 0, count: "int | None" = None) -> Future:
        """``buf.enqueue_read`` ordered by this stream."""
        return buf.enqueue_read(offset, count, stream=self)

    def launch(
        self,
        program,
        args: "Sequence[Any]",
        kernel: str,
        grid=None,
        block=None,
        out=None,
        sync: str = "ready",
    ) -> Future:
        """``program.run`` ordered by this stream (``Program.launch``
        with ``stream=self``)."""
        return program.run(args, kernel, grid=grid, block=block, out=out, sync=sync, stream=self)

    def replay(self, exe, feeds: "dict | None" = None, sync: str = "ready") -> Future:
        """Replay an instantiated single-segment ``GraphExec`` on THIS
        stream (``cudaGraphLaunch(exec, stream)``): the whole fused replay
        — feed writes, launches, fetches — runs FIFO with this stream's
        other work and concurrently with the device's other lanes.  The
        serving engine drives its decode micro-batches through this, one
        engine-owned stream per device, so token feeds overlap default-
        lane compute.  Equivalent to ``exe.replay(feeds, sync, stream=self)``.

        The replay future is noted as a stream completion (the same
        contract as ``Program.run(stream=...)``): a later ``record()`` /
        ``query()`` / ``synchronize()`` covers the replayed graph's
        device completion under the default ``sync="ready"``.  As with
        launches, ``sync="dispatch"`` resolves — and records — at
        dispatch; use ``"ready"`` where events must mean completion."""
        fut = exe.replay(feeds=feeds, sync=sync, stream=self)
        self._note_completion(fut)
        return fut

    # -- events ----------------------------------------------------------------

    def _note_completion(self, fut: Future) -> None:
        """Track an async launch's completion future so ``record()`` means
        device completion (called by ``Program.run(stream=...)``)."""
        with self._lock:
            # Drop already-completed entries: the list stays O(in-flight).
            self._completions = [f for f in self._completions if not f.done()]
            self._completions.append(fut)

    def record(self, name: str = "") -> Event:
        """Record an event at the current tail of this stream
        (``cudaEventRecord``): it fires once everything submitted so far
        has COMPLETED — a lane marker covers transfers and host callbacks
        (their tasks occupy the lane until done), joined with the pending
        launch-completion futures (kernels complete asynchronously after
        their dispatch task releases the lane)."""
        from repro.core.futures import when_all

        self._events += 1
        marker = self.lane.submit(lambda: None)
        with self._lock:
            pending = list(self._completions)
            if pending:
                fut = when_all([marker, *pending], name=f"record:{self.name}").then(
                    lambda _: None, executor="inline"
                )
                # Collapse: the event covers every completion noted so
                # far, so it REPLACES them — a later record (or a
                # synchronize/query) waits on this one future instead of
                # re-joining the whole pending set.
                self._completions = [fut]
            else:
                fut = marker
        return Event(self, fut, name or f"{self.name}:e{self._events}")

    def wait_event(self, event: Event) -> Future:
        """Gate LATER work on this stream behind ``event``
        (``cudaStreamWaitEvent``): returns the future of the gate task.
        Ops submitted to this stream after the call run only once the
        event's recorded point has been reached; the calling host thread
        does not block."""
        if event.stream is self:
            # Same-stream FIFO already orders later work behind the
            # recorded point; a gate task would only park the lane on an
            # earlier task of itself (completed by FIFO) — a no-op.
            return event.future
        fut = event.future

        def _gate() -> None:
            # wait(), not get(): the gate orders, it does not re-raise —
            # a failure surfaces on the event's own future, and on
            # whichever later op actually consumes the poisoned value.
            fut.wait()

        return self.lane.submit(_gate)

    # -- synchronization --------------------------------------------------------

    def query(self) -> bool:
        """Non-blocking: is every operation submitted so far complete —
        including kernels still executing after dispatch?
        (``cudaStreamQuery``)."""
        if self.lane.load().depth != 0:
            return False
        with self._lock:
            return all(f.done() for f in self._completions)

    def synchronize(self) -> "Stream":
        """Block until everything submitted to this stream has COMPLETED —
        the lane is drained and every async launch has finished
        (``cudaStreamSynchronize``)."""
        self.lane.drain()
        with self._lock:
            pending = list(self._completions)
        for f in pending:
            f.wait()
        return self

    def load(self):
        """This lane's backlog snapshot (per-stream ``QueueLoad``)."""
        return self.lane.load()

    def __repr__(self) -> str:
        return f"Stream({self.name} @ {self.device.key}, depth={self.lane.load().depth})"
