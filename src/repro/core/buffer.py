"""Device memory buffer (paper §4, Fig. 2 ``buffer``).

Operations are submitted to one of the owning device's streams (the
default stream unless ``stream=`` is given — DESIGN.md §11) and return
futures — ``enqueue_write`` / ``enqueue_read`` are the
``cudaMemcpyAsync(H2D/D2H)`` analogues; ``copy_to`` moves a buffer between
devices ("effective memory exchange between different entities", §4) and
updates the AGAS placement (percolation).

Offsets are in *elements* (dtype-safe), applied on a flat view of the
buffer, matching HPXCL's (offset, size) windows.  Windows are validated
eagerly at enqueue time: an out-of-range (offset, count) raises
``ValueError`` instead of being silently clamped by XLA's dynamic-slice
semantics (which would read/overwrite the wrong elements).

Hot-path notes (DESIGN.md §8): a full-buffer write whose source already
matches the buffer's shape/dtype skips the flatten/reshape/astype copies —
a ready ``jax.Array`` on the right device is adopted outright (zero-copy);
partial writes donate the old device array to ``_flat_update`` so XLA
updates in place.  Replaying a captured graph may *donate* a buffer's
storage to the fused executable; the buffer is then invalidated and reads
raise until it is written again (CUDA Graphs' ownership rule).
"""
from __future__ import annotations

import threading
import time
import weakref
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import agas
from repro.core.futures import Future

__all__ = ["Buffer"]


@partial(jax.jit, static_argnums=(3,), donate_argnums=(0,))
def _flat_update(dst, src, offset, dst_shape):
    flat = dst.reshape(-1)
    flat = jax.lax.dynamic_update_slice(flat, src.reshape(-1).astype(flat.dtype), (offset,))
    return flat.reshape(dst_shape)


@partial(jax.jit, static_argnums=(2,))
def _flat_slice(src, offset, count):
    return jax.lax.dynamic_slice(src.reshape(-1), (offset,), (count,))


# Guards the submit-once of Buffer.free across racing threads; free is
# rare enough that one process-wide lock beats a lock per buffer.
_free_lock = threading.Lock()


def _check_window(size: int, offset: int, count: int, op: str) -> None:
    """Validate an (offset, count) element window against a buffer of
    ``size`` elements, raising ``ValueError`` on any out-of-range request.

    ``jax.lax.dynamic_slice`` / ``dynamic_update_slice`` CLAMP out-of-range
    start indices instead of failing, so without this check a bad window
    silently reads/overwrites the wrong elements — the validation must
    happen eagerly at enqueue time, before the op reaches a queue."""
    if offset < 0 or count < 0 or offset + count > size:
        raise ValueError(
            f"{op} window out of range: offset={offset}, count={count} on a "
            f"buffer of {size} element(s) — need 0 <= offset and "
            "offset + count <= size"
        )


class Buffer:
    """Memory allocated on a specific device; handle is location-transparent."""

    def __init__(self):  # use Device.create_buffer*, not this
        self.device = None
        self.shape: tuple = ()
        self.dtype = None
        self._array: "jax.Array | None" = None
        self._donated: bool = False
        # True when _array is a caller-owned jax.Array adopted by reference
        # (zero-copy write): its storage must never be donated in place.
        self._aliased: bool = False
        self._freed: bool = False
        self._free_future: "Future | None" = None
        self.gid: agas.GID = 0
        self._finalizer: "weakref.finalize | None" = None
        # Spill state (DESIGN.md §14): when device storage is evicted the
        # contents live in _spilled_host and the AGAS record moves to
        # HOST_KEY; the next array() refetches transparently.  _last_use is
        # the LRU signal the memory-aware scheduler evicts by.
        self._spilled_host: "np.ndarray | None" = None
        self._spill_lock = threading.Lock()
        self._last_use: float = time.monotonic()

    def _register(self, device) -> None:
        """AGAS registration with resident-bytes accounting and a GC-safe
        finalizer: a buffer collected without an explicit ``free()`` still
        retires its registry record (and its byte count) — registrations
        must not outlive the data they describe."""
        self.device = device
        self.gid = agas.registry.register(
            self,
            agas.Placement(device.key, device.jax_device.process_index),
            kind="buffer",
            nbytes=self.nbytes,
        )
        # Bound args only (gid) — the finalizer must not keep self alive.
        self._finalizer = weakref.finalize(self, agas.registry.unregister, self.gid)

    # -- allocation (runs on the device ops queue) ---------------------------

    @staticmethod
    def _allocate(device, shape, dtype, fill) -> "Buffer":
        b = Buffer()
        b.shape = (shape,) if isinstance(shape, int) else tuple(shape)
        b.dtype = np.dtype(dtype)
        if fill is None:
            arr = jnp.zeros(b.shape, dtype=b.dtype)
        else:
            arr = jnp.full(b.shape, fill, dtype=b.dtype)
        b._array = jax.device_put(arr, device.jax_device)
        b._register(device)
        return b

    @property
    def size(self) -> int:
        return int(np.prod(self.shape)) if self.shape else 1

    @property
    def nbytes(self) -> int:
        return self.size * self.dtype.itemsize

    # -- async transfer surface ----------------------------------------------

    def enqueue_write(self, offset: int, data, count: "int | None" = None,
                      stream=None) -> Future:
        """Asynchronously copy host ``data`` into the buffer at ``offset``
        (elements, flat view). ``cudaMemcpyAsync(HostToDevice)`` analogue.

        ``stream`` scopes the ordering (DESIGN.md §11): the write runs
        FIFO with that stream's other work and concurrently with other
        streams; ``None`` means the device's default stream.  Full-buffer
        writes (offset 0, covering size) take a zero-copy fast path when
        ``data`` already matches shape and dtype.  Inside a
        ``graph.capture()`` region the write is recorded (full-buffer only)
        and a graph node is returned instead of a future.
        """
        from repro.core.graph import current_graph

        data_len = int(np.size(data))
        _check_window(
            self.size, offset, count if count is not None else data_len,
            "enqueue_write",
        )
        if count is not None and count > data_len:
            # The write path copies min(count, len(data)) elements; a count
            # the data cannot cover would silently write a SHORTER window
            # than the one just validated.
            raise ValueError(
                f"enqueue_write count={count} exceeds the {data_len} element(s) "
                "of data supplied"
            )
        g = current_graph()
        if g is not None:
            return g.write(self, data, offset=offset, count=count)

        def _write():
            self._last_use = time.monotonic()
            if offset == 0 and count is None:
                # Fast path: adopt a matching jax.Array outright, or
                # device_put a matching ndarray without flatten/astype.
                if isinstance(data, jax.Array) and data.shape == self.shape and data.dtype == self.dtype:
                    arr = data
                    adopted = True
                    if arr.devices() != {self.device.jax_device}:
                        arr = jax.device_put(arr, self.device.jax_device)
                        adopted = False
                    self._array = arr
                    self._aliased = adopted  # caller still owns this storage
                    self._donated = False
                    self._discard_spill()
                    return None
                src = np.asarray(data)
                if src.shape == self.shape and src.dtype == self.dtype:
                    self._array = jax.device_put(src, self.device.jax_device)
                    self._aliased = False
                    self._donated = False
                    self._discard_spill()
                    return None
            else:
                src = np.asarray(data)
            src = src.reshape(-1)
            if count is not None:
                src = src[:count]
            if offset == 0 and src.size == self.size:
                self._array = jax.device_put(
                    src.reshape(self.shape).astype(self.dtype), self.device.jax_device
                )
                self._discard_spill()
            else:
                staged = jax.device_put(src, self.device.jax_device)
                cur = self.array()
                if self._aliased:
                    # _flat_update donates its destination; never donate
                    # storage a caller still owns — un-alias with a copy.
                    cur = jnp.array(cur)
                self._array = _flat_update(cur, staged, offset, self.shape)
            self._aliased = False
            self._donated = False
            return None

        q = self.device.ops_queue if stream is None else stream._lane_for(self.device)
        return q.submit(_write)

    def enqueue_read(self, offset: int = 0, count: "int | None" = None,
                     stream=None) -> Future:
        """Asynchronously copy device data to the host; future of np.ndarray.
        ``cudaMemcpyAsync(DeviceToHost)`` analogue.

        ``stream`` scopes the ordering exactly as for ``enqueue_write``.
        Inside a ``graph.capture()`` region the read is recorded as a fetch
        node (full-buffer only) and the node handle is returned."""
        from repro.core.graph import current_graph

        n = self.size - offset if count is None else count
        _check_window(self.size, offset, n, "enqueue_read")
        g = current_graph()
        if g is not None:
            return g.read(self, offset=offset, count=count)

        def _read():
            src = self.array()
            if offset == 0 and n == self.size:
                out = src
            else:
                out = _flat_slice(src, offset, n)
            # start D2H without blocking the ops queue on completion
            out.copy_to_host_async()
            return out

        q = self.device.ops_queue if stream is None else stream._lane_for(self.device)
        # resolve to a numpy array; inline continuation (non-blocking fn)
        return q.submit(_read).then(
            lambda a: np.asarray(a), executor="inline", name=f"read:gid{self.gid}"
        )

    def enqueue_read_sync(self, offset: int = 0, count: "int | None" = None, stream=None):
        from repro.core.graph import current_graph

        if current_graph() is not None:
            raise RuntimeError(
                "enqueue_read_sync inside a graph-capture region: the value "
                "does not exist until replay. Use enqueue_read() to record a "
                "fetch node and index the replay's GraphResult with it."
            )
        return self.enqueue_read(offset, count, stream=stream).get()

    def copy_to(self, target_device) -> Future:
        """Move contents to ``target_device``; future of the *new* Buffer.
        Updates AGAS placement — the percolation primitive.

        A remote target turns the move into explicit transfer parcels:
        D2H read here, then a ``create_buffer_from`` parcel on the owning
        locality (future of the new ``RemoteBuffer``).

        Not captured by graph regions: inside ``capture()`` this executes
        eagerly (stage cross-device moves before the capture; captured
        launches read whatever device the buffer is on at replay)."""
        if getattr(target_device, "is_remote_proxy", False):
            from repro.core.executor import get_runtime

            return self.enqueue_read().then(
                lambda host: target_device.create_buffer_from(host).get(),
                executor=get_runtime().pool,
                name=f"copy:gid{self.gid}",
            )

        def _stage():
            return self.array()  # capture current contents in submission order

        def _land(arr):
            nb = Buffer()
            nb.shape, nb.dtype = self.shape, self.dtype
            nb._array = jax.device_put(arr, target_device.jax_device)
            nb._register(target_device)
            return nb

        from repro.core.executor import get_runtime

        staged = self.device.ops_queue.submit(_stage)
        # The continuation submits to (possibly the same) ops queue and
        # waits — run it on the host pool, never inline on a queue worker.
        return staged.then(
            lambda arr: target_device.ops_queue.submit(partial(_land, arr)).get(),
            executor=get_runtime().pool,
            name=f"copy:gid{self.gid}",
        )

    # -- lifetime --------------------------------------------------------------

    def free(self) -> Future:
        """Release device storage and retire the AGAS record (async;
        ``cudaFreeAsync`` analogue — future of None, idempotent).

        The release is gated on a barrier across ALL of the owning
        device's streams, so operations already enqueued on any lane
        (e.g. a launch reading this buffer from a non-default stream)
        complete against live storage first — freeing after submitting a
        launch is safe, exactly as ``cudaFree`` after kernel submission.
        Explicit counterpart of the GC finalizer: the registration and
        its resident-byte contribution go away at release time instead of
        collection time, and subsequently enqueued reads raise.

        Every call returns the SAME future (one release is submitted no
        matter how many threads race), so ``free().get()`` always means
        "the storage is actually released", never just "someone else
        asked first".
        """

        def _release(_=None):
            self._freed = True
            if self._finalizer is not None:
                self._finalizer.detach()
                self._finalizer = None
            agas.registry.unregister(self.gid)
            self._array = None
            self._spilled_host = None
            self._aliased = False

        with _free_lock:
            if self._free_future is None:
                disp = getattr(self.device, "_dispatcher", None)
                if disp is None:  # duck-typed device with a bare queue
                    self._free_future = self.device.ops_queue.submit(_release)
                else:
                    # _release is non-blocking; inline on the barrier is safe.
                    self._free_future = disp.barrier().then(_release, executor="inline")
        return self._free_future

    def _rehome(self, device) -> None:
        """Point the handle at a new owning device (location transparency:
        the GID is unchanged, only the AGAS placement record moves — the
        resident-bytes accounting follows the record's nbytes metadata)."""
        if device is self.device:
            return
        self.device = device
        if self._freed:
            return
        with self._spill_lock:
            if self._spilled_host is not None:
                # Data lives in host RAM, not on either device: the record
                # stays on HOST_KEY and follows the eventual refetch.
                return
        agas.registry.update_placement(
            self.gid, agas.Placement(device.key, device.jax_device.process_index)
        )

    # -- spill / refetch (DESIGN.md §14) --------------------------------------

    def spill(self) -> Future:
        """Evict device storage to a host-RAM copy; future of True when
        storage was actually released (False: nothing to spill — already
        spilled, freed, or donated).

        The AGAS record moves to ``agas.HOST_KEY`` so the device's
        resident-bytes total drops immediately; the next ``array()`` call
        refetches transparently and moves the record back.  Runs on the
        default stream, so same-stream work already enqueued completes
        against live storage first (same gating as ``free``)."""
        return self.device.ops_queue.submit(self._spill_now)

    def _spill_now(self) -> bool:
        with self._spill_lock:
            if self._freed or self._donated or self._array is None or self._spilled_host is not None:
                return False
            self._spilled_host = np.asarray(self._array)
            self._array = None
            self._aliased = False
            agas.registry.update_placement(
                self.gid, agas.Placement(agas.HOST_KEY, self.device.jax_device.process_index)
            )
            return True

    def _refetch(self) -> "jax.Array | None":
        with self._spill_lock:
            if self._spilled_host is None:
                return self._array  # lost the race to another refetcher
            arr = jax.device_put(self._spilled_host, self.device.jax_device)
            self._array = arr
            self._spilled_host = None
            self._aliased = False
            self._donated = False
            if not self._freed:
                agas.registry.update_placement(
                    self.gid, agas.Placement(self.device.key, self.device.jax_device.process_index)
                )
            return arr

    def _discard_spill(self) -> None:
        """Drop the host spill copy after a full overwrite made it dead,
        restoring the placement record to the owning device."""
        if self._spilled_host is None:
            return
        with self._spill_lock:
            if self._spilled_host is None:
                return
            self._spilled_host = None
            if not self._freed:
                agas.registry.update_placement(
                    self.gid, agas.Placement(self.device.key, self.device.jax_device.process_index)
                )

    # -- kernel-facing view ---------------------------------------------------

    def array(self) -> "jax.Array":
        """Current device-resident value (async; usable as a kernel arg).

        A spilled buffer is refetched from its host copy transparently
        (and its AGAS record moves back to the device).  Raises if the
        buffer was freed, or if its storage was donated to a fused graph
        executable (graph.replay with donation) and not rewritten since.
        """
        if self._freed:
            raise RuntimeError(f"Buffer gid={self.gid} was freed; its storage is released.")
        self._last_use = time.monotonic()
        arr = self._array
        if arr is None:
            if self._spilled_host is not None:
                arr = self._refetch()
                if arr is not None:
                    return arr
            if self._donated:
                raise RuntimeError(
                    f"Buffer gid={self.gid} was donated to a fused graph replay; "
                    "its contents are gone (XLA reused the memory). Write to it "
                    "before reading again."
                )
        return arr

    def _set_array(self, arr: "jax.Array", aliased: bool = False) -> None:
        self._array = arr
        self._aliased = aliased
        self._donated = False
        self._last_use = time.monotonic()
        self._discard_spill()

    def _invalidate(self) -> None:
        """Mark storage as consumed by a donating executable (graph replay)."""
        self._discard_spill()  # a stale host copy must not resurrect donated storage
        self._array = None
        self._donated = True

    def __repr__(self) -> str:
        return f"Buffer(gid={self.gid}, {self.dtype}{list(self.shape)} @ {self.device.key})"
