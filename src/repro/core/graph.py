"""Task-graph capture & fused replay — the CUDA Graphs analogue (DESIGN.md §8).

The futurization layer (paper §3.1) pays a small constant cost per
operation: a ``Future``, a queue hop, and (for chains) a ``when_all``
fan-in.  The paper's §5 claim is that this cost is negligible *per launch*;
this module drives the *per-graph* cost toward zero the same way CUDA
Graphs, StarPU bundles and Specx task collectives do — record the DAG once,
then replay it with amortized scheduling:

  * ``capture()`` (stream-capture style) or an explicit ``TaskGraph``
    builder records ``Buffer`` transfers and ``Program.run`` launches as a
    symbolic SSA DAG — nothing executes during capture.
  * ``instantiate()`` fuses every maximal run of same-device kernel
    launches into **one** ``jax.jit``-compiled executable.  Intermediate
    values that never escape a fused segment are elided entirely; segment
    inputs that die inside the segment are *donated* so XLA reuses their
    memory.  The replay route (which ops queue) is resolved once, here.
  * ``replay()`` then executes the whole graph with a **single** ops-queue
    hop and a **single** ``Future`` — N launches for the price of one.

Multi-device graphs (DESIGN.md §9): a capture whose launches span devices
(e.g. recorded through ``Program.run_on_any``) is planned as one fused
segment per maximal same-device run, with every cross-device SSA edge
resolved at instantiate into an explicit *transfer step* (the percolation
analogue, frozen into the plan).  At replay the segments are dispatched to
their **own** ops queues as soon as their producer segments finish —
independent segments overlap — and the whole graph still joins through
**one** future.  Single-device single-chain graphs keep the one-hop fast
path.

Stream assignment (DESIGN.md §11): at ``instantiate()`` every launch is
assigned to an SSA *chain* — a launch continues the chain of its first
same-device producer, a launch with no same-device producer starts a new
one — and each chain maps to its own stream lane on its device
(``Device._replay_lane``).  Independent chains therefore replay
concurrently (transfers overlap compute), while same-chain work keeps
capture order on one lane.  Where chains join, the cross-chain SSA edge
becomes an *event edge* (``GraphExec._event_edges``): the consuming
segment parks on the producer segment's future — exactly an ``Event``
recorded at the producer's tail and waited on by the consumer's stream.

Ordering guarantees: same-chain segments replay FIFO on one lane in
capture order; cross-chain and cross-device edges synchronize only
through event edges (the per-sym futures); the whole replay joins through
ONE future, and a buffer's committed state is whatever the LAST
capture-ordered node left it (SSA makes this deterministic regardless of
lane interleaving).

Correspondence: capture <-> ``cudaStreamBeginCapture``; ``GraphExec`` <->
``cudaGraphExec_t``; ``replay`` <-> ``cudaGraphLaunch``; feed overrides at
replay <-> ``cudaGraphExecKernelNodeSetParams``; chain -> stream lane <->
``cudaGraph`` node-to-stream assignment.  It is equally the paper's
Listing 2 execution graph, frozen and re-launched (PAPER §4).

Ownership rule (CUDA Graphs'): a buffer overwritten inside the graph whose
final value is consumed by a later in-graph launch is *graph-internal* —
after ``replay()`` it is invalidated (its storage may have been donated)
and reads raise until it is written again.  Buffers read from outside the
graph (extern inputs) are never donated, so a ``GraphExec`` can be
replayed any number of times.
"""
from __future__ import annotations

import os
import threading
import time
from contextlib import contextmanager
from typing import Any, Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.buffer import Buffer
from repro.core.futures import Future

__all__ = ["TaskGraph", "GraphExec", "GraphResult", "capture", "current_graph"]

_tls = threading.local()


def current_graph() -> "TaskGraph | None":
    """The graph currently recording on this thread (or None)."""
    return getattr(_tls, "graph", None)


@contextmanager
def capture(name: str = "captured"):
    """Record all ``Program.run`` / ``Buffer.enqueue_write`` /
    ``Buffer.enqueue_read`` calls on this thread into a ``TaskGraph``
    (``cudaStreamBeginCapture`` analogue).  Nothing executes until
    ``instantiate().replay()``."""
    g = TaskGraph(name)
    prev = current_graph()
    _tls.graph = g
    try:
        yield g
    finally:
        _tls.graph = prev


# ---------------------------------------------------------------------------
# symbolic nodes (returned as handles from capture-mode calls)
# ---------------------------------------------------------------------------


class _SymRef:
    """Reference to an SSA value inside the graph."""

    __slots__ = ("sym",)

    def __init__(self, sym: int):
        self.sym = sym


class WriteNode:
    """Recorded full-buffer H2D write; handle usable as a replay-feed key."""

    __slots__ = ("buf", "data", "sym")

    def __init__(self, buf: Buffer, data, sym: int):
        self.buf, self.data, self.sym = buf, data, sym


class LaunchNode:
    """Recorded kernel launch."""

    __slots__ = ("program", "kernel", "arg_refs", "out_bufs", "res_syms", "bound", "device",
                 "grid", "block")

    def __init__(self, program, kernel, arg_refs, out_bufs, res_syms, bound, device,
                 grid=None, block=None):
        self.program = program
        self.kernel = kernel
        self.arg_refs = arg_refs  # list of _SymRef | constant
        self.out_bufs = out_bufs  # list[Buffer] | None
        self.res_syms = res_syms  # list[int], one per kernel result
        self.bound = bound  # geometry-bound callable
        self.device = device
        # Raw geometry, kept for remote-segment plans (a parcel refers to
        # the kernel by NAME and re-binds geometry on the owning locality;
        # the local ``bound`` closure never crosses the wire).
        self.grid = grid
        self.block = block


class ReadNode:
    """Recorded full-buffer D2H read; handle indexes the GraphResult."""

    __slots__ = ("buf", "sym")

    def __init__(self, buf: Buffer, sym: int):
        self.buf, self.sym = buf, sym


class GraphResult:
    """Value of a completed replay: fetched reads (np.ndarray) and
    out-less launch results (raw arrays), indexed by their capture handle."""

    def __init__(self, fetches: dict, reads: list):
        self._fetches = fetches
        self.reads = reads  # read values in capture order

    def __getitem__(self, node):
        return self._fetches[node]

    def __repr__(self) -> str:
        return f"GraphResult({len(self._fetches)} fetches)"


# ---------------------------------------------------------------------------
# the graph builder
# ---------------------------------------------------------------------------


class TaskGraph:
    """Symbolic DAG of transfers and launches (build explicitly or via
    ``capture()``); compile with ``instantiate()``."""

    def __init__(self, name: str = "graph"):
        self.name = name
        self._nodes: list = []
        self._next_sym = 0
        self._cur: "dict[int, int]" = {}  # id(buffer) -> current sym
        self._buffers: "dict[int, Buffer]" = {}  # id(buffer) -> buffer (keepalive)
        self._sym_spec: "dict[int, jax.ShapeDtypeStruct]" = {}
        self._extern: "dict[int, Buffer]" = {}  # sym -> source buffer
        self._frozen = False

    # -- recording surface -------------------------------------------------

    def _new_sym(self, shape, dtype) -> int:
        s = self._next_sym
        self._next_sym += 1
        self._sym_spec[s] = jax.ShapeDtypeStruct(tuple(shape), dtype)
        return s

    def _check_mutable(self) -> None:
        if self._frozen:
            raise RuntimeError(f"TaskGraph '{self.name}' is frozen (already instantiated)")

    def _sym_of(self, buf: Buffer) -> _SymRef:
        """Current SSA value of a buffer; first touch binds an extern input
        (read live from the buffer at every replay)."""
        s = self._cur.get(id(buf))
        if s is None:
            s = self._new_sym(buf.shape, buf.dtype)
            self._cur[id(buf)] = s
            self._buffers[id(buf)] = buf
            self._extern[s] = buf
        return _SymRef(s)

    def write(self, buf: Buffer, data=None, offset: int = 0, count: "int | None" = None) -> WriteNode:
        """Record a full-buffer H2D write.  ``data`` is the default payload;
        override per replay with ``replay(feeds={node_or_buffer: new_data})``."""
        self._check_mutable()
        if getattr(buf, "is_remote_buffer", False):
            raise NotImplementedError(
                "graph capture writes to local buffers only; stage remote "
                "transfers outside the capture region"
            )
        if offset != 0 or (count is not None and count != buf.size):
            raise NotImplementedError(
                "graph capture supports full-buffer writes only (offset=0); "
                "stage partial updates outside the capture region"
            )
        sym = self._new_sym(buf.shape, buf.dtype)
        self._cur[id(buf)] = sym
        self._buffers[id(buf)] = buf
        node = WriteNode(buf, data, sym)
        self._nodes.append(node)
        return node

    def run(
        self,
        program,
        args: "Sequence[Buffer | Any]",
        name: str,
        grid=None,
        block=None,
        out: "Sequence[Buffer] | None" = None,
    ) -> LaunchNode:
        """Record a kernel launch (``Program.run`` analogue).  Non-buffer
        arguments are captured as constants and baked into the fused
        executable."""
        self._check_mutable()
        if name not in program._kernels:
            raise KeyError(f"no kernel '{name}' in {program.name}")
        if out is not None and any(getattr(b, "is_remote_buffer", False) for b in out):
            raise NotImplementedError(
                "captured graphs write results to local buffers only; a "
                "remote launch with local out buffers ships the values back "
                "at replay (remote buffers may still be read as extern inputs)"
            )
        bound = program._bind(name, grid, block)
        arg_refs: list = []
        shape_args: list = []
        for a in args:
            if isinstance(a, Buffer):
                ref = self._sym_of(a)
                arg_refs.append(ref)
                shape_args.append(self._sym_spec[ref.sym])
            else:
                arg_refs.append(a)
                shape_args.append(a)
        res_shapes = jax.eval_shape(bound, *shape_args)
        res_list = list(res_shapes) if isinstance(res_shapes, (tuple, list)) else [res_shapes]
        if out is not None and len(res_list) != len(out):
            raise ValueError(
                f"kernel '{name}' returns {len(res_list)} arrays for {len(out)} out buffers"
            )
        res_syms = [self._new_sym(r.shape, r.dtype) for r in res_list]
        if out is not None:
            for b, s in zip(out, res_syms):
                self._cur[id(b)] = s
                self._buffers[id(b)] = b
        node = LaunchNode(program, name, arg_refs, list(out) if out is not None else None,
                          res_syms, bound, program.device, grid=grid, block=block)
        self._nodes.append(node)
        return node

    def read(self, buf: Buffer, offset: int = 0, count: "int | None" = None) -> ReadNode:
        """Record a full-buffer D2H fetch; the handle indexes the replay's
        ``GraphResult`` (value is an ``np.ndarray``, as in eager reads)."""
        self._check_mutable()
        if offset != 0 or (count is not None and count != buf.size):
            raise NotImplementedError(
                "graph capture supports full-buffer reads only (offset=0)"
            )
        node = ReadNode(buf, self._sym_of(buf).sym)
        self._nodes.append(node)
        return node

    # -- instantiate: fuse + compile + pre-resolve the route ----------------

    def instantiate(self, donate: bool = True) -> "GraphExec":
        """Fuse, compile and freeze the graph into a replayable executable
        (``cudaGraphInstantiate`` analogue).  ``donate=False`` disables
        buffer donation (debugging aid: write-fed buffers then keep their
        payload after replay; values fused away inside a segment still
        invalidate their buffers)."""
        self._check_mutable()
        self._frozen = True
        return GraphExec(self, donate=donate)


# ---------------------------------------------------------------------------
# instantiated executable
# ---------------------------------------------------------------------------


class _Segment:
    __slots__ = ("device", "nodes", "chain", "queue", "in_syms", "out_syms", "compiled",
                 "donated_ixs", "transfer_ixs", "exec_mode")

    def __init__(self, device, nodes, chain: int = 0):
        self.device = device
        self.nodes = nodes
        self.chain = chain  # SSA chain id on this device -> stream lane
        self.queue = None  # lane resolved at instantiate (_replay_lane)
        self.in_syms: "list[int]" = []
        self.out_syms: "list[int]" = []
        self.compiled = None
        self.donated_ixs: "tuple[int, ...]" = ()
        self.transfer_ixs: "tuple[int, ...]" = ()  # input slots fed cross-device
        self.exec_mode = "fused"  # fused | staged | remote (calibrated at bind)


class _FastPlan:
    """Flat pre-bound replay record for single-segment local graphs: the
    lane, executable, staging order, commit decisions and fetch layout the
    generic path derives per replay, resolved once at instantiate."""

    __slots__ = ("exe", "in_syms", "out_syms", "externs", "extern_bufs", "writes",
                 "commit_sets", "commit_invs", "keep_externs", "fetch_plan", "jd")

    def __init__(self, *, exe, in_syms, out_syms, externs, extern_bufs, writes,
                 commit_sets, commit_invs, keep_externs, fetch_plan, jd):
        self.exe = exe
        self.in_syms = in_syms
        self.out_syms = out_syms
        self.externs = externs  # ((sym, Buffer), ...)
        self.extern_bufs = extern_bufs  # eligibility re-check in replay()
        self.writes = writes
        self.commit_sets = commit_sets  # ((Buffer, sym, planned producer), ...)
        self.commit_invs = commit_invs
        self.keep_externs = keep_externs
        self.fetch_plan = fetch_plan
        self.jd = jd


class GraphExec:
    """A frozen, fused, route-resolved task graph (``cudaGraphExec_t``)."""

    def __init__(self, graph: TaskGraph, donate: bool = True):
        self.graph = graph
        self._donate = donate
        self._writes: "list[WriteNode]" = [n for n in graph._nodes if isinstance(n, WriteNode)]
        self._reads: "list[ReadNode]" = [n for n in graph._nodes if isinstance(n, ReadNode)]
        self._build_plan()
        self._compile_segments()
        # Pre-resolved route: one ops-queue hop for the whole replay.
        route_dev = self._segments[0].device if self._segments else None
        if route_dev is None:
            for b in graph._buffers.values():
                route_dev = b.device
                break
        if route_dev is None:
            raise ValueError(f"TaskGraph '{graph.name}' is empty")
        self._route_dev = route_dev
        self._queue = route_dev.ops_queue
        # The single-hop path serializes replays through its queue; the
        # fan-out join/commit runs off-queue, so back-to-back replays of
        # the same exec must serialize explicitly (buffer commits would
        # otherwise race between iterations).  A plain Lock: acquired by
        # the replaying thread, released by whichever thread commits.
        # The single-hop path holds it only while submitting, and chains
        # its foreign-extern pre-reads behind _last_replay instead.
        self._replay_lock = threading.Lock()
        self._last_replay: "Future | None" = None
        self._last_replay_queue = self._queue  # lane of the previous replay
        # Placement spans segments AND extern inputs: a graph whose input
        # buffer lives on another device needs the replay-time device_put
        # guard even when all launches share one device.
        placements = {s.device.jax_device for s in self._segments}
        placements.update(b.device.jax_device for b in graph._extern.values())
        placements.update(n.buf.device.jax_device for n in self._writes)
        self._multi_device = len(placements) > 1
        # Pre-bound replay record (ISSUE: close the dispatch tax at bind
        # time).  For the common shape — one local segment, one device,
        # default lane — everything replay() decides per call is decided
        # HERE once, into flat tuples a single lane task walks.
        self._fast = self._build_fast_plan()
        # NOTE: extern buffers may have pending eager ops on their own
        # queues, and those queues can CHANGE between replays (percolation
        # re-homes handles) — so both replay paths read each extern ON its
        # owning queue, with the read submitted at replay() call time,
        # BEFORE anything that waits on it.  Queue tasks only ever park on
        # earlier-submitted work (or pool/compile work that never waits on
        # queues), which rules out cross-replay deadlock by induction on
        # submission order: the earliest uncompleted queue task is always
        # at its queue's head with all its dependencies already complete.

    # -- planning ----------------------------------------------------------

    def _build_plan(self) -> None:
        g = self.graph
        nodes = g._nodes

        # Stream assignment (DESIGN.md §11): every launch joins an SSA
        # *chain* — the chain of its first same-device producer, or a new
        # chain when it has none (an independent head).  Chains map 1:1 to
        # stream lanes at replay, so independent chains overlap.
        producer_launch: "dict[int, LaunchNode]" = {}  # sym -> producing launch
        chain_counters: "dict[str, int]" = {}  # device.key -> next chain id
        chain_of: "dict[int, int]" = {}  # id(LaunchNode) -> chain
        for n in nodes:
            if not isinstance(n, LaunchNode):
                continue
            chain = None
            for a in n.arg_refs:
                if isinstance(a, _SymRef):
                    p = producer_launch.get(a.sym)
                    if p is not None and p.device.key == n.device.key:
                        chain = chain_of[id(p)]
                        break
            if chain is None:
                chain = chain_counters.get(n.device.key, 0)
                chain_counters[n.device.key] = chain + 1
            chain_of[id(n)] = chain
            for s in n.res_syms:
                producer_launch[s] = n

        # Segment = maximal run of launches on one (device, chain) — i.e.
        # on one stream (writes/reads are replay-time host ops and do not
        # break fusion; SSA ordering keeps them correct regardless of
        # where they sit between launches).
        self._segments: "list[_Segment]" = []
        for n in nodes:
            if not isinstance(n, LaunchNode):
                continue
            last = self._segments[-1] if self._segments else None
            if last is not None and last.device is n.device and last.chain == chain_of[id(n)]:
                last.nodes.append(n)
            else:
                self._segments.append(_Segment(n.device, [n], chain=chain_of[id(n)]))

        # Liveness: which segment consumes each sym, and what must survive.
        launch_use_segs: "dict[int, list[int]]" = {}
        produced_in_seg: "dict[int, int]" = {}
        for si, seg in enumerate(self._segments):
            for n in seg.nodes:
                for a in n.arg_refs:
                    if isinstance(a, _SymRef):
                        launch_use_segs.setdefault(a.sym, []).append(si)
                for s in n.res_syms:
                    produced_in_seg[s] = si

        fetched: "set[int]" = {r.sym for r in self._reads}
        for n in nodes:
            if isinstance(n, LaunchNode) and n.out_bufs is None:
                fetched.update(n.res_syms)  # out-less launch: results fetched

        final_sym: "dict[int, int]" = {}  # id(buffer) -> final sym
        for bid, s in g._cur.items():
            final_sym[bid] = s
        # Keep set: fetched values + terminal buffer values (final value
        # with no in-graph launch consumer).  A buffer whose final value IS
        # consumed in-graph is graph-internal: fused away / donated.
        keep: "set[int]" = set(fetched)
        for bid, s in final_sym.items():
            if not launch_use_segs.get(s):
                keep.add(s)
        self._keep = keep
        self._final_sym = final_sym

        # Fan-out replay when the plan has more than one segment — launches
        # spanning devices (DESIGN.md §9) OR independent chains on one
        # device (§11): each segment runs on its own stream lane, joined
        # through one future.  Fan-out plans execute data-dependency
        # ordered, not capture-ordered: two segments that both consume a
        # sym may run CONCURRENTLY, so "last consumer donates" is only
        # safe when a sym's consumers all sit in one segment.
        self._fanout = len(self._segments) > 1

        # Per-segment interface: inputs (consumed, produced earlier) and
        # outputs (produced here, needed later or kept).
        for si, seg in enumerate(self._segments):
            in_syms: "list[int]" = []
            seen = set()
            local_produced = set()
            for n in seg.nodes:
                for a in n.arg_refs:
                    if isinstance(a, _SymRef) and a.sym not in local_produced and a.sym not in seen:
                        seen.add(a.sym)
                        in_syms.append(a.sym)
                local_produced.update(n.res_syms)
            out_syms = [
                s for n in seg.nodes for s in n.res_syms
                if s in keep or any(u > si for u in launch_use_segs.get(s, ()))
            ]
            seg.in_syms = in_syms
            seg.out_syms = out_syms
            # Remote segments never donate: their inputs are shipped in a
            # parcel, not handed to a local donating executable.
            if self._donate and not getattr(seg.device, "is_remote_proxy", False):
                donated = []
                for pos, s in enumerate(in_syms):
                    if s in g._extern:
                        continue  # replay re-reads extern buffers: never donate
                    if not g._sym_spec[s].shape:
                        continue  # XLA cannot alias 0-d inputs (warns, no-op)
                    if s in keep:
                        continue
                    if any(u > si for u in launch_use_segs.get(s, ())):
                        continue
                    if self._fanout and set(launch_use_segs.get(s, ())) != {si}:
                        continue  # a concurrent sibling segment also reads it
                    donated.append(pos)
                seg.donated_ixs = tuple(donated)

        self._donated_syms = {
            seg.in_syms[pos] for seg in self._segments for pos in seg.donated_ixs
        }

        # Cross-device edges -> explicit transfer steps (frozen percolation).
        # prod_dev maps each sym to the device its value materializes on:
        # externs/writes on their buffer's device, launch results on their
        # segment's device.  A segment input produced elsewhere gets a
        # transfer slot, executed on the consuming segment's queue at
        # replay (device_put at segment head).  _prod_dev also drives the
        # commit-time re-home of out buffers written on a foreign device.
        prod_dev: "dict[int, Any]" = {}
        for s, buf in g._extern.items():
            prod_dev[s] = buf.device
        for n in nodes:
            if isinstance(n, WriteNode):
                prod_dev[n.sym] = n.buf.device
        for seg in self._segments:
            for n in seg.nodes:
                for s in n.res_syms:
                    prod_dev[s] = seg.device
        self._prod_dev = prod_dev
        self._transfers: "list[tuple[int, str, str]]" = []  # (sym, src, dst)
        for seg in self._segments:
            slots = []
            for pos, s in enumerate(seg.in_syms):
                src = prod_dev.get(s)
                if src is not None and src.key != seg.device.key:
                    slots.append(pos)
                    self._transfers.append((s, src.key, seg.device.key))
            seg.transfer_ixs = tuple(slots)

        # Stream lanes + event edges (DESIGN.md §11).  Each segment's
        # replay lane is its chain's stream on its device, resolved once
        # here.  A sym produced by one segment and consumed by a segment
        # on a DIFFERENT lane is an *event edge* — record at the
        # producer's tail, wait by the consumer's stream.  At replay the
        # edge is realized by the per-sym futures (the consumer's lane
        # task parks on the producer segment's future); _event_edges is
        # the introspectable plan of those crossings (tests, __repr__),
        # not a separate synchronization mechanism.
        sym_seg: "dict[int, int]" = {}
        for si, seg in enumerate(self._segments):
            seg.queue = seg.device._replay_lane(seg.chain)
            for n in seg.nodes:
                for s in n.res_syms:
                    sym_seg[s] = si
        self._event_edges: "list[tuple[int, int, int]]" = []  # (producer, consumer, sym)
        for si, seg in enumerate(self._segments):
            for s in seg.in_syms:
                pi = sym_seg.get(s)
                if pi is not None and pi != si and self._segments[pi].queue is not seg.queue:
                    self._event_edges.append((pi, si, s))

    def _compile_segments(self) -> None:
        g = self.graph
        mode_env = os.environ.get("REPRO_SEGMENT_COMPILE", "auto").lower()
        for seg in self._segments:
            if getattr(seg.device, "is_remote_proxy", False):
                # A segment living on a remote locality replays as ONE
                # run_segment parcel: kernel-name plan + input arrays out,
                # output arrays back (DESIGN.md §10).  No local jit.
                seg.compiled = _remote_segment_executor(seg)
                seg.exec_mode = "remote"
                continue
            nodes, in_syms, out_syms = seg.nodes, tuple(seg.in_syms), tuple(seg.out_syms)

            def make_fused(nodes=nodes, in_syms=in_syms, out_syms=out_syms):
                def fused(*xs):
                    env = dict(zip(in_syms, xs))
                    for n in nodes:
                        vals = [env[a.sym] if isinstance(a, _SymRef) else a for a in n.arg_refs]
                        res = n.bound(*vals)
                        rl = list(res) if isinstance(res, (tuple, list)) else [res]
                        for s, v in zip(n.res_syms, rl):
                            env[s] = v
                    return tuple(env[s] for s in out_syms)

                return fused

            # Pin input shardings to the segment's device so replay on a
            # non-default device doesn't trip compiled-sharding checks.
            from repro.core.program import pin_specs

            specs = pin_specs([g._sym_spec[s] for s in in_syms], seg.device.jax_device)
            jitted = jax.jit(make_fused(), donate_argnums=seg.donated_ixs)
            seg.compiled = jitted.lower(*specs).compile()
            seg.exec_mode = "fused"
            # Bind-time calibration (StarPU performance-model style): a
            # whole-segment XLA module is not always the fastest executor —
            # on compute-bound transcendental chains the fused module can
            # LOSE to the per-node staged pipeline eager launches use
            # (fusion trades scheduling overhead for a different codegen,
            # and the trade goes either way).  Since instantiate is the
            # bind step, measure both ONCE here and freeze the winner;
            # replay cost is then whichever executor actually wins on this
            # backend.  REPRO_SEGMENT_COMPILE=fused|staged skips the
            # trials and forces one side (auto = measure).
            if len(nodes) < 2 or mode_env == "fused":
                continue
            staged = self._compile_staged(seg)
            if staged is None:
                continue
            if mode_env == "staged":
                seg.compiled = staged
                seg.exec_mode = "staged"
                continue
            winner, mode = _calibrate_executors(seg, g, seg.compiled, staged)
            seg.compiled = winner
            seg.exec_mode = mode

    def _compile_staged(self, seg: "_Segment"):
        """Per-node staged pipeline for one segment: each launch compiled
        alone (constants baked, SSA inputs as arguments), chained through a
        plain dict env — the executor shape of three eager ``Program.run``
        calls, minus their queue hops and futures.  Returns ``None`` when
        any node resists compilation (the fused module then stands)."""
        from repro.core.program import pin_specs

        g = self.graph
        jd = seg.device.jax_device
        # Donation mirrors the fused module's plan: a sym dies at its LAST
        # consuming node when it is either a donatable segment input (the
        # positions ``donated_ixs`` already vetted: not extern, not kept,
        # no later use) or a segment-internal intermediate that is not an
        # out_sym — XLA then reuses its storage in place, the same win
        # whole-segment compilation gets for free.
        donatable = {seg.in_syms[pos] for pos in seg.donated_ixs}
        produced: "set[int]" = set()
        last_use: "dict[int, int]" = {}
        for k, n in enumerate(seg.nodes):
            for a in n.arg_refs:
                if isinstance(a, _SymRef):
                    last_use[a.sym] = k
            produced.update(n.res_syms)
        dead_after = set(seg.out_syms) | self._keep
        for s in produced:
            if (self._donate and s in last_use and s not in dead_after
                    and g._sym_spec[s].shape):
                donatable.add(s)

        runners = []
        for k, n in enumerate(seg.nodes):
            sym_ix = tuple(i for i, a in enumerate(n.arg_refs) if isinstance(a, _SymRef))
            specs = pin_specs([g._sym_spec[n.arg_refs[i].sym] for i in sym_ix], jd)
            node_syms = [n.arg_refs[i].sym for i in sym_ix]
            donate_ix = tuple(
                j for j, s in enumerate(node_syms)
                if s in donatable and last_use[s] == k and node_syms.count(s) == 1
            )

            def make_node(n=n, sym_ix=sym_ix):
                refs = list(n.arg_refs)

                def node_fn(*sym_vals):
                    vals = list(refs)
                    for i, v in zip(sym_ix, sym_vals):
                        vals[i] = v
                    res = n.bound(*vals)
                    return res

                return node_fn

            try:
                compiled = jax.jit(
                    make_node(), donate_argnums=donate_ix
                ).lower(*specs).compile()
            except Exception:  # noqa: BLE001 — any uncompilable node: keep fused
                return None
            runners.append((n, sym_ix, compiled))
        in_syms, out_syms = tuple(seg.in_syms), tuple(seg.out_syms)

        def staged(*xs):
            env = dict(zip(in_syms, xs))
            for n, sym_ix, compiled in runners:
                res = compiled(*[env[n.arg_refs[i].sym] for i in sym_ix])
                rl = list(res) if isinstance(res, (tuple, list)) else [res]
                for s, v in zip(n.res_syms, rl):
                    env[s] = v
            return tuple(env[s] for s in out_syms)

        return staged

    # -- pre-bound fast path ------------------------------------------------

    def _build_fast_plan(self) -> "_FastPlan | None":
        """Freeze the single-hop replay into a ``_FastPlan`` when the graph
        qualifies: exactly one LOCAL segment on its device's default lane,
        single-device placement, no remote extern inputs.  Everything the
        generic path re-derives per replay — staging order, commit
        decisions (set/invalidate/keep per buffer), fetch layout — becomes
        flat tuples; ``_replay_fast`` then walks them in one lane task.
        Per-replay eligibility (externs still homed on the route queue,
        no stream override) is re-checked cheaply in ``replay()``."""
        g = self.graph
        if self._fanout or len(self._segments) != 1 or self._multi_device:
            return None
        seg = self._segments[0]
        if getattr(seg.device, "is_remote_proxy", False) or seg.queue is not self._queue:
            return None
        if any(getattr(b, "is_remote_buffer", False) for b in g._extern.values()):
            return None
        jd = seg.device.jax_device
        # Static env membership: externs + writes are always staged, the
        # segment adds its out_syms.  Anything else was fused away.
        env_syms = set(g._extern) | {n.sym for n in self._writes} | set(seg.out_syms)
        commit_sets: list = []   # (buffer, sym, planned producer device)
        commit_invs: list = []   # buffers whose final value did not survive
        keep_externs: list = []  # extern syms kept live for block_until_ready
        for bid, s in self._final_sym.items():
            buf = g._buffers[bid]
            if s in g._extern:
                if s in self._keep:
                    keep_externs.append(s)
                continue
            if s in env_syms and s not in self._donated_syms:
                commit_sets.append((buf, s, self._prod_dev.get(s)))
            else:
                commit_invs.append(buf)
        fetch_plan: list = []  # ("read", node, sym) | ("launch", node, res_syms)
        for n in g._nodes:
            if isinstance(n, ReadNode):
                fetch_plan.append(("read", n, n.sym))
            elif isinstance(n, LaunchNode) and n.out_bufs is None:
                fetch_plan.append(("launch", n, tuple(n.res_syms)))
        return _FastPlan(
            exe=seg.compiled,
            in_syms=tuple(seg.in_syms),
            out_syms=tuple(seg.out_syms),
            externs=tuple(g._extern.items()),
            extern_bufs=tuple(g._extern.values()),
            writes=tuple(self._writes),
            commit_sets=tuple(commit_sets),
            commit_invs=tuple(commit_invs),
            keep_externs=tuple(keep_externs),
            fetch_plan=tuple(fetch_plan),
            jd=jd,
        )

    def _replay_fast(self, feeds, block: bool, gate: "Future | None") -> GraphResult:
        """One pre-bound lane task: stage -> execute -> commit, all driven
        by the flat ``_FastPlan`` tuples (no per-replay plan derivation)."""
        if gate is not None:
            gate.wait()  # prior replay went down a different lane
        p = self._fast
        jd = p.jd
        env: "dict[int, Any]" = {}
        for s, buf in p.externs:
            arr = buf.array()
            env[s] = arr if arr.devices() == {jd} else jax.device_put(arr, jd)
        adopted: "set[int]" = set()
        for n in p.writes:
            env[n.sym], was_adopted = self._stage_write(n, feeds)
            if was_adopted:
                adopted.add(n.sym)
        outs = p.exe(*[env[s] for s in p.in_syms])
        for s, v in zip(p.out_syms, outs):
            env[s] = v
        live_vals = [env[s] for s in p.keep_externs]
        for buf, s, prod in p.commit_sets:
            buf._set_array(env[s], aliased=s in adopted)
            if prod is not None and prod is not buf.device:
                buf._rehome(prod)
            live_vals.append(env[s])
        for buf in p.commit_invs:
            buf._invalidate()
        fetches: dict = {}
        reads: list = []
        for kind, node, syms in p.fetch_plan:
            if kind == "read":
                val = np.asarray(env[syms])
                fetches[node] = val
                reads.append(val)
            else:
                vals = [env[s] for s in syms]
                fetches[node] = vals[0] if len(vals) == 1 else vals
                live_vals.extend(vals)
        if block and live_vals:
            jax.block_until_ready(live_vals)
        return GraphResult(fetches, reads)

    # -- replay ------------------------------------------------------------

    def _stage_write(self, n: WriteNode, feeds) -> "tuple[Any, bool]":
        """Resolve one write node's payload -> (device array on the planned
        device, adopted-by-reference?).  Shared by both replay paths so
        feeds/donation semantics cannot diverge."""
        data = n.data
        if feeds is not None:
            data = feeds.get(n, feeds.get(n.buf, data))
        if data is None:
            raise ValueError(
                f"write node for buffer gid={n.buf.gid} has no payload: "
                "record one at capture or pass feeds={node: data}"
            )
        arr = _prepare(n.buf, data, self._prod_dev[n.sym].jax_device)
        if arr is not data:
            return arr, False
        if n.sym in self._donated_syms:
            # The payload was adopted by reference and this replay will
            # donate its storage into a fused executable — copy so the
            # caller's array (and the recorded default) survives for the
            # next replay.
            return jnp.array(arr), False
        return arr, True  # caller-owned storage, by ref

    def _stage_env(self, feeds, pre: "dict[int, Future] | None" = None) -> "tuple[dict[int, Any], set[int]]":
        """Bind extern inputs and (fed) write payloads to their syms.

        Values are normalized onto the device the *plan* recorded for them
        (``_prod_dev``): segment executables are device-pinned and the
        transfer plan is frozen at instantiate, but ``Buffer.device`` can
        move between replays (percolation re-homes handles) — a moved
        extern must be brought back to its planned home, not fed as-is.
        ``pre`` carries futures of externs already being read on their
        owning queues (foreign buffers); the rest are read directly."""
        g = self.graph
        env: "dict[int, Any]" = {}
        adopted: "set[int]" = set()
        for s, buf in g._extern.items():
            if pre is not None and s in pre:
                env[s] = pre[s].get()  # earlier-submitted: safe to park on
            else:
                env[s] = _extern_read(buf, self._prod_dev[s].jax_device)()
        for n in self._writes:
            env[n.sym], was_adopted = self._stage_write(n, feeds)
            if was_adopted:
                adopted.add(n.sym)
        return env, adopted

    def _commit(self, env: "dict[int, Any]", adopted: "set[int]", block: bool) -> GraphResult:
        """Commit buffer states (CUDA Graphs ownership rule): a buffer
        keeps its final value when that value survived replay (it was
        materialized and not donated into a fused executable); otherwise
        its storage is gone and reads must fail.  A buffer whose final
        value materialized on another device is re-homed to it."""
        g = self.graph
        live_vals = []
        for bid, s in self._final_sym.items():
            buf = g._buffers[bid]
            if s in g._extern:
                if s in self._keep:
                    live_vals.append(env[s])
                continue
            if s in env and s not in self._donated_syms:
                buf._set_array(env[s], aliased=s in adopted)
                prod = self._prod_dev.get(s)
                # A remote producer's value was shipped BACK by the reply
                # parcel — the buffer's data is local, so it stays home.
                if (prod is not None and prod is not buf.device
                        and not getattr(prod, "is_remote_proxy", False)):
                    buf._rehome(prod)
                live_vals.append(env[s])
            else:
                buf._invalidate()

        fetches: dict = {}
        reads: list = []
        for n in g._nodes:
            if isinstance(n, ReadNode):
                val = np.asarray(env[n.sym])
                fetches[n] = val
                reads.append(val)
            elif isinstance(n, LaunchNode) and n.out_bufs is None:
                vals = [env[s] for s in n.res_syms]
                fetches[n] = vals[0] if len(vals) == 1 else vals
                live_vals.extend(vals)
        if block and live_vals:
            jax.block_until_ready(live_vals)
        return GraphResult(fetches, reads)

    def replay(self, feeds: "dict | None" = None, sync: str = "ready",
               stream=None) -> "Future[GraphResult]":
        """Execute the whole graph and resolve **one** ``Future``
        (``cudaGraphLaunch`` analogue).

        Single-segment graphs take one ops-queue hop.  Multi-segment
        graphs (launches spanning devices, or independent SSA chains on
        one device — §11) fan out: each fused segment is dispatched to
        its chain's stream lane the moment its producer segments finish
        (cross-device edges run their planned transfer steps first,
        cross-lane edges synchronize through event edges), and all
        segments join through the single returned future.

        ``feeds`` overrides recorded write payloads, keyed by the
        ``WriteNode`` handle or by the target ``Buffer``.  ``sync="ready"``
        resolves at device completion of all kept values (CUDA-event
        semantics); ``sync="dispatch"`` resolves once results are
        submitted (the queue is released immediately).

        ``stream`` replays a single-segment graph on a caller-chosen
        stream of the route device instead of its default lane
        (``cudaGraphLaunch(exec, stream)``): the replay is then FIFO with
        that stream's other work and overlaps the device's other lanes —
        the serving engine feeds micro-batches this way so H2D token
        writes and decode replays ride an engine-owned lane, concurrent
        with default-lane traffic.  Multi-segment graphs resolve their
        lanes at instantiate (chain -> stream, §11) and refuse the
        override."""
        block = sync == "ready"
        if stream is not None and self._fanout:
            raise ValueError(
                f"GraphExec '{self.graph.name}' is a fan-out plan ({len(self._segments)} "
                "segments): its lanes were resolved at instantiate (one stream per "
                "chain) and cannot be overridden per replay — stream= applies to "
                "single-segment graphs only"
            )
        if self._fanout:
            return self._replay_fanout(feeds, block)
        fast = self._fast
        if fast is not None and stream is None and all(
                b.device.ops_queue is self._queue for b in fast.extern_bufs):
            # Pre-bound fast path: the plan is frozen, the externs are
            # still homed on the route lane (no pre-reads needed — lane
            # FIFO orders the replay after their pending eager ops), and
            # no stream override.  Cost per replay: one lock-scoped lane
            # enqueue + one Future.
            with self._replay_lock:
                prev = self._last_replay
                gate = prev if self._last_replay_queue is not self._queue else None
                launched = self._queue.submit(self._replay_fast, feeds, block, gate)
                self._last_replay = launched
                self._last_replay_queue = self._queue
            return launched
        queue = self._queue if stream is None else stream._lane_for(self._route_dev)

        def _execute(pre, prev_gate=None) -> GraphResult:
            if prev_gate is not None:
                # A prior replay of this exec went down a DIFFERENT lane
                # (stream override): park on it so buffer commits never
                # race between replays.  Always earlier-submitted work, so
                # the deadlock-freedom induction in __init__ still holds.
                prev_gate.wait()
            env, adopted = self._stage_env(feeds, pre)
            for seg in self._segments:
                xs = [env[s] for s in seg.in_syms]
                if self._multi_device:
                    jd = seg.device.jax_device
                    xs = [x if x.devices() == {jd} else jax.device_put(x, jd) for x in xs]
                outs = seg.compiled(*xs)
                for s, v in zip(seg.out_syms, outs):
                    env[s] = v
            return self._commit(env, adopted, block)

        # Foreign externs: reads submitted NOW on their owning queues
        # (resolved per replay — a re-homed buffer reads on its current
        # queue), ordered after pending eager ops there AND behind the
        # previous replay of this exec (pipelined replays must not read
        # an extern before the prior commit rebinds it).  _execute and
        # the reads only park on earlier-submitted work (deadlock-freedom
        # note in __init__); the lock is held for submission only.
        with self._replay_lock:
            pre: "dict[int, Future]" = {}
            prev = self._last_replay
            for s, buf in self.graph._extern.items():
                q = buf.device.ops_queue
                if q is not queue:
                    pre[s] = q.submit(
                        _extern_read(buf, self._prod_dev[s].jax_device, after=prev)
                    )
            gate = prev if self._last_replay_queue is not queue else None
            launched = queue.submit(_execute, pre, gate)
            self._last_replay = launched
            self._last_replay_queue = queue
        return launched

    def _replay_fanout(self, feeds, block: bool) -> "Future[GraphResult]":
        """Concurrent multi-device replay.

        Everything queue-bound is submitted synchronously, in capture
        order, from the calling thread — extern reads on their owning
        queues, then one task per segment on its own queue — so the
        WorkQueue submission-ordering contract holds exactly as on the
        single-hop path: eager work submitted after ``replay()`` returns
        runs after the replay's work on that device.  A segment task
        parks its worker on its producers' futures (the same discipline
        eager launches use for pending builds); progress is guaranteed
        because producers are always capture-earlier, hence ahead on
        their queues.  Join + buffer commit run on the host pool and
        resolve the single returned future.
        """
        from repro.core.executor import get_runtime
        from repro.core.futures import Promise, when_all

        g = self.graph
        pool = get_runtime().pool
        # Serialize whole replays: released by the join task (a Lock may
        # be released by a different thread than took it).
        self._replay_lock.acquire()
        try:
            sym_futs: "dict[int, Future]" = {}
            # Extern inputs: read on the owning queue (ordered after any
            # pending eager ops there), normalized to the planned device.
            for s, buf in g._extern.items():
                sym_futs[s] = buf.device.ops_queue.submit(
                    _extern_read(buf, self._prod_dev[s].jax_device)
                )

            # Write payloads: host data, no queue ordering needed — one
            # pool task prepares them and resolves per-sym promises.
            adopted: "set[int]" = set()
            wpromises = {n.sym: Promise(name=f"write:{n.sym}") for n in self._writes}
            for s, p in wpromises.items():
                sym_futs[s] = p.get_future()

            def _stage_writes():
                pending = dict(wpromises)
                try:
                    for n in self._writes:
                        arr, was_adopted = self._stage_write(n, feeds)
                        if was_adopted:
                            adopted.add(n.sym)
                        pending.pop(n.sym).set_value(arr)
                except BaseException as e:  # noqa: BLE001
                    for p in pending.values():
                        p.set_exception(e)

            pool.submit(_stage_writes)

            # Segments: submitted NOW, in capture order, each to its own
            # stream lane (seg.queue — chain -> stream, §11), parked on
            # its producers (extern reads / write promises / earlier
            # segments' outputs).  Same-lane segments stay FIFO in capture
            # order; cross-lane dependencies synchronize through the sym
            # futures — the plan's event edges.
            seg_futs = []
            for seg in self._segments:
                deps = [sym_futs[s] for s in seg.in_syms]

                def _parked(seg=seg, deps=deps):
                    return _segment_runner(seg)(*[d.get() for d in deps])

                fut = seg.queue.submit(_parked)
                seg_futs.append(fut)
                for i, s in enumerate(seg.out_syms):
                    sym_futs[s] = fut.then(lambda outs, i=i: outs[i], executor="inline")
        except BaseException:
            self._replay_lock.release()
            raise

        def _join_and_commit() -> GraphResult:
            try:
                when_all(seg_futs, name=f"join:{g.name}").get()  # first failure propagates
                env = {s: f.get() for s, f in sym_futs.items()}
                return self._commit(env, adopted, block)
            finally:
                self._replay_lock.release()

        out: "Future[GraphResult]" = Future.from_concurrent(
            pool.submit(_join_and_commit), name=f"replay:{g.name}"
        )
        # Commit-visibility fences: the join/commit runs off-queue, so an
        # EAGER op submitted to a device's default lane after replay()
        # returns could otherwise run before _commit rebinds the buffers
        # and observe pre-replay state — the single-hop path's FIFO
        # guarantee, silently lost.  One fence per involved device parks
        # its default lane until commit.  No deadlock: everything the
        # commit waits on was submitted ABOVE, hence ahead of the fence
        # on any shared lane.
        fenced: "set[int]" = set()
        for dev in [seg.device for seg in self._segments] + [b.device for b in g._buffers.values()]:
            if id(dev) not in fenced:
                fenced.add(id(dev))
                dev.ops_queue.submit(out.wait)
        return out

    __call__ = replay

    def __repr__(self) -> str:
        nseg = len(self._segments)
        nk = sum(len(s.nodes) for s in self._segments)
        nt = len(self._transfers)
        nlanes = len({id(s.queue) for s in self._segments})
        ne = len(self._event_edges)
        if self._fanout:
            mode = "fan-out"
        else:
            mode = "pre-bound" if self._fast is not None else "single-hop"
        comp = "+".join(sorted({s.exec_mode for s in self._segments})) or "empty"
        return (
            f"GraphExec({self.graph.name}: {nk} launches -> {nseg} fused segment(s) "
            f"on {nlanes} stream(s), {nt} transfer(s), {ne} event edge(s), {mode}, "
            f"compile={comp})"
        )


def _extern_read(buf: Buffer, jd, after: "Future | None" = None):
    """Task reading an extern buffer's current value, normalized onto the
    planned device ``jd`` (submitted to the buffer's owning queue so it
    orders after pending eager ops there).  ``after`` orders the read
    behind a previous replay of the same exec (always an earlier-submitted
    task, so parking on it preserves the deadlock-freedom discipline).

    A remote extern is fetched with a synchronous read parcel
    (``_read_now``): this task already runs ON the proxy's ops queue, so
    an ``enqueue_read`` — which would enqueue *behind* this task — must
    not be used here."""

    def _read():
        if after is not None:
            after.wait()
        if getattr(buf, "is_remote_buffer", False):
            return jax.device_put(buf._read_now(), jd)
        arr = buf.array()
        return arr if arr.devices() == {jd} else jax.device_put(arr, jd)

    return _read


def _remote_segment_executor(seg: "_Segment"):
    """Executable for a segment owned by a remote locality.

    Encodes the segment's launch plan once — kernel names (plus the
    remote program's GID when the recording program lives on that
    locality), SSA arg refs, literal args, geometry — and at each call
    ships it with the input arrays as one ``run_segment`` parcel.  The
    reply's output arrays are staged onto the local anchor device so
    downstream segments/transfer steps consume them exactly like locally
    produced values.  Runs on the proxy's ops queue like any segment, so
    parcel ordering per remote device is preserved.
    """
    from repro.core.program import _normalize_dim

    dev = seg.device
    plan = []
    for n in seg.nodes:
        args = []
        for a in n.arg_refs:
            if isinstance(a, _SymRef):
                args.append(("sym", a.sym))
            elif isinstance(a, jax.Array):
                args.append(("val", np.asarray(a)))
            else:
                args.append(("val", a))
        plan.append({
            "kernel": n.kernel,
            "args": args,
            "res": list(n.res_syms),
            "grid": _normalize_dim(n.grid),
            "block": _normalize_dim(n.block),
            "_program": n.program,  # resolved to a GID lazily below
        })
    in_syms, out_syms = list(seg.in_syms), list(seg.out_syms)

    def _run_remote(*xs):
        nodes = []
        for node in plan:
            prog = node["_program"]
            gid_f = getattr(prog, "_remote_gid_f", None)
            pgid = None
            if gid_f is not None and getattr(prog.device, "locality_id", None) == dev.locality_id:
                pgid = gid_f.get()  # create parcel is earlier on this queue
            wire = {k: v for k, v in node.items() if k != "_program"}
            wire["program"] = pgid
            nodes.append(wire)
        outs = dev._port.call_sync(dev.locality_id, "run_segment", {
            "device": dev.remote_key,
            "nodes": nodes,
            "in_syms": in_syms,
            "out_syms": out_syms,
            "inputs": [np.asarray(x) for x in xs],
        })
        return tuple(jax.device_put(o, dev.jax_device) for o in outs)

    return _run_remote


def _segment_runner(seg: "_Segment"):
    """Executable for one fan-out dispatch: run the segment's planned
    transfer steps (cross-device SSA edges -> device_put onto this
    segment's device), then its fused executable."""
    jd = seg.device.jax_device

    def _run_segment(*xs):
        if seg.transfer_ixs:
            xs = list(xs)
            for i in seg.transfer_ixs:
                x = xs[i]
                if not isinstance(x, jax.Array) or x.devices() != {jd}:
                    xs[i] = jax.device_put(x, jd)
        return seg.compiled(*xs)

    return _run_segment


_CAL_TRIALS = 3
_CAL_MAX_BYTES = 256 << 20  # segments above this skip trials (alloc churn)
_CAL_FUSED_EDGE = 1.05  # prefer fused within 5%: it elides intermediates


def _calibrate_executors(seg: "_Segment", g: "TaskGraph", fused, staged):
    """Time both segment executors on throwaway zero inputs and return the
    winner.  Fresh inputs per trial (the fused module may donate its
    arguments), built and synced before the clock starts; min-of-N is the
    robust statistic for noise-prone hosts.  Ties go to fused — it elides
    intermediate materializations.  Any trial failure keeps fused."""
    specs = [g._sym_spec[s] for s in seg.in_syms]
    if sum(int(np.prod(sp.shape)) * np.dtype(sp.dtype).itemsize for sp in specs) > _CAL_MAX_BYTES:
        return fused, "fused"
    jd = seg.device.jax_device

    def timed(fn):
        xs = [jax.device_put(jnp.zeros(sp.shape, sp.dtype), jd) for sp in specs]
        jax.block_until_ready(xs)
        t0 = time.perf_counter()
        out = fn(*xs)
        jax.block_until_ready(out)
        return time.perf_counter() - t0

    try:
        timed(fused), timed(staged)  # warmup (staged eager fallbacks trace here)
        tf, ts = [], []
        for _ in range(_CAL_TRIALS):  # interleaved: drift hits both sides
            tf.append(timed(fused))
            ts.append(timed(staged))
        if min(ts) * _CAL_FUSED_EDGE < min(tf):
            return staged, "staged"
    except Exception:  # noqa: BLE001 — calibration must never break instantiate
        pass
    return fused, "fused"


def _prepare(buf: Buffer, data, jd):
    """Feed payload -> device array matching the buffer on ``jd`` (the
    planned device; zero-copy when the payload already conforms)."""
    if isinstance(data, jax.Array) and data.shape == buf.shape and data.dtype == buf.dtype:
        if data.devices() == {jd}:
            return data
        return jax.device_put(data, jd)
    src = np.asarray(data)
    if src.shape != buf.shape or src.dtype != buf.dtype:
        src = src.reshape(buf.shape).astype(buf.dtype)
    return jax.device_put(src, jd)
