"""Task-graph capture & fused replay — the CUDA Graphs analogue (DESIGN.md §8).

The futurization layer (paper §3.1) pays a small constant cost per
operation: a ``Future``, a queue hop, and (for chains) a ``when_all``
fan-in.  The paper's §5 claim is that this cost is negligible *per launch*;
this module drives the *per-graph* cost toward zero the same way CUDA
Graphs, StarPU bundles and Specx task collectives do — record the DAG once,
then replay it with amortized scheduling:

  * ``capture()`` (stream-capture style) or an explicit ``TaskGraph``
    builder records ``Buffer`` transfers and ``Program.run`` launches as a
    symbolic SSA DAG — nothing executes during capture.
  * ``instantiate()`` fuses every maximal run of same-device kernel
    launches into **one** ``jax.jit``-compiled executable.  Intermediate
    values that never escape a fused segment are elided entirely; segment
    inputs that die inside the segment are *donated* so XLA reuses their
    memory.  The replay route (which ops queue) is resolved once, here.
  * ``replay()`` then executes the whole graph with a **single** ops-queue
    hop and a **single** ``Future`` — N launches for the price of one.

Correspondence: capture <-> ``cudaStreamBeginCapture``; ``GraphExec`` <->
``cudaGraphExec_t``; ``replay`` <-> ``cudaGraphLaunch``; feed overrides at
replay <-> ``cudaGraphExecKernelNodeSetParams``.  It is equally the
paper's Listing 2 execution graph, frozen and re-launched (PAPER §4).

Ownership rule (CUDA Graphs'): a buffer overwritten inside the graph whose
final value is consumed by a later in-graph launch is *graph-internal* —
after ``replay()`` it is invalidated (its storage may have been donated)
and reads raise until it is written again.  Buffers read from outside the
graph (extern inputs) are never donated, so a ``GraphExec`` can be
replayed any number of times.
"""
from __future__ import annotations

import threading
from contextlib import contextmanager
from typing import Any, Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.buffer import Buffer
from repro.core.futures import Future

__all__ = ["TaskGraph", "GraphExec", "GraphResult", "capture", "current_graph"]

_tls = threading.local()


def current_graph() -> "TaskGraph | None":
    """The graph currently recording on this thread (or None)."""
    return getattr(_tls, "graph", None)


@contextmanager
def capture(name: str = "captured"):
    """Record all ``Program.run`` / ``Buffer.enqueue_write`` /
    ``Buffer.enqueue_read`` calls on this thread into a ``TaskGraph``
    (``cudaStreamBeginCapture`` analogue).  Nothing executes until
    ``instantiate().replay()``."""
    g = TaskGraph(name)
    prev = current_graph()
    _tls.graph = g
    try:
        yield g
    finally:
        _tls.graph = prev


# ---------------------------------------------------------------------------
# symbolic nodes (returned as handles from capture-mode calls)
# ---------------------------------------------------------------------------


class _SymRef:
    """Reference to an SSA value inside the graph."""

    __slots__ = ("sym",)

    def __init__(self, sym: int):
        self.sym = sym


class WriteNode:
    """Recorded full-buffer H2D write; handle usable as a replay-feed key."""

    __slots__ = ("buf", "data", "sym")

    def __init__(self, buf: Buffer, data, sym: int):
        self.buf, self.data, self.sym = buf, data, sym


class LaunchNode:
    """Recorded kernel launch."""

    __slots__ = ("program", "kernel", "arg_refs", "out_bufs", "res_syms", "bound", "device")

    def __init__(self, program, kernel, arg_refs, out_bufs, res_syms, bound, device):
        self.program = program
        self.kernel = kernel
        self.arg_refs = arg_refs  # list of _SymRef | constant
        self.out_bufs = out_bufs  # list[Buffer] | None
        self.res_syms = res_syms  # list[int], one per kernel result
        self.bound = bound  # geometry-bound callable
        self.device = device


class ReadNode:
    """Recorded full-buffer D2H read; handle indexes the GraphResult."""

    __slots__ = ("buf", "sym")

    def __init__(self, buf: Buffer, sym: int):
        self.buf, self.sym = buf, sym


class GraphResult:
    """Value of a completed replay: fetched reads (np.ndarray) and
    out-less launch results (raw arrays), indexed by their capture handle."""

    def __init__(self, fetches: dict, reads: list):
        self._fetches = fetches
        self.reads = reads  # read values in capture order

    def __getitem__(self, node):
        return self._fetches[node]

    def __repr__(self) -> str:
        return f"GraphResult({len(self._fetches)} fetches)"


# ---------------------------------------------------------------------------
# the graph builder
# ---------------------------------------------------------------------------


class TaskGraph:
    """Symbolic DAG of transfers and launches (build explicitly or via
    ``capture()``); compile with ``instantiate()``."""

    def __init__(self, name: str = "graph"):
        self.name = name
        self._nodes: list = []
        self._next_sym = 0
        self._cur: "dict[int, int]" = {}  # id(buffer) -> current sym
        self._buffers: "dict[int, Buffer]" = {}  # id(buffer) -> buffer (keepalive)
        self._sym_spec: "dict[int, jax.ShapeDtypeStruct]" = {}
        self._extern: "dict[int, Buffer]" = {}  # sym -> source buffer
        self._frozen = False

    # -- recording surface -------------------------------------------------

    def _new_sym(self, shape, dtype) -> int:
        s = self._next_sym
        self._next_sym += 1
        self._sym_spec[s] = jax.ShapeDtypeStruct(tuple(shape), dtype)
        return s

    def _check_mutable(self) -> None:
        if self._frozen:
            raise RuntimeError(f"TaskGraph '{self.name}' is frozen (already instantiated)")

    def _sym_of(self, buf: Buffer) -> _SymRef:
        """Current SSA value of a buffer; first touch binds an extern input
        (read live from the buffer at every replay)."""
        s = self._cur.get(id(buf))
        if s is None:
            s = self._new_sym(buf.shape, buf.dtype)
            self._cur[id(buf)] = s
            self._buffers[id(buf)] = buf
            self._extern[s] = buf
        return _SymRef(s)

    def write(self, buf: Buffer, data=None, offset: int = 0, count: "int | None" = None) -> WriteNode:
        """Record a full-buffer H2D write.  ``data`` is the default payload;
        override per replay with ``replay(feeds={node_or_buffer: new_data})``."""
        self._check_mutable()
        if offset != 0 or (count is not None and count != buf.size):
            raise NotImplementedError(
                "graph capture supports full-buffer writes only (offset=0); "
                "stage partial updates outside the capture region"
            )
        sym = self._new_sym(buf.shape, buf.dtype)
        self._cur[id(buf)] = sym
        self._buffers[id(buf)] = buf
        node = WriteNode(buf, data, sym)
        self._nodes.append(node)
        return node

    def run(
        self,
        program,
        args: "Sequence[Buffer | Any]",
        name: str,
        grid=None,
        block=None,
        out: "Sequence[Buffer] | None" = None,
    ) -> LaunchNode:
        """Record a kernel launch (``Program.run`` analogue).  Non-buffer
        arguments are captured as constants and baked into the fused
        executable."""
        self._check_mutable()
        if name not in program._kernels:
            raise KeyError(f"no kernel '{name}' in {program.name}")
        bound = program._bind(name, grid, block)
        arg_refs: list = []
        shape_args: list = []
        for a in args:
            if isinstance(a, Buffer):
                ref = self._sym_of(a)
                arg_refs.append(ref)
                shape_args.append(self._sym_spec[ref.sym])
            else:
                arg_refs.append(a)
                shape_args.append(a)
        res_shapes = jax.eval_shape(bound, *shape_args)
        res_list = list(res_shapes) if isinstance(res_shapes, (tuple, list)) else [res_shapes]
        if out is not None and len(res_list) != len(out):
            raise ValueError(
                f"kernel '{name}' returns {len(res_list)} arrays for {len(out)} out buffers"
            )
        res_syms = [self._new_sym(r.shape, r.dtype) for r in res_list]
        if out is not None:
            for b, s in zip(out, res_syms):
                self._cur[id(b)] = s
                self._buffers[id(b)] = b
        node = LaunchNode(program, name, arg_refs, list(out) if out is not None else None,
                          res_syms, bound, program.device)
        self._nodes.append(node)
        return node

    def read(self, buf: Buffer, offset: int = 0, count: "int | None" = None) -> ReadNode:
        """Record a full-buffer D2H fetch; the handle indexes the replay's
        ``GraphResult`` (value is an ``np.ndarray``, as in eager reads)."""
        self._check_mutable()
        if offset != 0 or (count is not None and count != buf.size):
            raise NotImplementedError(
                "graph capture supports full-buffer reads only (offset=0)"
            )
        node = ReadNode(buf, self._sym_of(buf).sym)
        self._nodes.append(node)
        return node

    # -- instantiate: fuse + compile + pre-resolve the route ----------------

    def instantiate(self, donate: bool = True) -> "GraphExec":
        """Fuse, compile and freeze the graph into a replayable executable
        (``cudaGraphInstantiate`` analogue).  ``donate=False`` disables
        buffer donation (debugging aid: write-fed buffers then keep their
        payload after replay; values fused away inside a segment still
        invalidate their buffers)."""
        self._check_mutable()
        self._frozen = True
        return GraphExec(self, donate=donate)


# ---------------------------------------------------------------------------
# instantiated executable
# ---------------------------------------------------------------------------


class _Segment:
    __slots__ = ("device", "nodes", "in_syms", "out_syms", "compiled", "donated_ixs")

    def __init__(self, device, nodes):
        self.device = device
        self.nodes = nodes
        self.in_syms: "list[int]" = []
        self.out_syms: "list[int]" = []
        self.compiled = None
        self.donated_ixs: "tuple[int, ...]" = ()


class GraphExec:
    """A frozen, fused, route-resolved task graph (``cudaGraphExec_t``)."""

    def __init__(self, graph: TaskGraph, donate: bool = True):
        self.graph = graph
        self._donate = donate
        self._writes: "list[WriteNode]" = [n for n in graph._nodes if isinstance(n, WriteNode)]
        self._reads: "list[ReadNode]" = [n for n in graph._nodes if isinstance(n, ReadNode)]
        self._build_plan()
        self._compile_segments()
        # Pre-resolved route: one ops-queue hop for the whole replay.
        route_dev = self._segments[0].device if self._segments else None
        if route_dev is None:
            for b in graph._buffers.values():
                route_dev = b.device
                break
        if route_dev is None:
            raise ValueError(f"TaskGraph '{graph.name}' is empty")
        self._queue = route_dev.ops_queue
        # Placement spans segments AND extern inputs: a graph whose input
        # buffer lives on another device needs the replay-time device_put
        # guard even when all launches share one device.
        placements = {s.device.jax_device for s in self._segments}
        placements.update(b.device.jax_device for b in graph._extern.values())
        placements.update(n.buf.device.jax_device for n in self._writes)
        self._multi_device = len(placements) > 1
        # Extern buffers owned by other devices may have pending ops on
        # their own queues; replay must drain those before reading, or it
        # could observe stale contents (the eager path got this ordering
        # for free by staging on the source queue).
        foreign = {}
        for b in graph._extern.values():
            q = b.device.ops_queue
            if q is not self._queue:
                foreign[id(q)] = q
        self._foreign_queues = list(foreign.values())

    # -- planning ----------------------------------------------------------

    def _build_plan(self) -> None:
        g = self.graph
        nodes = g._nodes

        # Segment = maximal run of launches on one device (writes/reads are
        # replay-time host ops and do not break fusion; SSA ordering keeps
        # them correct regardless of where they sit between launches).
        self._segments: "list[_Segment]" = []
        for n in nodes:
            if not isinstance(n, LaunchNode):
                continue
            if self._segments and self._segments[-1].device is n.device:
                self._segments[-1].nodes.append(n)
            else:
                self._segments.append(_Segment(n.device, [n]))

        # Liveness: which segment consumes each sym, and what must survive.
        launch_use_segs: "dict[int, list[int]]" = {}
        produced_in_seg: "dict[int, int]" = {}
        for si, seg in enumerate(self._segments):
            for n in seg.nodes:
                for a in n.arg_refs:
                    if isinstance(a, _SymRef):
                        launch_use_segs.setdefault(a.sym, []).append(si)
                for s in n.res_syms:
                    produced_in_seg[s] = si

        fetched: "set[int]" = {r.sym for r in self._reads}
        for n in nodes:
            if isinstance(n, LaunchNode) and n.out_bufs is None:
                fetched.update(n.res_syms)  # out-less launch: results fetched

        final_sym: "dict[int, int]" = {}  # id(buffer) -> final sym
        for bid, s in g._cur.items():
            final_sym[bid] = s
        # Keep set: fetched values + terminal buffer values (final value
        # with no in-graph launch consumer).  A buffer whose final value IS
        # consumed in-graph is graph-internal: fused away / donated.
        keep: "set[int]" = set(fetched)
        for bid, s in final_sym.items():
            if not launch_use_segs.get(s):
                keep.add(s)
        self._keep = keep
        self._final_sym = final_sym

        # Per-segment interface: inputs (consumed, produced earlier) and
        # outputs (produced here, needed later or kept).
        for si, seg in enumerate(self._segments):
            in_syms: "list[int]" = []
            seen = set()
            local_produced = set()
            for n in seg.nodes:
                for a in n.arg_refs:
                    if isinstance(a, _SymRef) and a.sym not in local_produced and a.sym not in seen:
                        seen.add(a.sym)
                        in_syms.append(a.sym)
                local_produced.update(n.res_syms)
            out_syms = [
                s for n in seg.nodes for s in n.res_syms
                if s in keep or any(u > si for u in launch_use_segs.get(s, ()))
            ]
            seg.in_syms = in_syms
            seg.out_syms = out_syms
            if self._donate:
                donated = []
                for pos, s in enumerate(in_syms):
                    if s in g._extern:
                        continue  # replay re-reads extern buffers: never donate
                    if s in keep:
                        continue
                    if any(u > si for u in launch_use_segs.get(s, ())):
                        continue
                    donated.append(pos)
                seg.donated_ixs = tuple(donated)

        self._donated_syms = {
            seg.in_syms[pos] for seg in self._segments for pos in seg.donated_ixs
        }

    def _compile_segments(self) -> None:
        g = self.graph
        for seg in self._segments:
            nodes, in_syms, out_syms = seg.nodes, tuple(seg.in_syms), tuple(seg.out_syms)

            def make_fused(nodes=nodes, in_syms=in_syms, out_syms=out_syms):
                def fused(*xs):
                    env = dict(zip(in_syms, xs))
                    for n in nodes:
                        vals = [env[a.sym] if isinstance(a, _SymRef) else a for a in n.arg_refs]
                        res = n.bound(*vals)
                        rl = list(res) if isinstance(res, (tuple, list)) else [res]
                        for s, v in zip(n.res_syms, rl):
                            env[s] = v
                    return tuple(env[s] for s in out_syms)

                return fused

            specs = [g._sym_spec[s] for s in in_syms]
            try:
                # Pin input shardings to the segment's device so replay on a
                # non-default device doesn't trip compiled-sharding checks.
                sharding = jax.sharding.SingleDeviceSharding(seg.device.jax_device)
                specs = [
                    jax.ShapeDtypeStruct(sp.shape, sp.dtype, sharding=sharding)
                    for sp in specs
                ]
            except (AttributeError, TypeError):  # older jax: default placement
                pass
            jitted = jax.jit(make_fused(), donate_argnums=seg.donated_ixs)
            seg.compiled = jitted.lower(*specs).compile()

    # -- replay ------------------------------------------------------------

    def replay(self, feeds: "dict | None" = None, sync: str = "ready") -> "Future[GraphResult]":
        """Execute the whole graph: one ops-queue hop, one ``Future``
        (``cudaGraphLaunch`` analogue).

        ``feeds`` overrides recorded write payloads, keyed by the
        ``WriteNode`` handle or by the target ``Buffer``.  ``sync="ready"``
        resolves at device completion of all kept values (CUDA-event
        semantics); ``sync="dispatch"`` resolves once results are
        submitted (the queue is released immediately)."""
        g = self.graph
        block = sync == "ready"

        def _execute() -> GraphResult:
            for q in self._foreign_queues:
                q.drain()  # order extern reads after their devices' pending ops
            env: "dict[int, Any]" = {}
            adopted: "set[int]" = set()
            for s, buf in g._extern.items():
                env[s] = buf.array()
            for n in self._writes:
                data = n.data
                if feeds is not None:
                    data = feeds.get(n, feeds.get(n.buf, data))
                if data is None:
                    raise ValueError(
                        f"write node for buffer gid={n.buf.gid} has no payload: "
                        "record one at capture or pass feeds={node: data}"
                    )
                arr = _prepare(n.buf, data)
                if arr is data:
                    if n.sym in self._donated_syms:
                        # The payload was adopted by reference and this
                        # replay will donate its storage into a fused
                        # executable — copy so the caller's array (and the
                        # recorded default) survives for the next replay.
                        arr = jnp.array(arr)
                    else:
                        adopted.add(n.sym)  # caller-owned storage, by ref
                env[n.sym] = arr
            for seg in self._segments:
                xs = [env[s] for s in seg.in_syms]
                if self._multi_device:
                    jd = seg.device.jax_device
                    xs = [x if x.devices() == {jd} else jax.device_put(x, jd) for x in xs]
                outs = seg.compiled(*xs)
                for s, v in zip(seg.out_syms, outs):
                    env[s] = v

            # Commit buffer states (CUDA Graphs ownership rule): a buffer
            # keeps its final value when that value survived replay (it was
            # materialized and not donated into a fused executable);
            # otherwise its storage is gone and reads must fail.
            live_vals = []
            for bid, s in self._final_sym.items():
                buf = g._buffers[bid]
                if s in g._extern:
                    if s in self._keep:
                        live_vals.append(env[s])
                    continue
                if s in env and s not in self._donated_syms:
                    buf._set_array(env[s], aliased=s in adopted)
                    live_vals.append(env[s])
                else:
                    buf._invalidate()

            fetches: dict = {}
            reads: list = []
            for n in g._nodes:
                if isinstance(n, ReadNode):
                    val = np.asarray(env[n.sym])
                    fetches[n] = val
                    reads.append(val)
                elif isinstance(n, LaunchNode) and n.out_bufs is None:
                    vals = [env[s] for s in n.res_syms]
                    fetches[n] = vals[0] if len(vals) == 1 else vals
                    live_vals.extend(vals)
            if block and live_vals:
                jax.block_until_ready(live_vals)
            return GraphResult(fetches, reads)

        return self._queue.submit(_execute)

    __call__ = replay

    def __repr__(self) -> str:
        nseg = len(self._segments)
        nk = sum(len(s.nodes) for s in self._segments)
        return f"GraphExec({self.graph.name}: {nk} launches -> {nseg} fused segment(s))"


def _prepare(buf: Buffer, data):
    """Feed payload -> device array matching the buffer (zero-copy when the
    payload already conforms)."""
    if isinstance(data, jax.Array) and data.shape == buf.shape and data.dtype == buf.dtype:
        if data.devices() == {buf.device.jax_device}:
            return data
        return jax.device_put(data, buf.device.jax_device)
    src = np.asarray(data)
    if src.shape != buf.shape or src.dtype != buf.dtype:
        src = src.reshape(buf.shape).astype(buf.dtype)
    return jax.device_put(src, buf.device.jax_device)
