"""Logical device abstraction (paper §4, Fig. 2 ``device``).

A ``Device`` wraps one ``jax.Device`` (local *or* remote — in
multi-controller JAX remote accelerators appear as non-addressable entries
of ``jax.devices()``) and exposes HPXCL's surface:

  * ``create_buffer``  — async allocation (``cudaMalloc`` analogue)
  * ``create_program`` — async program creation (NVRTC source analogue)
  * per-device work lanes: ``ops`` (transfers/launch submission order) and
    ``compile`` (runtime compilation), separate so that building a kernel
    overlaps data transfers exactly as in Listing 2
  * ``create_stream`` / ``default_stream`` — N ordered lanes per device
    (``cudaStream_t`` analogue, DESIGN.md §11): independent transfer/
    launch chains overlap, same-stream order is preserved;
    ``ops_queue`` IS the default stream's lane, so stream-less code keeps
    the exact single-queue semantics
  * ``synchronize``    — drain ALL the device's streams (not just the
    default lane) plus the compile queue

``get_all_devices(major, minor)`` mirrors the paper's Listing 1: it returns
a *future* of the device list, filtered by a minimum capability.

Scheduler surface (DESIGN.md §9): ``Device.load()`` exposes the ops-queue
backlog and ``Device.resident_bytes()`` the AGAS byte total placed here —
the two signals the ``least_loaded`` and ``affinity`` placement policies
read.  ``Locality`` groups devices by owning process (HPX locality
analogue); ``get_all_localities()`` mirrors ``hpx::find_all_localities``.

Remote proxies (DESIGN.md §10): ``RemoteDevice``/``RemoteBuffer`` are the
parcel-backed twins of ``Device``/``Buffer`` — same async surface, but
``create_buffer`` / ``enqueue_write`` / ``enqueue_read`` / ``free`` (and
launches, through ``RemoteProgram``) travel as parcels to the owning
locality and resolve the caller's futures from reply parcels.  A proxy's
``ops_queue`` is a real local ``WorkQueue``: it orders parcel submission
per remote device and feeds the same ``load()`` signal the placement
policies read for local devices.
"""
from __future__ import annotations

import os
import threading
import weakref
from typing import Any, Callable, Optional

import jax
import numpy as np

from repro.core import agas
from repro.core.executor import LaneDispatcher, QueueLoad, WorkQueue, get_runtime
from repro.core.futures import Future
from repro.core.stream import Stream

__all__ = [
    "Device",
    "Locality",
    "RemoteDevice",
    "RemoteBuffer",
    "get_all_devices",
    "get_all_localities",
    "capability_of",
]

# Pseudo "compute capability" per platform so the Listing-1 signature keeps
# meaning on TPU/CPU: (major, minor).
_PLATFORM_CAPABILITY = {
    "cpu": (1, 0),
    "gpu": (7, 0),
    "cuda": (7, 0),
    "rocm": (7, 0),
    "tpu": (9, 0),
}


def capability_of(jax_device: "jax.Device") -> "tuple[int, int]":
    return _PLATFORM_CAPABILITY.get(jax_device.platform, (1, 0))


def _default_memory_limit() -> int:
    """Per-device resident-bytes threshold for memory-aware placement
    (DESIGN.md §14).  0 means unlimited — the veto and LRU spill are off.
    The env default seeds every device; the attribute is plain and
    per-device, so a heterogeneous fleet can set different ceilings."""
    try:
        return int(os.environ.get("REPRO_SPILL_BYTES", "0") or 0)
    except ValueError:
        return 0


class Device:
    """Location-transparent handle to one accelerator."""

    def __init__(self, jax_device: "jax.Device"):
        self.jax_device = jax_device
        self.key = f"{jax_device.platform}:{jax_device.id}"
        rt = get_runtime()
        # Streams multiplex onto one lane dispatcher per device
        # (DESIGN.md §11); compilation keeps its own queue (NVRTC) so
        # building a kernel overlaps transfers on any stream.
        self._dispatcher: LaneDispatcher = rt.dispatcher(f"ops:{self.key}")
        self._streams: "list[Stream]" = []
        self._stream_lock = threading.Lock()
        self._replay_streams: "dict[int, Stream]" = {}
        self._default_stream = self.create_stream(name="default")
        # Back-compat alias: the default stream's lane IS the ops queue —
        # stream-less submission order is unchanged.
        self.ops_queue = self._default_stream.lane
        self.compile_queue: WorkQueue = rt.queue(f"compile:{self.key}")
        # Memory-aware placement threshold (DESIGN.md §14); 0 = unlimited.
        self.memory_limit: int = _default_memory_limit()
        self.gid: agas.GID = agas.registry.register(
            self, agas.Placement(self.key, jax_device.process_index), kind="device"
        )

    # -- identity ----------------------------------------------------------

    @property
    def platform(self) -> str:
        return self.jax_device.platform

    @property
    def process_index(self) -> int:
        return self.jax_device.process_index

    @property
    def is_local(self) -> bool:
        return self.jax_device.process_index == jax.process_index()

    def capability(self) -> "tuple[int, int]":
        return capability_of(self.jax_device)

    # -- streams (cudaStream_t analogue, DESIGN.md §11) ----------------------

    @property
    def default_stream(self) -> Stream:
        """Stream 0: the lane stream-less ops order through (``ops_queue``)."""
        return self._default_stream

    def create_stream(self, name: "str | None" = None) -> Stream:
        """A new ordered lane of work on this device (``cudaStreamCreate``).

        Work on distinct streams runs concurrently (the dispatcher
        multiplexes lanes onto a shared pool); work within one stream is
        strictly FIFO.  Streams are cheap — a deque plus counters; worker
        threads are pooled."""
        with self._stream_lock:
            idx = len(self._streams)
            label = name if name is not None else f"s{idx}"
            # Lane key is index-prefixed: dispatcher.lane() memoizes by
            # name, and two streams must NEVER share a lane (a user name
            # colliding with an auto 's{idx}' or 'replay' lane would
            # silently serialize them — or deadlock a wait_event).
            lane = self._dispatcher.lane(f"{idx}.{label}")
            s = Stream(self, lane, name=f"{self.key}/{label}")
            self._streams.append(s)
            return s

    def streams(self) -> "list[Stream]":
        with self._stream_lock:
            return list(self._streams)

    def _replay_lane(self, chain: int):
        """Lane carrying fused-graph chain ``chain`` at replay (DESIGN.md
        §11): chain 0 rides the default stream; higher chains get
        dedicated, memoized replay streams so independent chains of any
        captured graph overlap without growing a lane per ``GraphExec``."""
        if chain == 0:
            return self.ops_queue
        with self._stream_lock:
            s = self._replay_streams.get(chain)
            if s is None:
                # 'replay.' keys cannot collide with create_stream's
                # '{idx}.{label}' keys (idx is always an integer).
                lane = self._dispatcher.lane(f"replay.{chain}")
                s = Stream(self, lane, name=f"{self.key}/replay{chain}")
                self._streams.append(s)
                self._replay_streams[chain] = s
        return s.lane

    # -- scheduler signals --------------------------------------------------

    def load(self) -> QueueLoad:
        """Whole-device backlog snapshot: per-lane depths summed across
        every stream (``least_loaded`` input, DESIGN.md §9/§11)."""
        return self._dispatcher.load()

    def resident_bytes(self) -> int:
        """AGAS-registered bytes currently placed here (``affinity`` input)."""
        return agas.registry.resident_bytes(self.key)

    # -- factory surface (all async, returning futures) ---------------------

    def create_buffer(self, shape, dtype=np.float32, fill: Any = None) -> "Future":
        """Allocate a device buffer (async; ``cudaMalloc`` analogue).

        ``shape`` may be an int (1-D length in *elements*, not bytes — the
        dtype-safe adaptation of HPXCL's byte counts) or a tuple.
        """
        from repro.core.buffer import Buffer

        def _alloc():
            return Buffer._allocate(self, shape, dtype, fill)

        return self.ops_queue.submit(_alloc)

    def create_buffer_from(self, data) -> "Future":
        """Allocate + write in one async op."""
        from repro.core.buffer import Buffer

        def _alloc():
            arr = np.asarray(data)
            buf = Buffer._allocate(self, arr.shape, arr.dtype, None)
            buf._array = jax.device_put(arr, self.jax_device)
            return buf

        return self.ops_queue.submit(_alloc)

    def create_program(self, kernels, name: str = "program") -> "Future":
        """Create a program from ``{kernel_name: callable}`` (async)."""
        from repro.core.program import Program

        return self.compile_queue.submit(lambda: Program(self, kernels, name=name))

    def create_program_with_file(self, path: str) -> "Future":
        """Load kernels from a python file defining ``KERNELS`` (percolation:
        source code shipped to and compiled at the device — NVRTC analogue).
        """
        from repro.core.program import Program

        return self.compile_queue.submit(lambda: Program.from_file(self, path))

    # -- graph capture (CUDA Graphs analogue) --------------------------------

    def capture(self, name: str = "captured"):
        """Begin a graph-capture region on this thread (DESIGN.md §8).

        Transfers and launches recorded inside are fused and replayed with
        a single hop on this device's ops queue:

            with dev.capture("step") as g:
                buf.enqueue_write(0, host)
                prog.run([buf], "k", out=[out])
                r = out.enqueue_read()
            exe = g.instantiate()
            result = exe.replay().get()   # result[r] is the np.ndarray
        """
        from repro.core.graph import capture as _capture

        return _capture(name)

    # -- synchronization ----------------------------------------------------

    def synchronize(self) -> None:
        """Drain ALL of this device's streams — every lane, not just the
        default one — plus the compile queue (``cudaDeviceSynchronize``).
        The barrier covers everything submitted to any stream before the
        call; lanes drain in parallel, so synchronizing never serializes
        otherwise-overlapping streams."""
        self._dispatcher.drain()
        self.compile_queue.drain()

    def __repr__(self) -> str:
        where = "local" if self.is_local else "remote"
        return f"Device({self.key}, {where}, gid={self.gid})"


class Locality:
    """One process's worth of devices (the HPX *locality* analogue).

    In multi-controller JAX each participating process owns the devices
    whose ``process_index`` matches; scheduling across localities is what
    makes a placement "remote".
    """

    def __init__(self, process_index: int, devices: "list[Device]"):
        self.process_index = process_index
        self.devices = list(devices)

    @property
    def is_local(self) -> bool:
        return self.process_index == jax.process_index()

    def __len__(self) -> int:
        return len(self.devices)

    def __iter__(self):
        return iter(self.devices)

    def __repr__(self) -> str:
        where = "local" if self.is_local else "remote"
        return f"Locality(process={self.process_index}, {where}, {len(self.devices)} device(s))"


# ---------------------------------------------------------------------------
# remote proxies (parcel-backed; DESIGN.md §10)
# ---------------------------------------------------------------------------


def _release_remote(port, locality_id: int, gid: int, proxied: bool) -> None:
    """GC finalizer for RemoteBuffer: retire the local proxy record (the
    resident-bytes accounting must not outlive the handle) and send a
    best-effort free parcel (never raises — the port or worker may already
    be gone at collection time)."""
    if proxied:
        agas.registry.unregister(gid)
    try:
        port.call(locality_id, "free", {"gid": gid})
    except Exception:  # noqa: BLE001
        pass


class RemoteDevice:
    """Parcel-backed handle to a device owned by another locality.

    Duck-types ``Device`` everywhere the runtime reads it: ``key``,
    ``ops_queue``/``compile_queue`` (real local queues — parcel submission
    order per remote device, and the scheduler's ``load()`` signal),
    ``load()``, ``resident_bytes()``, ``capability()``, plus ``alive()``
    (heartbeat-fed; a dead locality is excluded from placement).
    ``jax_device`` is a *local staging anchor*: values bound for this
    device are normalized onto it before they are shipped in a parcel.
    """

    is_remote_proxy = True

    def __init__(self, port, locality_id: int, remote_key: str, platform: str = "cpu",
                 capability: "tuple[int, int]" = (1, 0)):
        self._port = port
        self.locality_id = locality_id
        self.remote_key = remote_key
        self.key = f"L{locality_id}/{remote_key}"
        self._platform = platform
        self._capability = tuple(capability)
        self.jax_device = jax.devices()[0]  # staging anchor, not the executor
        rt = get_runtime()
        self.ops_queue: WorkQueue = rt.queue(f"parcel-ops:{self.key}")
        self.compile_queue: WorkQueue = rt.queue(f"parcel-compile:{self.key}")
        # Streams on a remote device are ordered parcel *channels*: each
        # stream gets its own submission queue, so parcels of one stream
        # stay strictly ordered while different streams' parcels may be
        # in flight concurrently (DESIGN.md §11).
        self._stream_lock = threading.Lock()
        self._streams: "list[Stream]" = [Stream(self, self.ops_queue, name=f"{self.key}/default")]
        # Same memory-aware threshold as local devices: the veto reads the
        # proxied AGAS byte total for this locality's device key.
        self.memory_limit: int = _default_memory_limit()
        self.gid: agas.GID = agas.registry.register(
            self, agas.Placement(self.key, locality_id), kind="device"
        )

    # -- identity ----------------------------------------------------------

    @property
    def platform(self) -> str:
        return self._platform

    @property
    def process_index(self) -> int:
        return self.locality_id

    @property
    def is_local(self) -> bool:
        return False

    def capability(self) -> "tuple[int, int]":
        return self._capability

    # -- streams (ordered parcel channels, DESIGN.md §11) --------------------

    @property
    def default_stream(self) -> Stream:
        return self._streams[0]

    def create_stream(self, name: "str | None" = None) -> Stream:
        """A new ordered parcel channel to this remote device: stream verbs
        become parcels submitted through the channel's own queue, so each
        stream's parcels keep submission order while channels overlap."""
        rt = get_runtime()
        with self._stream_lock:
            idx = len(self._streams)
            label = name if name is not None else f"s{idx}"
            # Index-prefixed queue key: rt.queue() memoizes by name, and
            # two channels must never share a queue (see Device.create_stream).
            chan = rt.queue(f"parcel-ops:{self.key}:{idx}.{label}")
            s = Stream(self, chan, name=f"{self.key}/{label}")
            self._streams.append(s)
            return s

    def streams(self) -> "list[Stream]":
        with self._stream_lock:
            return list(self._streams)

    def _replay_lane(self, chain: int):
        # Remote fused segments replay as ONE parcel each; keeping every
        # chain on the default channel preserves the run_segment ordering
        # the multi-locality replay tests pin down.
        return self.ops_queue

    # -- scheduler signals ---------------------------------------------------

    def load(self) -> QueueLoad:
        """Backlog summed across every parcel channel of this device."""
        loads = [s.lane.load() for s in self.streams()]
        return QueueLoad(
            depth=sum(l.depth for l in loads),
            inflight=sum(l.inflight for l in loads),
            busy_for=max((l.busy_for for l in loads), default=0.0),
            busy_time=sum(l.busy_time for l in loads),
            submitted=sum(l.submitted for l in loads),
            completed=sum(l.completed for l in loads),
            busy_ewma=sum(l.busy_ewma for l in loads),
        )

    def resident_bytes(self) -> int:
        return agas.registry.resident_bytes(self.key)

    def alive(self) -> bool:
        """Heartbeat verdict for the owning locality (scheduler exclusion)."""
        return self._port.alive(self.locality_id)

    # -- parcel plumbing -----------------------------------------------------

    def _call(self, action: str, lane=None, **payload) -> "Future":
        """Send one action parcel, ordered through this device's default
        channel — or, when ``lane`` is given, through that stream's own
        parcel channel (same-stream parcels keep submission order).

        On a non-pipelined port the channel worker blocks on each reply,
        so the next parcel of the channel is only sent once the previous
        one has executed.  On a pipelined port the channel task only
        *stages and flushes* the parcel — the reply resolves the returned
        future asynchronously, and the channel is free to ship the next
        parcel immediately (same-channel order still holds end-to-end:
        staging order is wire order is the worker's execution order).
        NOTE: with pipelining, a drained lane proves dispatch, not remote
        completion — completion fences go through ``synchronize()`` (a
        ``barrier`` parcel) or the returned future itself."""
        payload.setdefault("device", self.remote_key)
        port, loc = self._port, self.locality_id
        if not port.alive(loc):
            return Future.failed(RuntimeError(
                f"parcel {action!r} to locality L{loc} failed fast: the locality is dead "
                "(missed heartbeat or worker exit) and is excluded from placement"
            ))
        q = self.ops_queue if lane is None else lane
        if getattr(port, "pipelined", False):
            from repro.core.futures import Promise, forward_failure

            promise: "Promise" = Promise(name=f"parcel:{action}:L{loc}")

            def _ship():
                port.stage(loc, action, payload, promise)
                port.flush(loc)

            forward_failure(q.submit(_ship), promise)
            return promise.get_future()
        return q.submit(lambda: port.call_sync(loc, action, payload))

    # -- factory surface -----------------------------------------------------

    def create_buffer(self, shape, dtype=np.float32, fill: Any = None) -> "Future":
        """Allocate a buffer on the remote locality (async; the
        ``create_buffer`` action parcel)."""
        shape_p = list(shape) if isinstance(shape, (tuple, list)) else int(shape)
        fut = self._call("create_buffer", shape=shape_p, dtype=np.dtype(dtype).str, fill=fill)
        return fut.then(lambda rep: RemoteBuffer(self, rep["gid"], rep["shape"], rep["dtype"]),
                        executor="inline")

    def create_buffer_from(self, data) -> "Future":
        fut = self._call("create_buffer_from", data=np.asarray(data))
        return fut.then(lambda rep: RemoteBuffer(self, rep["gid"], rep["shape"], rep["dtype"]),
                        executor="inline")

    def create_program(self, kernels, name: str = "program") -> "Future":
        """Create a program on the remote locality.  ``kernels`` are
        *names* (str or list of str) resolved by the remote's kernel
        registry, or a ``{name: callable}`` dict whose callables stay
        local as shape-inference shadows (percolation by reference)."""
        from repro.core.program import RemoteProgram

        return self.compile_queue.submit(lambda: RemoteProgram(self, kernels, name=name))

    # -- graph capture -------------------------------------------------------

    def capture(self, name: str = "captured"):
        from repro.core.graph import capture as _capture

        return _capture(name)

    # -- synchronization -----------------------------------------------------

    def synchronize(self) -> None:
        """Drain EVERY parcel channel of this device (all streams, not
        just the default one) plus the compile queue.  On a pipelined
        port a drained lane only proves every parcel was *shipped*, so a
        ``barrier`` parcel (executed on the worker's action pool, in
        arrival order, after everything shipped before it) closes the gap
        to remote completion."""
        for s in self.streams():
            s.lane.drain()
        self.compile_queue.drain()
        if getattr(self._port, "pipelined", False) and self._port.alive(self.locality_id):
            self._call("barrier").get()

    def __repr__(self) -> str:
        state = "alive" if self.alive() else "DEAD"
        return f"RemoteDevice({self.key}, {state}, gid={self.gid})"


class RemoteBuffer:
    """Location-transparent handle to a buffer owned by another locality.

    The remote-minted GID is proxied into the local AGAS registry (with
    ``nbytes``), so placement policies score remote-resident bytes exactly
    like local ones.  Transfers are parcels: ``enqueue_write`` ships host
    data out, ``enqueue_read`` brings it back, ``copy_to`` chains the two
    (the explicit cross-locality percolation move).
    """

    is_remote_proxy = True
    is_remote_buffer = True

    def __init__(self, device: RemoteDevice, gid: int, shape, dtype):
        self.device = device
        self.gid = gid
        self.shape = tuple(shape)
        self.dtype = np.dtype(dtype)
        self._freed = False
        self._free_future: "Future | None" = None
        self._proxied = agas.registry.register_proxy(
            self, gid, agas.Placement(device.key, device.locality_id),
            kind="buffer", nbytes=self.nbytes,
        )
        self._finalizer = weakref.finalize(
            self, _release_remote, device._port, device.locality_id, gid, self._proxied
        )

    @property
    def size(self) -> int:
        return int(np.prod(self.shape)) if self.shape else 1

    @property
    def nbytes(self) -> int:
        return self.size * self.dtype.itemsize

    # -- async transfer surface ----------------------------------------------

    def enqueue_write(self, offset: int, data, count: "int | None" = None,
                      stream=None) -> "Future":
        from repro.core.graph import current_graph

        if current_graph() is not None:
            raise NotImplementedError(
                "graph capture writes to local buffers only; stage remote "
                "transfers outside the capture region (remote buffers may be "
                "read as extern inputs)"
            )
        lane = None if stream is None else stream._lane_for(self.device)
        fut = self.device._call("enqueue_write", lane=lane, gid=self.gid, offset=offset,
                                data=np.asarray(data), count=count)
        if stream is not None:
            # Pipelined ports resolve the reply AFTER the lane task ends —
            # note it so record()/synchronize() mean remote completion.
            stream._note_completion(fut)
        return fut

    def enqueue_read(self, offset: int = 0, count: "int | None" = None,
                     stream=None) -> "Future":
        from repro.core.graph import current_graph

        g = current_graph()
        if g is not None:
            return g.read(self, offset=offset, count=count)
        lane = None if stream is None else stream._lane_for(self.device)
        fut = self.device._call("enqueue_read", lane=lane, gid=self.gid,
                                offset=offset, count=count)
        if stream is not None:
            stream._note_completion(fut)
        return fut

    def enqueue_read_sync(self, offset: int = 0, count: "int | None" = None, stream=None):
        from repro.core.graph import current_graph

        if current_graph() is not None:
            raise RuntimeError(
                "enqueue_read_sync inside a graph-capture region: the value "
                "does not exist until replay. Use enqueue_read()."
            )
        return self.enqueue_read(offset, count, stream=stream).get()

    def _read_now(self) -> np.ndarray:
        """Synchronous read bypassing the proxy queue — for callers already
        running ON this device's ops queue (graph extern reads), where an
        ``enqueue_read`` would deadlock behind the calling task."""
        return self.device._port.call_sync(
            self.device.locality_id,
            "enqueue_read",
            {"device": self.device.remote_key, "gid": self.gid, "offset": 0, "count": None},
        )

    def copy_to(self, target_device) -> "Future":
        """Percolation across localities: one read parcel here, one write
        on the target — future of the *new* buffer on ``target_device``."""
        if target_device is self.device:
            return Future.ready(self)
        pool = get_runtime().pool
        return self.enqueue_read().then(
            lambda host: target_device.create_buffer_from(host).get(),
            executor=pool,
            name=f"copy:gid{self.gid}",
        )

    # -- lifetime --------------------------------------------------------------

    def free(self) -> "Future":
        """Release the remote storage (idempotent; future of None).

        The free parcel is gated on a barrier across ALL of the device's
        parcel channels: channels are mutually unordered, so a free sent
        straight down the default channel could execute on the owning
        locality before writes/launches still in flight on a stream
        channel (remote use-after-free) — the same all-lanes rule as the
        local ``Buffer.free``."""
        if self._free_future is None:
            self._freed = True
            if self._finalizer is not None:
                self._finalizer.detach()
                self._finalizer = None
            if self._proxied:
                agas.registry.unregister(self.gid)
            dev = self.device
            others = [s.lane for s in dev.streams() if s.lane is not dev.ops_queue]
            if not others:
                self._free_future = dev._call("free", gid=self.gid)
            else:
                from repro.core.futures import when_all

                barrier = when_all([ch.submit(lambda: None) for ch in others])
                # The continuation submits the free parcel to the default
                # channel and waits for its reply — host pool, never
                # inline on a channel worker.
                self._free_future = barrier.then(
                    lambda _: dev._call("free", gid=self.gid).get(),
                    executor=get_runtime().pool,
                    name=f"free:gid{self.gid}",
                )
        return self._free_future

    # -- kernel-facing view ----------------------------------------------------

    def array(self):
        raise RuntimeError(
            f"RemoteBuffer gid={self.gid} lives on locality "
            f"L{self.device.locality_id}; its value is not addressable here — "
            "use enqueue_read() (or launch through a RemoteProgram on that locality)"
        )

    def __repr__(self) -> str:
        return f"RemoteBuffer(gid={self.gid}, {self.dtype}{list(self.shape)} @ {self.device.key})"


_device_cache: "dict[str, Device]" = {}


def _wrap(jd: "jax.Device") -> Device:
    key = f"{jd.platform}:{jd.id}"
    dev = _device_cache.get(key)
    if dev is None:
        dev = _device_cache[key] = Device(jd)
    return dev


def _on_runtime_reset() -> None:
    """Drop cached devices whose queues died with the old runtime.

    Called by ``executor.reset_runtime``: the cached ``Device``s hold
    ``WorkQueue``s from the runtime being torn down, so keeping them would
    make the next ``submit`` raise "WorkQueue ... is shut down".  Their
    AGAS records are retired too; the next ``get_all_devices`` re-wraps
    and re-registers every device against the fresh runtime.
    """
    devices = list(_device_cache.values())
    _device_cache.clear()
    for dev in devices:
        agas.registry.unregister(dev.gid)


def get_all_devices(major: int = 0, minor: int = 0) -> "Future[list[Device]]":
    """Discover every (local and remote) device with capability >= (major,
    minor). Returns a *future* of the list — call ``.get()`` (Listing 1)."""

    def _discover() -> "list[Device]":
        out = []
        for jd in jax.devices():
            if capability_of(jd) >= (major, minor):
                out.append(_wrap(jd))
        return out

    return get_runtime().async_(_discover)


def get_all_localities(major: int = 0, minor: int = 0, cluster=None) -> "Future[list[Locality]]":
    """Group capability-filtered devices by owning process
    (``hpx::find_all_localities`` analogue); future of the list, ordered
    by process index with the local locality's devices first within it.
    With ``cluster`` (a ``Parcelport``), the port's remote localities are
    appended — the cluster-wide discovery surface."""

    def _group() -> "list[Locality]":
        by_proc: "dict[int, list[Device]]" = {}
        for dev in get_all_devices(major, minor).get():
            by_proc.setdefault(dev.process_index, []).append(dev)
        locs = [Locality(pi, devs) for pi, devs in sorted(by_proc.items())]
        if cluster is not None:
            for loc in cluster.localities():
                devs = [d for d in loc if d.capability() >= (major, minor)]
                if devs:
                    locs.append(Locality(loc.process_index, devs))
        return locs

    return get_runtime().async_(_group)
