"""Logical device abstraction (paper §4, Fig. 2 ``device``).

A ``Device`` wraps one ``jax.Device`` (local *or* remote — in
multi-controller JAX remote accelerators appear as non-addressable entries
of ``jax.devices()``) and exposes HPXCL's surface:

  * ``create_buffer``  — async allocation (``cudaMalloc`` analogue)
  * ``create_program`` — async program creation (NVRTC source analogue)
  * per-device work queues: ``ops`` (transfers/launch submission order) and
    ``compile`` (runtime compilation), separate so that building a kernel
    overlaps data transfers exactly as in Listing 2
  * ``synchronize``    — drain queues and block on outstanding arrays

``get_all_devices(major, minor)`` mirrors the paper's Listing 1: it returns
a *future* of the device list, filtered by a minimum capability.

Scheduler surface (DESIGN.md §9): ``Device.load()`` exposes the ops-queue
backlog and ``Device.resident_bytes()`` the AGAS byte total placed here —
the two signals the ``least_loaded`` and ``affinity`` placement policies
read.  ``Locality`` groups devices by owning process (HPX locality
analogue); ``get_all_localities()`` mirrors ``hpx::find_all_localities``.
"""
from __future__ import annotations

from typing import Any, Callable, Optional

import jax
import numpy as np

from repro.core import agas
from repro.core.executor import QueueLoad, WorkQueue, get_runtime
from repro.core.futures import Future

__all__ = ["Device", "Locality", "get_all_devices", "get_all_localities", "capability_of"]

# Pseudo "compute capability" per platform so the Listing-1 signature keeps
# meaning on TPU/CPU: (major, minor).
_PLATFORM_CAPABILITY = {
    "cpu": (1, 0),
    "gpu": (7, 0),
    "cuda": (7, 0),
    "rocm": (7, 0),
    "tpu": (9, 0),
}


def capability_of(jax_device: "jax.Device") -> "tuple[int, int]":
    return _PLATFORM_CAPABILITY.get(jax_device.platform, (1, 0))


class Device:
    """Location-transparent handle to one accelerator."""

    def __init__(self, jax_device: "jax.Device"):
        self.jax_device = jax_device
        self.key = f"{jax_device.platform}:{jax_device.id}"
        rt = get_runtime()
        # Two queues per device: ops (stream analogue) + compile (NVRTC).
        self.ops_queue: WorkQueue = rt.queue(f"ops:{self.key}")
        self.compile_queue: WorkQueue = rt.queue(f"compile:{self.key}")
        self.gid: agas.GID = agas.registry.register(
            self, agas.Placement(self.key, jax_device.process_index), kind="device"
        )

    # -- identity ----------------------------------------------------------

    @property
    def platform(self) -> str:
        return self.jax_device.platform

    @property
    def process_index(self) -> int:
        return self.jax_device.process_index

    @property
    def is_local(self) -> bool:
        return self.jax_device.process_index == jax.process_index()

    def capability(self) -> "tuple[int, int]":
        return capability_of(self.jax_device)

    # -- scheduler signals --------------------------------------------------

    def load(self) -> QueueLoad:
        """Ops-queue backlog snapshot (``least_loaded`` input)."""
        return self.ops_queue.load()

    def resident_bytes(self) -> int:
        """AGAS-registered bytes currently placed here (``affinity`` input)."""
        return agas.registry.resident_bytes(self.key)

    # -- factory surface (all async, returning futures) ---------------------

    def create_buffer(self, shape, dtype=np.float32, fill: Any = None) -> "Future":
        """Allocate a device buffer (async; ``cudaMalloc`` analogue).

        ``shape`` may be an int (1-D length in *elements*, not bytes — the
        dtype-safe adaptation of HPXCL's byte counts) or a tuple.
        """
        from repro.core.buffer import Buffer

        def _alloc():
            return Buffer._allocate(self, shape, dtype, fill)

        return self.ops_queue.submit(_alloc)

    def create_buffer_from(self, data) -> "Future":
        """Allocate + write in one async op."""
        from repro.core.buffer import Buffer

        def _alloc():
            arr = np.asarray(data)
            buf = Buffer._allocate(self, arr.shape, arr.dtype, None)
            buf._array = jax.device_put(arr, self.jax_device)
            return buf

        return self.ops_queue.submit(_alloc)

    def create_program(self, kernels, name: str = "program") -> "Future":
        """Create a program from ``{kernel_name: callable}`` (async)."""
        from repro.core.program import Program

        return self.compile_queue.submit(lambda: Program(self, kernels, name=name))

    def create_program_with_file(self, path: str) -> "Future":
        """Load kernels from a python file defining ``KERNELS`` (percolation:
        source code shipped to and compiled at the device — NVRTC analogue).
        """
        from repro.core.program import Program

        return self.compile_queue.submit(lambda: Program.from_file(self, path))

    # -- graph capture (CUDA Graphs analogue) --------------------------------

    def capture(self, name: str = "captured"):
        """Begin a graph-capture region on this thread (DESIGN.md §8).

        Transfers and launches recorded inside are fused and replayed with
        a single hop on this device's ops queue:

            with dev.capture("step") as g:
                buf.enqueue_write(0, host)
                prog.run([buf], "k", out=[out])
                r = out.enqueue_read()
            exe = g.instantiate()
            result = exe.replay().get()   # result[r] is the np.ndarray
        """
        from repro.core.graph import capture as _capture

        return _capture(name)

    # -- synchronization ----------------------------------------------------

    def synchronize(self) -> None:
        """Drain both queues (``cudaDeviceSynchronize`` analogue)."""
        self.ops_queue.drain()
        self.compile_queue.drain()

    def __repr__(self) -> str:
        where = "local" if self.is_local else "remote"
        return f"Device({self.key}, {where}, gid={self.gid})"


class Locality:
    """One process's worth of devices (the HPX *locality* analogue).

    In multi-controller JAX each participating process owns the devices
    whose ``process_index`` matches; scheduling across localities is what
    makes a placement "remote".
    """

    def __init__(self, process_index: int, devices: "list[Device]"):
        self.process_index = process_index
        self.devices = list(devices)

    @property
    def is_local(self) -> bool:
        return self.process_index == jax.process_index()

    def __len__(self) -> int:
        return len(self.devices)

    def __iter__(self):
        return iter(self.devices)

    def __repr__(self) -> str:
        where = "local" if self.is_local else "remote"
        return f"Locality(process={self.process_index}, {where}, {len(self.devices)} device(s))"


_device_cache: "dict[str, Device]" = {}


def _wrap(jd: "jax.Device") -> Device:
    key = f"{jd.platform}:{jd.id}"
    dev = _device_cache.get(key)
    if dev is None:
        dev = _device_cache[key] = Device(jd)
    return dev


def _on_runtime_reset() -> None:
    """Drop cached devices whose queues died with the old runtime.

    Called by ``executor.reset_runtime``: the cached ``Device``s hold
    ``WorkQueue``s from the runtime being torn down, so keeping them would
    make the next ``submit`` raise "WorkQueue ... is shut down".  Their
    AGAS records are retired too; the next ``get_all_devices`` re-wraps
    and re-registers every device against the fresh runtime.
    """
    devices = list(_device_cache.values())
    _device_cache.clear()
    for dev in devices:
        agas.registry.unregister(dev.gid)


def get_all_devices(major: int = 0, minor: int = 0) -> "Future[list[Device]]":
    """Discover every (local and remote) device with capability >= (major,
    minor). Returns a *future* of the list — call ``.get()`` (Listing 1)."""

    def _discover() -> "list[Device]":
        out = []
        for jd in jax.devices():
            if capability_of(jd) >= (major, minor):
                out.append(_wrap(jd))
        return out

    return get_runtime().async_(_discover)


def get_all_localities(major: int = 0, minor: int = 0) -> "Future[list[Locality]]":
    """Group capability-filtered devices by owning process
    (``hpx::find_all_localities`` analogue); future of the list, ordered
    by process index with the local locality's devices first within it."""

    def _group() -> "list[Locality]":
        by_proc: "dict[int, list[Device]]" = {}
        for dev in get_all_devices(major, minor).get():
            by_proc.setdefault(dev.process_index, []).append(dev)
        return [Locality(pi, devs) for pi, devs in sorted(by_proc.items())]

    return get_runtime().async_(_group)
