"""Logical device abstraction (paper §4, Fig. 2 ``device``).

A ``Device`` wraps one ``jax.Device`` (local *or* remote — in
multi-controller JAX remote accelerators appear as non-addressable entries
of ``jax.devices()``) and exposes HPXCL's surface:

  * ``create_buffer``  — async allocation (``cudaMalloc`` analogue)
  * ``create_program`` — async program creation (NVRTC source analogue)
  * per-device work queues: ``ops`` (transfers/launch submission order) and
    ``compile`` (runtime compilation), separate so that building a kernel
    overlaps data transfers exactly as in Listing 2
  * ``synchronize``    — drain queues and block on outstanding arrays

``get_all_devices(major, minor)`` mirrors the paper's Listing 1: it returns
a *future* of the device list, filtered by a minimum capability.

Scheduler surface (DESIGN.md §9): ``Device.load()`` exposes the ops-queue
backlog and ``Device.resident_bytes()`` the AGAS byte total placed here —
the two signals the ``least_loaded`` and ``affinity`` placement policies
read.  ``Locality`` groups devices by owning process (HPX locality
analogue); ``get_all_localities()`` mirrors ``hpx::find_all_localities``.

Remote proxies (DESIGN.md §10): ``RemoteDevice``/``RemoteBuffer`` are the
parcel-backed twins of ``Device``/``Buffer`` — same async surface, but
``create_buffer`` / ``enqueue_write`` / ``enqueue_read`` / ``free`` (and
launches, through ``RemoteProgram``) travel as parcels to the owning
locality and resolve the caller's futures from reply parcels.  A proxy's
``ops_queue`` is a real local ``WorkQueue``: it orders parcel submission
per remote device and feeds the same ``load()`` signal the placement
policies read for local devices.
"""
from __future__ import annotations

import weakref
from typing import Any, Callable, Optional

import jax
import numpy as np

from repro.core import agas
from repro.core.executor import QueueLoad, WorkQueue, get_runtime
from repro.core.futures import Future

__all__ = [
    "Device",
    "Locality",
    "RemoteDevice",
    "RemoteBuffer",
    "get_all_devices",
    "get_all_localities",
    "capability_of",
]

# Pseudo "compute capability" per platform so the Listing-1 signature keeps
# meaning on TPU/CPU: (major, minor).
_PLATFORM_CAPABILITY = {
    "cpu": (1, 0),
    "gpu": (7, 0),
    "cuda": (7, 0),
    "rocm": (7, 0),
    "tpu": (9, 0),
}


def capability_of(jax_device: "jax.Device") -> "tuple[int, int]":
    return _PLATFORM_CAPABILITY.get(jax_device.platform, (1, 0))


class Device:
    """Location-transparent handle to one accelerator."""

    def __init__(self, jax_device: "jax.Device"):
        self.jax_device = jax_device
        self.key = f"{jax_device.platform}:{jax_device.id}"
        rt = get_runtime()
        # Two queues per device: ops (stream analogue) + compile (NVRTC).
        self.ops_queue: WorkQueue = rt.queue(f"ops:{self.key}")
        self.compile_queue: WorkQueue = rt.queue(f"compile:{self.key}")
        self.gid: agas.GID = agas.registry.register(
            self, agas.Placement(self.key, jax_device.process_index), kind="device"
        )

    # -- identity ----------------------------------------------------------

    @property
    def platform(self) -> str:
        return self.jax_device.platform

    @property
    def process_index(self) -> int:
        return self.jax_device.process_index

    @property
    def is_local(self) -> bool:
        return self.jax_device.process_index == jax.process_index()

    def capability(self) -> "tuple[int, int]":
        return capability_of(self.jax_device)

    # -- scheduler signals --------------------------------------------------

    def load(self) -> QueueLoad:
        """Ops-queue backlog snapshot (``least_loaded`` input)."""
        return self.ops_queue.load()

    def resident_bytes(self) -> int:
        """AGAS-registered bytes currently placed here (``affinity`` input)."""
        return agas.registry.resident_bytes(self.key)

    # -- factory surface (all async, returning futures) ---------------------

    def create_buffer(self, shape, dtype=np.float32, fill: Any = None) -> "Future":
        """Allocate a device buffer (async; ``cudaMalloc`` analogue).

        ``shape`` may be an int (1-D length in *elements*, not bytes — the
        dtype-safe adaptation of HPXCL's byte counts) or a tuple.
        """
        from repro.core.buffer import Buffer

        def _alloc():
            return Buffer._allocate(self, shape, dtype, fill)

        return self.ops_queue.submit(_alloc)

    def create_buffer_from(self, data) -> "Future":
        """Allocate + write in one async op."""
        from repro.core.buffer import Buffer

        def _alloc():
            arr = np.asarray(data)
            buf = Buffer._allocate(self, arr.shape, arr.dtype, None)
            buf._array = jax.device_put(arr, self.jax_device)
            return buf

        return self.ops_queue.submit(_alloc)

    def create_program(self, kernels, name: str = "program") -> "Future":
        """Create a program from ``{kernel_name: callable}`` (async)."""
        from repro.core.program import Program

        return self.compile_queue.submit(lambda: Program(self, kernels, name=name))

    def create_program_with_file(self, path: str) -> "Future":
        """Load kernels from a python file defining ``KERNELS`` (percolation:
        source code shipped to and compiled at the device — NVRTC analogue).
        """
        from repro.core.program import Program

        return self.compile_queue.submit(lambda: Program.from_file(self, path))

    # -- graph capture (CUDA Graphs analogue) --------------------------------

    def capture(self, name: str = "captured"):
        """Begin a graph-capture region on this thread (DESIGN.md §8).

        Transfers and launches recorded inside are fused and replayed with
        a single hop on this device's ops queue:

            with dev.capture("step") as g:
                buf.enqueue_write(0, host)
                prog.run([buf], "k", out=[out])
                r = out.enqueue_read()
            exe = g.instantiate()
            result = exe.replay().get()   # result[r] is the np.ndarray
        """
        from repro.core.graph import capture as _capture

        return _capture(name)

    # -- synchronization ----------------------------------------------------

    def synchronize(self) -> None:
        """Drain both queues (``cudaDeviceSynchronize`` analogue)."""
        self.ops_queue.drain()
        self.compile_queue.drain()

    def __repr__(self) -> str:
        where = "local" if self.is_local else "remote"
        return f"Device({self.key}, {where}, gid={self.gid})"


class Locality:
    """One process's worth of devices (the HPX *locality* analogue).

    In multi-controller JAX each participating process owns the devices
    whose ``process_index`` matches; scheduling across localities is what
    makes a placement "remote".
    """

    def __init__(self, process_index: int, devices: "list[Device]"):
        self.process_index = process_index
        self.devices = list(devices)

    @property
    def is_local(self) -> bool:
        return self.process_index == jax.process_index()

    def __len__(self) -> int:
        return len(self.devices)

    def __iter__(self):
        return iter(self.devices)

    def __repr__(self) -> str:
        where = "local" if self.is_local else "remote"
        return f"Locality(process={self.process_index}, {where}, {len(self.devices)} device(s))"


# ---------------------------------------------------------------------------
# remote proxies (parcel-backed; DESIGN.md §10)
# ---------------------------------------------------------------------------


def _release_remote(port, locality_id: int, gid: int, proxied: bool) -> None:
    """GC finalizer for RemoteBuffer: retire the local proxy record (the
    resident-bytes accounting must not outlive the handle) and send a
    best-effort free parcel (never raises — the port or worker may already
    be gone at collection time)."""
    if proxied:
        agas.registry.unregister(gid)
    try:
        port.call(locality_id, "free", {"gid": gid})
    except Exception:  # noqa: BLE001
        pass


class RemoteDevice:
    """Parcel-backed handle to a device owned by another locality.

    Duck-types ``Device`` everywhere the runtime reads it: ``key``,
    ``ops_queue``/``compile_queue`` (real local queues — parcel submission
    order per remote device, and the scheduler's ``load()`` signal),
    ``load()``, ``resident_bytes()``, ``capability()``, plus ``alive()``
    (heartbeat-fed; a dead locality is excluded from placement).
    ``jax_device`` is a *local staging anchor*: values bound for this
    device are normalized onto it before they are shipped in a parcel.
    """

    is_remote_proxy = True

    def __init__(self, port, locality_id: int, remote_key: str, platform: str = "cpu",
                 capability: "tuple[int, int]" = (1, 0)):
        self._port = port
        self.locality_id = locality_id
        self.remote_key = remote_key
        self.key = f"L{locality_id}/{remote_key}"
        self._platform = platform
        self._capability = tuple(capability)
        self.jax_device = jax.devices()[0]  # staging anchor, not the executor
        rt = get_runtime()
        self.ops_queue: WorkQueue = rt.queue(f"parcel-ops:{self.key}")
        self.compile_queue: WorkQueue = rt.queue(f"parcel-compile:{self.key}")
        self.gid: agas.GID = agas.registry.register(
            self, agas.Placement(self.key, locality_id), kind="device"
        )

    # -- identity ----------------------------------------------------------

    @property
    def platform(self) -> str:
        return self._platform

    @property
    def process_index(self) -> int:
        return self.locality_id

    @property
    def is_local(self) -> bool:
        return False

    def capability(self) -> "tuple[int, int]":
        return self._capability

    # -- scheduler signals ---------------------------------------------------

    def load(self) -> QueueLoad:
        return self.ops_queue.load()

    def resident_bytes(self) -> int:
        return agas.registry.resident_bytes(self.key)

    def alive(self) -> bool:
        """Heartbeat verdict for the owning locality (scheduler exclusion)."""
        return self._port.alive(self.locality_id)

    # -- parcel plumbing -----------------------------------------------------

    def _call(self, action: str, **payload) -> "Future":
        """Send one action parcel, ordered through this device's ops queue
        (submission order across writes/launches/reads is the stream
        contract, exactly as for local devices)."""
        payload.setdefault("device", self.remote_key)
        port, loc = self._port, self.locality_id
        if not port.alive(loc):
            return Future.failed(RuntimeError(
                f"parcel {action!r} to locality L{loc} failed fast: the locality is dead "
                "(missed heartbeat or worker exit) and is excluded from placement"
            ))
        return self.ops_queue.submit(lambda: port.call_sync(loc, action, payload))

    # -- factory surface -----------------------------------------------------

    def create_buffer(self, shape, dtype=np.float32, fill: Any = None) -> "Future":
        """Allocate a buffer on the remote locality (async; the
        ``create_buffer`` action parcel)."""
        shape_p = list(shape) if isinstance(shape, (tuple, list)) else int(shape)
        fut = self._call("create_buffer", shape=shape_p, dtype=np.dtype(dtype).str, fill=fill)
        return fut.then(lambda rep: RemoteBuffer(self, rep["gid"], rep["shape"], rep["dtype"]),
                        executor="inline")

    def create_buffer_from(self, data) -> "Future":
        fut = self._call("create_buffer_from", data=np.asarray(data))
        return fut.then(lambda rep: RemoteBuffer(self, rep["gid"], rep["shape"], rep["dtype"]),
                        executor="inline")

    def create_program(self, kernels, name: str = "program") -> "Future":
        """Create a program on the remote locality.  ``kernels`` are
        *names* (str or list of str) resolved by the remote's kernel
        registry, or a ``{name: callable}`` dict whose callables stay
        local as shape-inference shadows (percolation by reference)."""
        from repro.core.program import RemoteProgram

        return self.compile_queue.submit(lambda: RemoteProgram(self, kernels, name=name))

    # -- graph capture -------------------------------------------------------

    def capture(self, name: str = "captured"):
        from repro.core.graph import capture as _capture

        return _capture(name)

    # -- synchronization -----------------------------------------------------

    def synchronize(self) -> None:
        self.ops_queue.drain()
        self.compile_queue.drain()

    def __repr__(self) -> str:
        state = "alive" if self.alive() else "DEAD"
        return f"RemoteDevice({self.key}, {state}, gid={self.gid})"


class RemoteBuffer:
    """Location-transparent handle to a buffer owned by another locality.

    The remote-minted GID is proxied into the local AGAS registry (with
    ``nbytes``), so placement policies score remote-resident bytes exactly
    like local ones.  Transfers are parcels: ``enqueue_write`` ships host
    data out, ``enqueue_read`` brings it back, ``copy_to`` chains the two
    (the explicit cross-locality percolation move).
    """

    is_remote_proxy = True
    is_remote_buffer = True

    def __init__(self, device: RemoteDevice, gid: int, shape, dtype):
        self.device = device
        self.gid = gid
        self.shape = tuple(shape)
        self.dtype = np.dtype(dtype)
        self._freed = False
        self._free_future: "Future | None" = None
        self._proxied = agas.registry.register_proxy(
            self, gid, agas.Placement(device.key, device.locality_id),
            kind="buffer", nbytes=self.nbytes,
        )
        self._finalizer = weakref.finalize(
            self, _release_remote, device._port, device.locality_id, gid, self._proxied
        )

    @property
    def size(self) -> int:
        return int(np.prod(self.shape)) if self.shape else 1

    @property
    def nbytes(self) -> int:
        return self.size * self.dtype.itemsize

    # -- async transfer surface ----------------------------------------------

    def enqueue_write(self, offset: int, data, count: "int | None" = None) -> "Future":
        from repro.core.graph import current_graph

        if current_graph() is not None:
            raise NotImplementedError(
                "graph capture writes to local buffers only; stage remote "
                "transfers outside the capture region (remote buffers may be "
                "read as extern inputs)"
            )
        return self.device._call("enqueue_write", gid=self.gid, offset=offset,
                                 data=np.asarray(data), count=count)

    def enqueue_read(self, offset: int = 0, count: "int | None" = None) -> "Future":
        from repro.core.graph import current_graph

        g = current_graph()
        if g is not None:
            return g.read(self, offset=offset, count=count)
        return self.device._call("enqueue_read", gid=self.gid, offset=offset, count=count)

    def enqueue_read_sync(self, offset: int = 0, count: "int | None" = None):
        from repro.core.graph import current_graph

        if current_graph() is not None:
            raise RuntimeError(
                "enqueue_read_sync inside a graph-capture region: the value "
                "does not exist until replay. Use enqueue_read()."
            )
        return self.enqueue_read(offset, count).get()

    def _read_now(self) -> np.ndarray:
        """Synchronous read bypassing the proxy queue — for callers already
        running ON this device's ops queue (graph extern reads), where an
        ``enqueue_read`` would deadlock behind the calling task."""
        return self.device._port.call_sync(
            self.device.locality_id,
            "enqueue_read",
            {"device": self.device.remote_key, "gid": self.gid, "offset": 0, "count": None},
        )

    def copy_to(self, target_device) -> "Future":
        """Percolation across localities: one read parcel here, one write
        on the target — future of the *new* buffer on ``target_device``."""
        if target_device is self.device:
            return Future.ready(self)
        pool = get_runtime().pool
        return self.enqueue_read().then(
            lambda host: target_device.create_buffer_from(host).get(),
            executor=pool,
            name=f"copy:gid{self.gid}",
        )

    # -- lifetime --------------------------------------------------------------

    def free(self) -> "Future":
        if self._free_future is None:
            self._freed = True
            if self._finalizer is not None:
                self._finalizer.detach()
                self._finalizer = None
            if self._proxied:
                agas.registry.unregister(self.gid)
            self._free_future = self.device._call("free", gid=self.gid)
        return self._free_future

    # -- kernel-facing view ----------------------------------------------------

    def array(self):
        raise RuntimeError(
            f"RemoteBuffer gid={self.gid} lives on locality "
            f"L{self.device.locality_id}; its value is not addressable here — "
            "use enqueue_read() (or launch through a RemoteProgram on that locality)"
        )

    def __repr__(self) -> str:
        return f"RemoteBuffer(gid={self.gid}, {self.dtype}{list(self.shape)} @ {self.device.key})"


_device_cache: "dict[str, Device]" = {}


def _wrap(jd: "jax.Device") -> Device:
    key = f"{jd.platform}:{jd.id}"
    dev = _device_cache.get(key)
    if dev is None:
        dev = _device_cache[key] = Device(jd)
    return dev


def _on_runtime_reset() -> None:
    """Drop cached devices whose queues died with the old runtime.

    Called by ``executor.reset_runtime``: the cached ``Device``s hold
    ``WorkQueue``s from the runtime being torn down, so keeping them would
    make the next ``submit`` raise "WorkQueue ... is shut down".  Their
    AGAS records are retired too; the next ``get_all_devices`` re-wraps
    and re-registers every device against the fresh runtime.
    """
    devices = list(_device_cache.values())
    _device_cache.clear()
    for dev in devices:
        agas.registry.unregister(dev.gid)


def get_all_devices(major: int = 0, minor: int = 0) -> "Future[list[Device]]":
    """Discover every (local and remote) device with capability >= (major,
    minor). Returns a *future* of the list — call ``.get()`` (Listing 1)."""

    def _discover() -> "list[Device]":
        out = []
        for jd in jax.devices():
            if capability_of(jd) >= (major, minor):
                out.append(_wrap(jd))
        return out

    return get_runtime().async_(_discover)


def get_all_localities(major: int = 0, minor: int = 0, cluster=None) -> "Future[list[Locality]]":
    """Group capability-filtered devices by owning process
    (``hpx::find_all_localities`` analogue); future of the list, ordered
    by process index with the local locality's devices first within it.
    With ``cluster`` (a ``Parcelport``), the port's remote localities are
    appended — the cluster-wide discovery surface."""

    def _group() -> "list[Locality]":
        by_proc: "dict[int, list[Device]]" = {}
        for dev in get_all_devices(major, minor).get():
            by_proc.setdefault(dev.process_index, []).append(dev)
        locs = [Locality(pi, devs) for pi, devs in sorted(by_proc.items())]
        if cluster is not None:
            for loc in cluster.localities():
                devs = [d for d in loc if d.capability() >= (major, minor)]
                if devs:
                    locs.append(Locality(loc.process_index, devs))
        return locs

    return get_runtime().async_(_group)
