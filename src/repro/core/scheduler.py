"""Locality-aware placement scheduler (DESIGN.md §9).

The paper's headline — "any user defined CUDA kernel can be launched on
any (local or remote) GPU device" — needs a layer that *chooses* the
device.  HPXCL leaves placement to the caller; StarPU and Specx showed
that a task-based runtime earns its keep through pluggable scheduling
policies sitting between submission and heterogeneous workers.  This
module is that layer for our runtime: a ``Scheduler`` holds the device
fleet and a ``PlacementPolicy`` maps each task (its argument buffers) to
one device.

Policies
--------
``static``       pin everything to one device (HPXCL's implicit policy —
                 the baseline every other policy is measured against).
``round_robin``  cycle through the fleet regardless of state.
``least_loaded`` pick the device whose ops queue has the smallest
                 backlog (``WorkQueue.load()`` depth); ties rotate, so a
                 blind signal degrades to round-robin, never a pile-up.
``affinity``     pick the device already holding the most argument bytes
                 (AGAS placement records / resident-bytes reverse index),
                 minimizing percolation traffic; load breaks ties.
``percolation``  score the full ``localities × devices`` grid by the
                 *bytes that would have to move* if the task ran there —
                 a cross-locality move (an explicit transfer parcel pair,
                 DESIGN.md §10) costs a configurable multiple of an
                 intra-locality copy; load breaks ties.  This is the
                 cluster-aware generalization of ``affinity``.

Liveness (DESIGN.md §10): devices exposing ``alive()`` (remote proxies,
fed by the parcelport heartbeat) are excluded from placement while dead —
a locality whose worker missed its deadline never receives new work, and
``select`` raises descriptively when the whole fleet is gone.

The policy input is deliberately duck-typed: an argument counts toward
affinity if it exposes ``device``/``nbytes`` (our ``Buffer``) or is a
committed ``jax.Array`` — so policies are unit-testable with fakes and
serve-path fan-out can score raw arrays.

``Program.run_on_any`` routes launches through the default scheduler
(``get_scheduler()``); serving fan-out (``repro.serving``) and the fig6
benchmark use the same object, so one placement decision layer sees all
traffic.

Continuous rebalancing (DESIGN.md §14): placement used to be one-shot —
a decision made at submit time was never revisited, so one slow lane
stranded its queue while siblings idled (the negative-scaling fig6).
The scheduler now keeps a per-device *pending deque* in front of the
device lanes and runs one *pump* per device on the host pool.  A pump
drains its own deque head-first (FIFO for the owner); when it runs dry
it STEALS from the tail of the deepest sibling backlog,
dask-distributed-style — tail-stealing preserves the victim's head-of-
queue FIFO order, and eligibility is gated on the task's argument bytes
versus the migration cost (``REPRO_STEAL_MAX_BYTES``, divided by the
cross-locality cost factor when the steal crosses a parcel boundary).
A stolen launch re-binds to the thief through the same per-device
sibling-program mechanism ``run_on_any`` uses; its buffers re-home
through the existing percolation machinery, and cross-locality steals
batch their argument fetches into one ``steal_fetch`` parcel (shm lane
for large arrays).  ``REPRO_STEAL=off`` restores one-shot placement.

Memory-aware placement (also §14): devices whose AGAS resident-bytes
would exceed their threshold (``Device.memory_limit`` /
``REPRO_SPILL_BYTES``) are vetoed as placement candidates; when every
candidate is over threshold the pick goes through anyway and the
least-recently-used buffers on the chosen device are spilled to host
RAM (``Buffer.spill``; refetch on next use is transparent).
"""
from __future__ import annotations

import os
import threading
import time as _time
from collections import deque
from dataclasses import replace as _dc_replace
from typing import Any, Callable, Optional, Sequence

__all__ = [
    "PlacementPolicy",
    "StaticPolicy",
    "RoundRobinPolicy",
    "LeastLoadedPolicy",
    "AffinityPolicy",
    "PercolationPolicy",
    "Scheduler",
    "get_scheduler",
    "set_scheduler",
    "make_policy",
    "locality_of_key",
    "POLICIES",
]


def locality_of_key(key: "str | None") -> int:
    """Locality id encoded in a device key (``L3/cpu:0`` -> 3; local
    keys -> 0)."""
    if key and key.startswith("L"):
        head, sep, _ = key.partition("/")
        if sep:
            try:
                return int(head[1:])
            except ValueError:
                return 0
    return 0


def _is_alive(device: Any) -> bool:
    alive = getattr(device, "alive", None)
    return True if alive is None else bool(alive())


def _arg_home(arg: Any) -> "tuple[str | None, int]":
    """(device_key, nbytes) of ``arg``'s resident storage, or (None, 0).

    Buffers resolve through their AGAS placement record (the handle may
    have been re-homed by percolation); committed ``jax.Array``s through
    their sharding (checked before the duck-typed fallback — a jax.Array
    has ``.device``/``.nbytes`` too, but its device has no ``.key``).
    Anything else contributes nothing.
    """
    nbytes = getattr(arg, "nbytes", None)
    if nbytes is None:
        return None, 0
    if hasattr(arg, "gid") and getattr(arg, "device", None) is not None:  # Buffer
        from repro.core import agas

        try:
            return agas.registry.placement(arg.gid).device_key, int(nbytes)
        except KeyError:
            return getattr(arg.device, "key", None), int(nbytes)
    devices = getattr(arg, "devices", None)
    if callable(devices):  # committed jax.Array
        try:
            keys = {f"{d.platform}:{d.id}" for d in devices()}
        except Exception:  # noqa: BLE001 - uncommitted/abstract arrays
            return None, 0
        if len(keys) == 1:
            return next(iter(keys)), int(nbytes)
        return None, 0
    key = getattr(getattr(arg, "device", None), "key", None)  # duck-typed fake
    return (key, int(nbytes)) if key is not None else (None, 0)


def _device_load(device):
    """Backlog snapshot for placement: ``device.load()`` when the device
    aggregates per-lane depths across its streams (DESIGN.md §11 — a
    device busy on three lanes is three deep), else the bare ops queue
    (duck-typed fakes and plain queue holders)."""
    ld = getattr(device, "load", None)
    if callable(ld):
        return ld()
    return device.ops_queue.load()


def _occupancy(device) -> float:
    """The honest load score (DESIGN.md §14): backlog depth plus the
    exponentially-decayed recent busy time (``QueueLoad.busy_ewma``).
    Depth alone is stale by the time a batch lands — a device that just
    finished a long task and one that sat idle both report depth 0; the
    decayed busy term separates them without the never-forgets bias of
    the lifetime ``busy_time`` total."""
    l = _device_load(device)
    return l.depth + getattr(l, "busy_ewma", 0.0)


def _load_score(device) -> float:
    # Quantized to half-tau steps so NEAR-equal devices compare EQUAL
    # and the tie-rotation can see the tie.  The busy-ewma term is
    # *history*: scoring sub-half-tau deltas would pile a whole
    # depth-blind submit burst (launches that enqueue only after their
    # percolation copies resolve) onto whichever device was momentarily
    # idlest — and that device's now-elevated history shifts the NEXT
    # burst wholesale onto a sibling, oscillating forever.  A device
    # must have been busy for >25% of the decay window to lose a tie;
    # depth is integral, so real backlog differences always survive.
    return round(_occupancy(device) * 2.0) / 2.0


def _rotate_pick(policy, devices, scores):
    """Min-score pick with ROTATING tie-break: equal-score devices take
    turns (per-policy counter) instead of resolving by ``min()``'s
    stable-first order, which pins every cold-start/coalesced-window tie
    to device 0."""
    lo = min(scores)
    tied = [i for i, s in enumerate(scores) if s == lo]
    with policy._lock:
        pick = tied[policy._rr % len(tied)]
        policy._rr += 1
    return devices[pick]


class PlacementPolicy:
    """Maps (args, devices) -> one device.  Stateless unless noted."""

    name = "base"

    def select(self, devices: Sequence, args: Sequence = (), program=None):
        raise NotImplementedError

    def select_batch(self, devices: Sequence, batch_args: "Sequence[Sequence]" = (),
                     program=None):
        """Place one *micro-batch* of requests as a unit (the serving
        engine's hook, DESIGN.md §12): ``batch_args`` is one arg sequence
        per member request.  The default flattens every member's args into
        a single scoring set, so ``affinity``/``percolation`` weigh the
        whole batch's resident bytes (a batch is placed where MOST of its
        KV bytes already live) and load policies see one decision, not N.
        Policies with batch-specific knowledge can override."""
        flat = [a for args in batch_args for a in args]
        return self.select(devices, args=flat, program=program)


class StaticPolicy(PlacementPolicy):
    """Everything on one device (HPXCL's hand-placement, as a policy)."""

    name = "static"

    def __init__(self, index: int = 0):
        self.index = index

    def select(self, devices, args=(), program=None):
        return devices[self.index % len(devices)]


class RoundRobinPolicy(PlacementPolicy):
    """Cycle through the fleet; stateful (one counter, lock-protected)."""

    name = "round_robin"

    def __init__(self):
        self._next = 0
        self._lock = threading.Lock()

    def select(self, devices, args=(), program=None):
        with self._lock:
            i = self._next
            self._next = i + 1
        return devices[i % len(devices)]


class LeastLoadedPolicy(PlacementPolicy):
    """Smallest device occupancy wins: backlog depth summed across every
    stream lane of the device (``Device.load()``, DESIGN.md §11) PLUS the
    exponentially-decayed recent busy time (DESIGN.md §14) — so a device
    that just spent 200ms inside a launch scores above one that sat idle,
    even though both report depth 0 between batches.  Ties ROTATE through
    the tied devices (stateful counter), so when the whole signal is
    blind — e.g. percolating launches enqueue only after their copies
    resolve — the policy degrades to round-robin spread, never to piling
    everything on one historically-favored device.  Before rotating, a
    tie is narrowed by data locality: if some tied device already holds
    argument bytes, placing anywhere else buys nothing (same load) and
    costs a percolation copy, so the launch stays with its bytes."""

    name = "least_loaded"

    def __init__(self):
        self._rr = 0
        self._lock = threading.Lock()

    def select(self, devices, args=(), program=None):
        scores = [_load_score(d) for d in devices]
        lo = min(scores)
        tied = [i for i, s in enumerate(scores) if s == lo]
        if len(tied) > 1 and args:
            bytes_at: "dict[str, int]" = {}
            for a in args:
                key, nb = _arg_home(a)
                if key is not None and nb:
                    bytes_at[key] = bytes_at.get(key, 0) + nb
            best = max((bytes_at.get(getattr(devices[i], "key", None), 0)
                        for i in tied), default=0)
            if best > 0:
                tied = [i for i in tied
                        if bytes_at.get(getattr(devices[i], "key", None), 0) == best]
        with self._lock:
            pick = tied[self._rr % len(tied)]
            self._rr += 1
        return devices[pick]


class AffinityPolicy(PlacementPolicy):
    """Most argument bytes already resident wins (percolation avoidance);
    among equally-good hosts the least-loaded one is chosen, so a fleet
    with no resident data degrades to ``least_loaded``."""

    name = "affinity"

    def __init__(self):
        self._fallback = LeastLoadedPolicy()
        self._rr = 0
        self._lock = threading.Lock()

    def select(self, devices, args=(), program=None):
        # Resolve every arg's placement ONCE (one AGAS lookup per arg),
        # then score devices against the aggregated bytes-per-key map.
        resident: "dict[str, int]" = {}
        for a in args:
            key, nb = _arg_home(a)
            if key is not None and nb:
                resident[key] = resident.get(key, 0) + nb
        if not resident:
            return self._fallback.select(devices, args=args, program=program)
        scores = [(-resident.get(d.key, 0), _load_score(d)) for d in devices]
        return _rotate_pick(self, devices, scores)


class PercolationPolicy(PlacementPolicy):
    """Minimize percolation traffic over the ``localities × devices`` grid.

    Each candidate device is charged the bytes every argument would have
    to move to reach it: nothing when the bytes are already there, 1x for
    an intra-locality copy, ``cross_locality_cost``x when the move crosses
    a locality boundary (an explicit read-parcel + write-parcel pair over
    the transport, DESIGN.md §10).  Ties break by queue load; with no
    resident argument bytes at all the policy degrades to ``least_loaded``.
    """

    name = "percolation"

    def __init__(self, cross_locality_cost: float = 8.0):
        self.cross_locality_cost = float(cross_locality_cost)
        self._fallback = LeastLoadedPolicy()
        self._rr = 0
        self._lock = threading.Lock()

    def select(self, devices, args=(), program=None):
        homes: "list[tuple[str, int, int]]" = []
        for a in args:
            key, nb = _arg_home(a)
            if key is not None and nb:
                homes.append((key, locality_of_key(key), nb))
        if not homes:
            return self._fallback.select(devices, args=args, program=program)

        def score(dev):
            dev_loc = locality_of_key(dev.key)
            cost = 0.0
            for key, loc, nb in homes:
                if key == dev.key:
                    continue
                cost += nb * (self.cross_locality_cost if loc != dev_loc else 1.0)
            return (cost, _load_score(dev))

        return _rotate_pick(self, devices, [score(d) for d in devices])


POLICIES: "dict[str, Callable[[], PlacementPolicy]]" = {
    "static": StaticPolicy,
    "round_robin": RoundRobinPolicy,
    "least_loaded": LeastLoadedPolicy,
    "affinity": AffinityPolicy,
    "percolation": PercolationPolicy,
}


def make_policy(policy: "str | PlacementPolicy") -> PlacementPolicy:
    if isinstance(policy, PlacementPolicy):
        return policy
    try:
        return POLICIES[policy]()
    except KeyError:
        raise ValueError(f"unknown placement policy {policy!r}; have {sorted(POLICIES)}") from None


class _LoadView:
    """Policy-facing device view that charges the device for work THIS
    scheduler knows about but the lanes may not show yet: the steal-pool
    pending backlog, plus the decayed recent-placement count (a launch
    placed a moment ago enqueues only after its percolation copies
    resolve — dask's assigned-but-not-started occupancy).  A launch that
    HAS reached a lane is in both its depth and the recency counter, so
    the two signals combine as ``max(depth + pending, recent)`` — a
    floor on outstanding work, never a double charge.  Everything else
    forwards to the wrapped device."""

    __slots__ = ("_dev", "_pending", "_recent")

    def __init__(self, dev, pending: int = 0, recent: float = 0.0):
        self._dev = dev
        self._pending = pending
        self._recent = recent

    def load(self):
        l = _device_load(self._dev)
        extra = self._pending + max(0.0, self._recent - (l.depth + self._pending))
        if not extra:
            return l
        try:
            return _dc_replace(l, depth=l.depth + extra,
                               submitted=l.submitted + extra)
        except TypeError:  # duck-typed fake load object
            return l

    def __getattr__(self, name):
        return getattr(self._dev, name)

    def __repr__(self) -> str:
        return f"_LoadView({self._dev!r}, +{self._pending}, ~{self._recent:.2f})"


def _unwrap(dev):
    return dev._dev if isinstance(dev, _LoadView) else dev


class _PendingLaunch:
    """One launch parked in the steal pool (``Scheduler.submit``)."""

    __slots__ = ("program", "args", "kernel", "grid", "block", "out", "sync",
                 "promise", "nbytes", "home_key", "stolen")

    def __init__(self, program, args, kernel, grid, block, out, sync, promise,
                 nbytes, home_key):
        self.program = program
        self.args = args
        self.kernel = kernel
        self.grid = grid
        self.block = block
        self.out = out
        self.sync = sync
        self.promise = promise
        self.nbytes = nbytes
        self.home_key = home_key
        self.stolen = False


class Scheduler:
    """Placement decisions over a device fleet.

    ``devices=None`` discovers the fleet lazily (all devices, Listing 1)
    on first use, so the default scheduler works before/without explicit
    setup.  ``select`` returns the chosen ``Device`` and records the
    decision in per-device placement counters (``stats()``), which the
    integration tests and fig6 use to verify spread.

    With stealing enabled (the default; ``REPRO_STEAL=off`` or
    ``steal=False`` disables) ``submit`` parks launches in per-device
    pending deques drained by one pump per device — see the module
    docstring for the rebalancing protocol.  ``spill_bytes`` (or
    ``REPRO_SPILL_BYTES`` via ``Device.memory_limit``) arms the
    memory-aware veto + LRU spill.
    """

    def __init__(self, devices: "Sequence | None" = None,
                 policy: "str | PlacementPolicy" = "least_loaded",
                 steal: "bool | None" = None,
                 spill_bytes: "int | None" = None,
                 steal_max_bytes: "int | None" = None):
        self.policy = make_policy(policy)
        self._devices: "list | None" = list(devices) if devices is not None else None
        self._placements: "dict[str, int]" = {}
        self._lock = threading.Lock()
        if steal is None:
            steal = os.environ.get("REPRO_STEAL", "auto").lower() != "off"
        self._steal = bool(steal)
        if steal_max_bytes is None:
            steal_max_bytes = int(os.environ.get("REPRO_STEAL_MAX_BYTES", str(32 << 20)))
        self._steal_max_bytes = int(steal_max_bytes)
        self._spill_bytes = spill_bytes  # None -> per-device memory_limit
        self._cross_penalty = 8  # migration-cost multiple of a parcel-pair move
        # Steal pool: device key -> deque of _PendingLaunch; one pump flag
        # per device.  One lock covers both (operations are O(fleet)).
        self._pump_lock = threading.Lock()
        self._pending: "dict[str, deque]" = {}
        self._pumping: "set[str]" = set()
        self._steals = 0
        self._cross_steals = 0
        # Cordoned devices: excluded from placement like heartbeat-dead
        # localities, but by explicit request (fault injection / drains)
        # rather than liveness.  Waived only when it would empty the fleet.
        self._cordoned: "set[str]" = set()
        # Decayed recent-placement counters (device key -> (count, stamp)):
        # a launch placed a moment ago may not show in the device's lane
        # depth yet (percolating launches enqueue only after their copies
        # resolve), so the load views charge each device for what THIS
        # scheduler just sent it — dask's assigned-but-not-started
        # occupancy.  Decays with the busy-signal half-life.
        self._recent: "dict[str, tuple[float, float]]" = {}

    def devices(self) -> list:
        devs = self._devices
        if devs is None:
            from repro.core.device import get_all_devices

            devs = self._devices = list(get_all_devices().get())
        if not devs:
            raise RuntimeError("Scheduler has no devices to place on")
        return devs

    def _live(self) -> list:
        devs = self.devices()
        # Heartbeat exclusion: a locality whose worker died takes no new
        # placements — its devices report alive() False until recovery,
        # and alive() is re-read on EVERY decision, so a recovered
        # (un-latched) locality re-enters the fleet immediately.
        live = [d for d in devs if _is_alive(d)]
        if not live:
            raise RuntimeError(
                "Scheduler has no live devices: every locality in the fleet "
                "is dead (missed heartbeat or worker exit)"
            )
        if self._cordoned:
            open_devs = [d for d in live if d.key not in self._cordoned]
            if open_devs:  # an all-cordoned fleet waives the cordon
                return open_devs
        return live

    def cordon(self, device_key: str) -> None:
        """Exclude ``device_key`` from new placements (drain / fault
        injection).  Unlike heartbeat death this is an explicit operator
        decision; in-flight work on the device is untouched."""
        with self._lock:
            self._cordoned.add(device_key)

    def uncordon(self, device_key: str) -> None:
        with self._lock:
            self._cordoned.discard(device_key)

    def _record(self, dev):
        from repro.core import executor

        now = _time.monotonic()
        hl = executor._LOAD_HALFLIFE
        with self._lock:
            self._placements[dev.key] = self._placements.get(dev.key, 0) + 1
            count, stamp = self._recent.get(dev.key, (0.0, now))
            self._recent[dev.key] = (count * 2.0 ** (-(now - stamp) / hl) + 1.0, now)
        return dev

    def charge(self, dev, n: float = 1.0) -> None:
        """Add ``n`` extra units to ``dev``'s decayed recent-placement
        counter WITHOUT logging a placement.  ``select_batch`` records one
        unit per batch decision, which under-weights a 32-row decode burst
        against a 1-row one; the serving engine charges ``rows - 1`` here
        after dispatch so ``least_loaded`` sees the burst's true size (the
        direct-jit route never touches a lane queue until the batch is
        already running, so the recency counter is its only load signal)."""
        if n <= 0:
            return
        from repro.core import executor

        now = _time.monotonic()
        hl = executor._LOAD_HALFLIFE
        with self._lock:
            count, stamp = self._recent.get(dev.key, (0.0, now))
            self._recent[dev.key] = (
                count * 2.0 ** (-(now - stamp) / hl) + float(n), now)

    def _recent_extras(self) -> "dict[str, float]":
        from repro.core import executor

        now = _time.monotonic()
        hl = executor._LOAD_HALFLIFE
        out = {}
        with self._lock:
            for key, (count, stamp) in self._recent.items():
                c = count * 2.0 ** (-(now - stamp) / hl)
                if c > 0.05:
                    out[key] = c
        return out

    def occupancy(self, dev, *, recent: bool = True) -> float:
        """Honest occupancy of one device as placement sees it: lane
        depth + decayed busy time, plus the steal-pool backlog and this
        scheduler's decayed recent placements unless ``recent=False``.

        ``recent=False`` is the hysteresis probe for sticky placement
        (``select_batch(prefer=...)``): a caller deciding whether a
        sticky home must yield compares *structural* load only, because
        the recent-placement counter on the home is mostly the caller's
        own just-charged work — scoring it would repel every micro-batch
        from the device it just warmed (self-repulsion), which is the
        exact spray the sticky hint exists to stop."""
        pending = 0
        if self._steal:
            with self._pump_lock:
                dq = self._pending.get(dev.key)
                pending = len(dq) if dq else 0
        extra = self._recent_extras().get(dev.key, 0.0) if recent else 0.0
        return _occupancy(_LoadView(dev, pending, extra))

    # -- memory-aware placement (DESIGN.md §14) ------------------------------

    def _limit_of(self, dev) -> int:
        if self._spill_bytes is not None:
            return int(self._spill_bytes)
        return int(getattr(dev, "memory_limit", 0) or 0)

    @staticmethod
    def _resident_of(dev) -> int:
        rb = getattr(dev, "resident_bytes", None)
        if callable(rb):
            try:
                return int(rb())
            except Exception:  # noqa: BLE001 - advisory signal only
                return 0
        return 0

    def _fit_memory(self, devs: list, args: Sequence) -> list:
        """Drop candidates whose resident bytes plus the task's incoming
        (not-already-there) argument bytes exceed their threshold.  When
        nothing fits the full list is returned — the pick then triggers
        an LRU spill instead of failing placement."""
        limits = [self._limit_of(d) for d in devs]
        if not any(limits):
            return devs
        homes = [_arg_home(a) for a in args]
        fits = []
        for d, lim in zip(devs, limits):
            if not lim:
                fits.append(d)
                continue
            incoming = sum(nb for key, nb in homes if nb and key != d.key)
            if self._resident_of(d) + incoming <= lim:
                fits.append(d)
        return fits or devs

    def _maybe_spill(self, dev, args: Sequence) -> None:
        """After placing on ``dev``: if the task pushes it over threshold,
        evict LRU buffers (asynchronously, on the device's default stream)
        until the incoming bytes fit.  The task's own arguments are never
        evicted."""
        lim = self._limit_of(dev)
        if not lim:
            return
        homes = [_arg_home(a) for a in args]
        incoming = sum(nb for key, nb in homes if nb and key != dev.key)
        need = self._resident_of(dev) + incoming - lim
        if need > 0:
            keep = {a.gid for a in args if hasattr(a, "gid")}
            self.spill_lru(dev, need, keep=keep)

    def spill_lru(self, dev, need_bytes: int, keep=()) -> list:
        """Submit spills of the least-recently-used buffers resident on
        ``dev`` until ``need_bytes`` are on their way to host RAM; returns
        the spill futures (each resolves True when storage is released).
        Buffers whose GID is in ``keep`` are never evicted."""
        from repro.core import agas

        keep = set(keep)
        cands = []
        for gid in agas.registry.gids_on(dev.key, kind="buffer"):
            if gid in keep:
                continue
            try:
                b = agas.registry.resolve(gid)
            except KeyError:
                continue
            if callable(getattr(b, "spill", None)):
                cands.append(b)
        cands.sort(key=lambda b: getattr(b, "_last_use", 0.0))
        futs, freed = [], 0
        for b in cands:
            if freed >= need_bytes:
                break
            futs.append(b.spill())
            freed += b.nbytes
        return futs

    # -- placement ------------------------------------------------------------

    def _views(self, devs: list) -> list:
        pending = {}
        if self._steal:
            with self._pump_lock:
                pending = {k: len(dq) for k, dq in self._pending.items() if dq}
        recent = self._recent_extras()
        if not pending and not recent:
            return devs
        out = []
        for d in devs:
            p = pending.get(d.key, 0)
            r = recent.get(d.key, 0.0)
            out.append(_LoadView(d, p, r) if (p or r) else d)
        return out

    def select(self, args: Sequence = (), program=None):
        cands = self._fit_memory(self._live(), args)
        dev = _unwrap(self.policy.select(self._views(cands), args=args, program=program))
        self._maybe_spill(dev, args)
        return self._record(dev)

    def select_batch(self, batch_args: "Sequence[Sequence]" = (), program=None,
                     prefer: "str | None" = None, prefer_slack: float = 16.0):
        """One placement decision for a whole micro-batch of requests
        (``PlacementPolicy.select_batch``): the engine hands every member
        request's argument leaves, the policy scores them as a unit, and
        the decision is logged once in ``stats()``.  The batch sees the
        same memory veto and pending-backlog-aware load views as single
        launches — one signal for all traffic.

        ``prefer`` is a sticky-home hint (device key): under a pure load
        policy, the recent-placement charge a batch deposits makes the
        NEXT batch of the same route score its own home as busy and hop
        devices — consecutive micro-batches of one request stream spray
        across the fleet, churning per-device executable caches (the
        fig9 batched fan-out regression).  When the policy is
        ``least_loaded`` and the preferred device is alive, un-vetoed and
        within ``prefer_slack`` of the policy's pick on *recent-free*
        occupancy (depth + busy only — see ``occupancy``), the batch
        stays home.  The slack is in units of queued submissions: a
        burst legitimately parks its whole in-flight window (engine
        ``max_batch`` x queued micro-batches, each ~100us of work) on
        its home lane, while hopping costs an executable-cache warmup
        worth tens of milliseconds — hundreds of micro-batches.  So the
        slack is sized well past any burst window, and only a backlog
        comparable to the warmup cost itself justifies the move.  A genuinely backed-up home (queued work
        the pick does not have, beyond that slack) still yields, so
        loaded fleets fan out — and this structural yield runs on every
        placement, so it is also the mechanism by which a sticky stream
        eventually re-homes.  Spread
        policies (round_robin/static) and byte-aware policies
        (affinity/percolation) ignore the hint — their placement is the
        point.  The *recorded* placement is always the device actually
        chosen, so ``stats()`` stays honest."""
        flat = [a for args in batch_args for a in args]
        live = self._live()
        if prefer is not None and self.policy.name == "least_loaded":
            # Fast path: any pick's recent-free occupancy is >= 0, so a
            # home within the slack of ZERO holds no matter what the
            # policy would have chosen — skip scoring the whole fleet
            # (memory fit + lock-guarded occupancy per device), which
            # otherwise taxes every held batch ~linearly in fleet size.
            home = next((d for d in live if d.key == prefer), None)
            if (home is not None
                    and self.occupancy(home, recent=False) <= prefer_slack
                    and self._fit_memory([home], flat)):
                self._maybe_spill(home, flat)
                return self._record(home)
        cands = self._fit_memory(live, flat)
        dev = _unwrap(
            self.policy.select_batch(self._views(cands), batch_args=batch_args, program=program)
        )
        if prefer is not None and dev.key != prefer and self.policy.name == "least_loaded":
            home = next((d for d in cands if d.key == prefer), None)
            if home is not None and (
                self.occupancy(home, recent=False)
                <= self.occupancy(dev, recent=False) + prefer_slack
            ):
                dev = home
        self._maybe_spill(dev, flat)
        return self._record(dev)

    # -- steal pool (DESIGN.md §14) -------------------------------------------

    @property
    def steals(self) -> bool:
        """True when launches should route through the rebalancing pool
        (stealing enabled AND more than one device to balance across)."""
        if not self._steal:
            return False
        try:
            return len(self.devices()) > 1
        except RuntimeError:
            return False

    def pending_depth(self, key: str) -> int:
        with self._pump_lock:
            dq = self._pending.get(key)
            return len(dq) if dq else 0

    def submit(self, program, args: Sequence = (), kernel: "str | None" = None, *,
               grid=None, block=None, out=None, sync: str = "ready"):
        """Schedule a kernel launch through the rebalancing pool: place it
        (same decision ``select`` would make, with the pending backlog
        folded into the load signal), park it on the chosen device's
        pending deque, and return a future of the launch result.  Idle
        sibling pumps may steal it off the tail before the owner gets
        there; results are identical either way (the stolen launch runs
        through the thief's sibling program and its buffers re-home)."""
        from repro.core.futures import Promise

        dev = self.select(args=args, program=program)
        nbytes = sum(_arg_home(a)[1] for a in args)
        promise = Promise(name=f"steal-pool:{kernel}")
        task = _PendingLaunch(program, args, kernel, grid, block, out, sync,
                              promise, nbytes, dev.key)
        with self._pump_lock:
            self._pending.setdefault(dev.key, deque()).append(task)
            backlog = len(self._pending[dev.key])
        self._ensure_pump(dev)
        if backlog > 1:
            # The owner is behind: wake every idle sibling so one can steal.
            for d in self._live():
                if d.key != dev.key:
                    self._ensure_pump(d)
        return promise.get_future()

    def _ensure_pump(self, dev) -> None:
        key = dev.key
        with self._pump_lock:
            if key in self._pumping:
                return
            self._pumping.add(key)
        from repro.core.executor import get_runtime

        get_runtime().pool.submit(self._pump, dev)

    def _pump(self, dev) -> None:
        """Per-device drain loop (host pool): own head first — FIFO for
        everything the owner runs — then tail-steals, then exit.  Unit
        concurrency per device: the pump blocks on each launch, so an
        idle pump is exactly an idle device."""
        key = dev.key
        while True:
            with self._pump_lock:
                dq = self._pending.get(key)
                if dq:
                    task = dq.popleft()
                else:
                    task = self._steal_locked(dev)
                    if task is None:
                        self._pumping.discard(key)
                        return
            self._run_task(dev, task)

    def _steal_locked(self, thief) -> "_PendingLaunch | None":
        """Pop the tail of the deepest eligible sibling backlog (caller
        holds ``_pump_lock``).  Eligibility: the task's argument bytes
        must be worth moving — at most ``REPRO_STEAL_MAX_BYTES``, divided
        by the cross-locality penalty when victim and thief live in
        different localities (a steal there costs a parcel pair per
        buffer, so only small tasks are worth shipping)."""
        if not self._steal:
            return None
        thief_loc = locality_of_key(getattr(thief, "key", ""))
        for vkey, dq in sorted(self._pending.items(), key=lambda kv: -len(kv[1])):
            if vkey == thief.key or not dq:
                continue
            task = dq[-1]
            limit = self._steal_max_bytes
            cross = locality_of_key(vkey) != thief_loc
            if cross:
                limit //= self._cross_penalty
            if task.nbytes > limit:
                continue
            dq.pop()
            task.stolen = True
            self._steals += 1
            if cross:
                self._cross_steals += 1
            return task
        return None

    def _run_task(self, dev, task: "_PendingLaunch") -> None:
        try:
            args = task.args
            if task.stolen:
                args = self._prefetch_stolen_args(dev, args)
            prog = task.program
            if callable(getattr(prog, "for_device", None)):
                prog = prog.for_device(dev)  # re-bind: sibling compile cache
            fut = prog.run(args, task.kernel, grid=task.grid, block=task.block,
                           out=task.out, sync=task.sync)
            task.promise.set_value(fut.get())
        except BaseException as e:  # noqa: BLE001 - fails the caller's future
            try:
                task.promise.set_exception(e)
            except Exception:  # noqa: BLE001 - consumer cancelled/raced
                pass

    def _prefetch_stolen_args(self, dev, args: Sequence) -> Sequence:
        """Batch-fetch remote argument buffers before a cross-locality
        stolen launch runs: one ``steal_fetch`` parcel brings every array
        over (the shm lane carries large payloads) instead of N separate
        percolation round-trips inside ``run``.  Falls back to per-arg
        percolation on any failure."""
        dev_loc = locality_of_key(getattr(dev, "key", ""))
        groups: "dict[tuple[int, int], tuple[Any, list[int]]]" = {}
        for i, a in enumerate(args):
            if not getattr(a, "is_remote_buffer", False):
                continue
            rdev = getattr(a, "device", None)
            port = getattr(rdev, "_port", None)
            loc = getattr(rdev, "locality_id", None)
            if port is None or loc is None or loc == dev_loc:
                continue
            groups.setdefault((id(port), loc), (port, []))[1].append(i)
        fetched = None
        for (_, loc), (port, idxs) in groups.items():
            if len(idxs) < 2:
                continue  # one buffer: plain percolation is one parcel anyway
            try:
                arrays = port.call(
                    loc, "steal_fetch", {"gids": [args[i].gid for i in idxs]}
                ).get()
            except Exception:  # noqa: BLE001 - fall back to percolation
                continue
            if fetched is None:
                fetched = list(args)
            for i, arr in zip(idxs, arrays):
                fetched[i] = arr
        return fetched if fetched is not None else args

    # -- introspection ---------------------------------------------------------

    def stats(self) -> "dict[str, int]":
        """Placement counts per device key (decision log, not queue state)."""
        with self._lock:
            return dict(self._placements)

    def steal_stats(self) -> dict:
        """Rebalancing counters: total steals, the cross-locality subset,
        and the current pending backlog per device."""
        with self._pump_lock:
            return {
                "steals": self._steals,
                "cross_locality": self._cross_steals,
                "pending": {k: len(dq) for k, dq in self._pending.items() if dq},
            }

    def __repr__(self) -> str:
        n = len(self._devices) if self._devices is not None else "?"
        return f"Scheduler(policy={self.policy.name}, devices={n})"


_default: "Scheduler | None" = None
_default_lock = threading.Lock()


def get_scheduler() -> Scheduler:
    """Process-default scheduler (lazy fleet discovery, ``least_loaded``)."""
    global _default
    if _default is None:
        with _default_lock:
            if _default is None:
                _default = Scheduler()
    return _default


def set_scheduler(sched: "Scheduler | None") -> None:
    """Replace the process-default scheduler (None restores lazy default)."""
    global _default
    with _default_lock:
        _default = sched


def _on_runtime_reset() -> None:
    """Drop the default scheduler with the runtime: it caches ``Device``
    handles whose queues died (see ``executor.reset_runtime``)."""
    set_scheduler(None)
