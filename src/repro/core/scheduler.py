"""Locality-aware placement scheduler (DESIGN.md §9).

The paper's headline — "any user defined CUDA kernel can be launched on
any (local or remote) GPU device" — needs a layer that *chooses* the
device.  HPXCL leaves placement to the caller; StarPU and Specx showed
that a task-based runtime earns its keep through pluggable scheduling
policies sitting between submission and heterogeneous workers.  This
module is that layer for our runtime: a ``Scheduler`` holds the device
fleet and a ``PlacementPolicy`` maps each task (its argument buffers) to
one device.

Policies
--------
``static``       pin everything to one device (HPXCL's implicit policy —
                 the baseline every other policy is measured against).
``round_robin``  cycle through the fleet regardless of state.
``least_loaded`` pick the device whose ops queue has the smallest
                 backlog (``WorkQueue.load()`` depth); ties rotate, so a
                 blind signal degrades to round-robin, never a pile-up.
``affinity``     pick the device already holding the most argument bytes
                 (AGAS placement records / resident-bytes reverse index),
                 minimizing percolation traffic; load breaks ties.
``percolation``  score the full ``localities × devices`` grid by the
                 *bytes that would have to move* if the task ran there —
                 a cross-locality move (an explicit transfer parcel pair,
                 DESIGN.md §10) costs a configurable multiple of an
                 intra-locality copy; load breaks ties.  This is the
                 cluster-aware generalization of ``affinity``.

Liveness (DESIGN.md §10): devices exposing ``alive()`` (remote proxies,
fed by the parcelport heartbeat) are excluded from placement while dead —
a locality whose worker missed its deadline never receives new work, and
``select`` raises descriptively when the whole fleet is gone.

The policy input is deliberately duck-typed: an argument counts toward
affinity if it exposes ``device``/``nbytes`` (our ``Buffer``) or is a
committed ``jax.Array`` — so policies are unit-testable with fakes and
serve-path fan-out can score raw arrays.

``Program.run_on_any`` routes launches through the default scheduler
(``get_scheduler()``); serving fan-out (``repro.serving``) and the fig6
benchmark use the same object, so one placement decision layer sees all
traffic.
"""
from __future__ import annotations

import threading
from typing import Any, Callable, Optional, Sequence

__all__ = [
    "PlacementPolicy",
    "StaticPolicy",
    "RoundRobinPolicy",
    "LeastLoadedPolicy",
    "AffinityPolicy",
    "PercolationPolicy",
    "Scheduler",
    "get_scheduler",
    "set_scheduler",
    "make_policy",
    "locality_of_key",
    "POLICIES",
]


def locality_of_key(key: "str | None") -> int:
    """Locality id encoded in a device key (``L3/cpu:0`` -> 3; local
    keys -> 0)."""
    if key and key.startswith("L"):
        head, sep, _ = key.partition("/")
        if sep:
            try:
                return int(head[1:])
            except ValueError:
                return 0
    return 0


def _is_alive(device: Any) -> bool:
    alive = getattr(device, "alive", None)
    return True if alive is None else bool(alive())


def _arg_home(arg: Any) -> "tuple[str | None, int]":
    """(device_key, nbytes) of ``arg``'s resident storage, or (None, 0).

    Buffers resolve through their AGAS placement record (the handle may
    have been re-homed by percolation); committed ``jax.Array``s through
    their sharding (checked before the duck-typed fallback — a jax.Array
    has ``.device``/``.nbytes`` too, but its device has no ``.key``).
    Anything else contributes nothing.
    """
    nbytes = getattr(arg, "nbytes", None)
    if nbytes is None:
        return None, 0
    if hasattr(arg, "gid") and getattr(arg, "device", None) is not None:  # Buffer
        from repro.core import agas

        try:
            return agas.registry.placement(arg.gid).device_key, int(nbytes)
        except KeyError:
            return getattr(arg.device, "key", None), int(nbytes)
    devices = getattr(arg, "devices", None)
    if callable(devices):  # committed jax.Array
        try:
            keys = {f"{d.platform}:{d.id}" for d in devices()}
        except Exception:  # noqa: BLE001 - uncommitted/abstract arrays
            return None, 0
        if len(keys) == 1:
            return next(iter(keys)), int(nbytes)
        return None, 0
    key = getattr(getattr(arg, "device", None), "key", None)  # duck-typed fake
    return (key, int(nbytes)) if key is not None else (None, 0)


def _device_load(device):
    """Backlog snapshot for placement: ``device.load()`` when the device
    aggregates per-lane depths across its streams (DESIGN.md §11 — a
    device busy on three lanes is three deep), else the bare ops queue
    (duck-typed fakes and plain queue holders)."""
    ld = getattr(device, "load", None)
    if callable(ld):
        return ld()
    return device.ops_queue.load()


def _load_score(device) -> "tuple[int, float]":
    l = _device_load(device)
    return (l.depth, l.busy_time)


class PlacementPolicy:
    """Maps (args, devices) -> one device.  Stateless unless noted."""

    name = "base"

    def select(self, devices: Sequence, args: Sequence = (), program=None):
        raise NotImplementedError

    def select_batch(self, devices: Sequence, batch_args: "Sequence[Sequence]" = (),
                     program=None):
        """Place one *micro-batch* of requests as a unit (the serving
        engine's hook, DESIGN.md §12): ``batch_args`` is one arg sequence
        per member request.  The default flattens every member's args into
        a single scoring set, so ``affinity``/``percolation`` weigh the
        whole batch's resident bytes (a batch is placed where MOST of its
        KV bytes already live) and load policies see one decision, not N.
        Policies with batch-specific knowledge can override."""
        flat = [a for args in batch_args for a in args]
        return self.select(devices, args=flat, program=program)


class StaticPolicy(PlacementPolicy):
    """Everything on one device (HPXCL's hand-placement, as a policy)."""

    name = "static"

    def __init__(self, index: int = 0):
        self.index = index

    def select(self, devices, args=(), program=None):
        return devices[self.index % len(devices)]


class RoundRobinPolicy(PlacementPolicy):
    """Cycle through the fleet; stateful (one counter, lock-protected)."""

    name = "round_robin"

    def __init__(self):
        self._next = 0
        self._lock = threading.Lock()

    def select(self, devices, args=(), program=None):
        with self._lock:
            i = self._next
            self._next = i + 1
        return devices[i % len(devices)]


class LeastLoadedPolicy(PlacementPolicy):
    """Smallest device backlog wins — summed across every stream lane of
    the device (``Device.load()``, DESIGN.md §11), so a device running
    three concurrent streams counts three deep; ties ROTATE through the tied
    devices (stateful counter), so when the depth signal is blind — e.g.
    percolating launches enqueue only after their copies resolve — the
    policy degrades to round-robin spread, never to piling everything on
    one historically-favored device."""

    name = "least_loaded"

    def __init__(self):
        self._rr = 0
        self._lock = threading.Lock()

    def select(self, devices, args=(), program=None):
        depths = [_device_load(d).depth for d in devices]
        lo = min(depths)
        tied = [i for i, depth in enumerate(depths) if depth == lo]
        with self._lock:
            pick = tied[self._rr % len(tied)]
            self._rr += 1
        return devices[pick]


class AffinityPolicy(PlacementPolicy):
    """Most argument bytes already resident wins (percolation avoidance);
    among equally-good hosts the least-loaded one is chosen, so a fleet
    with no resident data degrades to ``least_loaded``."""

    name = "affinity"

    def __init__(self):
        self._fallback = LeastLoadedPolicy()

    def select(self, devices, args=(), program=None):
        # Resolve every arg's placement ONCE (one AGAS lookup per arg),
        # then score devices against the aggregated bytes-per-key map.
        resident: "dict[str, int]" = {}
        for a in args:
            key, nb = _arg_home(a)
            if key is not None and nb:
                resident[key] = resident.get(key, 0) + nb
        if not resident:
            return self._fallback.select(devices, args=args, program=program)

        def score(dev):
            depth, busy = _load_score(dev)
            return (-resident.get(dev.key, 0), depth, busy)

        return min(devices, key=score)


class PercolationPolicy(PlacementPolicy):
    """Minimize percolation traffic over the ``localities × devices`` grid.

    Each candidate device is charged the bytes every argument would have
    to move to reach it: nothing when the bytes are already there, 1x for
    an intra-locality copy, ``cross_locality_cost``x when the move crosses
    a locality boundary (an explicit read-parcel + write-parcel pair over
    the transport, DESIGN.md §10).  Ties break by queue load; with no
    resident argument bytes at all the policy degrades to ``least_loaded``.
    """

    name = "percolation"

    def __init__(self, cross_locality_cost: float = 8.0):
        self.cross_locality_cost = float(cross_locality_cost)
        self._fallback = LeastLoadedPolicy()

    def select(self, devices, args=(), program=None):
        homes: "list[tuple[str, int, int]]" = []
        for a in args:
            key, nb = _arg_home(a)
            if key is not None and nb:
                homes.append((key, locality_of_key(key), nb))
        if not homes:
            return self._fallback.select(devices, args=args, program=program)

        def score(dev):
            dev_loc = locality_of_key(dev.key)
            cost = 0.0
            for key, loc, nb in homes:
                if key == dev.key:
                    continue
                cost += nb * (self.cross_locality_cost if loc != dev_loc else 1.0)
            depth, busy = _load_score(dev)
            return (cost, depth, busy)

        return min(devices, key=score)


POLICIES: "dict[str, Callable[[], PlacementPolicy]]" = {
    "static": StaticPolicy,
    "round_robin": RoundRobinPolicy,
    "least_loaded": LeastLoadedPolicy,
    "affinity": AffinityPolicy,
    "percolation": PercolationPolicy,
}


def make_policy(policy: "str | PlacementPolicy") -> PlacementPolicy:
    if isinstance(policy, PlacementPolicy):
        return policy
    try:
        return POLICIES[policy]()
    except KeyError:
        raise ValueError(f"unknown placement policy {policy!r}; have {sorted(POLICIES)}") from None


class Scheduler:
    """Placement decisions over a device fleet.

    ``devices=None`` discovers the fleet lazily (all devices, Listing 1)
    on first use, so the default scheduler works before/without explicit
    setup.  ``select`` returns the chosen ``Device`` and records the
    decision in per-device placement counters (``stats()``), which the
    integration tests and fig6 use to verify spread.
    """

    def __init__(self, devices: "Sequence | None" = None, policy: "str | PlacementPolicy" = "least_loaded"):
        self.policy = make_policy(policy)
        self._devices: "list | None" = list(devices) if devices is not None else None
        self._placements: "dict[str, int]" = {}
        self._lock = threading.Lock()

    def devices(self) -> list:
        devs = self._devices
        if devs is None:
            from repro.core.device import get_all_devices

            devs = self._devices = list(get_all_devices().get())
        if not devs:
            raise RuntimeError("Scheduler has no devices to place on")
        return devs

    def _live(self) -> list:
        devs = self.devices()
        # Heartbeat exclusion: a locality whose worker died takes no new
        # placements — its devices report alive() False until recovery.
        live = [d for d in devs if _is_alive(d)]
        if not live:
            raise RuntimeError(
                "Scheduler has no live devices: every locality in the fleet "
                "is dead (missed heartbeat or worker exit)"
            )
        return live

    def _record(self, dev):
        with self._lock:
            self._placements[dev.key] = self._placements.get(dev.key, 0) + 1
        return dev

    def select(self, args: Sequence = (), program=None):
        return self._record(self.policy.select(self._live(), args=args, program=program))

    def select_batch(self, batch_args: "Sequence[Sequence]" = (), program=None):
        """One placement decision for a whole micro-batch of requests
        (``PlacementPolicy.select_batch``): the engine hands every member
        request's argument leaves, the policy scores them as a unit, and
        the decision is logged once in ``stats()``."""
        return self._record(
            self.policy.select_batch(self._live(), batch_args=batch_args, program=program)
        )

    def stats(self) -> "dict[str, int]":
        """Placement counts per device key (decision log, not queue state)."""
        with self._lock:
            return dict(self._placements)

    def __repr__(self) -> str:
        n = len(self._devices) if self._devices is not None else "?"
        return f"Scheduler(policy={self.policy.name}, devices={n})"


_default: "Scheduler | None" = None
_default_lock = threading.Lock()


def get_scheduler() -> Scheduler:
    """Process-default scheduler (lazy fleet discovery, ``least_loaded``)."""
    global _default
    if _default is None:
        with _default_lock:
            if _default is None:
                _default = Scheduler()
    return _default


def set_scheduler(sched: "Scheduler | None") -> None:
    """Replace the process-default scheduler (None restores lazy default)."""
    global _default
    with _default_lock:
        _default = sched


def _on_runtime_reset() -> None:
    """Drop the default scheduler with the runtime: it caches ``Device``
    handles whose queues died (see ``executor.reset_runtime``)."""
    set_scheduler(None)
