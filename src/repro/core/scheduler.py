"""Locality-aware placement scheduler (DESIGN.md §9).

The paper's headline — "any user defined CUDA kernel can be launched on
any (local or remote) GPU device" — needs a layer that *chooses* the
device.  HPXCL leaves placement to the caller; StarPU and Specx showed
that a task-based runtime earns its keep through pluggable scheduling
policies sitting between submission and heterogeneous workers.  This
module is that layer for our runtime: a ``Scheduler`` holds the device
fleet and a ``PlacementPolicy`` maps each task (its argument buffers) to
one device.

Policies
--------
``static``       pin everything to one device (HPXCL's implicit policy —
                 the baseline every other policy is measured against).
``round_robin``  cycle through the fleet regardless of state.
``least_loaded`` pick the device whose ops queue has the smallest
                 backlog (``WorkQueue.load()`` depth); ties rotate, so a
                 blind signal degrades to round-robin, never a pile-up.
``affinity``     pick the device already holding the most argument bytes
                 (AGAS placement records / resident-bytes reverse index),
                 minimizing percolation traffic; load breaks ties.

The policy input is deliberately duck-typed: an argument counts toward
affinity if it exposes ``device``/``nbytes`` (our ``Buffer``) or is a
committed ``jax.Array`` — so policies are unit-testable with fakes and
serve-path fan-out can score raw arrays.

``Program.run_on_any`` routes launches through the default scheduler
(``get_scheduler()``); serving fan-out (``repro.serving``) and the fig6
benchmark use the same object, so one placement decision layer sees all
traffic.
"""
from __future__ import annotations

import threading
from typing import Any, Callable, Optional, Sequence

__all__ = [
    "PlacementPolicy",
    "StaticPolicy",
    "RoundRobinPolicy",
    "LeastLoadedPolicy",
    "AffinityPolicy",
    "Scheduler",
    "get_scheduler",
    "set_scheduler",
    "make_policy",
    "POLICIES",
]


def _arg_home(arg: Any) -> "tuple[str | None, int]":
    """(device_key, nbytes) of ``arg``'s resident storage, or (None, 0).

    Buffers resolve through their AGAS placement record (the handle may
    have been re-homed by percolation); committed ``jax.Array``s through
    their sharding (checked before the duck-typed fallback — a jax.Array
    has ``.device``/``.nbytes`` too, but its device has no ``.key``).
    Anything else contributes nothing.
    """
    nbytes = getattr(arg, "nbytes", None)
    if nbytes is None:
        return None, 0
    if hasattr(arg, "gid") and getattr(arg, "device", None) is not None:  # Buffer
        from repro.core import agas

        try:
            return agas.registry.placement(arg.gid).device_key, int(nbytes)
        except KeyError:
            return getattr(arg.device, "key", None), int(nbytes)
    devices = getattr(arg, "devices", None)
    if callable(devices):  # committed jax.Array
        try:
            keys = {f"{d.platform}:{d.id}" for d in devices()}
        except Exception:  # noqa: BLE001 - uncommitted/abstract arrays
            return None, 0
        if len(keys) == 1:
            return next(iter(keys)), int(nbytes)
        return None, 0
    key = getattr(getattr(arg, "device", None), "key", None)  # duck-typed fake
    return (key, int(nbytes)) if key is not None else (None, 0)


def _load_score(device) -> "tuple[int, float]":
    l = device.ops_queue.load()
    return (l.depth, l.busy_time)


class PlacementPolicy:
    """Maps (args, devices) -> one device.  Stateless unless noted."""

    name = "base"

    def select(self, devices: Sequence, args: Sequence = (), program=None):
        raise NotImplementedError


class StaticPolicy(PlacementPolicy):
    """Everything on one device (HPXCL's hand-placement, as a policy)."""

    name = "static"

    def __init__(self, index: int = 0):
        self.index = index

    def select(self, devices, args=(), program=None):
        return devices[self.index % len(devices)]


class RoundRobinPolicy(PlacementPolicy):
    """Cycle through the fleet; stateful (one counter, lock-protected)."""

    name = "round_robin"

    def __init__(self):
        self._next = 0
        self._lock = threading.Lock()

    def select(self, devices, args=(), program=None):
        with self._lock:
            i = self._next
            self._next = i + 1
        return devices[i % len(devices)]


class LeastLoadedPolicy(PlacementPolicy):
    """Smallest ops-queue backlog wins; ties ROTATE through the tied
    devices (stateful counter), so when the depth signal is blind — e.g.
    percolating launches enqueue only after their copies resolve — the
    policy degrades to round-robin spread, never to piling everything on
    one historically-favored device."""

    name = "least_loaded"

    def __init__(self):
        self._rr = 0
        self._lock = threading.Lock()

    def select(self, devices, args=(), program=None):
        depths = [d.ops_queue.load().depth for d in devices]
        lo = min(depths)
        tied = [i for i, depth in enumerate(depths) if depth == lo]
        with self._lock:
            pick = tied[self._rr % len(tied)]
            self._rr += 1
        return devices[pick]


class AffinityPolicy(PlacementPolicy):
    """Most argument bytes already resident wins (percolation avoidance);
    among equally-good hosts the least-loaded one is chosen, so a fleet
    with no resident data degrades to ``least_loaded``."""

    name = "affinity"

    def __init__(self):
        self._fallback = LeastLoadedPolicy()

    def select(self, devices, args=(), program=None):
        # Resolve every arg's placement ONCE (one AGAS lookup per arg),
        # then score devices against the aggregated bytes-per-key map.
        resident: "dict[str, int]" = {}
        for a in args:
            key, nb = _arg_home(a)
            if key is not None and nb:
                resident[key] = resident.get(key, 0) + nb
        if not resident:
            return self._fallback.select(devices, args=args, program=program)

        def score(dev):
            depth, busy = _load_score(dev)
            return (-resident.get(dev.key, 0), depth, busy)

        return min(devices, key=score)


POLICIES: "dict[str, Callable[[], PlacementPolicy]]" = {
    "static": StaticPolicy,
    "round_robin": RoundRobinPolicy,
    "least_loaded": LeastLoadedPolicy,
    "affinity": AffinityPolicy,
}


def make_policy(policy: "str | PlacementPolicy") -> PlacementPolicy:
    if isinstance(policy, PlacementPolicy):
        return policy
    try:
        return POLICIES[policy]()
    except KeyError:
        raise ValueError(f"unknown placement policy {policy!r}; have {sorted(POLICIES)}") from None


class Scheduler:
    """Placement decisions over a device fleet.

    ``devices=None`` discovers the fleet lazily (all devices, Listing 1)
    on first use, so the default scheduler works before/without explicit
    setup.  ``select`` returns the chosen ``Device`` and records the
    decision in per-device placement counters (``stats()``), which the
    integration tests and fig6 use to verify spread.
    """

    def __init__(self, devices: "Sequence | None" = None, policy: "str | PlacementPolicy" = "least_loaded"):
        self.policy = make_policy(policy)
        self._devices: "list | None" = list(devices) if devices is not None else None
        self._placements: "dict[str, int]" = {}
        self._lock = threading.Lock()

    def devices(self) -> list:
        devs = self._devices
        if devs is None:
            from repro.core.device import get_all_devices

            devs = self._devices = list(get_all_devices().get())
        if not devs:
            raise RuntimeError("Scheduler has no devices to place on")
        return devs

    def select(self, args: Sequence = (), program=None):
        dev = self.policy.select(self.devices(), args=args, program=program)
        with self._lock:
            self._placements[dev.key] = self._placements.get(dev.key, 0) + 1
        return dev

    def stats(self) -> "dict[str, int]":
        """Placement counts per device key (decision log, not queue state)."""
        with self._lock:
            return dict(self._placements)

    def __repr__(self) -> str:
        n = len(self._devices) if self._devices is not None else "?"
        return f"Scheduler(policy={self.policy.name}, devices={n})"


_default: "Scheduler | None" = None
_default_lock = threading.Lock()


def get_scheduler() -> Scheduler:
    """Process-default scheduler (lazy fleet discovery, ``least_loaded``)."""
    global _default
    if _default is None:
        with _default_lock:
            if _default is None:
                _default = Scheduler()
    return _default


def set_scheduler(sched: "Scheduler | None") -> None:
    """Replace the process-default scheduler (None restores lazy default)."""
    global _default
    with _default_lock:
        _default = sched


def _on_runtime_reset() -> None:
    """Drop the default scheduler with the runtime: it caches ``Device``
    handles whose queues died (see ``executor.reset_runtime``)."""
    set_scheduler(None)
