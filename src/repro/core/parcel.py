"""Parcel transport & remote actions — the HPX parcelport re-derived (§3).

HPX moves work between localities as *parcels*: a serialized action (the
function to run), its arguments, and a continuation that resolves the
caller's future when the reply arrives.  This module is that layer for
the repro runtime, closing the paper's "any (local or remote) GPU
device" claim: every runtime verb — ``create_buffer``, ``enqueue_write``,
``launch`` (by registered-kernel name), ``enqueue_read``, ``free`` — has
a parcel encoding, and reply parcels resolve the sender's ``Future``s.

Three pieces:

* **Codec** — ``dumps``/``loads``: a small self-describing binary format
  for parcel payloads.  Covers None/bool/int/float/str/bytes, lists,
  tuples, dicts, numpy arrays of any numeric dtype (bit-exact round
  trip), numpy scalars, and exceptions (type + args + message, rebuilt
  on the receiving locality; unknown types degrade to ``RemoteError``).
  Deliberately *not* pickle: the wire format admits no code execution.

* **Transports** — ``LoopbackParcelport`` runs N simulated localities in
  this process (every request still round-trips the codec, so the parcel
  path is tier-1 testable with zero dependencies); ``LocalClusterParcelport``
  spawns N worker *processes* via ``multiprocessing``, each owning a real
  remote ``Locality``: its own JAX runtime, its own ``Runtime``/
  ``WorkQueue``s, its own AGAS registry minting locality-scoped GIDs.

* **Actions** — ``ActionServer`` executes decoded parcels against the
  owning process's devices through the ordinary ``Device``/``Buffer``/
  ``Program`` API, so a remote launch takes exactly the local submission
  path once it lands.  Kernels percolate *by name*: the server resolves
  them through ``register_kernel`` entries, the ``repro.kernels``
  registry, or an importable ``"module:attr"`` path — source travels as
  a reference, never as code.

Ordering guarantees (the contract the stream engine extends across
localities, DESIGN.md §11):

* **Parcel-channel FIFO** — parcels submitted through one channel (one
  ``RemoteDevice`` stream, including its default ``ops_queue`` channel)
  execute on the owning locality strictly in submission order.  On a
  non-pipelined port the channel's worker sends a parcel and blocks on
  its reply before sending the next, so order holds end-to-end trivially.
  On a pipelined ``LocalClusterParcelport`` (the default) the channel
  *stages* each parcel (``stage``) and ships the backlog in one queue hop
  (``flush``) without waiting for replies; FIFO still holds end-to-end
  because staging order is flush order is wire order, and the worker
  executes actions on a single pool thread in arrival order.  Large array
  payloads (≥ ``REPRO_PARCEL_SHM_MIN`` bytes) cross via POSIX shared
  memory instead of the pipe when ``REPRO_PARCEL_SHM`` permits — the
  blob then carries only the segment name + dtype + shape.
* **Cross-channel: none** — parcels of different channels (different
  streams, or different devices) may interleave arbitrarily on the
  owning locality; synchronization between them is explicit (an
  ``Event`` recorded on one stream, waited on by the other — the event's
  future resolves on the reply parcel of the recorded channel's marker).
* **Replies resolve futures exactly once** — each request ``pid`` is
  matched to one reply; a dead locality fails its pending parcels fast
  instead of leaving futures forever pending.

Fault model (DESIGN.md §6, wired here): each cluster worker is watched by
a ``fault.monitor.Heartbeat``; replies tick it, a monitor thread pings
it, and a missed deadline (or a dead process) marks the locality dead —
its queued parcels fail fast with a descriptive error and the scheduler
excludes its devices from placement (``RemoteDevice.alive``).
"""
from __future__ import annotations

import importlib
import itertools
import os
import queue as _queue
import struct
import threading
import time
import weakref
from dataclasses import dataclass, field
from typing import Any, Callable

import numpy as np

__all__ = [
    "Parcel",
    "Parcelport",
    "LoopbackParcelport",
    "LocalClusterParcelport",
    "ActionServer",
    "RemoteError",
    "dumps",
    "loads",
    "encode_parcel",
    "decode_parcel",
    "register_kernel",
    "resolve_kernel",
]


class RemoteError(RuntimeError):
    """A failure that crossed a locality boundary and could not be
    reconstructed as its original exception type."""


# ---------------------------------------------------------------------------
# codec: payload values <-> bytes (no pickle, no code on the wire)
# ---------------------------------------------------------------------------

_Q = struct.Struct("<Q")
_q = struct.Struct("<q")
_d = struct.Struct("<d")

# -- shared-memory array lane (same-host localities) -------------------------
#
# Array payloads at/above _SHM_MIN bytes travel OUT-OF-BAND through a
# POSIX shared-memory segment: the wire carries only a control header
# (segment name + dtype + shape), so the pipe/queue hop stays constant-
# size no matter how large the tensor.  Protocol: the SENDER creates and
# fills the segment and immediately unregisters it from its own
# resource_tracker (the tracker would otherwise unlink it at sender exit,
# racing the receiver); the RECEIVER copies the payload out and unlinks —
# sole owner of the segment's lifetime in normal operation.  Each side
# additionally remembers the names it created in a ``_ShmTracker`` whose
# ``purge()`` unlinks whatever a dead/never-started receiver left behind
# (no leaked segments after ``reset_runtime``).
# ``REPRO_PARCEL_SHM=off`` forces everything inline on the wire;
# ``REPRO_PARCEL_SHM_MIN`` tunes the out-of-band threshold (bytes).

_SHM_MODE = os.environ.get("REPRO_PARCEL_SHM", "auto").lower()
# Default threshold: the segment's fixed cost (shm_open/ftruncate/mmap on
# each side plus the unlink) runs a few hundred µs — measured against the
# pipe's per-byte cost that only pays off from ~half a MB up.
_SHM_MIN = int(os.environ.get("REPRO_PARCEL_SHM_MIN", str(512 << 10)))
_shm_state: "dict[str, Any]" = {"ok": None}


def _shm_untrack(seg) -> None:
    """Drop a segment from THIS process's resource_tracker ledger (the
    other side of the transfer owns the unlink)."""
    try:
        from multiprocessing import resource_tracker

        resource_tracker.unregister(seg._name, "shared_memory")
    except Exception:  # noqa: BLE001 - tracker bookkeeping is best-effort
        pass


def shm_available() -> bool:
    """Can this process create shared-memory segments (and is the lane
    enabled)?  Probed once; ``REPRO_PARCEL_SHM=off`` always answers False."""
    if _SHM_MODE == "off":
        return False
    if _shm_state["ok"] is None:
        try:
            from multiprocessing import shared_memory

            seg = shared_memory.SharedMemory(create=True, size=16)
            seg.close()
            seg.unlink()
            _shm_state["ok"] = True
        except Exception:  # noqa: BLE001 - no /dev/shm, sandboxed, etc.
            _shm_state["ok"] = False
    return bool(_shm_state["ok"])


def _shm_export(arr: np.ndarray) -> "str | None":
    """Copy ``arr`` into a fresh segment; returns its name (or None to
    fall back to inline encoding)."""
    try:
        from multiprocessing import shared_memory

        seg = shared_memory.SharedMemory(create=True, size=max(arr.nbytes, 1))
    except Exception:  # noqa: BLE001 - creation failed: inline fallback
        return None
    try:
        dst = np.frombuffer(seg.buf, dtype=arr.dtype, count=arr.size).reshape(arr.shape)
        np.copyto(dst, arr)
        del dst
        name = seg.name
        _shm_untrack(seg)
        seg.close()
        return name
    except Exception:  # noqa: BLE001
        try:
            seg.close()
            seg.unlink()
        except Exception:  # noqa: BLE001
            pass
        return None


def _shm_import(name: str, descr: str, shape) -> np.ndarray:
    """Receiver half: attach, copy out, unlink (consuming the segment)."""
    from multiprocessing import shared_memory

    try:
        seg = shared_memory.SharedMemory(name=name)
    except FileNotFoundError:
        raise RemoteError(
            f"shared-memory parcel segment {name!r} vanished before it was "
            "consumed (sender torn down mid-flight?)"
        ) from None
    try:
        dt = np.dtype(descr)
        count = 1
        for d in shape:
            count *= int(d)
        arr = np.frombuffer(seg.buf, dtype=dt, count=count).reshape(shape).copy()
    finally:
        try:
            seg.close()
        except Exception:  # noqa: BLE001
            pass
        try:
            seg.unlink()  # also unregisters from this process's tracker
        except FileNotFoundError:
            pass
    return arr


class _ShmTracker:
    """Names of segments this process created that are still (possibly)
    unconsumed.  ``sweep`` drops names the receiver has already unlinked;
    ``purge`` unlinks the rest (receiver died / port shut down)."""

    __slots__ = ("names", "lock")

    def __init__(self):
        self.names: "list[str]" = []
        self.lock = threading.Lock()

    def add(self, names) -> None:
        with self.lock:
            self.names.extend(names)
            if len(self.names) > 64:
                self._sweep_locked()

    def sweep(self) -> None:
        with self.lock:
            self._sweep_locked()

    def _sweep_locked(self) -> None:
        from multiprocessing import shared_memory

        keep = []
        for nm in self.names:
            try:
                seg = shared_memory.SharedMemory(name=nm)
            except Exception:  # noqa: BLE001 - gone: consumed by the receiver
                continue
            _shm_untrack(seg)
            seg.close()
            keep.append(nm)
        self.names = keep

    def purge(self) -> None:
        """Unlink every still-existing tracked segment (terminal cleanup)."""
        from multiprocessing import shared_memory

        with self.lock:
            names, self.names = self.names, []
        for nm in names:
            try:
                seg = shared_memory.SharedMemory(name=nm)
            except Exception:  # noqa: BLE001 - already consumed
                continue
            try:
                seg.close()
                seg.unlink()
            except Exception:  # noqa: BLE001
                pass


def _put_len(out: bytearray, n: int) -> None:
    out += _Q.pack(n)


def _put_str(out: bytearray, s: str) -> None:
    b = s.encode("utf-8")
    _put_len(out, len(b))
    out += b


def _enc(obj: Any, out: bytearray, sink: "list | None" = None) -> None:
    if obj is None:
        out += b"N"
    elif obj is True:
        out += b"T"
    elif obj is False:
        out += b"F"
    elif type(obj) is int:
        if -(2**63) <= obj < 2**63:
            out += b"i"
            out += _q.pack(obj)
        else:  # arbitrary precision via decimal string
            out += b"I"
            _put_str(out, str(obj))
    elif type(obj) is float:
        out += b"f"
        out += _d.pack(obj)
    elif type(obj) is complex:
        out += b"c"
        out += _d.pack(obj.real) + _d.pack(obj.imag)
    elif type(obj) is str:
        out += b"s"
        _put_str(out, obj)
    elif type(obj) is bytes:
        out += b"b"
        _put_len(out, len(obj))
        out += obj
    elif isinstance(obj, np.generic):  # numpy scalar: dtype-preserving
        out += b"y"
        _put_str(out, obj.dtype.str)
        raw = obj.tobytes()
        _put_len(out, len(raw))
        out += raw
    elif isinstance(obj, np.ndarray):
        if obj.dtype.hasobject:
            raise ValueError("object-dtype arrays are not parcel-encodable")
        arr = np.ascontiguousarray(obj)
        if sink is not None and arr.nbytes >= _SHM_MIN:
            # Out-of-band lane: payload bytes via shared memory, only the
            # control header on the wire (falls back inline on failure).
            name = _shm_export(arr)
            if name is not None:
                sink.append(name)
                out += b"A"
                _put_str(out, name)
                _put_str(out, arr.dtype.str)
                _enc(tuple(int(d) for d in obj.shape), out)
                return
        out += b"a"
        _put_str(out, arr.dtype.str)
        # shape from the ORIGINAL: ascontiguousarray promotes 0-d to (1,)
        # (same bytes, wrong rank) — a 0-d array must round-trip as 0-d.
        _enc(tuple(int(d) for d in obj.shape), out)
        raw = arr.tobytes()
        _put_len(out, len(raw))
        out += raw
    elif isinstance(obj, (list, tuple)):
        out += b"l" if type(obj) is list else b"t"
        _put_len(out, len(obj))
        for v in obj:
            _enc(v, out, sink)
    elif isinstance(obj, dict):
        out += b"d"
        _put_len(out, len(obj))
        for k, v in obj.items():
            _enc(k, out, sink)
            _enc(v, out, sink)
    elif isinstance(obj, BaseException):
        out += b"e"
        cls = type(obj)
        _put_str(out, cls.__module__ or "builtins")
        _put_str(out, cls.__qualname__)
        args = []
        for a in obj.args:  # best effort: unencodable args degrade to repr
            try:
                probe = bytearray()
                _enc(a, probe)
                args.append(a)
            except (ValueError, TypeError):
                args.append(repr(a))
        _enc(args, out)  # exception args stay inline: no shm for error paths
        _put_str(out, str(obj))
    else:
        # Last chance: things that quack like arrays (jax.Array, memoryview).
        try:
            arr = np.asarray(obj)
        except Exception:  # noqa: BLE001
            raise ValueError(f"{type(obj).__name__} is not parcel-encodable") from None
        if arr.dtype.hasobject:
            raise ValueError(f"{type(obj).__name__} is not parcel-encodable")
        _enc(arr, out, sink)


def dumps(obj: Any, shm_sink: "list | None" = None) -> bytes:
    """Serialize a payload value to bytes (see module docstring).

    ``shm_sink``: a list enables the shared-memory lane — arrays of at
    least ``REPRO_PARCEL_SHM_MIN`` bytes travel out-of-band and the names
    of the segments created are appended to the list (the caller tracks
    them for crash cleanup; the receiver unlinks on decode)."""
    out = bytearray()
    _enc(obj, out, shm_sink)
    return bytes(out)


def _get_len(buf: bytes, pos: int) -> "tuple[int, int]":
    return _Q.unpack_from(buf, pos)[0], pos + 8


def _get_str(buf: bytes, pos: int) -> "tuple[str, int]":
    n, pos = _get_len(buf, pos)
    return buf[pos : pos + n].decode("utf-8"), pos + n


def _rebuild_exception(module: str, qualname: str, args: list, text: str) -> BaseException:
    try:
        cls: Any = importlib.import_module(module)
        for part in qualname.split("."):
            cls = getattr(cls, part)
        if isinstance(cls, type) and issubclass(cls, BaseException):
            return cls(*args)
    except Exception:  # noqa: BLE001 - fall through to the generic carrier
        pass
    return RemoteError(f"{qualname}: {text}")


def _dec(buf: bytes, pos: int) -> "tuple[Any, int]":
    tag = buf[pos : pos + 1]
    pos += 1
    if tag == b"N":
        return None, pos
    if tag == b"T":
        return True, pos
    if tag == b"F":
        return False, pos
    if tag == b"i":
        return _q.unpack_from(buf, pos)[0], pos + 8
    if tag == b"I":
        s, pos = _get_str(buf, pos)
        return int(s), pos
    if tag == b"f":
        return _d.unpack_from(buf, pos)[0], pos + 8
    if tag == b"c":
        re = _d.unpack_from(buf, pos)[0]
        im = _d.unpack_from(buf, pos + 8)[0]
        return complex(re, im), pos + 16
    if tag == b"s":
        return _get_str(buf, pos)
    if tag == b"b":
        n, pos = _get_len(buf, pos)
        return buf[pos : pos + n], pos + n
    if tag == b"y":
        descr, pos = _get_str(buf, pos)
        n, pos = _get_len(buf, pos)
        return np.frombuffer(buf[pos : pos + n], dtype=np.dtype(descr))[0], pos + n
    if tag == b"a":
        descr, pos = _get_str(buf, pos)
        shape, pos = _dec(buf, pos)
        n, pos = _get_len(buf, pos)
        arr = np.frombuffer(buf[pos : pos + n], dtype=np.dtype(descr)).reshape(shape)
        return arr.copy(), pos + n  # writable, detached from the wire buffer
    if tag == b"A":  # out-of-band array: payload in a shared-memory segment
        name, pos = _get_str(buf, pos)
        descr, pos = _get_str(buf, pos)
        shape, pos = _dec(buf, pos)
        return _shm_import(name, descr, shape), pos
    if tag in (b"l", b"t"):
        n, pos = _get_len(buf, pos)
        items = []
        for _ in range(n):
            v, pos = _dec(buf, pos)
            items.append(v)
        return (items if tag == b"l" else tuple(items)), pos
    if tag == b"d":
        n, pos = _get_len(buf, pos)
        d = {}
        for _ in range(n):
            k, pos = _dec(buf, pos)
            v, pos = _dec(buf, pos)
            d[k] = v
        return d, pos
    if tag == b"e":
        module, pos = _get_str(buf, pos)
        qualname, pos = _get_str(buf, pos)
        args, pos = _dec(buf, pos)
        text, pos = _get_str(buf, pos)
        return _rebuild_exception(module, qualname, list(args), text), pos
    raise ValueError(f"corrupt parcel: unknown tag {tag!r} at offset {pos - 1}")


def loads(buf: bytes) -> Any:
    """Inverse of ``dumps``."""
    obj, pos = _dec(buf, 0)
    if pos != len(buf):
        raise ValueError(f"corrupt parcel: {len(buf) - pos} trailing byte(s)")
    return obj


# ---------------------------------------------------------------------------
# the parcel itself
# ---------------------------------------------------------------------------


@dataclass
class Parcel:
    """One serialized action (or its reply) in flight between localities.

    ``pid`` matches a reply to its request; ``locality`` is the
    destination (requests) / origin (replies); replies carry
    ``action="reply"`` with ``payload={"value": ...}`` on success or
    ``payload={"error": exception}`` and ``ok=False`` on failure.
    """

    action: str
    payload: dict = field(default_factory=dict)
    pid: int = 0
    locality: int = 0
    ok: bool = True


def encode_parcel(p: Parcel, shm_sink: "list | None" = None) -> bytes:
    return dumps((p.action, p.payload, p.pid, p.locality, p.ok), shm_sink=shm_sink)


def decode_parcel(buf: bytes) -> Parcel:
    action, payload, pid, locality, ok = loads(buf)
    return Parcel(action, payload, pid, locality, ok)


# ---------------------------------------------------------------------------
# kernel registry: remote launches reference kernels BY NAME
# ---------------------------------------------------------------------------

_extra_kernels: "dict[str, Callable]" = {}


def register_kernel(name: str, fn: Callable) -> None:
    """Register ``fn`` under ``name`` for launch-by-name parcels.

    In-process registration only: a ``LocalClusterParcelport`` worker is a
    separate process and resolves names through its *own* registry — ship
    kernels to a cluster via ``repro.kernels`` packages or an importable
    ``"module:attr"`` reference instead.
    """
    _extra_kernels[name] = fn


def resolve_kernel(name: str) -> Callable:
    """Kernel callable for a parcel's kernel-name reference."""
    fn = _extra_kernels.get(name)
    if fn is not None:
        return fn
    from repro.kernels import all_kernels

    fn = all_kernels().get(name)
    if fn is not None:
        return fn
    if ":" in name:
        mod, _, attr = name.partition(":")
        try:
            target: Any = importlib.import_module(mod)
            for part in attr.split("."):
                target = getattr(target, part)
            if callable(target):
                return target
        except Exception:  # noqa: BLE001 - fall through to the KeyError
            pass
    from repro.core import agas

    raise KeyError(
        f"kernel {name!r} is not resolvable on locality L{agas.get_locality_id()}: "
        "register it with repro.core.parcel.register_kernel, add it to a "
        "repro.kernels package, or reference it as an importable 'module:attr'"
    )


def _bind_geometry(fn: Callable, grid, block) -> Callable:
    """Geometry-kwarg binding for registry kernels (``Program._bind`` twin
    for kernels launched outside a ``Program``)."""
    import inspect

    params = inspect.signature(fn).parameters
    kwargs = {}
    if "grid" in params:
        kwargs["grid"] = tuple(grid) if grid is not None else None
    if "block" in params:
        kwargs["block"] = tuple(block) if block is not None else None
    if not kwargs:
        return fn
    return lambda *args: fn(*args, **kwargs)


# ---------------------------------------------------------------------------
# action server: decoded parcels -> the ordinary local runtime API
# ---------------------------------------------------------------------------


class ActionServer:
    """Executes parcels against this process's devices.

    One per locality.  Objects created by parcels (buffers, programs) are
    held strongly in an object table keyed by their AGAS GID — the remote
    holder owns them; the table is their anchor until a ``free`` parcel
    (or server shutdown) releases them.
    """

    def __init__(self, locality_id: int):
        self.locality_id = locality_id
        self._objects: "dict[int, Any]" = {}
        # key -> Device memo: discovery is a pool hop + device walk; the
        # transport hot path must not pay it per parcel (devices are
        # process-stable — the device module's cache guarantees identity).
        self._devices: "dict[str | None, Any]" = {}

    # -- helpers ------------------------------------------------------------

    def _device(self, key: "str | None"):
        dev = self._devices.get(key)
        if dev is not None:
            return dev
        from repro.core.device import get_all_devices

        devices = get_all_devices().get()
        if key is None:
            dev = devices[0]
        else:
            dev = next((d for d in devices if d.key == key), None)
            if dev is None:
                raise KeyError(f"locality L{self.locality_id} has no device {key!r}")
        self._devices[key] = dev
        return dev

    def _buffer(self, gid: int):
        buf = self._objects.get(gid)
        if buf is None:
            raise KeyError(
                f"GID {gid} is not a live parcel-created buffer on locality "
                f"L{self.locality_id} (freed, or never created here)"
            )
        return buf

    def _program(self, gid: int):
        prog = self._objects.get(gid)
        if prog is None:
            raise KeyError(f"GID {gid} is not a live parcel-created program on L{self.locality_id}")
        return prog

    def _resolve_args(self, descs):
        out = []
        for tag, v in descs:
            out.append(self._buffer(v) if tag == "gid" else v)
        return out

    # -- dispatch -----------------------------------------------------------

    def handle(self, action: str, payload: dict) -> Any:
        fn = getattr(self, f"_do_{action}", None)
        if fn is None:
            raise KeyError(f"unknown parcel action {action!r}")
        return fn(payload)

    # -- actions ------------------------------------------------------------

    def _do_ping(self, payload: dict) -> str:
        return "pong"

    def _do_barrier(self, payload: dict) -> None:
        # Completion fence for pipelined channels: unlike "ping" (answered
        # inline by the worker's receive loop), "barrier" rides the worker's
        # single-threaded action pool, so its reply proves every parcel
        # staged before it has fully executed.
        return None

    def _do_discover(self, payload: dict) -> list:
        from repro.core.device import get_all_devices

        return [
            {"key": d.key, "platform": d.platform, "capability": list(d.capability())}
            for d in get_all_devices().get()
        ]

    def _do_create_buffer(self, payload: dict) -> dict:
        dev = self._device(payload.get("device"))
        shape = payload["shape"]
        shape = tuple(shape) if isinstance(shape, (list, tuple)) else int(shape)
        buf = dev.create_buffer(shape, np.dtype(payload["dtype"]), payload.get("fill")).get()
        self._objects[buf.gid] = buf
        return {"gid": buf.gid, "shape": list(buf.shape), "dtype": buf.dtype.str}

    def _do_create_buffer_from(self, payload: dict) -> dict:
        dev = self._device(payload.get("device"))
        buf = dev.create_buffer_from(payload["data"]).get()
        self._objects[buf.gid] = buf
        return {"gid": buf.gid, "shape": list(buf.shape), "dtype": buf.dtype.str}

    def _do_enqueue_write(self, payload: dict) -> None:
        buf = self._buffer(payload["gid"])
        buf.enqueue_write(payload.get("offset", 0), payload["data"], payload.get("count")).get()
        return None

    def _do_enqueue_read(self, payload: dict) -> np.ndarray:
        buf = self._buffer(payload["gid"])
        return np.asarray(buf.enqueue_read(payload.get("offset", 0), payload.get("count")).get())

    def _do_steal_fetch(self, payload: dict) -> "list[np.ndarray]":
        """Batched re-home read for work stealing (DESIGN.md §14): one
        parcel returns the full contents of every requested buffer, so a
        thief re-binding a stolen launch pays one round-trip instead of
        one per argument.  Reads are submitted to the owning queues first
        and gathered after, overlapping the device-side D2H copies; large
        replies ride the shm lane like any other array payload."""
        futs = [self._buffer(gid).enqueue_read() for gid in payload["gids"]]
        return [np.asarray(f.get()) for f in futs]

    def _do_free(self, payload: dict) -> None:
        buf = self._objects.pop(payload["gid"], None)
        if buf is not None:
            buf.free().get()
        return None

    def _do_create_program(self, payload: dict) -> dict:
        from repro.core.program import Program

        dev = self._device(payload.get("device"))
        kernels = {name: resolve_kernel(name) for name in payload["kernels"]}
        prog = Program(dev, kernels, name=payload.get("name", "program"))
        self._objects[prog.gid] = prog
        return {"gid": prog.gid}

    def _do_build(self, payload: dict) -> None:
        import jax

        prog = self._program(payload["program"])
        specs = [jax.ShapeDtypeStruct(tuple(s), np.dtype(d)) for s, d in payload.get("specs", [])]
        prog.build(
            payload["kernel"], *specs, grid=payload.get("grid"), block=payload.get("block")
        ).get()
        return None

    def _do_launch(self, payload: dict) -> "list | None":
        prog = self._program(payload["program"])
        args = self._resolve_args(payload["args"])
        out_gids = payload.get("out")
        out = [self._buffer(g) for g in out_gids] if out_gids is not None else None
        fut = prog.run(
            args, payload["kernel"], grid=payload.get("grid"), block=payload.get("block"), out=out
        )
        res = fut.get()
        if out is not None:
            return None  # results live in the remote buffers; nothing to ship
        res_list = list(res) if isinstance(res, (tuple, list)) else [res]
        return [np.asarray(r) for r in res_list]

    def _do_apply(self, payload: dict) -> Any:
        """Run a registry kernel over a pytree batch on this locality's
        device queue (the serving fan-out action)."""
        import jax

        dev = self._device(payload.get("device"))
        fn = resolve_kernel(payload["kernel"])
        batch = payload["batch"]

        def _run():
            placed = jax.device_put(batch, dev.jax_device)
            return jax.tree_util.tree_map(np.asarray, fn(placed))

        return dev.ops_queue.submit(_run).get()

    def _do_apply_batched(self, payload: dict) -> list:
        """Run a registry kernel ONCE over a stacked micro-batch assembled
        from many requests, and reply with one result chunk per request
        (the serving engine's cross-locality action, DESIGN.md §12).

        ``batch`` is the padded, bucket-shaped pytree (all leaves share a
        leading row axis); ``rows`` lists each member request's row count
        in order.  One parcel carries the whole micro-batch out, and the
        reply ships only the real rows back — padding never crosses the
        wire twice."""
        import jax

        dev = self._device(payload.get("device"))
        fn = resolve_kernel(payload["kernel"])
        batch = payload["batch"]
        rows = [int(r) for r in payload["rows"]]

        def _run():
            placed = jax.tree_util.tree_map(
                lambda a: jax.device_put(a, dev.jax_device), batch
            )
            out = jax.tree_util.tree_map(np.asarray, fn(placed))
            chunks, off = [], 0
            for r in rows:
                chunks.append(jax.tree_util.tree_map(
                    # 0-d output leaves are shared, not row-sliced (same
                    # rule as the engine's local slice path)
                    lambda a, o=off, n=r: a[o : o + n] if getattr(a, "ndim", 0) >= 1 else a,
                    out,
                ))
                off += r
            return chunks

        return dev.ops_queue.submit(_run).get()

    def _do_invoke(self, payload: dict) -> Any:
        """Named-function RPC: resolve ``fn`` exactly like a kernel
        reference (``register_kernel`` entry, ``repro.kernels`` registry,
        or an importable ``"module:attr"``) and call it with the decoded
        payload value directly — no ``device_put``, so the payload may mix
        arrays with plain scalars/strings (the elastic trainer's shard-step
        action ships params + tokens + config knobs in one dict)."""
        fn = resolve_kernel(payload["fn"])
        return fn(payload.get("payload"))

    def _do_run_segment(self, payload: dict) -> list:
        """Execute one fused-graph segment plan: a sequence of launches by
        kernel name over an SSA environment seeded with the shipped inputs
        (the remote half of multi-locality graph replay)."""
        import jax

        dev = self._device(payload.get("device"))
        nodes = payload["nodes"]
        in_syms = payload["in_syms"]
        out_syms = payload["out_syms"]
        inputs = payload["inputs"]

        def _exec():
            env = {s: jax.device_put(x, dev.jax_device) for s, x in zip(in_syms, inputs)}
            for node in nodes:
                pgid = node.get("program")
                if pgid is not None:
                    fn = self._program(pgid)._bind(node["kernel"], node.get("grid"), node.get("block"))
                else:
                    fn = _bind_geometry(resolve_kernel(node["kernel"]), node.get("grid"), node.get("block"))
                vals = [env[v] if tag == "sym" else v for tag, v in node["args"]]
                res = fn(*vals)
                res_list = list(res) if isinstance(res, (tuple, list)) else [res]
                for s, v in zip(node["res"], res_list):
                    env[s] = v
            return [np.asarray(env[s]) for s in out_syms]

        return dev.ops_queue.submit(_exec).get()

    def shutdown(self) -> None:
        objects, self._objects = list(self._objects.values()), {}
        for obj in objects:
            free = getattr(obj, "free", None)
            if free is not None:
                try:
                    free()
                except Exception:  # noqa: BLE001 - teardown is best-effort
                    pass


# ---------------------------------------------------------------------------
# transports
# ---------------------------------------------------------------------------

# Locality ids are unique across every port this process ever creates, so
# two ports' workers can never mint colliding proxy GIDs in our registry.
_locality_counter = itertools.count(1)
_live_ports: "weakref.WeakSet" = weakref.WeakSet()


def _next_locality_id() -> int:
    return next(_locality_counter)


def _shutdown_all_ports() -> None:
    """Drain and stop every live parcelport (called by ``reset_runtime``:
    worker processes must never outlive the runtime that owns their proxy
    queues)."""
    for port in list(_live_ports):
        try:
            port.shutdown()
        except Exception:  # noqa: BLE001 - reset must not fail on teardown
            pass


class Parcelport:
    """Transport interface: named-action requests to remote localities.

    Concrete transports implement ``call`` (async request, future of the
    reply's value), ``alive`` and ``shutdown``; discovery results are
    exposed as ``localities()`` — a list of ``Locality`` groups whose
    devices are ``RemoteDevice`` proxies routing through this port.
    """

    in_process = False

    def __init__(self):
        self._localities: "list" = []
        self._schedulers: dict = {}
        self._shut = False
        self._fault_filter: "Callable | None" = None
        _live_ports.add(self)

    # -- fault injection ------------------------------------------------------

    def set_fault_filter(self, fn: "Callable | None") -> None:
        """Install (or clear, with ``None``) a chaos hook consulted on every
        outbound parcel: ``fn(locality_id, action) -> None`` passes the
        parcel through, ``("drop", exc)`` fails it with ``exc`` without
        sending, ``("delay", seconds)`` sleeps on the sender before the
        send — FIFO-preserving, because later parcels on the same channel
        queue behind the delay.  Installed by ``repro.fault.inject``; the
        transport itself stays deterministic."""
        self._fault_filter = fn

    def _fault_verdict(self, locality_id: int, action: str):
        """None to proceed, or the exception an injected drop fails with.
        Injected delays are served here (on the sending thread)."""
        fn = self._fault_filter
        if fn is None:
            return None
        verdict = fn(locality_id, action)
        if verdict is None:
            return None
        if verdict[0] == "delay":
            time.sleep(float(verdict[1]))
            return None
        return verdict[1]  # ("drop", exc)

    # -- transport surface (implemented by subclasses) ----------------------

    def call(self, locality_id: int, action: str, payload: dict):
        raise NotImplementedError

    def call_sync(self, locality_id: int, action: str, payload: dict):
        return self.call(locality_id, action, payload).get()

    def alive(self, locality_id: int) -> bool:
        return not self._shut

    def shutdown(self) -> None:
        self._shut = True

    # -- discovery / placement ----------------------------------------------

    def localities(self) -> list:
        """Remote localities reachable through this port (HPX
        ``find_all_localities``, minus the caller's own)."""
        return list(self._localities)

    def devices(self) -> list:
        return [d for loc in self._localities for d in loc]

    def scheduler(self, policy: "str | Any" = "percolation", include_local: bool = True):
        """A ``Scheduler`` over the cluster-wide ``localities × devices``
        grid (local fleet + every remote device), cached per policy."""
        from repro.core.scheduler import Scheduler

        key = (policy if isinstance(policy, str) else id(policy), include_local)
        sched = self._schedulers.get(key)
        if sched is None:
            fleet = []
            if include_local:
                from repro.core.device import get_all_devices

                fleet.extend(get_all_devices().get())
            fleet.extend(self.devices())
            sched = self._schedulers[key] = Scheduler(fleet, policy=policy)
        return sched

    def _wrap_discovery(self, locality_id: int, descriptors: list) -> None:
        from repro.core.device import Locality, RemoteDevice

        devs = [
            RemoteDevice(
                self,
                locality_id,
                d["key"],
                platform=d.get("platform", "cpu"),
                capability=tuple(d.get("capability", (1, 0))),
            )
            for d in descriptors
        ]
        self._localities.append(Locality(locality_id, devs))

    def _retire_proxies(self) -> None:
        from repro.core import agas

        for loc in self._localities:
            for dev in loc:
                agas.registry.unregister(dev.gid)
        self._localities = []
        self._schedulers = {}


class LoopbackParcelport(Parcelport):
    """In-process transport: N simulated remote localities, zero deps.

    Every request is *really* encoded and decoded (both ways), and every
    locality executes on its own serial queue — so the full parcel path
    (codec, action dispatch, reply resolution, proxy objects) is exercised
    without any process machinery.  Simulated localities share this
    process's devices and AGAS registry; placement records of objects they
    create therefore keep local device keys (the one observable difference
    from a real cluster).
    """

    in_process = True

    def __init__(self, n_localities: int = 1):
        super().__init__()
        from repro.core.executor import get_runtime

        rt = get_runtime()
        self._servers: "dict[int, ActionServer]" = {}
        self._queues: dict = {}
        self._pid = itertools.count(1)
        self._dead: "set[int]" = set()
        for _ in range(n_localities):
            lid = _next_locality_id()
            self._servers[lid] = ActionServer(lid)
            self._queues[lid] = rt.queue(f"parcelport:loopback:L{lid}")
            self._wrap_discovery(lid, self._servers[lid].handle("discover", {}))

    def call(self, locality_id: int, action: str, payload: dict):
        from repro.core.futures import Future

        if self._shut:
            return Future.failed(RuntimeError(f"parcelport is shut down; parcel {action!r} dropped"))
        server = self._servers.get(locality_id)
        if server is None:
            return Future.failed(KeyError(f"no locality L{locality_id} on this parcelport"))
        if locality_id in self._dead:
            return Future.failed(RuntimeError(
                f"parcel {action!r} to locality L{locality_id} failed fast: "
                "locality killed (fault injection); it is excluded from placement"))
        exc = self._fault_verdict(locality_id, action)
        if exc is not None:
            return Future.failed(exc)
        blob = encode_parcel(Parcel(action, payload, next(self._pid), locality_id))

        def _serve():
            req = decode_parcel(blob)
            try:
                rep = Parcel("reply", {"value": server.handle(req.action, req.payload)}, req.pid, locality_id)
            except BaseException as e:  # noqa: BLE001 - errors travel as parcels
                rep = Parcel("reply", {"error": e}, req.pid, locality_id, ok=False)
            rep = decode_parcel(encode_parcel(rep))  # the reply round-trips too
            if not rep.ok:
                raise rep.payload["error"]
            return rep.payload.get("value")

        return self._queues[locality_id].submit(_serve)

    def alive(self, locality_id: int) -> bool:
        return (
            not self._shut
            and locality_id in self._servers
            and locality_id not in self._dead
        )

    def kill(self, locality_id: int) -> None:
        """Simulate worker death on an in-process fleet: subsequent parcels
        fail fast and ``alive()`` reads False until ``revive`` — the chaos
        analogue of a cluster worker's process exit."""
        self._dead.add(locality_id)

    def revive(self, locality_id: int) -> None:
        """Re-admit a killed locality (the recovered-worker path)."""
        self._dead.discard(locality_id)

    def shutdown(self) -> None:
        if self._shut:
            return
        self._shut = True
        for server in self._servers.values():
            server.shutdown()
        self._retire_proxies()


# -- cluster transport -------------------------------------------------------


def _cluster_worker_main(locality_id: int, rx, tx, shm_replies: bool = True) -> None:
    """Entry point of one spawned worker process: one remote locality.

    Owns its own JAX runtime, ``Runtime``/``WorkQueue``s and AGAS registry
    (GIDs minted under ``locality_id``).  The receive loop answers pings
    inline (process liveness, not business progress) and runs every other
    action on a single-thread executor, preserving arrival order while
    keeping the heartbeat responsive during long launches.  A ``multi``
    parcel (coalesced channel flush) is unpacked here and its sub-parcels
    submitted in order — arrival-order execution, one wire hop.  Reply
    arrays ride the shared-memory lane when available (``shm_replies``
    mirrors the parent port's setting).

    ``rx``/``tx`` are raw ``multiprocessing`` pipe connections carrying
    already-encoded parcel blobs (``send_bytes``/``recv_bytes``: no
    pickle layer, no ``mp.Queue`` feeder thread).  An empty message is
    the hard-stop sentinel; a closed pipe (parent gone) ends the loop.
    """
    import concurrent.futures as _cf

    from repro.core import agas

    txlock = threading.Lock()  # replies come from the pool AND the rx loop

    def _send(blob: bytes) -> None:
        with txlock:
            tx.send_bytes(blob)

    agas.set_locality_id(locality_id)
    try:
        server = ActionServer(locality_id)
        hello = Parcel("hello", {"devices": server.handle("discover", {}), "os_pid": os.getpid()}, 0, locality_id)
        _send(encode_parcel(hello))
    except BaseException as e:  # noqa: BLE001 - surface startup failure to parent
        _send(encode_parcel(Parcel("hello", {"error": e}, 0, locality_id, ok=False)))
        return

    pool = _cf.ThreadPoolExecutor(max_workers=1, thread_name_prefix=f"parcel-L{locality_id}")
    use_shm = bool(shm_replies) and shm_available()
    tracker = _ShmTracker() if use_shm else None

    def _reply(pid: int, value=None, error=None) -> None:
        if error is None:
            rep = Parcel("reply", {"value": value}, pid, locality_id)
        else:
            rep = Parcel("reply", {"error": error}, pid, locality_id, ok=False)
        sink: "list | None" = [] if use_shm else None
        try:
            blob = encode_parcel(rep, shm_sink=sink)
        except Exception as e:  # noqa: BLE001 - unencodable reply value
            blob = encode_parcel(
                Parcel("reply", {"error": RemoteError(f"unencodable reply: {e}")}, pid, locality_id, ok=False)
            )
        if sink:
            tracker.add(sink)
        try:
            _send(blob)
        except (BrokenPipeError, OSError):  # parent gone: nothing to reply to
            pass

    def _work(req: Parcel) -> None:
        try:
            _reply(req.pid, value=server.handle(req.action, req.payload))
        except BaseException as e:  # noqa: BLE001 - errors travel as parcels
            _reply(req.pid, error=e)

    def _work_blob(blob: bytes) -> None:
        try:
            req = decode_parcel(blob)
        except BaseException as e:  # noqa: BLE001 - no pid to reply to
            del e
            return
        _work(req)

    req: "Parcel | None" = None
    while True:
        try:
            blob = rx.recv_bytes()
        except (EOFError, OSError):  # parent closed its end / died
            req = None
            break
        if not blob:  # empty message: hard-stop sentinel
            req = None
            break
        try:
            req = decode_parcel(blob)
        except BaseException:  # noqa: BLE001 - undecodable: no pid to reply to
            continue
        if req.action == "shutdown":
            break
        if req.action == "ping":
            _reply(req.pid, value="pong")  # answered inline: liveness signal
            continue
        if req.action == "multi":
            # One coalesced channel flush: sub-parcels keep their staging
            # order (the pool is single-threaded), each replying alone.
            # Sub-decode runs ON the pool: a shared-memory import must not
            # stall the receive loop (heartbeat stays responsive).
            for sub in req.payload["parcels"]:
                pool.submit(_work_blob, sub)
            continue
        pool.submit(_work, req)
    # Orderly drain before the shutdown reply: queued work finishes (and
    # its reply segments get consumed or tracked), THEN still-unconsumed
    # reply segments are unlinked so nothing outlives the worker.
    pool.shutdown(wait=True)
    if req is not None and req.action == "shutdown":
        _reply(req.pid, value=None)
    server.shutdown()
    if tracker is not None:
        tracker.purge()


class _ClusterWorker:
    __slots__ = ("locality_id", "proc", "tx", "rx", "txlock", "heartbeat", "pending", "lock",
                 "dead", "death_reason", "sendbuf", "sendlock", "shm_names")

    def __init__(self, locality_id, proc, tx, rx, heartbeat):
        self.locality_id = locality_id
        self.proc = proc
        self.tx = tx  # parent -> worker pipe connection (blobs out)
        self.rx = rx  # worker -> parent pipe connection (replies in)
        self.txlock = threading.Lock()
        self.heartbeat = heartbeat
        self.pending: "dict[int, tuple[str, Any]]" = {}
        self.lock = threading.Lock()
        self.dead = False
        self.death_reason = ""
        self.sendbuf: "list[tuple[int, bytes]]" = []  # staged, awaiting flush
        self.sendlock = threading.Lock()
        self.shm_names = _ShmTracker()  # segments sent, maybe unconsumed


class LocalClusterParcelport(Parcelport):
    """N worker processes, each a real remote locality (own interpreter,
    own JAX runtime, own ``Runtime``/``WorkQueue``s, own AGAS registry).

    Transport is a pair of one-way ``multiprocessing`` pipes per worker
    carrying already-encoded parcel blobs (``send_bytes``/``recv_bytes``
    — no pickle layer, no ``mp.Queue`` feeder threads; large arrays side-
    step the pipe entirely through the shared-memory lane, the blob then
    carrying only segment name + dtype + shape).  Workers start via
    *spawn* (never fork: the parent's
    JAX/XLA threads must not be duplicated into a child).  A per-worker
    ``fault.monitor.Heartbeat`` is ticked by every reply; a monitor thread
    pings each worker and checks deadlines — a dead worker fails its
    pending parcels fast and its devices report ``alive() == False`` so
    the scheduler stops placing there.
    """

    def __init__(
        self,
        n_workers: int = 2,
        heartbeat_timeout: float = 30.0,
        startup_timeout: float = 180.0,
        name: str = "cluster",
        shm: "bool | None" = None,
        pipeline: "bool | None" = None,
    ):
        super().__init__()
        import multiprocessing as mp

        from repro.fault.monitor import Heartbeat

        self.name = name
        self.heartbeat_timeout = float(heartbeat_timeout)
        # Shared-memory array lane: on when the host supports it (None =
        # auto-probe; REPRO_PARCEL_SHM=off wins over an explicit True).
        self._shm_ok = shm_available() if shm is None else (bool(shm) and shm_available())
        # Pipelined channels: senders stage + flush without blocking on
        # replies (arrival order at the worker preserves channel FIFO).
        if pipeline is None:
            pipeline = os.environ.get("REPRO_PARCEL_PIPELINE", "auto").lower() != "off"
        self.pipelined = bool(pipeline)
        ctx = mp.get_context("spawn")
        self._workers: "dict[int, _ClusterWorker]" = {}
        self._pid = itertools.count(1)
        self._stop = threading.Event()
        self._threads: "list[threading.Thread]" = []
        for _ in range(n_workers):
            lid = _next_locality_id()
            # Two one-way pipes per worker: raw blob bytes, no mp.Queue
            # feeder thread between send and wire (2 fewer threads per
            # worker, roughly one third the round-trip latency).
            c2w_rx, c2w_tx = ctx.Pipe(duplex=False)  # parent -> worker
            w2p_rx, w2p_tx = ctx.Pipe(duplex=False)  # worker -> parent
            proc = ctx.Process(
                target=_cluster_worker_main,
                args=(lid, c2w_rx, w2p_tx, self._shm_ok),
                daemon=True,
                name=f"parcel-worker-L{lid}",
            )
            proc.start()
            # Close the child's ends here so EOF propagates when a side dies.
            c2w_rx.close()
            w2p_tx.close()
            hb = Heartbeat(timeout_s=self.heartbeat_timeout)
            hb.on_dead = self._make_on_dead(lid)
            self._workers[lid] = _ClusterWorker(lid, proc, c2w_tx, w2p_rx, hb)
        try:
            import time as _time

            for w in self._workers.values():
                deadline = _time.monotonic() + startup_timeout
                while True:  # poll so a worker that dies during startup fails fast
                    try:
                        if w.rx.poll(0.5):
                            hello = decode_parcel(w.rx.recv_bytes())
                            break
                        raise _queue.Empty
                    except _queue.Empty:
                        if not w.proc.is_alive():
                            raise RuntimeError(
                                f"worker L{w.locality_id} died during startup "
                                f"(exit code {w.proc.exitcode})"
                            ) from None
                        if _time.monotonic() > deadline:
                            raise TimeoutError(
                                f"worker L{w.locality_id} sent no hello within {startup_timeout}s"
                            ) from None
                if not hello.ok or "error" in hello.payload:
                    raise RuntimeError(
                        f"worker L{w.locality_id} failed to start: {hello.payload.get('error')}"
                    )
                self._wrap_discovery(w.locality_id, hello.payload["devices"])
                w.heartbeat.tick()
        except BaseException:
            self.shutdown()
            raise
        for w in self._workers.values():
            t = threading.Thread(target=self._listen, args=(w,), daemon=True, name=f"parcel-rx-L{w.locality_id}")
            t.start()
            self._threads.append(t)
        t = threading.Thread(target=self._monitor, daemon=True, name=f"parcel-hb:{name}")
        t.start()
        self._threads.append(t)

    # -- liveness ------------------------------------------------------------

    def _make_on_dead(self, locality_id: int):
        return lambda: self._mark_dead(locality_id, f"missed its heartbeat deadline ({self.heartbeat_timeout}s)")

    def _mark_dead(self, locality_id: int, reason: str) -> None:
        w = self._workers.get(locality_id)
        if w is None:
            return
        with w.lock:
            if w.dead:
                return
            w.dead = True
            w.death_reason = f"locality L{locality_id} {reason}"
            pending, w.pending = dict(w.pending), {}
        # Queued parcels fail fast, each naming its action and the cause.
        for action, promise in pending.values():
            promise.set_exception(
                RuntimeError(
                    f"parcel {action!r} to locality L{locality_id} failed: {w.death_reason}; "
                    "the locality is excluded from placement"
                )
            )
        # A dead worker will never consume its in-flight shm segments.
        w.shm_names.purge()

    def _mark_recovered(self, w: "_ClusterWorker") -> None:
        """Re-admit a heartbeat-flapped locality: the dead latch cleared
        (the worker ticked again), so lift the fail-fast gate too —
        ``alive()`` turns true and the scheduler re-includes the locality
        in placement on its next decision (it re-reads liveness every
        time; there is no exclusion set to clear).  PR 5 cleared only the
        ``Heartbeat`` latch; without this the port-level ``dead`` flag
        stayed latched and a recovered worker took no new work forever.
        Process-exit deaths never reach here (the process is gone)."""
        with w.lock:
            if not w.dead:
                return
            w.dead = False
            w.death_reason = ""

    def alive(self, locality_id: int) -> bool:
        w = self._workers.get(locality_id)
        return w is not None and not w.dead and not self._shut

    # -- wire threads --------------------------------------------------------

    def _listen(self, w: _ClusterWorker) -> None:
        while not self._stop.is_set():
            try:
                if not w.rx.poll(0.25):
                    if w.dead and not w.proc.is_alive():
                        # Process gone: no more replies, ever.  A worker
                        # that is merely heartbeat-dead keeps its listener
                        # — a late reply is the recovery signal.
                        return
                    continue
                blob = w.rx.recv_bytes()
            except (EOFError, OSError):
                return
            w.heartbeat.tick()  # any reply is proof of life
            rep = decode_parcel(blob)
            with w.lock:
                entry = w.pending.pop(rep.pid, None)
            if entry is None:
                continue
            _, promise = entry
            if rep.ok:
                promise.set_value(rep.payload.get("value"))
            else:
                promise.set_exception(rep.payload["error"])

    def _probe(self, w: "_ClusterWorker") -> None:
        """Recovery ping that bypasses the dead-worker fail-fast gate: no
        pending entry is registered (the reply's heartbeat tick IS the
        signal; the unmatched pid is dropped by ``_listen``)."""
        try:
            blob = encode_parcel(Parcel("ping", {}, next(self._pid), w.locality_id))
            with w.txlock:
                w.tx.send_bytes(blob)
        except Exception:  # noqa: BLE001 - pipe gone; the exit path handles it
            pass

    def _monitor(self) -> None:
        interval = min(2.0, max(0.05, self.heartbeat_timeout / 4.0))
        while not self._stop.wait(interval):
            for w in list(self._workers.values()):
                if w.dead:
                    if not w.proc.is_alive():
                        continue  # permanent: the process exited
                    # Heartbeat deaths are a latch on a LIVE process — a
                    # stalled worker that resumes should flow work again.
                    # Probe past the fail-fast gate; once a reply ticks
                    # the heartbeat, check() clears the latch and the
                    # locality is re-admitted.
                    self._probe(w)
                    if w.heartbeat.check():
                        self._mark_recovered(w)
                    continue
                if not w.proc.is_alive():
                    self._mark_dead(
                        w.locality_id, f"worker process exited with code {w.proc.exitcode}"
                    )
                    continue
                try:
                    self.call(w.locality_id, "ping", {})  # reply ticks the heartbeat
                except Exception:  # noqa: BLE001
                    pass
                w.heartbeat.check()  # fires on_dead on a missed deadline

    # -- transport -----------------------------------------------------------

    def call(self, locality_id: int, action: str, payload: dict):
        from repro.core.futures import Future, Promise

        if self._shut:
            return Future.failed(RuntimeError(f"parcelport {self.name!r} is shut down; parcel {action!r} dropped"))
        w = self._workers.get(locality_id)
        if w is None:
            return Future.failed(KeyError(f"no locality L{locality_id} on parcelport {self.name!r}"))
        exc = self._fault_verdict(locality_id, action)
        if exc is not None:
            return Future.failed(exc)
        pid = next(self._pid)
        promise: Promise = Promise(name=f"parcel:{action}:L{locality_id}")
        with w.lock:
            if w.dead:
                return Future.failed(
                    RuntimeError(f"parcel {action!r} to locality L{locality_id} failed fast: {w.death_reason}")
                )
            w.pending[pid] = (action, promise)
        sink: "list | None" = [] if self._shm_ok else None
        try:
            blob = encode_parcel(Parcel(action, payload, pid, locality_id), shm_sink=sink)
            if sink:
                w.shm_names.add(sink)
            with w.txlock:
                w.tx.send_bytes(blob)
        except BaseException as e:  # noqa: BLE001 - pipe torn down under us
            with w.lock:
                w.pending.pop(pid, None)
            return Future.failed(RuntimeError(f"parcel {action!r} to L{locality_id} could not be sent: {e}"))
        return promise.get_future()

    def stage(self, locality_id: int, action: str, payload: dict, promise) -> None:
        """Pipelined half-send: encode NOW (shared-memory exports included),
        register the reply promise, and buffer the parcel for the next
        ``flush``.  Unlike ``call``+``get``, a staged parcel never blocks
        its channel on the reply — channel FIFO holds end-to-end because
        staging order is flush order is worker arrival order (the worker
        executes actions on one thread, in arrival order)."""
        if self._shut:
            promise.set_exception(
                RuntimeError(f"parcelport {self.name!r} is shut down; parcel {action!r} dropped"))
            return
        w = self._workers.get(locality_id)
        if w is None:
            promise.set_exception(KeyError(f"no locality L{locality_id} on parcelport {self.name!r}"))
            return
        exc = self._fault_verdict(locality_id, action)
        if exc is not None:
            promise.set_exception(exc)
            return
        pid = next(self._pid)
        with w.lock:
            if w.dead:
                promise.set_exception(
                    RuntimeError(f"parcel {action!r} to locality L{locality_id} failed fast: {w.death_reason}"))
                return
            w.pending[pid] = (action, promise)
        sink: "list | None" = [] if self._shm_ok else None
        try:
            blob = encode_parcel(Parcel(action, payload, pid, locality_id), shm_sink=sink)
        except BaseException as e:  # noqa: BLE001 - unencodable payload
            with w.lock:
                w.pending.pop(pid, None)
            promise.set_exception(e)
            return
        if sink:
            w.shm_names.add(sink)
        with w.sendlock:
            w.sendbuf.append((pid, blob))

    def flush(self, locality_id: int) -> None:
        """Ship every parcel staged since the last flush as ONE queue hop:
        a single parcel goes as itself, several go as one ``multi`` parcel
        the worker unpacks in staging order (parcel coalescing)."""
        w = self._workers.get(locality_id)
        if w is None:
            return
        with w.sendlock:
            if not w.sendbuf:
                return  # an earlier flush already took them
            batch, w.sendbuf = w.sendbuf, []
        try:
            if len(batch) == 1:
                blob = batch[0][1]
            else:
                blob = encode_parcel(
                    Parcel("multi", {"parcels": [b for _, b in batch]}, 0, locality_id))
            with w.txlock:
                w.tx.send_bytes(blob)
        except BaseException as e:  # noqa: BLE001 - pipe torn down under us
            entries = []
            with w.lock:
                for pid, _ in batch:
                    entries.append(w.pending.pop(pid, None))
            for entry in entries:
                if entry is not None:
                    entry[1].set_exception(
                        RuntimeError(f"parcel {entry[0]!r} to L{locality_id} could not be sent: {e}"))

    # -- teardown ------------------------------------------------------------

    def shutdown(self) -> None:
        if self._shut:
            return
        self._shut = True
        self._stop.set()
        for w in self._workers.values():
            if w.proc.is_alive():
                try:
                    with w.txlock:
                        w.tx.send_bytes(
                            encode_parcel(Parcel("shutdown", {}, next(self._pid), w.locality_id)))
                except Exception:  # noqa: BLE001
                    pass
        for w in self._workers.values():
            w.proc.join(timeout=5)
            if w.proc.is_alive():
                w.proc.terminate()
                w.proc.join(timeout=2)
            self._mark_dead(w.locality_id, "parcelport shut down")
            for conn in (w.tx, w.rx):
                try:
                    conn.close()
                except Exception:  # noqa: BLE001
                    pass
            # Worker has exited (joined above): anything it never consumed
            # is ours to unlink; racing its decoder is no longer possible.
            w.shm_names.purge()
        self._retire_proxies()
