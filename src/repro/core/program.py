"""Program: runtime-compiled device code (paper §4, Fig. 2 ``program``).

The NVRTC analogue on TPU/JAX is the JIT itself: ``build`` runs
``jax.jit(kernel).lower(specs).compile()`` asynchronously on the device's
*compile* queue, so compilation overlaps data transfers exactly like
Listing 2 (copies and ``prog.build`` futures run concurrently, joined by
``wait_all``).  Compiled executables are cached per (kernel, shapes, grid,
block).

Launch semantics keep HPXCL's user-visible tuning knobs: ``grid`` and
``block`` (``Dim3``) are forwarded to kernels that accept them (our Pallas
kernels map them onto grid/BlockSpec tiling — the TPU equivalent of CUDA
launch geometry, DESIGN.md §2).

Percolation: ``run`` executes where the program's device is; argument
buffers living on other devices are first moved there with async copies
(futures), never blocking the caller.  Executables are pinned to the
program's device (input shardings fixed at lowering), so a launch really
runs *there*, not wherever XLA's default placement lands.

``run_on_any`` (DESIGN.md §9) is the scheduler-routed launch: a placement
policy picks the device, the program's per-device *sibling* (same kernels,
compiled for that device — the paper's "any kernel on any device") runs
it, and argument percolation plus ``out``-buffer re-homing happen
automatically.  This is §3 percolation done by policy instead of by hand.

Hot-path notes (DESIGN.md §8): signature inspection is done once per
kernel (``inspect.signature`` costs ~10 µs — far more than a queue hop),
bound callables are cached per (kernel, grid, block), and the executable
cache key hashes interned dtype objects instead of ``str(dtype)``.
Inside a ``graph.capture()`` region ``run`` records a symbolic node
instead of executing (CUDA-Graphs stream capture analogue).
"""
from __future__ import annotations

import importlib.util
import inspect
import weakref
from dataclasses import dataclass
from typing import Any, Callable, Optional, Sequence

import jax
import numpy as np

from repro.core.buffer import Buffer
from repro.core.futures import Future, dataflow, when_all

__all__ = ["Dim3", "Program", "RemoteProgram"]


def _is_remote_buffer(a: Any) -> bool:
    return getattr(a, "is_remote_buffer", False)


@dataclass
class Dim3:
    """CUDA-style launch geometry, kept user-visible (paper's philosophy)."""

    x: int = 1
    y: int = 1
    z: int = 1

    def as_tuple(self) -> "tuple[int, int, int]":
        return (self.x, self.y, self.z)


def pin_specs(specs, jax_device) -> list:
    """``ShapeDtypeStruct``s with shardings pinned to one device.

    Pinned lowering is what makes a launch execute where its program (or
    graph segment) lives instead of on XLA's default device; older jax
    without sharding-carrying specs falls back to default placement.
    Shared by ``Program.build`` and graph segment compilation so the
    compat behavior cannot diverge between the two launch paths.
    """
    specs = [
        s if isinstance(s, jax.ShapeDtypeStruct) else jax.ShapeDtypeStruct(s.shape, s.dtype)
        for s in specs
    ]
    try:
        sharding = jax.sharding.SingleDeviceSharding(jax_device)
        return [jax.ShapeDtypeStruct(sp.shape, sp.dtype, sharding=sharding) for sp in specs]
    except (AttributeError, TypeError):  # older jax: default placement
        return specs


def _normalize_dim(d) -> "tuple[int, ...] | None":
    if d is None:
        return None
    if isinstance(d, Dim3):
        return d.as_tuple()
    if isinstance(d, int):
        return (d, 1, 1)
    return tuple(d)


class Program:
    """A named set of kernels compiled on demand for one device."""

    def __init__(self, device, kernels, name: str = "program"):
        from repro.core import agas

        if callable(kernels) and not isinstance(kernels, dict):
            kernels = {getattr(kernels, "__name__", "kernel"): kernels}
        self.device = device
        self.name = name
        self._kernels: "dict[str, Callable]" = dict(kernels)
        self._cache: "dict[tuple, Any]" = {}
        self._build_futures: "dict[tuple, Future]" = {}
        # Hot-path caches: geometry-kwarg names per kernel (inspect.signature
        # once, not per launch) and bound callables per (name, grid, block).
        self._geo_params: "dict[str, tuple[bool, bool]]" = {}
        self._bound_cache: "dict[tuple, Callable]" = {}
        # Per-device sibling programs (run_on_any targets), device.key -> Program.
        self._siblings: "dict[str, Program]" = {}
        self.gid = agas.registry.register(
            self, agas.Placement(device.key, device.jax_device.process_index), kind="program"
        )
        # GC-safe AGAS retirement (same leak fix as Buffer): the registry
        # must not pin dead programs forever.
        self._finalizer = weakref.finalize(self, agas.registry.unregister, self.gid)

    # -- construction ---------------------------------------------------------

    @staticmethod
    def from_file(device, path: str) -> "Program":
        """Load kernels from a python source file defining ``KERNELS``.

        This is the percolation path for *code*: source is loaded and
        runtime-compiled at the device that will execute it
        (``create_program_with_file("kernel.cu")`` analogue).
        """
        spec = importlib.util.spec_from_file_location(f"repro_kernel_{abs(hash(path))}", path)
        mod = importlib.util.module_from_spec(spec)
        spec.loader.exec_module(mod)  # type: ignore[union-attr]
        kernels = getattr(mod, "KERNELS", None)
        if kernels is None:
            raise ValueError(f"{path} does not define KERNELS = {{name: callable}}")
        return Program(device, kernels, name=path)

    def kernel_names(self) -> "list[str]":
        return sorted(self._kernels)

    def for_device(self, device) -> "Program":
        """This program's sibling on ``device`` (cached; self if home).

        Siblings share the kernel sources but keep their own compile
        caches — the "any kernel on any device" half of run_on_any: the
        same source percolates to whatever device the policy picks and is
        runtime-compiled there (NVRTC-per-device analogue).
        """
        if device is self.device or device.key == self.device.key:
            return self
        sib = self._siblings.get(device.key)
        if sib is None:
            if getattr(device, "is_remote_proxy", False):
                sib = RemoteProgram(device, self._kernels, name=f"{self.name}@{device.key}")
            else:
                sib = Program(device, self._kernels, name=f"{self.name}@{device.key}")
            sib = self._siblings.setdefault(device.key, sib)  # racing creator loses
        return sib

    # -- build (async runtime compilation) -------------------------------------

    def _geometry_of(self, name: str) -> "tuple[bool, bool]":
        """(accepts_grid, accepts_block) — computed once per kernel."""
        geo = self._geo_params.get(name)
        if geo is None:
            params = inspect.signature(self._kernels[name]).parameters
            geo = self._geo_params[name] = ("grid" in params, "block" in params)
        return geo

    def _bind(self, name: str, grid, block) -> Callable:
        """Bound callable for (kernel, normalized grid/block), cached."""
        grid_n, block_n = _normalize_dim(grid), _normalize_dim(block)
        bkey = (name, grid_n, block_n)
        bound = self._bound_cache.get(bkey)
        if bound is not None:
            return bound
        fn = self._kernels[name]
        has_grid, has_block = self._geometry_of(name)
        kwargs = {}
        if has_grid:
            kwargs["grid"] = grid_n
        if has_block:
            kwargs["block"] = block_n
        if kwargs:
            bound = lambda *args: fn(*args, **kwargs)  # noqa: E731
            bound.__name__ = name
        else:
            bound = fn
        self._bound_cache[bkey] = bound
        return bound

    def _key(self, name: str, specs, grid, block) -> tuple:
        # np.dtype objects are interned and hashable — hashing them directly
        # beats building str(dtype) per spec on every launch.
        sig = tuple((s.shape, s.dtype) for s in specs)
        return (name, sig, _normalize_dim(grid), _normalize_dim(block))

    def build(self, name: str, *specs, grid=None, block=None) -> Future:
        """Compile kernel ``name`` asynchronously (NVRTC analogue).

        With ``specs`` (``jax.ShapeDtypeStruct``/arrays) the executable is
        fully compiled and cached; without, the kernel is resolved/bound
        only (shape specialization then happens at first ``run``, still on
        the compile queue). Returns a future — a dependency for launches.
        """
        if name not in self._kernels:
            return Future.failed(KeyError(f"no kernel '{name}' in {self.name}"))
        if not specs:
            return self.device.compile_queue.submit(self._bind, name, grid, block)

        key = self._key(name, specs, grid, block)
        fut = self._build_futures.get(key)
        if fut is not None:
            return fut

        def _compile():
            compiled = self._cache.get(key)
            if compiled is None:
                bound = self._bind(name, grid, block)
                # Device-pinned lowering: a launch must execute where the
                # program lives (the paper's placement contract) — without
                # this, run_on_any siblings would all compile for device 0
                # and the scheduler would place nothing.
                arg_specs = pin_specs(specs, self.device.jax_device)
                try:
                    compiled = jax.jit(bound).lower(*arg_specs).compile()
                except jax.errors.JAXTypeError:
                    # Value-dependent kernel (shapes read from argument
                    # DATA, e.g. mandelbrot's int32[2] size vector): not
                    # traceable, so it runs eagerly — the NVRTC-refuses-
                    # to-compile path, degraded to interpretation.
                    compiled = bound
                self._cache[key] = compiled
            return compiled

        fut = self.device.compile_queue.submit(_compile)
        self._build_futures[key] = fut
        return fut

    # -- launch -----------------------------------------------------------------

    def run(
        self,
        args: "Sequence[Buffer | Any]",
        name: str,
        grid=None,
        block=None,
        out: "Sequence[Buffer] | None" = None,
        sync: str = "ready",
        stream=None,
    ):
        """Launch kernel ``name`` with buffer/array ``args`` (async).

        ``out``: buffers to receive the kernel's results (CUDA's mutate-
        in-place adapted to functional JAX) — the future resolves to them.
        Without ``out`` the future resolves to the raw result arrays.
        ``sync="ready"`` resolves at device completion (CUDA-event
        semantics); ``sync="dispatch"`` resolves at submission.
        ``stream`` scopes the submission order (DESIGN.md §11): the launch
        runs FIFO with that stream's other work and concurrently with the
        device's other streams; ``None`` means the default stream — the
        pre-stream single-queue semantics, unchanged.

        Inside a ``repro.core.graph.capture()`` region the launch is
        *recorded*, not executed: the return value is then the graph node
        (symbolic handle), and execution happens at ``replay()`` — capture
        ignores ``stream`` and assigns chains to streams itself at
        ``instantiate()`` (§11).
        """
        from repro.core.graph import current_graph

        g = current_graph()
        if g is not None:
            return g.run(self, args, name, grid=grid, block=block, out=out)

        home = self.device
        queue = home.ops_queue if stream is None else stream._lane_for(home)

        # Percolation: move foreign buffers to the program's device first.
        # A RemoteBuffer is always foreign to a local program — the move is
        # then an explicit cross-locality transfer (read parcel + device_put).
        moved: "dict[int, Future] | None" = None
        for i, a in enumerate(args):
            if (isinstance(a, Buffer) and a.device is not home) or _is_remote_buffer(a):
                if moved is None:
                    moved = {}
                moved[i] = a.copy_to(home)

        specs = [
            a.array() if isinstance(a, Buffer)
            else jax.ShapeDtypeStruct(a.shape, a.dtype) if _is_remote_buffer(a)
            else a
            for a in args
        ]
        build_fut = self.build(name, *specs, grid=grid, block=block)

        def _launch(compiled, *resolved_args):
            arg_list = list(args)
            if moved:
                for i, b in zip(moved.keys(), resolved_args):
                    arg_list[i] = b
            jd = home.jax_device
            vals = []
            for a in arg_list:
                v = a.array() if isinstance(a, Buffer) else a
                # Executables are device-pinned (see build): host values and
                # stragglers the percolation pass didn't cover land here.
                if not isinstance(v, jax.Array) or v.devices() != {jd}:
                    v = jax.device_put(v, jd)
                vals.append(v)
            res = compiled(*vals)
            if out is None:
                return res
            res_list = list(res) if isinstance(res, (tuple, list)) else [res]
            if len(res_list) != len(out):
                raise ValueError(
                    f"kernel '{name}' returned {len(res_list)} arrays for {len(out)} out buffers"
                )
            for b, v in zip(out, res_list):
                b._set_array(v)
                # Results live where they were computed; the handle follows
                # (location transparency: AGAS placement moves, GID doesn't).
                b._rehome(home)
            return list(out)

        # Order: (copies, build) -> ops-queue launch.  Non-percolating
        # launches enqueue on the ops queue *now* — compiled executables
        # run with one hop, uncompiled ones park the queue worker on the
        # build future (the compile queue never depends on the ops queue,
        # so this cannot deadlock).  Eager enqueue keeps the queue's depth
        # an honest load signal at submission time (DESIGN.md §9): the
        # scheduler sees a launch the moment it is placed, not after its
        # kernel finishes compiling.  (Head-of-line blocking during a cold
        # compile is accepted: per-device queues are in-order streams, and
        # a parked worker is exactly the backlog the signal should show.)
        # Percolating launches must not block
        # the worker (the copy lands *on this queue*), so they join via
        # dataflow off-queue; their depth shows up when the copy resolves.
        if moved is None:
            if build_fut.done():
                launched = queue.submit(_launch, build_fut.get())
            else:
                launched = queue.submit(lambda: _launch(build_fut.get()))
        else:

            def _enqueue(compiled, *resolved):
                return queue.submit(_launch, compiled, *resolved).get()

            launched = dataflow(_enqueue, build_fut, *moved.values(), name=f"run:{name}")

        if sync == "dispatch":
            # Dispatch-resolved future: stream events recorded after this
            # launch mean "dispatched", as cudaEventRecord would if the
            # work were still queued — completion events need sync="ready".
            if stream is not None:
                stream._note_completion(launched)
            return launched

        def _ready(res):
            vals = [b.array() for b in res] if out is not None else res
            jax.block_until_ready(vals)
            return res

        from repro.core.executor import get_runtime

        done = launched.then(_ready, executor=get_runtime().pool, name=f"done:{name}")
        if stream is not None:
            # Stream events must mean device completion (DESIGN.md §11):
            # the lane task ends at dispatch, this future at readiness.
            stream._note_completion(done)
        return done

    def launch(
        self,
        args: "Sequence[Buffer | Any]",
        name: str,
        grid=None,
        block=None,
        out: "Sequence[Buffer] | None" = None,
        sync: str = "ready",
        stream=None,
    ):
        """``run`` under its CUDA name — ``prog.launch([...], "k",
        stream=s)`` submits the kernel on stream ``s`` (``<<<grid, block,
        0, stream>>>``).  Identical semantics to ``run``."""
        return self.run(args, name, grid=grid, block=block, out=out, sync=sync, stream=stream)

    def run_on_any(
        self,
        args: "Sequence[Buffer | Any]",
        name: str,
        grid=None,
        block=None,
        out: "Sequence[Buffer] | None" = None,
        sync: str = "ready",
        scheduler=None,
        cluster=None,
    ):
        """Launch kernel ``name`` on whatever device the placement policy
        picks — the paper's "any kernel on any (local or remote) device",
        with §3 percolation done by policy instead of by hand.

        The scheduler (default: process scheduler, ``least_loaded``)
        chooses from its fleet; the launch runs through the per-device
        sibling program, foreign argument buffers percolate over, and
        ``out`` buffers are re-homed to the chosen device.  Semantics
        otherwise match ``run`` (works under graph capture too: the node
        records against the chosen device, giving multi-device graphs).

        ``cluster`` (a ``Parcelport``) widens the fleet to every locality
        the port reaches — ``hpx::async(locality, action)`` as a placement
        decision: the policy scores the full localities × devices grid
        (``percolation`` cost model by default), and a remote pick routes
        the launch through a ``RemoteProgram`` sibling as parcels.
        """
        from repro.core.graph import current_graph
        from repro.core.scheduler import get_scheduler

        if scheduler is not None:
            sched = scheduler
        elif cluster is not None:
            sched = cluster.scheduler()
        else:
            sched = get_scheduler()
        # Rebalancing path (DESIGN.md §14): with stealing enabled and more
        # than one device to balance, the launch parks in the scheduler's
        # steal pool so an idle sibling can take it if the placed device
        # falls behind.  Graph capture keeps the direct path — a recorded
        # node must bind its device at capture time.
        if current_graph() is None and getattr(sched, "steals", False):
            return sched.submit(self, args, name, grid=grid, block=block, out=out, sync=sync)
        dev = sched.select(args=args, program=self)
        return self.for_device(dev).run(args, name, grid=grid, block=block, out=out, sync=sync)


def _release_remote_program(port, locality_id: int, gid_future: "Future") -> None:
    """GC finalizer for RemoteProgram: best-effort free parcel so the
    worker's object table does not grow without bound.  Skips (rather than
    blocks) when the create reply never arrived."""
    try:
        if gid_future.done() and gid_future.exception() is None:
            port.call(locality_id, "free", {"gid": gid_future.get()})
    except Exception:  # noqa: BLE001 - teardown is best-effort
        pass


class RemoteProgram(Program):
    """Proxy for a program owned by a remote locality (DESIGN.md §10).

    Kernels percolate **by name**: construction sends a ``create_program``
    parcel listing kernel names, which the owning locality resolves
    through its own registry and runtime-compiles there (the NVRTC-at-the-
    device analogue, across a process boundary).  The callables kept here
    are *shadows* — used only for shape inference (``jax.eval_shape``
    during graph capture) and geometry binding; they never execute
    locally through this class.

    ``run`` turns into a ``launch`` parcel: locality-resident buffer
    arguments travel as GID references (zero copy), everything else is
    read back to the host and shipped inline; ``out`` buffers on the
    target locality keep results remote, local ``out`` buffers receive
    the reply arrays.  The reply parcel resolves the returned future —
    completion on the remote device, i.e. ``sync="ready"`` semantics.
    """

    def __init__(self, device, kernels, name: str = "program"):
        from repro.core.parcel import resolve_kernel

        if isinstance(kernels, str):
            kernels = [kernels]
        if callable(kernels) and not isinstance(kernels, dict):
            kernels = {getattr(kernels, "__name__", "kernel"): kernels}
        elif not isinstance(kernels, dict):
            kernels = {n: resolve_kernel(n) for n in kernels}
        super().__init__(device, kernels, name=name)
        self._remote_gid_f: Future = device._call(
            "create_program", kernels=list(self._kernels), name=name
        ).then(lambda rep: rep["gid"], executor="inline")
        # The owning locality holds its Program strongly in the action
        # server's object table; retire it when this proxy is collected
        # (same free parcel as buffers — _do_free pops any GID).
        self._remote_finalizer = weakref.finalize(
            self, _release_remote_program, device._port, device.locality_id, self._remote_gid_f
        )

    def remote_gid(self) -> int:
        """GID of the program object on the owning locality (blocks on the
        create reply the first time)."""
        return self._remote_gid_f.get()

    def build(self, name: str, *specs, grid=None, block=None) -> Future:
        """Remote runtime compilation (async): ships shape/dtype specs, the
        owning locality lowers and caches the executable there."""
        if name not in self._kernels:
            return Future.failed(KeyError(f"no kernel '{name}' in {self.name}"))
        spec_p = [(tuple(s.shape), np.dtype(s.dtype).str) for s in specs]
        dev = self.device
        port, loc = dev._port, dev.locality_id
        gid_f = self._remote_gid_f
        grid_n, block_n = _normalize_dim(grid), _normalize_dim(block)

        def _send():
            return port.call_sync(loc, "build", {
                "device": dev.remote_key, "program": gid_f.get(), "kernel": name,
                "specs": spec_p, "grid": grid_n, "block": block_n,
            })

        return dev.compile_queue.submit(_send)

    def run(
        self,
        args: "Sequence[Buffer | Any]",
        name: str,
        grid=None,
        block=None,
        out: "Sequence[Buffer] | None" = None,
        sync: str = "ready",
        stream=None,
    ):
        from repro.core.graph import current_graph

        g = current_graph()
        if g is not None:
            return g.run(self, args, name, grid=grid, block=block, out=out)
        if name not in self._kernels:
            return Future.failed(KeyError(f"no kernel '{name}' in {self.name}"))

        dev = self.device
        port, loc = dev._port, dev.locality_id
        # Stream-scoped remote launch: the parcel rides that stream's
        # ordered channel instead of the default one (DESIGN.md §11).
        lane = dev.ops_queue if stream is None else stream._lane_for(dev)

        # Argument descriptors: locality-resident buffers go as GID refs;
        # everything else materializes on the host and ships inline.
        descs: "list" = [None] * len(args)
        fetch_ix: "list[int]" = []
        fetch_futs: "list[Future]" = []
        for i, a in enumerate(args):
            if _is_remote_buffer(a) and a.device.locality_id == loc:
                descs[i] = ("gid", a.gid)
            elif isinstance(a, Buffer) or _is_remote_buffer(a):
                fetch_ix.append(i)
                fetch_futs.append(a.enqueue_read())
            elif isinstance(a, jax.Array):
                descs[i] = ("val", np.asarray(a))
            else:
                descs[i] = ("val", a)

        if out is None:
            out_gids, mode = None, "none"
        elif all(_is_remote_buffer(b) and b.device.locality_id == loc for b in out):
            out_gids, mode = [b.gid for b in out], "remote"
        elif all(isinstance(b, Buffer) for b in out):
            out_gids, mode = None, "local"
        else:
            raise ValueError(
                "out buffers of a remote launch must either all live on the "
                "target locality (results stay remote) or all be local "
                "buffers (results ship back)"
            )

        grid_n, block_n = _normalize_dim(grid), _normalize_dim(block)
        gid_f = self._remote_gid_f

        def _post(rep):
            if mode == "remote":
                return list(out)
            if mode == "local":
                for b, v in zip(out, rep):
                    b._set_array(jax.device_put(np.asarray(v), b.device.jax_device))
                return list(out)
            return rep

        def _payload(vals):
            for i, v in zip(fetch_ix, vals):
                descs[i] = ("val", np.asarray(v))
            return {
                "device": dev.remote_key, "program": gid_f.get(), "kernel": name,
                "args": descs, "out": out_gids, "grid": grid_n, "block": block_n,
            }

        # Pipelined port: the channel task stages+flushes the launch parcel
        # and releases the lane immediately — the reply resolves the result
        # future asynchronously, so back-to-back remote launches overlap on
        # the wire instead of serializing on round trips.
        if getattr(port, "pipelined", False):
            from repro.core.executor import get_runtime
            from repro.core.futures import Promise, forward_failure

            inner: "Promise" = Promise(name=f"parcel:launch:L{loc}")

            def _ship(*vals):
                port.stage(loc, "launch", _payload(vals), inner)
                port.flush(loc)

            if not fetch_futs:
                forward_failure(lane.submit(_ship), inner)
            else:
                forward_failure(dataflow(
                    lambda *vals: lane.submit(lambda: _ship(*vals)).get(),
                    *fetch_futs,
                    executor=get_runtime().pool,
                    name=f"remote-run:{name}",
                ), inner)
            # "local" mode writes device arrays — post-process on the host
            # pool, never inline on the port's reply-listener thread.
            result = inner.get_future().then(
                _post,
                executor="inline" if mode != "local" else get_runtime().pool,
                name=f"remote-run:{name}",
            )
            if stream is not None:
                stream._note_completion(result)
            return result

        def _send(*vals):
            return _post(port.call_sync(loc, "launch", _payload(vals)))

        # Ordering: the launch parcel goes through the remote device's ops
        # queue, after any previously submitted writes there.  Pending host
        # fetches join off-queue first (same discipline as the percolating
        # local launch path — a queue worker must not wait on its own queue).
        if not fetch_futs:
            return lane.submit(_send)
        from repro.core.executor import get_runtime

        return dataflow(
            lambda *vals: lane.submit(lambda: _send(*vals)).get(),
            *fetch_futs,
            executor=get_runtime().pool,
            name=f"remote-run:{name}",
        )
