"""Program: runtime-compiled device code (paper §4, Fig. 2 ``program``).

The NVRTC analogue on TPU/JAX is the JIT itself: ``build`` runs
``jax.jit(kernel).lower(specs).compile()`` asynchronously on the device's
*compile* queue, so compilation overlaps data transfers exactly like
Listing 2 (copies and ``prog.build`` futures run concurrently, joined by
``wait_all``).  Compiled executables are cached per (kernel, shapes, grid,
block).

Launch semantics keep HPXCL's user-visible tuning knobs: ``grid`` and
``block`` (``Dim3``) are forwarded to kernels that accept them (our Pallas
kernels map them onto grid/BlockSpec tiling — the TPU equivalent of CUDA
launch geometry, DESIGN.md §2).

Percolation: ``run`` executes where the program's device is; argument
buffers living on other devices are first moved there with async copies
(futures), never blocking the caller.

Hot-path notes (DESIGN.md §8): signature inspection is done once per
kernel (``inspect.signature`` costs ~10 µs — far more than a queue hop),
bound callables are cached per (kernel, grid, block), and the executable
cache key hashes interned dtype objects instead of ``str(dtype)``.
Inside a ``graph.capture()`` region ``run`` records a symbolic node
instead of executing (CUDA-Graphs stream capture analogue).
"""
from __future__ import annotations

import importlib.util
import inspect
from dataclasses import dataclass
from typing import Any, Callable, Optional, Sequence

import jax

from repro.core.buffer import Buffer
from repro.core.futures import Future, dataflow, when_all

__all__ = ["Dim3", "Program"]


@dataclass
class Dim3:
    """CUDA-style launch geometry, kept user-visible (paper's philosophy)."""

    x: int = 1
    y: int = 1
    z: int = 1

    def as_tuple(self) -> "tuple[int, int, int]":
        return (self.x, self.y, self.z)


def _normalize_dim(d) -> "tuple[int, ...] | None":
    if d is None:
        return None
    if isinstance(d, Dim3):
        return d.as_tuple()
    if isinstance(d, int):
        return (d, 1, 1)
    return tuple(d)


class Program:
    """A named set of kernels compiled on demand for one device."""

    def __init__(self, device, kernels, name: str = "program"):
        from repro.core import agas

        if callable(kernels) and not isinstance(kernels, dict):
            kernels = {getattr(kernels, "__name__", "kernel"): kernels}
        self.device = device
        self.name = name
        self._kernels: "dict[str, Callable]" = dict(kernels)
        self._cache: "dict[tuple, Any]" = {}
        self._build_futures: "dict[tuple, Future]" = {}
        # Hot-path caches: geometry-kwarg names per kernel (inspect.signature
        # once, not per launch) and bound callables per (name, grid, block).
        self._geo_params: "dict[str, tuple[bool, bool]]" = {}
        self._bound_cache: "dict[tuple, Callable]" = {}
        self.gid = agas.registry.register(
            self, agas.Placement(device.key, device.jax_device.process_index), kind="program"
        )

    # -- construction ---------------------------------------------------------

    @staticmethod
    def from_file(device, path: str) -> "Program":
        """Load kernels from a python source file defining ``KERNELS``.

        This is the percolation path for *code*: source is loaded and
        runtime-compiled at the device that will execute it
        (``create_program_with_file("kernel.cu")`` analogue).
        """
        spec = importlib.util.spec_from_file_location(f"repro_kernel_{abs(hash(path))}", path)
        mod = importlib.util.module_from_spec(spec)
        spec.loader.exec_module(mod)  # type: ignore[union-attr]
        kernels = getattr(mod, "KERNELS", None)
        if kernels is None:
            raise ValueError(f"{path} does not define KERNELS = {{name: callable}}")
        return Program(device, kernels, name=path)

    def kernel_names(self) -> "list[str]":
        return sorted(self._kernels)

    # -- build (async runtime compilation) -------------------------------------

    def _geometry_of(self, name: str) -> "tuple[bool, bool]":
        """(accepts_grid, accepts_block) — computed once per kernel."""
        geo = self._geo_params.get(name)
        if geo is None:
            params = inspect.signature(self._kernels[name]).parameters
            geo = self._geo_params[name] = ("grid" in params, "block" in params)
        return geo

    def _bind(self, name: str, grid, block) -> Callable:
        """Bound callable for (kernel, normalized grid/block), cached."""
        grid_n, block_n = _normalize_dim(grid), _normalize_dim(block)
        bkey = (name, grid_n, block_n)
        bound = self._bound_cache.get(bkey)
        if bound is not None:
            return bound
        fn = self._kernels[name]
        has_grid, has_block = self._geometry_of(name)
        kwargs = {}
        if has_grid:
            kwargs["grid"] = grid_n
        if has_block:
            kwargs["block"] = block_n
        if kwargs:
            bound = lambda *args: fn(*args, **kwargs)  # noqa: E731
            bound.__name__ = name
        else:
            bound = fn
        self._bound_cache[bkey] = bound
        return bound

    def _key(self, name: str, specs, grid, block) -> tuple:
        # np.dtype objects are interned and hashable — hashing them directly
        # beats building str(dtype) per spec on every launch.
        sig = tuple((s.shape, s.dtype) for s in specs)
        return (name, sig, _normalize_dim(grid), _normalize_dim(block))

    def build(self, name: str, *specs, grid=None, block=None) -> Future:
        """Compile kernel ``name`` asynchronously (NVRTC analogue).

        With ``specs`` (``jax.ShapeDtypeStruct``/arrays) the executable is
        fully compiled and cached; without, the kernel is resolved/bound
        only (shape specialization then happens at first ``run``, still on
        the compile queue). Returns a future — a dependency for launches.
        """
        if name not in self._kernels:
            return Future.failed(KeyError(f"no kernel '{name}' in {self.name}"))
        if not specs:
            return self.device.compile_queue.submit(self._bind, name, grid, block)

        key = self._key(name, specs, grid, block)
        fut = self._build_futures.get(key)
        if fut is not None:
            return fut

        def _compile():
            compiled = self._cache.get(key)
            if compiled is None:
                bound = self._bind(name, grid, block)
                arg_specs = [
                    jax.ShapeDtypeStruct(s.shape, s.dtype) if not isinstance(s, jax.ShapeDtypeStruct) else s
                    for s in specs
                ]
                compiled = jax.jit(bound).lower(*arg_specs).compile()
                self._cache[key] = compiled
            return compiled

        fut = self.device.compile_queue.submit(_compile)
        self._build_futures[key] = fut
        return fut

    # -- launch -----------------------------------------------------------------

    def run(
        self,
        args: "Sequence[Buffer | Any]",
        name: str,
        grid=None,
        block=None,
        out: "Sequence[Buffer] | None" = None,
        sync: str = "ready",
    ):
        """Launch kernel ``name`` with buffer/array ``args`` (async).

        ``out``: buffers to receive the kernel's results (CUDA's mutate-
        in-place adapted to functional JAX) — the future resolves to them.
        Without ``out`` the future resolves to the raw result arrays.
        ``sync="ready"`` resolves at device completion (CUDA-event
        semantics); ``sync="dispatch"`` resolves at submission.

        Inside a ``repro.core.graph.capture()`` region the launch is
        *recorded*, not executed: the return value is then the graph node
        (symbolic handle), and execution happens at ``replay()``.
        """
        from repro.core.graph import current_graph

        g = current_graph()
        if g is not None:
            return g.run(self, args, name, grid=grid, block=block, out=out)

        home = self.device

        # Percolation: move foreign buffers to the program's device first.
        moved: "dict[int, Future] | None" = None
        for i, a in enumerate(args):
            if isinstance(a, Buffer) and a.device is not home:
                if moved is None:
                    moved = {}
                moved[i] = a.copy_to(home)

        specs = [a.array() if isinstance(a, Buffer) else a for a in args]
        build_fut = self.build(name, *specs, grid=grid, block=block)

        def _launch(compiled, *resolved_args):
            arg_list = list(args)
            if moved:
                for i, b in zip(moved.keys(), resolved_args):
                    arg_list[i] = b
            vals = [a.array() if isinstance(a, Buffer) else a for a in arg_list]
            res = compiled(*vals)
            if out is None:
                return res
            res_list = list(res) if isinstance(res, (tuple, list)) else [res]
            if len(res_list) != len(out):
                raise ValueError(
                    f"kernel '{name}' returned {len(res_list)} arrays for {len(out)} out buffers"
                )
            for b, v in zip(out, res_list):
                b._set_array(v)
            return list(out)

        # Order: (copies, build) -> ops-queue launch. Fast path: when the
        # executable is already cached and nothing percolates, submit the
        # launch directly (one hop) — this keeps the layer overhead at the
        # paper's "negligible" level. Slow path: dataflow joins the futures.
        if moved is None and build_fut.done():
            launched = home.ops_queue.submit(_launch, build_fut.get())
        else:

            def _enqueue(compiled, *resolved):
                return home.ops_queue.submit(_launch, compiled, *resolved).get()

            deps = moved.values() if moved else ()
            launched = dataflow(_enqueue, build_fut, *deps, name=f"run:{name}")

        if sync == "dispatch":
            return launched

        def _ready(res):
            vals = [b.array() for b in res] if out is not None else res
            jax.block_until_ready(vals)
            return res

        from repro.core.executor import get_runtime

        return launched.then(_ready, executor=get_runtime().pool, name=f"done:{name}")
