"""Futurization layer: HPX futures re-derived for JAX (paper §3.1).

One future type spans
  * host tasks (functions running on the runtime's thread pools),
  * asynchronously dispatched device values (``jax.Array`` — XLA's async
    dispatch plays the role of the CUDA stream),
  * composites built with the combinators below.

API mirrors HPX:
  ``Future.get()``                <-> ``hpx::future<T>::get()``
  ``Future.then(fn)``             <-> ``hpx::future<T>::then``
  ``when_all(fs) / when_any(fs)`` <-> ``hpx::when_all / when_any``
  ``dataflow(fn, *args)``         <-> ``hpx::dataflow``
  ``async_(fn, *args)``           <-> ``hpx::async``
  ``wait_all(fs)``                <-> ``hpx::wait_all`` (Listing 2, l. 38)

Design notes
------------
A pending ``Future`` wraps a ``concurrent.futures.Future`` for its
thread-safe result/callback machinery, plus an optional *resolver*: a
one-shot blocking callable producing the value.  Resolvers make
device-value futures lazy — wrapping a ``jax.Array`` costs one object
allocation and **no** thread work unless/until a continuation is attached
(then the wait is moved to the completion pool) or ``.get()`` is called
(then the wait happens inline).

Two hot-path properties keep the layer at the paper's §5 "no additional
computational overhead" level (DESIGN.md §2, §8):

* **No-alloc ready futures.**  An already-completed ``Future`` stores its
  value (or exception) directly and never allocates the inner
  ``concurrent.futures.Future`` — which carries a ``threading.Condition``
  (a lock + waiter list) that is pure waste for a value that already
  exists.  ``then``/``when_all``/``when_any`` short-circuit completed
  inputs inline: no callback registration, no pool submission.

* **Lock-free resolver handoff.**  The one-shot resolver is claimed via
  ``list.pop()`` on a single-element cell — atomic under the GIL — so the
  race between ``.get()``, ``.then`` and combinators needs no per-future
  ``threading.Lock`` (one fewer allocation per future, no acquire/release
  on every state check).
"""
from __future__ import annotations

import concurrent.futures as _cf
from enum import Enum
from typing import Any, Callable, Generic, Iterable, Sequence, TypeVar

T = TypeVar("T")
U = TypeVar("U")

__all__ = [
    "Future",
    "FutureState",
    "Promise",
    "async_",
    "dataflow",
    "make_ready_future",
    "make_exceptional_future",
    "wait_all",
    "when_all",
    "when_any",
]

_UNSET = object()


class FutureState(Enum):
    PENDING = "pending"
    READY = "ready"
    FAILED = "failed"


def _default_pool():
    # Local import: executor imports futures for its return types.
    from repro.core.executor import get_runtime

    return get_runtime().pool


class Future(Generic[T]):
    """Asynchronous value, composable into an execution DAG.

    Internal representation (one of three modes):
      * value mode:    ``_cf is None`` — completed; ``_value``/``_exc``
                       hold the outcome (the no-alloc ready fast path),
      * pending mode:  ``_cf`` is a live ``concurrent.futures.Future``,
      * resolver mode: pending mode plus ``_rcell = [resolver]``; the
                       resolver is claimed exactly once via the
                       GIL-atomic ``list.pop()``.
    """

    __slots__ = ("_cf", "_rcell", "_value", "_exc", "name")

    def __init__(
        self,
        inner: "_cf.Future | None" = None,
        resolver: "Callable[[], T] | None" = None,
        name: str = "",
    ):
        self._cf: "_cf.Future | None" = inner if inner is not None else _cf.Future()
        self._rcell: "list | None" = [resolver] if resolver is not None else None
        self._value = _UNSET
        self._exc: "BaseException | None" = None
        self.name = name

    # -- constructors ------------------------------------------------------

    @staticmethod
    def ready(value: T, name: str = "") -> "Future[T]":
        """Completed future holding ``value`` — allocates no inner future,
        no lock, no condition variable (hot-path constructor)."""
        f: "Future[T]" = Future.__new__(Future)
        f._cf = None
        f._rcell = None
        f._value = value
        f._exc = None
        f.name = name
        return f

    @staticmethod
    def failed(exc: BaseException, name: str = "") -> "Future[T]":
        f: "Future[T]" = Future.__new__(Future)
        f._cf = None
        f._rcell = None
        f._value = _UNSET
        f._exc = exc
        f.name = name
        return f

    @staticmethod
    def from_concurrent(f: "_cf.Future", name: str = "") -> "Future[T]":
        return Future(f, name=name)

    @staticmethod
    def from_array(x, name: str = "") -> "Future":
        """Wrap an async-dispatched ``jax.Array`` (or pytree of them).

        The future becomes READY when the device computation producing the
        value has finished — the CUDA-event analogue, realized through
        array readiness instead (DESIGN.md §2).
        """
        import jax

        def _resolve():
            return jax.block_until_ready(x)

        return Future(resolver=_resolve, name=name)

    # -- resolver plumbing -------------------------------------------------

    def _take_resolver(self):
        """Claim the one-shot resolver; GIL-atomic, at most one caller wins."""
        cell = self._rcell
        if cell is None:
            return None
        try:
            return cell.pop()
        except IndexError:  # another thread won the handoff
            return None

    def _has_resolver(self) -> bool:
        cell = self._rcell
        return bool(cell)

    def _run_resolver_inline(self, r) -> None:
        try:
            self._cf.set_result(r())
        except BaseException as e:  # noqa: BLE001 - futures carry any error
            try:
                self._cf.set_exception(e)
            except _cf.InvalidStateError:
                # cancel() raced the resolver: the consumer walked away, the
                # produced value (or its error) is discarded, never raised.
                if not self._cf.cancelled():
                    raise

    def _spawn_resolver(self) -> None:
        """Move a pending resolver onto the completion pool (if any)."""
        r = self._take_resolver()
        if r is not None:
            _default_pool().submit(self._run_resolver_inline, r)

    # -- core API ----------------------------------------------------------

    @property
    def state(self) -> FutureState:
        if self._cf is None:
            return FutureState.FAILED if self._exc is not None else FutureState.READY
        if self._has_resolver() or not self._cf.done():
            return FutureState.PENDING
        if self._cf.cancelled():
            return FutureState.FAILED
        return FutureState.FAILED if self._cf.exception() else FutureState.READY

    def done(self) -> bool:
        if self._cf is None:
            return True
        return not self._has_resolver() and self._cf.done()

    def is_ready(self) -> bool:
        return self.state is FutureState.READY

    def get(self, timeout: "float | None" = None) -> T:
        """Block until the value is available and return it (HPX ``get``)."""
        if self._cf is None:
            if self._exc is not None:
                raise self._exc
            return self._value
        if not self._cf.done():
            # About to block: flush this thread's coalesced submissions so a
            # staged task's result can always be awaited (executor.coalesce).
            from repro.core.executor import flush_coalesced

            flush_coalesced()
        r = self._take_resolver()
        if r is not None:
            self._run_resolver_inline(r)
        return self._cf.result(timeout)

    def exception(self, timeout: "float | None" = None) -> "BaseException | None":
        if self._cf is None:
            return self._exc
        if not self._cf.done():
            from repro.core.executor import flush_coalesced

            flush_coalesced()
        r = self._take_resolver()
        if r is not None:
            self._run_resolver_inline(r)
        try:
            return self._cf.exception(timeout)
        except _cf.CancelledError as e:  # a cancelled future *carries* it
            return e

    def wait(self, timeout: "float | None" = None) -> "Future[T]":
        try:
            self.get(timeout)
        except BaseException:  # noqa: BLE001 - wait() never raises
            pass
        return self

    def cancel(self) -> bool:
        """Best-effort cancellation of a still-pending future.

        Returns True when the future was cancelled before anything started
        producing its value; ``get()`` then raises ``CancelledError``.  A
        completed (or value-mode) future — and a task already running on a
        queue worker — cannot be cancelled and returns False.  Producers
        (``Promise.set_value``, the serving engine's batch resolution)
        tolerate a racing cancel: a result arriving after a successful
        cancel is discarded, never raised."""
        if self._cf is None:
            return False
        # Claiming the resolver keeps a lazy device-value future from
        # starting its blocking wait after the cancel.
        self._take_resolver()
        return self._cf.cancel()

    def cancelled(self) -> bool:
        return self._cf is not None and self._cf.cancelled()

    # -- completion (used by Promise / WorkQueue) --------------------------

    def _set_result(self, value) -> None:
        self._cf.set_result(value)

    def _set_exception(self, exc: BaseException) -> None:
        self._cf.set_exception(exc)

    # -- composition --------------------------------------------------------

    def then(
        self,
        fn: "Callable[[T], U]",
        *,
        executor=None,
        name: str = "",
    ) -> "Future[U]":
        """Continuation: run ``fn(value)`` once this future is READY.

        Failure propagates: if this future failed, ``fn`` is not called and
        the returned future carries the same exception.

        Launch policy: by default the continuation runs on the runtime host
        pool — never inline on a device work-queue worker, because a
        continuation that *blocks* on further queue submissions would then
        deadlock the queue (HPX avoids this by suspending its user-level
        threads; OS threads cannot suspend, so we hop).  If the parent is
        already done, run inline on the caller (cheap fast path: no
        callback registration, no pool hop, and the returned future is a
        no-alloc completed one).  Pass ``executor="inline"`` to force
        inline execution, or any object with ``submit`` to choose a pool.
        """
        # Fast path: parent complete -> run inline, return completed future.
        if self._cf is None or (not self._has_resolver() and self._cf.done()):
            if self._cf is not None and self._cf.cancelled():
                return Future.failed(_cf.CancelledError(), name=name or f"{self.name}.then")
            exc = self._exc if self._cf is None else self._cf.exception()
            if exc is not None:
                return Future.failed(exc, name=name or f"{self.name}.then")
            try:
                value = self._value if self._cf is None else self._cf.result()
                return Future.ready(fn(value), name=name or f"{self.name}.then")
            except BaseException as e:  # noqa: BLE001
                return Future.failed(e, name=name or f"{self.name}.then")

        out: Future[U] = Future(name=name or f"{self.name}.then")
        self._spawn_resolver()

        def _fire(parent: _cf.Future) -> None:
            exc = _cf.CancelledError() if parent.cancelled() else parent.exception()
            if exc is not None:
                out._cf.set_exception(exc)
                return

            def _run():
                try:
                    out._cf.set_result(fn(parent.result()))
                except BaseException as e:  # noqa: BLE001
                    out._cf.set_exception(e)

            if executor == "inline":
                _run()
            elif executor is None:
                _default_pool().submit(_run)
            else:
                executor.submit(_run)

        self._cf.add_done_callback(_fire)
        return out

    def __repr__(self) -> str:
        return f"Future({self.name or hex(id(self))}, {self.state.value})"


class Promise(Generic[T]):
    """Manually-resolved future source (``hpx::promise``).

    A promise whose future was ``cancel()``-ed discards late results
    instead of raising: the consumer walked away, the producer should not
    crash for it."""

    def __init__(self, name: str = ""):
        self._future: Future[T] = Future(name=name)

    def get_future(self) -> Future[T]:
        return self._future

    def set_value(self, value: T) -> None:
        try:
            self._future._set_result(value)
        except _cf.InvalidStateError:
            if not self._future._cf.cancelled():
                raise

    def set_exception(self, exc: BaseException) -> None:
        try:
            self._future._set_exception(exc)
        except _cf.InvalidStateError:
            if not self._future._cf.cancelled():
                raise


def forward_failure(src: Future, promise: Promise) -> None:
    """If ``src`` fails, fail ``promise``; on success, do nothing.

    Used by pipelined parcel dispatch: the reply promise is normally
    resolved by the port's listener thread, but when the *dispatch task*
    itself dies (lane shut down before it ran, send failed) nobody ever
    stages the parcel — this hook keeps the reply future from pending
    forever.  Races with a real resolution are benign: first writer wins,
    the late failure is dropped."""
    def _fail(exc: BaseException) -> None:
        try:
            promise.set_exception(exc)
        except _cf.InvalidStateError:
            pass
    if src._cf is None:
        if src._exc is not None:
            _fail(src._exc)
        return
    src._spawn_resolver()

    def _cb(parent: _cf.Future) -> None:
        exc = _cf.CancelledError() if parent.cancelled() else parent.exception()
        if exc is not None:
            _fail(exc)

    src._cf.add_done_callback(_cb)


def make_ready_future(value: T) -> Future[T]:
    return Future.ready(value)


def make_exceptional_future(exc: BaseException) -> Future[Any]:
    return Future.failed(exc)


def when_all(futures: "Iterable[Future]", name: str = "when_all") -> Future[list]:
    """Future of the list of values; fails with the first failure.

    Fast path: inputs that are already complete are collected inline —
    ``when_all`` over N ready futures performs zero pool submissions,
    zero callback registrations and zero lock operations, returning a
    no-alloc completed future (DESIGN.md §8).
    """
    futs = list(futures)
    n = len(futs)
    results: list = [None] * n

    # Inline sweep over already-complete inputs; collect the pending rest.
    pending: "list[tuple[int, Future]]" = []
    for i, f in enumerate(futs):
        if f.done():
            exc = f.exception()
            if exc is not None:
                return Future.failed(exc, name=name)
            results[i] = f.get()
        else:
            pending.append((i, f))

    if not pending:
        return Future.ready(results, name=name)

    out: Future[list] = Future(name=name)
    # Countdown via GIL-atomic list.pop(): each completing dependency takes
    # one token; whoever observes the empty list publishes the result (a
    # late double-publish is absorbed by the InvalidStateError guard).
    tokens = [None] * len(pending)

    def _make_cb(i: int):
        def _cb(parent: _cf.Future) -> None:
            exc = _cf.CancelledError() if parent.cancelled() else parent.exception()
            if exc is not None:
                # set_exception on an already-done future raises; guard.
                if not out._cf.done():
                    try:
                        out._cf.set_exception(exc)
                    except _cf.InvalidStateError:
                        pass
                return
            results[i] = parent.result()
            tokens.pop()
            if not tokens and not out._cf.done():
                try:
                    out._cf.set_result(results)
                except _cf.InvalidStateError:
                    pass

        return _cb

    for i, f in pending:
        f._spawn_resolver()
        f._cf.add_done_callback(_make_cb(i))
    return out


def when_any(futures: "Iterable[Future]", name: str = "when_any") -> Future[tuple]:
    """Future of ``(index, value)`` of the first future to become READY."""
    futs = list(futures)
    if not futs:
        raise ValueError("when_any of empty set")

    # Fast path: any input already complete wins without pool work.
    for i, f in enumerate(futs):
        if f.done():
            exc = f.exception()
            if exc is not None:
                return Future.failed(exc, name=name)
            return Future.ready((i, f.get()), name=name)

    out: Future[tuple] = Future(name=name)

    def _make_cb(i: int):
        def _cb(parent: _cf.Future) -> None:
            if out._cf.done():
                return
            try:
                exc = _cf.CancelledError() if parent.cancelled() else parent.exception()
                if exc is not None:
                    out._cf.set_exception(exc)
                else:
                    out._cf.set_result((i, parent.result()))
            except _cf.InvalidStateError:
                pass

        return _cb

    for i, f in enumerate(futs):
        f._spawn_resolver()
        f._cf.add_done_callback(_make_cb(i))
    return out


def wait_all(futures: "Iterable[Future]") -> None:
    """Blocking barrier (``hpx::wait_all`` — Listing 2, line 38)."""
    for f in list(futures):
        f.wait()


def async_(fn: Callable[..., T], *args, executor=None, name: str = "", **kwargs) -> Future[T]:
    """Run ``fn`` on the runtime host pool (``hpx::async``)."""
    pool = executor if executor is not None else _default_pool()
    return Future.from_concurrent(pool.submit(fn, *args, **kwargs), name=name or getattr(fn, "__name__", "async"))


def dataflow(fn: Callable[..., T], *args, executor=None, name: str = "", **kwargs) -> Future[T]:
    """Run ``fn`` when every future among ``args``/``kwargs`` is READY.

    Non-future arguments pass through unchanged (``hpx::dataflow``).  The
    body runs on the host pool so long chains never recurse on a completing
    thread (unless every dependency is already READY, in which case the
    ``when_all``/``then`` fast paths run the body inline).
    """
    dep_ixs = [i for i, a in enumerate(args) if isinstance(a, Future)]
    dep_keys = [k for k, v in kwargs.items() if isinstance(v, Future)]
    deps = [args[i] for i in dep_ixs] + [kwargs[k] for k in dep_keys]

    def _body(values: list) -> T:
        a = list(args)
        kw = dict(kwargs)
        for slot, v in zip(dep_ixs, values[: len(dep_ixs)]):
            a[slot] = v
        for key, v in zip(dep_keys, values[len(dep_ixs):]):
            kw[key] = v
        return fn(*a, **kw)

    pool = executor if executor is not None else _default_pool()
    return when_all(deps).then(_body, executor=pool, name=name or f"dataflow:{getattr(fn, '__name__', 'fn')}")
