"""Futurization layer: HPX futures re-derived for JAX (paper §3.1).

One future type spans
  * host tasks (functions running on the runtime's thread pools),
  * asynchronously dispatched device values (``jax.Array`` — XLA's async
    dispatch plays the role of the CUDA stream),
  * composites built with the combinators below.

API mirrors HPX:
  ``Future.get()``                <-> ``hpx::future<T>::get()``
  ``Future.then(fn)``             <-> ``hpx::future<T>::then``
  ``when_all(fs) / when_any(fs)`` <-> ``hpx::when_all / when_any``
  ``dataflow(fn, *args)``         <-> ``hpx::dataflow``
  ``async_(fn, *args)``           <-> ``hpx::async``
  ``wait_all(fs)``                <-> ``hpx::wait_all`` (Listing 2, l. 38)

Design notes
------------
A ``Future`` wraps a ``concurrent.futures.Future`` for its thread-safe
result/callback machinery, plus an optional *resolver*: a one-shot blocking
callable producing the value.  Resolvers make device-value futures lazy —
wrapping a ``jax.Array`` costs one object allocation and **no** thread work
unless/until a continuation is attached (then the wait is moved to the
completion pool) or ``.get()`` is called (then the wait happens inline).
This is what keeps the layer overhead negligible (paper §5: "no additional
computational overhead").
"""
from __future__ import annotations

import concurrent.futures as _cf
import threading
from enum import Enum
from typing import Any, Callable, Generic, Iterable, Sequence, TypeVar

T = TypeVar("T")
U = TypeVar("U")

__all__ = [
    "Future",
    "FutureState",
    "Promise",
    "async_",
    "dataflow",
    "make_ready_future",
    "make_exceptional_future",
    "wait_all",
    "when_all",
    "when_any",
]


class FutureState(Enum):
    PENDING = "pending"
    READY = "ready"
    FAILED = "failed"


def _default_pool():
    # Local import: executor imports futures for its return types.
    from repro.core.executor import get_runtime

    return get_runtime().pool


class Future(Generic[T]):
    """Asynchronous value, composable into an execution DAG."""

    __slots__ = ("_cf", "_resolver", "_lock", "name")

    def __init__(
        self,
        inner: "_cf.Future | None" = None,
        resolver: "Callable[[], T] | None" = None,
        name: str = "",
    ):
        self._cf: _cf.Future = inner if inner is not None else _cf.Future()
        self._resolver = resolver
        self._lock = threading.Lock()
        self.name = name

    # -- constructors ------------------------------------------------------

    @staticmethod
    def ready(value: T, name: str = "") -> "Future[T]":
        f: _cf.Future = _cf.Future()
        f.set_result(value)
        return Future(f, name=name)

    @staticmethod
    def failed(exc: BaseException, name: str = "") -> "Future[T]":
        f: _cf.Future = _cf.Future()
        f.set_exception(exc)
        return Future(f, name=name)

    @staticmethod
    def from_concurrent(f: "_cf.Future", name: str = "") -> "Future[T]":
        return Future(f, name=name)

    @staticmethod
    def from_array(x, name: str = "") -> "Future":
        """Wrap an async-dispatched ``jax.Array`` (or pytree of them).

        The future becomes READY when the device computation producing the
        value has finished — the CUDA-event analogue, realized through
        array readiness instead (DESIGN.md §2).
        """
        import jax

        def _resolve():
            return jax.block_until_ready(x)

        return Future(resolver=_resolve, name=name)

    # -- resolver plumbing -------------------------------------------------

    def _take_resolver(self):
        if self._resolver is None:
            return None
        with self._lock:
            r, self._resolver = self._resolver, None
        return r

    def _run_resolver_inline(self, r) -> None:
        try:
            self._cf.set_result(r())
        except BaseException as e:  # noqa: BLE001 - futures carry any error
            self._cf.set_exception(e)

    def _spawn_resolver(self) -> None:
        """Move a pending resolver onto the completion pool (if any)."""
        r = self._take_resolver()
        if r is not None:
            _default_pool().submit(self._run_resolver_inline, r)

    # -- core API ----------------------------------------------------------

    @property
    def state(self) -> FutureState:
        if self._resolver is not None:
            return FutureState.PENDING
        if not self._cf.done():
            return FutureState.PENDING
        return FutureState.FAILED if self._cf.exception() else FutureState.READY

    def done(self) -> bool:
        return self._resolver is None and self._cf.done()

    def is_ready(self) -> bool:
        return self.state is FutureState.READY

    def get(self, timeout: "float | None" = None) -> T:
        """Block until the value is available and return it (HPX ``get``)."""
        r = self._take_resolver()
        if r is not None:
            self._run_resolver_inline(r)
        return self._cf.result(timeout)

    def exception(self, timeout: "float | None" = None) -> "BaseException | None":
        r = self._take_resolver()
        if r is not None:
            self._run_resolver_inline(r)
        return self._cf.exception(timeout)

    def wait(self, timeout: "float | None" = None) -> "Future[T]":
        try:
            self.get(timeout)
        except BaseException:  # noqa: BLE001 - wait() never raises
            pass
        return self

    # -- composition --------------------------------------------------------

    def then(
        self,
        fn: "Callable[[T], U]",
        *,
        executor=None,
        name: str = "",
    ) -> "Future[U]":
        """Continuation: run ``fn(value)`` once this future is READY.

        Failure propagates: if this future failed, ``fn`` is not called and
        the returned future carries the same exception.

        Launch policy: by default the continuation runs on the runtime host
        pool — never inline on a device work-queue worker, because a
        continuation that *blocks* on further queue submissions would then
        deadlock the queue (HPX avoids this by suspending its user-level
        threads; OS threads cannot suspend, so we hop).  If the parent is
        already done, run inline on the caller (cheap fast path).  Pass
        ``executor="inline"`` to force inline execution, or any object with
        ``submit`` to choose a pool.
        """
        out: Future[U] = Future(name=name or f"{self.name}.then")
        self._spawn_resolver()
        already_done = self._cf.done()

        def _fire(parent: _cf.Future) -> None:
            exc = parent.exception()
            if exc is not None:
                out._cf.set_exception(exc)
                return

            def _run():
                try:
                    out._cf.set_result(fn(parent.result()))
                except BaseException as e:  # noqa: BLE001
                    out._cf.set_exception(e)

            if executor == "inline" or already_done:
                _run()
            elif executor is None:
                _default_pool().submit(_run)
            else:
                executor.submit(_run)

        self._cf.add_done_callback(_fire)
        return out

    def __repr__(self) -> str:
        return f"Future({self.name or hex(id(self))}, {self.state.value})"


class Promise(Generic[T]):
    """Manually-resolved future source (``hpx::promise``)."""

    def __init__(self, name: str = ""):
        self._future: Future[T] = Future(name=name)

    def get_future(self) -> Future[T]:
        return self._future

    def set_value(self, value: T) -> None:
        self._future._cf.set_result(value)

    def set_exception(self, exc: BaseException) -> None:
        self._future._cf.set_exception(exc)


def make_ready_future(value: T) -> Future[T]:
    return Future.ready(value)


def make_exceptional_future(exc: BaseException) -> Future[Any]:
    return Future.failed(exc)


def when_all(futures: "Iterable[Future]", name: str = "when_all") -> Future[list]:
    """Future of the list of values; fails with the first failure."""
    futs = list(futures)
    out: Future[list] = Future(name=name)
    n = len(futs)
    if n == 0:
        out._cf.set_result([])
        return out

    results: list = [None] * n
    remaining = [n]
    lock = threading.Lock()

    def _make_cb(i: int):
        def _cb(parent: _cf.Future) -> None:
            exc = parent.exception()
            if exc is not None:
                # set_exception on an already-done future raises; guard.
                if not out._cf.done():
                    try:
                        out._cf.set_exception(exc)
                    except _cf.InvalidStateError:
                        pass
                return
            results[i] = parent.result()
            with lock:
                remaining[0] -= 1
                last = remaining[0] == 0
            if last and not out._cf.done():
                try:
                    out._cf.set_result(results)
                except _cf.InvalidStateError:
                    pass

        return _cb

    for i, f in enumerate(futs):
        f._spawn_resolver()
        f._cf.add_done_callback(_make_cb(i))
    return out


def when_any(futures: "Iterable[Future]", name: str = "when_any") -> Future[tuple]:
    """Future of ``(index, value)`` of the first future to become READY."""
    futs = list(futures)
    if not futs:
        raise ValueError("when_any of empty set")
    out: Future[tuple] = Future(name=name)

    def _make_cb(i: int):
        def _cb(parent: _cf.Future) -> None:
            if out._cf.done():
                return
            try:
                exc = parent.exception()
                if exc is not None:
                    out._cf.set_exception(exc)
                else:
                    out._cf.set_result((i, parent.result()))
            except _cf.InvalidStateError:
                pass

        return _cb

    for i, f in enumerate(futs):
        f._spawn_resolver()
        f._cf.add_done_callback(_make_cb(i))
    return out


def wait_all(futures: "Iterable[Future]") -> None:
    """Blocking barrier (``hpx::wait_all`` — Listing 2, line 38)."""
    for f in list(futures):
        f.wait()


def async_(fn: Callable[..., T], *args, executor=None, name: str = "", **kwargs) -> Future[T]:
    """Run ``fn`` on the runtime host pool (``hpx::async``)."""
    pool = executor if executor is not None else _default_pool()
    return Future.from_concurrent(pool.submit(fn, *args, **kwargs), name=name or getattr(fn, "__name__", "async"))


def dataflow(fn: Callable[..., T], *args, executor=None, name: str = "", **kwargs) -> Future[T]:
    """Run ``fn`` when every future among ``args``/``kwargs`` is READY.

    Non-future arguments pass through unchanged (``hpx::dataflow``).  The
    body runs on the host pool so long chains never recurse on a completing
    thread.
    """
    dep_ixs = [i for i, a in enumerate(args) if isinstance(a, Future)]
    dep_keys = [k for k, v in kwargs.items() if isinstance(v, Future)]
    deps = [args[i] for i in dep_ixs] + [kwargs[k] for k in dep_keys]

    def _body(values: list) -> T:
        a = list(args)
        kw = dict(kwargs)
        for slot, v in zip(dep_ixs, values[: len(dep_ixs)]):
            a[slot] = v
        for key, v in zip(dep_keys, values[len(dep_ixs):]):
            kw[key] = v
        return fn(*a, **kw)

    pool = executor if executor is not None else _default_pool()
    return when_all(deps).then(_body, executor=pool, name=name or f"dataflow:{getattr(fn, '__name__', 'fn')}")
