"""Host-side execution resources: thread pools, static per-device queues,
and the lane-aware dispatcher behind streams.

HPXCL attaches every device operation to a lightweight user-level thread
under the *static* scheduling policy (one queue pinned per device — paper
§3/§4).  The JAX analogue: a ``WorkQueue`` is a single-thread FIFO executor;
one is created per logical device for ordered submission (XLA then overlaps
the *execution*), plus a shared host pool for continuations, I/O and
``async_`` tasks.

Lanes (DESIGN.md §11): a ``LaneDispatcher`` multiplexes N FIFO *lanes*
onto one shared worker pool — each lane is the ordering substrate of one
``repro.core.stream.Stream`` (the ``cudaStream_t`` analogue).  At most one
task per lane runs at a time, so every lane preserves strict submission
order, while tasks on *different* lanes of the same device run
concurrently (transfer–compute overlap).

Ordering guarantees, stated once here because every layer above relies on
them:

* **Same-lane FIFO** — tasks submitted to one lane (one stream) execute
  strictly in submission order, never interleaved or reordered.
* **Cross-lane: none** — two lanes of the same dispatcher have NO implied
  ordering; synchronization between them is explicit (an ``Event``
  recorded in one stream and waited on in another — happens-before is
  then carried by the event's ``Future``).
* **Dispatcher barrier** — ``barrier()``/``drain()`` cover everything
  submitted to *any* lane before the call (``cudaDeviceSynchronize``).

Load accounting (DESIGN.md §9): every queue and lane counts submissions
and completions and tracks how long its worker has been busy, so a
placement policy (``least_loaded``) can read a real backlog signal off
``WorkQueue.load()`` / ``LaneDispatcher.load()`` (the per-lane depths are
summed — a device busy on three lanes reports a depth of three) instead
of guessing.  Counters are monotonically increasing; the snapshot is
advisory (reads are unsynchronized with the worker by design — scheduling
decisions tolerate a stale-by-one view).
"""
from __future__ import annotations

import atexit
import concurrent.futures as _cf
import os
import queue as _queue
import threading
import time
from dataclasses import dataclass
from typing import Callable, Optional

from collections import deque

from contextlib import contextmanager

from repro.core.futures import Future

__all__ = [
    "QueueLoad",
    "WorkQueue",
    "Lane",
    "LaneDispatcher",
    "Runtime",
    "get_runtime",
    "reset_runtime",
    "coalesce",
    "flush_coalesced",
]


# ---------------------------------------------------------------------------
# submission coalescing (DESIGN.md §13)
#
# A queue hop costs two thread wakeups (worker kick + result wakeup); a
# batched enqueue pays them once for N tasks (the submit_many row in
# BENCH_overhead).  ``coalesce()`` makes that batching the *default* for
# any code that submits several tasks before blocking: inside the scope,
# ``submit``/``submit_many`` on any Lane or WorkQueue stage their items in
# a thread-local buffer instead of waking a worker, and the whole window
# flushes as ONE enqueue per touched queue.  The window adapts to the
# caller's natural batch boundary: it closes at scope exit, and *any*
# blocking operation — ``Future.get``/``exception``, ``drain``,
# ``barrier`` — flushes first, so a task whose result is awaited inside
# the scope can never deadlock behind its own staged submission.
#
# Load honesty (DESIGN.md §9): staged items bump their queue's submitted
# counter at STAGE time, so ``load().depth`` sees a coalesced batch the
# moment it is placed — coalescing must not blind the least_loaded signal.
# ---------------------------------------------------------------------------

_coalesce_tls = threading.local()

_COALESCE_ENABLED = os.environ.get("REPRO_COALESCE", "auto").lower() != "off"
# Safety valve: a pathologically large window degrades to eager flushes
# (bounded staging memory; the batch is already big enough to amortize).
_COALESCE_CAP = int(os.environ.get("REPRO_COALESCE_CAP", "256"))

# Load-signal decay (DESIGN.md §14): completed busy-time folds into an
# exponentially decayed accumulator so ``least_loaded`` scores *recent*
# occupancy instead of a lifetime total (which never forgets) or the
# instantaneous depth (which is stale by the time a batch lands).
# REPRO_LOAD_HALFLIFE is the half-life in seconds: work done one half-life
# ago counts half as much as work finishing now.
_LOAD_HALFLIFE = float(os.environ.get("REPRO_LOAD_HALFLIFE", "0.25") or 0.25)
_LN2 = 0.6931471805599453


def _fold_busy(decayed: float, stamp: float, duration: float, now: float) -> float:
    """Decay the busy accumulator to ``now`` and fold in a finished task."""
    return decayed * 2.0 ** (-(now - stamp) / _LOAD_HALFLIFE) + duration


def _busy_ewma(decayed: float, stamp: float, busy_for: float, now: float) -> float:
    """Utilization-like occupancy score from the decayed accumulator.

    Normalized by the decay time-constant tau = halflife/ln2: a worker that
    has been continuously busy scores ~1.0, an idle one decays toward 0.
    The currently-running task contributes its elapsed time (capped at tau)
    so long tasks register before they complete.
    """
    tau = _LOAD_HALFLIFE / _LN2
    return (decayed * 2.0 ** (-(now - stamp) / _LOAD_HALFLIFE) + min(busy_for, tau)) / tau


class _CoalesceScope:
    __slots__ = ("targets", "depth")

    def __init__(self):
        # id(queue) -> (queue, staged item list); insertion-ordered so
        # flush preserves cross-queue submission order.
        self.targets: "dict[int, tuple[Any, list]]" = {}
        self.depth = 1

    def stage(self, q, items: list) -> None:
        entry = self.targets.get(id(q))
        if entry is None:
            self.targets[id(q)] = (q, list(items))
        else:
            entry[1].extend(items)
            if len(entry[1]) >= _COALESCE_CAP:
                del self.targets[id(q)]
                q._flush_items(entry[1])

    def flush(self) -> None:
        targets, self.targets = self.targets, {}
        for q, items in targets.values():
            q._flush_items(items)


def _current_scope() -> "_CoalesceScope | None":
    return getattr(_coalesce_tls, "scope", None)


def flush_coalesced() -> None:
    """Flush this thread's staged submissions (if any) without closing the
    scope.  Called automatically by every blocking primitive; safe and
    near-free (one TLS read) when nothing is staged."""
    scope = getattr(_coalesce_tls, "scope", None)
    if scope is not None and scope.targets:
        scope.flush()


@contextmanager
def coalesce():
    """Batch every ``submit`` in this scope into one enqueue per queue.

    Same-queue FIFO order is exactly preserved (the staged batch occupies
    one queue slot and runs uninterleaved, the ``submit_many`` contract);
    results are identical to unscoped submission — only the number of
    worker wakeups changes.  Nesting is flattened into the outermost
    scope.  Blocking inside the scope (``Future.get``, ``drain``,
    ``barrier``) flushes staged work first, so awaiting a staged task's
    result is always safe.  ``REPRO_COALESCE=off`` disables staging
    (the scope becomes a no-op)."""
    if not _COALESCE_ENABLED:
        yield
        return
    scope = getattr(_coalesce_tls, "scope", None)
    if scope is not None:
        scope.depth += 1
        try:
            yield
        finally:
            scope.depth -= 1
        return
    scope = _coalesce_tls.scope = _CoalesceScope()
    try:
        yield
    finally:
        _coalesce_tls.scope = None
        scope.flush()


@dataclass(frozen=True)
class QueueLoad:
    """Snapshot of one queue's backlog (the ``least_loaded`` signal).

    ``depth`` counts submissions not yet completed (queued + running);
    ``inflight`` is 1 while the worker is inside a task; ``busy_for`` is
    how long the current task has been running (0.0 when idle) and
    ``busy_time`` the lifetime total of task execution seconds.
    ``busy_ewma`` is the exponentially-decayed recent occupancy normalized
    to ~[0, 1] per worker (DESIGN.md §14) — the half of the honest load
    signal that survives between depth samples.
    """

    depth: int
    inflight: int
    busy_for: float
    busy_time: float
    submitted: int
    completed: int
    busy_ewma: float = 0.0


class WorkQueue:
    """Single-worker FIFO queue — the 'static scheduling policy' of HPXCL.

    Submissions execute strictly in order; each returns a ``Future``.  This
    is the submission-ordering analogue of a CUDA stream (DESIGN.md §2).
    """

    def __init__(self, name: str):
        self.name = name
        self._q: _queue.SimpleQueue = _queue.SimpleQueue()
        self._shutdown = threading.Event()
        # Load accounting: _submitted is bumped under _count_lock (many
        # submitter threads); _completed/_busy_* have a single writer (the
        # worker) and need no lock.
        self._count_lock = threading.Lock()
        self._submitted = 0
        self._completed = 0
        self._busy_time = 0.0
        self._busy_since: "float | None" = None
        # Decayed occupancy (single writer: the worker thread).
        self._decayed_busy = 0.0
        self._decay_stamp = time.monotonic()
        self._thread = threading.Thread(target=self._loop, name=f"wq:{name}", daemon=True)
        self._thread.start()

    def _loop(self) -> None:
        while True:
            item = self._q.get()
            if item is None:
                return
            if type(item) is list:  # batched enqueue (submit_many)
                for sub in item:
                    self._run_one(sub)
            else:
                self._run_one(item)
            # Drop the reference while blocked in get(): a worker idling on
            # an empty queue must not pin its last result (the futures keep
            # results alive for their owners; the queue should not).
            del item

    def _run_one(self, item) -> None:
        fut, fn, args, kwargs = item
        self._busy_since = time.monotonic()
        try:
            if fut._cf.set_running_or_notify_cancel():
                try:
                    fut._cf.set_result(fn(*args, **kwargs))
                except BaseException as e:  # noqa: BLE001
                    fut._cf.set_exception(e)
        finally:
            t0, self._busy_since = self._busy_since, None
            now = time.monotonic()
            self._busy_time += now - t0
            self._decayed_busy = _fold_busy(self._decayed_busy, self._decay_stamp, now - t0, now)
            self._decay_stamp = now
            self._completed += 1

    def submit(self, fn: Callable, *args, **kwargs) -> Future:
        if self._shutdown.is_set():
            raise RuntimeError(f"WorkQueue {self.name} is shut down")
        fut: Future = Future(name=f"{self.name}:{getattr(fn, '__name__', 'task')}")
        with self._count_lock:
            self._submitted += 1
        item = (fut, fn, args, kwargs)
        scope = _current_scope()
        if scope is not None:
            scope.stage(self, [item])
        else:
            self._q.put(item)
        return fut

    def _flush_items(self, items: list) -> None:
        """Enqueue staged items as one batch (counters already bumped at
        stage time — see ``coalesce``)."""
        if self._shutdown.is_set():
            err = RuntimeError(f"WorkQueue {self.name} shut down with staged submissions")
            for fut, _, _, _ in items:
                try:
                    fut._cf.set_exception(err)
                except Exception:  # noqa: BLE001 - already resolved/cancelled
                    pass
            return
        self._q.put(items if len(items) > 1 else items[0])

    def submit_many(self, calls) -> "list[Future]":
        """Batched enqueue: one queue hop for N calls (DESIGN.md §8).

        ``calls`` is an iterable of callables or ``(fn, args)`` /
        ``(fn, args, kwargs)`` tuples.  The batch occupies a single queue
        slot, so the per-submission put/wakeup cost is paid once; the
        calls still run strictly in the given order, uninterleaved with
        other submissions.  Returns one ``Future`` per call.
        """
        if self._shutdown.is_set():
            raise RuntimeError(f"WorkQueue {self.name} is shut down")
        batch = []
        futs: "list[Future]" = []
        for c in calls:
            if callable(c):
                fn, args, kwargs = c, (), {}
            else:
                fn = c[0]
                args = c[1] if len(c) > 1 else ()
                kwargs = c[2] if len(c) > 2 else {}
            fut: Future = Future(name=f"{self.name}:{getattr(fn, '__name__', 'task')}")
            futs.append(fut)
            batch.append((fut, fn, args, kwargs))
        if batch:
            with self._count_lock:
                self._submitted += len(batch)
            scope = _current_scope()
            if scope is not None:
                scope.stage(self, batch)
            else:
                self._q.put(batch)
        return futs

    def load(self) -> QueueLoad:
        """Advisory backlog snapshot (see module docstring)."""
        submitted, completed = self._submitted, self._completed
        since = self._busy_since
        now = time.monotonic()
        busy_for = (now - since) if since is not None else 0.0
        return QueueLoad(
            depth=max(0, submitted - completed),
            inflight=1 if since is not None else 0,
            busy_for=busy_for,
            busy_time=self._busy_time,
            submitted=submitted,
            completed=completed,
            busy_ewma=_busy_ewma(self._decayed_busy, self._decay_stamp, busy_for, now),
        )

    def drain(self) -> None:
        """Block until everything submitted so far has run."""
        self.submit(lambda: None).get()

    def shutdown(self) -> None:
        if not self._shutdown.is_set():
            self._shutdown.set()
            self._q.put(None)
            self._thread.join(timeout=5)


def _normalize_call(c) -> tuple:
    """(fn, args, kwargs) from a callable or (fn[, args[, kwargs]]) tuple."""
    if callable(c):
        return c, (), {}
    fn = c[0]
    args = c[1] if len(c) > 1 else ()
    kwargs = c[2] if len(c) > 2 else {}
    return fn, args, kwargs


class Lane:
    """One FIFO lane of a ``LaneDispatcher`` — a stream's ordering substrate.

    Duck-types ``WorkQueue`` (``submit`` / ``submit_many`` / ``load`` /
    ``drain`` / ``name``) so every layer written against per-device queues
    works unchanged against a lane.  At most one task of this lane runs at
    a time (same-lane FIFO); the running happens on the dispatcher's
    shared pool, so independent lanes execute concurrently.
    """

    def __init__(self, dispatcher: "LaneDispatcher", name: str):
        self.dispatcher = dispatcher
        self.name = name
        self._pending: deque = deque()
        self._lock = threading.Lock()  # guards _pending + the active handoff
        self._active = False
        self._submitted = 0
        # Single-writer counters (only one pool thread runs this lane at a
        # time — the _active handoff guarantees it): no lock needed.
        self._completed = 0
        self._busy_time = 0.0
        self._busy_since: "float | None" = None
        self._decayed_busy = 0.0
        self._decay_stamp = time.monotonic()

    def _put(self, items: list) -> None:
        d = self.dispatcher
        if d._shutdown.is_set():
            raise RuntimeError(f"Lane {self.name} is shut down")
        scope = _current_scope()
        if scope is not None:
            # Stage for one flush per lane; submitted is bumped NOW so the
            # scheduler's depth signal sees the coalesced batch immediately.
            with self._lock:
                self._submitted += len(items)
            scope.stage(self, items)
            return
        with self._lock:
            self._submitted += len(items)
            self._pending.extend(items)
            kick = not self._active
            if kick:
                self._active = True
        if kick:
            d._pool.submit(self._run)

    def _flush_items(self, items: list) -> None:
        """Hand staged items to the lane as one batch (one pool kick at
        most; counters were bumped at stage time)."""
        d = self.dispatcher
        if d._shutdown.is_set():
            err = RuntimeError(f"Lane {self.name} shut down with staged submissions")
            for fut, _, _, _ in items:
                try:
                    fut._cf.set_exception(err)
                except Exception:  # noqa: BLE001 - already resolved/cancelled
                    pass
            return
        with self._lock:
            self._pending.extend(items)
            kick = not self._active
            if kick:
                self._active = True
        if kick:
            d._pool.submit(self._run)

    def submit(self, fn: Callable, *args, **kwargs) -> Future:
        fut: Future = Future(name=f"{self.name}:{getattr(fn, '__name__', 'task')}")
        self._put([(fut, fn, args, kwargs)])
        return fut

    def submit_many(self, calls) -> "list[Future]":
        """Batched enqueue: one handoff for N ordered calls (``WorkQueue``
        contract — the calls run in order, uninterleaved with later
        submissions to this lane)."""
        items = []
        futs: "list[Future]" = []
        for c in calls:
            fn, args, kwargs = _normalize_call(c)
            fut: Future = Future(name=f"{self.name}:{getattr(fn, '__name__', 'task')}")
            futs.append(fut)
            items.append((fut, fn, args, kwargs))
        if items:
            self._put(items)
        return futs

    def _run(self) -> None:
        """Drain the lane on a pool worker; exactly one runner at a time."""
        d = self.dispatcher
        d._note_lane_active(+1)
        try:
            while True:
                with self._lock:
                    if not self._pending:
                        self._active = False
                        return
                    item = self._pending.popleft()
                self._run_one(item)
        finally:
            d._note_lane_active(-1)

    def _run_one(self, item) -> None:
        fut, fn, args, kwargs = item
        self._busy_since = time.monotonic()
        try:
            if fut._cf.set_running_or_notify_cancel():
                try:
                    fut._cf.set_result(fn(*args, **kwargs))
                except BaseException as e:  # noqa: BLE001
                    fut._cf.set_exception(e)
        finally:
            t0, self._busy_since = self._busy_since, None
            now = time.monotonic()
            self._busy_time += now - t0
            self._decayed_busy = _fold_busy(self._decayed_busy, self._decay_stamp, now - t0, now)
            self._decay_stamp = now
            self._completed += 1

    def load(self) -> QueueLoad:
        """Advisory backlog snapshot (same contract as ``WorkQueue.load``)."""
        submitted, completed = self._submitted, self._completed
        since = self._busy_since
        now = time.monotonic()
        busy_for = (now - since) if since is not None else 0.0
        return QueueLoad(
            depth=max(0, submitted - completed),
            inflight=1 if since is not None else 0,
            busy_for=busy_for,
            busy_time=self._busy_time,
            submitted=submitted,
            completed=completed,
            busy_ewma=_busy_ewma(self._decayed_busy, self._decay_stamp, busy_for, now),
        )

    def drain(self) -> None:
        """Block until everything submitted to THIS lane so far has run."""
        self.submit(lambda: None).get()

    def __repr__(self) -> str:
        return f"Lane({self.name}, depth={self.load().depth})"


class LaneDispatcher:
    """N FIFO lanes multiplexed onto one shared pool (DESIGN.md §11).

    The device-side half of the stream engine: each ``Stream`` owns one
    lane; the dispatcher hands runnable lanes to the pool and tracks how
    many lanes are executing at once (``high_water()`` — the observable
    proof that transfer–compute overlap actually happened).
    """

    def __init__(self, name: str, pool: "_cf.ThreadPoolExecutor"):
        self.name = name
        self._pool = pool
        self._lanes: "dict[str, Lane]" = {}
        self._lock = threading.Lock()
        self._shutdown = threading.Event()
        self._active_lanes = 0
        self._high_water = 0

    def lane(self, name: str) -> Lane:
        """The lane called ``name`` (created on first use)."""
        with self._lock:
            ln = self._lanes.get(name)
            if ln is None:
                ln = self._lanes[name] = Lane(self, f"{self.name}/{name}")
            return ln

    def lanes(self) -> "list[Lane]":
        with self._lock:
            return list(self._lanes.values())

    # -- concurrency accounting (single counter, one lock) -------------------

    def _note_lane_active(self, delta: int) -> None:
        with self._lock:
            self._active_lanes += delta
            if self._active_lanes > self._high_water:
                self._high_water = self._active_lanes

    def high_water(self) -> int:
        """Max lanes ever observed running concurrently (>1 == overlap)."""
        with self._lock:
            return self._high_water

    def reset_high_water(self) -> None:
        with self._lock:
            self._high_water = self._active_lanes

    # -- aggregate signals ---------------------------------------------------

    def load(self) -> QueueLoad:
        """Whole-device backlog: per-lane depths summed (DESIGN.md §9 —
        the scheduler's load signal counts every lane, so a device busy on
        three streams is three deep, not one)."""
        depth = inflight = submitted = completed = 0
        busy_for = busy_time = busy_ewma = 0.0
        for ln in self.lanes():
            l = ln.load()
            depth += l.depth
            inflight += l.inflight
            busy_for = max(busy_for, l.busy_for)
            busy_time += l.busy_time
            submitted += l.submitted
            completed += l.completed
            busy_ewma += l.busy_ewma
        return QueueLoad(depth, inflight, busy_for, busy_time, submitted, completed, busy_ewma)

    # -- synchronization ------------------------------------------------------

    def barrier(self) -> Future:
        """Future resolving when everything submitted to ANY lane before
        this call has completed (async ``cudaDeviceSynchronize``).  Markers
        go to every lane in parallel — a barrier never serializes lanes."""
        from repro.core.futures import when_all

        flush_coalesced()  # staged work counts as "submitted before the call"
        markers = [ln.submit(lambda: None) for ln in self.lanes()]
        flush_coalesced()  # the markers themselves must not linger staged
        return when_all(markers, name=f"barrier:{self.name}").then(
            lambda _: None, executor="inline"
        )

    def drain(self) -> None:
        """Blocking ``barrier()``."""
        self.barrier().get()

    def shutdown(self) -> None:
        self._shutdown.set()

    def __repr__(self) -> str:
        return f"LaneDispatcher({self.name}, {len(self._lanes)} lane(s))"


class Runtime:
    """Process-wide execution resources (HPX thread-manager analogue)."""

    def __init__(self, host_workers: Optional[int] = None):
        # generous: workers mostly *wait* (device readiness, queue results,
        # file I/O), so oversubscription is the deadlock-safe choice
        n = host_workers or max(32, 4 * (os.cpu_count() or 1))
        self.pool = _cf.ThreadPoolExecutor(max_workers=n, thread_name_prefix="repro-host")
        # Lanes get their own pool: a parked lane task (a launch waiting on
        # its build future, a graph segment on its producers) must never
        # starve host continuations of workers.  Same oversubscription
        # argument as the host pool — lane tasks mostly wait.
        self.lane_pool = _cf.ThreadPoolExecutor(max_workers=n, thread_name_prefix="repro-lane")
        self._queues: dict[str, WorkQueue] = {}
        self._dispatchers: "dict[str, LaneDispatcher]" = {}
        self._lock = threading.Lock()

    def queue(self, name: str) -> WorkQueue:
        with self._lock:
            q = self._queues.get(name)
            if q is None:
                q = self._queues[name] = WorkQueue(name)
            return q

    def dispatcher(self, name: str) -> LaneDispatcher:
        """The lane dispatcher called ``name`` (one per device; created on
        first use) — the multi-stream twin of ``queue()``."""
        with self._lock:
            d = self._dispatchers.get(name)
            if d is None:
                d = self._dispatchers[name] = LaneDispatcher(name, self.lane_pool)
            return d

    def async_(self, fn: Callable, *args, **kwargs) -> Future:
        return Future.from_concurrent(self.pool.submit(fn, *args, **kwargs))

    def shutdown(self) -> None:
        with self._lock:
            queues, self._queues = list(self._queues.values()), {}
            dispatchers, self._dispatchers = list(self._dispatchers.values()), {}
        for d in dispatchers:
            d.shutdown()
        for q in queues:
            q.shutdown()
        self.pool.shutdown(wait=False)
        self.lane_pool.shutdown(wait=False)


_runtime: Optional[Runtime] = None
_runtime_lock = threading.Lock()


def get_runtime() -> Runtime:
    global _runtime
    if _runtime is None:
        with _runtime_lock:
            if _runtime is None:
                _runtime = Runtime()
                atexit.register(_runtime.shutdown)
    return _runtime


def reset_runtime() -> None:
    """Tear down and replace the global runtime (tests).

    Cached ``Device`` objects hold ``WorkQueue``s owned by the runtime
    being torn down; leaving them cached means the next ``submit`` hits a
    dead queue ("WorkQueue ... is shut down").  The device cache and the
    default scheduler (which holds ``Device`` handles) are therefore
    dropped with the runtime — the next discovery re-registers devices
    against the fresh runtime's queues.

    Live parcelports are drained and shut down FIRST: their remote-device
    proxy queues belong to the runtime being torn down, and their cluster
    worker *processes* must never outlive the session that spawned them
    (a leaked worker would survive the test run).
    """
    import sys

    flush_coalesced()  # staged submissions must not straddle the reset
    _parcel = sys.modules.get("repro.core.parcel")
    if _parcel is not None:  # never import the transport just to reset it
        _parcel._shutdown_all_ports()
    global _runtime
    with _runtime_lock:
        if _runtime is not None:
            _runtime.shutdown()
        _runtime = None
    # Local imports: device/scheduler import this module at top level.
    from repro.core import device as _device
    from repro.core import scheduler as _scheduler

    _device._on_runtime_reset()
    _scheduler._on_runtime_reset()
    _elastic = sys.modules.get("repro.training.elastic")
    if _elastic is not None:  # never import the trainer just to reset it
        _elastic._on_runtime_reset()
